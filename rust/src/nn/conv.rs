//! Convolution via im2col + the packed GEMM path.
//!
//! [`im2col`] extracts stride-1, zero-padded patches with layout
//! (ky, kx, c) fastest-last, identical to
//! `python/compile/model.py::im2col` so weight tensors interchange
//! between the PJRT artifacts and this engine.  [`conv2d`] lowers the
//! convolution onto the same packed, tiled kernels every other GEMM in
//! the engine runs on (`nn::gemm::GemmPlan`).

use super::gemm::{Epilogue, GemmPlan};
use super::tensor::Tensor;

/// Convolution as im2col + packed GEMM: `x` is [B,H,W,C], `w2` the
/// kernel flattened to [kh*kw*C, cout] (pre-quantized, as
/// `Model::prepare` produces).  Returns [B*H*W, cout]; the caller
/// reshapes to [B,H,W,cout].  The im2col activations are rebuilt per
/// call (they depend on `x`); the *filter* panels come from the plan's
/// prepacked cache when present — the constant side of the GEMM is
/// conditioned exactly once, at `prepare`.
pub fn conv2d(plan: &GemmPlan, x: &Tensor, w2: &Tensor, kh: usize,
              kw: usize, pad: usize, threads: usize) -> Tensor {
    conv2d_with(plan, x, w2, kh, kw, pad, &Epilogue::None, threads)
}

/// [`conv2d`] with a fused [`Epilogue`] applied per cache-resident
/// output tile (per-channel bias indexed by `cout`, ReLU, requantize
/// for the consumer layer) — the model forward loop's conv path.
pub fn conv2d_with(plan: &GemmPlan, x: &Tensor, w2: &Tensor, kh: usize,
                   kw: usize, pad: usize, ep: &Epilogue,
                   threads: usize) -> Tensor {
    let cols = im2col(x, kh, kw, pad);
    let (m, k) = (cols.shape[0], cols.shape[1]);
    assert_eq!(w2.ndim(), 2, "conv weights must be [kh*kw*C, cout]");
    assert_eq!(w2.shape[0], k, "conv weight rows != patch length");
    let n = w2.shape[1];
    let mut out = Tensor::zeros(vec![m, n]);
    plan.run_cached_with(&cols.data, &w2.data, m, k, n, &mut out.data,
                         threads, ep);
    out
}

/// [B,H,W,C] -> [B*H*W, kh*kw*C] patches (stride 1, zero padding `pad`).
pub fn im2col(x: &Tensor, kh: usize, kw: usize, pad: usize) -> Tensor {
    assert_eq!(x.ndim(), 4, "im2col expects [B,H,W,C]");
    let (b, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let kcols = kh * kw * c;
    let mut out = vec![0.0f32; b * h * w * kcols];
    let xs = &x.data;

    for bi in 0..b {
        let xbase = bi * h * w * c;
        let obase = bi * h * w * kcols;
        for oy in 0..h {
            for ox in 0..w {
                let orow = obase + (oy * w + ox) * kcols;
                for ky in 0..kh {
                    let iy = oy as isize + ky as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // zero padding: leave zeros
                    }
                    let iy = iy as usize;
                    for kx in 0..kw {
                        let ix = ox as isize + kx as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let ix = ix as usize;
                        let src = xbase + (iy * w + ix) * c;
                        let dst = orow + (ky * kw + kx) * c;
                        out[dst..dst + c]
                            .copy_from_slice(&xs[src..src + c]);
                    }
                }
            }
        }
    }
    Tensor::new(vec![b * h * w, kcols], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_matches_python() {
        // same fixture as python/tests/test_model.py::test_im2col_layout
        let (b, h, w, c) = (1, 4, 4, 2);
        let data: Vec<f32> = (0..(b * h * w * c)).map(|v| v as f32).collect();
        let x = Tensor::new(vec![b, h, w, c], data);
        let cols = im2col(&x, 3, 3, 1);
        assert_eq!(cols.shape, vec![16, 18]);
        // patch at (y=1, x=1): center offset (ky=1, kx=1) is x[0,1,1,:]
        let row = 4 + 1; // y * W + x at (1, 1)
        let patch = &cols.data[row * 18..(row + 1) * 18];
        let center = &patch[(3 + 1) * 2..(3 + 1) * 2 + 2]; // ky*kw + kx
        let want = &x.data[row * 2..row * 2 + 2];
        assert_eq!(center, want);
        // top-left of patch (0,0) is padding
        let p00 = &cols.data[0..18];
        assert_eq!(&p00[0..2], &[0.0, 0.0]);
    }

    #[test]
    fn identity_kernel_1x1() {
        let x = Tensor::new(vec![1, 2, 2, 3],
                            (0..12).map(|v| v as f32).collect());
        let cols = im2col(&x, 1, 1, 0);
        assert_eq!(cols.shape, vec![4, 3]);
        assert_eq!(cols.data, x.data);
    }

    #[test]
    fn conv2d_identity_1x1() {
        use crate::approx::arith::ArithKind;
        let x = Tensor::new(vec![1, 2, 2, 3],
                            (0..12).map(|v| v as f32).collect());
        let mut wid = vec![0.0f32; 9];
        for i in 0..3 {
            wid[i * 3 + i] = 1.0;
        }
        let w2 = Tensor::new(vec![3, 3], wid);
        let plan = GemmPlan::new(&ArithKind::Float32);
        let out = conv2d(&plan, &x, &w2, 1, 1, 0, 1);
        assert_eq!(out.shape, vec![4, 3]);
        assert_eq!(out.data, x.data);
    }

    #[test]
    fn batch_independence() {
        let mut d = vec![0.0f32; 2 * 3 * 3];
        for (i, v) in d.iter_mut().enumerate() {
            *v = i as f32;
        }
        let x = Tensor::new(vec![2, 3, 3, 1], d.clone());
        let cols = im2col(&x, 3, 3, 1);
        // batch 1 patches only reference batch-1 pixels (>= 9)
        let b1 = &cols.data[9 * 9..];
        for &v in b1 {
            assert!(v == 0.0 || v >= 9.0, "batch leakage: {v}");
        }
    }
}
