//! Weight quantization: snap trained float32 parameters onto a target
//! representation once, ahead of inference — the paper's "converting some
//! pre-trained floating-point weights to fixed-point numbers with a
//! predefined bit-width" (§1), applied per partition part.

use super::tensor::Tensor;
use crate::approx::arith::ArithKind;

/// Quantize a tensor onto the provider's lattice (returns a new tensor).
pub fn quantize_tensor(kind: &ArithKind, t: &Tensor) -> Tensor {
    let mut out = t.clone();
    for v in &mut out.data {
        *v = kind.quantize(*v);
    }
    out
}

/// Mean squared quantization error — a quick proxy used in reports.
pub fn quantization_mse(kind: &ArithKind, t: &Tensor) -> f64 {
    if t.is_empty() {
        return 0.0;
    }
    let mut acc = 0f64;
    for &v in &t.data {
        let d = (kind.quantize(v) - v) as f64;
        acc += d * d;
    }
    acc / t.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn randn(n: usize, seed: u64, sigma: f64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(vec![n],
                    (0..n).map(|_| (rng.normal() * sigma) as f32).collect())
    }

    #[test]
    fn quantized_values_on_lattice() {
        let kind = ArithKind::parse("FI(4,6)").unwrap();
        let t = randn(500, 1, 3.0);
        let q = quantize_tensor(&kind, &t);
        for &v in &q.data {
            assert_eq!(kind.quantize(v), v);
        }
    }

    #[test]
    fn mse_decreases_with_more_bits() {
        let t = randn(2000, 2, 1.0);
        let coarse = quantization_mse(&ArithKind::parse("FI(2,3)").unwrap(),
                                      &t);
        let fine = quantization_mse(&ArithKind::parse("FI(2,10)").unwrap(),
                                    &t);
        assert!(fine < coarse, "fine {fine} >= coarse {coarse}");
        assert!(quantization_mse(&ArithKind::Float32, &t) == 0.0);
    }
}
