//! Topology-generic model description: [`NetSpec`] (an arbitrary
//! sequence of conv/dense layers, shapes validated at build time) and
//! [`ReprMap`] (one [`ArithKind`] per layer — the paper's layer-wise
//! partition, arity-checked against the spec).
//!
//! This is the API that retired the hardcoded 4-layer
//! `[ArithKind; 4]` config: the paper's Fig. 2 DCNN is now just the
//! [`NetSpec::paper_dcnn`] preset, and every consumer — `Model::prepare`,
//! the explorer, the plan cache, the server — iterates `spec.len()`
//! parts instead of indexing `0..4`.
//!
//! Three string forms, all round-trippable:
//!
//! * the **spec grammar** (`Display`/[`NetSpec::parse`]):
//!   `"28x28x1: conv(5x5,32,pad=2)+relu+pool | ... | dense(10)"` —
//!   input `HxWxC`, then `|`-separated layers; derived quantities
//!   (conv `cin`, dense `d_in`) are never written, they re-derive from
//!   the running shape;
//! * the **config grammar** ([`ReprMap::parse_for`]): the existing
//!   `"FI(6,8)|...|H(8,8,14)"` notation generalized to N layers — one
//!   segment broadcasts uniformly, otherwise the segment count must
//!   equal the spec's depth;
//! * the **structural fingerprint** ([`NetSpec::fingerprint`]):
//!   `"<spec> :: <kind|kind|...>"` — injective over (topology,
//!   assignment), the key `coordinator::plan_cache` stores prepared
//!   networks under (names are labels, not identity: two structurally
//!   equal specs share cache entries by design).

use crate::approx::arith::ArithKind;
use crate::nn::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt;

/// Activation applied to a layer's pre-activation output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    /// No nonlinearity (e.g. a logits layer).
    Linear,
    /// Rectified linear unit.
    Relu,
}

/// The parameterized operator of one layer.  Derived quantities
/// (`cin`, `d_in`) are filled in by the builder from the running
/// activation shape, never by the caller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Stride-1, zero-padded 2-D convolution (`same` spatial size;
    /// the builder requires a centered window, odd
    /// `kh == kw == 2*pad + 1`), lowered onto the packed GEMM path
    /// via im2col.
    Conv2d {
        kh: usize,
        kw: usize,
        cin: usize,
        cout: usize,
        pad: usize,
    },
    /// Fully-connected layer; a 4-D input flattens to `[b, d_in]`.
    Dense { d_in: usize, d_out: usize },
}

/// One layer of a [`NetSpec`]: operator + activation + optional 2x2
/// max-pool, plus the parameter-name stem (`conv1`, `fc2`, ...) the
/// weight map is keyed by.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerSpec {
    /// Parameter-name stem: weights live at `{name}_w`, biases at
    /// `{name}_b`.  Assigned by the builder (`convN` / `fcN`), so it
    /// is a function of the structure.
    pub name: String,
    pub kind: LayerKind,
    pub activation: Activation,
    /// 2x2 stride-2 max pooling after the activation (conv layers
    /// only; requires even spatial dims).
    pub pool: bool,
}

impl LayerSpec {
    /// `(weight shape, bias shape)` of this layer's parameters.
    /// Conv weights are stored `[kh, kw, cin, cout]` (flattened to
    /// `(kh*kw*cin, cout)` for the GEMM at prepare time), dense
    /// weights `[d_in, d_out]`.
    pub fn param_shapes(&self) -> (Vec<usize>, Vec<usize>) {
        match self.kind {
            LayerKind::Conv2d { kh, kw, cin, cout, .. } => {
                (vec![kh, kw, cin, cout], vec![cout])
            }
            LayerKind::Dense { d_in, d_out } => {
                (vec![d_in, d_out], vec![d_out])
            }
        }
    }
}

impl fmt::Display for LayerSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            LayerKind::Conv2d { kh, kw, cout, pad, .. } => {
                write!(f, "conv({kh}x{kw},{cout}")?;
                if pad > 0 {
                    write!(f, ",pad={pad}")?;
                }
                write!(f, ")")?;
            }
            LayerKind::Dense { d_out, .. } => {
                write!(f, "dense({d_out})")?;
            }
        }
        if self.activation == Activation::Relu {
            write!(f, "+relu")?;
        }
        if self.pool {
            write!(f, "+pool")?;
        }
        Ok(())
    }
}

/// An arbitrary-depth feed-forward topology: input shape plus a
/// validated sequence of [`LayerSpec`]s.  Construct through
/// [`NetSpec::builder`], [`NetSpec::parse`], or a preset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetSpec {
    input: [usize; 3],
    layers: Vec<LayerSpec>,
}

impl fmt::Display for NetSpec {
    /// The canonical spec-grammar string; [`NetSpec::parse`] of this
    /// output reconstructs an equal spec (round-trip pinned by
    /// `rust/tests/config_roundtrip.rs`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}x{}:", self.input[0], self.input[1],
               self.input[2])?;
        for (i, l) in self.layers.iter().enumerate() {
            if i > 0 {
                write!(f, " |")?;
            }
            write!(f, " {l}")?;
        }
        Ok(())
    }
}

impl NetSpec {
    /// Start a builder over an `[h, w, c]` input.
    pub fn builder(input: [usize; 3]) -> NetSpecBuilder {
        let err = if input.iter().any(|&d| d == 0) {
            Some(format!("input shape {input:?} has a zero dim"))
        } else {
            None
        };
        NetSpecBuilder {
            input,
            layers: Vec::new(),
            state: State::Spatial(input[0], input[1], input[2]),
            err,
            n_conv: 0,
            n_dense: 0,
        }
    }

    /// The paper's Fig. 2 DCNN as a preset: 28x28x1 → conv 5x5x32 →
    /// pool → conv 5x5x64 → pool → FC 1024 → FC 10.  Layer names come
    /// out as `conv1`, `conv2`, `fc1`, `fc2` — the same stems the LOPW
    /// artifact weights use.
    pub fn paper_dcnn() -> NetSpec {
        NetSpec::builder([28, 28, 1])
            .conv2d(5, 5, 32, 2)
            .relu()
            .pool()
            .conv2d(5, 5, 64, 2)
            .relu()
            .pool()
            .dense(1024)
            .relu()
            .dense(10)
            .build()
            .expect("paper preset is well-formed")
    }

    /// Resolve a preset name (`"paper_dcnn"`) or, failing that, parse
    /// `s` as spec grammar — the form config files and `--model` take.
    pub fn preset_or_parse(s: &str) -> Result<NetSpec, String> {
        match s.trim() {
            "paper_dcnn" => Ok(NetSpec::paper_dcnn()),
            other if other.contains(':') => NetSpec::parse(other),
            other => Err(format!(
                "unknown model '{other}' (expected the preset \
                 'paper_dcnn' or spec grammar like \
                 '28x28x1: dense(64)+relu | dense(10)')"
            )),
        }
    }

    /// Parse the spec grammar (the inverse of `Display`).  Errors name
    /// the offending layer index and token.
    pub fn parse(s: &str) -> Result<NetSpec, String> {
        let (head, body) = s.split_once(':').ok_or_else(|| {
            format!("missing ':' after the input shape in '{s}'")
        })?;
        let input = parse_dims(head.trim())?;
        let mut b = NetSpec::builder(input);
        let segs: Vec<&str> = body.split('|').map(str::trim).collect();
        for (i, seg) in segs.iter().enumerate() {
            let at = |m: String| {
                format!("layer {}/{}: {m}", i + 1, segs.len())
            };
            if seg.is_empty() {
                return Err(at(format!("empty segment in '{s}'")));
            }
            let mut mods = seg.split('+');
            let op = mods.next().unwrap().trim();
            if let Some(args) = strip_call(op, "conv") {
                let (kh, kw, cout, pad) =
                    parse_conv_args(args).map_err(&at)?;
                b = b.conv2d(kh, kw, cout, pad);
            } else if let Some(args) = strip_call(op, "dense") {
                let d_out = args.trim().parse::<usize>().map_err(|e| {
                    at(format!("bad dense width '{args}': {e}"))
                })?;
                b = b.dense(d_out);
            } else {
                return Err(at(format!("unknown layer op '{op}'")));
            }
            for m in mods {
                match m.trim() {
                    "relu" => b = b.relu(),
                    "pool" => b = b.pool(),
                    other => {
                        return Err(at(format!(
                            "unknown modifier '+{other}'"
                        )))
                    }
                }
            }
        }
        b.build()
    }

    /// Number of layers (= partition parts = [`ReprMap`] arity).
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// Always false — the builder rejects empty specs.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn layers(&self) -> &[LayerSpec] {
        &self.layers
    }

    /// Input activation shape `[h, w, c]` (batch dim excluded).
    pub fn input_shape(&self) -> [usize; 3] {
        self.input
    }

    /// Flattened input length `h * w * c` (the per-request image size
    /// the serving router validates against).
    pub fn input_len(&self) -> usize {
        self.input.iter().product()
    }

    /// Parameter tensor names in layer order, weights before biases
    /// (`conv1_w`, `conv1_b`, ...).
    pub fn param_names(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(2 * self.layers.len());
        for l in &self.layers {
            out.push(format!("{}_w", l.name));
            out.push(format!("{}_b", l.name));
        }
        out
    }

    /// Total trainable parameter count.
    pub fn param_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                let (w, b) = l.param_shapes();
                w.iter().product::<usize>() + b.iter().product::<usize>()
            })
            .sum()
    }

    /// Post-layer activation shapes (after pooling), one per layer:
    /// `[h, w, c]` for spatial layers, `[d]` after a dense layer.
    pub fn output_shapes(&self) -> Vec<Vec<usize>> {
        let mut cur = self.input.to_vec();
        let mut out = Vec::with_capacity(self.layers.len());
        for l in &self.layers {
            match l.kind {
                LayerKind::Conv2d { cout, .. } => {
                    cur[2] = cout;
                    if l.pool {
                        cur[0] /= 2;
                        cur[1] /= 2;
                    }
                }
                LayerKind::Dense { d_out, .. } => {
                    cur = vec![d_out];
                }
            }
            out.push(cur.clone());
        }
        out
    }

    /// Check a parameter map against this spec: every layer's
    /// `{name}_w` / `{name}_b` tensor must exist with the exact shape
    /// (extra tensors are ignored, as the LOPW loader may carry them).
    pub fn validate_params(&self,
                           params: &BTreeMap<String, Tensor>)
                           -> Result<()> {
        for l in &self.layers {
            let (wshape, bshape) = l.param_shapes();
            for (suffix, want) in [("w", wshape), ("b", bshape)] {
                let name = format!("{}_{suffix}", l.name);
                let t = params
                    .get(&name)
                    .with_context(|| format!("missing tensor '{name}'"))?;
                if t.shape != want {
                    bail!("tensor '{name}' has shape {:?}, want {want:?}",
                          t.shape);
                }
            }
        }
        Ok(())
    }

    /// Deterministic random input batch `[b, h, w, c]` with values in
    /// `[0, 1)` — the hermetic companion fixture to
    /// `Model::synthetic`, shared by tests/benches so the input
    /// contract cannot drift per copy.
    pub fn synthetic_input(&self, b: usize, seed: u64) -> Tensor {
        let mut rng = crate::util::prng::Rng::new(seed);
        let n = b * self.input_len();
        let [h, w, c] = self.input;
        Tensor::new(vec![b, h, w, c],
                    (0..n).map(|_| rng.range_f32(0.0, 1.0)).collect())
    }

    /// Multiply-accumulate count per layer for a single input sample —
    /// the workload term of the explorer's analytic latency surrogate
    /// (`coordinator::pareto::CostModel`).  Conv layers count the full
    /// `same`-size im2col GEMM (`h*w * kh*kw*cin * cout` at the
    /// layer's *input* spatial size); dense layers count
    /// `d_in * d_out`.
    pub fn layer_macs(&self) -> Vec<u64> {
        let (mut h, mut w) = (self.input[0], self.input[1]);
        let mut out = Vec::with_capacity(self.layers.len());
        for l in &self.layers {
            match l.kind {
                LayerKind::Conv2d { kh, kw, cin, cout, .. } => {
                    out.push((h * w * kh * kw * cin * cout) as u64);
                    if l.pool {
                        h /= 2;
                        w /= 2;
                    }
                }
                LayerKind::Dense { d_in, d_out } => {
                    out.push((d_in * d_out) as u64);
                }
            }
        }
        out
    }

    /// The canonical structural fingerprint of (this topology, `map`):
    /// the spec-grammar string plus every layer's full provider name.
    /// Injective over (structure, assignment) — two fingerprints are
    /// equal iff the specs are structurally equal and the assignments
    /// match layer for layer (pinned by
    /// `rust/tests/config_roundtrip.rs`).  `coordinator::plan_cache`
    /// keys prepared networks by this string.
    ///
    /// Panics on arity mismatch — parse-level APIs
    /// ([`ReprMap::parse_for`]) reject that before it can get here.
    pub fn fingerprint(&self, map: &ReprMap) -> String {
        assert_eq!(
            map.len(),
            self.len(),
            "ReprMap has {} kinds for a {}-layer spec",
            map.len(),
            self.len()
        );
        let kinds: Vec<String> =
            map.kinds().iter().map(|k| k.name()).collect();
        format!("{self} :: {}", kinds.join("|"))
    }

    /// Whether this spec is structurally the paper's Fig. 2 DCNN —
    /// the only topology the PJRT AOT artifacts implement, so the
    /// server's worker-mask split and the evaluator's backend choice
    /// gate on it.
    pub fn is_paper_dcnn(&self) -> bool {
        *self == NetSpec::paper_dcnn()
    }
}

fn parse_dims(s: &str) -> Result<[usize; 3], String> {
    let dims: Vec<usize> = s
        .split('x')
        .map(|d| d.trim().parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|e| format!("bad input shape '{s}': {e}"))?;
    match dims.as_slice() {
        [h, w, c] => Ok([*h, *w, *c]),
        _ => Err(format!("input shape '{s}' must be HxWxC")),
    }
}

/// `"conv(ARGS)"` with head `"conv"` → `Some("ARGS")`.
fn strip_call<'a>(s: &'a str, head: &str) -> Option<&'a str> {
    s.strip_prefix(head)?.trim().strip_prefix('(')?.strip_suffix(')')
}

/// `KHxKW,COUT[,pad=P]` → `(kh, kw, cout, pad)`.
fn parse_conv_args(args: &str)
                   -> Result<(usize, usize, usize, usize), String> {
    let parts: Vec<&str> = args.split(',').map(str::trim).collect();
    if parts.len() < 2 || parts.len() > 3 {
        return Err(format!(
            "conv takes 'KHxKW,COUT[,pad=P]', got '{args}'"
        ));
    }
    let (khs, kws) = parts[0].split_once('x').ok_or_else(|| {
        format!("conv kernel '{}' must be KHxKW", parts[0])
    })?;
    let num = |what: &str, s: &str| -> Result<usize, String> {
        s.trim()
            .parse::<usize>()
            .map_err(|e| format!("bad conv {what} '{s}': {e}"))
    };
    let kh = num("kernel height", khs)?;
    let kw = num("kernel width", kws)?;
    let cout = num("channel count", parts[1])?;
    let pad = match parts.get(2) {
        Some(p) => {
            let v = p.strip_prefix("pad=").ok_or_else(|| {
                format!("expected 'pad=P', got '{p}'")
            })?;
            num("padding", v)?
        }
        None => 0,
    };
    Ok((kh, kw, cout, pad))
}

#[derive(Clone, Copy)]
enum State {
    /// Running `[h, w, c]` activation shape.
    Spatial(usize, usize, usize),
    /// Flattened feature count (after the first dense layer).
    Flat(usize),
}

/// Fluent, shape-checked [`NetSpec`] constructor.  The first invalid
/// call records an error (with its layer index); `build` surfaces it.
pub struct NetSpecBuilder {
    input: [usize; 3],
    layers: Vec<LayerSpec>,
    state: State,
    err: Option<String>,
    n_conv: usize,
    n_dense: usize,
}

impl NetSpecBuilder {
    /// Record an error for a *layer-appending* op (conv2d/dense): the
    /// failing layer is the one that was about to be pushed, at index
    /// `layers.len() + 1`.
    fn fail(mut self, msg: String) -> Self {
        if self.err.is_none() {
            self.err = Some(format!("layer {}: {msg}",
                                    self.layers.len() + 1));
        }
        self
    }

    /// Record an error for a *modifier* op (relu/pool): these attach
    /// to the layer already pushed, so the failing layer is the last
    /// one — reported by index and name so a bad spec string (e.g. a
    /// pool at odd spatial dims) fails at build/parse time pointing at
    /// the offending layer, not mid-forward in `maxpool2`.
    fn fail_on_last(mut self, msg: String) -> Self {
        if self.err.is_none() {
            self.err = Some(match self.layers.last() {
                Some(l) => format!("layer {} ({}): {msg}",
                                   self.layers.len(), l.name),
                None => format!("layer 1: {msg}"),
            });
        }
        self
    }

    /// Append a stride-1 zero-padded convolution producing `cout`
    /// channels.  The window must be centered (odd
    /// `kh == kw == 2*pad + 1` — what the engine's fixed-grid im2col
    /// actually computes); invalid after a dense layer (the input is
    /// flat).
    pub fn conv2d(mut self, kh: usize, kw: usize, cout: usize,
                  pad: usize) -> Self {
        if self.err.is_some() {
            return self;
        }
        let (h, w, c) = match self.state {
            State::Spatial(h, w, c) => (h, w, c),
            State::Flat(_) => {
                return self.fail("conv2d after a dense layer \
                                  (input already flattened)"
                    .into());
            }
        };
        if kh == 0 || kw == 0 || cout == 0 {
            return self.fail(format!(
                "conv2d({kh}x{kw},{cout}) has a zero parameter"
            ));
        }
        // The engine's im2col anchors every kernel window at
        // (oy - pad, ox - pad) over a fixed HxW output grid, so the
        // operation is a standard 'same' convolution ONLY when the
        // window is centered: odd kh == kw == 2*pad + 1.  Any other
        // pad would silently compute a spatially shifted op, so
        // reject it here instead of mis-multiplying at runtime.
        if kh != 2 * pad + 1 || kw != 2 * pad + 1 {
            return self.fail(format!(
                "conv2d({kh}x{kw}, pad={pad}) is not centered: the \
                 'same'-size engine needs odd kh == kw == 2*pad + 1 \
                 (e.g. 3x3 with pad=1, 5x5 with pad=2)"
            ));
        }
        // centered kernels always fit: 2*pad + 1 <= h + 2*pad for
        // any h >= 1, so no separate size check is needed
        self.n_conv += 1;
        self.layers.push(LayerSpec {
            name: format!("conv{}", self.n_conv),
            kind: LayerKind::Conv2d { kh, kw, cin: c, cout, pad },
            activation: Activation::Linear,
            pool: false,
        });
        self.state = State::Spatial(h, w, cout);
        self
    }

    /// Append a fully-connected layer with `d_out` outputs; a spatial
    /// input flattens to `h * w * c` features.
    pub fn dense(mut self, d_out: usize) -> Self {
        if self.err.is_some() {
            return self;
        }
        if d_out == 0 {
            return self.fail("dense(0) has no outputs".into());
        }
        let d_in = match self.state {
            State::Spatial(h, w, c) => h * w * c,
            State::Flat(n) => n,
        };
        self.n_dense += 1;
        self.layers.push(LayerSpec {
            name: format!("fc{}", self.n_dense),
            kind: LayerKind::Dense { d_in, d_out },
            activation: Activation::Linear,
            pool: false,
        });
        self.state = State::Flat(d_out);
        self
    }

    /// ReLU on the most recent layer's output.
    pub fn relu(mut self) -> Self {
        if self.err.is_some() {
            return self;
        }
        match self.layers.last_mut() {
            None => self.fail_on_last("relu before any layer".into()),
            Some(l) if l.activation == Activation::Relu => {
                self.fail_on_last("duplicate relu".into())
            }
            Some(l) => {
                l.activation = Activation::Relu;
                self
            }
        }
    }

    /// 2x2 stride-2 max pooling after the most recent (conv) layer;
    /// requires even spatial dims.
    pub fn pool(mut self) -> Self {
        if self.err.is_some() {
            return self;
        }
        let (h, w, c) = match self.state {
            State::Spatial(h, w, c) => (h, w, c),
            State::Flat(_) => {
                return self.fail_on_last(
                    "pool on a flattened (dense) output".into());
            }
        };
        match self.layers.last_mut() {
            None => self.fail_on_last("pool before any layer".into()),
            Some(l) if l.pool => {
                self.fail_on_last("duplicate pool".into())
            }
            Some(l) if !matches!(l.kind, LayerKind::Conv2d { .. }) => {
                self.fail_on_last("pool only follows conv layers"
                    .into())
            }
            Some(_) if h % 2 != 0 || w % 2 != 0 => {
                self.fail_on_last(format!(
                    "pool needs even spatial dims, have {h}x{w}"
                ))
            }
            Some(l) => {
                l.pool = true;
                self.state = State::Spatial(h / 2, w / 2, c);
                self
            }
        }
    }

    /// Finish: the validated spec, or the first recorded error.
    pub fn build(self) -> Result<NetSpec, String> {
        if let Some(e) = self.err {
            return Err(e);
        }
        if self.layers.is_empty() {
            return Err("a NetSpec needs at least one layer".into());
        }
        Ok(NetSpec { input: self.input, layers: self.layers })
    }
}

/// Per-layer representation assignment — the network *configuration*
/// (formerly the fixed-arity `NetConfig`): one [`ArithKind`] per
/// [`NetSpec`] layer.  Arity is fixed at construction; the
/// spec-checked entry points ([`ReprMap::parse_for`],
/// [`ReprMap::uniform_for`]) guarantee it matches the topology.
#[derive(Clone, Debug, PartialEq)]
pub struct ReprMap {
    kinds: Vec<ArithKind>,
}

impl fmt::Display for ReprMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.name())
    }
}

impl ReprMap {
    /// Explicit per-layer assignment.  Panics on an empty vector
    /// (no spec has zero layers).
    pub fn from_kinds(kinds: Vec<ArithKind>) -> ReprMap {
        assert!(!kinds.is_empty(), "a ReprMap needs at least one layer");
        ReprMap { kinds }
    }

    /// `kind` broadcast over `n` layers.
    pub fn uniform(kind: ArithKind, n: usize) -> ReprMap {
        ReprMap::from_kinds(vec![kind; n])
    }

    /// `kind` broadcast over every layer of `spec`.
    pub fn uniform_for(spec: &NetSpec, kind: ArithKind) -> ReprMap {
        ReprMap::uniform(kind, spec.len())
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    /// Always false — construction rejects empty assignments.
    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    pub fn kinds(&self) -> &[ArithKind] {
        &self.kinds
    }

    pub fn kind(&self, layer: usize) -> &ArithKind {
        &self.kinds[layer]
    }

    /// Reassign one layer's provider (the explorer's per-part move).
    pub fn set(&mut self, layer: usize, kind: ArithKind) {
        self.kinds[layer] = kind;
    }

    /// Human name: the single provider name when uniform, else the
    /// `" | "`-joined per-layer names.  Parses back via
    /// [`ReprMap::parse_for`] against the same-arity spec.
    pub fn name(&self) -> String {
        if self.kinds.iter().all(|k| k == &self.kinds[0]) {
            self.kinds[0].name()
        } else {
            self.kinds
                .iter()
                .map(|k| k.name())
                .collect::<Vec<_>>()
                .join(" | ")
        }
    }

    /// Parse the config grammar against `spec`: one segment
    /// broadcasts uniformly, otherwise exactly `spec.len()` segments.
    pub fn parse_for(spec: &NetSpec, s: &str)
                     -> Result<ReprMap, String> {
        ReprMap::parse_n(s, spec.len())
    }

    /// [`ReprMap::parse_for`] with an explicit arity.  Errors name
    /// the offending layer index and token; empty segments (e.g.
    /// `"FI(6,8)||float32"`) are rejected rather than skipped.
    pub fn parse_n(s: &str, n: usize) -> Result<ReprMap, String> {
        assert!(n > 0, "a ReprMap needs at least one layer");
        let parts: Vec<&str> = s.split('|').map(str::trim).collect();
        for (i, p) in parts.iter().enumerate() {
            if p.is_empty() {
                return Err(format!(
                    "layer {}/{}: empty segment in '{s}'",
                    i + 1,
                    parts.len()
                ));
            }
        }
        if parts.len() == 1 {
            let k = ArithKind::parse(parts[0]).map_err(|e| {
                format!("layer 1/1 ('{}'): {e}", parts[0])
            })?;
            return Ok(ReprMap::uniform(k, n));
        }
        if parts.len() != n {
            return Err(format!(
                "expected 1 or {n} layer configs in '{s}', got {}",
                parts.len()
            ));
        }
        let kinds = parts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                ArithKind::parse(p).map_err(|e| {
                    format!("layer {}/{n} ('{p}'): {e}", i + 1)
                })
            })
            .collect::<Result<Vec<_>, _>>()?;
        Ok(ReprMap::from_kinds(kinds))
    }

    /// True when every layer is PJRT-expressible (exact arithmetic).
    pub fn pjrt_expressible(&self) -> bool {
        self.kinds.iter().all(|k| k.pjrt_expressible())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_fig2() {
        let s = NetSpec::paper_dcnn();
        assert_eq!(s.len(), 4);
        assert_eq!(s.input_shape(), [28, 28, 1]);
        assert_eq!(s.input_len(), 784);
        assert!(s.is_paper_dcnn());
        assert_eq!(
            s.param_names(),
            vec!["conv1_w", "conv1_b", "conv2_w", "conv2_b", "fc1_w",
                 "fc1_b", "fc2_w", "fc2_b"]
        );
        assert_eq!(s.layers()[2].param_shapes().0, vec![3136, 1024]);
        assert_eq!(s.output_shapes(),
                   vec![vec![14, 14, 32], vec![7, 7, 64], vec![1024],
                        vec![10]]);
        let params = 5 * 5 * 32 + 32 + 5 * 5 * 32 * 64 + 64
            + 3136 * 1024 + 1024 + 1024 * 10 + 10;
        assert_eq!(s.param_count(), params);
    }

    #[test]
    fn display_parse_roundtrip_paper() {
        let s = NetSpec::paper_dcnn();
        let text = s.to_string();
        assert_eq!(
            text,
            "28x28x1: conv(5x5,32,pad=2)+relu+pool | \
             conv(5x5,64,pad=2)+relu+pool | dense(1024)+relu | \
             dense(10)"
        );
        assert_eq!(NetSpec::parse(&text).unwrap(), s);
    }

    #[test]
    fn mlp_spec_builds_and_roundtrips() {
        let s = NetSpec::parse(
            "28x28x1: dense(256)+relu | dense(128)+relu | \
             dense(64)+relu | dense(32)+relu | dense(10)",
        )
        .unwrap();
        assert_eq!(s.len(), 5);
        assert!(!s.is_paper_dcnn());
        assert_eq!(s.layers()[0].param_shapes().0, vec![784, 256]);
        assert_eq!(s.layers()[0].name, "fc1");
        assert_eq!(s.layers()[4].name, "fc5");
        assert_eq!(NetSpec::parse(&s.to_string()).unwrap(), s);
    }

    #[test]
    fn builder_rejects_bad_shapes() {
        // pool on odd dims: 28 -> 14 -> 7, a third pool must fail
        let e = NetSpec::builder([28, 28, 1])
            .conv2d(3, 3, 4, 1)
            .pool()
            .conv2d(3, 3, 4, 1)
            .pool()
            .conv2d(3, 3, 4, 1)
            .pool()
            .build()
            .unwrap_err();
        assert!(e.contains("even spatial"), "{e}");
        // conv after dense
        let e = NetSpec::builder([8, 8, 1])
            .dense(4)
            .conv2d(3, 3, 2, 1)
            .build()
            .unwrap_err();
        assert!(e.contains("layer 2") && e.contains("flattened"), "{e}");
        // non-centered windows (the engine's fixed-grid im2col would
        // silently compute a shifted op) are rejected up front
        let e = NetSpec::builder([8, 8, 1])
            .conv2d(3, 3, 2, 0) // 3x3 needs pad=1
            .build()
            .unwrap_err();
        assert!(e.contains("not centered"), "{e}");
        let e = NetSpec::builder([8, 8, 1])
            .conv2d(2, 2, 2, 1) // even kernels have no centered pad
            .build()
            .unwrap_err();
        assert!(e.contains("not centered"), "{e}");
        let e = NetSpec::parse("8x8x1: conv(5x5,4,pad=1) | dense(2)")
            .unwrap_err();
        assert!(e.contains("not centered"), "{e}");
        // no layers at all
        assert!(NetSpec::builder([4, 4, 1]).build().is_err());
        // modifiers without / duplicated on a layer
        assert!(NetSpec::builder([4, 4, 1]).relu().build().is_err());
        let e = NetSpec::builder([4, 4, 1])
            .dense(2)
            .relu()
            .relu()
            .build()
            .unwrap_err();
        assert!(e.contains("duplicate relu"), "{e}");
        let e = NetSpec::builder([4, 4, 1])
            .dense(2)
            .pool()
            .build()
            .unwrap_err();
        assert!(e.contains("pool"), "{e}");
    }

    #[test]
    fn modifier_errors_name_the_offending_layer() {
        // Modifier (relu/pool) errors attach to the layer already
        // pushed — index *and* name — so a bad spec fails at build
        // time pointing at the right layer instead of panicking
        // mid-forward in `maxpool2`.  28 -> 14 -> 7: the third pool
        // sees odd 7x7 on conv3.
        let e = NetSpec::builder([28, 28, 1])
            .conv2d(3, 3, 4, 1)
            .pool()
            .conv2d(3, 3, 4, 1)
            .pool()
            .conv2d(3, 3, 4, 1)
            .pool()
            .build()
            .unwrap_err();
        assert!(
            e.contains("layer 3 (conv3)")
                && e.contains("pool needs even spatial dims, have 7x7"),
            "{e}"
        );
        // same failure through the parse-level grammar
        let e = NetSpec::parse(
            "28x28x1: conv(3x3,4,pad=1)+pool | conv(3x3,4,pad=1)+pool \
             | conv(3x3,4,pad=1)+pool | dense(10)",
        )
        .unwrap_err();
        assert!(e.contains("conv3") && e.contains("7x7"), "{e}");
        // duplicate relu names the dense layer it modifies
        let e = NetSpec::builder([4, 4, 1])
            .dense(2)
            .relu()
            .relu()
            .build()
            .unwrap_err();
        assert!(e.contains("layer 1 (fc1)")
                    && e.contains("duplicate relu"),
                "{e}");
        // pool on a dense output names the dense layer
        let e = NetSpec::builder([4, 4, 1])
            .dense(2)
            .pool()
            .build()
            .unwrap_err();
        assert!(e.contains("layer 1 (fc1)") && e.contains("flattened"),
                "{e}");
        // modifiers before any layer report layer 1 without a name
        let e = NetSpec::builder([4, 4, 1]).relu().build().unwrap_err();
        assert!(e.contains("layer 1: relu before any layer"), "{e}");
    }

    #[test]
    fn parse_errors_name_the_layer() {
        let e = NetSpec::parse("28x28x1: dense(10) |  | dense(4)")
            .unwrap_err();
        assert!(e.contains("layer 2/3") && e.contains("empty segment"),
                "{e}");
        let e = NetSpec::parse("28x28x1: blorp(3)").unwrap_err();
        assert!(e.contains("layer 1/1") && e.contains("blorp"), "{e}");
        let e = NetSpec::parse("28x28x1: dense(10)+swish").unwrap_err();
        assert!(e.contains("+swish"), "{e}");
        assert!(NetSpec::parse("dense(10)").unwrap_err()
            .contains("missing ':'"));
        assert!(NetSpec::parse("28x28: dense(10)").unwrap_err()
            .contains("HxWxC"));
    }

    #[test]
    fn fingerprint_separates_structure_and_assignment() {
        let paper = NetSpec::paper_dcnn();
        let mlp =
            NetSpec::parse("28x28x1: dense(64)+relu | dense(10)")
                .unwrap();
        let u4 = ReprMap::uniform_for(&paper, ArithKind::Float32);
        let u2 = ReprMap::uniform_for(&mlp, ArithKind::Float32);
        // same (spec, map) -> same fingerprint
        assert_eq!(paper.fingerprint(&u4),
                   NetSpec::paper_dcnn().fingerprint(&u4));
        // different topology, same uniform kind -> different
        assert_ne!(paper.fingerprint(&u4), mlp.fingerprint(&u2));
        // same topology, different assignment -> different
        let mut v4 = u4.clone();
        v4.set(2, ArithKind::parse("FI(6,8)").unwrap());
        assert_ne!(paper.fingerprint(&u4), paper.fingerprint(&v4));
    }

    #[test]
    #[should_panic(expected = "ReprMap has 2 kinds")]
    fn fingerprint_rejects_arity_mismatch() {
        let paper = NetSpec::paper_dcnn();
        let two = ReprMap::uniform(ArithKind::Float32, 2);
        paper.fingerprint(&two);
    }

    #[test]
    fn reprmap_parse_for_checks_arity() {
        let mlp = NetSpec::parse(
            "28x28x1: dense(64)+relu | dense(32)+relu | dense(10)",
        )
        .unwrap();
        // broadcast
        let u = ReprMap::parse_for(&mlp, "FI(6,8)").unwrap();
        assert_eq!(u.len(), 3);
        assert_eq!(u.name(), "FI(6, 8)");
        // exact arity
        let m =
            ReprMap::parse_for(&mlp, "FI(6,8)|FL(4,9)|H(8,8,14)")
                .unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m.kind(1).name(), "FL(4, 9)");
        // wrong arity names both counts
        let e = ReprMap::parse_for(&mlp, "FI(6,8)|FL(4,9)")
            .unwrap_err();
        assert!(e.contains("expected 1 or 3") && e.contains("got 2"),
                "{e}");
    }

    #[test]
    fn reprmap_parse_rejects_empty_segments_with_index() {
        let e = ReprMap::parse_n("FI(6,8)||float32", 3).unwrap_err();
        assert!(e.contains("layer 2/3") && e.contains("empty segment"),
                "{e}");
        let e = ReprMap::parse_n("", 3).unwrap_err();
        assert!(e.contains("empty segment"), "{e}");
        let e = ReprMap::parse_n("FI(6,8)|XX(1)|float32", 3)
            .unwrap_err();
        assert!(e.contains("layer 2/3") && e.contains("XX(1)"), "{e}");
    }

    #[test]
    fn layer_macs_count_the_gemm_workload() {
        // paper DCNN: conv1 28*28*5*5*1*32, conv2 14*14*5*5*32*64,
        // fc1 3136*1024, fc2 1024*10
        assert_eq!(NetSpec::paper_dcnn().layer_macs(),
                   vec![627_200, 10_035_200, 3_211_264, 10_240]);
        let mlp = NetSpec::parse("28x28x1: dense(64)+relu | dense(10)")
            .unwrap();
        assert_eq!(mlp.layer_macs(), vec![784 * 64, 64 * 10]);
    }

    #[test]
    fn synthetic_input_shapes_follow_the_spec() {
        let s = NetSpec::parse("6x4x2: dense(3)").unwrap();
        let x = s.synthetic_input(5, 1);
        assert_eq!(x.shape, vec![5, 6, 4, 2]);
        assert!(x.data.iter().all(|&v| (0.0..1.0).contains(&v)));
        // deterministic in the seed
        assert_eq!(s.synthetic_input(5, 1).data, x.data);
    }
}
