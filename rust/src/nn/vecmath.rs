//! Vectorized elementwise layer (rten-vecmath shape): every
//! activation-tensor walk in the engine goes through one of the
//! `_in_place` slice routines here, each a thin loop over the single
//! scalar definition of the op — so fused GEMM epilogues
//! (`gemm::Epilogue`), the standalone layer ops in [`super::layers`],
//! and future heads (softmax, sigmoid for sequence models) share one
//! semantics per op instead of re-deriving it per call site.
//!
//! # Pass counters
//!
//! Every `_in_place` call counts one *pass* over its slice, per op, in
//! thread-local [`PassCounts`] (mirroring `gemm::pack`'s
//! `weight_pack_count` pattern).  The fused epilogue path inside the
//! blocked GEMM driver never routes through this module, so
//! `tests/epilogue_differential.rs` pins the fusion contract
//! structurally: a `dense+relu` / `conv+relu` forward must leave the
//! `bias` and `relu` counters untouched — zero standalone tensor
//! passes, not merely equal output.

use crate::approx::arith::ArithKind;
use std::cell::Cell;
use std::sync::{Arc, OnceLock};

// ---------------------------------------------------------------- scalar ops

/// The relu: `if x < 0.0 { 0.0 } else { x }`.  The *branch* form, not
/// `max`: the branch keeps `-0.0` and NaN untouched, and the fused
/// epilogues (`gemm::Epilogue`, scalar and AVX2) replicate exactly
/// these semantics — one definition, pinned bit-for-bit by
/// `tests/epilogue_differential.rs`.
#[inline]
pub fn relu(x: f32) -> f32 {
    if x < 0.0 {
        0.0
    } else {
        x
    }
}

/// The logistic sigmoid `1 / (1 + e^-x)` (future sequence-model heads;
/// not yet fused into any epilogue).
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

// ----------------------------------------------------------- slice variants

/// ReLU every element of `xs` (one counted pass).
pub fn relu_in_place(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = relu(*v);
    }
    note(|c| c.relu += 1);
}

/// Broadcast-add `bias` over `xs` rows of `bias.len()` columns (one
/// counted pass).  `xs.len()` must be a multiple of `bias.len()`.
pub fn add_bias_in_place(xs: &mut [f32], bias: &[f32]) {
    assert!(!bias.is_empty(), "empty bias");
    assert_eq!(xs.len() % bias.len(), 0,
               "tensor of {} elements is not rows of {} columns",
               xs.len(), bias.len());
    for row in xs.chunks_mut(bias.len()) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += *b;
        }
    }
    note(|c| c.bias += 1);
}

/// Snap every element of `xs` onto `kind`'s representation lattice
/// (one counted pass).
pub fn quantize_in_place(kind: &ArithKind, xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = kind.quantize(*v);
    }
    note(|c| c.quantize += 1);
}

/// Numerically-stable softmax over rows of `width` columns, in place
/// (one counted pass).  Max-shift, exponentiate, normalize — the same
/// op order as the historical `layers::softmax`, which now routes
/// through here.
pub fn softmax_in_place(xs: &mut [f32], width: usize) {
    assert!(width >= 1, "softmax needs >= 1 column");
    assert_eq!(xs.len() % width, 0,
               "tensor of {} elements is not rows of {width} columns",
               xs.len());
    for row in xs.chunks_mut(width) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    note(|c| c.softmax += 1);
}

/// Sigmoid every element of `xs` (one counted pass).
pub fn sigmoid_in_place(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = sigmoid(*v);
    }
    note(|c| c.sigmoid += 1);
}

// ------------------------------------------------------------ pass counters

/// Per-op tensor-pass counts (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PassCounts {
    pub bias: u64,
    pub relu: u64,
    pub quantize: u64,
    pub softmax: u64,
    pub sigmoid: u64,
}

impl PassCounts {
    /// Sum over all ops — handy for "no passes at all" assertions.
    pub fn total(&self) -> u64 {
        self.bias + self.relu + self.quantize + self.softmax
            + self.sigmoid
    }
}

thread_local! {
    static PASSES: Cell<PassCounts> =
        const { Cell::new(PassCounts { bias: 0, relu: 0, quantize: 0,
                                       softmax: 0, sigmoid: 0 }) };
}

/// Cross-thread total (all ops, all threads) — the coarse companion to
/// the precise thread-local [`pass_counts`], for tests whose layer
/// work may run on pool threads.  Lives on the global telemetry
/// registry as `vecmath.passes`, so serving snapshots export it.
fn passes_global() -> &'static Arc<crate::telemetry::Counter> {
    static C: OnceLock<Arc<crate::telemetry::Counter>> = OnceLock::new();
    C.get_or_init(|| crate::telemetry::global().counter("vecmath.passes"))
}

fn note(f: impl FnOnce(&mut PassCounts)) {
    PASSES.with(|c| {
        let mut v = c.get();
        f(&mut v);
        c.set(v);
    });
    passes_global().inc();
}

/// This thread's per-op pass counts since thread start.  Tests
/// snapshot before / after and compare deltas.
pub fn pass_counts() -> PassCounts {
    PASSES.with(|c| c.get())
}

/// Process-wide total passes across all ops and threads.
pub fn pass_count_global() -> u64 {
    passes_global().get()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_branch_semantics() {
        assert_eq!(relu(2.5), 2.5);
        assert_eq!(relu(-2.5), 0.0);
        assert_eq!(relu(0.0), 0.0);
        // the branch keeps -0.0 (max would flip it to +0.0)
        assert_eq!(relu(-0.0).to_bits(), (-0.0f32).to_bits());
        assert!(relu(f32::NAN).is_nan());
    }

    #[test]
    fn slice_ops_match_scalar_defs() {
        let before = pass_counts();
        let mut xs = vec![-1.0f32, 0.5, -0.0, 3.0];
        relu_in_place(&mut xs);
        assert_eq!(xs, vec![0.0, 0.5, -0.0, 3.0]);

        let mut xs = vec![0.0f32; 6];
        add_bias_in_place(&mut xs, &[1.0, 2.0, 3.0]);
        assert_eq!(xs, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);

        let kind = ArithKind::parse("FI(2,2)").unwrap();
        let mut xs = vec![0.3f32, -0.3, 10.0];
        quantize_in_place(&kind, &mut xs);
        assert_eq!(xs,
                   vec![kind.quantize(0.3), kind.quantize(-0.3),
                        kind.quantize(10.0)]);

        let mut xs = vec![0.0f32, 1.0];
        sigmoid_in_place(&mut xs);
        assert_eq!(xs, vec![sigmoid(0.0), sigmoid(1.0)]);
        assert_eq!(xs[0], 0.5);

        let after = pass_counts();
        assert_eq!(after.relu - before.relu, 1);
        assert_eq!(after.bias - before.bias, 1);
        assert_eq!(after.quantize - before.quantize, 1);
        assert_eq!(after.sigmoid - before.sigmoid, 1);
    }

    #[test]
    fn softmax_rows_normalize() {
        let before = pass_counts().softmax;
        let mut xs = vec![1.0f32, 2.0, 3.0, -5.0, 0.0, 5.0];
        softmax_in_place(&mut xs, 3);
        for row in xs.chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
            assert!(row.iter().all(|&v| v > 0.0));
        }
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
        assert_eq!(pass_counts().softmax - before, 1);
    }

    #[test]
    fn global_counter_moves_with_any_pass() {
        let g0 = pass_count_global();
        relu_in_place(&mut [1.0, -1.0]);
        assert!(pass_count_global() > g0);
    }

    #[test]
    #[should_panic(expected = "not rows of")]
    fn bias_rejects_ragged_tensor() {
        add_bias_in_place(&mut [0.0; 5], &[1.0, 2.0]);
    }
}
