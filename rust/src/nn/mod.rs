//! Bit-accurate NN inference engine — the Rust analogue of "LopPy
//! integrated into an ML framework" (paper §4.3): arbitrary
//! [`spec::NetSpec`] topologies (the paper's DCNN is the
//! [`spec::NetSpec::paper_dcnn`] preset) with every MAC routed through
//! a configurable (representation × arithmetic) provider per layer
//! ([`spec::ReprMap`]), including the approximate multipliers the PJRT
//! path cannot express.
//!
//! Layer semantics mirror `python/compile/model.py` exactly: values are
//! snapped onto the representation lattice as they enter each layer's MAC
//! array (weights/biases pre-quantized), partial sums accumulate wide
//! (the paper widens the integral-bit BCI for partial-sum range, §4.2).

pub mod conv;
pub mod gemm;
pub mod layers;
pub mod loader;
pub mod network;
pub mod quantizer;
pub mod spec;
pub mod tensor;
pub mod vecmath;

pub use network::{Dcnn, Model, PreparedNet};
pub use spec::{NetSpec, ReprMap};
pub use tensor::Tensor;
