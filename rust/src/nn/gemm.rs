//! Monomorphized GEMM kernels — one per arithmetic provider, no dispatch
//! inside MAC loops.  This is the L3 performance hot path (§Perf in
//! EXPERIMENTS.md records the optimization iterations).
//!
//! All kernels compute `out[m,n] = quant(x)[m,k] · w[k,n]` with *wide*
//! accumulation (i64 for fixed-point codes, f64 for float lattices),
//! mirroring the widened-partial-sum datapath of the paper (§4.2) and the
//! f32-accumulation semantics of the PJRT artifacts.
//!
//! Key optimizations (kept because they measured >5% each, see
//! EXPERIMENTS.md §Perf):
//!   * operand conditioning is hoisted out of the inner loop — quantize /
//!     encode / DRUM-condition each operand once (O(mk + kn)), so inner
//!     loops are plain integer/float MACs;
//!   * row-parallel execution over a scoped thread pool;
//!   * 4-wide j-unrolling on the integer kernels (autovectorizes).

use crate::approx::arith::ArithKind;
use crate::approx::cfpu::CfpuMul;
use crate::approx::drum::{drum_approx_operand, DrumMul};
use crate::numeric::{BinXnor, FixedPoint, FloatRep, Representation};

/// Threads used by row-parallel GEMM (0 = all available cores).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// `out = quant(x) @ w` for any provider.  `w` must already be quantized
/// (the layer does this once at load time).  `out.len() == m * n`.
///
/// ```
/// use lop::approx::arith::ArithKind;
/// use lop::nn::gemm::gemm;
///
/// // FI(6, 8): x entries below are exactly representable, and an
/// // identity weight matrix is on every lattice, so the product is
/// // exact — out equals x.
/// let kind = ArithKind::parse("FI(6,8)").unwrap();
/// let x = [0.5f32, -1.0, 2.0, 0.25]; // 2 x 2, row-major
/// let w = [1.0f32, 0.0, 0.0, 1.0]; // identity, pre-quantized
/// let mut out = [0.0f32; 4];
/// gemm(&kind, &x, &w, 2, 2, 2, &mut out, 1);
/// assert_eq!(out, x);
/// ```
pub fn gemm(kind: &ArithKind, x: &[f32], w: &[f32], m: usize, k: usize,
            n: usize, out: &mut [f32], threads: usize) {
    assert_eq!(x.len(), m * k, "x shape mismatch");
    assert_eq!(w.len(), k * n, "w shape mismatch");
    assert_eq!(out.len(), m * n, "out shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    match kind {
        ArithKind::Float32 => gemm_f32(x, w, m, k, n, out, threads),
        ArithKind::FixedExact(rep) => {
            let xc = encode_fixed(rep, x);
            let wc = encode_fixed(rep, w);
            gemm_int(&xc, &wc, m, k, n, out, 2 * rep.f_bits, threads);
        }
        ArithKind::FixedDrum(d) => {
            let xc = encode_fixed_drum(d, x);
            let wc = encode_fixed_drum(d, w);
            gemm_int(&xc, &wc, m, k, n, out, 2 * d.rep.f_bits, threads);
        }
        ArithKind::FloatExact(rep) => {
            let xq = quantize_f64(rep, x);
            let wq = quantize_f64(rep, w);
            gemm_f64(&xq, &wq, m, k, n, out, threads);
        }
        ArithKind::FloatCfpu(c) => {
            gemm_cfpu(c, x, w, m, k, n, out, threads);
        }
        ArithKind::Binary => gemm_binary(x, w, m, k, n, out, threads),
    }
}

/// Split `out` into row chunks and run `body(row0, rows_chunk)` on a scoped
/// thread pool.
fn row_parallel<F>(out: &mut [f32], m: usize, n: usize, threads: usize,
                   body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let threads = if threads == 0 { default_threads() } else { threads };
    let threads = threads.min(m.max(1));
    if threads <= 1 || m * n < 16 * 1024 {
        body(0, out);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
            let body = &body;
            s.spawn(move || body(t * rows_per, chunk));
        }
    });
}

// ---------------------------------------------------------------------------
// float32 baseline
// ---------------------------------------------------------------------------

fn gemm_f32(x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
            out: &mut [f32], threads: usize) {
    row_parallel(out, m, n, threads, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let xrow = &x[(row0 + r) * k..(row0 + r + 1) * k];
            orow.fill(0.0);
            // (i,k,j) loop order: stream w rows, accumulate into out row —
            // autovectorizes on the j axis.
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[kk * n..(kk + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// fixed-point code paths (exact and DRUM)
// ---------------------------------------------------------------------------

/// Signed magnitude code: sign(x) * code_of(|x|); fits i32 for i+f <= 30.
fn encode_fixed(rep: &FixedPoint, xs: &[f32]) -> Vec<i32> {
    xs.iter()
        .map(|&x| {
            let k = rep.code_of(x) as i32;
            if x < 0.0 {
                -k
            } else {
                k
            }
        })
        .collect()
}

/// Signed DRUM-conditioned code: conditioning commutes with the product
/// (drum_mul(a,b) = approx(a) * approx(b)), so hoisting it out of the MAC
/// loop is exact, not an approximation of the approximation.
fn encode_fixed_drum(d: &DrumMul, xs: &[f32]) -> Vec<i32> {
    xs.iter()
        .map(|&x| {
            let k = drum_approx_operand(d.rep.code_of(x), d.t) as i32;
            if x < 0.0 {
                -k
            } else {
                k
            }
        })
        .collect()
}

/// Integer GEMM over signed codes with i64 accumulation; result scaled by
/// 2^-frac2 (`frac2 = 2f`: products carry doubled fractional bits).
fn gemm_int(xc: &[i32], wc: &[i32], m: usize, k: usize, n: usize,
            out: &mut [f32], frac2: u32, threads: usize) {
    let inv = 1.0f64 / (1u64 << frac2) as f64;
    row_parallel(out, m, n, threads, |row0, chunk| {
        let mut acc = vec![0i64; n];
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            acc.fill(0);
            let xrow = &xc[(row0 + r) * k..(row0 + r + 1) * k];
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0 {
                    continue;
                }
                let xv = xv as i64;
                let wrow = &wc[kk * n..(kk + 1) * n];
                for (a, &wv) in acc.iter_mut().zip(wrow) {
                    *a += xv * wv as i64;
                }
            }
            for (o, &a) in orow.iter_mut().zip(&acc) {
                *o = (a as f64 * inv) as f32;
            }
        }
    });
}

// ---------------------------------------------------------------------------
// float lattice paths
// ---------------------------------------------------------------------------

fn quantize_f64(rep: &FloatRep, xs: &[f32]) -> Vec<f64> {
    xs.iter().map(|&x| rep.quantize_f64(x as f64)).collect()
}

fn gemm_f64(xq: &[f64], wq: &[f64], m: usize, k: usize, n: usize,
            out: &mut [f32], threads: usize) {
    row_parallel(out, m, n, threads, |row0, chunk| {
        let mut acc = vec![0f64; n];
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            acc.fill(0.0);
            let xrow = &xq[(row0 + r) * k..(row0 + r + 1) * k];
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &wq[kk * n..(kk + 1) * n];
                for (a, &wv) in acc.iter_mut().zip(wrow) {
                    *a += xv * wv;
                }
            }
            for (o, &a) in orow.iter_mut().zip(&acc) {
                *o = a as f32;
            }
        }
    });
}

/// Pre-conditioned CFPU operand (§Perf iteration 4): field extraction,
/// top-w classification and the power-of-two exponent factor are hoisted
/// out of the MAC loop, so the inner loop is a 3-way class dispatch with
/// one multiply on the approximate paths and a bit-trick re-quantization
/// on the exact-fallback path.
#[derive(Clone, Copy)]
struct CfpuOp {
    /// decoded signed value (0.0 for the zero encoding)
    dec: f64,
    /// 2^(unbiased exponent) — the factor the skip path multiplies by
    pow: f64,
    /// 0: top-w mantissa bits all zero (operand ~ 2^e, round down)
    /// 1: all one (operand ~ 2^(e+1), round up)
    /// 2: neither -> exact multiply path
    class: u8,
}

fn condition_cfpu(c: &CfpuMul, xs: &[f32]) -> Vec<CfpuOp> {
    let (e, m) = (c.rep.e_bits, c.rep.m_bits);
    let man_mask = (1u64 << m) - 1;
    let bias = c.rep.bias();
    xs.iter()
        .map(|&x| {
            let bits = c.rep.encode(x);
            let field = ((bits >> m) & ((1u64 << e) - 1)) as i32;
            if field == 0 {
                return CfpuOp { dec: 0.0, pow: 0.0, class: 2 };
            }
            let man = bits & man_mask;
            let class = if c.w > m {
                2
            } else {
                let top = (1u64 << c.w) - 1;
                let t = (man >> (m - c.w)) & top;
                if t == 0 {
                    0
                } else if t == top {
                    1
                } else {
                    2
                }
            };
            CfpuOp {
                dec: c.rep.decode(bits) as f64,
                pow: crate::numeric::float::exp2i(field - bias),
                class,
            }
        })
        .collect()
}

/// One CFPU product from pre-conditioned operands.  Matches
/// `CfpuMul::mul_bits` bit-for-bit (the gemm unit tests pin this against
/// the scalar path).
#[inline]
fn cfpu_product(c: &CfpuMul, x: &CfpuOp, w: &CfpuOp) -> f64 {
    if x.dec == 0.0 || w.dec == 0.0 {
        return 0.0;
    }
    // skip path: |kept| * 2^(dropped exponent) [ * 2 when rounding up ]
    let (val, sign_src) = match (w.class, x.class) {
        (0, _) => (x.dec.abs() * w.pow, x.dec * w.dec),
        (1, _) => (x.dec.abs() * w.pow * 2.0, x.dec * w.dec),
        (_, 0) => (w.dec.abs() * x.pow, x.dec * w.dec),
        (_, 1) => (w.dec.abs() * x.pow * 2.0, x.dec * w.dec),
        _ => {
            // exact fallback: multiply + RNE re-quantization
            return c.rep.quantize_f64(x.dec * w.dec);
        }
    };
    let clamped = cfpu_clamp(c, val);
    if sign_src < 0.0 {
        -clamped
    } else {
        clamped
    }
}

#[inline]
fn cfpu_clamp(c: &CfpuMul, y: f64) -> f64 {
    let mx = c.rep.max_finite();
    if y > mx {
        return mx;
    }
    let mn = c.rep.min_normal();
    if y < mn {
        return if y * 2.0 >= mn { mn } else { 0.0 };
    }
    y
}

fn gemm_cfpu(c: &CfpuMul, xs: &[f32], ws: &[f32], m: usize, k: usize,
             n: usize, out: &mut [f32], threads: usize) {
    let xo = condition_cfpu(c, xs);
    let wo = condition_cfpu(c, ws);
    row_parallel(out, m, n, threads, |row0, chunk| {
        let mut acc = vec![0f64; n];
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            acc.fill(0.0);
            let xrow = &xo[(row0 + r) * k..(row0 + r + 1) * k];
            for (kk, xv) in xrow.iter().enumerate() {
                if xv.dec == 0.0 {
                    continue;
                }
                let wrow = &wo[kk * n..(kk + 1) * n];
                for (a, wv) in acc.iter_mut().zip(wrow) {
                    *a += cfpu_product(c, xv, wv);
                }
            }
            for (o, &a) in orow.iter_mut().zip(&acc) {
                *o = a as f32;
            }
        }
    });
}

// ---------------------------------------------------------------------------
// binary XNOR path (paper §4.5): bit-packed popcount GEMM
// ---------------------------------------------------------------------------

fn gemm_binary(x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
               out: &mut [f32], threads: usize) {
    let words = k.div_ceil(64);
    // pack x rows and w columns as sign bitmaps
    let mut xp = vec![0u64; m * words];
    for r in 0..m {
        for kk in 0..k {
            let bit = BinXnor::binarize(x[r * k + kk]);
            xp[r * words + kk / 64] |= bit << (kk % 64);
        }
    }
    let mut wp = vec![0u64; n * words];
    for j in 0..n {
        for kk in 0..k {
            let bit = BinXnor::binarize(w[kk * n + j]);
            wp[j * words + kk / 64] |= bit << (kk % 64);
        }
    }
    // tail mask: bits >= k in the last word must not count as agreements
    let tail_bits = k % 64;
    let tail_mask = if tail_bits == 0 { u64::MAX } else { (1u64 << tail_bits) - 1 };
    row_parallel(out, m, n, threads, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let xr = &xp[(row0 + r) * words..(row0 + r + 1) * words];
            for (j, o) in orow.iter_mut().enumerate() {
                let wc = &wp[j * words..(j + 1) * words];
                let mut agree = 0u32;
                for ww in 0..words {
                    let mut eq = !(xr[ww] ^ wc[ww]);
                    if ww == words - 1 {
                        eq &= tail_mask;
                    }
                    agree += eq.count_ones();
                }
                // dot of ±1 vectors = agreements - disagreements
                *o = (2 * agree as i64 - k as i64) as f32;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn naive(kind: &ArithKind, x: &[f32], w: &[f32], m: usize, k: usize,
             n: usize) -> Vec<f32> {
        // reference: scalar quantize + wide scalar mul + f64 accumulate
        let mut out = vec![0.0f32; m * n];
        for r in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for kk in 0..k {
                    let a = kind.quantize(x[r * k + kk]);
                    acc += kind.mul_wide(a, w[kk * n + j]);
                }
                out[r * n + j] = acc as f32;
            }
        }
        out
    }

    fn rand_mats(seed: u64, m: usize, k: usize, n: usize)
                 -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..m * k).map(|_| (rng.normal() * 2.0) as f32)
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        (x, w)
    }

    fn check_kind(kind: ArithKind, seed: u64) {
        let (m, k, n) = (13, 37, 11);
        let (x, mut w) = rand_mats(seed, m, k, n);
        // weights pre-quantized, as the layer contract requires
        for wv in &mut w {
            *wv = kind.quantize(*wv);
        }
        let mut out = vec![0.0; m * n];
        gemm(&kind, &x, &w, m, k, n, &mut out, 1);
        let want = naive(&kind, &x, &w, m, k, n);
        for (idx, (g, ww)) in out.iter().zip(&want).enumerate() {
            let tol = 1e-4 * ww.abs().max(1.0);
            assert!(
                (g - ww).abs() <= tol,
                "{}: out[{idx}] = {g}, want {ww}",
                kind.name()
            );
        }
    }

    #[test]
    fn f32_matches_naive() {
        check_kind(ArithKind::Float32, 1);
    }

    #[test]
    fn fixed_exact_matches_naive() {
        check_kind(ArithKind::parse("FI(6,8)").unwrap(), 2);
        check_kind(ArithKind::parse("FI(3,4)").unwrap(), 3);
    }

    #[test]
    fn fixed_drum_matches_naive() {
        check_kind(ArithKind::parse("H(6,8,6)").unwrap(), 4);
        check_kind(ArithKind::parse("H(8,8,14)").unwrap(), 5);
    }

    #[test]
    fn float_exact_matches_naive() {
        check_kind(ArithKind::parse("FL(4,9)").unwrap(), 6);
        check_kind(ArithKind::parse("FL(5,10)").unwrap(), 7);
    }

    #[test]
    fn float_cfpu_matches_naive() {
        check_kind(ArithKind::parse("I(5,10)").unwrap(), 8);
        check_kind(ArithKind::parse("I(4,9,2)").unwrap(), 9);
    }

    #[test]
    fn binary_matches_pm1_dot() {
        let (m, k, n) = (5, 130, 7); // k > 2 words incl. tail
        let (x, w) = rand_mats(10, m, k, n);
        let mut out = vec![0.0; m * n];
        gemm(&ArithKind::Binary, &x, &w, m, k, n, &mut out, 1);
        for r in 0..m {
            for j in 0..n {
                let mut dot = 0f32;
                for kk in 0..k {
                    let a = if x[r * k + kk] >= 0.0 { 1.0 } else { -1.0 };
                    let b = if w[kk * n + j] >= 0.0 { 1.0 } else { -1.0 };
                    dot += a * b;
                }
                assert_eq!(out[r * n + j], dot, "r={r} j={j}");
            }
        }
    }

    #[test]
    fn multithreaded_matches_single() {
        for kind in [
            ArithKind::Float32,
            ArithKind::parse("FI(6,8)").unwrap(),
            ArithKind::parse("H(6,8,12)").unwrap(),
            ArithKind::parse("FL(4,9)").unwrap(),
        ] {
            let (m, k, n) = (64, 100, 96); // big enough to engage threads
            let (x, mut w) = rand_mats(11, m, k, n);
            for wv in &mut w {
                *wv = kind.quantize(*wv);
            }
            let mut a = vec![0.0; m * n];
            let mut b = vec![0.0; m * n];
            gemm(&kind, &x, &w, m, k, n, &mut a, 1);
            gemm(&kind, &x, &w, m, k, n, &mut b, 4);
            assert_eq!(a, b, "{}", kind.name());
        }
    }

    #[test]
    fn zero_sized_edges() {
        let kind = ArithKind::Float32;
        let mut out = vec![0.0; 0];
        gemm(&kind, &[], &[], 0, 0, 0, &mut out, 1);
        let mut out1 = vec![0.0; 1];
        gemm(&kind, &[2.0], &[3.0], 1, 1, 1, &mut out1, 1);
        assert_eq!(out1[0], 6.0);
    }
}
