//! The paper's DCNN (Fig. 2) with per-layer arithmetic providers — the
//! layer-wise *partition* of §3/§4.2: each layer is one part, each part has
//! one (representation × arithmetic) domain.

use super::conv::conv2d;
use super::gemm::GemmPlan;
use super::layers::{add_bias, dense, maxpool2, relu};
use super::loader::validate_dcnn;
use super::quantizer::quantize_tensor;
use super::tensor::Tensor;
use crate::approx::arith::ArithKind;
use anyhow::Result;
use std::collections::BTreeMap;

pub const LAYER_NAMES: [&str; 4] = ["conv1", "conv2", "fc1", "fc2"];

/// One partition part = one layer's domain choice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerConfig {
    pub arith: ArithKind,
}

/// A full network configuration (one provider per layer).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetConfig {
    pub layers: [ArithKind; 4],
}

impl NetConfig {
    pub fn uniform(kind: ArithKind) -> Self {
        NetConfig { layers: [kind; 4] }
    }

    pub fn name(&self) -> String {
        if self.layers.iter().all(|l| l == &self.layers[0]) {
            self.layers[0].name()
        } else {
            self.layers.iter().map(|l| l.name()).collect::<Vec<_>>()
                .join(" | ")
        }
    }

    /// Parse "FI(6,8)" (uniform) or "FI(5,8)|FI(5,8)|FI(6,8)|FI(6,8)".
    pub fn parse(s: &str) -> Result<Self, String> {
        let parts: Vec<&str> = s.split('|').map(str::trim).collect();
        match parts.len() {
            1 => Ok(NetConfig::uniform(ArithKind::parse(parts[0])?)),
            4 => {
                let mut layers = [ArithKind::Float32; 4];
                for (l, p) in layers.iter_mut().zip(&parts) {
                    *l = ArithKind::parse(p)?;
                }
                Ok(NetConfig { layers })
            }
            n => Err(format!("expected 1 or 4 layer configs, got {n}")),
        }
    }

    /// True when every layer is PJRT-expressible (exact arithmetic).
    pub fn pjrt_expressible(&self) -> bool {
        self.layers.iter().all(|l| l.pjrt_expressible())
    }
}

/// Trained float32 parameters + architecture checks.
pub struct Dcnn {
    pub params: BTreeMap<String, Tensor>,
}

/// Per-layer activation/weight ranges (reproduces paper Table 1).
#[derive(Clone, Debug)]
pub struct LayerRanges {
    pub layer: &'static str,
    pub w: (f32, f32),
    pub b: (f32, f32),
    pub a: (f32, f32), // pre-activation outputs (the WBA "activation")
}

impl LayerRanges {
    pub fn combined(&self) -> (f32, f32) {
        (
            self.w.0.min(self.b.0).min(self.a.0),
            self.w.1.max(self.b.1).max(self.a.1),
        )
    }
}

impl Dcnn {
    pub fn new(params: BTreeMap<String, Tensor>) -> Result<Self> {
        validate_dcnn(&params)?;
        Ok(Dcnn { params })
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Dcnn::new(super::loader::load_weights(path)?)
    }

    /// A randomly-initialized network with the exact architecture
    /// `validate_dcnn` requires — the hermetic fixture behind
    /// `Server::start_with_dcnn`, `benches/serving_throughput.rs` and
    /// the plan-cache suites (no `make artifacts` needed).  One
    /// definition serves the lib tests, integration tests and benches
    /// so the shapes cannot drift from the loader contract.
    /// Deterministic in `seed`; the weights are untrained (use real
    /// artifacts for accuracy claims).
    pub fn synthetic(seed: u64) -> Dcnn {
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut t = |shape: Vec<usize>, sigma: f64| {
            let n: usize = shape.iter().product();
            Tensor::new(shape,
                        (0..n).map(|_| (rng.normal() * sigma) as f32)
                            .collect())
        };
        let mut params = BTreeMap::new();
        params.insert("conv1_w".into(), t(vec![5, 5, 1, 32], 0.2));
        params.insert("conv1_b".into(), t(vec![32], 0.05));
        params.insert("conv2_w".into(), t(vec![5, 5, 32, 64], 0.05));
        params.insert("conv2_b".into(), t(vec![64], 0.05));
        params.insert("fc1_w".into(), t(vec![3136, 1024], 0.02));
        params.insert("fc1_b".into(), t(vec![1024], 0.02));
        params.insert("fc2_w".into(), t(vec![1024, 10], 0.05));
        params.insert("fc2_b".into(), t(vec![10], 0.02));
        Dcnn::new(params).expect("synthetic params match the validator")
    }

    /// Companion fixture to [`Dcnn::synthetic`]: a deterministic
    /// random input batch shaped for this network's forward pass
    /// (`[b, 28, 28, 1]`, values in `[0, 1)`), shared by the hermetic
    /// suites so the input contract cannot drift per copy.
    pub fn synthetic_input(b: usize, seed: u64) -> Tensor {
        let mut rng = crate::util::prng::Rng::new(seed);
        Tensor::new(vec![b, 28, 28, 1],
                    (0..b * 784).map(|_| rng.range_f32(0.0, 1.0))
                        .collect())
    }

    /// Quantize weights/biases for `cfg` and return a runnable network.
    pub fn prepare(&self, cfg: NetConfig) -> PreparedNet {
        let mut wq = Vec::with_capacity(4);
        let mut bq = Vec::with_capacity(4);
        for (li, lname) in LAYER_NAMES.iter().enumerate() {
            let kind = &cfg.layers[li];
            let w = &self.params[&format!("{lname}_w")];
            let b = &self.params[&format!("{lname}_b")];
            // conv weights flatten to (kh*kw*cin, cout) for the GEMM
            let w2 = if w.ndim() == 4 {
                let cout = w.shape[3];
                let rows = w.len() / cout;
                quantize_tensor(kind, w).reshape(vec![rows, cout])
            } else {
                quantize_tensor(kind, w)
            };
            wq.push(w2);
            bq.push(quantize_tensor(kind, b));
        }
        // resolve each layer's packed kernel once AND condition its
        // constant weight matrix into that kernel's panel layout; every
        // forward pass reuses both — zero weight-side packing per call
        // (tests/prepack_differential.rs pins this via
        // gemm::pack::weight_pack_count)
        let mut plans: Vec<GemmPlan> =
            cfg.layers.iter().map(GemmPlan::new).collect();
        for (plan, w2) in plans.iter_mut().zip(&wq) {
            plan.prepack(&w2.data, w2.shape[0], w2.shape[1]);
        }
        PreparedNet { cfg, wq, bq, plans }
    }

    /// Float32 forward that records per-layer WBA ranges (Table 1).
    pub fn ranges(&self, x: &Tensor, threads: usize) -> Vec<LayerRanges> {
        let net = self.prepare(NetConfig::uniform(ArithKind::Float32));
        let (_, zs) = net.forward_capture(x, threads);
        LAYER_NAMES
            .iter()
            .enumerate()
            .map(|(li, lname)| {
                let w = &self.params[&format!("{lname}_w")];
                let b = &self.params[&format!("{lname}_b")];
                LayerRanges {
                    layer: LAYER_NAMES[li],
                    w: w.minmax(),
                    b: b.minmax(),
                    a: zs[li],
                }
            })
            .collect()
    }
}

/// A network with weights snapped to a configuration, ready for inference.
///
/// **Immutable after `prepare`.**  Every field is conditioned exactly
/// once inside [`Dcnn::prepare`] (quantized weights, resolved plans,
/// prepacked panels) and only read afterwards — there is no `&mut
/// self` method on this type.  That is the contract that makes
/// `Arc<PreparedNet>` safe to share across the whole engine worker
/// pool: `coordinator::plan_cache` hands out one `Arc` per
/// configuration instead of one private copy per worker, so panel
/// residency scales with *configs*, not `workers x configs`.
/// (`Send + Sync` is pinned by a test below; the cross-kind panel
/// identity guards live in `gemm::PackedWeights`.)
pub struct PreparedNet {
    pub cfg: NetConfig,
    wq: Vec<Tensor>, // flattened (rows, cout) weights, quantized
    bq: Vec<Tensor>,
    /// per-layer packed-kernel selection, resolved once in `prepare`
    plans: Vec<GemmPlan>,
}

impl PreparedNet {
    /// Forward pass: x is [B,28,28,1] in [0,1]; returns logits [B,10].
    pub fn forward(&self, x: &Tensor, threads: usize) -> Tensor {
        self.forward_capture(x, threads).0
    }

    /// Forward returning per-layer pre-activation (min,max) as well.
    pub fn forward_capture(&self, x: &Tensor, threads: usize)
                           -> (Tensor, Vec<(f32, f32)>) {
        assert_eq!(x.ndim(), 4, "input must be [B,28,28,1]");
        assert_eq!(&x.shape[1..], &[28, 28, 1]);
        let b = x.shape[0];
        let mut ranges = Vec::with_capacity(4);

        // CONV1: quantization of the input happens inside gemm (the MAC
        // entry point), matching model.py where cols are fake-quantized.
        let mut z = self.conv_block(x, 0, 28, 32, threads);
        ranges.push(z.minmax());
        relu(&mut z);
        let a = maxpool2(&z); // [B,14,14,32]

        let mut z = self.conv_block(&a, 1, 14, 64, threads);
        ranges.push(z.minmax());
        relu(&mut z);
        let a = maxpool2(&z); // [B,7,7,64]

        // FC1: flatten (h, w, c) row-major — same layout as python
        let a = a.reshape(vec![b, 3136]);
        let mut z = self.fc_block(&a, 2, threads);
        ranges.push(z.minmax());
        relu(&mut z);

        let z = self.fc_block(&z, 3, threads);
        ranges.push(z.minmax());
        (z, ranges)
    }

    /// Kernel selected for each layer (e.g. `packed-fi`), in layer
    /// order — surfaced through `runtime::execution_plan`.
    pub fn kernel_names(&self) -> [&'static str; 4] {
        let mut names = [""; 4];
        for (n, p) in names.iter_mut().zip(&self.plans) {
            *n = p.kernel_name();
        }
        names
    }

    /// Panel-cache observability: (number of layers with cached weight
    /// panels, resident panel bytes across layers).  The serving stack
    /// surfaces this through `coordinator::metrics`.
    pub fn packed_panel_stats(&self) -> (usize, usize) {
        let count = self
            .plans
            .iter()
            .filter(|p| p.is_prepacked())
            .count();
        let bytes = self.plans.iter().map(|p| p.panel_bytes()).sum();
        (count, bytes)
    }

    fn conv_block(&self, x: &Tensor, li: usize, hw: usize, cout: usize,
                  threads: usize) -> Tensor {
        let b = x.shape[0];
        let mut out =
            conv2d(&self.plans[li], x, &self.wq[li], 5, 5, 2, threads);
        add_bias(&mut out, &self.bq[li].data);
        out.reshape(vec![b, hw, hw, cout])
    }

    fn fc_block(&self, x: &Tensor, li: usize, threads: usize) -> Tensor {
        dense(&self.plans[li], x, &self.wq[li], &self.bq[li].data,
              threads)
    }

    /// Classify: argmax of logits.
    pub fn predict(&self, x: &Tensor, threads: usize) -> Vec<usize> {
        self.forward(x, threads).argmax_rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let net = Dcnn::synthetic(1).prepare(NetConfig::uniform(ArithKind::Float32));
        let logits = net.forward(&Dcnn::synthetic_input(3, 2), 1);
        assert_eq!(logits.shape, vec![3, 10]);
    }

    #[test]
    fn quantized_forward_close_to_f32_with_wide_config() {
        let dcnn = Dcnn::synthetic(3);
        let x = Dcnn::synthetic_input(2, 4);
        let base = dcnn
            .prepare(NetConfig::uniform(ArithKind::Float32))
            .forward(&x, 1);
        let fine = dcnn
            .prepare(NetConfig::uniform(
                ArithKind::parse("FI(8,14)").unwrap(),
            ))
            .forward(&x, 1);
        for (a, b) in base.data.iter().zip(&fine.data) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn coarse_quantization_perturbs() {
        let dcnn = Dcnn::synthetic(5);
        let x = Dcnn::synthetic_input(2, 6);
        let base = dcnn
            .prepare(NetConfig::uniform(ArithKind::Float32))
            .forward(&x, 1);
        let coarse = dcnn
            .prepare(NetConfig::uniform(ArithKind::parse("FI(1,1)").unwrap()))
            .forward(&x, 1);
        let diff: f32 = base
            .data
            .iter()
            .zip(&coarse.data)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 0.1, "coarse quantization had no effect ({diff})");
    }

    #[test]
    fn mixed_config_parses_and_runs() {
        let cfg = NetConfig::parse("FI(6,8)|FI(6,8)|H(8,8,14)|H(8,8,14)")
            .unwrap();
        assert!(!cfg.pjrt_expressible());
        let net = Dcnn::synthetic(7).prepare(cfg);
        assert_eq!(net.kernel_names(),
                   ["packed-fi", "packed-fi", "packed-drum",
                    "packed-drum"]);
        let out = net.forward(&Dcnn::synthetic_input(1, 8), 1);
        assert_eq!(out.shape, vec![1, 10]);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn ranges_structure() {
        let dcnn = Dcnn::synthetic(9);
        let r = dcnn.ranges(&Dcnn::synthetic_input(4, 10), 1);
        assert_eq!(r.len(), 4);
        for lr in &r {
            assert!(lr.w.0 <= lr.w.1);
            let (lo, hi) = lr.combined();
            assert!(lo <= hi);
        }
        // conv1 pre-activations on positive inputs: max must be > 0
        assert!(r[0].a.1 > 0.0);
    }

    #[test]
    fn prepare_caches_weight_panels() {
        let cfg = NetConfig::parse("FI(6,8)|FI(6,8)|FL(4,9)|binxnor")
            .unwrap();
        let net = Dcnn::synthetic(13).prepare(cfg);
        let (count, bytes) = net.packed_panel_stats();
        assert_eq!(count, 4, "every layer's panels are cached");
        assert!(bytes > 0);
    }

    #[test]
    fn prepared_net_is_send_sync() {
        // The auto-trait pin behind `Arc<PreparedNet>` sharing in
        // `coordinator::plan_cache`: compile-time, fails here if a
        // future field (e.g. interior mutability in a plan) breaks it.
        fn check<T: Send + Sync>() {}
        check::<PreparedNet>();
        check::<std::sync::Arc<PreparedNet>>();
    }

    #[test]
    fn threads_do_not_change_results() {
        let dcnn = Dcnn::synthetic(11);
        let x = Dcnn::synthetic_input(4, 12);
        let cfg = NetConfig::uniform(ArithKind::parse("FI(6,8)").unwrap());
        let a = dcnn.prepare(cfg).forward(&x, 1);
        let b = dcnn.prepare(cfg).forward(&x, 4);
        assert_eq!(a.data, b.data);
    }
}
