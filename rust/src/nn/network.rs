//! Spec-driven network engine: a [`Model`] pairs a [`NetSpec`]
//! topology with trained parameters; [`Model::prepare`] snaps them to
//! a [`ReprMap`] (one arithmetic provider per layer — the layer-wise
//! *partition* of §3/§4.2) and returns a runnable [`PreparedNet`].
//!
//! The paper's Fig. 2 DCNN is just the [`NetSpec::paper_dcnn`] preset;
//! every loop below runs over `spec.len()` layers, so a 5-layer MLP or
//! a 2-conv net flows through the same prepare/forward/serve machinery
//! (pinned by `rust/tests/netspec_topology.rs`).

use super::conv::conv2d_with;
use super::gemm::{Epilogue, GemmPlan};
use super::layers::{dense_with, maxpool2, relu};
use super::quantizer::quantize_tensor;
use super::spec::{Activation, LayerKind, NetSpec, ReprMap};
use super::tensor::Tensor;
use crate::approx::arith::ArithKind;
use anyhow::Result;
use std::collections::BTreeMap;

/// Transitional alias — the paper-specific `Dcnn` type generalized
/// into the spec-driven [`Model`]; construct paper-shaped instances
/// with [`NetSpec::paper_dcnn`].
pub type Dcnn = Model;

/// Trained float32 parameters bound to a [`NetSpec`] (shapes validated
/// at construction).
pub struct Model {
    spec: NetSpec,
    pub params: BTreeMap<String, Tensor>,
}

/// Per-layer activation/weight ranges (reproduces paper Table 1).
#[derive(Clone, Debug)]
pub struct LayerRanges {
    /// Layer name from the spec (`conv1`, `fc2`, ...).
    pub layer: String,
    pub w: (f32, f32),
    pub b: (f32, f32),
    pub a: (f32, f32), // pre-activation outputs (the WBA "activation")
}

impl LayerRanges {
    pub fn combined(&self) -> (f32, f32) {
        (
            self.w.0.min(self.b.0).min(self.a.0),
            self.w.1.max(self.b.1).max(self.a.1),
        )
    }
}

impl Model {
    pub fn new(spec: NetSpec, params: BTreeMap<String, Tensor>)
               -> Result<Model> {
        spec.validate_params(&params)?;
        Ok(Model { spec, params })
    }

    pub fn load(spec: NetSpec, path: &std::path::Path)
                -> Result<Model> {
        Model::new(spec, super::loader::load_weights(path)?)
    }

    /// A randomly-initialized network for *any* spec — the hermetic
    /// fixture behind `Server::start_with_model`,
    /// `benches/serving_throughput.rs` and the plan-cache/topology
    /// suites (no `make artifacts` needed).  One definition serves
    /// the lib tests, integration tests and benches so the shapes
    /// cannot drift from the spec contract.  Weight sigma is
    /// He-style (`sqrt(2 / fan_in)`) so activations stay sane at any
    /// depth; deterministic in `seed`; the weights are untrained (use
    /// real artifacts for accuracy claims).
    pub fn synthetic(spec: NetSpec, seed: u64) -> Model {
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut params = BTreeMap::new();
        for layer in spec.layers() {
            let (wshape, bshape) = layer.param_shapes();
            let fan_in: usize =
                wshape[..wshape.len() - 1].iter().product();
            let sigma = (2.0 / fan_in.max(1) as f64).sqrt();
            let mut t = |shape: Vec<usize>, s: f64| {
                let n: usize = shape.iter().product();
                Tensor::new(shape,
                            (0..n).map(|_| (rng.normal() * s) as f32)
                                .collect())
            };
            params.insert(format!("{}_w", layer.name),
                          t(wshape, sigma));
            params.insert(format!("{}_b", layer.name),
                          t(bshape, 0.02));
        }
        Model::new(spec, params)
            .expect("synthetic params match the spec by construction")
    }

    /// The topology this model's parameters implement.
    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    /// Quantize weights/biases for `cfg` and return a runnable
    /// network.  Panics on arity mismatch (the parse-level APIs,
    /// `ReprMap::parse_for` / `uniform_for`, reject that earlier).
    pub fn prepare(&self, cfg: &ReprMap) -> PreparedNet {
        assert_eq!(
            cfg.len(),
            self.spec.len(),
            "ReprMap has {} kinds for the {}-layer spec '{}'",
            cfg.len(),
            self.spec.len(),
            self.spec
        );
        let n = self.spec.len();
        let mut wq = Vec::with_capacity(n);
        let mut bq = Vec::with_capacity(n);
        for (li, layer) in self.spec.layers().iter().enumerate() {
            let kind = cfg.kind(li);
            let w = &self.params[&format!("{}_w", layer.name)];
            let b = &self.params[&format!("{}_b", layer.name)];
            // conv weights flatten to (kh*kw*cin, cout) for the GEMM
            let w2 = if w.ndim() == 4 {
                let cout = w.shape[3];
                let rows = w.len() / cout;
                quantize_tensor(kind, w).reshape(vec![rows, cout])
            } else {
                quantize_tensor(kind, w)
            };
            wq.push(w2);
            bq.push(quantize_tensor(kind, b));
        }
        // resolve each layer's packed kernel once AND condition its
        // constant weight matrix into that kernel's panel layout; every
        // forward pass reuses both — zero weight-side packing per call
        // (tests/prepack_differential.rs pins this via
        // gemm::pack::weight_pack_count)
        let mut plans: Vec<GemmPlan> =
            cfg.kinds().iter().map(GemmPlan::new).collect();
        for (plan, w2) in plans.iter_mut().zip(&wq) {
            plan.prepack(&w2.data, w2.shape[0], w2.shape[1]);
        }
        PreparedNet {
            spec: self.spec.clone(),
            cfg: cfg.clone(),
            wq,
            bq,
            plans,
        }
    }

    /// Float32 forward that records per-layer WBA ranges (Table 1).
    pub fn ranges(&self, x: &Tensor, threads: usize)
                  -> Vec<LayerRanges> {
        let net = self.prepare(&ReprMap::uniform_for(
            &self.spec,
            ArithKind::Float32,
        ));
        let (_, zs) = net.forward_capture(x, threads);
        self.spec
            .layers()
            .iter()
            .enumerate()
            .map(|(li, layer)| {
                let w = &self.params[&format!("{}_w", layer.name)];
                let b = &self.params[&format!("{}_b", layer.name)];
                LayerRanges {
                    layer: layer.name.clone(),
                    w: w.minmax(),
                    b: b.minmax(),
                    a: zs[li],
                }
            })
            .collect()
    }
}

/// A network with weights snapped to a configuration, ready for
/// inference.
///
/// **Immutable after `prepare`.**  Every field is conditioned exactly
/// once inside [`Model::prepare`] (quantized weights, resolved plans,
/// prepacked panels) and only read afterwards — there is no `&mut
/// self` method on this type.  That is the contract that makes
/// `Arc<PreparedNet>` safe to share across the whole engine worker
/// pool: `coordinator::plan_cache` hands out one `Arc` per
/// (spec, assignment) fingerprint instead of one private copy per
/// worker, so panel residency scales with *configs*, not
/// `workers x configs`.  (`Send + Sync` is pinned by a test below;
/// the cross-kind panel identity guards live in
/// `gemm::PackedWeights`.)
pub struct PreparedNet {
    spec: NetSpec,
    pub cfg: ReprMap,
    wq: Vec<Tensor>, // flattened (rows, cout) weights, quantized
    bq: Vec<Tensor>,
    /// per-layer packed-kernel selection, resolved once in `prepare`
    plans: Vec<GemmPlan>,
}

impl PreparedNet {
    /// The topology this net runs.
    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    /// Forward pass: `x` is `[B, h, w, c]` matching the spec's input
    /// shape; returns the last layer's output (e.g. logits `[B, n]`).
    ///
    /// Each `dense(..)+relu` / `conv(..)+relu` spec segment compiles
    /// to **one fused pass**: bias, ReLU and — when a consumer layer
    /// follows — requantization onto the consumer's representation all
    /// run inside the GEMM's per-tile epilogue, so no standalone
    /// `add_bias`/`relu` tensor walk happens (pinned by the
    /// pass-counter assertion in `tests/epilogue_differential.rs`).
    /// Pool stays a separate structural pass over the fused output.
    pub fn forward(&self, x: &Tensor, threads: usize) -> Tensor {
        self.forward_impl(x, threads, false).0
    }

    /// Forward returning per-layer pre-activation (min,max) as well.
    /// Capture needs the *pre-ReLU* tensor per layer (Table 1 profiles
    /// it), so this path fuses only the bias and applies ReLU as a
    /// standalone pass after reading the range — the fully-fused fast
    /// path is [`PreparedNet::forward`].
    pub fn forward_capture(&self, x: &Tensor, threads: usize)
                           -> (Tensor, Vec<(f32, f32)>) {
        self.forward_impl(x, threads, true)
    }

    /// The epilogue for layer `li`: bias always; ReLU fused when the
    /// layer activates and we are not capturing pre-ReLU ranges; the
    /// consumer layer's lattice snap fused on top when a consumer
    /// exists.  Requantizing here is sound because every provider's
    /// pack-time conditioning is idempotent over its own lattice
    /// (`cond(quantize(v)) == cond(v)`) and `maxpool2` commutes with
    /// the monotone `quantize` — see DESIGN.md §gemm epilogue
    /// contract.
    fn epilogue_for(&self, li: usize, capture: bool) -> Epilogue<'_> {
        let bias = &self.bq[li].data;
        let relu_here = self.spec.layers()[li].activation
            == Activation::Relu;
        if capture || !relu_here {
            return Epilogue::Bias { bias };
        }
        match self.cfg.kinds().get(li + 1) {
            Some(consumer) => Epilogue::BiasReluQuant {
                bias,
                quant: *consumer,
            },
            None => Epilogue::BiasRelu { bias },
        }
    }

    fn forward_impl(&self, x: &Tensor, threads: usize, capture: bool)
                    -> (Tensor, Vec<(f32, f32)>) {
        assert_eq!(x.ndim(), 4, "input must be [B, h, w, c]");
        let ishape = self.spec.input_shape();
        assert_eq!(&x.shape[1..], &ishape[..],
                   "input shape does not match spec '{}'", self.spec);
        let b = x.shape[0];
        let mut ranges = Vec::with_capacity(self.spec.len());
        let mut cur: Option<Tensor> = None;
        for (li, layer) in self.spec.layers().iter().enumerate() {
            let ep = self.epilogue_for(li, capture);
            let mut z = match layer.kind {
                LayerKind::Conv2d { kh, kw, cout, pad, .. } => {
                    let inp = cur.as_ref().unwrap_or(x);
                    let (h, w) = (inp.shape[1], inp.shape[2]);
                    // im2col + packed GEMM -> [B*H*W, cout]; the
                    // quantization of the activations happens inside
                    // gemm (the MAC entry point), matching model.py;
                    // bias (+ fused post-work) rides the epilogue
                    let z = conv2d_with(&self.plans[li], inp,
                                        &self.wq[li], kh, kw, pad, &ep,
                                        threads);
                    z.reshape(vec![b, h, w, cout])
                }
                LayerKind::Dense { d_in, .. } => {
                    // flatten (h, w, c) row-major — same layout as
                    // the python model
                    let flat = match cur.take() {
                        Some(t) => t.reshape(vec![b, d_in]),
                        None => Tensor::new(vec![b, d_in],
                                            x.data.clone()),
                    };
                    dense_with(&self.plans[li], &flat, &self.wq[li],
                               &ep, threads)
                }
            };
            if capture {
                // pre-ReLU ranges (the epilogue fused bias only)
                ranges.push(z.minmax());
                if layer.activation == Activation::Relu {
                    relu(&mut z);
                }
            }
            if layer.pool {
                z = maxpool2(&z);
            }
            cur = Some(z);
        }
        (cur.expect("spec has at least one layer"), ranges)
    }

    /// Kernel selected for each layer (e.g. `packed-fi`), in layer
    /// order — surfaced through `runtime::execution_plan`.
    pub fn kernel_names(&self) -> Vec<&'static str> {
        self.plans.iter().map(|p| p.kernel_name()).collect()
    }

    /// Panel-cache observability: (number of layers with cached weight
    /// panels, resident panel bytes across layers).  The serving stack
    /// surfaces this through `coordinator::metrics`.
    pub fn packed_panel_stats(&self) -> (usize, usize) {
        let count = self
            .plans
            .iter()
            .filter(|p| p.is_prepacked())
            .count();
        let bytes = self.plans.iter().map(|p| p.panel_bytes()).sum();
        (count, bytes)
    }

    /// Classify: argmax of the (2-D) final output's rows.
    pub fn predict(&self, x: &Tensor, threads: usize) -> Vec<usize> {
        self.forward(x, threads).argmax_rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_model(seed: u64) -> Model {
        Model::synthetic(NetSpec::paper_dcnn(), seed)
    }

    fn cfg(s: &str) -> ReprMap {
        ReprMap::parse_for(&NetSpec::paper_dcnn(), s).unwrap()
    }

    #[test]
    fn forward_shapes() {
        let net = paper_model(1).prepare(&cfg("float32"));
        let x = NetSpec::paper_dcnn().synthetic_input(3, 2);
        let logits = net.forward(&x, 1);
        assert_eq!(logits.shape, vec![3, 10]);
    }

    #[test]
    fn quantized_forward_close_to_f32_with_wide_config() {
        let model = paper_model(3);
        let x = NetSpec::paper_dcnn().synthetic_input(2, 4);
        let base = model.prepare(&cfg("float32")).forward(&x, 1);
        let fine = model.prepare(&cfg("FI(8,14)")).forward(&x, 1);
        for (a, b) in base.data.iter().zip(&fine.data) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn coarse_quantization_perturbs() {
        let model = paper_model(5);
        let x = NetSpec::paper_dcnn().synthetic_input(2, 6);
        let base = model.prepare(&cfg("float32")).forward(&x, 1);
        let coarse = model.prepare(&cfg("FI(1,1)")).forward(&x, 1);
        let diff: f32 = base
            .data
            .iter()
            .zip(&coarse.data)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 0.1, "coarse quantization had no effect ({diff})");
    }

    #[test]
    fn mixed_config_parses_and_runs() {
        let c = cfg("FI(6,8)|FI(6,8)|H(8,8,14)|H(8,8,14)");
        assert!(!c.pjrt_expressible());
        let net = paper_model(7).prepare(&c);
        // names are ISA-suffixed under native dispatch; derive the
        // expectation from the dispatcher rather than pinning one tier
        let want: Vec<&'static str> = ["FI(6,8)", "FI(6,8)",
                                       "H(8,8,14)", "H(8,8,14)"]
            .iter()
            .map(|s| {
                crate::nn::gemm::kernel_name(
                    &ArithKind::parse(s).unwrap())
            })
            .collect();
        assert_eq!(net.kernel_names(), want);
        assert!(want[0].starts_with("packed-fi"));
        assert!(want[2].starts_with("packed-drum"));
        let x = NetSpec::paper_dcnn().synthetic_input(1, 8);
        let out = net.forward(&x, 1);
        assert_eq!(out.shape, vec![1, 10]);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn ranges_structure() {
        let model = paper_model(9);
        let x = NetSpec::paper_dcnn().synthetic_input(4, 10);
        let r = model.ranges(&x, 1);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].layer, "conv1");
        assert_eq!(r[3].layer, "fc2");
        for lr in &r {
            assert!(lr.w.0 <= lr.w.1);
            let (lo, hi) = lr.combined();
            assert!(lo <= hi);
        }
        // conv1 pre-activations on positive inputs: max must be > 0
        assert!(r[0].a.1 > 0.0);
    }

    #[test]
    fn prepare_caches_weight_panels() {
        let net = paper_model(13)
            .prepare(&cfg("FI(6,8)|FI(6,8)|FL(4,9)|binxnor"));
        let (count, bytes) = net.packed_panel_stats();
        assert_eq!(count, 4, "every layer's panels are cached");
        assert!(bytes > 0);
    }

    #[test]
    #[should_panic(expected = "ReprMap has 2 kinds")]
    fn prepare_rejects_arity_mismatch() {
        let two = ReprMap::uniform(ArithKind::Float32, 2);
        paper_model(1).prepare(&two);
    }

    #[test]
    fn prepared_net_is_send_sync() {
        // The auto-trait pin behind `Arc<PreparedNet>` sharing in
        // `coordinator::plan_cache`: compile-time, fails here if a
        // future field (e.g. interior mutability in a plan) breaks it.
        fn check<T: Send + Sync>() {}
        check::<PreparedNet>();
        check::<std::sync::Arc<PreparedNet>>();
    }

    #[test]
    fn threads_do_not_change_results() {
        let model = paper_model(11);
        let x = NetSpec::paper_dcnn().synthetic_input(4, 12);
        let c = cfg("FI(6,8)");
        let a = model.prepare(&c).forward(&x, 1);
        let b = model.prepare(&c).forward(&x, 4);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn non_paper_topologies_run_end_to_end() {
        // a deeper MLP: 5 dense layers (first flattens the input)
        let mlp = NetSpec::parse(
            "28x28x1: dense(64)+relu | dense(48)+relu | \
             dense(32)+relu | dense(24)+relu | dense(10)",
        )
        .unwrap();
        let m = Model::synthetic(mlp.clone(), 31);
        let c = ReprMap::parse_for(
            &mlp,
            "FI(6,8)|FL(4,9)|H(6,8,12)|I(5,10)|float32",
        )
        .unwrap();
        let net = m.prepare(&c);
        assert_eq!(net.packed_panel_stats().0, 5);
        assert_eq!(net.kernel_names().len(), 5);
        let out = net.forward(&mlp.synthetic_input(2, 32), 1);
        assert_eq!(out.shape, vec![2, 10]);
        assert!(out.data.iter().all(|v| v.is_finite()));

        // a small 2-conv net with a different kernel size than the
        // paper's
        let conv = NetSpec::parse(
            "28x28x1: conv(3x3,8,pad=1)+relu+pool | \
             conv(3x3,16,pad=1)+relu+pool | dense(10)",
        )
        .unwrap();
        let m = Model::synthetic(conv.clone(), 33);
        let net =
            m.prepare(&ReprMap::uniform_for(&conv,
                                            ArithKind::Float32));
        let out = net.forward(&conv.synthetic_input(2, 34), 1);
        assert_eq!(out.shape, vec![2, 10]);
        // ranges profile one entry per layer, named from the spec
        let r = m.ranges(&conv.synthetic_input(2, 35), 1);
        assert_eq!(r.len(), 3);
        assert_eq!(r[2].layer, "fc1");
    }
}
