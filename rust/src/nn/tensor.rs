//! Dense row-major f32 tensor — the engine's only data type (bit patterns
//! of custom representations are materialized transiently inside GEMM
//! kernels, not stored).

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Reshape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "cannot reshape {:?} -> {shape:?}",
            self.shape
        );
        self.shape = shape;
        self
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for d in (0..self.shape.len().saturating_sub(1)).rev() {
            s[d] = s[d + 1] * self.shape[d + 1];
        }
        s
    }

    /// (min, max) over all elements; (0, 0) when empty.
    pub fn minmax(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in &self.data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if self.data.is_empty() {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }

    /// Argmax per row of a 2-D tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        let (n, c) = (self.shape[0], self.shape[1]);
        let mut out = Vec::with_capacity(n);
        for r in 0..n {
            let row = &self.data[r * c..(r + 1) * c];
            let mut best = 0;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            out.push(best);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.clone().reshape(vec![3, 2]);
        assert_eq!(r.data, t.data);
        assert_eq!(r.shape, vec![3, 2]);
    }

    #[test]
    #[should_panic(expected = "cannot reshape")]
    fn reshape_bad_count() {
        Tensor::zeros(vec![2, 2]).reshape(vec![5]);
    }

    #[test]
    fn minmax_and_argmax() {
        let t = Tensor::new(vec![2, 3],
                            vec![1.0, 5.0, 2.0, -7.0, 0.0, 3.0]);
        assert_eq!(t.minmax(), (-7.0, 5.0));
        assert_eq!(t.argmax_rows(), vec![1, 2]);
    }
}
