//! Runtime ISA dispatch for the packed GEMM kernels (§Perf iteration
//! 9).  Every `MicroArith` monomorphization has a portable scalar
//! kernel; on x86_64 the f32, fixed-point/DRUM and binary paths
//! additionally have `target_feature`-gated SIMD kernels (see the
//! `simd` module).  This module owns the policy of *which* kernel a
//! `GemmPlan` gets:
//!
//! ```text
//! GemmPlan::new(kind)
//!   └─ active()                      ──  LOP_FORCE_ISA, else detect()
//!        └─ select_kernel_isa(kind, isa)
//!             ├─ Scalar: portable BlockedKernel / BinaryKernel
//!             └─ Avx2:   f32 → 6x16 AVX2+FMA microkernel
//!                        FI/H → 4x8 AVX2 i32/i64 microkernel
//!                        binxnor → 8x8 popcnt word-panel kernel
//!                        FL/I → scalar (no SIMD variant; see below)
//! ```
//!
//! Detection happens once, at plan-build time — never inside a MAC
//! loop.  [`detect`] returns the *widest* ISA whose instructions are
//! all available on the running machine ([`Isa::Avx2`] requires
//! `avx2`, `fma` *and* `popcnt` so every SIMD kernel behind it is
//! safe to call).  The `LOP_FORCE_ISA` environment variable
//! ([`FORCE_ENV`]) overrides detection for the whole process —
//! `LOP_FORCE_ISA=scalar` makes every machine run the portable
//! kernels, which is how CI pins the per-ISA differential suites on
//! any runner.  Forcing an ISA the machine does not support, or a
//! name this module does not know, is a loud startup error (the
//! offending token is in the message), never a silent fallback.
//!
//! Exactness policy (enforced by `tests/gemm_differential.rs` and
//! `tests/prepack_differential.rs`, documented in DESIGN.md §gemm):
//! integer and bit-parallel SIMD kernels (fi/drum/binary) are
//! *bit-identical* to `gemm::reference` — integer accumulation is
//! associative, so lane order cannot change results.  The AVX2+FMA
//! f32 kernel fuses each multiply-add into one rounding, which is the
//! point of using FMA; it is pinned by the documented per-element
//! bound [`super::fma_f32_bound`] instead of bitwise equality.  The
//! FL (f64 lattice) and CFPU paths have no SIMD variant — their
//! scalar kernel is the widest on every ISA — so their bit-exactness
//! contract is ISA-independent.

use std::sync::OnceLock;

/// Environment variable that overrides ISA detection for the whole
/// process (`scalar` | `avx2`, case-insensitive; empty/whitespace
/// means "not set").
pub const FORCE_ENV: &str = "LOP_FORCE_ISA";

/// An instruction-set tier the kernel table can dispatch to.  Ordered
/// narrowest to widest: [`detect`] picks the largest supported
/// variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Isa {
    /// Portable scalar kernels — supported everywhere.
    Scalar,
    /// x86_64 with AVX2 + FMA + POPCNT (all three are required so the
    /// f32, integer and binary SIMD kernels are unconditionally safe
    /// once this tier is selected).
    Avx2,
}

impl Isa {
    /// Every dispatchable tier, narrowest first.
    pub const ALL: [Isa; 2] = [Isa::Scalar, Isa::Avx2];

    /// The token this ISA parses from / displays as.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
        }
    }

    /// Parse an ISA token (as found in `LOP_FORCE_ISA`).  Unknown
    /// names error with the offending token — a forced run must never
    /// silently dispatch somewhere the caller did not ask for.
    ///
    /// ```
    /// use lop::nn::gemm::isa::Isa;
    /// assert_eq!(Isa::parse("scalar"), Ok(Isa::Scalar));
    /// assert_eq!(Isa::parse(" AVX2 "), Ok(Isa::Avx2));
    /// assert!(Isa::parse("avx999").unwrap_err().contains("avx999"));
    /// ```
    pub fn parse(s: &str) -> Result<Isa, String> {
        let tok = s.trim();
        match tok.to_ascii_lowercase().as_str() {
            "scalar" => Ok(Isa::Scalar),
            "avx2" => Ok(Isa::Avx2),
            _ => Err(format!(
                "unknown ISA `{tok}` (valid: scalar, avx2)"
            )),
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Whether every instruction `isa`'s kernels use is available on the
/// running machine.  [`Isa::Scalar`] is always supported.
pub fn supported(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
                && std::arch::is_x86_feature_detected!("popcnt")
        }
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => false,
    }
}

/// Every supported ISA, narrowest first (always starts with
/// [`Isa::Scalar`]).  The per-ISA differential suites iterate this so
/// each kernel the dispatcher could pick on this machine is tested.
pub fn detected() -> Vec<Isa> {
    Isa::ALL.iter().copied().filter(|&i| supported(i)).collect()
}

/// The widest supported ISA — what dispatch uses when `LOP_FORCE_ISA`
/// is not set.
pub fn detect() -> Isa {
    *detected().last().expect("scalar is always supported")
}

/// Resolve an optional forced-ISA token against this machine: `None`
/// (or an empty/whitespace token) means [`detect`]; a known,
/// supported token selects that ISA; anything else is an error
/// carrying the offending token.  This is the pure core of
/// [`active`], split out so tests can exercise every branch without
/// touching process environment.
pub fn resolve(force: Option<&str>) -> Result<Isa, String> {
    let tok = match force {
        None => return Ok(detect()),
        Some(s) if s.trim().is_empty() => return Ok(detect()),
        Some(s) => s,
    };
    let isa = Isa::parse(tok)?;
    if supported(isa) {
        Ok(isa)
    } else {
        Err(format!(
            "forced ISA `{}` is not supported on this machine \
             (detected: {})",
            isa.name(),
            detect().name()
        ))
    }
}

/// The ISA the process dispatches to: [`resolve`] over `LOP_FORCE_ISA`,
/// read once and cached for the life of the process (so every
/// `GemmPlan` — and every panel the plan cache retains — is built for
/// the same ISA).  Panics with the offending token if the variable
/// names an unknown or unsupported ISA: a forced run that cannot run
/// as forced must fail at startup, not quietly degrade.
pub fn active() -> Isa {
    static ACTIVE: OnceLock<Isa> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let force = std::env::var(FORCE_ENV).ok();
        resolve(force.as_deref())
            .unwrap_or_else(|e| panic!("{FORCE_ENV}: {e}"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_known_tokens() {
        assert_eq!(Isa::parse("scalar"), Ok(Isa::Scalar));
        assert_eq!(Isa::parse("avx2"), Ok(Isa::Avx2));
        // case-insensitive, whitespace-tolerant (env values are messy)
        assert_eq!(Isa::parse("Scalar"), Ok(Isa::Scalar));
        assert_eq!(Isa::parse("  AVX2\n"), Ok(Isa::Avx2));
    }

    #[test]
    fn parse_errors_carry_the_token() {
        let e = Isa::parse("avx999").unwrap_err();
        assert!(e.contains("avx999"), "{e}");
        assert!(e.contains("scalar") && e.contains("avx2"),
                "error must list the valid tokens: {e}");
    }

    #[test]
    fn scalar_always_supported_and_detected_first() {
        assert!(supported(Isa::Scalar));
        let d = detected();
        assert_eq!(d.first(), Some(&Isa::Scalar));
        // detect() is the widest of the detected list
        assert_eq!(detect(), *d.last().unwrap());
        assert!(supported(detect()));
    }

    #[test]
    fn resolve_defaults_and_forces() {
        assert_eq!(resolve(None), Ok(detect()));
        assert_eq!(resolve(Some("")), Ok(detect()));
        assert_eq!(resolve(Some("  ")), Ok(detect()));
        assert_eq!(resolve(Some("scalar")), Ok(Isa::Scalar));
        let e = resolve(Some("bogus-isa")).unwrap_err();
        assert!(e.contains("bogus-isa"), "{e}");
        if supported(Isa::Avx2) {
            assert_eq!(resolve(Some("avx2")), Ok(Isa::Avx2));
        } else {
            let e = resolve(Some("avx2")).unwrap_err();
            assert!(e.contains("avx2") && e.contains("not supported"),
                    "{e}");
        }
    }

    #[test]
    fn active_is_stable_and_consistent_with_env() {
        let a = active();
        assert_eq!(a, active(), "active() must be memoized");
        match std::env::var(FORCE_ENV) {
            Ok(s) if !s.trim().is_empty() => {
                assert_eq!(a, Isa::parse(&s).unwrap());
            }
            _ => assert_eq!(a, detect()),
        }
    }

    #[test]
    fn isa_ordering_is_narrow_to_wide() {
        assert!(Isa::Scalar < Isa::Avx2);
        assert_eq!(Isa::Scalar.to_string(), "scalar");
        assert_eq!(Isa::Avx2.to_string(), "avx2");
    }
}
