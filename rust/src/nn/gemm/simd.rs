//! x86_64 SIMD microkernels behind the [`super::isa`] dispatch layer.
//!
//! Three kernels live here, mirroring the rten exemplar's per-arch
//! backend split (SNIPPETS.md): a 6x16 AVX2+FMA register tile for the
//! f32 path, a 4x8 AVX2 tile with exact i64 accumulation for the
//! fixed-point code paths (FI and DRUM share it — both condition to
//! i32 codes), and a POPCNT-enabled instantiation of the binary
//! word-panel drive.  Each is a plain `fn` matching the driver's
//! [`super::kernel::MicroFn`] / [`super::kernel::BinaryDriveFn`]
//! signature, so `BlockedKernel`/`BinaryKernel` hold them as function
//! pointers — dispatch happens once at plan-build time, never inside
//! MAC loops.
//!
//! # Safety discipline
//!
//! Every `#[target_feature]` function here is reachable only through
//! a safe wrapper whose contract is enforced upstream:
//! `select_kernel_isa` refuses to construct an [`Isa::Avx2`] kernel
//! unless `isa::supported(Isa::Avx2)` confirmed `avx2`, `fma` *and*
//! `popcnt` at plan-build time (the rten "construct only if
//! supported" discipline).  The wrappers therefore never execute on a
//! machine missing the features they enable.
//!
//! # Exactness
//!
//! * `micro_i32_avx2` is **bit-exact** vs the scalar microkernel:
//!   `VPMULDQ` sign-extends the low 32 bits of each 64-bit lane, so
//!   every i32 x i32 -> i64 product is exact, and i64 addition is
//!   associative — lane order cannot change the sum.
//! * `binary_drive_popcnt` is **bit-exact**: it is the *same* generic
//!   drive as the scalar kernel (`binary_drive_impl`, `inline(always)`
//!   so the `popcnt` feature propagates into `count_ones`), just
//!   instantiated at a wider 8x8 word tile.
//! * `micro_f32_avx2` is **not** bitwise: FMA fuses each multiply-add
//!   into one rounding, and the 16-wide tile changes nothing else —
//!   per output element the k order is preserved, so the deviation is
//!   bounded by [`super::fma_f32_bound`] (the documented tolerance
//!   table in DESIGN.md §gemm).
//!
//! [`Isa::Avx2`]: super::isa::Isa::Avx2

use super::kernel::{binary_drive_impl, Epilogue};
use super::micro::{F32Micro, MicroArith};
use std::arch::x86_64::*;

// ---------------------------------------------------------------------------
// f32: 6x16 AVX2+FMA register tile
// ---------------------------------------------------------------------------

/// AVX2+FMA f32 microkernel: 6 rows x 16 columns (two `__m256` per
/// row — 12 accumulator registers + a/b operands fit the 16 ymm
/// registers).  Matches `MicroFn<F32Micro>`.
///
/// Not bitwise vs scalar (FMA, by design); bounded by
/// [`super::fma_f32_bound`].
pub(crate) fn micro_f32_avx2(_arith: &F32Micro, apan: &[f32],
                             bpan: &[f32], kc: usize, acc: &mut [f32],
                             stride: usize) {
    debug_assert!(apan.len() >= kc * 6 && bpan.len() >= kc * 16);
    debug_assert!(acc.len() >= 5 * stride + 16);
    // SAFETY: kernels holding this fn pointer are only constructed by
    // `select_kernel_isa` after `isa::supported(Isa::Avx2)` confirmed
    // avx2 + fma on this machine (see module docs).
    unsafe { micro_f32_6x16(apan, bpan, kc, acc, stride) }
}

/// # Safety
/// Requires AVX2 + FMA; `apan`/`bpan` hold `kc` packed depth steps of
/// 6 / 16 lanes; `acc` spans the 6x16 tile at `stride`.
#[target_feature(enable = "avx2,fma")]
unsafe fn micro_f32_6x16(apan: &[f32], bpan: &[f32], kc: usize,
                         acc: &mut [f32], stride: usize) {
    let mut t = [[_mm256_setzero_ps(); 2]; 6];
    for (i, trow) in t.iter_mut().enumerate() {
        let base = acc.as_ptr().add(i * stride);
        trow[0] = _mm256_loadu_ps(base);
        trow[1] = _mm256_loadu_ps(base.add(8));
    }
    for p in 0..kc {
        let bptr = bpan.as_ptr().add(p * 16);
        let b0 = _mm256_loadu_ps(bptr);
        let b1 = _mm256_loadu_ps(bptr.add(8));
        let aptr = apan.as_ptr().add(p * 6);
        for (i, trow) in t.iter_mut().enumerate() {
            let a = _mm256_set1_ps(*aptr.add(i));
            trow[0] = _mm256_fmadd_ps(a, b0, trow[0]);
            trow[1] = _mm256_fmadd_ps(a, b1, trow[1]);
        }
    }
    for (i, trow) in t.iter().enumerate() {
        let base = acc.as_mut_ptr().add(i * stride);
        _mm256_storeu_ps(base, trow[0]);
        _mm256_storeu_ps(base.add(8), trow[1]);
    }
}

// ---------------------------------------------------------------------------
// fixed-point codes: 4x8 AVX2 tile, exact i64 accumulation
// ---------------------------------------------------------------------------

/// AVX2 integer microkernel for the i32-code providers (FI and DRUM):
/// 4 rows x 8 columns, i64 lanes (two `__m256i` of four i64 per row).
/// Matches `MicroFn<A>` for any `A` packing to i32 / accumulating in
/// i64.
///
/// Bit-exact vs the scalar microkernel: `VPMULDQ` multiplies the
/// sign-extended low 32 bits of each 64-bit lane — an exact
/// i32 x i32 -> i64 product — and integer addition is associative.
pub(crate) fn micro_i32_avx2<A: MicroArith<Elem = i32, Acc = i64>>(
    _arith: &A, apan: &[i32], bpan: &[i32], kc: usize, acc: &mut [i64],
    stride: usize,
) {
    debug_assert!(apan.len() >= kc * 4 && bpan.len() >= kc * 8);
    debug_assert!(acc.len() >= 3 * stride + 8);
    // SAFETY: see module docs — only constructed when Avx2 is
    // supported.
    unsafe { micro_i32_4x8(apan, bpan, kc, acc, stride) }
}

/// # Safety
/// Requires AVX2; `apan`/`bpan` hold `kc` packed depth steps of 4 / 8
/// lanes; `acc` spans the 4x8 tile at `stride`.
#[target_feature(enable = "avx2")]
unsafe fn micro_i32_4x8(apan: &[i32], bpan: &[i32], kc: usize,
                        acc: &mut [i64], stride: usize) {
    let mut t = [[_mm256_setzero_si256(); 2]; 4];
    for (i, trow) in t.iter_mut().enumerate() {
        let base = acc.as_ptr().add(i * stride) as *const __m256i;
        trow[0] = _mm256_loadu_si256(base);
        trow[1] = _mm256_loadu_si256(base.add(1));
    }
    for p in 0..kc {
        let bptr = bpan.as_ptr().add(p * 8);
        // widen 4+4 i32 codes to i64 lanes; VPMULDQ below reads (and
        // sign-extends) only the low 32 bits of each lane, so the
        // product is the exact i32 x i32 -> i64 the scalar path does
        let b0 = _mm256_cvtepi32_epi64(
            _mm_loadu_si128(bptr as *const __m128i));
        let b1 = _mm256_cvtepi32_epi64(
            _mm_loadu_si128(bptr.add(4) as *const __m128i));
        let aptr = apan.as_ptr().add(p * 4);
        for (i, trow) in t.iter_mut().enumerate() {
            let a = _mm256_set1_epi64x(*aptr.add(i) as i64);
            trow[0] = _mm256_add_epi64(trow[0], _mm256_mul_epi32(a, b0));
            trow[1] = _mm256_add_epi64(trow[1], _mm256_mul_epi32(a, b1));
        }
    }
    for (i, trow) in t.iter().enumerate() {
        let base = acc.as_mut_ptr().add(i * stride) as *mut __m256i;
        _mm256_storeu_si256(base, trow[0]);
        _mm256_storeu_si256(base.add(1), trow[1]);
    }
}

// ---------------------------------------------------------------------------
// binary: POPCNT instantiation of the shared word-panel drive
// ---------------------------------------------------------------------------

/// Binary word-panel drive with hardware POPCNT.  Matches
/// `BinaryDriveFn`; the body is the *same* `binary_drive_impl` the
/// scalar kernel runs (`inline(always)` lets the `popcnt` target
/// feature reach its `count_ones` calls), so results are bit-exact by
/// construction — the ISA variant only changes the emitted popcount
/// instruction and the BMR/BNR word-tile shape it is instantiated at.
#[allow(clippy::too_many_arguments)]
pub(crate) fn binary_drive_popcnt<const BMR: usize, const BNR: usize>(
    ap: &[u64], bp: &[u64], row0: usize, chunk: &mut [f32],
    words: usize, tail_mask: u64, k: usize, n: usize, ep: &Epilogue,
) {
    // SAFETY: see module docs — only constructed when Avx2 (which
    // requires popcnt) is supported.
    unsafe {
        binary_drive_popcnt_inner::<BMR, BNR>(ap, bp, row0, chunk,
                                              words, tail_mask, k, n,
                                              ep)
    }
}

/// # Safety
/// Requires POPCNT (x86_64's baseline `count_ones` lowering is a bit
/// ladder without it).
#[target_feature(enable = "popcnt")]
unsafe fn binary_drive_popcnt_inner<const BMR: usize, const BNR: usize>(
    ap: &[u64], bp: &[u64], row0: usize, chunk: &mut [f32],
    words: usize, tail_mask: u64, k: usize, n: usize, ep: &Epilogue,
) {
    binary_drive_impl::<BMR, BNR>(ap, bp, row0, chunk, words, tail_mask,
                                  k, n, ep)
}

// ---------------------------------------------------------------------------
// epilogue: 8-lane AVX2 bias + relu, bound next to the SIMD microkernels
// ---------------------------------------------------------------------------

/// AVX2 epilogue row application, bound by `select_kernel_isa` into
/// the f32 and integer AVX2 kernels (matches
/// [`super::kernel::EpilogueFn`]).  FL/CFPU kernels stay scalar at
/// every tier, so they keep [`super::kernel::epilogue_scalar`].
///
/// Bit-identical to the scalar [`Epilogue::apply_row`]:
///
/// * the bias add is `_mm256_add_ps` — IEEE single addition, the same
///   operation per lane as the scalar `+`;
/// * the relu is a compare + andnot (`v < 0.0 ? 0.0 : v`), not
///   `_mm256_max_ps`: max would turn `-0.0` into `+0.0` (and its
///   NaN-propagation depends on operand order), while `LT_OQ` is false
///   for both `-0.0` (equal to zero) and NaN — so negative zeros and
///   NaNs survive exactly as the scalar branch leaves them;
/// * the quantize step of [`Epilogue::BiasReluQuant`] runs as a scalar
///   sweep over the (still cache-resident) segment — the lattice snap
///   is per-kind control flow, not yet profitably vectorizable.
pub(crate) fn epilogue_avx2(ep: &Epilogue, row: &mut [f32],
                            col0: usize) {
    match ep {
        Epilogue::None => {}
        Epilogue::Bias { bias } => {
            // SAFETY: see module docs — only bound into kernels
            // constructed when Avx2 is supported.
            unsafe { bias_relu_avx2(row, &bias[col0..], false) }
        }
        Epilogue::BiasRelu { bias } => {
            // SAFETY: as above.
            unsafe { bias_relu_avx2(row, &bias[col0..], true) }
        }
        Epilogue::BiasReluQuant { bias, quant } => {
            // SAFETY: as above.
            unsafe { bias_relu_avx2(row, &bias[col0..], true) }
            for v in row.iter_mut() {
                *v = quant.quantize(*v);
            }
        }
    }
}

/// # Safety
/// Requires AVX2; `bias` covers `row.len()` entries.
#[target_feature(enable = "avx2")]
unsafe fn bias_relu_avx2(row: &mut [f32], bias: &[f32], relu: bool) {
    debug_assert!(bias.len() >= row.len());
    let n = row.len();
    let zero = _mm256_setzero_ps();
    let mut j = 0;
    while j + 8 <= n {
        let p = row.as_mut_ptr().add(j);
        let mut v = _mm256_add_ps(_mm256_loadu_ps(p),
                                  _mm256_loadu_ps(bias.as_ptr().add(j)));
        if relu {
            // keep v where !(v < 0), i.e. zero exactly the strictly
            // negative lanes — -0.0 and NaN pass through untouched
            let neg = _mm256_cmp_ps::<_CMP_LT_OQ>(v, zero);
            v = _mm256_andnot_ps(neg, v);
        }
        _mm256_storeu_ps(p, v);
        j += 8;
    }
    for (v, b) in row[j..].iter_mut().zip(&bias[j..]) {
        *v += *b;
        if relu && *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::epilogue_avx2;
    use crate::approx::arith::ArithKind;
    use crate::nn::gemm::isa::{supported, Isa};
    use crate::nn::gemm::reference::gemm_reference;
    use crate::nn::gemm::{fma_f32_bound, select_kernel_isa, Epilogue,
                          Kernel};
    use crate::util::prng::Rng;

    /// Tail-heavy shape: m, n not divisible by any tile in play (6,
    /// 16, 4, 8), k crosses the KC = 256 depth blocking and ends
    /// mid-word for the binary path.
    const SHAPES: [(usize, usize, usize); 3] =
        [(13, 300, 11), (7, 65, 17), (64, 129, 96)];

    fn rand_operands(seed: u64, kind: &ArithKind, m: usize, k: usize,
                     n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> =
            (0..m * k).map(|_| (rng.normal() * 2.0) as f32).collect();
        let w: Vec<f32> = (0..k * n)
            .map(|_| kind.quantize(rng.normal() as f32))
            .collect();
        (x, w)
    }

    #[test]
    fn avx2_int_and_binary_bit_exact_vs_reference() {
        if !supported(Isa::Avx2) {
            return; // kernels not constructible here; covered in CI
        }
        for ks in ["FI(6,8)", "FI(3,4)", "H(6,8,6)", "H(8,8,14)",
                   "binxnor"] {
            let kind = ArithKind::parse(ks).unwrap();
            let kern = select_kernel_isa(&kind, Isa::Avx2);
            for (si, &(m, k, n)) in SHAPES.iter().enumerate() {
                let (x, w) =
                    rand_operands(41 + si as u64, &kind, m, k, n);
                let mut got = vec![f32::NAN; m * n];
                kern.run(&x, &w, m, k, n, &mut got, 1,
                         &Epilogue::None);
                let mut want = vec![f32::NAN; m * n];
                gemm_reference(&kind, &x, &w, m, k, n, &mut want, 1);
                for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), ww.to_bits(),
                               "{ks} ({m}x{k}x{n}): out[{i}] = {g} vs \
                                reference {ww}");
                }
            }
        }
    }

    #[test]
    fn avx2_f32_within_fma_bound_of_reference() {
        if !supported(Isa::Avx2) {
            return;
        }
        let kind = ArithKind::Float32;
        let kern = select_kernel_isa(&kind, Isa::Avx2);
        for (si, &(m, k, n)) in SHAPES.iter().enumerate() {
            let (x, w) = rand_operands(51 + si as u64, &kind, m, k, n);
            let mut got = vec![f32::NAN; m * n];
            kern.run(&x, &w, m, k, n, &mut got, 1, &Epilogue::None);
            let mut want = vec![f32::NAN; m * n];
            gemm_reference(&kind, &x, &w, m, k, n, &mut want, 1);
            let bound = fma_f32_bound(&x, &w, m, k, n);
            for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
                let err = (*g as f64 - *ww as f64).abs();
                assert!(err <= bound[i],
                        "f32+avx2 ({m}x{k}x{n}): out[{i}] = {g} vs \
                         reference {ww}, |err| = {err:e} > bound \
                         {:e}",
                        bound[i]);
            }
        }
    }

    /// The AVX2 epilogue must be *bitwise* the scalar
    /// `Epilogue::apply_row` — including the awkward lanes: -0.0
    /// (branch relu keeps it, max would not), NaN (kept), values that
    /// cross zero only after the bias add, vector body + scalar tail,
    /// and non-zero `col0` offsets into the bias.
    #[test]
    fn avx2_epilogue_bitwise_matches_scalar() {
        if !supported(Isa::Avx2) {
            return;
        }
        let mut rng = Rng::new(61);
        let quant = ArithKind::parse("FI(4,6)").unwrap();
        for len in [0usize, 1, 7, 8, 9, 16, 37] {
            for col0 in [0usize, 3] {
                let bias: Vec<f32> = (0..col0 + len)
                    .map(|_| rng.normal() as f32)
                    .collect();
                let mut base: Vec<f32> = (0..len)
                    .map(|_| (rng.normal() * 2.0) as f32)
                    .collect();
                // salt in the awkward values
                for (i, v) in base.iter_mut().enumerate() {
                    match i % 5 {
                        0 => *v = -0.0,
                        1 => *v = f32::NAN,
                        2 => *v = -(v.abs() + 1.0),
                        _ => {}
                    }
                }
                let eps = [
                    Epilogue::Bias { bias: &bias },
                    Epilogue::BiasRelu { bias: &bias },
                    Epilogue::BiasReluQuant { bias: &bias, quant },
                ];
                for ep in &eps {
                    let mut scalar = base.clone();
                    ep.apply_row(&mut scalar, col0);
                    let mut simd = base.clone();
                    epilogue_avx2(ep, &mut simd, col0);
                    for (i, (s, v)) in
                        scalar.iter().zip(&simd).enumerate()
                    {
                        assert_eq!(
                            s.to_bits(), v.to_bits(),
                            "len={len} col0={col0} lane {i}: scalar \
                             {s} vs avx2 {v}"
                        );
                    }
                }
            }
        }
    }
}
