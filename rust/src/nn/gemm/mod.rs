//! Packed, cache-tiled GEMM kernels — one monomorphized kernel per
//! arithmetic provider, no dispatch inside MAC loops.  This is the L3
//! performance hot path (§Perf in EXPERIMENTS.md records the
//! optimization iterations).
//!
//! All kernels compute `out[m,n] = quant(x)[m,k] · w[k,n]` with *wide*
//! accumulation (i64 for fixed-point codes, f64 for float lattices),
//! mirroring the widened-partial-sum datapath of the paper (§4.2) and
//! the f32-accumulation semantics of the PJRT artifacts.
//!
//! Module split (§Perf iteration 6 — the packed/tiled architecture):
//!
//! * [`micro`] — `MicroArith`: packed element + wide accumulator +
//!   fused operand conditioning, one impl per `ArithKind` variant;
//! * [`pack`] — `pack_a_block` / `pack_b_block`: MR-row / NR-column
//!   panels with conditioning fused into the copy (O(mk + kn) once);
//! * [`kernel`] — the object-safe [`Kernel`] trait, the MC/KC/NC
//!   blocked driver, the MR x NR register-tile microkernel, and the
//!   bit-packed binary/XNOR kernel;
//! * [`reference`] — the pre-tiling kernels, kept as the oracle:
//!   `tests/gemm_differential.rs` proves the packed path bit-identical
//!   to them for every provider across randomized shapes and thread
//!   counts.
//!
//! [`GemmPlan`] is the selection layer: resolve an [`ArithKind`] to its
//! kernel once (per prepared layer, per bench case), then `run`
//! repeatedly.  [`gemm`] is the one-shot convenience wrapper.
//!
//! Weight matrices are *constant* per prepared layer, so the plan can
//! additionally own their conditioned panels: [`GemmPlan::prepack`]
//! runs the kernel's weight-side packing ([`Kernel::prepack_weights`])
//! once, and [`GemmPlan::run_prepacked`] / [`GemmPlan::run_cached`]
//! then serve every forward pass from the cached [`PackedWeights`] —
//! zero weight-side `pack_b_block`/bitmap-encode work per call
//! (`tests/prepack_differential.rs` proves the cached path bit-identical
//! to [`reference`] and pins the zero-repack contract via
//! [`pack::weight_pack_count`]).

pub mod kernel;
pub mod micro;
pub mod pack;
pub mod reference;

pub use kernel::{default_threads, weight_fingerprint, Kernel,
                 PackedWeights, KC, MC, NC};

use crate::approx::arith::ArithKind;
use kernel::{BinaryKernel, BlockedKernel};
use micro::{CfpuMicro, DrumMicro, F32Micro, FixedMicro, FloatMicro};

/// The name of the kernel [`select_kernel`] resolves for `kind`,
/// without constructing it — for plan reporting (`execution_plan`)
/// on hot paths like the explorer's backend choice.
pub fn kernel_name(kind: &ArithKind) -> &'static str {
    match kind {
        ArithKind::Float32 => "packed-f32",
        ArithKind::FixedExact(_) => "packed-fi",
        ArithKind::FixedDrum(_) => "packed-drum",
        ArithKind::FloatExact(_) => "packed-fl",
        ArithKind::FloatCfpu(_) => "packed-cfpu",
        ArithKind::Binary => "packed-binxnor",
    }
}

/// Resolve the packed kernel for a provider.  Microkernel tiles: 8x8
/// for f32 (f32 register tile), 4x8 for the i64/f64 accumulators, 4x4
/// for CFPU (scalar-heavy inner op) and binary (word panels).
pub fn select_kernel(kind: &ArithKind) -> Box<dyn Kernel> {
    match kind {
        ArithKind::Float32 => {
            Box::new(BlockedKernel::<_, 8, 8>::new(F32Micro))
        }
        ArithKind::FixedExact(rep) => {
            Box::new(BlockedKernel::<_, 4, 8>::new(FixedMicro::new(*rep)))
        }
        ArithKind::FixedDrum(d) => {
            Box::new(BlockedKernel::<_, 4, 8>::new(DrumMicro::new(*d)))
        }
        ArithKind::FloatExact(rep) => {
            Box::new(BlockedKernel::<_, 4, 8>::new(FloatMicro::new(*rep)))
        }
        ArithKind::FloatCfpu(c) => {
            Box::new(BlockedKernel::<_, 4, 4>::new(CfpuMicro::new(*c)))
        }
        ArithKind::Binary => Box::new(BinaryKernel),
    }
}

/// A resolved (provider -> packed kernel) pairing, optionally carrying
/// the layer's prepacked weight panels.  Layers resolve their plan
/// once at `prepare` time — which also conditions the constant weight
/// matrix into panels via [`GemmPlan::prepack`] — and reuse both every
/// forward pass; the explorer and benches do the same per
/// configuration.
///
/// ```
/// use lop::approx::arith::ArithKind;
/// use lop::nn::gemm::GemmPlan;
///
/// let plan = GemmPlan::new(&ArithKind::parse("FI(6,8)").unwrap());
/// assert_eq!(plan.kernel_name(), "packed-fi");
/// let (x, w) = ([0.5f32, -1.0], [2.0f32]);
/// let mut out = [0.0f32; 2];
/// plan.run(&x, &w, 2, 1, 1, &mut out, 1);
/// assert_eq!(out, [1.0, -2.0]);
/// ```
///
/// Prepack once, run many (the serving hot path — no weight-side
/// packing per call):
///
/// ```
/// use lop::approx::arith::ArithKind;
/// use lop::nn::gemm::GemmPlan;
///
/// let mut plan = GemmPlan::new(&ArithKind::parse("FI(6,8)").unwrap());
/// plan.prepack(&[2.0f32], 1, 1); // the layer's constant 1 x 1 weights
/// assert!(plan.packed_weights().is_some());
/// let mut out = [0.0f32; 2];
/// plan.run_prepacked(&[0.5, -1.0], 2, &mut out, 1);
/// assert_eq!(out, [1.0, -2.0]);
/// ```
pub struct GemmPlan {
    kind: ArithKind,
    kernel: Box<dyn Kernel>,
    /// Cached conditioned weight panels (`prepack`); `run_cached` and
    /// `run_prepacked` consume these instead of re-packing per call.
    packed: Option<PackedWeights>,
}

impl GemmPlan {
    pub fn new(kind: &ArithKind) -> GemmPlan {
        GemmPlan { kind: *kind, kernel: select_kernel(kind), packed: None }
    }

    pub fn kind(&self) -> &ArithKind {
        &self.kind
    }

    /// The selected kernel's name (e.g. `packed-fi`), for logs and the
    /// runtime's execution-plan reporting.
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// `out = quant(x) @ w`.  `w` must already be quantized (the layer
    /// does this once at load time); `out.len() == m * n`; `threads`
    /// 0 means all cores.
    pub fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize,
               n: usize, out: &mut [f32], threads: usize) {
        assert_eq!(x.len(), m * k, "x shape mismatch");
        assert_eq!(w.len(), k * n, "w shape mismatch");
        assert_eq!(out.len(), m * n, "out shape mismatch");
        if m == 0 || n == 0 {
            return;
        }
        if k == 0 {
            out.fill(0.0);
            return;
        }
        self.kernel.run(x, w, m, k, n, out, threads);
    }

    /// Condition `w` (`k` x `n`, row-major, already quantized — the
    /// same contract as [`GemmPlan::run`]) into the kernel's panel
    /// layout and cache the panels on this plan.  Replaces any
    /// previously cached panels.
    pub fn prepack(&mut self, w: &[f32], k: usize, n: usize) {
        assert_eq!(w.len(), k * n, "w shape mismatch");
        self.packed = Some(self.kernel.prepack_weights(w, k, n));
    }

    /// The cached weight panels, if [`GemmPlan::prepack`] has run.
    pub fn packed_weights(&self) -> Option<&PackedWeights> {
        self.packed.as_ref()
    }

    /// Whether this plan carries prepacked weight panels.  After
    /// `Model::prepare` every layer plan does; the plan (and the
    /// `PreparedNet` owning it) is immutable from then on, which is
    /// what lets `coordinator::plan_cache` share one prepared network
    /// across engine workers behind an `Arc`.
    pub fn is_prepacked(&self) -> bool {
        self.packed.is_some()
    }

    /// Bytes resident in this plan's cached panels (0 when not
    /// prepacked) — surfaced through `coordinator::metrics`.
    pub fn panel_bytes(&self) -> usize {
        self.packed.as_ref().map_or(0, |p| p.resident_bytes())
    }

    /// `out = quant(x) @ w_prepacked`: the weight side comes entirely
    /// from the panels cached by [`GemmPlan::prepack`] (which fixed
    /// `k` and `n`) — zero weight-side conditioning or packing per
    /// call.  Panics if the plan was never prepacked.
    pub fn run_prepacked(&self, x: &[f32], m: usize, out: &mut [f32],
                         threads: usize) {
        let pw = self
            .packed
            .as_ref()
            .expect("GemmPlan::run_prepacked called before prepack");
        let (k, n) = (pw.k(), pw.n());
        assert_eq!(x.len(), m * k, "x shape mismatch");
        assert_eq!(out.len(), m * n, "out shape mismatch");
        if m == 0 || n == 0 {
            return;
        }
        if k == 0 {
            out.fill(0.0);
            return;
        }
        self.kernel.run_prepacked(x, pw, m, out, threads);
    }

    /// The layer entry point: run on the cached panels when the plan
    /// is prepacked (in which case `w` MUST be the matrix that was
    /// prepacked — debug builds verify its fingerprint), else pack `w`
    /// per call like [`GemmPlan::run`].
    pub fn run_cached(&self, x: &[f32], w: &[f32], m: usize, k: usize,
                      n: usize, out: &mut [f32], threads: usize) {
        match &self.packed {
            Some(pw) => {
                assert_eq!(
                    (pw.k(), pw.n()),
                    (k, n),
                    "prepacked panels are {}x{}, call is {k}x{n}",
                    pw.k(),
                    pw.n()
                );
                debug_assert_eq!(
                    weight_fingerprint(w),
                    pw.fingerprint(),
                    "run_cached: w is not the prepacked weight matrix"
                );
                self.run_prepacked(x, m, out, threads);
            }
            None => self.run(x, w, m, k, n, out, threads),
        }
    }
}

/// `out = quant(x) @ w` for any provider — one-shot wrapper around
/// [`GemmPlan`].
///
/// ```
/// use lop::approx::arith::ArithKind;
/// use lop::nn::gemm::gemm;
///
/// // FI(6, 8): x entries below are exactly representable, and an
/// // identity weight matrix is on every lattice, so the product is
/// // exact — out equals x.
/// let kind = ArithKind::parse("FI(6,8)").unwrap();
/// let x = [0.5f32, -1.0, 2.0, 0.25]; // 2 x 2, row-major
/// let w = [1.0f32, 0.0, 0.0, 1.0]; // identity, pre-quantized
/// let mut out = [0.0f32; 4];
/// gemm(&kind, &x, &w, 2, 2, 2, &mut out, 1);
/// assert_eq!(out, x);
/// ```
pub fn gemm(kind: &ArithKind, x: &[f32], w: &[f32], m: usize, k: usize,
            n: usize, out: &mut [f32], threads: usize) {
    GemmPlan::new(kind).run(x, w, m, k, n, out, threads);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn naive(kind: &ArithKind, x: &[f32], w: &[f32], m: usize, k: usize,
             n: usize) -> Vec<f32> {
        // semantic reference: scalar quantize + wide scalar mul + f64
        // accumulate (f32-rounded scalar quantization makes this a
        // tolerance check, not a bit check — the bit-level oracle is
        // reference::gemm_reference)
        let mut out = vec![0.0f32; m * n];
        for r in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for kk in 0..k {
                    let a = kind.quantize(x[r * k + kk]);
                    acc += kind.mul_wide(a, w[kk * n + j]);
                }
                out[r * n + j] = acc as f32;
            }
        }
        out
    }

    fn rand_mats(seed: u64, m: usize, k: usize, n: usize)
                 -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..m * k).map(|_| (rng.normal() * 2.0) as f32)
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        (x, w)
    }

    fn check_kind(kind: ArithKind, seed: u64) {
        let (m, k, n) = (13, 37, 11);
        let (x, mut w) = rand_mats(seed, m, k, n);
        // weights pre-quantized, as the layer contract requires
        for wv in &mut w {
            *wv = kind.quantize(*wv);
        }
        let mut out = vec![0.0; m * n];
        gemm(&kind, &x, &w, m, k, n, &mut out, 1);
        let want = naive(&kind, &x, &w, m, k, n);
        for (idx, (g, ww)) in out.iter().zip(&want).enumerate() {
            let tol = 1e-4 * ww.abs().max(1.0);
            assert!(
                (g - ww).abs() <= tol,
                "{}: out[{idx}] = {g}, want {ww}",
                kind.name()
            );
        }
    }

    #[test]
    fn f32_matches_naive() {
        check_kind(ArithKind::Float32, 1);
    }

    #[test]
    fn fixed_exact_matches_naive() {
        check_kind(ArithKind::parse("FI(6,8)").unwrap(), 2);
        check_kind(ArithKind::parse("FI(3,4)").unwrap(), 3);
    }

    #[test]
    fn fixed_drum_matches_naive() {
        check_kind(ArithKind::parse("H(6,8,6)").unwrap(), 4);
        check_kind(ArithKind::parse("H(8,8,14)").unwrap(), 5);
    }

    #[test]
    fn float_exact_matches_naive() {
        check_kind(ArithKind::parse("FL(4,9)").unwrap(), 6);
        check_kind(ArithKind::parse("FL(5,10)").unwrap(), 7);
    }

    #[test]
    fn float_cfpu_matches_naive() {
        check_kind(ArithKind::parse("I(5,10)").unwrap(), 8);
        check_kind(ArithKind::parse("I(4,9,2)").unwrap(), 9);
    }

    #[test]
    fn binary_matches_pm1_dot() {
        let (m, k, n) = (5, 130, 7); // k > 2 words incl. tail
        let (x, w) = rand_mats(10, m, k, n);
        let mut out = vec![0.0; m * n];
        gemm(&ArithKind::Binary, &x, &w, m, k, n, &mut out, 1);
        for r in 0..m {
            for j in 0..n {
                let mut dot = 0f32;
                for kk in 0..k {
                    let a = if x[r * k + kk] >= 0.0 { 1.0 } else { -1.0 };
                    let b = if w[kk * n + j] >= 0.0 { 1.0 } else { -1.0 };
                    dot += a * b;
                }
                assert_eq!(out[r * n + j], dot, "r={r} j={j}");
            }
        }
    }

    #[test]
    fn packed_bit_identical_to_reference_smoke() {
        // The full randomized sweep lives in tests/gemm_differential.rs;
        // this in-module smoke keeps the invariant visible to plain
        // `cargo test` on shapes that exercise every tail path (m, n
        // not divisible by any tile, k crossing a KC boundary).
        let (m, k, n) = (13, 300, 11);
        for ks in ["float32", "FI(6,8)", "H(6,8,6)", "FL(4,9)",
                   "I(5,10)", "binxnor"] {
            let kind = ArithKind::parse(ks).unwrap();
            let (x, mut w) = rand_mats(20, m, k, n);
            for wv in &mut w {
                *wv = kind.quantize(*wv);
            }
            let mut got = vec![0.0; m * n];
            let mut want = vec![0.0; m * n];
            gemm(&kind, &x, &w, m, k, n, &mut got, 1);
            reference::gemm_reference(&kind, &x, &w, m, k, n, &mut want,
                                      1);
            for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), ww.to_bits(),
                           "{ks}: out[{i}] = {g} vs reference {ww}");
            }
        }
    }

    #[test]
    fn multithreaded_matches_single() {
        for kind in [
            ArithKind::Float32,
            ArithKind::parse("FI(6,8)").unwrap(),
            ArithKind::parse("H(6,8,12)").unwrap(),
            ArithKind::parse("FL(4,9)").unwrap(),
        ] {
            let (m, k, n) = (64, 100, 96); // big enough to engage threads
            let (x, mut w) = rand_mats(11, m, k, n);
            for wv in &mut w {
                *wv = kind.quantize(*wv);
            }
            let mut a = vec![0.0; m * n];
            let mut b = vec![0.0; m * n];
            gemm(&kind, &x, &w, m, k, n, &mut a, 1);
            gemm(&kind, &x, &w, m, k, n, &mut b, 4);
            assert_eq!(a, b, "{}", kind.name());
        }
    }

    #[test]
    fn plan_reuse_is_stable() {
        let kind = ArithKind::parse("FI(6,8)").unwrap();
        let plan = GemmPlan::new(&kind);
        assert_eq!(plan.kind(), &kind);
        let (m, k, n) = (9, 17, 5);
        let (x, mut w) = rand_mats(12, m, k, n);
        for wv in &mut w {
            *wv = kind.quantize(*wv);
        }
        let mut a = vec![0.0; m * n];
        let mut b = vec![0.0; m * n];
        plan.run(&x, &w, m, k, n, &mut a, 1);
        plan.run(&x, &w, m, k, n, &mut b, 1);
        assert_eq!(a, b);
    }

    #[test]
    fn kernel_names_per_kind() {
        for (ks, name) in [
            ("float32", "packed-f32"),
            ("FI(6,8)", "packed-fi"),
            ("H(6,8,12)", "packed-drum"),
            ("FL(4,9)", "packed-fl"),
            ("I(5,10)", "packed-cfpu"),
            ("binxnor", "packed-binxnor"),
        ] {
            let kind = ArithKind::parse(ks).unwrap();
            assert_eq!(GemmPlan::new(&kind).kernel_name(), name, "{ks}");
            // the allocation-free name lookup must agree with the
            // constructed kernel
            assert_eq!(kernel_name(&kind), name, "{ks}");
            let kern = select_kernel(&kind);
            assert!(kern.mr() >= 1 && kern.nr() >= 1);
        }
    }

    #[test]
    fn zero_sized_edges() {
        let kind = ArithKind::Float32;
        let mut out = vec![0.0; 0];
        gemm(&kind, &[], &[], 0, 0, 0, &mut out, 1);
        let mut out1 = vec![0.0; 1];
        gemm(&kind, &[2.0], &[3.0], 1, 1, 1, &mut out1, 1);
        assert_eq!(out1[0], 6.0);
        // k = 0 with nonzero m, n zeroes the output
        let mut out2 = vec![7.0f32; 6];
        gemm(&kind, &[], &[], 2, 0, 3, &mut out2, 1);
        assert!(out2.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn prepacked_matches_run_smoke() {
        // The full randomized sweep lives in
        // tests/prepack_differential.rs; this smoke keeps the cached
        // path visible to plain `cargo test` on a tail-heavy shape.
        let (m, k, n) = (13, 300, 11);
        for ks in ["float32", "FI(6,8)", "H(6,8,6)", "FL(4,9)",
                   "I(5,10)", "binxnor"] {
            let kind = ArithKind::parse(ks).unwrap();
            let (x, mut w) = rand_mats(30, m, k, n);
            for wv in &mut w {
                *wv = kind.quantize(*wv);
            }
            let mut plan = GemmPlan::new(&kind);
            plan.prepack(&w, k, n);
            let mut got = vec![0.0; m * n];
            plan.run_prepacked(&x, m, &mut got, 1);
            let mut want = vec![0.0; m * n];
            plan.run(&x, &w, m, k, n, &mut want, 1);
            for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), ww.to_bits(),
                           "{ks}: out[{i}] = {g} vs per-call {ww}");
            }
            // run_cached hits the same panels
            let mut cached = vec![0.0; m * n];
            plan.run_cached(&x, &w, m, k, n, &mut cached, 1);
            assert_eq!(cached, want, "{ks}");
        }
    }

    #[test]
    fn prepacked_zero_sized_edges() {
        let kind = ArithKind::Float32;
        // k = 0: panels are empty, output zeroed
        let mut plan = GemmPlan::new(&kind);
        plan.prepack(&[], 0, 3);
        let mut out = vec![7.0f32; 6];
        plan.run_prepacked(&[], 2, &mut out, 1);
        assert!(out.iter().all(|&v| v == 0.0));
        // m = 0: no output
        let mut plan1 = GemmPlan::new(&kind);
        plan1.prepack(&[1.0, 2.0, 3.0], 1, 3);
        let mut empty: [f32; 0] = [];
        plan1.run_prepacked(&[], 0, &mut empty, 1);
        // n = 1 single column
        let mut plan2 = GemmPlan::new(&kind);
        plan2.prepack(&[2.0, 4.0], 2, 1);
        let mut out1 = [0.0f32; 1];
        plan2.run_prepacked(&[1.0, 0.5], 1, &mut out1, 1);
        assert_eq!(out1[0], 4.0);
    }

    #[test]
    fn prepack_replaces_panels() {
        let kind = ArithKind::Float32;
        let mut plan = GemmPlan::new(&kind);
        assert!(!plan.is_prepacked());
        plan.prepack(&[1.0], 1, 1);
        assert!(plan.is_prepacked());
        let fp0 = plan.packed_weights().unwrap().fingerprint();
        plan.prepack(&[2.0], 1, 1);
        let fp1 = plan.packed_weights().unwrap().fingerprint();
        assert_ne!(fp0, fp1);
        let mut out = [0.0f32; 1];
        plan.run_prepacked(&[3.0], 1, &mut out, 1);
        assert_eq!(out[0], 6.0);
        assert!(plan.panel_bytes() > 0);
    }

    #[test]
    #[should_panic(expected = "before prepack")]
    fn run_prepacked_requires_prepack() {
        let plan = GemmPlan::new(&ArithKind::Float32);
        let mut out = [0.0f32; 1];
        plan.run_prepacked(&[1.0], 1, &mut out, 1);
    }
}
