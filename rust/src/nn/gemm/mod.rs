//! Packed, cache-tiled GEMM kernels — one monomorphized kernel per
//! arithmetic provider, no dispatch inside MAC loops.  This is the L3
//! performance hot path (§Perf in EXPERIMENTS.md records the
//! optimization iterations).
//!
//! All kernels compute `out[m,n] = quant(x)[m,k] · w[k,n]` with *wide*
//! accumulation (i64 for fixed-point codes, f64 for float lattices),
//! mirroring the widened-partial-sum datapath of the paper (§4.2) and
//! the f32-accumulation semantics of the PJRT artifacts.
//!
//! Module split (§Perf iterations 6–9 — the packed/tiled architecture
//! plus runtime ISA dispatch):
//!
//! * [`micro`] — `MicroArith`: packed element + wide accumulator +
//!   fused operand conditioning, one impl per `ArithKind` variant;
//! * [`pack`] — `pack_a_block` / `pack_b_block`: MR-row / NR-column
//!   panels with conditioning fused into the copy (O(mk + kn) once);
//! * [`kernel`] — the object-safe [`Kernel`] trait, the blocked driver
//!   (per-kernel MR/NR via `eff_blocks`), the portable register-tile
//!   microkernel, and the bit-packed binary/XNOR kernel;
//! * [`isa`] — runtime ISA detection and the `LOP_FORCE_ISA` override:
//!   [`GemmPlan::new`] resolves the widest supported kernel once, at
//!   plan-build time;
//! * `simd` (x86_64) — `target_feature`-gated AVX2+FMA / AVX2 /
//!   POPCNT microkernels the dispatch layer binds into the driver;
//! * [`reference`] — the pre-tiling kernels, kept as the oracle:
//!   `tests/gemm_differential.rs` proves the packed path matches them
//!   for every provider and every detected ISA across randomized
//!   shapes and thread counts (bitwise for every integer/bit-parallel
//!   kernel; within [`fma_f32_bound`] for the AVX2+FMA f32 kernel,
//!   where fused rounding is the point).
//!
//! [`GemmPlan`] is the selection layer: resolve an [`ArithKind`] (at
//! the active [`Isa`]) to its kernel once (per prepared layer, per
//! bench case), then `run` repeatedly.  [`gemm`] is the one-shot
//! convenience wrapper.
//!
//! Weight matrices are *constant* per prepared layer, so the plan can
//! additionally own their conditioned panels: [`GemmPlan::prepack`]
//! runs the kernel's weight-side packing ([`Kernel::prepack_weights`])
//! once, and [`GemmPlan::run_prepacked`] / [`GemmPlan::run_cached`]
//! then serve every forward pass from the cached [`PackedWeights`] —
//! zero weight-side `pack_b_block`/bitmap-encode work per call
//! (`tests/prepack_differential.rs` proves the cached path matches
//! [`reference`] and pins the zero-repack contract via
//! [`pack::weight_pack_count`]).  Panels carry their kernel's name
//! (ISA-suffixed) and panel geometry, so panels packed under one
//! forced ISA panic — never mis-multiply — under another
//! (`tests/isa_dispatch.rs`).

pub mod isa;
pub mod kernel;
pub mod micro;
pub mod pack;
pub mod reference;
#[cfg(target_arch = "x86_64")]
mod simd;

pub use isa::Isa;
pub use kernel::{default_threads, weight_fingerprint, Epilogue, Kernel,
                 PackedWeights, KC, MC, NC};

use crate::approx::arith::ArithKind;
use kernel::{BinaryKernel, BlockedKernel};
use micro::{CfpuMicro, DrumMicro, F32Micro, FixedMicro, FloatMicro};

/// The name of the kernel [`select_kernel`] resolves for `kind` at the
/// process's active ISA, without constructing it — for plan reporting
/// (`execution_plan`) on hot paths like the explorer's backend choice.
pub fn kernel_name(kind: &ArithKind) -> &'static str {
    kernel_name_isa(kind, isa::active())
}

/// The name [`select_kernel_isa`] would report for `kind` at `isa` —
/// a pure name table (no feature detection): SIMD variants carry an
/// ISA suffix, providers without a SIMD kernel (FL's f64 lattice,
/// CFPU's class dispatch) keep their scalar name at every tier.
pub fn kernel_name_isa(kind: &ArithKind, isa: Isa) -> &'static str {
    match (isa, kind) {
        (Isa::Avx2, ArithKind::Float32) => "packed-f32+avx2",
        (Isa::Avx2, ArithKind::FixedExact(_)) => "packed-fi+avx2",
        (Isa::Avx2, ArithKind::FixedDrum(_)) => "packed-drum+avx2",
        (Isa::Avx2, ArithKind::Binary) => "packed-binxnor+popcnt",
        (_, ArithKind::Float32) => "packed-f32",
        (_, ArithKind::FixedExact(_)) => "packed-fi",
        (_, ArithKind::FixedDrum(_)) => "packed-drum",
        (_, ArithKind::FloatExact(_)) => "packed-fl",
        (_, ArithKind::FloatCfpu(_)) => "packed-cfpu",
        (_, ArithKind::Binary) => "packed-binxnor",
    }
}

/// Resolve the packed kernel for a provider at the process's active
/// ISA (`LOP_FORCE_ISA` override, else the widest detected — see
/// [`isa::active`]).
pub fn select_kernel(kind: &ArithKind) -> Box<dyn Kernel> {
    select_kernel_isa(kind, isa::active())
}

/// Resolve the packed kernel for a provider at an explicit ISA tier.
/// Panics if `isa` is not supported on this machine — a kernel must
/// never be constructed whose instructions cannot run (the safety
/// contract of the `simd` module).  The per-ISA differential suites
/// iterate [`isa::detected`] through this entry point.
pub fn select_kernel_isa(kind: &ArithKind, isa: Isa) -> Box<dyn Kernel> {
    assert!(
        isa::supported(isa),
        "cannot build `{}` kernels: ISA `{isa}` is not supported on \
         this machine",
        kernel_name_isa(kind, isa)
    );
    match isa {
        Isa::Scalar => select_scalar(kind),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => select_avx2(kind),
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => unreachable!("Avx2 is never supported off x86_64"),
    }
}

/// The portable kernels.  Microkernel tiles: 8x8 for f32 (f32 register
/// tile), 4x8 for the i64/f64 accumulators, 4x4 for CFPU
/// (scalar-heavy inner op) and binary (word panels).
fn select_scalar(kind: &ArithKind) -> Box<dyn Kernel> {
    match kind {
        ArithKind::Float32 => {
            Box::new(BlockedKernel::<_, 8, 8>::new(F32Micro))
        }
        ArithKind::FixedExact(rep) => {
            Box::new(BlockedKernel::<_, 4, 8>::new(FixedMicro::new(*rep)))
        }
        ArithKind::FixedDrum(d) => {
            Box::new(BlockedKernel::<_, 4, 8>::new(DrumMicro::new(*d)))
        }
        ArithKind::FloatExact(rep) => {
            Box::new(BlockedKernel::<_, 4, 8>::new(FloatMicro::new(*rep)))
        }
        ArithKind::FloatCfpu(c) => {
            Box::new(BlockedKernel::<_, 4, 4>::new(CfpuMicro::new(*c)))
        }
        ArithKind::Binary => Box::new(BinaryKernel::scalar()),
    }
}

/// The AVX2-tier kernels (only constructed after `isa::supported`
/// confirmed avx2 + fma + popcnt).  Tiles: 6x16 for f32 (12 ymm
/// accumulators + operands fill the register file), 4x8 i64 lanes for
/// the i32-code paths, an 8x8 word tile for binary.  FL (f64 lattice
/// quantization per MAC) and CFPU (3-way class dispatch per product)
/// have no profitable SIMD formulation — they keep the scalar kernel,
/// so their bit-exactness contract is ISA-independent.
#[cfg(target_arch = "x86_64")]
fn select_avx2(kind: &ArithKind) -> Box<dyn Kernel> {
    match kind {
        ArithKind::Float32 => {
            Box::new(BlockedKernel::<_, 6, 16>::with_micro(
                F32Micro, "packed-f32+avx2", Isa::Avx2,
                simd::micro_f32_avx2, simd::epilogue_avx2))
        }
        ArithKind::FixedExact(rep) => {
            Box::new(BlockedKernel::<_, 4, 8>::with_micro(
                FixedMicro::new(*rep), "packed-fi+avx2", Isa::Avx2,
                simd::micro_i32_avx2::<FixedMicro>,
                simd::epilogue_avx2))
        }
        ArithKind::FixedDrum(d) => {
            Box::new(BlockedKernel::<_, 4, 8>::with_micro(
                DrumMicro::new(*d), "packed-drum+avx2", Isa::Avx2,
                simd::micro_i32_avx2::<DrumMicro>,
                simd::epilogue_avx2))
        }
        ArithKind::FloatExact(_) | ArithKind::FloatCfpu(_) => {
            select_scalar(kind)
        }
        ArithKind::Binary => {
            Box::new(BinaryKernel::<8, 8>::with_drive(
                "packed-binxnor+popcnt", Isa::Avx2,
                simd::binary_drive_popcnt::<8, 8>))
        }
    }
}

/// Per-element tolerance for comparing an FMA/vectorized f32 kernel
/// against the scalar `reference` path — the documented tolerance
/// table of DESIGN.md §gemm, as code.
///
/// Both the scalar sum and the FMA-fused, NR-lane-vectorized sum fold
/// each output element's k products in increasing k order; standard
/// forward-error analysis bounds either ordering's error by
/// `γ_k · Σ|x·w|` with `γ_k ≈ k·u` (u = unit roundoff = ε/2), so the
/// *difference* between the two is at most `2 γ_k Σ|x·w| ≤ k·ε·Σ`.
/// This function returns `2·k·ε·Σ|x·w| + f32::MIN_POSITIVE` per
/// element — a further 2x headroom over the worst case, plus an
/// absolute floor so exact-zero sums compare non-strictly.
///
/// Every non-f32 kernel is bit-exact across ISAs (integer/bit
/// accumulation is associative; FL/CFPU have no SIMD variant), so this
/// bound applies to exactly one kernel: `packed-f32+avx2`.
pub fn fma_f32_bound(x: &[f32], w: &[f32], m: usize, k: usize,
                     n: usize) -> Vec<f64> {
    assert_eq!(x.len(), m * k, "x shape mismatch");
    assert_eq!(w.len(), k * n, "w shape mismatch");
    let mut bound = vec![0.0f64; m * n];
    for r in 0..m {
        for j in 0..n {
            let mut mag = 0.0f64;
            for kk in 0..k {
                mag +=
                    (x[r * k + kk] as f64 * w[kk * n + j] as f64).abs();
            }
            bound[r * n + j] = 2.0 * k as f64 * f32::EPSILON as f64
                * mag
                + f32::MIN_POSITIVE as f64;
        }
    }
    bound
}

/// A resolved (provider -> packed kernel) pairing, optionally carrying
/// the layer's prepacked weight panels.  Layers resolve their plan
/// once at `prepare` time — which also conditions the constant weight
/// matrix into panels via [`GemmPlan::prepack`] — and reuse both every
/// forward pass; the explorer and benches do the same per
/// configuration.  [`GemmPlan::new`] dispatches at the active ISA
/// ([`isa::active`]); [`GemmPlan::with_isa`] pins a tier explicitly
/// (the per-ISA test suites use this).
///
/// ```
/// use lop::approx::arith::ArithKind;
/// use lop::nn::gemm::{GemmPlan, Isa};
///
/// let kind = ArithKind::parse("FI(6,8)").unwrap();
/// let plan = GemmPlan::with_isa(&kind, Isa::Scalar);
/// assert_eq!(plan.kernel_name(), "packed-fi");
/// assert_eq!(plan.isa(), Isa::Scalar);
/// let (x, w) = ([0.5f32, -1.0], [2.0f32]);
/// let mut out = [0.0f32; 2];
/// plan.run(&x, &w, 2, 1, 1, &mut out, 1);
/// assert_eq!(out, [1.0, -2.0]);
/// ```
///
/// Prepack once, run many (the serving hot path — no weight-side
/// packing per call):
///
/// ```
/// use lop::approx::arith::ArithKind;
/// use lop::nn::gemm::GemmPlan;
///
/// let mut plan = GemmPlan::new(&ArithKind::parse("FI(6,8)").unwrap());
/// plan.prepack(&[2.0f32], 1, 1); // the layer's constant 1 x 1 weights
/// assert!(plan.packed_weights().is_some());
/// let mut out = [0.0f32; 2];
/// plan.run_prepacked(&[0.5, -1.0], 2, &mut out, 1);
/// assert_eq!(out, [1.0, -2.0]);
/// ```
pub struct GemmPlan {
    kind: ArithKind,
    kernel: Box<dyn Kernel>,
    /// Cached conditioned weight panels (`prepack`); `run_cached` and
    /// `run_prepacked` consume these instead of re-packing per call.
    packed: Option<PackedWeights>,
}

impl GemmPlan {
    /// A plan at the process's active ISA (`LOP_FORCE_ISA` override,
    /// else the widest detected).
    pub fn new(kind: &ArithKind) -> GemmPlan {
        GemmPlan::with_isa(kind, isa::active())
    }

    /// A plan pinned to an explicit ISA tier.  Panics if `isa` is not
    /// supported on this machine (see [`select_kernel_isa`]).
    pub fn with_isa(kind: &ArithKind, isa: Isa) -> GemmPlan {
        GemmPlan {
            kind: *kind,
            kernel: select_kernel_isa(kind, isa),
            packed: None,
        }
    }

    pub fn kind(&self) -> &ArithKind {
        &self.kind
    }

    /// The selected kernel's name (e.g. `packed-fi`, or
    /// `packed-fi+avx2` for a SIMD tier), for logs and the runtime's
    /// execution-plan reporting.
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// The ISA tier of the selected kernel.  Note this reports the
    /// *kernel's* tier: providers without a SIMD variant (FL, CFPU)
    /// report [`Isa::Scalar`] even when the plan was built at a wider
    /// tier, because the scalar kernel *is* their widest kernel.
    pub fn isa(&self) -> Isa {
        self.kernel.isa()
    }

    /// `out = quant(x) @ w`.  `w` must already be quantized (the layer
    /// does this once at load time); `out.len() == m * n`; `threads`
    /// 0 means all cores.
    pub fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize,
               n: usize, out: &mut [f32], threads: usize) {
        self.run_with(x, w, m, k, n, out, threads, &Epilogue::None);
    }

    /// [`GemmPlan::run`] with a fused [`Epilogue`] applied per output
    /// tile while it is cache-resident.  With `Epilogue::None` this is
    /// exactly `run`; with a bias-carrying epilogue the result is bit
    /// for bit what `run` + the separate `vecmath` passes would
    /// produce (pinned by `tests/epilogue_differential.rs`).
    pub fn run_with(&self, x: &[f32], w: &[f32], m: usize, k: usize,
                    n: usize, out: &mut [f32], threads: usize,
                    ep: &Epilogue) {
        assert_eq!(x.len(), m * k, "x shape mismatch");
        assert_eq!(w.len(), k * n, "w shape mismatch");
        assert_eq!(out.len(), m * n, "out shape mismatch");
        ep.validate(n);
        if m == 0 || n == 0 {
            return;
        }
        if k == 0 {
            // empty reduction: the GEMM term is zero, but the epilogue
            // still applies (bias, relu, quantize of the bias)
            out.fill(0.0);
            for row in out.chunks_mut(n) {
                ep.apply_row(row, 0);
            }
            return;
        }
        self.kernel.run(x, w, m, k, n, out, threads, ep);
    }

    /// Condition `w` (`k` x `n`, row-major, already quantized — the
    /// same contract as [`GemmPlan::run`]) into the kernel's panel
    /// layout and cache the panels on this plan.  Replaces any
    /// previously cached panels.
    pub fn prepack(&mut self, w: &[f32], k: usize, n: usize) {
        assert_eq!(w.len(), k * n, "w shape mismatch");
        self.packed = Some(self.kernel.prepack_weights(w, k, n));
    }

    /// The cached weight panels, if [`GemmPlan::prepack`] has run.
    pub fn packed_weights(&self) -> Option<&PackedWeights> {
        self.packed.as_ref()
    }

    /// Whether this plan carries prepacked weight panels.  After
    /// `Model::prepare` every layer plan does; the plan (and the
    /// `PreparedNet` owning it) is immutable from then on, which is
    /// what lets `coordinator::plan_cache` share one prepared network
    /// across engine workers behind an `Arc`.
    pub fn is_prepacked(&self) -> bool {
        self.packed.is_some()
    }

    /// Bytes resident in this plan's cached panels (0 when not
    /// prepacked) — surfaced through `coordinator::metrics`.
    pub fn panel_bytes(&self) -> usize {
        self.packed.as_ref().map_or(0, |p| p.resident_bytes())
    }

    /// `out = quant(x) @ w_prepacked`: the weight side comes entirely
    /// from the panels cached by [`GemmPlan::prepack`] (which fixed
    /// `k` and `n`) — zero weight-side conditioning or packing per
    /// call.  Panics if the plan was never prepacked.
    pub fn run_prepacked(&self, x: &[f32], m: usize, out: &mut [f32],
                         threads: usize) {
        self.run_prepacked_with(x, m, out, threads, &Epilogue::None);
    }

    /// [`GemmPlan::run_prepacked`] with a fused [`Epilogue`] (same
    /// contract as [`GemmPlan::run_with`]).
    pub fn run_prepacked_with(&self, x: &[f32], m: usize,
                              out: &mut [f32], threads: usize,
                              ep: &Epilogue) {
        let pw = self
            .packed
            .as_ref()
            .expect("GemmPlan::run_prepacked called before prepack");
        let (k, n) = (pw.k(), pw.n());
        assert_eq!(x.len(), m * k, "x shape mismatch");
        assert_eq!(out.len(), m * n, "out shape mismatch");
        ep.validate(n);
        if m == 0 || n == 0 {
            return;
        }
        if k == 0 {
            out.fill(0.0);
            for row in out.chunks_mut(n) {
                ep.apply_row(row, 0);
            }
            return;
        }
        self.kernel.run_prepacked(x, pw, m, out, threads, ep);
    }

    /// The layer entry point: run on the cached panels when the plan
    /// is prepacked (in which case `w` MUST be the matrix that was
    /// prepacked — debug builds verify its fingerprint), else pack `w`
    /// per call like [`GemmPlan::run`].
    pub fn run_cached(&self, x: &[f32], w: &[f32], m: usize, k: usize,
                      n: usize, out: &mut [f32], threads: usize) {
        self.run_cached_with(x, w, m, k, n, out, threads,
                             &Epilogue::None);
    }

    /// [`GemmPlan::run_cached`] with a fused [`Epilogue`] (same
    /// contract as [`GemmPlan::run_with`]) — the fused-layer entry
    /// point `layers::dense_with` / `conv::conv2d_with` drive.
    pub fn run_cached_with(&self, x: &[f32], w: &[f32], m: usize,
                           k: usize, n: usize, out: &mut [f32],
                           threads: usize, ep: &Epilogue) {
        match &self.packed {
            Some(pw) => {
                assert_eq!(
                    (pw.k(), pw.n()),
                    (k, n),
                    "prepacked panels are {}x{}, call is {k}x{n}",
                    pw.k(),
                    pw.n()
                );
                debug_assert_eq!(
                    weight_fingerprint(w),
                    pw.fingerprint(),
                    "run_cached: w is not the prepacked weight matrix"
                );
                self.run_prepacked_with(x, m, out, threads, ep);
            }
            None => self.run_with(x, w, m, k, n, out, threads, ep),
        }
    }
}

/// `out = quant(x) @ w` for any provider — one-shot wrapper around
/// [`GemmPlan`].
///
/// ```
/// use lop::approx::arith::ArithKind;
/// use lop::nn::gemm::gemm;
///
/// // FI(6, 8): x entries below are exactly representable, and an
/// // identity weight matrix is on every lattice, so the product is
/// // exact — out equals x.
/// let kind = ArithKind::parse("FI(6,8)").unwrap();
/// let x = [0.5f32, -1.0, 2.0, 0.25]; // 2 x 2, row-major
/// let w = [1.0f32, 0.0, 0.0, 1.0]; // identity, pre-quantized
/// let mut out = [0.0f32; 4];
/// gemm(&kind, &x, &w, 2, 2, 2, &mut out, 1);
/// assert_eq!(out, x);
/// ```
pub fn gemm(kind: &ArithKind, x: &[f32], w: &[f32], m: usize, k: usize,
            n: usize, out: &mut [f32], threads: usize) {
    GemmPlan::new(kind).run(x, w, m, k, n, out, threads);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn naive(kind: &ArithKind, x: &[f32], w: &[f32], m: usize, k: usize,
             n: usize) -> Vec<f32> {
        // semantic reference: scalar quantize + wide scalar mul + f64
        // accumulate (f32-rounded scalar quantization makes this a
        // tolerance check, not a bit check — the bit-level oracle is
        // reference::gemm_reference)
        let mut out = vec![0.0f32; m * n];
        for r in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for kk in 0..k {
                    let a = kind.quantize(x[r * k + kk]);
                    acc += kind.mul_wide(a, w[kk * n + j]);
                }
                out[r * n + j] = acc as f32;
            }
        }
        out
    }

    fn rand_mats(seed: u64, m: usize, k: usize, n: usize)
                 -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..m * k).map(|_| (rng.normal() * 2.0) as f32)
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        (x, w)
    }

    fn check_kind(kind: ArithKind, seed: u64) {
        let (m, k, n) = (13, 37, 11);
        let (x, mut w) = rand_mats(seed, m, k, n);
        // weights pre-quantized, as the layer contract requires
        for wv in &mut w {
            *wv = kind.quantize(*wv);
        }
        let mut out = vec![0.0; m * n];
        gemm(&kind, &x, &w, m, k, n, &mut out, 1);
        let want = naive(&kind, &x, &w, m, k, n);
        for (idx, (g, ww)) in out.iter().zip(&want).enumerate() {
            let tol = 1e-4 * ww.abs().max(1.0);
            assert!(
                (g - ww).abs() <= tol,
                "{}: out[{idx}] = {g}, want {ww}",
                kind.name()
            );
        }
    }

    #[test]
    fn f32_matches_naive() {
        check_kind(ArithKind::Float32, 1);
    }

    #[test]
    fn fixed_exact_matches_naive() {
        check_kind(ArithKind::parse("FI(6,8)").unwrap(), 2);
        check_kind(ArithKind::parse("FI(3,4)").unwrap(), 3);
    }

    #[test]
    fn fixed_drum_matches_naive() {
        check_kind(ArithKind::parse("H(6,8,6)").unwrap(), 4);
        check_kind(ArithKind::parse("H(8,8,14)").unwrap(), 5);
    }

    #[test]
    fn float_exact_matches_naive() {
        check_kind(ArithKind::parse("FL(4,9)").unwrap(), 6);
        check_kind(ArithKind::parse("FL(5,10)").unwrap(), 7);
    }

    #[test]
    fn float_cfpu_matches_naive() {
        check_kind(ArithKind::parse("I(5,10)").unwrap(), 8);
        check_kind(ArithKind::parse("I(4,9,2)").unwrap(), 9);
    }

    #[test]
    fn binary_matches_pm1_dot() {
        let (m, k, n) = (5, 130, 7); // k > 2 words incl. tail
        let (x, w) = rand_mats(10, m, k, n);
        let mut out = vec![0.0; m * n];
        gemm(&ArithKind::Binary, &x, &w, m, k, n, &mut out, 1);
        for r in 0..m {
            for j in 0..n {
                let mut dot = 0f32;
                for kk in 0..k {
                    let a = if x[r * k + kk] >= 0.0 { 1.0 } else { -1.0 };
                    let b = if w[kk * n + j] >= 0.0 { 1.0 } else { -1.0 };
                    dot += a * b;
                }
                assert_eq!(out[r * n + j], dot, "r={r} j={j}");
            }
        }
    }

    #[test]
    fn packed_matches_reference_smoke_per_isa() {
        // The full randomized sweep lives in tests/gemm_differential.rs;
        // this in-module smoke keeps the invariant visible to plain
        // `cargo test` on shapes that exercise every tail path (m, n
        // not divisible by any tile, k crossing a KC boundary), at
        // every ISA this machine can dispatch to.  Bitwise everywhere
        // except the AVX2+FMA f32 kernel, which is pinned by
        // fma_f32_bound (fused rounding is the point of that kernel).
        let (m, k, n) = (13, 300, 11);
        for tier in isa::detected() {
            for ks in ["float32", "FI(6,8)", "H(6,8,6)", "FL(4,9)",
                       "I(5,10)", "binxnor"] {
                let kind = ArithKind::parse(ks).unwrap();
                let plan = GemmPlan::with_isa(&kind, tier);
                let (x, mut w) = rand_mats(20, m, k, n);
                for wv in &mut w {
                    *wv = kind.quantize(*wv);
                }
                let mut got = vec![0.0; m * n];
                let mut want = vec![0.0; m * n];
                plan.run(&x, &w, m, k, n, &mut got, 1);
                reference::gemm_reference(&kind, &x, &w, m, k, n,
                                          &mut want, 1);
                let fma = kind == ArithKind::Float32
                    && plan.isa() != Isa::Scalar;
                let bound = if fma {
                    fma_f32_bound(&x, &w, m, k, n)
                } else {
                    Vec::new()
                };
                for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
                    if fma {
                        let err = (*g as f64 - *ww as f64).abs();
                        assert!(err <= bound[i],
                                "{ks}@{tier}: out[{i}] = {g} vs \
                                 reference {ww} (err {err:e})");
                    } else {
                        assert_eq!(g.to_bits(), ww.to_bits(),
                                   "{ks}@{tier}: out[{i}] = {g} vs \
                                    reference {ww}");
                    }
                }
            }
        }
    }

    #[test]
    fn multithreaded_matches_single() {
        // bit-identical across thread counts holds per kernel — the
        // same microkernel folds each output element in the same k
        // order regardless of which thread owns the row
        for kind in [
            ArithKind::Float32,
            ArithKind::parse("FI(6,8)").unwrap(),
            ArithKind::parse("H(6,8,12)").unwrap(),
            ArithKind::parse("FL(4,9)").unwrap(),
        ] {
            let (m, k, n) = (64, 100, 96); // big enough to engage threads
            let (x, mut w) = rand_mats(11, m, k, n);
            for wv in &mut w {
                *wv = kind.quantize(*wv);
            }
            let mut a = vec![0.0; m * n];
            let mut b = vec![0.0; m * n];
            gemm(&kind, &x, &w, m, k, n, &mut a, 1);
            gemm(&kind, &x, &w, m, k, n, &mut b, 4);
            assert_eq!(a, b, "{}", kind.name());
        }
    }

    #[test]
    fn plan_reuse_is_stable() {
        let kind = ArithKind::parse("FI(6,8)").unwrap();
        let plan = GemmPlan::new(&kind);
        assert_eq!(plan.kind(), &kind);
        let (m, k, n) = (9, 17, 5);
        let (x, mut w) = rand_mats(12, m, k, n);
        for wv in &mut w {
            *wv = kind.quantize(*wv);
        }
        let mut a = vec![0.0; m * n];
        let mut b = vec![0.0; m * n];
        plan.run(&x, &w, m, k, n, &mut a, 1);
        plan.run(&x, &w, m, k, n, &mut b, 1);
        assert_eq!(a, b);
    }

    #[test]
    fn kernel_names_per_kind() {
        let kinds = ["float32", "FI(6,8)", "H(6,8,12)", "FL(4,9)",
                     "I(5,10)", "binxnor"];
        // scalar names are the unsuffixed base literals
        for (ks, name) in kinds.iter().zip([
            "packed-f32", "packed-fi", "packed-drum", "packed-fl",
            "packed-cfpu", "packed-binxnor",
        ]) {
            let kind = ArithKind::parse(ks).unwrap();
            assert_eq!(kernel_name_isa(&kind, Isa::Scalar), name, "{ks}");
            assert_eq!(GemmPlan::with_isa(&kind, Isa::Scalar)
                           .kernel_name(),
                       name, "{ks}");
        }
        // the avx2 name table: SIMD paths suffixed, FL/CFPU unchanged
        for (ks, name) in kinds.iter().zip([
            "packed-f32+avx2", "packed-fi+avx2", "packed-drum+avx2",
            "packed-fl", "packed-cfpu", "packed-binxnor+popcnt",
        ]) {
            let kind = ArithKind::parse(ks).unwrap();
            assert_eq!(kernel_name_isa(&kind, Isa::Avx2), name, "{ks}");
        }
        // at every detected tier, the constructed kernel agrees with
        // the allocation-free name table, and the active-ISA shortcuts
        // agree with each other
        for tier in isa::detected() {
            for ks in kinds {
                let kind = ArithKind::parse(ks).unwrap();
                let kern = select_kernel_isa(&kind, tier);
                assert_eq!(kern.name(), kernel_name_isa(&kind, tier),
                           "{ks}@{tier}");
                assert!(kern.mr() >= 1 && kern.nr() >= 1);
            }
        }
        for ks in kinds {
            let kind = ArithKind::parse(ks).unwrap();
            assert_eq!(GemmPlan::new(&kind).kernel_name(),
                       kernel_name(&kind), "{ks}");
        }
    }

    #[test]
    fn fma_f32_bound_shape_and_scaling() {
        // bound is strictly positive (absolute floor) and scales with
        // operand magnitude and depth
        let b0 = fma_f32_bound(&[0.0, 0.0], &[0.0, 0.0], 1, 2, 1);
        assert_eq!(b0.len(), 1);
        assert!(b0[0] > 0.0);
        let small = fma_f32_bound(&[1.0, 1.0], &[1.0, 1.0], 1, 2, 1)[0];
        let big = fma_f32_bound(&[8.0, 8.0], &[8.0, 8.0], 1, 2, 1)[0];
        assert!(big > small);
        let deep =
            fma_f32_bound(&[1.0; 64], &[1.0; 64], 1, 64, 1)[0];
        assert!(deep > small);
        // the bound is tiny relative to the values it guards
        assert!(small < 1e-4);
    }

    #[test]
    fn zero_sized_edges() {
        let kind = ArithKind::Float32;
        let mut out = vec![0.0; 0];
        gemm(&kind, &[], &[], 0, 0, 0, &mut out, 1);
        let mut out1 = vec![0.0; 1];
        gemm(&kind, &[2.0], &[3.0], 1, 1, 1, &mut out1, 1);
        assert_eq!(out1[0], 6.0);
        // k = 0 with nonzero m, n zeroes the output
        let mut out2 = vec![7.0f32; 6];
        gemm(&kind, &[], &[], 2, 0, 3, &mut out2, 1);
        assert!(out2.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn prepacked_matches_run_smoke() {
        // The full randomized sweep lives in
        // tests/prepack_differential.rs; this smoke keeps the cached
        // path visible to plain `cargo test` on a tail-heavy shape.
        // Bitwise at every ISA: run and run_prepacked share the same
        // kernel and packing, FMA or not.
        let (m, k, n) = (13, 300, 11);
        for tier in isa::detected() {
            for ks in ["float32", "FI(6,8)", "H(6,8,6)", "FL(4,9)",
                       "I(5,10)", "binxnor"] {
                let kind = ArithKind::parse(ks).unwrap();
                let (x, mut w) = rand_mats(30, m, k, n);
                for wv in &mut w {
                    *wv = kind.quantize(*wv);
                }
                let mut plan = GemmPlan::with_isa(&kind, tier);
                plan.prepack(&w, k, n);
                let mut got = vec![0.0; m * n];
                plan.run_prepacked(&x, m, &mut got, 1);
                let mut want = vec![0.0; m * n];
                plan.run(&x, &w, m, k, n, &mut want, 1);
                for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), ww.to_bits(),
                               "{ks}@{tier}: out[{i}] = {g} vs \
                                per-call {ww}");
                }
                // run_cached hits the same panels
                let mut cached = vec![0.0; m * n];
                plan.run_cached(&x, &w, m, k, n, &mut cached, 1);
                assert_eq!(cached, want, "{ks}@{tier}");
            }
        }
    }

    #[test]
    fn prepacked_zero_sized_edges() {
        let kind = ArithKind::Float32;
        // k = 0: panels are empty, output zeroed
        let mut plan = GemmPlan::new(&kind);
        plan.prepack(&[], 0, 3);
        let mut out = vec![7.0f32; 6];
        plan.run_prepacked(&[], 2, &mut out, 1);
        assert!(out.iter().all(|&v| v == 0.0));
        // m = 0: no output
        let mut plan1 = GemmPlan::new(&kind);
        plan1.prepack(&[1.0, 2.0, 3.0], 1, 3);
        let mut empty: [f32; 0] = [];
        plan1.run_prepacked(&[], 0, &mut empty, 1);
        // n = 1 single column
        let mut plan2 = GemmPlan::new(&kind);
        plan2.prepack(&[2.0, 4.0], 2, 1);
        let mut out1 = [0.0f32; 1];
        plan2.run_prepacked(&[1.0, 0.5], 1, &mut out1, 1);
        assert_eq!(out1[0], 4.0);
    }

    #[test]
    fn prepack_replaces_panels() {
        let kind = ArithKind::Float32;
        let mut plan = GemmPlan::new(&kind);
        assert!(!plan.is_prepacked());
        plan.prepack(&[1.0], 1, 1);
        assert!(plan.is_prepacked());
        let fp0 = plan.packed_weights().unwrap().fingerprint();
        plan.prepack(&[2.0], 1, 1);
        let fp1 = plan.packed_weights().unwrap().fingerprint();
        assert_ne!(fp0, fp1);
        let mut out = [0.0f32; 1];
        plan.run_prepacked(&[3.0], 1, &mut out, 1);
        assert_eq!(out[0], 6.0);
        assert!(plan.panel_bytes() > 0);
    }

    #[test]
    #[should_panic(expected = "before prepack")]
    fn run_prepacked_requires_prepack() {
        let plan = GemmPlan::new(&ArithKind::Float32);
        let mut out = [0.0f32; 1];
        plan.run_prepacked(&[1.0], 1, &mut out, 1);
    }
}
