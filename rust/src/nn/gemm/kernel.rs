//! The object-safe [`Kernel`] trait, the Goto-style blocked driver and
//! the MR x NR register-tile microkernel.
//!
//! Loop structure (per thread, over its row chunk; `mcb`/`ncb` are the
//! per-kernel block sizes from [`eff_blocks`] — the largest multiples
//! of the kernel's MR/NR fitting the MC/NC cache targets, so the
//! driver never assumes one tile shape):
//!
//! ```text
//! for ic in mcb row blocks         // L2: A block  (mcb x KC)
//!   for jc in ncb column blocks    // L2/L3: wide accumulator tile
//!     acc[mcb x ncb] = 0           //   (f64/i64 — stays wide across
//!     for pc in KC depth blocks    //    *all* depth blocks)
//!       for ir in MR panels        // registers
//!         for jr in NR panels
//!           microkernel: acc += A-panel x B-panel over kc
//!     out[ic+.., jc+..] = finish(acc)   // one narrowing, at the end
//! ```
//!
//! This deviates from the textbook Goto ordering (`jc -> pc -> ic`) in
//! one deliberate way: the depth loop `pc` is *innermost* of the cache
//! loops so the wide accumulator tile persists across the whole k
//! reduction.  That is what makes the tiled path bit-identical to the
//! `reference` kernels (each output element folds its products in
//! strictly increasing k order into one wide accumulator, narrowed
//! once) — a partial-sum spill to f32 between depth blocks would
//! change roundings.  Operands are packed once up front
//! (`pack_a_block` / `pack_b_block`), so no packing work is repeated
//! inside the block loops.
//!
//! The innermost step is a function pointer ([`MicroFn`] /
//! [`BinaryDriveFn`]) selected once at kernel construction by
//! `super::isa` — the portable register-tile [`micro`] for
//! [`Isa::Scalar`], a `target_feature`-gated SIMD kernel from
//! `super::simd` for wider tiers.  Each kernel also advertises its own
//! MR/NR ([`Kernel::mr`]/[`Kernel::nr`]), which the pack routines and
//! this driver honor — and which travels with every prepacked panel
//! buffer so panels can never be consumed at a different geometry than
//! they were packed for.
//!
//! Threading splits rows into per-thread chunks aligned to MR (panels
//! never straddle threads); each output element is still reduced by
//! exactly one thread in the same order, so results are bit-identical
//! across thread counts.

use super::isa::Isa;
use super::micro::MicroArith;
use super::pack::{pack_a_bits, pack_a_block, pack_b_bits, pack_b_block};
use crate::approx::arith::ArithKind;
use crate::telemetry::{Span, Stage};
use std::any::Any;

/// Row-block target: the A sub-block (~MC x KC) an inner sweep works
/// on.  Kernels round down to their MR ([`eff_blocks`]).
pub const MC: usize = 64;
/// Depth-block size: panel slices streamed through the microkernel.
pub const KC: usize = 256;
/// Column-block target: bounds the wide accumulator tile (~MC x NC
/// wide elements, 128 KiB at f64/i64 — L2-resident on the target
/// cores).  Kernels round down to their NR ([`eff_blocks`]).
pub const NC: usize = 256;

/// The effective (row, column) block sizes for a kernel with the given
/// microtile: the largest multiples of `mr`/`nr` not exceeding
/// [`MC`]/[`NC`], clamped up to one whole tile when the tile itself is
/// bigger than the cache target.  The driver steps its cache loops by
/// these, so any MR x NR — 8x8, 6x16, a deliberately odd 5x7 mock —
/// gets whole panels per block with no hardcoded remainder
/// assumptions.
pub fn eff_blocks(mr: usize, nr: usize) -> (usize, usize) {
    ((MC / mr).max(1) * mr, (NC / nr).max(1) * nr)
}

/// Outputs below this threshold stay single-threaded (same heuristic
/// as the pre-tiled kernels: thread spawn costs more than the GEMM).
const PAR_MIN_OUT: usize = 16 * 1024;

/// Threads used by the row-parallel drivers (0 = all available cores).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested thread count against the problem size.
fn effective_threads(threads: usize, m: usize, n: usize) -> usize {
    let t = if threads == 0 { default_threads() } else { threads };
    if m * n < PAR_MIN_OUT {
        1
    } else {
        t.min(m).max(1)
    }
}

/// FNV-1a over the raw f32 bit patterns — the cheap fingerprint
/// [`PackedWeights`] carries so debug builds can verify that the `w`
/// a caller hands to the cached path is the matrix the panels were
/// conditioned from.
pub fn weight_fingerprint(w: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in w {
        h = (h ^ v.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Post-GEMM work fused into the blocked driver: applied to each
/// finished output row segment immediately after its k reduction
/// completes — while the segment is still cache-resident — instead of
/// as separate full passes over the output tensor afterwards.
///
/// The bias reference is borrowed (one bias vector per layer, length
/// `n`); [`Epilogue::BiasReluQuant`] additionally snaps the activation
/// onto the *consumer* layer's representation lattice, so the next
/// layer's pack step receives pre-conditioned data.
///
/// Bit-identity contract: per element, [`Epilogue::apply_row`]
/// performs exactly the operations of the separate passes
/// (`nn::vecmath::add_bias_in_place`, `nn::vecmath::relu_in_place`,
/// `ArithKind::quantize`) in the same order, so a fused run equals
/// separate-passes-over-the-same-GEMM-output bit for bit — for every
/// provider, FMA or not (pinned by `tests/epilogue_differential.rs`).
pub enum Epilogue<'a> {
    /// Plain GEMM output, no post-work.  `run` with this epilogue is
    /// byte-for-byte the pre-epilogue behavior.
    None,
    /// `out[r][j] += bias[j]`.
    Bias { bias: &'a [f32] },
    /// `out[r][j] = relu(out[r][j] + bias[j])`.
    BiasRelu { bias: &'a [f32] },
    /// `out[r][j] = quant(relu(out[r][j] + bias[j]))` — requantized in
    /// the consumer layer's representation.
    BiasReluQuant { bias: &'a [f32], quant: ArithKind },
}

impl Epilogue<'_> {
    /// Whether this is [`Epilogue::None`] (no post-GEMM work).
    pub fn is_none(&self) -> bool {
        matches!(self, Epilogue::None)
    }

    /// The bias vector, when this epilogue carries one.
    pub fn bias(&self) -> Option<&[f32]> {
        match self {
            Epilogue::None => None,
            Epilogue::Bias { bias }
            | Epilogue::BiasRelu { bias }
            | Epilogue::BiasReluQuant { bias, .. } => Some(bias),
        }
    }

    /// Assert the bias vector covers all `n` output columns
    /// (`GemmPlan` calls this once per entry, before any tile work).
    pub fn validate(&self, n: usize) {
        if let Some(b) = self.bias() {
            assert_eq!(
                b.len(), n,
                "epilogue bias has {} entries for {n} output columns",
                b.len()
            );
        }
    }

    /// Apply this epilogue to one finished output row segment whose
    /// first element is output column `col0`.
    ///
    /// The relu is the *branch* form (`if v < 0.0 { 0.0 }`), not
    /// `max`: the branch keeps `-0.0` (as the standalone relu pass
    /// always did) where `max(-0.0, 0.0)` would return `+0.0` — the
    /// SIMD fast path in `super::simd` replicates the branch semantics
    /// with a compare + andnot for the same reason.
    #[inline]
    pub fn apply_row(&self, row: &mut [f32], col0: usize) {
        match self {
            Epilogue::None => {}
            Epilogue::Bias { bias } => {
                for (v, b) in row.iter_mut().zip(&bias[col0..]) {
                    *v += *b;
                }
            }
            Epilogue::BiasRelu { bias } => {
                for (v, b) in row.iter_mut().zip(&bias[col0..]) {
                    *v += *b;
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            Epilogue::BiasReluQuant { bias, quant } => {
                for (v, b) in row.iter_mut().zip(&bias[col0..]) {
                    *v += *b;
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                    *v = quant.quantize(*v);
                }
            }
        }
    }
}

/// The signature of an epilogue row application: `(epilogue, row
/// segment, first output column)`.  Like [`MicroFn`], a
/// `BlockedKernel` binds one of these at construction — the portable
/// [`epilogue_scalar`] by default, the AVX2 fast path from
/// `super::simd` for the SIMD tiers.
pub type EpilogueFn = fn(&Epilogue, &mut [f32], usize);

/// The portable [`EpilogueFn`]: scalar [`Epilogue::apply_row`].
pub fn epilogue_scalar(ep: &Epilogue, row: &mut [f32], col0: usize) {
    ep.apply_row(row, col0);
}

/// The signature of a blocked microkernel step: `(arith, A panel
/// slice, B panel slice, kc, accumulator tile at stride)`.  The
/// scalar [`micro`] and the `super::simd` SIMD kernels all match it,
/// so a `BlockedKernel` binds its inner loop once at construction.
pub type MicroFn<A> = fn(&A, &[<A as MicroArith>::Elem],
                         &[<A as MicroArith>::Elem], usize,
                         &mut [<A as MicroArith>::Acc], usize);

/// The signature of a binary word-panel drive: `(A word panels,
/// B word panels, row0, output chunk, words, tail_mask, k, n,
/// epilogue)`.  The binary drive applies its epilogue through the
/// scalar [`Epilogue::apply_row`] at every tier — the ±1 dot output is
/// one f32 per tile cell, not a SIMD register tile.
pub type BinaryDriveFn = fn(&[u64], &[u64], usize, &mut [f32], usize,
                            u64, usize, usize, &Epilogue);

/// Prepacked, conditioned weight-side panels for one kernel — the
/// output of [`Kernel::prepack_weights`], owned by `GemmPlan` (one per
/// prepared layer) and consumed by [`Kernel::run_prepacked`].
///
/// The panel buffer is opaque (`dyn Any`, `Send + Sync`): conditioned
/// element panels for the blocked kernels (`Vec<Elem>` in the
/// `pack_b_block` layout), sign-bit word panels (`Vec<u64>`) for the
/// binary kernel.  The identity triple (kernel name — which carries
/// the ISA suffix for SIMD kernels — provider `cfg_tag`, and NR panel
/// geometry) travels with the buffer; `run_prepacked` panics rather
/// than consume panels conditioned by a different kernel, a
/// differently-parameterized provider, or at a different panel
/// geometry — so two `prepare` calls with different `ArithKind`s, and
/// panels packed under a different forced ISA, can never be silently
/// consumed.
pub struct PackedWeights {
    panels: Box<dyn Any + Send + Sync>,
    kernel: &'static str,
    cfg_tag: u64,
    /// NR the panels were laid out at — panel geometry is part of the
    /// identity, so a kernel with a different tile width refuses them.
    panel_nr: usize,
    k: usize,
    n: usize,
    bytes: usize,
    w_fnv: u64,
}

impl PackedWeights {
    /// Name of the kernel that conditioned these panels (includes the
    /// ISA suffix for SIMD kernels, e.g. `packed-f32+avx2`).
    pub fn kernel_name(&self) -> &'static str {
        self.kernel
    }

    /// The NR panel width these panels were laid out at.
    pub fn panel_nr(&self) -> usize {
        self.panel_nr
    }

    /// Depth (weight rows) the panels were packed for.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns the panels were packed for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Resident panel-buffer size in bytes (conditioned elements only;
    /// excludes this header).
    pub fn resident_bytes(&self) -> usize {
        self.bytes
    }

    /// [`weight_fingerprint`] of the source weight matrix.
    pub fn fingerprint(&self) -> u64 {
        self.w_fnv
    }
}

/// Guarded panel access: identity-check `pw` against the consuming
/// kernel (name, provider tag, panel geometry), then downcast to its
/// concrete panel buffer.  All checks panic — handing a kernel foreign
/// panels is a caller bug that must not produce silently
/// mis-multiplied results.
fn panels_of<'p, T: 'static>(pw: &'p PackedWeights, kernel: &'static str,
                             cfg_tag: u64, nr: usize) -> &'p T {
    assert_eq!(
        pw.kernel, kernel,
        "weight panels were packed by kernel `{}`, not `{}`",
        pw.kernel, kernel
    );
    assert_eq!(
        pw.cfg_tag, cfg_tag,
        "weight panels were packed under a different `{kernel}` \
         configuration"
    );
    assert_eq!(
        pw.panel_nr, nr,
        "weight panels were packed at panel geometry NR={}, but kernel \
         `{kernel}` needs NR={nr}",
        pw.panel_nr
    );
    pw.panels
        .downcast_ref::<T>()
        .expect("panel buffer type does not match the kernel")
}

/// One packed, tiled GEMM engine for a fixed `ArithKind`.  Object-safe:
/// `GemmPlan` holds these as `Box<dyn Kernel>`; the monomorphized
/// implementations behind it are `BlockedKernel<A, MR, NR>` (one per
/// provider, per ISA tile shape) and the bit-packed `BinaryKernel`.
pub trait Kernel: Send + Sync {
    /// Kernel name for plans/logs, e.g. `packed-fi` — SIMD variants
    /// carry an ISA suffix (`packed-fi+avx2`).
    fn name(&self) -> &'static str;

    /// The ISA tier this kernel's inner loop was selected for.
    fn isa(&self) -> Isa;

    /// Microkernel tile height.
    fn mr(&self) -> usize;

    /// Microkernel tile width.
    fn nr(&self) -> usize;

    /// `out = ep(cond(x) @ cond(w))` with `ep` applied per output tile
    /// while it is cache-resident (pass [`Epilogue::None`] for a plain
    /// GEMM).  The caller (`GemmPlan::run_with`) checks the shape
    /// invariants (including the epilogue bias length) and
    /// short-circuits the m/n/k = 0 edges, so implementations may
    /// assume `m, k, n >= 1` and exact slice lengths.
    fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
           out: &mut [f32], threads: usize, ep: &Epilogue);

    /// Condition `w` (`k` x `n`, row-major) into this kernel's panel
    /// layout once, for arbitrarily many [`Kernel::run_prepacked`]
    /// calls.  The returned panels are exactly what [`Kernel::run`]
    /// builds internally per call, so the two entry points are
    /// bit-identical by construction.
    fn prepack_weights(&self, w: &[f32], k: usize, n: usize)
                       -> PackedWeights;

    /// `out = ep(cond(x) @ panels)` with the weight side already
    /// conditioned by [`Kernel::prepack_weights`] (which fixes `k` and
    /// `n`).  Same caller contract as [`Kernel::run`]: shapes checked
    /// and m/k/n = 0 short-circuited by `GemmPlan`, so implementations
    /// may assume `m >= 1` and `pw.k(), pw.n() >= 1`.  Panics if `pw`
    /// was packed by a different kernel, provider configuration, or
    /// panel geometry.
    fn run_prepacked(&self, x: &[f32], pw: &PackedWeights, m: usize,
                     out: &mut [f32], threads: usize, ep: &Epilogue);
}

/// The generic blocked engine: one monomorphization per provider and
/// tile shape, with the inner microkernel bound as a function pointer
/// at construction (scalar or a `super::simd` SIMD kernel).
pub struct BlockedKernel<A: MicroArith, const MR: usize, const NR: usize> {
    arith: A,
    name: &'static str,
    isa: Isa,
    micro_fn: MicroFn<A>,
    /// Epilogue row application, bound like `micro_fn`: scalar
    /// [`Epilogue::apply_row`] for portable kernels, the AVX2 fast
    /// path for the SIMD tiers.
    ep_fn: EpilogueFn,
}

impl<A: MicroArith, const MR: usize, const NR: usize>
    BlockedKernel<A, MR, NR>
{
    /// The portable scalar kernel for this provider at this tile
    /// shape.
    pub fn new(arith: A) -> Self {
        let name = arith.name();
        BlockedKernel { arith, name, isa: Isa::Scalar,
                        micro_fn: micro::<A, MR, NR>,
                        ep_fn: epilogue_scalar }
    }

    /// A kernel with explicit (typically SIMD) microkernel and
    /// epilogue implementations bound.
    /// `super::isa::select_kernel_isa` only calls this after verifying
    /// the target ISA is supported on this machine.
    pub(crate) fn with_micro(arith: A, name: &'static str, isa: Isa,
                             micro_fn: MicroFn<A>, ep_fn: EpilogueFn)
                             -> Self {
        BlockedKernel { arith, name, isa, micro_fn, ep_fn }
    }

    /// The engine proper, over already-packed B panels: pack A, split
    /// rows across threads, drive the blocked sweep.  Shared verbatim
    /// by `run` (packs B per call) and `run_prepacked` (cached panels),
    /// which is what makes the two entry points bit-identical.
    fn run_packed_b(&self, x: &[f32], bp: &[A::Elem], m: usize, k: usize,
                    n: usize, out: &mut [f32], threads: usize,
                    ep: &Epilogue) {
        let ap = {
            let _span = Span::enter(Stage::GemmPack);
            pack_a_block::<A, MR>(&self.arith, x, m, k)
        };
        let threads = effective_threads(threads, m, n);
        if threads <= 1 {
            drive::<A, MR, NR>(&self.arith, self.micro_fn, &ap, bp, 0,
                               out, k, n, ep, self.ep_fn);
            return;
        }
        // Chunk rows per thread, aligned to MR so no A panel straddles
        // two threads.  Each chunk spans the full column width, so the
        // per-column epilogue bias indexing is thread-independent.
        let rows_per = m.div_ceil(threads).next_multiple_of(MR);
        std::thread::scope(|s| {
            for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let (ap, arith) = (&ap, &self.arith);
                let micro_fn = self.micro_fn;
                let ep_fn = self.ep_fn;
                s.spawn(move || {
                    drive::<A, MR, NR>(arith, micro_fn, ap, bp,
                                       t * rows_per, chunk, k, n, ep,
                                       ep_fn);
                });
            }
        });
    }
}

impl<A: MicroArith, const MR: usize, const NR: usize> Kernel
    for BlockedKernel<A, MR, NR>
{
    fn name(&self) -> &'static str {
        self.name
    }

    fn isa(&self) -> Isa {
        self.isa
    }

    fn mr(&self) -> usize {
        MR
    }

    fn nr(&self) -> usize {
        NR
    }

    fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
           out: &mut [f32], threads: usize, ep: &Epilogue) {
        let bp = {
            let _span = Span::enter(Stage::GemmPack);
            pack_b_block::<A, NR>(&self.arith, w, k, n)
        };
        self.run_packed_b(x, &bp, m, k, n, out, threads, ep);
    }

    fn prepack_weights(&self, w: &[f32], k: usize, n: usize)
                       -> PackedWeights {
        assert_eq!(w.len(), k * n, "w shape mismatch");
        let _span = Span::enter(Stage::GemmPack);
        let bp = pack_b_block::<A, NR>(&self.arith, w, k, n);
        let bytes = bp.len() * std::mem::size_of::<A::Elem>();
        PackedWeights {
            panels: Box::new(bp),
            kernel: self.name,
            cfg_tag: self.arith.cfg_tag(),
            panel_nr: NR,
            k,
            n,
            bytes,
            w_fnv: weight_fingerprint(w),
        }
    }

    fn run_prepacked(&self, x: &[f32], pw: &PackedWeights, m: usize,
                     out: &mut [f32], threads: usize, ep: &Epilogue) {
        let bp = panels_of::<Vec<A::Elem>>(pw, self.name,
                                           self.arith.cfg_tag(), NR);
        self.run_packed_b(x, bp, m, pw.k, pw.n, out, threads, ep);
    }
}

/// Blocked sweep over one thread's row chunk (`chunk` = rows
/// `[row0, row0 + chunk.len()/n)` of the output).  `row0` is a
/// multiple of MR.
///
/// The epilogue hook lives here: because the depth loop `pc` is
/// innermost of the cache loops, each `(ic, jc)` output row segment is
/// stored exactly once — with its k reduction complete — in the
/// `finish` loop at the bottom.  `ep_fn` runs right after that store,
/// while the segment is still cache-resident, with `jc` as the first
/// output column (so the bias is indexed globally and row chunking
/// across threads cannot skew it).
fn drive<A: MicroArith, const MR: usize, const NR: usize>(
    arith: &A, micro_fn: MicroFn<A>, ap: &[A::Elem], bp: &[A::Elem],
    row0: usize, chunk: &mut [f32], k: usize, n: usize, ep: &Epilogue,
    ep_fn: EpilogueFn,
) {
    let (mcb, ncb) = eff_blocks(MR, NR);
    let mrows = chunk.len() / n;
    // Wide accumulator tile, reused across blocks (zeroed per tile).
    let mut acc: Vec<A::Acc> = vec![arith.zero_acc(); mcb * ncb];
    for ic in (0..mrows).step_by(mcb) {
        let mc = mcb.min(mrows - ic);
        let mc_pad = mc.next_multiple_of(MR);
        for jc in (0..n).step_by(ncb) {
            let nc = ncb.min(n - jc);
            let nc_pad = nc.next_multiple_of(NR);
            for a in acc[..mc_pad * nc_pad].iter_mut() {
                *a = arith.zero_acc();
            }
            {
                // One GemmKernel span per (ic, jc) block: the whole
                // k reduction for this output block.  Inert (one
                // relaxed load, no clock read) unless LOP_TRACE is
                // on.
                let _span = Span::enter(Stage::GemmKernel);
                for pc in (0..k).step_by(KC) {
                    let kc = KC.min(k - pc);
                    for ir in (0..mc_pad).step_by(MR) {
                        // global A panel (row0/ic/ir all MR-aligned)
                        let p = (row0 + ic + ir) / MR;
                        let abase = p * MR * k + pc * MR;
                        let apan = &ap[abase..abase + kc * MR];
                        for jr in (0..nc_pad).step_by(NR) {
                            let q = (jc + jr) / NR;
                            let bbase = q * NR * k + pc * NR;
                            let bpan = &bp[bbase..bbase + kc * NR];
                            micro_fn(
                                arith, apan, bpan, kc,
                                &mut acc[ir * nc_pad + jr..],
                                nc_pad,
                            );
                        }
                    }
                }
            }
            {
                // Narrowing store + fused epilogue for the block.
                let _span = Span::enter(Stage::GemmEpilogue);
                for r in 0..mc {
                    let o0 = (ic + r) * n + jc;
                    let orow = &mut chunk[o0..o0 + nc];
                    let arow = &acc[r * nc_pad..r * nc_pad + nc];
                    for (o, a) in orow.iter_mut().zip(arow) {
                        *o = arith.finish(*a);
                    }
                    ep_fn(ep, orow, jc);
                }
            }
        }
    }
}

/// The portable MR x NR register-tile microkernel: load the accumulator
/// tile, stream `kc` packed depth steps through it, store it back.  Per
/// output element this appends products in increasing k order — the
/// bit-exactness invariant (the `super::simd` kernels preserve the same
/// per-element order; their lanes run along NR).
#[inline]
fn micro<A: MicroArith, const MR: usize, const NR: usize>(
    arith: &A, apan: &[A::Elem], bpan: &[A::Elem], kc: usize,
    acc: &mut [A::Acc], stride: usize,
) {
    let mut t = [[arith.zero_acc(); NR]; MR];
    for (i, trow) in t.iter_mut().enumerate() {
        trow.copy_from_slice(&acc[i * stride..i * stride + NR]);
    }
    for p in 0..kc {
        let av = &apan[p * MR..(p + 1) * MR];
        let bv = &bpan[p * NR..(p + 1) * NR];
        for (i, trow) in t.iter_mut().enumerate() {
            let a = av[i];
            for (j, tv) in trow.iter_mut().enumerate() {
                *tv = arith.mul_acc(a, bv[j], *tv);
            }
        }
    }
    for (i, trow) in t.iter().enumerate() {
        acc[i * stride..i * stride + NR].copy_from_slice(trow);
    }
}

// ---------------------------------------------------------------------------
// binary XNOR kernel (paper §4.5): the packing *is* the conditioning —
// 64 sign bits per word, so panels are built along k in words and the
// microkernel is popcount over word panels.
// ---------------------------------------------------------------------------

/// Provider fingerprint for the (parameterless) binary configuration.
const BINARY_CFG_TAG: u64 = 0x06;

/// Bit-packed XNOR/popcount kernel for `ArithKind::Binary`, generic
/// over its BMR x BNR word-panel tile: the scalar tier runs 4x4, the
/// AVX2 tier an 8x8 tile driven through a `popcnt`-enabled
/// instantiation of the same [`binary_drive_impl`] (bit-exact by
/// construction — only the emitted popcount instruction and tile
/// shape differ).
pub struct BinaryKernel<const BMR: usize, const BNR: usize> {
    name: &'static str,
    isa: Isa,
    drive_fn: BinaryDriveFn,
}

impl BinaryKernel<4, 4> {
    /// The portable scalar binary kernel (4x4 word tile).
    pub fn scalar() -> Self {
        BinaryKernel {
            name: "packed-binxnor",
            isa: Isa::Scalar,
            drive_fn: binary_drive_scalar::<4, 4>,
        }
    }
}

impl<const BMR: usize, const BNR: usize> BinaryKernel<BMR, BNR> {
    /// A binary kernel with an explicit drive (typically the
    /// `popcnt`-enabled one).  `super::isa::select_kernel_isa` only
    /// calls this after verifying the target ISA is supported.
    pub(crate) fn with_drive(name: &'static str, isa: Isa,
                             drive_fn: BinaryDriveFn) -> Self {
        BinaryKernel { name, isa, drive_fn }
    }

    /// The popcount engine over already-packed B word panels: pack A
    /// sign bits, split rows across threads, drive.  Shared by `run`
    /// and `run_prepacked` — the packing *is* the conditioning for this
    /// representation, so the cached panels carry the whole weight-side
    /// cost.
    fn run_packed_b(&self, x: &[f32], bp: &[u64], m: usize, k: usize,
                    n: usize, out: &mut [f32], threads: usize,
                    ep: &Epilogue) {
        let words = k.div_ceil(64);
        // A: BMR-row word panels (same middle-axis layout as
        // pack::pack_a_block, 64 depth steps per word).
        let ap = {
            let _span = Span::enter(Stage::GemmPack);
            pack_a_bits::<BMR>(x, m, k)
        };
        // bits >= k in the last word must not count as agreements
        let tail_bits = k % 64;
        let tail_mask =
            if tail_bits == 0 { u64::MAX } else { (1u64 << tail_bits) - 1 };

        let threads = effective_threads(threads, m, n);
        let rows_per = if threads <= 1 {
            m.next_multiple_of(BMR)
        } else {
            m.div_ceil(threads).next_multiple_of(BMR)
        };
        std::thread::scope(|s| {
            for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let ap = &ap;
                let drive_fn = self.drive_fn;
                let worker = move || {
                    // The word sweep applies the epilogue inline per
                    // finished tile row, so on the binary path the
                    // epilogue time lands under gemm_kernel rather
                    // than gemm_epilogue.
                    let _span = Span::enter(Stage::GemmKernel);
                    drive_fn(ap, bp, t * rows_per, chunk, words,
                             tail_mask, k, n, ep);
                };
                if threads <= 1 {
                    worker();
                } else {
                    s.spawn(worker);
                }
            }
        });
    }
}

impl<const BMR: usize, const BNR: usize> Kernel
    for BinaryKernel<BMR, BNR>
{
    fn name(&self) -> &'static str {
        self.name
    }

    fn isa(&self) -> Isa {
        self.isa
    }

    fn mr(&self) -> usize {
        BMR
    }

    fn nr(&self) -> usize {
        BNR
    }

    fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
           out: &mut [f32], threads: usize, ep: &Epilogue) {
        let bp = {
            let _span = Span::enter(Stage::GemmPack);
            pack_b_bits::<BNR>(w, k, n)
        };
        self.run_packed_b(x, &bp, m, k, n, out, threads, ep);
    }

    fn prepack_weights(&self, w: &[f32], k: usize, n: usize)
                       -> PackedWeights {
        assert_eq!(w.len(), k * n, "w shape mismatch");
        let _span = Span::enter(Stage::GemmPack);
        let bp = pack_b_bits::<BNR>(w, k, n);
        let bytes = bp.len() * std::mem::size_of::<u64>();
        PackedWeights {
            panels: Box::new(bp),
            kernel: self.name,
            cfg_tag: BINARY_CFG_TAG,
            panel_nr: BNR,
            k,
            n,
            bytes,
            w_fnv: weight_fingerprint(w),
        }
    }

    fn run_prepacked(&self, x: &[f32], pw: &PackedWeights, m: usize,
                     out: &mut [f32], threads: usize, ep: &Epilogue) {
        let bp = panels_of::<Vec<u64>>(pw, self.name, BINARY_CFG_TAG,
                                       BNR);
        self.run_packed_b(x, bp, m, pw.k, pw.n, out, threads, ep);
    }
}

/// The binary word-panel sweep, generic over the BMR x BNR word tile.
/// `inline(always)` so `target_feature` wrappers (the `popcnt` drive
/// in `super::simd`) propagate their feature set into the `count_ones`
/// calls.
#[inline(always)]
pub(crate) fn binary_drive_impl<const BMR: usize, const BNR: usize>(
    ap: &[u64], bp: &[u64], row0: usize, chunk: &mut [f32],
    words: usize, tail_mask: u64, k: usize, n: usize, ep: &Epilogue,
) {
    let mrows = chunk.len() / n;
    for ir in (0..mrows).step_by(BMR) {
        let p = (row0 + ir) / BMR;
        let apan = &ap[p * BMR * words..(p + 1) * BMR * words];
        for jr in (0..n).step_by(BNR) {
            let q = jr / BNR;
            let bpan = &bp[q * BNR * words..(q + 1) * BNR * words];
            let mut agree = [[0u32; BNR]; BMR];
            for wd in 0..words {
                let msk = if wd == words - 1 { tail_mask } else { u64::MAX };
                let av = &apan[wd * BMR..(wd + 1) * BMR];
                let bv = &bpan[wd * BNR..(wd + 1) * BNR];
                for (i, arow) in agree.iter_mut().enumerate() {
                    let a = av[i];
                    for (j, c) in arow.iter_mut().enumerate() {
                        *c += (!(a ^ bv[j]) & msk).count_ones();
                    }
                }
            }
            // dot of ±1 vectors = agreements - disagreements; the
            // epilogue runs per finished tile row (the word sweep
            // completed the whole k reduction for this tile), scalar
            // at every tier — BNR f32 cells don't fill a vector.
            for i in 0..BMR.min(mrows - ir) {
                let jw = BNR.min(n - jr);
                let o0 = (ir + i) * n + jr;
                for (j, cell) in
                    chunk[o0..o0 + jw].iter_mut().enumerate()
                {
                    *cell = (2 * agree[i][j] as i64 - k as i64) as f32;
                }
                ep.apply_row(&mut chunk[o0..o0 + jw], jr);
            }
        }
    }
}

/// The portable (no `target_feature`) instantiation of
/// [`binary_drive_impl`], matching [`BinaryDriveFn`].
fn binary_drive_scalar<const BMR: usize, const BNR: usize>(
    ap: &[u64], bp: &[u64], row0: usize, chunk: &mut [f32],
    words: usize, tail_mask: u64, k: usize, n: usize, ep: &Epilogue,
) {
    binary_drive_impl::<BMR, BNR>(ap, bp, row0, chunk, words, tail_mask,
                                  k, n, ep)
}

#[cfg(test)]
mod tests {
    use super::super::micro::{F32Micro, FixedMicro};
    use super::super::reference::gemm_reference;
    use super::*;
    use crate::approx::arith::ArithKind;
    use crate::numeric::FixedPoint;
    use crate::util::prng::Rng;

    #[test]
    fn eff_blocks_covers_any_tile() {
        // the production tiles
        assert_eq!(eff_blocks(8, 8), (64, 256));
        assert_eq!(eff_blocks(4, 8), (64, 256));
        assert_eq!(eff_blocks(4, 4), (64, 256));
        assert_eq!(eff_blocks(6, 16), (60, 256)); // avx2 f32: MC rounds
        // odd tiles and tiles larger than the cache targets
        assert_eq!(eff_blocks(5, 7), (60, 252));
        assert_eq!(eff_blocks(100, 300), (100, 300));
        for (mr, nr) in [(1, 1), (3, 5), (6, 16), (7, 9), (64, 256),
                         (65, 257)] {
            let (mcb, ncb) = eff_blocks(mr, nr);
            assert!(mcb % mr == 0 && ncb % nr == 0, "({mr},{nr})");
            assert!(mcb >= mr && ncb >= nr, "({mr},{nr})");
        }
    }

    #[test]
    fn effective_threads_heuristics() {
        assert_eq!(effective_threads(4, 8, 8), 1); // tiny: stay serial
        assert_eq!(effective_threads(4, 200, 100), 4);
        assert_eq!(effective_threads(8, 2, 16 * 1024), 2); // capped by m
        assert!(effective_threads(0, 200, 100) >= 1);
    }

    #[test]
    fn fingerprint_is_order_and_value_sensitive() {
        assert_eq!(weight_fingerprint(&[1.0, 2.0]),
                   weight_fingerprint(&[1.0, 2.0]));
        assert_ne!(weight_fingerprint(&[1.0, 2.0]),
                   weight_fingerprint(&[2.0, 1.0]));
        assert_ne!(weight_fingerprint(&[1.0]),
                   weight_fingerprint(&[1.5]));
        // 0.0 and -0.0 are different bit patterns -> different panels
        // for sign-sensitive providers (binary)
        assert_ne!(weight_fingerprint(&[0.0]),
                   weight_fingerprint(&[-0.0]));
    }

    #[test]
    fn prepack_carries_identity_and_shape() {
        let kern = BlockedKernel::<_, 8, 8>::new(F32Micro);
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let pw = kern.prepack_weights(&w, 2, 3);
        assert_eq!(pw.kernel_name(), "packed-f32");
        assert_eq!((pw.k(), pw.n()), (2, 3));
        assert_eq!(pw.panel_nr(), 8);
        // one 8-wide panel of depth 2, f32 elements
        assert_eq!(pw.resident_bytes(), 8 * 2 * 4);
        assert_eq!(pw.fingerprint(), weight_fingerprint(&w));
        // binary panels report word-panel bytes
        let pb = BinaryKernel::scalar().prepack_weights(&w, 2, 3);
        assert_eq!(pb.kernel_name(), "packed-binxnor");
        assert_eq!(pb.panel_nr(), 4);
        assert_eq!(pb.resident_bytes(), 4 * 8); // one BNR=4 word panel
    }

    #[test]
    #[should_panic(expected = "packed by kernel")]
    fn foreign_panels_rejected_by_kernel_name() {
        let f32k = BlockedKernel::<_, 8, 8>::new(F32Micro);
        let pw = BinaryKernel::scalar().prepack_weights(&[1.0; 6], 2, 3);
        let mut out = [0.0f32; 3];
        f32k.run_prepacked(&[1.0, 1.0], &pw, 1, &mut out, 1,
                           &Epilogue::None);
    }

    #[test]
    #[should_panic(expected = "panel geometry")]
    fn same_name_panels_with_different_geometry_are_refused() {
        // identical provider (same name, same cfg_tag) at two tile
        // widths: without the geometry check the NR=4 kernel would
        // silently mis-index NR=8 panels
        let wide = BlockedKernel::<_, 8, 8>::new(F32Micro);
        let narrow = BlockedKernel::<_, 8, 4>::new(F32Micro);
        let pw = wide.prepack_weights(&[0.5f32; 12], 4, 3);
        let mut out = [0.0f32; 3];
        narrow.run_prepacked(&[1.0; 4], &pw, 1, &mut out, 1,
                             &Epilogue::None);
    }

    /// Regression for the former `MC % MR == 0` constructor assert:
    /// deliberately odd tiles (5x7, 3x5) whose effective blocks (60,
    /// 252) divide neither MC nor NC must still match the reference
    /// oracle bitwise on shapes with every kind of tail — m crossing
    /// mcb, n crossing ncb, k crossing KC, and sizes not divisible by
    /// any tile dimension.
    #[test]
    fn odd_tile_kernels_match_reference() {
        let shapes =
            [(61, 257, 253), (5, 7, 1), (13, 300, 11), (1, 1, 9)];
        let mut rng = Rng::new(73);
        let f32_kind = ArithKind::Float32;
        let fi_kind = ArithKind::parse("FI(6,8)").unwrap();
        let odd_f32 = BlockedKernel::<_, 5, 7>::new(F32Micro);
        let odd_fi = BlockedKernel::<_, 3, 5>::new(FixedMicro::new(
            FixedPoint::new(6, 8)));
        let kerns: [(&ArithKind, &dyn Kernel); 2] =
            [(&f32_kind, &odd_f32), (&fi_kind, &odd_fi)];
        for (kind, kern) in kerns {
            for &(m, k, n) in &shapes {
                let x: Vec<f32> = (0..m * k)
                    .map(|_| (rng.normal() * 2.0) as f32)
                    .collect();
                let w: Vec<f32> = (0..k * n)
                    .map(|_| kind.quantize(rng.normal() as f32))
                    .collect();
                let mut want = vec![f32::NAN; m * n];
                gemm_reference(kind, &x, &w, m, k, n, &mut want, 1);
                for threads in [1, 3] {
                    let mut got = vec![f32::NAN; m * n];
                    kern.run(&x, &w, m, k, n, &mut got, threads,
                             &Epilogue::None);
                    for (i, (g, ww)) in got.iter().zip(&want).enumerate()
                    {
                        assert_eq!(
                            g.to_bits(), ww.to_bits(),
                            "{} {}x{}x{} t={threads}: out[{i}] = {g} \
                             vs reference {ww}",
                            kern.name(), m, k, n
                        );
                    }
                    // prepacked path at the same odd geometry
                    let pw = kern.prepack_weights(&w, k, n);
                    if m > 0 && k > 0 && n > 0 {
                        let mut pre = vec![f32::NAN; m * n];
                        kern.run_prepacked(&x, &pw, m, &mut pre,
                                           threads, &Epilogue::None);
                        assert_eq!(pre, got, "{} prepacked diverged",
                                   kern.name());
                    }
                }
            }
        }
    }

    /// Same regression for the binary word-panel drive: an odd 3x5
    /// word tile must agree with the ±1 dot product on tail-heavy
    /// shapes (k mid-word, n/m not divisible by the tile).
    #[test]
    fn odd_tile_binary_kernel_matches_pm1_dot() {
        let kern = BinaryKernel::<3, 5>::with_drive(
            "packed-binxnor", Isa::Scalar, binary_drive_scalar::<3, 5>);
        let mut rng = Rng::new(74);
        for (m, k, n) in [(7, 130, 11), (1, 63, 1), (4, 64, 5)] {
            let x: Vec<f32> =
                (0..m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> =
                (0..k * n).map(|_| rng.normal() as f32).collect();
            let mut got = vec![f32::NAN; m * n];
            kern.run(&x, &w, m, k, n, &mut got, 1, &Epilogue::None);
            for r in 0..m {
                for j in 0..n {
                    let mut dot = 0f32;
                    for kk in 0..k {
                        let a =
                            if x[r * k + kk] >= 0.0 { 1.0 } else { -1.0 };
                        let b =
                            if w[kk * n + j] >= 0.0 { 1.0 } else { -1.0 };
                        dot += a * b;
                    }
                    assert_eq!(got[r * n + j], dot, "r={r} j={j}");
                }
            }
        }
    }

    #[test]
    fn epilogue_apply_row_semantics() {
        let bias = [10.0f32, -20.0, 0.5, 0.0];
        let mut row = [1.0f32, 1.0, 1.0, 1.0];
        Epilogue::Bias { bias: &bias }.apply_row(&mut row, 0);
        assert_eq!(row, [11.0, -19.0, 1.5, 1.0]);

        let mut row = [1.0f32, 1.0, 1.0, 1.0];
        Epilogue::BiasRelu { bias: &bias }.apply_row(&mut row, 0);
        assert_eq!(row, [11.0, 0.0, 1.5, 1.0]);

        let quant = ArithKind::parse("FI(2,2)").unwrap(); // step 0.25
        let mut row = [1.0f32, 1.0, 0.6, 1.0];
        Epilogue::BiasReluQuant { bias: &bias, quant }
            .apply_row(&mut row, 0);
        assert_eq!(row, [quant.quantize(11.0), 0.0, 1.0, 1.0]);

        // None leaves the row untouched
        let mut row = [f32::NAN, -3.0];
        Epilogue::None.apply_row(&mut row, 0);
        assert!(row[0].is_nan() && row[1] == -3.0);
    }

    #[test]
    fn epilogue_col0_offsets_into_bias() {
        // a segment starting at output column 2 must read bias[2..]
        let bias = [100.0f32, 200.0, 1.0, 2.0, 3.0];
        let mut seg = [10.0f32, 10.0, 10.0];
        Epilogue::Bias { bias: &bias }.apply_row(&mut seg, 2);
        assert_eq!(seg, [11.0, 12.0, 13.0]);
    }

    #[test]
    fn epilogue_relu_branch_keeps_negative_zero_and_nan() {
        // branch relu (not max): -0.0 stays -0.0, NaN stays NaN —
        // identical to the standalone relu pass it replaces
        let bias = [0.0f32; 3];
        let mut row = [-0.0f32, f32::NAN, -1.0];
        Epilogue::BiasRelu { bias: &bias }.apply_row(&mut row, 0);
        assert_eq!(row[0].to_bits(), (-0.0f32).to_bits());
        assert!(row[1].is_nan());
        assert_eq!(row[2], 0.0);
    }

    #[test]
    #[should_panic(expected = "epilogue bias")]
    fn epilogue_validate_rejects_short_bias() {
        Epilogue::Bias { bias: &[1.0, 2.0] }.validate(3);
    }

    /// Fused epilogue through the full blocked driver == plain GEMM +
    /// the same scalar passes, bit for bit — on an odd tile so the
    /// per-segment `col0` bookkeeping crosses block boundaries.
    #[test]
    fn fused_run_matches_separate_passes_on_odd_tile() {
        let kern = BlockedKernel::<_, 5, 7>::new(F32Micro);
        let (m, k, n) = (13, 30, 300); // n crosses ncb=252
        let mut rng = Rng::new(75);
        let x: Vec<f32> =
            (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..k * n).map(|_| rng.normal() as f32).collect();
        let bias: Vec<f32> =
            (0..n).map(|_| rng.normal() as f32).collect();
        let quant = ArithKind::parse("FI(4,6)").unwrap();

        let mut plain = vec![f32::NAN; m * n];
        kern.run(&x, &w, m, k, n, &mut plain, 1, &Epilogue::None);
        let mut want = plain.clone();
        for row in want.chunks_mut(n) {
            for (v, b) in row.iter_mut().zip(&bias) {
                *v += *b;
                if *v < 0.0 {
                    *v = 0.0;
                }
                *v = quant.quantize(*v);
            }
        }
        for threads in [1, 3] {
            let mut got = vec![f32::NAN; m * n];
            kern.run(&x, &w, m, k, n, &mut got, threads,
                     &Epilogue::BiasReluQuant { bias: &bias, quant });
            for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), ww.to_bits(),
                           "t={threads} out[{i}]: {g} vs {ww}");
            }
        }
    }

    /// Same fusion check for the binary word-panel drive.
    #[test]
    fn fused_binary_matches_separate_passes() {
        let kern = BinaryKernel::scalar();
        let (m, k, n) = (7, 130, 11);
        let mut rng = Rng::new(76);
        let x: Vec<f32> =
            (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..k * n).map(|_| rng.normal() as f32).collect();
        let bias: Vec<f32> =
            (0..n).map(|i| i as f32 - 5.0).collect();

        let mut plain = vec![f32::NAN; m * n];
        kern.run(&x, &w, m, k, n, &mut plain, 1, &Epilogue::None);
        let mut want = plain.clone();
        for row in want.chunks_mut(n) {
            for (v, b) in row.iter_mut().zip(&bias) {
                *v += *b;
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        let mut got = vec![f32::NAN; m * n];
        kern.run(&x, &w, m, k, n, &mut got, 1,
                 &Epilogue::BiasRelu { bias: &bias });
        assert_eq!(got, want);
    }
}
