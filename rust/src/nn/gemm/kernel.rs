//! The object-safe [`Kernel`] trait, the Goto-style blocked driver and
//! the MR x NR register-tile microkernel.
//!
//! Loop structure (per thread, over its row chunk):
//!
//! ```text
//! for ic in MC row blocks          // L2: A block  (MC x KC)
//!   for jc in NC column blocks     // L2/L3: wide accumulator tile
//!     acc[MC x NC] = 0             //   (f64/i64 — stays wide across
//!     for pc in KC depth blocks    //    *all* depth blocks)
//!       for ir in MR panels        // registers
//!         for jr in NR panels
//!           microkernel: acc += A-panel x B-panel over kc
//!     out[ic+.., jc+..] = finish(acc)   // one narrowing, at the end
//! ```
//!
//! This deviates from the textbook Goto ordering (`jc -> pc -> ic`) in
//! one deliberate way: the depth loop `pc` is *innermost* of the cache
//! loops so the wide accumulator tile persists across the whole k
//! reduction.  That is what makes the tiled path bit-identical to the
//! `reference` kernels (each output element folds its products in
//! strictly increasing k order into one wide accumulator, narrowed
//! once) — a partial-sum spill to f32 between depth blocks would
//! change roundings.  Operands are packed once up front
//! (`pack_a_block` / `pack_b_block`), so no packing work is repeated
//! inside the block loops.
//!
//! Threading splits rows into per-thread chunks aligned to MR (panels
//! never straddle threads); each output element is still reduced by
//! exactly one thread in the same order, so results are bit-identical
//! across thread counts.

use super::micro::MicroArith;
use super::pack::{pack_a_block, pack_b_block};
use crate::numeric::BinXnor;

/// Row-block size: the A sub-block (MC x KC) an inner sweep works on.
pub const MC: usize = 64;
/// Depth-block size: panel slices streamed through the microkernel.
pub const KC: usize = 256;
/// Column-block size: bounds the wide accumulator tile (MC x NC wide
/// elements, 128 KiB at f64/i64 — L2-resident on the target cores).
pub const NC: usize = 256;

/// Outputs below this threshold stay single-threaded (same heuristic
/// as the pre-tiled kernels: thread spawn costs more than the GEMM).
const PAR_MIN_OUT: usize = 16 * 1024;

/// Threads used by the row-parallel drivers (0 = all available cores).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested thread count against the problem size.
fn effective_threads(threads: usize, m: usize, n: usize) -> usize {
    let t = if threads == 0 { default_threads() } else { threads };
    if m * n < PAR_MIN_OUT {
        1
    } else {
        t.min(m).max(1)
    }
}

/// One packed, tiled GEMM engine for a fixed `ArithKind`.  Object-safe:
/// `GemmPlan` holds these as `Box<dyn Kernel>`; the monomorphized
/// implementations behind it are `BlockedKernel<A, MR, NR>` (one per
/// provider) and the bit-packed `BinaryKernel`.
pub trait Kernel: Send + Sync {
    /// Kernel name for plans/logs, e.g. `packed-fi`.
    fn name(&self) -> &'static str;

    /// Microkernel tile height.
    fn mr(&self) -> usize;

    /// Microkernel tile width.
    fn nr(&self) -> usize;

    /// `out = cond(x) @ cond(w)`.  The caller (`GemmPlan::run`) checks
    /// the shape invariants and short-circuits the m/n/k = 0 edges, so
    /// implementations may assume `m, k, n >= 1` and exact slice
    /// lengths.
    fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
           out: &mut [f32], threads: usize);
}

/// The generic blocked engine: one monomorphization per provider.
pub struct BlockedKernel<A: MicroArith, const MR: usize, const NR: usize> {
    arith: A,
}

impl<A: MicroArith, const MR: usize, const NR: usize>
    BlockedKernel<A, MR, NR>
{
    pub fn new(arith: A) -> Self {
        // The block loops assume whole panels fit a block.
        assert!(MC % MR == 0, "MC must be a multiple of MR");
        assert!(NC % NR == 0, "NC must be a multiple of NR");
        BlockedKernel { arith }
    }
}

impl<A: MicroArith, const MR: usize, const NR: usize> Kernel
    for BlockedKernel<A, MR, NR>
{
    fn name(&self) -> &'static str {
        self.arith.name()
    }

    fn mr(&self) -> usize {
        MR
    }

    fn nr(&self) -> usize {
        NR
    }

    fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
           out: &mut [f32], threads: usize) {
        let ap = pack_a_block::<A, MR>(&self.arith, x, m, k);
        let bp = pack_b_block::<A, NR>(&self.arith, w, k, n);
        let threads = effective_threads(threads, m, n);
        if threads <= 1 {
            drive::<A, MR, NR>(&self.arith, &ap, &bp, 0, out, k, n);
            return;
        }
        // Chunk rows per thread, aligned to MR so no A panel straddles
        // two threads.
        let rows_per = m.div_ceil(threads).next_multiple_of(MR);
        std::thread::scope(|s| {
            for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let (ap, bp, arith) = (&ap, &bp, &self.arith);
                s.spawn(move || {
                    drive::<A, MR, NR>(arith, ap, bp, t * rows_per,
                                       chunk, k, n);
                });
            }
        });
    }
}

/// Blocked sweep over one thread's row chunk (`chunk` = rows
/// `[row0, row0 + chunk.len()/n)` of the output).  `row0` is a
/// multiple of MR.
fn drive<A: MicroArith, const MR: usize, const NR: usize>(
    arith: &A, ap: &[A::Elem], bp: &[A::Elem], row0: usize,
    chunk: &mut [f32], k: usize, n: usize,
) {
    let mrows = chunk.len() / n;
    // Wide accumulator tile, reused across blocks (zeroed per tile).
    let mut acc: Vec<A::Acc> = vec![arith.zero_acc(); MC * NC];
    for ic in (0..mrows).step_by(MC) {
        let mc = MC.min(mrows - ic);
        let mc_pad = mc.next_multiple_of(MR);
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            let nc_pad = nc.next_multiple_of(NR);
            for a in acc[..mc_pad * nc_pad].iter_mut() {
                *a = arith.zero_acc();
            }
            for pc in (0..k).step_by(KC) {
                let kc = KC.min(k - pc);
                for ir in (0..mc_pad).step_by(MR) {
                    // global A panel (row0, ic, ir all MR-aligned)
                    let p = (row0 + ic + ir) / MR;
                    let abase = p * MR * k + pc * MR;
                    let apan = &ap[abase..abase + kc * MR];
                    for jr in (0..nc_pad).step_by(NR) {
                        let q = (jc + jr) / NR;
                        let bbase = q * NR * k + pc * NR;
                        let bpan = &bp[bbase..bbase + kc * NR];
                        micro::<A, MR, NR>(
                            arith, apan, bpan, kc,
                            &mut acc[ir * nc_pad + jr..],
                            nc_pad,
                        );
                    }
                }
            }
            for r in 0..mc {
                let o0 = (ic + r) * n + jc;
                let orow = &mut chunk[o0..o0 + nc];
                let arow = &acc[r * nc_pad..r * nc_pad + nc];
                for (o, a) in orow.iter_mut().zip(arow) {
                    *o = arith.finish(*a);
                }
            }
        }
    }
}

/// The MR x NR register-tile microkernel: load the accumulator tile,
/// stream `kc` packed depth steps through it, store it back.  Per
/// output element this appends products in increasing k order — the
/// bit-exactness invariant.
#[inline]
fn micro<A: MicroArith, const MR: usize, const NR: usize>(
    arith: &A, apan: &[A::Elem], bpan: &[A::Elem], kc: usize,
    acc: &mut [A::Acc], stride: usize,
) {
    let mut t = [[arith.zero_acc(); NR]; MR];
    for (i, trow) in t.iter_mut().enumerate() {
        trow.copy_from_slice(&acc[i * stride..i * stride + NR]);
    }
    for p in 0..kc {
        let av = &apan[p * MR..(p + 1) * MR];
        let bv = &bpan[p * NR..(p + 1) * NR];
        for (i, trow) in t.iter_mut().enumerate() {
            let a = av[i];
            for (j, tv) in trow.iter_mut().enumerate() {
                *tv = arith.mul_acc(a, bv[j], *tv);
            }
        }
    }
    for (i, trow) in t.iter().enumerate() {
        acc[i * stride..i * stride + NR].copy_from_slice(trow);
    }
}

// ---------------------------------------------------------------------------
// binary XNOR kernel (paper §4.5): the packing *is* the conditioning —
// 64 sign bits per word, so panels are built along k in words and the
// microkernel is popcount over word panels.
// ---------------------------------------------------------------------------

/// Microkernel tile for the binary path (word panels, u32 agree
/// counters).
const BMR: usize = 4;
const BNR: usize = 4;

/// Bit-packed XNOR/popcount kernel for `ArithKind::Binary`.
pub struct BinaryKernel;

impl Kernel for BinaryKernel {
    fn name(&self) -> &'static str {
        "packed-binxnor"
    }

    fn mr(&self) -> usize {
        BMR
    }

    fn nr(&self) -> usize {
        BNR
    }

    fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
           out: &mut [f32], threads: usize) {
        let words = k.div_ceil(64);
        // A: BMR-row word panels, offset(p, wd, r) = p*BMR*words +
        // wd*BMR + r (same middle-axis layout as pack::pack_a_block).
        let apanels = m.div_ceil(BMR);
        let mut ap = vec![0u64; apanels * BMR * words];
        for r in 0..m {
            let base = (r / BMR) * BMR * words + r % BMR;
            let xrow = &x[r * k..(r + 1) * k];
            for (d, &v) in xrow.iter().enumerate() {
                ap[base + (d / 64) * BMR] |=
                    BinXnor::binarize(v) << (d % 64);
            }
        }
        // B: BNR-column word panels.
        let bpanels = n.div_ceil(BNR);
        let mut bp = vec![0u64; bpanels * BNR * words];
        for d in 0..k {
            let wrow = &w[d * n..(d + 1) * n];
            for (c, &v) in wrow.iter().enumerate() {
                let base = (c / BNR) * BNR * words + c % BNR;
                bp[base + (d / 64) * BNR] |=
                    BinXnor::binarize(v) << (d % 64);
            }
        }
        // bits >= k in the last word must not count as agreements
        let tail_bits = k % 64;
        let tail_mask =
            if tail_bits == 0 { u64::MAX } else { (1u64 << tail_bits) - 1 };

        let threads = effective_threads(threads, m, n);
        let rows_per = if threads <= 1 {
            m.next_multiple_of(BMR)
        } else {
            m.div_ceil(threads).next_multiple_of(BMR)
        };
        std::thread::scope(|s| {
            for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let (ap, bp) = (&ap, &bp);
                let worker = move || {
                    binary_drive(ap, bp, t * rows_per, chunk, words,
                                 tail_mask, k, n);
                };
                if threads <= 1 {
                    worker();
                } else {
                    s.spawn(worker);
                }
            }
        });
    }
}

fn binary_drive(ap: &[u64], bp: &[u64], row0: usize, chunk: &mut [f32],
                words: usize, tail_mask: u64, k: usize, n: usize) {
    let mrows = chunk.len() / n;
    for ir in (0..mrows).step_by(BMR) {
        let p = (row0 + ir) / BMR;
        let apan = &ap[p * BMR * words..(p + 1) * BMR * words];
        for jr in (0..n).step_by(BNR) {
            let q = jr / BNR;
            let bpan = &bp[q * BNR * words..(q + 1) * BNR * words];
            let mut agree = [[0u32; BNR]; BMR];
            for wd in 0..words {
                let msk = if wd == words - 1 { tail_mask } else { u64::MAX };
                let av = &apan[wd * BMR..(wd + 1) * BMR];
                let bv = &bpan[wd * BNR..(wd + 1) * BNR];
                for (i, arow) in agree.iter_mut().enumerate() {
                    let a = av[i];
                    for (j, c) in arow.iter_mut().enumerate() {
                        *c += (!(a ^ bv[j]) & msk).count_ones();
                    }
                }
            }
            // dot of ±1 vectors = agreements - disagreements
            for i in 0..BMR.min(mrows - ir) {
                for j in 0..BNR.min(n - jr) {
                    chunk[(ir + i) * n + jr + j] =
                        (2 * agree[i][j] as i64 - k as i64) as f32;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_sizes_divide() {
        // the driver's panel-index arithmetic relies on these
        assert_eq!(MC % 4, 0);
        assert_eq!(MC % 8, 0);
        assert_eq!(NC % 4, 0);
        assert_eq!(NC % 8, 0);
    }

    #[test]
    fn effective_threads_heuristics() {
        assert_eq!(effective_threads(4, 8, 8), 1); // tiny: stay serial
        assert_eq!(effective_threads(4, 200, 100), 4);
        assert_eq!(effective_threads(8, 2, 16 * 1024), 2); // capped by m
        assert!(effective_threads(0, 200, 100) >= 1);
    }
}
