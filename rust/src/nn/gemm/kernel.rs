//! The object-safe [`Kernel`] trait, the Goto-style blocked driver and
//! the MR x NR register-tile microkernel.
//!
//! Loop structure (per thread, over its row chunk):
//!
//! ```text
//! for ic in MC row blocks          // L2: A block  (MC x KC)
//!   for jc in NC column blocks     // L2/L3: wide accumulator tile
//!     acc[MC x NC] = 0             //   (f64/i64 — stays wide across
//!     for pc in KC depth blocks    //    *all* depth blocks)
//!       for ir in MR panels        // registers
//!         for jr in NR panels
//!           microkernel: acc += A-panel x B-panel over kc
//!     out[ic+.., jc+..] = finish(acc)   // one narrowing, at the end
//! ```
//!
//! This deviates from the textbook Goto ordering (`jc -> pc -> ic`) in
//! one deliberate way: the depth loop `pc` is *innermost* of the cache
//! loops so the wide accumulator tile persists across the whole k
//! reduction.  That is what makes the tiled path bit-identical to the
//! `reference` kernels (each output element folds its products in
//! strictly increasing k order into one wide accumulator, narrowed
//! once) — a partial-sum spill to f32 between depth blocks would
//! change roundings.  Operands are packed once up front
//! (`pack_a_block` / `pack_b_block`), so no packing work is repeated
//! inside the block loops.
//!
//! Threading splits rows into per-thread chunks aligned to MR (panels
//! never straddle threads); each output element is still reduced by
//! exactly one thread in the same order, so results are bit-identical
//! across thread counts.

use super::micro::MicroArith;
use super::pack::{pack_a_bits, pack_a_block, pack_b_bits, pack_b_block};
use std::any::Any;

/// Row-block size: the A sub-block (MC x KC) an inner sweep works on.
pub const MC: usize = 64;
/// Depth-block size: panel slices streamed through the microkernel.
pub const KC: usize = 256;
/// Column-block size: bounds the wide accumulator tile (MC x NC wide
/// elements, 128 KiB at f64/i64 — L2-resident on the target cores).
pub const NC: usize = 256;

/// Outputs below this threshold stay single-threaded (same heuristic
/// as the pre-tiled kernels: thread spawn costs more than the GEMM).
const PAR_MIN_OUT: usize = 16 * 1024;

/// Threads used by the row-parallel drivers (0 = all available cores).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested thread count against the problem size.
fn effective_threads(threads: usize, m: usize, n: usize) -> usize {
    let t = if threads == 0 { default_threads() } else { threads };
    if m * n < PAR_MIN_OUT {
        1
    } else {
        t.min(m).max(1)
    }
}

/// FNV-1a over the raw f32 bit patterns — the cheap fingerprint
/// [`PackedWeights`] carries so debug builds can verify that the `w`
/// a caller hands to the cached path is the matrix the panels were
/// conditioned from.
pub fn weight_fingerprint(w: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in w {
        h = (h ^ v.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Prepacked, conditioned weight-side panels for one kernel — the
/// output of [`Kernel::prepack_weights`], owned by `GemmPlan` (one per
/// prepared layer) and consumed by [`Kernel::run_prepacked`].
///
/// The panel buffer is opaque (`dyn Any`, `Send + Sync`): conditioned
/// element panels for the blocked kernels (`Vec<Elem>` in the
/// `pack_b_block` layout), sign-bit word panels (`Vec<u64>`) for the
/// binary kernel.  The identity pair (kernel name, provider `cfg_tag`)
/// travels with the buffer; `run_prepacked` panics rather than
/// consume panels conditioned by a different kernel or a
/// differently-parameterized provider, so two `prepare` calls with
/// different `ArithKind`s can never share panels.
pub struct PackedWeights {
    panels: Box<dyn Any + Send + Sync>,
    kernel: &'static str,
    cfg_tag: u64,
    k: usize,
    n: usize,
    bytes: usize,
    w_fnv: u64,
}

impl PackedWeights {
    /// Name of the kernel that conditioned these panels.
    pub fn kernel_name(&self) -> &'static str {
        self.kernel
    }

    /// Depth (weight rows) the panels were packed for.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns the panels were packed for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Resident panel-buffer size in bytes (conditioned elements only;
    /// excludes this header).
    pub fn resident_bytes(&self) -> usize {
        self.bytes
    }

    /// [`weight_fingerprint`] of the source weight matrix.
    pub fn fingerprint(&self) -> u64 {
        self.w_fnv
    }
}

/// Guarded panel access: identity-check `pw` against the consuming
/// kernel, then downcast to its concrete panel buffer.  Both checks
/// panic — handing a kernel foreign panels is a caller bug that must
/// not produce silently-misconditioned results.
fn panels_of<'p, T: 'static>(pw: &'p PackedWeights, kernel: &'static str,
                             cfg_tag: u64) -> &'p T {
    assert_eq!(
        pw.kernel, kernel,
        "weight panels were packed by kernel `{}`, not `{}`",
        pw.kernel, kernel
    );
    assert_eq!(
        pw.cfg_tag, cfg_tag,
        "weight panels were packed under a different `{kernel}` \
         configuration"
    );
    pw.panels
        .downcast_ref::<T>()
        .expect("panel buffer type does not match the kernel")
}

/// One packed, tiled GEMM engine for a fixed `ArithKind`.  Object-safe:
/// `GemmPlan` holds these as `Box<dyn Kernel>`; the monomorphized
/// implementations behind it are `BlockedKernel<A, MR, NR>` (one per
/// provider) and the bit-packed `BinaryKernel`.
pub trait Kernel: Send + Sync {
    /// Kernel name for plans/logs, e.g. `packed-fi`.
    fn name(&self) -> &'static str;

    /// Microkernel tile height.
    fn mr(&self) -> usize;

    /// Microkernel tile width.
    fn nr(&self) -> usize;

    /// `out = cond(x) @ cond(w)`.  The caller (`GemmPlan::run`) checks
    /// the shape invariants and short-circuits the m/n/k = 0 edges, so
    /// implementations may assume `m, k, n >= 1` and exact slice
    /// lengths.
    fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
           out: &mut [f32], threads: usize);

    /// Condition `w` (`k` x `n`, row-major) into this kernel's panel
    /// layout once, for arbitrarily many [`Kernel::run_prepacked`]
    /// calls.  The returned panels are exactly what [`Kernel::run`]
    /// builds internally per call, so the two entry points are
    /// bit-identical by construction.
    fn prepack_weights(&self, w: &[f32], k: usize, n: usize)
                       -> PackedWeights;

    /// `out = cond(x) @ panels` with the weight side already
    /// conditioned by [`Kernel::prepack_weights`] (which fixes `k` and
    /// `n`).  Same caller contract as [`Kernel::run`]: shapes checked
    /// and m/k/n = 0 short-circuited by `GemmPlan`, so implementations
    /// may assume `m >= 1` and `pw.k(), pw.n() >= 1`.  Panics if `pw`
    /// was packed by a different kernel or provider configuration.
    fn run_prepacked(&self, x: &[f32], pw: &PackedWeights, m: usize,
                     out: &mut [f32], threads: usize);
}

/// The generic blocked engine: one monomorphization per provider.
pub struct BlockedKernel<A: MicroArith, const MR: usize, const NR: usize> {
    arith: A,
}

impl<A: MicroArith, const MR: usize, const NR: usize>
    BlockedKernel<A, MR, NR>
{
    pub fn new(arith: A) -> Self {
        // The block loops assume whole panels fit a block.
        assert!(MC % MR == 0, "MC must be a multiple of MR");
        assert!(NC % NR == 0, "NC must be a multiple of NR");
        BlockedKernel { arith }
    }

    /// The engine proper, over already-packed B panels: pack A, split
    /// rows across threads, drive the blocked sweep.  Shared verbatim
    /// by `run` (packs B per call) and `run_prepacked` (cached panels),
    /// which is what makes the two entry points bit-identical.
    fn run_packed_b(&self, x: &[f32], bp: &[A::Elem], m: usize, k: usize,
                    n: usize, out: &mut [f32], threads: usize) {
        let ap = pack_a_block::<A, MR>(&self.arith, x, m, k);
        let threads = effective_threads(threads, m, n);
        if threads <= 1 {
            drive::<A, MR, NR>(&self.arith, &ap, bp, 0, out, k, n);
            return;
        }
        // Chunk rows per thread, aligned to MR so no A panel straddles
        // two threads.
        let rows_per = m.div_ceil(threads).next_multiple_of(MR);
        std::thread::scope(|s| {
            for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let (ap, arith) = (&ap, &self.arith);
                s.spawn(move || {
                    drive::<A, MR, NR>(arith, ap, bp, t * rows_per,
                                       chunk, k, n);
                });
            }
        });
    }
}

impl<A: MicroArith, const MR: usize, const NR: usize> Kernel
    for BlockedKernel<A, MR, NR>
{
    fn name(&self) -> &'static str {
        self.arith.name()
    }

    fn mr(&self) -> usize {
        MR
    }

    fn nr(&self) -> usize {
        NR
    }

    fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
           out: &mut [f32], threads: usize) {
        let bp = pack_b_block::<A, NR>(&self.arith, w, k, n);
        self.run_packed_b(x, &bp, m, k, n, out, threads);
    }

    fn prepack_weights(&self, w: &[f32], k: usize, n: usize)
                       -> PackedWeights {
        assert_eq!(w.len(), k * n, "w shape mismatch");
        let bp = pack_b_block::<A, NR>(&self.arith, w, k, n);
        let bytes = bp.len() * std::mem::size_of::<A::Elem>();
        PackedWeights {
            panels: Box::new(bp),
            kernel: self.arith.name(),
            cfg_tag: self.arith.cfg_tag(),
            k,
            n,
            bytes,
            w_fnv: weight_fingerprint(w),
        }
    }

    fn run_prepacked(&self, x: &[f32], pw: &PackedWeights, m: usize,
                     out: &mut [f32], threads: usize) {
        let bp = panels_of::<Vec<A::Elem>>(pw, self.arith.name(),
                                           self.arith.cfg_tag());
        self.run_packed_b(x, bp, m, pw.k, pw.n, out, threads);
    }
}

/// Blocked sweep over one thread's row chunk (`chunk` = rows
/// `[row0, row0 + chunk.len()/n)` of the output).  `row0` is a
/// multiple of MR.
fn drive<A: MicroArith, const MR: usize, const NR: usize>(
    arith: &A, ap: &[A::Elem], bp: &[A::Elem], row0: usize,
    chunk: &mut [f32], k: usize, n: usize,
) {
    let mrows = chunk.len() / n;
    // Wide accumulator tile, reused across blocks (zeroed per tile).
    let mut acc: Vec<A::Acc> = vec![arith.zero_acc(); MC * NC];
    for ic in (0..mrows).step_by(MC) {
        let mc = MC.min(mrows - ic);
        let mc_pad = mc.next_multiple_of(MR);
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            let nc_pad = nc.next_multiple_of(NR);
            for a in acc[..mc_pad * nc_pad].iter_mut() {
                *a = arith.zero_acc();
            }
            for pc in (0..k).step_by(KC) {
                let kc = KC.min(k - pc);
                for ir in (0..mc_pad).step_by(MR) {
                    // global A panel (row0, ic, ir all MR-aligned)
                    let p = (row0 + ic + ir) / MR;
                    let abase = p * MR * k + pc * MR;
                    let apan = &ap[abase..abase + kc * MR];
                    for jr in (0..nc_pad).step_by(NR) {
                        let q = (jc + jr) / NR;
                        let bbase = q * NR * k + pc * NR;
                        let bpan = &bp[bbase..bbase + kc * NR];
                        micro::<A, MR, NR>(
                            arith, apan, bpan, kc,
                            &mut acc[ir * nc_pad + jr..],
                            nc_pad,
                        );
                    }
                }
            }
            for r in 0..mc {
                let o0 = (ic + r) * n + jc;
                let orow = &mut chunk[o0..o0 + nc];
                let arow = &acc[r * nc_pad..r * nc_pad + nc];
                for (o, a) in orow.iter_mut().zip(arow) {
                    *o = arith.finish(*a);
                }
            }
        }
    }
}

/// The MR x NR register-tile microkernel: load the accumulator tile,
/// stream `kc` packed depth steps through it, store it back.  Per
/// output element this appends products in increasing k order — the
/// bit-exactness invariant.
#[inline]
fn micro<A: MicroArith, const MR: usize, const NR: usize>(
    arith: &A, apan: &[A::Elem], bpan: &[A::Elem], kc: usize,
    acc: &mut [A::Acc], stride: usize,
) {
    let mut t = [[arith.zero_acc(); NR]; MR];
    for (i, trow) in t.iter_mut().enumerate() {
        trow.copy_from_slice(&acc[i * stride..i * stride + NR]);
    }
    for p in 0..kc {
        let av = &apan[p * MR..(p + 1) * MR];
        let bv = &bpan[p * NR..(p + 1) * NR];
        for (i, trow) in t.iter_mut().enumerate() {
            let a = av[i];
            for (j, tv) in trow.iter_mut().enumerate() {
                *tv = arith.mul_acc(a, bv[j], *tv);
            }
        }
    }
    for (i, trow) in t.iter().enumerate() {
        acc[i * stride..i * stride + NR].copy_from_slice(trow);
    }
}

// ---------------------------------------------------------------------------
// binary XNOR kernel (paper §4.5): the packing *is* the conditioning —
// 64 sign bits per word, so panels are built along k in words and the
// microkernel is popcount over word panels.
// ---------------------------------------------------------------------------

/// Microkernel tile for the binary path (word panels, u32 agree
/// counters).
const BMR: usize = 4;
const BNR: usize = 4;

/// Provider fingerprint for the (parameterless) binary configuration.
const BINARY_CFG_TAG: u64 = 0x06;

/// Bit-packed XNOR/popcount kernel for `ArithKind::Binary`.
pub struct BinaryKernel;

impl BinaryKernel {
    /// The popcount engine over already-packed B word panels: pack A
    /// sign bits, split rows across threads, drive.  Shared by `run`
    /// and `run_prepacked` — the packing *is* the conditioning for this
    /// representation, so the cached panels carry the whole weight-side
    /// cost.
    fn run_packed_b(&self, x: &[f32], bp: &[u64], m: usize, k: usize,
                    n: usize, out: &mut [f32], threads: usize) {
        let words = k.div_ceil(64);
        // A: BMR-row word panels (same middle-axis layout as
        // pack::pack_a_block, 64 depth steps per word).
        let ap = pack_a_bits::<BMR>(x, m, k);
        // bits >= k in the last word must not count as agreements
        let tail_bits = k % 64;
        let tail_mask =
            if tail_bits == 0 { u64::MAX } else { (1u64 << tail_bits) - 1 };

        let threads = effective_threads(threads, m, n);
        let rows_per = if threads <= 1 {
            m.next_multiple_of(BMR)
        } else {
            m.div_ceil(threads).next_multiple_of(BMR)
        };
        std::thread::scope(|s| {
            for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let ap = &ap;
                let worker = move || {
                    binary_drive(ap, bp, t * rows_per, chunk, words,
                                 tail_mask, k, n);
                };
                if threads <= 1 {
                    worker();
                } else {
                    s.spawn(worker);
                }
            }
        });
    }
}

impl Kernel for BinaryKernel {
    fn name(&self) -> &'static str {
        "packed-binxnor"
    }

    fn mr(&self) -> usize {
        BMR
    }

    fn nr(&self) -> usize {
        BNR
    }

    fn run(&self, x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
           out: &mut [f32], threads: usize) {
        let bp = pack_b_bits::<BNR>(w, k, n);
        self.run_packed_b(x, &bp, m, k, n, out, threads);
    }

    fn prepack_weights(&self, w: &[f32], k: usize, n: usize)
                       -> PackedWeights {
        assert_eq!(w.len(), k * n, "w shape mismatch");
        let bp = pack_b_bits::<BNR>(w, k, n);
        let bytes = bp.len() * std::mem::size_of::<u64>();
        PackedWeights {
            panels: Box::new(bp),
            kernel: self.name(),
            cfg_tag: BINARY_CFG_TAG,
            k,
            n,
            bytes,
            w_fnv: weight_fingerprint(w),
        }
    }

    fn run_prepacked(&self, x: &[f32], pw: &PackedWeights, m: usize,
                     out: &mut [f32], threads: usize) {
        let bp = panels_of::<Vec<u64>>(pw, self.name(), BINARY_CFG_TAG);
        self.run_packed_b(x, bp, m, pw.k, pw.n, out, threads);
    }
}

fn binary_drive(ap: &[u64], bp: &[u64], row0: usize, chunk: &mut [f32],
                words: usize, tail_mask: u64, k: usize, n: usize) {
    let mrows = chunk.len() / n;
    for ir in (0..mrows).step_by(BMR) {
        let p = (row0 + ir) / BMR;
        let apan = &ap[p * BMR * words..(p + 1) * BMR * words];
        for jr in (0..n).step_by(BNR) {
            let q = jr / BNR;
            let bpan = &bp[q * BNR * words..(q + 1) * BNR * words];
            let mut agree = [[0u32; BNR]; BMR];
            for wd in 0..words {
                let msk = if wd == words - 1 { tail_mask } else { u64::MAX };
                let av = &apan[wd * BMR..(wd + 1) * BMR];
                let bv = &bpan[wd * BNR..(wd + 1) * BNR];
                for (i, arow) in agree.iter_mut().enumerate() {
                    let a = av[i];
                    for (j, c) in arow.iter_mut().enumerate() {
                        *c += (!(a ^ bv[j]) & msk).count_ones();
                    }
                }
            }
            // dot of ±1 vectors = agreements - disagreements
            for i in 0..BMR.min(mrows - ir) {
                for j in 0..BNR.min(n - jr) {
                    chunk[(ir + i) * n + jr + j] =
                        (2 * agree[i][j] as i64 - k as i64) as f32;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_sizes_divide() {
        // the driver's panel-index arithmetic relies on these
        assert_eq!(MC % 4, 0);
        assert_eq!(MC % 8, 0);
        assert_eq!(NC % 4, 0);
        assert_eq!(NC % 8, 0);
    }

    #[test]
    fn effective_threads_heuristics() {
        assert_eq!(effective_threads(4, 8, 8), 1); // tiny: stay serial
        assert_eq!(effective_threads(4, 200, 100), 4);
        assert_eq!(effective_threads(8, 2, 16 * 1024), 2); // capped by m
        assert!(effective_threads(0, 200, 100) >= 1);
    }

    #[test]
    fn fingerprint_is_order_and_value_sensitive() {
        assert_eq!(weight_fingerprint(&[1.0, 2.0]),
                   weight_fingerprint(&[1.0, 2.0]));
        assert_ne!(weight_fingerprint(&[1.0, 2.0]),
                   weight_fingerprint(&[2.0, 1.0]));
        assert_ne!(weight_fingerprint(&[1.0]),
                   weight_fingerprint(&[1.5]));
        // 0.0 and -0.0 are different bit patterns -> different panels
        // for sign-sensitive providers (binary)
        assert_ne!(weight_fingerprint(&[0.0]),
                   weight_fingerprint(&[-0.0]));
    }

    #[test]
    fn prepack_carries_identity_and_shape() {
        use super::super::micro::F32Micro;
        let kern = BlockedKernel::<_, 8, 8>::new(F32Micro);
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let pw = kern.prepack_weights(&w, 2, 3);
        assert_eq!(pw.kernel_name(), "packed-f32");
        assert_eq!((pw.k(), pw.n()), (2, 3));
        // one 8-wide panel of depth 2, f32 elements
        assert_eq!(pw.resident_bytes(), 8 * 2 * 4);
        assert_eq!(pw.fingerprint(), weight_fingerprint(&w));
        // binary panels report word-panel bytes
        let pb = BinaryKernel.prepack_weights(&w, 2, 3);
        assert_eq!(pb.kernel_name(), "packed-binxnor");
        assert_eq!(pb.resident_bytes(), 4 * 8); // one BNR=4 word panel
    }

    #[test]
    #[should_panic(expected = "packed by kernel")]
    fn foreign_panels_rejected_by_kernel_name() {
        use super::super::micro::F32Micro;
        let f32k = BlockedKernel::<_, 8, 8>::new(F32Micro);
        let pw = BinaryKernel.prepack_weights(&[1.0; 6], 2, 3);
        let mut out = [0.0f32; 3];
        f32k.run_prepacked(&[1.0, 1.0], &pw, 1, &mut out, 1);
    }
}
