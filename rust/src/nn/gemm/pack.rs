//! Operand packing: reorder A into MR-row panels and B into NR-column
//! panels, fusing the provider's operand conditioning (quantize /
//! encode / DRUM-condition / CFPU-classify) into the copy so the
//! microkernel reads conditioned elements at unit stride.
//!
//! Panel layout, after the rten/BLIS convention:
//!
//! ```text
//! A (m x k), MR-row panels:        B (k x n), NR-column panels:
//!   panel p covers rows              panel q covers cols
//!   [p*MR, p*MR + MR)                [q*NR, q*NR + NR)
//!   offset(p, d, r) =                offset(q, d, c) =
//!     p*MR*k + d*MR + r                q*NR*k + d*NR + c
//! ```
//!
//! Because depth is the middle axis, the slice a microkernel needs for
//! a (panel, depth-block) pair is contiguous: `p*MR*k + d0*MR ..
//! p*MR*k + d1*MR`.  The Goto-style KC blocking in `kernel` is
//! therefore pure loop structure over one packed buffer — operands are
//! packed (and conditioned) exactly once, keeping conditioning at
//! O(mk + kn).
//!
//! Rows past `m` / columns past `n` in the trailing panel pad with
//! `MicroArith::zero_elem`, which is absorbing in `mul_acc`; padded
//! outputs are computed into the accumulator tile but never stored.

use super::micro::MicroArith;
use crate::numeric::BinXnor;
use crate::telemetry::{self, Counter};
use std::cell::Cell;
use std::sync::{Arc, OnceLock};

thread_local! {
    /// Weight-side (B-operand) packing operations performed by this
    /// thread.  Thread-local is the right scope: every kernel packs on
    /// the *calling* thread before spawning workers, so a caller can
    /// bracket its own forwards without interference from concurrent
    /// tests or serving threads.
    static WEIGHT_PACKS: Cell<u64> = const { Cell::new(0) };
}

/// Process-wide total of weight-side packing operations, across all
/// threads — a `gemm.weight_packs` counter on the global telemetry
/// registry, so serving snapshots export it alongside the stage
/// histograms.
fn weight_packs_global() -> &'static Arc<Counter> {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| telemetry::global().counter("gemm.weight_packs"))
}

/// How many weight-side packing operations ([`pack_b_block`] calls and
/// binary weight-bitmap builds) this thread has performed.  The
/// prepack-once contract (`tests/prepack_differential.rs`) asserts this
/// stays flat across `PreparedNet::forward` calls after `prepare`.
pub fn weight_pack_count() -> u64 {
    WEIGHT_PACKS.with(|c| c.get())
}

/// Cross-thread companion to [`weight_pack_count`]: the same counter
/// summed over every thread in the process.  The shared
/// `coordinator::plan_cache` prepares a config on whichever worker
/// wins the single-flight race, so per-thread counters cannot observe
/// the cache-wide prepare-once contract — `tests/plan_cache.rs`
/// brackets this one instead.  Tests asserting exact deltas must
/// serialize themselves: the test harness runs tests of one binary
/// concurrently in a single process.
pub fn weight_pack_count_global() -> u64 {
    weight_packs_global().get()
}

fn note_weight_pack() {
    WEIGHT_PACKS.with(|c| c.set(c.get() + 1));
    weight_packs_global().inc();
}

/// Pack all of row-major `x` (`m` x `k`, row stride `k`) into MR-row
/// panels, conditioning each element.  Returns
/// `m.div_ceil(MR) * MR * k` elements.
pub fn pack_a_block<A: MicroArith, const MR: usize>(
    arith: &A, x: &[f32], m: usize, k: usize,
) -> Vec<A::Elem> {
    let panels = m.div_ceil(MR);
    let mut out = vec![arith.zero_elem(); panels * MR * k];
    for p in 0..panels {
        let base = p * MR * k;
        let r_hi = (p * MR + MR).min(m);
        for (ri, r) in (p * MR..r_hi).enumerate() {
            let xrow = &x[r * k..(r + 1) * k];
            for (d, &v) in xrow.iter().enumerate() {
                out[base + d * MR + ri] = arith.condition(v);
            }
        }
    }
    out
}

/// Pack all of row-major `w` (`k` x `n`, row stride `n`) into NR-column
/// panels, conditioning each element.  Returns
/// `n.div_ceil(NR) * NR * k` elements.
pub fn pack_b_block<A: MicroArith, const NR: usize>(
    arith: &A, w: &[f32], k: usize, n: usize,
) -> Vec<A::Elem> {
    note_weight_pack();
    let panels = n.div_ceil(NR);
    let mut out = vec![arith.zero_elem(); panels * NR * k];
    for d in 0..k {
        let wrow = &w[d * n..(d + 1) * n];
        for q in 0..panels {
            let base = q * NR * k + d * NR;
            let c_hi = (q * NR + NR).min(n);
            for (ci, c) in (q * NR..c_hi).enumerate() {
                out[base + ci] = arith.condition(wrow[c]);
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// bit packing for the binary/XNOR kernel: 64 sign bits per word along
// k, so the packing *is* the conditioning (paper §4.5).  Shared by the
// per-call path and the prepacked weight path of `kernel::BinaryKernel`.
// ---------------------------------------------------------------------------

/// Pack row-major `x` (`m` x `k`) into MR-row *word* panels of sign
/// bits: `offset(p, wd, r) = p*MR*words + wd*MR + r` with
/// `words = k.div_ceil(64)` (same middle-axis layout as
/// [`pack_a_block`], with 64 depth steps per word).
pub fn pack_a_bits<const MR: usize>(x: &[f32], m: usize, k: usize)
                                    -> Vec<u64> {
    let words = k.div_ceil(64);
    let panels = m.div_ceil(MR);
    let mut out = vec![0u64; panels * MR * words];
    for r in 0..m {
        let base = (r / MR) * MR * words + r % MR;
        let xrow = &x[r * k..(r + 1) * k];
        for (d, &v) in xrow.iter().enumerate() {
            out[base + (d / 64) * MR] |= BinXnor::binarize(v) << (d % 64);
        }
    }
    out
}

/// Pack row-major `w` (`k` x `n`) into NR-column word panels of sign
/// bits: `offset(q, wd, c) = q*NR*words + wd*NR + c`.
pub fn pack_b_bits<const NR: usize>(w: &[f32], k: usize, n: usize)
                                    -> Vec<u64> {
    note_weight_pack();
    let words = k.div_ceil(64);
    let panels = n.div_ceil(NR);
    let mut out = vec![0u64; panels * NR * words];
    for d in 0..k {
        let wrow = &w[d * n..(d + 1) * n];
        for (c, &v) in wrow.iter().enumerate() {
            let base = (c / NR) * NR * words + c % NR;
            out[base + (d / 64) * NR] |= BinXnor::binarize(v) << (d % 64);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::gemm::micro::{F32Micro, FixedMicro};
    use crate::numeric::FixedPoint;
    use crate::util::prop;

    #[test]
    fn a_panel_layout_and_padding() {
        // 3 x 2 matrix with MR = 2: panel 0 = rows {0, 1}, panel 1 =
        // row 2 + one padded row.
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = pack_a_block::<F32Micro, 2>(&F32Micro, &x, 3, 2);
        assert_eq!(p.len(), 2 * 2 * 2);
        // panel 0, depth 0: rows 0..2 of column 0
        assert_eq!(&p[0..2], &[1.0, 3.0]);
        // panel 0, depth 1: rows 0..2 of column 1
        assert_eq!(&p[2..4], &[2.0, 4.0]);
        // panel 1: row 2 then zero padding, per depth
        assert_eq!(&p[4..8], &[5.0, 0.0, 6.0, 0.0]);
    }

    #[test]
    fn b_panel_layout_and_padding() {
        // 2 x 3 matrix with NR = 2: panel 0 = cols {0, 1}, panel 1 =
        // col 2 + one padded column.
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = pack_b_block::<F32Micro, 2>(&F32Micro, &w, 2, 3);
        assert_eq!(p.len(), 2 * 2 * 2);
        // panel 0: (d=0: cols 0,1), (d=1: cols 0,1)
        assert_eq!(&p[0..4], &[1.0, 2.0, 4.0, 5.0]);
        // panel 1: (d=0: col 2, pad), (d=1: col 2, pad)
        assert_eq!(&p[4..8], &[3.0, 0.0, 6.0, 0.0]);
    }

    #[test]
    fn zero_depth_packs_empty() {
        let p = pack_a_block::<F32Micro, 4>(&F32Micro, &[], 0, 0);
        assert!(p.is_empty());
        let q = pack_b_block::<F32Micro, 4>(&F32Micro, &[], 0, 5);
        assert!(q.is_empty());
    }

    #[test]
    fn bit_panel_layout() {
        // 2 x 3 sign matrix with NR = 2: panel 0 = cols {0, 1}, panel 1
        // = col 2 + one padded (all-zero-bit) column; k = 2 fits in one
        // word per lane.
        let w = [1.0f32, -2.0, 3.0, -4.0, 5.0, -6.0];
        let p = pack_b_bits::<2>(&w, 2, 3);
        assert_eq!(p.len(), 2 * 2);
        // col 0: signs (+, -) -> bits (1, 0); col 1: (-, +) -> (0, 1)
        assert_eq!(p[0], 0b01);
        assert_eq!(p[1], 0b10);
        // col 2: (+, -) -> (1, 0); padded col stays 0
        assert_eq!(p[2], 0b01);
        assert_eq!(p[3], 0);
        // A-side: 3 x 2 with MR = 2 -> panel 1 holds row 2 + padding
        let a = pack_a_bits::<2>(&w, 3, 2);
        assert_eq!(a.len(), 2 * 2);
        // row 0: (+, -) -> 0b01; row 1: (+, -) -> 0b01
        assert_eq!(&a[0..2], &[0b01, 0b01]);
        assert_eq!(&a[2..4], &[0b01, 0]);
    }

    #[test]
    fn weight_pack_counter_counts_b_side_only() {
        let c0 = weight_pack_count();
        let _ = pack_a_block::<F32Micro, 4>(&F32Micro, &[1.0; 8], 2, 4);
        let _ = pack_a_bits::<4>(&[1.0; 8], 2, 4);
        assert_eq!(weight_pack_count(), c0, "A-side packs must not count");
        let _ = pack_b_block::<F32Micro, 4>(&F32Micro, &[1.0; 8], 2, 4);
        let _ = pack_b_bits::<4>(&[1.0; 8], 2, 4);
        assert_eq!(weight_pack_count(), c0 + 2);
    }

    // -----------------------------------------------------------------
    // pack-geometry properties: with the dispatch layer, kernels carry
    // their own MR/NR, so the panel math must hold for *any* tile
    // width — including the widened SIMD tiles (6, 16) and odd mocks —
    // across m = 0, k = 0, n = 1 and every non-divisible tail.
    // -----------------------------------------------------------------

    /// Element-wise oracle for [`pack_a_block`]: panel `p`, depth `d`,
    /// lane `r` holds `condition(x[(p*MR + r) * k + d])`, zero-padded
    /// past `m`.
    fn check_a_layout<const MR: usize>(arith: &FixedMicro, x: &[f32],
                                       m: usize, k: usize)
                                       -> Result<(), String> {
        let p = pack_a_block::<FixedMicro, MR>(arith, x, m, k);
        let panels = m.div_ceil(MR);
        if p.len() != panels * MR * k {
            return Err(format!(
                "A len {} != {panels}*{MR}*{k}", p.len()));
        }
        for pi in 0..panels {
            for d in 0..k {
                for r in 0..MR {
                    let got = p[pi * MR * k + d * MR + r];
                    let row = pi * MR + r;
                    let want = if row < m {
                        arith.condition(x[row * k + d])
                    } else {
                        arith.zero_elem()
                    };
                    if got != want {
                        return Err(format!(
                            "A MR={MR} m={m} k={k}: (p={pi}, d={d}, \
                             r={r}) = {got}, want {want}"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Element-wise oracle for [`pack_b_block`]: panel `q`, depth `d`,
    /// lane `c` holds `condition(w[d * n + q*NR + c])`, zero-padded
    /// past `n`.
    fn check_b_layout<const NR: usize>(arith: &FixedMicro, w: &[f32],
                                       k: usize, n: usize)
                                       -> Result<(), String> {
        let p = pack_b_block::<FixedMicro, NR>(arith, w, k, n);
        let panels = n.div_ceil(NR);
        if p.len() != panels * NR * k {
            return Err(format!(
                "B len {} != {panels}*{NR}*{k}", p.len()));
        }
        for q in 0..panels {
            for d in 0..k {
                for c in 0..NR {
                    let got = p[q * NR * k + d * NR + c];
                    let col = q * NR + c;
                    let want = if col < n {
                        arith.condition(w[d * n + col])
                    } else {
                        arith.zero_elem()
                    };
                    if got != want {
                        return Err(format!(
                            "B NR={NR} k={k} n={n}: (q={q}, d={d}, \
                             c={c}) = {got}, want {want}"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Bit-wise oracle for the binary word panels: bit `d % 64` of the
    /// word at `(lane_block, d / 64, lane)` is the sign bit of the
    /// corresponding element; lanes past the matrix edge and bits past
    /// `k` stay zero.
    fn check_bit_layouts<const T: usize>(v: &[f32], rows: usize,
                                         k: usize) -> Result<(), String> {
        let words = k.div_ceil(64);
        // A side: rows x k, T-row panels
        let a = pack_a_bits::<T>(v, rows, k);
        let panels = rows.div_ceil(T);
        if a.len() != panels * T * words {
            return Err(format!(
                "A bits len {} != {panels}*{T}*{words}", a.len()));
        }
        for pi in 0..panels {
            for wd in 0..words {
                for r in 0..T {
                    let got = a[pi * T * words + wd * T + r];
                    let row = pi * T + r;
                    let mut want = 0u64;
                    if row < rows {
                        for bit in 0..64 {
                            let d = wd * 64 + bit;
                            if d < k {
                                want |= BinXnor::binarize(v[row * k + d])
                                    << bit;
                            }
                        }
                    }
                    if got != want {
                        return Err(format!(
                            "A bits T={T} rows={rows} k={k}: (p={pi}, \
                             wd={wd}, r={r}) = {got:#x}, want \
                             {want:#x}"));
                    }
                }
            }
        }
        // B side: k x rows (reuse `v` transposed shape: k rows of
        // `rows` columns requires v.len() == k * rows, same buffer)
        let b = pack_b_bits::<T>(v, k, rows);
        if b.len() != panels * T * words {
            return Err(format!(
                "B bits len {} != {panels}*{T}*{words}", b.len()));
        }
        for q in 0..panels {
            for wd in 0..words {
                for c in 0..T {
                    let got = b[q * T * words + wd * T + c];
                    let col = q * T + c;
                    let mut want = 0u64;
                    if col < rows {
                        for bit in 0..64 {
                            let d = wd * 64 + bit;
                            if d < k {
                                want |= BinXnor::binarize(
                                    v[d * rows + col]) << bit;
                            }
                        }
                    }
                    if got != want {
                        return Err(format!(
                            "B bits T={T} k={k} n={rows}: (q={q}, \
                             wd={wd}, c={c}) = {got:#x}, want \
                             {want:#x}"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Runtime-to-const dispatch so one property sweeps every tile
    /// width in play (1..=8 plus the 16-wide AVX2 f32 tile).
    fn check_all_for_tile(tile: usize, arith: &FixedMicro, x: &[f32],
                          m: usize, k: usize) -> Result<(), String> {
        macro_rules! per_tile {
            ($($t:literal),*) => {
                match tile {
                    $($t => {
                        check_a_layout::<$t>(arith, x, m, k)?;
                        check_b_layout::<$t>(arith, x, m, k)?;
                        check_bit_layouts::<$t>(x, m, k)
                    })*
                    _ => panic!("no instantiation for tile {tile}"),
                }
            };
        }
        per_tile!(1, 2, 3, 4, 5, 6, 7, 8, 16)
    }

    #[test]
    fn prop_panel_layouts_for_every_tile_width() {
        // B-side reuses the same buffer as a k x m matrix, so x must
        // cover max(m*k, k*m) = m*k elements either way.
        prop::check_msg(
            "pack layout == element oracle (all tiles)",
            0x9A22,
            64,
            |rng| {
                let edges = [0, 1, 2, 5, 63, 64, 65];
                let m = if rng.below(3) == 0 {
                    edges[rng.below(5) as usize] // 0, 1, 2, 5, 63
                } else {
                    rng.below(18) as usize
                };
                let k = if rng.below(3) == 0 {
                    edges[rng.below(edges.len() as u64) as usize]
                } else {
                    rng.below(70) as usize
                };
                let tiles = [1usize, 2, 3, 4, 5, 6, 7, 8, 16];
                let tile = tiles[rng.below(tiles.len() as u64) as usize];
                (m, k, tile, rng.next_u64())
            },
            |&(m, k, tile, seed)| {
                let mut rng = crate::util::prng::Rng::new(seed);
                let x: Vec<f32> = (0..m * k)
                    .map(|_| (rng.normal() * 4.0) as f32)
                    .collect();
                let arith = FixedMicro::new(FixedPoint::new(6, 8));
                check_all_for_tile(tile, &arith, &x, m, k)
            },
        );
    }

    #[test]
    fn explicit_tile_edges() {
        // n = 1 against every tile width, plus the empty shapes, which
        // the randomized sweep only samples
        let arith = FixedMicro::new(FixedPoint::new(6, 8));
        for tile in [1usize, 2, 3, 4, 5, 6, 7, 8, 16] {
            check_all_for_tile(tile, &arith, &[0.5], 1, 1).unwrap();
            check_all_for_tile(tile, &arith, &[], 0, 3).unwrap();
            check_all_for_tile(tile, &arith, &[], 3, 0).unwrap();
            check_all_for_tile(tile, &arith, &[], 0, 0).unwrap();
        }
    }

    #[test]
    fn global_counter_sees_other_threads() {
        // Two B-side packs on a spawned thread: invisible to this
        // thread's local counter, visible to the global one.  Only a
        // lower bound is asserted on the global delta — sibling tests
        // in this binary run concurrently and also pack.
        let l0 = weight_pack_count();
        let g0 = weight_pack_count_global();
        std::thread::spawn(|| {
            let _ = pack_b_block::<F32Micro, 4>(&F32Micro, &[1.0; 8],
                                                2, 4);
            let _ = pack_b_bits::<4>(&[1.0; 8], 2, 4);
        })
        .join()
        .unwrap();
        assert_eq!(weight_pack_count(), l0,
                   "local counter must not see the other thread");
        assert!(weight_pack_count_global() >= g0 + 2);
    }
}
