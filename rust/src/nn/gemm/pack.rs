//! Operand packing: reorder A into MR-row panels and B into NR-column
//! panels, fusing the provider's operand conditioning (quantize /
//! encode / DRUM-condition / CFPU-classify) into the copy so the
//! microkernel reads conditioned elements at unit stride.
//!
//! Panel layout, after the rten/BLIS convention:
//!
//! ```text
//! A (m x k), MR-row panels:        B (k x n), NR-column panels:
//!   panel p covers rows              panel q covers cols
//!   [p*MR, p*MR + MR)                [q*NR, q*NR + NR)
//!   offset(p, d, r) =                offset(q, d, c) =
//!     p*MR*k + d*MR + r                q*NR*k + d*NR + c
//! ```
//!
//! Because depth is the middle axis, the slice a microkernel needs for
//! a (panel, depth-block) pair is contiguous: `p*MR*k + d0*MR ..
//! p*MR*k + d1*MR`.  The Goto-style KC blocking in `kernel` is
//! therefore pure loop structure over one packed buffer — operands are
//! packed (and conditioned) exactly once, keeping conditioning at
//! O(mk + kn).
//!
//! Rows past `m` / columns past `n` in the trailing panel pad with
//! `MicroArith::zero_elem`, which is absorbing in `mul_acc`; padded
//! outputs are computed into the accumulator tile but never stored.

use super::micro::MicroArith;

/// Pack all of row-major `x` (`m` x `k`, row stride `k`) into MR-row
/// panels, conditioning each element.  Returns
/// `m.div_ceil(MR) * MR * k` elements.
pub fn pack_a_block<A: MicroArith, const MR: usize>(
    arith: &A, x: &[f32], m: usize, k: usize,
) -> Vec<A::Elem> {
    let panels = m.div_ceil(MR);
    let mut out = vec![arith.zero_elem(); panels * MR * k];
    for p in 0..panels {
        let base = p * MR * k;
        let r_hi = (p * MR + MR).min(m);
        for (ri, r) in (p * MR..r_hi).enumerate() {
            let xrow = &x[r * k..(r + 1) * k];
            for (d, &v) in xrow.iter().enumerate() {
                out[base + d * MR + ri] = arith.condition(v);
            }
        }
    }
    out
}

/// Pack all of row-major `w` (`k` x `n`, row stride `n`) into NR-column
/// panels, conditioning each element.  Returns
/// `n.div_ceil(NR) * NR * k` elements.
pub fn pack_b_block<A: MicroArith, const NR: usize>(
    arith: &A, w: &[f32], k: usize, n: usize,
) -> Vec<A::Elem> {
    let panels = n.div_ceil(NR);
    let mut out = vec![arith.zero_elem(); panels * NR * k];
    for d in 0..k {
        let wrow = &w[d * n..(d + 1) * n];
        for q in 0..panels {
            let base = q * NR * k + d * NR;
            let c_hi = (q * NR + NR).min(n);
            for (ci, c) in (q * NR..c_hi).enumerate() {
                out[base + ci] = arith.condition(wrow[c]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::gemm::micro::F32Micro;

    #[test]
    fn a_panel_layout_and_padding() {
        // 3 x 2 matrix with MR = 2: panel 0 = rows {0, 1}, panel 1 =
        // row 2 + one padded row.
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = pack_a_block::<F32Micro, 2>(&F32Micro, &x, 3, 2);
        assert_eq!(p.len(), 2 * 2 * 2);
        // panel 0, depth 0: rows 0..2 of column 0
        assert_eq!(&p[0..2], &[1.0, 3.0]);
        // panel 0, depth 1: rows 0..2 of column 1
        assert_eq!(&p[2..4], &[2.0, 4.0]);
        // panel 1: row 2 then zero padding, per depth
        assert_eq!(&p[4..8], &[5.0, 0.0, 6.0, 0.0]);
    }

    #[test]
    fn b_panel_layout_and_padding() {
        // 2 x 3 matrix with NR = 2: panel 0 = cols {0, 1}, panel 1 =
        // col 2 + one padded column.
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = pack_b_block::<F32Micro, 2>(&F32Micro, &w, 2, 3);
        assert_eq!(p.len(), 2 * 2 * 2);
        // panel 0: (d=0: cols 0,1), (d=1: cols 0,1)
        assert_eq!(&p[0..4], &[1.0, 2.0, 4.0, 5.0]);
        // panel 1: (d=0: col 2, pad), (d=1: col 2, pad)
        assert_eq!(&p[4..8], &[3.0, 0.0, 6.0, 0.0]);
    }

    #[test]
    fn zero_depth_packs_empty() {
        let p = pack_a_block::<F32Micro, 4>(&F32Micro, &[], 0, 0);
        assert!(p.is_empty());
        let q = pack_b_block::<F32Micro, 4>(&F32Micro, &[], 0, 5);
        assert!(q.is_empty());
    }
}
