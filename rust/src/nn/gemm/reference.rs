//! The pre-tiling GEMM kernels, kept verbatim as the *oracle* for the
//! packed/tiled path: unpacked, row-parallel, conditioning hoisted out
//! of the MAC loop (EXPERIMENTS.md §Perf iterations 1–4), but no panel
//! packing and no cache blocking.
//!
//! `tests/gemm_differential.rs` asserts the packed kernels are
//! bit-identical to these across randomized shapes and thread counts;
//! the unit tests in `gemm::tests` in turn pin these against the
//! scalar `ArithKind::quantize` + `mul_wide` semantics (with the f64
//! tolerance that f32-rounded scalar quantization requires), and the
//! CFPU conditioning shared with the packed path is property-pinned
//! against `CfpuMul::mul_bits` in `gemm::micro::tests`.  Never
//! optimize this module — its value is being boring.

use super::micro::{cfpu_product, condition_cfpu, CfpuOp};
use crate::approx::arith::ArithKind;
use crate::approx::cfpu::CfpuMul;
use crate::approx::drum::{drum_approx_operand, DrumMul};
use crate::numeric::{BinXnor, FixedPoint, FloatRep};

/// `out = quant(x) @ w` with the pre-tiling kernels.  Same contract as
/// [`super::gemm`]: `w` pre-quantized, `out.len() == m * n`.
pub fn gemm_reference(kind: &ArithKind, x: &[f32], w: &[f32], m: usize,
                      k: usize, n: usize, out: &mut [f32],
                      threads: usize) {
    assert_eq!(x.len(), m * k, "x shape mismatch");
    assert_eq!(w.len(), k * n, "w shape mismatch");
    assert_eq!(out.len(), m * n, "out shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    match kind {
        ArithKind::Float32 => gemm_f32(x, w, m, k, n, out, threads),
        ArithKind::FixedExact(rep) => {
            let xc = encode_fixed(rep, x);
            let wc = encode_fixed(rep, w);
            gemm_int(&xc, &wc, m, k, n, out, 2 * rep.f_bits, threads);
        }
        ArithKind::FixedDrum(d) => {
            let xc = encode_fixed_drum(d, x);
            let wc = encode_fixed_drum(d, w);
            gemm_int(&xc, &wc, m, k, n, out, 2 * d.rep.f_bits, threads);
        }
        ArithKind::FloatExact(rep) => {
            let xq = quantize_f64(rep, x);
            let wq = quantize_f64(rep, w);
            gemm_f64(&xq, &wq, m, k, n, out, threads);
        }
        ArithKind::FloatCfpu(c) => {
            gemm_cfpu(c, x, w, m, k, n, out, threads);
        }
        ArithKind::Binary => gemm_binary(x, w, m, k, n, out, threads),
    }
}

/// Split `out` into row chunks and run `body(row0, rows_chunk)` on a
/// scoped thread pool.
fn row_parallel<F>(out: &mut [f32], m: usize, n: usize, threads: usize,
                   body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let threads =
        if threads == 0 { super::default_threads() } else { threads };
    let threads = threads.min(m.max(1));
    if threads <= 1 || m * n < 16 * 1024 {
        body(0, out);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
            let body = &body;
            s.spawn(move || body(t * rows_per, chunk));
        }
    });
}

fn gemm_f32(x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
            out: &mut [f32], threads: usize) {
    row_parallel(out, m, n, threads, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let xrow = &x[(row0 + r) * k..(row0 + r + 1) * k];
            orow.fill(0.0);
            // (i,k,j) loop order: stream w rows, accumulate into out
            // row — autovectorizes on the j axis.
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[kk * n..(kk + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
    });
}

/// Signed magnitude code: sign(x) * code_of(|x|); fits i32 for
/// i + f <= 30.
fn encode_fixed(rep: &FixedPoint, xs: &[f32]) -> Vec<i32> {
    xs.iter()
        .map(|&x| {
            let k = rep.code_of(x) as i32;
            if x < 0.0 {
                -k
            } else {
                k
            }
        })
        .collect()
}

/// Signed DRUM-conditioned code (conditioning commutes with the
/// product, so hoisting is exact).
fn encode_fixed_drum(d: &DrumMul, xs: &[f32]) -> Vec<i32> {
    xs.iter()
        .map(|&x| {
            let k = drum_approx_operand(d.rep.code_of(x), d.t) as i32;
            if x < 0.0 {
                -k
            } else {
                k
            }
        })
        .collect()
}

/// Integer GEMM over signed codes with i64 accumulation; result scaled
/// by 2^-frac2 (`frac2 = 2f`: products carry doubled fractional bits).
fn gemm_int(xc: &[i32], wc: &[i32], m: usize, k: usize, n: usize,
            out: &mut [f32], frac2: u32, threads: usize) {
    let inv = 1.0f64 / (1u64 << frac2) as f64;
    row_parallel(out, m, n, threads, |row0, chunk| {
        let mut acc = vec![0i64; n];
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            acc.fill(0);
            let xrow = &xc[(row0 + r) * k..(row0 + r + 1) * k];
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0 {
                    continue;
                }
                let xv = xv as i64;
                let wrow = &wc[kk * n..(kk + 1) * n];
                for (a, &wv) in acc.iter_mut().zip(wrow) {
                    *a += xv * wv as i64;
                }
            }
            for (o, &a) in orow.iter_mut().zip(&acc) {
                *o = (a as f64 * inv) as f32;
            }
        }
    });
}

fn quantize_f64(rep: &FloatRep, xs: &[f32]) -> Vec<f64> {
    xs.iter().map(|&x| rep.quantize_f64(x as f64)).collect()
}

fn gemm_f64(xq: &[f64], wq: &[f64], m: usize, k: usize, n: usize,
            out: &mut [f32], threads: usize) {
    row_parallel(out, m, n, threads, |row0, chunk| {
        let mut acc = vec![0f64; n];
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            acc.fill(0.0);
            let xrow = &xq[(row0 + r) * k..(row0 + r + 1) * k];
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &wq[kk * n..(kk + 1) * n];
                for (a, &wv) in acc.iter_mut().zip(wrow) {
                    *a += xv * wv;
                }
            }
            for (o, &a) in orow.iter_mut().zip(&acc) {
                *o = a as f32;
            }
        }
    });
}

fn gemm_cfpu(c: &CfpuMul, xs: &[f32], ws: &[f32], m: usize, k: usize,
             n: usize, out: &mut [f32], threads: usize) {
    let xo: Vec<CfpuOp> =
        xs.iter().map(|&x| condition_cfpu(c, x)).collect();
    let wo: Vec<CfpuOp> =
        ws.iter().map(|&x| condition_cfpu(c, x)).collect();
    row_parallel(out, m, n, threads, |row0, chunk| {
        let mut acc = vec![0f64; n];
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            acc.fill(0.0);
            let xrow = &xo[(row0 + r) * k..(row0 + r + 1) * k];
            for (kk, xv) in xrow.iter().enumerate() {
                if xv.dec == 0.0 {
                    continue;
                }
                let wrow = &wo[kk * n..(kk + 1) * n];
                for (a, wv) in acc.iter_mut().zip(wrow) {
                    *a += cfpu_product(c, xv, wv);
                }
            }
            for (o, &a) in orow.iter_mut().zip(&acc) {
                *o = a as f32;
            }
        }
    });
}

/// Bit-packed popcount GEMM for the binary representation (paper
/// §4.5) — unpacked-per-output variant.
fn gemm_binary(x: &[f32], w: &[f32], m: usize, k: usize, n: usize,
               out: &mut [f32], threads: usize) {
    let words = k.div_ceil(64);
    // pack x rows and w columns as sign bitmaps
    let mut xp = vec![0u64; m * words];
    for r in 0..m {
        for kk in 0..k {
            let bit = BinXnor::binarize(x[r * k + kk]);
            xp[r * words + kk / 64] |= bit << (kk % 64);
        }
    }
    let mut wp = vec![0u64; n * words];
    for j in 0..n {
        for kk in 0..k {
            let bit = BinXnor::binarize(w[kk * n + j]);
            wp[j * words + kk / 64] |= bit << (kk % 64);
        }
    }
    // tail mask: bits >= k in the last word must not count as
    // agreements
    let tail_bits = k % 64;
    let tail_mask =
        if tail_bits == 0 { u64::MAX } else { (1u64 << tail_bits) - 1 };
    row_parallel(out, m, n, threads, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let xr = &xp[(row0 + r) * words..(row0 + r + 1) * words];
            for (j, o) in orow.iter_mut().enumerate() {
                let wc = &wp[j * words..(j + 1) * words];
                let mut agree = 0u32;
                for ww in 0..words {
                    let mut eq = !(xr[ww] ^ wc[ww]);
                    if ww == words - 1 {
                        eq &= tail_mask;
                    }
                    agree += eq.count_ones();
                }
                // dot of ±1 vectors = agreements - disagreements
                *o = (2 * agree as i64 - k as i64) as f32;
            }
        }
    });
}
