//! Per-provider microkernel arithmetic.  [`MicroArith`] binds, for one
//! `ArithKind` variant, the packed element type, the wide accumulator
//! type, and the operand conditioning that `pack` fuses into panel
//! construction.  Each impl monomorphizes the blocked driver and the
//! MR x NR register-tile microkernel in `kernel` into straight-line MAC
//! code — no dispatch inside MAC loops, same discipline as the
//! pre-tiled kernels (EXPERIMENTS.md §Perf iteration 1).
//!
//! Bit-exactness contract (enforced by `tests/gemm_differential.rs`):
//! for every output element, the packed path applies `condition` to the
//! same operands and folds the products with `mul_acc` in strictly
//! increasing k order into a single wide accumulator, converting once
//! with `finish` — exactly what the `reference` kernels do.  Integer
//! accumulation is associative so tiling is trivially exact; for the
//! float accumulators the k order is what makes tiling bit-exact.

use crate::approx::cfpu::CfpuMul;
use crate::approx::drum::{drum_approx_operand, DrumMul};
use crate::numeric::float::exp2i;
use crate::numeric::{FixedPoint, FloatRep, Representation};

/// Arithmetic plugged into the blocked driver and microkernel.  One
/// monomorphization per `ArithKind` variant; the bit-packed binary/XNOR
/// path has its own dedicated kernel (`kernel::BinaryKernel`) because
/// its packing is along k (64 operands per word), not per element.
pub trait MicroArith: Copy + Send + Sync {
    /// Packed operand: the conditioned form of one f32 input.
    /// (`'static` because prepacked weight panels are stored behind
    /// `dyn Any` in [`super::kernel::PackedWeights`].)
    type Elem: Copy + Send + Sync + 'static;
    /// Wide accumulator carried across the *entire* k reduction (the
    /// paper widens the partial-sum datapath, §4.2 — nothing narrows
    /// until `finish`).
    type Acc: Copy + Send + Sync;

    /// Kernel name for plans/logs, e.g. `packed-fi`.
    fn name(&self) -> &'static str;

    /// Stable fingerprint of this provider's full parameterization
    /// (representation widths, approximation windows).  Two providers
    /// with the same `name` but different parameters — e.g. FI(6, 8)
    /// vs FI(3, 4) — must return different tags: `run_prepacked`
    /// refuses weight panels whose tag does not match, so panels
    /// conditioned under one configuration can never be silently
    /// reused under another.
    fn cfg_tag(&self) -> u64;

    /// Operand conditioning fused into packing: quantize / encode /
    /// DRUM-condition / CFPU-classify, hoisted to O(mk + kn) total.
    fn condition(&self, x: f32) -> Self::Elem;

    /// Panel padding element; `mul_acc(pad, b, acc)` must return `acc`
    /// bit-for-bit (padded rows/cols are never stored, but the float
    /// accumulators must not be perturbed by a stray `-0.0`).
    fn zero_elem(&self) -> Self::Elem;

    /// The zero accumulator.
    fn zero_acc(&self) -> Self::Acc;

    /// One MAC through the provider's datapath: `acc + a * b` at full
    /// width.
    fn mul_acc(&self, a: Self::Elem, b: Self::Elem, acc: Self::Acc)
               -> Self::Acc;

    /// Convert the wide accumulator to the f32 output element.
    fn finish(&self, acc: Self::Acc) -> f32;
}

// ---------------------------------------------------------------------------
// float32 baseline: f32 elements, f32 accumulation (matches the PJRT
// artifacts' f32-accumulation semantics)
// ---------------------------------------------------------------------------

/// `ArithKind::Float32`.
#[derive(Clone, Copy, Debug)]
pub struct F32Micro;

impl MicroArith for F32Micro {
    type Elem = f32;
    type Acc = f32;

    fn name(&self) -> &'static str {
        "packed-f32"
    }

    fn cfg_tag(&self) -> u64 {
        0x01
    }

    #[inline(always)]
    fn condition(&self, x: f32) -> f32 {
        x
    }

    #[inline(always)]
    fn zero_elem(&self) -> f32 {
        0.0
    }

    #[inline(always)]
    fn zero_acc(&self) -> f32 {
        0.0
    }

    #[inline(always)]
    fn mul_acc(&self, a: f32, b: f32, acc: f32) -> f32 {
        acc + a * b
    }

    #[inline(always)]
    fn finish(&self, acc: f32) -> f32 {
        acc
    }
}

// ---------------------------------------------------------------------------
// fixed-point code paths: signed i32 codes, i64 accumulation
// ---------------------------------------------------------------------------

/// `ArithKind::FixedExact`: signed magnitude code, exact i64 MACs,
/// result scaled by 2^-2f (products carry doubled fractional bits).
#[derive(Clone, Copy, Debug)]
pub struct FixedMicro {
    rep: FixedPoint,
    /// 2^-(2 f_bits), the product scale applied once in `finish`.
    inv: f64,
}

impl FixedMicro {
    pub fn new(rep: FixedPoint) -> FixedMicro {
        FixedMicro { rep, inv: 1.0 / (1u64 << (2 * rep.f_bits)) as f64 }
    }
}

/// Signed magnitude code: sign(x) * code_of(|x|); fits i32 for
/// i + f <= 30 (`FixedPoint::MAX_TOTAL`).
#[inline(always)]
fn signed_code(rep: &FixedPoint, x: f32) -> i32 {
    let k = rep.code_of(x) as i32;
    if x < 0.0 {
        -k
    } else {
        k
    }
}

impl MicroArith for FixedMicro {
    type Elem = i32;
    type Acc = i64;

    fn name(&self) -> &'static str {
        "packed-fi"
    }

    fn cfg_tag(&self) -> u64 {
        0x02 | ((self.rep.i_bits as u64) << 8)
            | ((self.rep.f_bits as u64) << 16)
    }

    #[inline(always)]
    fn condition(&self, x: f32) -> i32 {
        signed_code(&self.rep, x)
    }

    #[inline(always)]
    fn zero_elem(&self) -> i32 {
        0
    }

    #[inline(always)]
    fn zero_acc(&self) -> i64 {
        0
    }

    #[inline(always)]
    fn mul_acc(&self, a: i32, b: i32, acc: i64) -> i64 {
        acc + a as i64 * b as i64
    }

    #[inline(always)]
    fn finish(&self, acc: i64) -> f32 {
        (acc as f64 * self.inv) as f32
    }
}

/// `ArithKind::FixedDrum`: DRUM(t) conditioning folded into packing.
/// Conditioning commutes with the product (`drum_mul(a, b) =
/// approx(a) * approx(b)`), so hoisting it out of the MAC loop is
/// exact, not an approximation of the approximation.
#[derive(Clone, Copy, Debug)]
pub struct DrumMicro {
    rep: FixedPoint,
    t: u32,
    inv: f64,
}

impl DrumMicro {
    pub fn new(d: DrumMul) -> DrumMicro {
        DrumMicro {
            rep: d.rep,
            t: d.t,
            inv: 1.0 / (1u64 << (2 * d.rep.f_bits)) as f64,
        }
    }
}

impl MicroArith for DrumMicro {
    type Elem = i32;
    type Acc = i64;

    fn name(&self) -> &'static str {
        "packed-drum"
    }

    fn cfg_tag(&self) -> u64 {
        0x03 | ((self.rep.i_bits as u64) << 8)
            | ((self.rep.f_bits as u64) << 16)
            | ((self.t as u64) << 24)
    }

    #[inline(always)]
    fn condition(&self, x: f32) -> i32 {
        let k = drum_approx_operand(self.rep.code_of(x), self.t) as i32;
        if x < 0.0 {
            -k
        } else {
            k
        }
    }

    #[inline(always)]
    fn zero_elem(&self) -> i32 {
        0
    }

    #[inline(always)]
    fn zero_acc(&self) -> i64 {
        0
    }

    #[inline(always)]
    fn mul_acc(&self, a: i32, b: i32, acc: i64) -> i64 {
        acc + a as i64 * b as i64
    }

    #[inline(always)]
    fn finish(&self, acc: i64) -> f32 {
        (acc as f64 * self.inv) as f32
    }
}

// ---------------------------------------------------------------------------
// float lattice paths: f64 elements / f64 accumulation
// ---------------------------------------------------------------------------

/// `ArithKind::FloatExact`: operands snapped onto the FL(e, m) lattice
/// once, exact f64 MACs.
#[derive(Clone, Copy, Debug)]
pub struct FloatMicro {
    rep: FloatRep,
}

impl FloatMicro {
    pub fn new(rep: FloatRep) -> FloatMicro {
        FloatMicro { rep }
    }
}

impl MicroArith for FloatMicro {
    type Elem = f64;
    type Acc = f64;

    fn name(&self) -> &'static str {
        "packed-fl"
    }

    fn cfg_tag(&self) -> u64 {
        0x04 | ((self.rep.e_bits as u64) << 8)
            | ((self.rep.m_bits as u64) << 16)
    }

    #[inline(always)]
    fn condition(&self, x: f32) -> f64 {
        self.rep.quantize_f64(x as f64)
    }

    #[inline(always)]
    fn zero_elem(&self) -> f64 {
        0.0
    }

    #[inline(always)]
    fn zero_acc(&self) -> f64 {
        0.0
    }

    #[inline(always)]
    fn mul_acc(&self, a: f64, b: f64, acc: f64) -> f64 {
        acc + a * b
    }

    #[inline(always)]
    fn finish(&self, acc: f64) -> f32 {
        acc as f32
    }
}

// ---------------------------------------------------------------------------
// CFPU path: pre-classified operands (§Perf iteration 4)
// ---------------------------------------------------------------------------

/// Pre-conditioned CFPU operand: field extraction, top-w classification
/// and the power-of-two exponent factor are hoisted out of the MAC
/// loop, so the inner loop is a 3-way class dispatch with one multiply
/// on the approximate paths and a bit-trick re-quantization on the
/// exact-fallback path.
#[derive(Clone, Copy, Debug)]
pub struct CfpuOp {
    /// decoded signed value (0.0 for the zero encoding)
    pub dec: f64,
    /// 2^(unbiased exponent) — the factor the skip path multiplies by
    pub pow: f64,
    /// 0: top-w mantissa bits all zero (operand ~ 2^e, round down)
    /// 1: all one (operand ~ 2^(e+1), round up)
    /// 2: neither -> exact multiply path
    pub class: u8,
}

/// Condition one operand for the CFPU inner loop.  `micro::tests` pins
/// `cfpu_product` over conditioned operands against the scalar
/// `CfpuMul::mul_bits` bit-for-bit.
#[inline]
pub fn condition_cfpu(c: &CfpuMul, x: f32) -> CfpuOp {
    let (e, m) = (c.rep.e_bits, c.rep.m_bits);
    let man_mask = (1u64 << m) - 1;
    let bias = c.rep.bias();
    let bits = c.rep.encode(x);
    let field = ((bits >> m) & ((1u64 << e) - 1)) as i32;
    if field == 0 {
        return CfpuOp { dec: 0.0, pow: 0.0, class: 2 };
    }
    let man = bits & man_mask;
    let class = if c.w > m {
        2
    } else {
        let top = (1u64 << c.w) - 1;
        let t = (man >> (m - c.w)) & top;
        if t == 0 {
            0
        } else if t == top {
            1
        } else {
            2
        }
    };
    CfpuOp {
        dec: c.rep.decode(bits) as f64,
        pow: exp2i(field - bias),
        class,
    }
}

/// One CFPU product from pre-conditioned operands.  Matches
/// `CfpuMul::mul_bits` bit-for-bit (property-pinned in this module's
/// tests) — shared by the packed and `reference` paths, which is
/// deliberate: the differential suite isolates packing/tiling bugs,
/// while the semantic pin against the scalar unit lives here.
#[inline]
pub fn cfpu_product(c: &CfpuMul, x: &CfpuOp, w: &CfpuOp) -> f64 {
    if x.dec == 0.0 || w.dec == 0.0 {
        return 0.0;
    }
    // skip path: |kept| * 2^(dropped exponent) [ * 2 when rounding up ]
    let (val, sign_src) = match (w.class, x.class) {
        (0, _) => (x.dec.abs() * w.pow, x.dec * w.dec),
        (1, _) => (x.dec.abs() * w.pow * 2.0, x.dec * w.dec),
        (_, 0) => (w.dec.abs() * x.pow, x.dec * w.dec),
        (_, 1) => (w.dec.abs() * x.pow * 2.0, x.dec * w.dec),
        _ => {
            // exact fallback: multiply + RNE re-quantization
            return c.rep.quantize_f64(x.dec * w.dec);
        }
    };
    let clamped = cfpu_clamp(c, val);
    if sign_src < 0.0 {
        -clamped
    } else {
        clamped
    }
}

#[inline]
fn cfpu_clamp(c: &CfpuMul, y: f64) -> f64 {
    let mx = c.rep.max_finite();
    if y > mx {
        return mx;
    }
    let mn = c.rep.min_normal();
    if y < mn {
        return if y * 2.0 >= mn { mn } else { 0.0 };
    }
    y
}

/// `ArithKind::FloatCfpu`.
#[derive(Clone, Copy, Debug)]
pub struct CfpuMicro {
    c: CfpuMul,
}

impl CfpuMicro {
    pub fn new(c: CfpuMul) -> CfpuMicro {
        CfpuMicro { c }
    }
}

impl MicroArith for CfpuMicro {
    type Elem = CfpuOp;
    type Acc = f64;

    fn name(&self) -> &'static str {
        "packed-cfpu"
    }

    fn cfg_tag(&self) -> u64 {
        0x05 | ((self.c.rep.e_bits as u64) << 8)
            | ((self.c.rep.m_bits as u64) << 16)
            | ((self.c.w as u64) << 24)
    }

    #[inline(always)]
    fn condition(&self, x: f32) -> CfpuOp {
        condition_cfpu(&self.c, x)
    }

    #[inline(always)]
    fn zero_elem(&self) -> CfpuOp {
        CfpuOp { dec: 0.0, pow: 0.0, class: 2 }
    }

    #[inline(always)]
    fn zero_acc(&self) -> f64 {
        0.0
    }

    #[inline(always)]
    fn mul_acc(&self, a: CfpuOp, b: CfpuOp, acc: f64) -> f64 {
        acc + cfpu_product(&self.c, &a, &b)
    }

    #[inline(always)]
    fn finish(&self, acc: f64) -> f32 {
        acc as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn prop_cfpu_product_matches_scalar_unit() {
        // The conditioned-operand product must reproduce the scalar
        // CFPU datapath bit-for-bit — this is the semantic anchor the
        // packed and reference GEMM paths both stand on.
        prop::check_msg(
            "cfpu_product == CfpuMul::mul_bits",
            61,
            prop::DEFAULT_CASES,
            |rng| {
                let e = 2 + rng.below(6) as u32;
                let m = 1 + rng.below(14) as u32;
                let w = 1 + rng.below(5) as u32;
                let c = CfpuMul::new(FloatRep::new(e, m), w);
                let x = (rng.normal() * 8.0) as f32;
                let y = (rng.normal() * 8.0) as f32;
                (c, x, y)
            },
            |(c, x, y)| {
                let want = c.mul_bits(c.rep.encode(*x), c.rep.encode(*y));
                let got = cfpu_product(
                    c,
                    &condition_cfpu(c, *x),
                    &condition_cfpu(c, *y),
                ) as f32;
                if got.to_bits() == want.to_bits()
                    || (got == 0.0 && want == 0.0)
                {
                    Ok(())
                } else {
                    Err(format!("got {got}, want {want}"))
                }
            },
        );
    }

    #[test]
    fn conditioning_commutes_for_drum() {
        // drum_mul(a, b) == approx(a) * approx(b): packing-time
        // conditioning is exact for the H paths.
        let d = DrumMul::new(FixedPoint::new(6, 8), 6);
        let micro = DrumMicro::new(d);
        for (x, y) in [(1.5f32, 2.75f32), (-3.2, 0.4), (60.0, -60.0)] {
            let ka = d.rep.code_of(x);
            let kb = d.rep.code_of(y);
            let via_unit = d.mul_codes(ka, kb);
            let a = micro.condition(x).unsigned_abs() as u64;
            let b = micro.condition(y).unsigned_abs() as u64;
            assert_eq!(a * b, via_unit, "x={x} y={y}");
        }
    }

    #[test]
    fn zero_elem_is_absorbing() {
        let f = FixedMicro::new(FixedPoint::new(6, 8));
        assert_eq!(f.mul_acc(f.zero_elem(), 123, 77), 77);
        let g = F32Micro;
        let acc = 1.25f32;
        assert_eq!(g.mul_acc(g.zero_elem(), -3.0, acc).to_bits(),
                   acc.to_bits());
    }
}
