//! Layers: the GEMM-backed fully-connected layer plus elementwise /
//! structural ops (bias add, ReLU, 2x2 max pooling, softmax).  All
//! mirror `python/compile/model.py`; [`dense`] routes through the
//! packed, tiled kernel selected by the layer's `GemmPlan`, and every
//! elementwise tensor walk routes through [`super::vecmath`] (one
//! scalar definition per op, pass-counted).

use super::gemm::{Epilogue, GemmPlan};
use super::tensor::Tensor;
use super::vecmath;

/// Fully-connected layer: `x [m,k] @ w [k,n] + bias` on the packed
/// GEMM path (`w` pre-quantized, as `Model::prepare` produces), with
/// the bias fused into the GEMM's per-tile epilogue — no standalone
/// bias pass.  When the plan carries prepacked panels for `w`
/// (`Model::prepare` builds them), the weight side is served from the
/// cache — no per-call conditioning or packing.
pub fn dense(plan: &GemmPlan, x: &Tensor, w: &Tensor, bias: &[f32],
             threads: usize) -> Tensor {
    dense_with(plan, x, w, &Epilogue::Bias { bias }, threads)
}

/// [`dense`] with an explicit fused [`Epilogue`] — the model forward
/// loop uses this to fold bias + ReLU + requantize-for-the-consumer
/// into the GEMM's cache-resident tile store.
pub fn dense_with(plan: &GemmPlan, x: &Tensor, w: &Tensor,
                  ep: &Epilogue, threads: usize) -> Tensor {
    assert_eq!(x.ndim(), 2, "dense input must be [m, k]");
    assert_eq!(w.ndim(), 2, "dense weights must be [k, n]");
    let (m, k) = (x.shape[0], x.shape[1]);
    assert_eq!(w.shape[0], k, "dense weight rows != input cols");
    let n = w.shape[1];
    let mut out = Tensor::zeros(vec![m, n]);
    plan.run_cached_with(&x.data, &w.data, m, k, n, &mut out.data,
                         threads, ep);
    out
}

/// ReLU in place.
pub fn relu(t: &mut Tensor) {
    vecmath::relu_in_place(&mut t.data);
}

/// Add a per-channel bias to the last axis.
pub fn add_bias(t: &mut Tensor, bias: &[f32]) {
    let c = *t.shape.last().expect("bias needs >= 1 axis");
    assert_eq!(c, bias.len(), "bias length mismatch");
    vecmath::add_bias_in_place(&mut t.data, bias);
}

/// 2x2 max pooling, stride 2, [B,H,W,C] with even H and W.
pub fn maxpool2(x: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 4);
    let (b, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert!(h % 2 == 0 && w % 2 == 0, "maxpool2 needs even H, W");
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; b * oh * ow * c];
    for bi in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let src = ((bi * h + y) * w + xx) * c;
                let dst = ((bi * oh + y / 2) * ow + xx / 2) * c;
                for ch in 0..c {
                    let v = x.data[src + ch];
                    if v > out[dst + ch] {
                        out[dst + ch] = v;
                    }
                }
            }
        }
    }
    Tensor::new(vec![b, oh, ow, c], out)
}

/// Numerically-stable softmax over the last axis of a 2-D tensor
/// (routes through [`vecmath::softmax_in_place`]).
pub fn softmax(t: &Tensor) -> Tensor {
    assert_eq!(t.ndim(), 2);
    let mut out = t.data.clone();
    vecmath::softmax_in_place(&mut out, t.shape[1]);
    Tensor::new(t.shape.clone(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps() {
        let mut t = Tensor::new(vec![4], vec![-1.0, 0.0, 2.0, -0.5]);
        relu(&mut t);
        assert_eq!(t.data, vec![0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn dense_matmul_plus_bias() {
        use crate::approx::arith::ArithKind;
        let plan = GemmPlan::new(&ArithKind::Float32);
        let x = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let out = dense(&plan, &x, &w, &[10.0, 20.0], 1);
        assert_eq!(out.shape, vec![2, 2]);
        assert_eq!(out.data, vec![11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn bias_broadcasts_last_axis() {
        let mut t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        add_bias(&mut t, &[1.0, 2.0, 3.0]);
        assert_eq!(t.data, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn maxpool_matches_python_fixture() {
        // same as python test: arange(16) in [1,4,4,1] -> [[5,7],[13,15]]
        let t = Tensor::new(vec![1, 4, 4, 1],
                            (0..16).map(|v| v as f32).collect());
        let p = maxpool2(&t);
        assert_eq!(p.shape, vec![1, 2, 2, 1]);
        assert_eq!(p.data, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn maxpool_multichannel() {
        let mut d = vec![0.0f32; 2 * 2 * 2];
        d[0] = 9.0; // (0,0,c0)
        d[3 * 2 + 1] = 7.0; // (1,1,c1)
        let t = Tensor::new(vec![1, 2, 2, 2], d);
        let p = maxpool2(&t);
        assert_eq!(p.data, vec![9.0, 7.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = Tensor::new(vec![2, 3],
                            vec![1.0, 2.0, 3.0, -5.0, 0.0, 5.0]);
        let s = softmax(&t);
        for row in s.data.chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
            assert!(row.iter().all(|&v| v > 0.0));
        }
        // monotone: larger logit -> larger probability
        assert!(s.data[2] > s.data[1] && s.data[1] > s.data[0]);
    }
}
