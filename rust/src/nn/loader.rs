//! LOPW weight-file loader — reads `artifacts/weights.bin` written by
//! `python/compile/train.py::save_weights_bin`.
//!
//! Format: magic "LOPW", u32 version, u32 ntensors, then per tensor:
//! u32 name_len, name bytes, u32 ndim, u32 dims[ndim], f32 data (LE).

use super::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub const PARAM_NAMES: [&str; 8] = [
    "conv1_w", "conv1_b", "conv2_w", "conv2_b",
    "fc1_w", "fc1_b", "fc2_w", "fc2_b",
];

pub fn load_weights(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let raw = std::fs::read(path)
        .with_context(|| format!("reading weights from {path:?}"))?;
    parse_weights(&raw)
}

pub fn parse_weights(raw: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut off = 0usize;
    let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
        if *off + n > raw.len() {
            bail!("weights file truncated at byte {}", *off);
        }
        let s = &raw[*off..*off + n];
        *off += n;
        Ok(s)
    };
    let u32le = |off: &mut usize| -> Result<u32> {
        Ok(u32::from_le_bytes(take(off, 4)?.try_into().unwrap()))
    };

    if take(&mut off, 4)? != b"LOPW" {
        bail!("bad magic (expected LOPW)");
    }
    let ver = u32le(&mut off)?;
    if ver != 1 {
        bail!("unsupported LOPW version {ver}");
    }
    let ntensors = u32le(&mut off)? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..ntensors {
        let nlen = u32le(&mut off)? as usize;
        let name = String::from_utf8(take(&mut off, nlen)?.to_vec())
            .context("tensor name is not utf-8")?;
        let ndim = u32le(&mut off)? as usize;
        if ndim > 8 {
            bail!("implausible ndim {ndim} for tensor '{name}'");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(u32le(&mut off)? as usize);
        }
        let count: usize = dims.iter().product();
        let bytes = take(&mut off, count * 4)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.insert(name, Tensor::new(dims, data));
    }
    if off != raw.len() {
        bail!("{} trailing bytes in weights file", raw.len() - off);
    }
    Ok(out)
}

/// Validate the parameter set against the paper's Fig. 2 architecture
/// — a thin wrapper over the generic
/// [`NetSpec::validate_params`](super::spec::NetSpec::validate_params)
/// on the [`paper_dcnn`](super::spec::NetSpec::paper_dcnn) preset,
/// kept because the PJRT runner (whose AOT artifacts only implement
/// that topology) calls it by name.
pub fn validate_dcnn(params: &BTreeMap<String, Tensor>) -> Result<()> {
    super::spec::NetSpec::paper_dcnn().validate_params(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(tensors: &[(&str, Vec<usize>, Vec<f32>)]) -> Vec<u8> {
        let mut raw = b"LOPW".to_vec();
        raw.extend(1u32.to_le_bytes());
        raw.extend((tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            raw.extend((name.len() as u32).to_le_bytes());
            raw.extend(name.as_bytes());
            raw.extend((dims.len() as u32).to_le_bytes());
            for d in dims {
                raw.extend((*d as u32).to_le_bytes());
            }
            for v in data {
                raw.extend(v.to_le_bytes());
            }
        }
        raw
    }

    #[test]
    fn roundtrip() {
        let raw = encode(&[
            ("a", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            ("b", vec![3], vec![-1.0, 0.0, 1.0]),
        ]);
        let m = parse_weights(&raw).unwrap();
        assert_eq!(m["a"].shape, vec![2, 2]);
        assert_eq!(m["a"].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m["b"].data, vec![-1.0, 0.0, 1.0]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_weights(b"NOPE").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut raw = encode(&[("a", vec![4], vec![1.0, 2.0, 3.0, 4.0])]);
        raw.truncate(raw.len() - 3);
        assert!(parse_weights(&raw).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut raw = encode(&[("a", vec![1], vec![1.0])]);
        raw.push(0);
        assert!(parse_weights(&raw).is_err());
    }

    #[test]
    fn param_names_match_the_paper_spec() {
        // the artifact ordering contract the PJRT runner relies on:
        // PARAM_NAMES is exactly the paper spec's derived name list
        let from_spec =
            crate::nn::spec::NetSpec::paper_dcnn().param_names();
        let want: Vec<String> =
            PARAM_NAMES.iter().map(|s| s.to_string()).collect();
        assert_eq!(from_spec, want);
    }

    #[test]
    fn validates_architecture() {
        let raw = encode(&[("conv1_w", vec![5, 5, 1, 32],
                            vec![0.0; 800])]);
        let m = parse_weights(&raw).unwrap();
        assert!(validate_dcnn(&m).is_err()); // missing the rest
    }
}
