//! Lop: customized data representations + approximate computing for ML —
//! a three-layer Rust + JAX + Pallas reproduction of Nazemi & Pedram
//! (2018), "Deploying Customized Data Representation and Approximate
//! Computing in Machine Learning Applications".
//!
//! See `DESIGN.md` (repo root) for the architecture and module map, and
//! `EXPERIMENTS.md` for the paper-vs-measured methodology, the §Perf
//! optimization log the code comments cite, and how to regenerate every
//! reported number.  `README.md` covers building and running.
//!
//! The layer map, bottom to top:
//!
//! * [`numeric`] — customizable data representations (FI / FL / binary);
//! * [`approx`] — approximate arithmetic units (DRUM, CFPU, Mitchell,
//!   SSM, truncated multipliers, LOA adders) and the [`approx::ArithKind`]
//!   provider that pairs a representation with a multiplier;
//! * [`nn`] — the bit-accurate engine over arbitrary
//!   [`nn::spec::NetSpec`] topologies (the paper's DCNN is the
//!   [`nn::spec::NetSpec::paper_dcnn`] preset; [`nn::spec::ReprMap`]
//!   assigns one provider per layer), whose packed, cache-tiled GEMM
//!   kernels ([`nn::gemm::gemm`], selected per layer through
//!   [`nn::gemm::GemmPlan`]) are monomorphized per provider;
//! * [`hw`] — the analytical hardware cost model (Table 5 substitute for
//!   Quartus synthesis);
//! * [`runtime`] — the PJRT/XLA executor for exact-arithmetic configs
//!   (gated behind the `pjrt` feature, stubbed otherwise);
//! * [`coordinator`] — value-range profiling, accuracy evaluation, the
//!   §4.2 design-space explorer, and the serving stack
//!   (router → batcher → workers);
//! * [`telemetry`] — process-wide observability: the metric registry
//!   (counters / sequence-tagged gauges / lock-free log2 histograms),
//!   `LOP_TRACE`-gated stage spans over the request path, and
//!   versioned snapshot exporters (JSON artifact + Prometheus text);
//! * [`data`] / [`config`] / [`util`] / [`cli`] — substrates: datasets,
//!   TOML configs, PRNG/property-test/bench/JSON helpers, argument
//!   parsing.

#![deny(rustdoc::broken_intra_doc_links)]

pub mod approx;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod nn;
pub mod numeric;
pub mod runtime;
pub mod telemetry;
pub mod util;
