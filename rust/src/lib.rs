//! Lop: customized data representations + approximate computing for ML —
//! a three-layer Rust + JAX + Pallas reproduction of Nazemi & Pedram
//! (2018).  See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod approx;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod nn;
pub mod numeric;
pub mod runtime;
pub mod util;
