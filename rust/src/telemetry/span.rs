//! Stage-scoped spans over the request path.
//!
//! Every stop a request makes between `Router::submit` and its reply
//! has a [`Stage`] label; a [`Span`] is an RAII timer that records
//! its stage's elapsed microseconds on drop (including during a panic
//! unwind) into the global `stage.<name>_us` histogram *and* a
//! thread-local accumulator that lets the engine worker assemble a
//! per-request [`StageBreakdown`] without any shared state.
//!
//! Tracing is off by default: `Span::enter` then costs one relaxed
//! atomic load and takes no timestamp.  It turns on process-wide via
//! `LOP_TRACE=1` (read once, lazily) or [`set_trace`] from tests.
//!
//! Stage taxonomy (units: microseconds):
//!
//! | label            | covers                                        |
//! |------------------|-----------------------------------------------|
//! | `submit`         | `Router::submit` admission (policy + enqueue) |
//! | `queue_wait`     | admit -> batch release (per request)          |
//! | `batch_assemble` | gathering the released batch into a tensor    |
//! | `plan_lookup`    | `PlanCache` get-or-prepare for the config     |
//! | `gemm_pack`      | A/B panel packing inside the blocked driver   |
//! | `gemm_kernel`    | the blocked k-reduction macrokernel loops     |
//! | `gemm_epilogue`  | fused bias/ReLU/requantize finish sweeps      |
//! | `reply`          | delivering responses to waiting callers       |
//!
//! `submit` overlaps `queue_wait` (admission happens while the clock
//! on queueing starts) and `reply` lands after the end-to-end latency
//! stamp, so accounting identities over breakdowns should sum the six
//! interior stages only — the CI `telemetry-sanity` gate does.

use super::histogram::Histogram;
use super::registry::global;
use std::cell::Cell;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// One stop on the request path (see the module-level taxonomy table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Submit,
    QueueWait,
    BatchAssemble,
    PlanLookup,
    GemmPack,
    GemmKernel,
    GemmEpilogue,
    Reply,
}

/// Every stage, in request-path order.
pub const STAGES: [Stage; 8] = [
    Stage::Submit,
    Stage::QueueWait,
    Stage::BatchAssemble,
    Stage::PlanLookup,
    Stage::GemmPack,
    Stage::GemmKernel,
    Stage::GemmEpilogue,
    Stage::Reply,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Submit => "submit",
            Stage::QueueWait => "queue_wait",
            Stage::BatchAssemble => "batch_assemble",
            Stage::PlanLookup => "plan_lookup",
            Stage::GemmPack => "gemm_pack",
            Stage::GemmKernel => "gemm_kernel",
            Stage::GemmEpilogue => "gemm_epilogue",
            Stage::Reply => "reply",
        }
    }

    /// Registry name of this stage's global histogram.
    pub fn metric_name(self) -> &'static str {
        match self {
            Stage::Submit => "stage.submit_us",
            Stage::QueueWait => "stage.queue_wait_us",
            Stage::BatchAssemble => "stage.batch_assemble_us",
            Stage::PlanLookup => "stage.plan_lookup_us",
            Stage::GemmPack => "stage.gemm_pack_us",
            Stage::GemmKernel => "stage.gemm_kernel_us",
            Stage::GemmEpilogue => "stage.gemm_epilogue_us",
            Stage::Reply => "stage.reply_us",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

// 0 = uninitialized, 1 = off, 2 = on.  Lazily seeded from LOP_TRACE
// so library users never pay the env lookup unless a span site runs.
static TRACE: AtomicU8 = AtomicU8::new(0);

/// Is stage tracing on?  (`LOP_TRACE=1`, or forced via [`set_trace`].)
#[inline]
pub fn trace_enabled() -> bool {
    match TRACE.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let on = std::env::var("LOP_TRACE")
                .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
                .unwrap_or(false);
            TRACE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
            on
        }
    }
}

/// Force tracing on/off process-wide (tests, `serve` wiring).
pub fn set_trace(on: bool) {
    TRACE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

/// The global per-stage histograms, registered once.
fn stage_hist(stage: Stage) -> &'static Arc<Histogram> {
    static HISTS: OnceLock<[Arc<Histogram>; 8]> = OnceLock::new();
    let hists = HISTS.get_or_init(|| {
        std::array::from_fn(|i| global().histogram(STAGES[i].metric_name()))
    });
    &hists[stage.index()]
}

thread_local! {
    // Per-thread running total of traced microseconds per stage; the
    // engine worker diffs this around a batch to build breakdowns.
    static STAGE_SUMS: Cell<[u64; 8]> = const { Cell::new([0; 8]) };
}

/// Record `us` microseconds against `stage`: global histogram plus
/// the calling thread's breakdown accumulator.
pub fn record_stage(stage: Stage, us: u64) {
    stage_hist(stage).record(us);
    STAGE_SUMS.with(|c| {
        let mut sums = c.get();
        sums[stage.index()] += us;
        c.set(sums);
    });
}

/// This thread's cumulative traced microseconds, indexed like
/// [`STAGES`].  Diff two readings to attribute work done in between.
pub fn local_stage_sums() -> [u64; 8] {
    STAGE_SUMS.with(|c| c.get())
}

/// RAII stage timer: times its own drop scope when tracing is on,
/// does nothing (no timestamp taken) when off.  Records on unwind
/// too — a panicking batch still accounts its partial stages.
pub struct Span {
    stage: Stage,
    start: Option<Instant>,
}

impl Span {
    #[inline]
    pub fn enter(stage: Stage) -> Span {
        let start = if trace_enabled() { Some(Instant::now()) } else { None };
        Span { stage, start }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            record_stage(self.stage, t0.elapsed().as_micros() as u64);
        }
    }
}

/// Per-request stage attribution, attached to a `Response` when
/// tracing is on.  Stage order follows [`STAGES`]; only stages that
/// actually ran appear.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageBreakdown {
    pub stages: Vec<(&'static str, u64)>,
}

impl StageBreakdown {
    pub fn total_us(&self) -> u64 {
        self.stages.iter().map(|(_, us)| us).sum()
    }

    /// One-line rendering: `queue_wait=120us plan_lookup=4us ...`.
    pub fn render(&self) -> String {
        self.stages
            .iter()
            .map(|(name, us)| format!("{name}={us}us"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test owns the process-global trace flag: splitting the
    // off/on halves into separate #[test]s would race under the
    // parallel test runner.
    #[test]
    fn spans_gate_on_the_trace_flag() {
        set_trace(false);
        let before = stage_hist(Stage::Submit).count();
        {
            let _s = Span::enter(Stage::Submit);
        }
        assert_eq!(stage_hist(Stage::Submit).count(), before);
        assert!(!trace_enabled());

        set_trace(true);
        let hist_before = stage_hist(Stage::PlanLookup).count();
        let local_before = local_stage_sums();
        {
            let _s = Span::enter(Stage::PlanLookup);
        }
        {
            let _s = Span::enter(Stage::PlanLookup);
        }
        assert_eq!(stage_hist(Stage::PlanLookup).count(), hist_before + 2);
        let local_after = local_stage_sums();
        let i = Stage::PlanLookup.index();
        assert!(local_after[i] >= local_before[i]);
        for (j, (a, b)) in
            local_before.iter().zip(local_after.iter()).enumerate()
        {
            if j != i {
                assert_eq!(a, b, "stage {j} moved");
            }
        }
        set_trace(false);
    }

    #[test]
    fn stage_names_are_stable() {
        // the CI sanity gate and DESIGN.md both key on these strings
        let names: Vec<&str> = STAGES.iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            vec![
                "submit", "queue_wait", "batch_assemble", "plan_lookup",
                "gemm_pack", "gemm_kernel", "gemm_epilogue", "reply",
            ]
        );
        for s in STAGES {
            assert_eq!(s.metric_name(),
                       format!("stage.{}_us", s.name()).as_str());
        }
    }

    #[test]
    fn breakdown_totals_and_renders() {
        let b = StageBreakdown {
            stages: vec![("queue_wait", 120), ("gemm_kernel", 40)],
        };
        assert_eq!(b.total_us(), 160);
        assert_eq!(b.render(), "queue_wait=120us gemm_kernel=40us");
    }
}
