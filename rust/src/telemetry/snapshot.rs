//! Versioned registry exports: JSON artifact + Prometheus-style text.
//!
//! The JSON artifact uses the repo's standard bench shape
//! (`util::bench::write_bench_json`: `{"bench": "telemetry", "rows":
//! [{...}, ...]}`) so CI's sanity gates parse serving benches and
//! telemetry snapshots with the same code.  The first row is a meta
//! row carrying [`SCHEMA_VERSION`]; every following row is one metric
//! (`"kind"`: `"counter"` / `"gauge"` / `"histogram"`).  Histogram
//! rows embed the *cumulative* per-bucket counts — monotonicity of
//! that array is a cheap structural invariant the CI gate asserts.

use super::histogram::{Histogram, BUCKETS};
use crate::util::bench::write_bench_json;
use crate::util::json::Json;

/// Version of the snapshot row schema.  Bump when row fields change
/// meaning; the CI `telemetry-sanity` gate pins it.
pub const SCHEMA_VERSION: u64 = 1;

/// Point-in-time export of one histogram.
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub p50: u64,
    pub p99: u64,
    pub p999: u64,
    /// Cumulative bucket counts (`BUCKETS` entries, monotone
    /// non-decreasing; the last entry equals `count` once recording
    /// has quiesced).
    pub cumulative: Vec<u64>,
}

impl From<&Histogram> for HistogramSnapshot {
    fn from(h: &Histogram) -> HistogramSnapshot {
        let mut cumulative = Vec::with_capacity(BUCKETS);
        let mut cum = 0u64;
        for c in h.bucket_counts() {
            cum += c;
            cumulative.push(cum);
        }
        HistogramSnapshot {
            count: h.count(),
            sum: h.sum(),
            max: h.max_value(),
            p50: h.percentile(50.0),
            p99: h.percentile(99.0),
            p999: h.percentile(99.9),
            cumulative,
        }
    }
}

/// Exported value of one registered metric.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(u64),
    Histogram(HistogramSnapshot),
}

/// A versioned, name-ordered export of a [`super::Registry`].
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetrySnapshot {
    pub version: u64,
    pub entries: Vec<(String, MetricValue)>,
}

impl TelemetrySnapshot {
    pub fn new(entries: Vec<(String, MetricValue)>) -> TelemetrySnapshot {
        TelemetrySnapshot { version: SCHEMA_VERSION, entries }
    }

    /// Look up an exported value by metric name.
    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    /// Merge another snapshot into this one (e.g. a server's serving
    /// series plus the process-global registry's stage histograms),
    /// restoring deterministic name order.  `other` wins on a name
    /// clash.
    pub fn merged_with(mut self, other: TelemetrySnapshot)
                       -> TelemetrySnapshot {
        for (name, v) in other.entries {
            match self.entries.iter_mut().find(|(n, _)| *n == name) {
                Some(slot) => slot.1 = v,
                None => self.entries.push((name, v)),
            }
        }
        self.entries.sort_by(|a, b| a.0.cmp(&b.0));
        self
    }

    /// Serialize to `write_bench_json` row bodies (no braces): one
    /// meta row, then one row per metric.
    pub fn to_rows(&self) -> Vec<String> {
        let mut rows = Vec::with_capacity(self.entries.len() + 1);
        rows.push(format!(
            "\"name\": \"_meta\", \"kind\": \"meta\", \"version\": {}",
            self.version
        ));
        for (name, v) in &self.entries {
            rows.push(match v {
                MetricValue::Counter(c) => format!(
                    "\"name\": \"{name}\", \"kind\": \"counter\", \
                     \"value\": {c}"
                ),
                MetricValue::Gauge(g) => format!(
                    "\"name\": \"{name}\", \"kind\": \"gauge\", \
                     \"value\": {g}"
                ),
                MetricValue::Histogram(h) => {
                    let cum = h
                        .cumulative
                        .iter()
                        .map(|c| c.to_string())
                        .collect::<Vec<_>>()
                        .join(", ");
                    format!(
                        "\"name\": \"{name}\", \"kind\": \"histogram\", \
                         \"count\": {}, \"sum\": {}, \"max\": {}, \
                         \"p50\": {}, \"p99\": {}, \"p999\": {}, \
                         \"cumulative\": [{cum}]",
                        h.count, h.sum, h.max, h.p50, h.p99, h.p999
                    )
                }
            });
        }
        rows
    }

    /// The full artifact as an in-memory string (same layout
    /// `write_bench_json` writes to disk).
    pub fn to_json(&self) -> String {
        let rows = self.to_rows();
        let mut body =
            String::from("{\n  \"bench\": \"telemetry\",\n  \"rows\": [\n");
        for (i, row) in rows.iter().enumerate() {
            body.push_str(&format!(
                "    {{{row}}}{}\n",
                if i + 1 == rows.len() { "" } else { "," }
            ));
        }
        body.push_str("  ]\n}\n");
        body
    }

    /// Write the artifact to `default_path` (or `$<env_override>`)
    /// through the shared bench-JSON writer.
    pub fn write_json(&self, env_override: &str, default_path: &str) {
        write_bench_json("telemetry", env_override, default_path,
                         &self.to_rows());
    }

    /// Parse an artifact back (the JSON round-trip counterpart of
    /// [`TelemetrySnapshot::to_json`]).  Values survive exactly up to
    /// f64 integer precision (2^53), far above any latency count.
    pub fn from_json(s: &str) -> Result<TelemetrySnapshot, String> {
        let j = Json::parse(s).map_err(|e| e.to_string())?;
        let rows = j
            .get("rows")
            .and_then(|r| r.as_arr())
            .ok_or("no 'rows' array")?;
        let num = |row: &Json, key: &str| -> Result<u64, String> {
            row.get(key)
                .and_then(|v| v.as_f64())
                .map(|v| v as u64)
                .ok_or_else(|| format!("row missing numeric '{key}'"))
        };
        let mut version = None;
        let mut entries = Vec::new();
        for row in rows {
            let kind = row
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or("row missing 'kind'")?;
            if kind == "meta" {
                version = Some(num(row, "version")?);
                continue;
            }
            let name = row
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or("row missing 'name'")?
                .to_string();
            let v = match kind {
                "counter" => MetricValue::Counter(num(row, "value")?),
                "gauge" => MetricValue::Gauge(num(row, "value")?),
                "histogram" => {
                    let cumulative = row
                        .get("cumulative")
                        .and_then(|c| c.as_arr())
                        .ok_or("histogram row missing 'cumulative'")?
                        .iter()
                        .map(|v| v.as_f64().map(|f| f as u64))
                        .collect::<Option<Vec<u64>>>()
                        .ok_or("non-numeric cumulative entry")?;
                    MetricValue::Histogram(HistogramSnapshot {
                        count: num(row, "count")?,
                        sum: num(row, "sum")?,
                        max: num(row, "max")?,
                        p50: num(row, "p50")?,
                        p99: num(row, "p99")?,
                        p999: num(row, "p999")?,
                        cumulative,
                    })
                }
                other => return Err(format!("unknown row kind '{other}'")),
            };
            entries.push((name, v));
        }
        let version = version.ok_or("no meta row with a schema version")?;
        if version != SCHEMA_VERSION {
            return Err(format!(
                "snapshot schema version {version}, expected \
                 {SCHEMA_VERSION}"
            ));
        }
        Ok(TelemetrySnapshot { version, entries })
    }

    /// Prometheus-style exposition text.  Metric names are prefixed
    /// `lop_` with non-alphanumeric characters folded to `_`;
    /// histograms render as summaries (quantile labels plus
    /// `_sum`/`_count`/`_max` series).
    pub fn render_prometheus(&self) -> String {
        let sanitize = |name: &str| -> String {
            name.chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect()
        };
        let mut out = String::new();
        for (name, v) in &self.entries {
            let n = sanitize(name);
            match v {
                MetricValue::Counter(c) => {
                    out.push_str(&format!("lop_{n} {c}\n"));
                }
                MetricValue::Gauge(g) => {
                    out.push_str(&format!("lop_{n} {g}\n"));
                }
                MetricValue::Histogram(h) => {
                    for (q, val) in [
                        ("0.5", h.p50),
                        ("0.99", h.p99),
                        ("0.999", h.p999),
                    ] {
                        out.push_str(&format!(
                            "lop_{n}{{quantile=\"{q}\"}} {val}\n"
                        ));
                    }
                    out.push_str(&format!("lop_{n}_sum {}\n", h.sum));
                    out.push_str(&format!("lop_{n}_count {}\n", h.count));
                    out.push_str(&format!("lop_{n}_max {}\n", h.max));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Registry;

    fn sample() -> TelemetrySnapshot {
        let r = Registry::new();
        r.counter("serving.submitted").add(100);
        r.gauge("plan_cache.resident_panels").set_at(7, 3);
        let h = r.histogram("serving.latency_us");
        for v in [100u64, 200, 400, 800, 100_000] {
            h.record(v);
        }
        r.snapshot()
    }

    #[test]
    fn snapshot_reads_back_registered_values() {
        let s = sample();
        assert_eq!(s.version, SCHEMA_VERSION);
        assert_eq!(s.get("serving.submitted"),
                   Some(&MetricValue::Counter(100)));
        assert_eq!(s.get("plan_cache.resident_panels"),
                   Some(&MetricValue::Gauge(3)));
        match s.get("serving.latency_us") {
            Some(MetricValue::Histogram(h)) => {
                assert_eq!(h.count, 5);
                assert_eq!(h.max, 100_000);
                assert_eq!(h.p50, 512);
                assert_eq!(h.cumulative.len(), BUCKETS);
                assert_eq!(*h.cumulative.last().unwrap(), 5);
                assert!(h.cumulative.windows(2).all(|w| w[0] <= w[1]));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn merge_unions_and_overrides_by_name() {
        let a = TelemetrySnapshot::new(vec![
            ("b.one".into(), MetricValue::Counter(1)),
            ("d.two".into(), MetricValue::Gauge(2)),
        ]);
        let b = TelemetrySnapshot::new(vec![
            ("a.zero".into(), MetricValue::Counter(9)),
            ("b.one".into(), MetricValue::Counter(5)),
        ]);
        let m = a.merged_with(b);
        let names: Vec<&str> =
            m.entries.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["a.zero", "b.one", "d.two"]);
        assert_eq!(m.get("b.one"), Some(&MetricValue::Counter(5)));
    }

    #[test]
    fn json_round_trips() {
        let s = sample();
        let text = s.to_json();
        let back = TelemetrySnapshot::from_json(&text).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn from_json_rejects_versions_from_the_future() {
        let s = sample().to_json().replace(
            &format!("\"version\": {SCHEMA_VERSION}"),
            "\"version\": 999",
        );
        let err = TelemetrySnapshot::from_json(&s).unwrap_err();
        assert!(err.contains("999"), "{err}");
    }

    #[test]
    fn prometheus_render_has_every_series() {
        let text = sample().render_prometheus();
        assert!(text.contains("lop_serving_submitted 100"), "{text}");
        assert!(text.contains("lop_plan_cache_resident_panels 3"),
                "{text}");
        assert!(text.contains(
            "lop_serving_latency_us{quantile=\"0.5\"} 512"
        ), "{text}");
        assert!(text.contains("lop_serving_latency_us_count 5"), "{text}");
        assert!(text.contains("lop_serving_latency_us_max 100000"),
                "{text}");
    }
}
