//! Process-wide observability: registry, histograms, spans, snapshots.
//!
//! The serving/exploration stack measures representation tradeoffs for
//! a living, which makes its own instrumentation load-bearing: bench
//! tables, CI gates, and the `serve` loop must all agree on what "p99"
//! means.  This module is the single definition.
//!
//! * [`Histogram`] — a lock-free, fixed 64-bucket log2 latency
//!   histogram (atomic counters, mergeable per-thread
//!   [`LocalHistogram`] shards).  `max` is exact; any percentile
//!   read-out lands in `[true, 2*true)` — never an underestimate
//!   (see `histogram.rs` for the bound proof sketch).
//! * [`Registry`] — named counters / gauges / histograms handed out as
//!   `Arc` handles.  [`global()`] hosts genuinely process-wide series
//!   (GEMM pack counts, vecmath passes, `stage.*` span histograms);
//!   per-[`crate::coordinator::metrics::Metrics`] instances own a
//!   private registry so multiple servers in one process (tests!)
//!   don't cross-pollute.
//! * [`Span`] — stage-scoped RAII timers over the request path
//!   ([`Stage`] names every stop: submit, queue_wait, batch_assemble,
//!   plan_lookup, gemm_pack, gemm_kernel, gemm_epilogue, reply),
//!   env-gated by `LOP_TRACE=1` (or [`set_trace`] in tests) so the
//!   untraced hot path pays one relaxed atomic load per span site.
//! * [`TelemetrySnapshot`] — a versioned export of a registry: JSON
//!   artifact in the `util::bench::write_bench_json` shape (consumed
//!   by the CI `telemetry-sanity` gate) and a Prometheus-style text
//!   rendering (`serve --stats-every N`, shutdown summary).

mod histogram;
mod registry;
mod snapshot;
mod span;

pub use histogram::{
    bucket_index, bucket_upper_bound, Histogram, LocalHistogram, BUCKETS,
};
pub use registry::{global, Counter, Gauge, Metric, Registry};
pub use snapshot::{
    HistogramSnapshot, MetricValue, TelemetrySnapshot, SCHEMA_VERSION,
};
pub use span::{
    local_stage_sums, record_stage, set_trace, trace_enabled, Span, Stage,
    StageBreakdown, STAGES,
};
