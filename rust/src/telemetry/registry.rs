//! Named metric registry: counters, gauges, histograms as `Arc` handles.
//!
//! A registry is a name -> metric map behind a mutex; the mutex guards
//! only registration and snapshotting, never the update path — handles
//! are `Arc`s onto lock-free (counter/histogram) or tiny-critical-
//! section (gauge) state, so callers register once and update forever
//! without touching the map.
//!
//! [`global()`] is the process-wide registry for series that are
//! genuinely per-process (GEMM pack counts, vecmath pass counts, the
//! `stage.*` span histograms, explorer totals).  Serving state lives
//! in per-`Metrics` private registries instead, so two `Server`s in
//! one process — the normal situation in tests — never share counts.

use super::histogram::Histogram;
use super::snapshot::{MetricValue, TelemetrySnapshot};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Raise the counter to `v` if it is currently lower (`fetch_max`).
    /// The right primitive for mirroring an external monotone series:
    /// racing stale stores can never lower the published value.
    #[inline]
    pub fn store_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A point-in-time value published with a monotonic sequence tag.
///
/// Gauges mirror snapshots of external state (e.g. `PlanCache`
/// residency) taken by racing workers.  Two separate atomics cannot
/// publish a (seq, value) pair atomically, so the pair lives behind
/// one mutex with a microscopic critical section; [`Gauge::set_at`]
/// applies a snapshot only if its sequence is newer than the one
/// already published — a stale snapshot can never overwrite a fresher
/// one, closing the PR-4 "self-heals next batch" staleness race.
#[derive(Debug, Default)]
pub struct Gauge {
    inner: Mutex<(u64, u64)>, // (seq, value)
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge { inner: Mutex::new((0, 0)) }
    }

    /// Unconditional set (for single-writer gauges); bumps the
    /// internal sequence so it still orders against `set_at` callers.
    pub fn set(&self, v: u64) {
        let mut g = self.inner.lock().unwrap();
        g.0 += 1;
        g.1 = v;
    }

    /// Publish `(seq, v)` iff `seq` is strictly newer than the
    /// currently published sequence.  Returns whether it applied.
    pub fn set_at(&self, seq: u64, v: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        if seq > g.0 {
            *g = (seq, v);
            true
        } else {
            false
        }
    }

    pub fn get(&self) -> u64 {
        self.inner.lock().unwrap().1
    }

    /// Sequence tag of the currently published value.
    pub fn seq(&self) -> u64 {
        self.inner.lock().unwrap().0
    }
}

/// One registered metric (handles are cheap `Arc` clones).
#[derive(Clone, Debug)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Name -> metric map.  See the module docs for the locking story.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry { inner: Mutex::new(BTreeMap::new()) }
    }

    /// Get-or-create the named counter.
    ///
    /// Panics if `name` is already registered as a different metric
    /// type — that is a wiring bug, not a runtime condition.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.inner.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())))
        {
            Metric::Counter(c) => c.clone(),
            other => panic!(
                "metric '{name}' already registered as {other:?}, not a \
                 counter"
            ),
        }
    }

    /// Get-or-create the named gauge (panics on a type clash).
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.inner.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())))
        {
            Metric::Gauge(g) => g.clone(),
            other => panic!(
                "metric '{name}' already registered as {other:?}, not a \
                 gauge"
            ),
        }
    }

    /// Get-or-create the named histogram (panics on a type clash).
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.inner.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new())))
        {
            Metric::Histogram(h) => h.clone(),
            other => panic!(
                "metric '{name}' already registered as {other:?}, not a \
                 histogram"
            ),
        }
    }

    /// Look up an existing metric without creating one.
    pub fn get(&self, name: &str) -> Option<Metric> {
        self.inner.lock().unwrap().get(name).cloned()
    }

    /// Registered names in deterministic (sorted) order.
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().keys().cloned().collect()
    }

    /// Export every registered metric.  Deterministically ordered by
    /// name (the map is a BTreeMap), so renders diff cleanly.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let m = self.inner.lock().unwrap();
        let entries = m
            .iter()
            .map(|(name, metric)| {
                let v = match metric {
                    Metric::Counter(c) => MetricValue::Counter(c.get()),
                    Metric::Gauge(g) => MetricValue::Gauge(g.get()),
                    Metric::Histogram(h) => {
                        MetricValue::Histogram(h.as_ref().into())
                    }
                };
                (name.clone(), v)
            })
            .collect();
        TelemetrySnapshot::new(entries)
    }
}

/// The process-wide registry (see the module docs for what belongs
/// here vs in a per-`Metrics` registry).
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_shared_per_name() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(r.get("x").is_some());
        assert!(r.get("y").is_none());
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn type_clash_panics() {
        let r = Registry::new();
        r.counter("x");
        r.gauge("x");
    }

    #[test]
    fn counter_store_max_ignores_stale_values() {
        let c = Counter::new();
        c.store_max(10);
        c.store_max(7); // stale mirror of a monotone series
        assert_eq!(c.get(), 10);
        c.store_max(12);
        assert_eq!(c.get(), 12);
    }

    #[test]
    fn gauge_rejects_stale_sequences() {
        let g = Gauge::new();
        assert!(g.set_at(5, 500));
        assert!(!g.set_at(3, 300)); // older snapshot arrives late
        assert_eq!(g.get(), 500);
        assert_eq!(g.seq(), 5);
        assert!(g.set_at(6, 600));
        assert_eq!(g.get(), 600);
    }

    #[test]
    fn names_are_sorted() {
        let r = Registry::new();
        r.counter("b");
        r.histogram("a");
        r.gauge("c");
        assert_eq!(r.names(), vec!["a", "b", "c"]);
    }
}
