//! Lock-free log2-bucketed histograms.
//!
//! Bucket `i` holds values `v` with `floor(log2(max(v, 1))) == i`,
//! i.e. the half-open magnitude class `[2^i, 2^(i+1))` (with 0 folded
//! into bucket 0).  64 buckets cover the whole `u64` range, so there
//! is no clamping and no configuration: any latency in nanoseconds,
//! microseconds, or request counts fits.
//!
//! Error bound: a percentile read-out returns the upper bound of the
//! bucket holding the target rank, clamped by the exactly-tracked
//! maximum.  For a true percentile value `t >= 1` in bucket `i`,
//! `2^i <= t < 2^(i+1)` and the read-out is `min(2^(i+1), max)`, so
//! the result lies in `[t, 2t)` — at most one binary order high,
//! never low.  `max` (and hence p100) is exact; `count`/`sum`/`mean`
//! are exact.
//!
//! All updates are single relaxed RMW atomics — safe from any thread,
//! no locks on the record path.  Per-thread [`LocalHistogram`] shards
//! (plain integers) can batch records entirely contention-free and
//! merge in O(buckets).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: one per binary order of magnitude of `u64`.
pub const BUCKETS: usize = 64;

/// `floor(log2(v))` for `v >= 1`; 0 maps to bucket 0.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    (63 - v.max(1).leading_zeros()) as usize
}

/// Exclusive upper bound of bucket `i` (saturating at `u64::MAX`).
#[inline]
pub fn bucket_upper_bound(i: usize) -> u64 {
    if i >= 63 {
        u64::MAX
    } else {
        1u64 << (i + 1)
    }
}

/// A lock-free latency/size histogram (see the module docs for the
/// bucket scheme and error bound).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one observation.  Wait-free: four relaxed RMWs.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max_value(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Exact arithmetic mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Percentile read-out, `p` in percent (50.0, 99.0, 99.9, 100.0).
    ///
    /// Walks the cumulative bucket counts to the rank
    /// `ceil(p/100 * count)` (at least 1) and returns that bucket's
    /// upper bound clamped by the exact maximum; 0 when empty.  The
    /// result is in `[true, 2*true)` — see the module docs.
    pub fn percentile(&self, p: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for i in 0..BUCKETS {
            cum += self.buckets[i].load(Ordering::Relaxed);
            if cum >= target {
                return bucket_upper_bound(i).min(self.max_value());
            }
        }
        // p > 100 walks off the end; answer with the exact max.
        self.max_value()
    }

    /// Raw per-bucket counts (a consistent-enough relaxed snapshot;
    /// concurrent recorders may be mid-flight).
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Add every observation of `other` into `self` in O(buckets).
    pub fn merge_from(&self, other: &Histogram) {
        for i in 0..BUCKETS {
            let n = other.buckets[i].load(Ordering::Relaxed);
            if n > 0 {
                self.buckets[i].fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max
            .fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Clone for Histogram {
    fn clone(&self) -> Histogram {
        let h = Histogram::new();
        h.merge_from(self);
        h
    }
}

impl fmt::Debug for Histogram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Histogram {{ count: {}, sum: {}, max: {} }}",
            self.count(),
            self.sum(),
            self.max_value()
        )
    }
}

/// A plain-integer per-thread shard: record without any atomics, then
/// [`LocalHistogram::merge_into`] a shared [`Histogram`] once.
#[derive(Clone)]
pub struct LocalHistogram {
    buckets: [u64; BUCKETS],
    count: u64,
    sum: u64,
    max: u64,
}

impl LocalHistogram {
    pub fn new() -> LocalHistogram {
        LocalHistogram { buckets: [0; BUCKETS], count: 0, sum: 0, max: 0 }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Flush this shard into a shared histogram and reset it.
    pub fn merge_into(&mut self, target: &Histogram) {
        for i in 0..BUCKETS {
            if self.buckets[i] > 0 {
                target.buckets[i].fetch_add(self.buckets[i], Ordering::Relaxed);
            }
        }
        target.count.fetch_add(self.count, Ordering::Relaxed);
        target.sum.fetch_add(self.sum, Ordering::Relaxed);
        target.max.fetch_max(self.max, Ordering::Relaxed);
        *self = LocalHistogram::new();
    }
}

impl Default for LocalHistogram {
    fn default() -> LocalHistogram {
        LocalHistogram::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_matches_the_scalar_oracle() {
        // oracle: position of the highest set bit (integer math; a
        // float log2 rounds wrong near 2^64)
        let oracle = |v: u64| (64 - v.max(1).leading_zeros() - 1) as usize;
        for v in 0..=1026u64 {
            assert_eq!(bucket_index(v), oracle(v), "v={v}");
        }
        for i in 0..64u32 {
            let b = 1u64 << i;
            assert_eq!(bucket_index(b), i as usize);
            assert_eq!(bucket_index(b + (b >> 1)), i as usize);
            if b > 2 {
                assert_eq!(bucket_index(b - 1), (i - 1) as usize);
            }
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(u64::MAX), 63);
    }

    #[test]
    fn percentiles_never_underestimate_and_p100_is_exact() {
        let h = Histogram::new();
        for v in [3u64, 17, 120, 900, 7_000, 100_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.max_value(), 100_000);
        assert_eq!(h.percentile(100.0), 100_000); // exact: max clamp
        // p50 rank = 3 -> value 120 in bucket 6 -> upper bound 128
        assert_eq!(h.percentile(50.0), 128);
        for (p, t) in [(50.0, 120u64), (99.0, 100_000), (99.9, 100_000)] {
            let r = h.percentile(p);
            assert!(r >= t && r < 2 * t, "p{p}: {r} vs true {t}");
        }
        // monotone in p
        assert!(h.percentile(50.0) <= h.percentile(99.0));
        assert!(h.percentile(99.0) <= h.percentile(99.9));
        assert!(h.percentile(99.9) <= h.percentile(100.0));
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.percentile(100.0), 0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.is_empty());
    }

    #[test]
    fn zero_values_count_but_do_not_inflate() {
        let h = Histogram::new();
        h.record(0);
        h.record(0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum(), 0);
        // bucket 0's upper bound is 2 but the max clamp keeps it honest
        assert_eq!(h.percentile(50.0), 0);
    }

    #[test]
    fn local_shard_merges_exactly() {
        let shared = Histogram::new();
        let mut local = LocalHistogram::new();
        for v in [1u64, 2, 3, 1000, 65_536] {
            local.record(v);
        }
        assert_eq!(local.count(), 5);
        local.merge_into(&shared);
        assert_eq!(local.count(), 0); // reset after flush
        assert_eq!(shared.count(), 5);
        assert_eq!(shared.sum(), 1 + 2 + 3 + 1000 + 65_536);
        assert_eq!(shared.max_value(), 65_536);
    }

    #[test]
    fn merge_from_conserves_totals() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in 1..=100u64 {
            a.record(v);
            b.record(v * 10);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 200);
        assert_eq!(a.max_value(), 1000);
        let direct = Histogram::new();
        for v in 1..=100u64 {
            direct.record(v);
            direct.record(v * 10);
        }
        assert_eq!(a.bucket_counts(), direct.bucket_counts());
        assert_eq!(a.percentile(99.0), direct.percentile(99.0));
    }

    #[test]
    fn clone_is_a_snapshot() {
        let h = Histogram::new();
        h.record(42);
        let snap = h.clone();
        h.record(7);
        assert_eq!(snap.count(), 1);
        assert_eq!(h.count(), 2);
    }
}
