//! Power model: static + activity-scaled dynamic power.
//!
//! P = P_static + f · (α_ALM · ALMs + α_DSP · DSPs + α_REG · reg_bits)
//!
//! The α constants were least-squares fit against the paper's own Table 5
//! rows (float32 / float16 / FL(4,9) / I(5,10) / FI(6,8) at their reported
//! ALM, DSP and clock values) with P_static fixed at a typical Arria-10
//! figure; the fit reproduces all five power cells within ±10%:
//!
//!   float32 12.38 W → 11.69 W (−5.6%)     float16 7.30 → 7.53 (+3.2%)
//!   FL(4,9)  6.68 → 7.11 (+6.4%)          I(5,10) 6.28 → 6.88 (+9.6%)
//!   FI(6,8)  4.90 → 4.85 (−1.1%)

/// Static (leakage + always-on) power of the device, watts.
pub const P_STATIC_W: f64 = 1.2;
/// Dynamic power per ALM per Hz (W/(ALM·Hz)).
pub const ALPHA_ALM: f64 = 4.2817e-13;
/// Dynamic power per DSP block per Hz.
pub const ALPHA_DSP: f64 = 5.7403e-12;
/// Dynamic power per clocked register/BRAM bit per Hz.
pub const ALPHA_REG: f64 = 3.8404e-13;

/// Total power in watts.
pub fn power_w(alms: f64, dsps: u32, reg_bits: u64, f_hz: f64) -> f64 {
    P_STATIC_W
        + f_hz
            * (ALPHA_ALM * alms
                + ALPHA_DSP * dsps as f64
                + ALPHA_REG * reg_bits as f64)
}

/// Energy efficiency in Gops/J, with the paper's op accounting:
/// one op per PE per cycle (Table 5 note).
pub fn gops_per_joule(n_pe: usize, f_hz: f64, p_w: f64) -> f64 {
    (n_pe as f64 * f_hz) / p_w / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_float32_row_within_10pct() {
        // paper: 209,805 ALMs, 500 DSPs, 94.41 MHz -> 12.38 W, 3.81 Gops/J
        let p = power_w(209_805.0, 500, 500 * 96, 94.41e6);
        assert!((p - 12.38).abs() / 12.38 < 0.10, "p = {p}");
        let ge = gops_per_joule(500, 94.41e6, p);
        assert!((ge - 3.81).abs() / 3.81 < 0.12, "gops/j = {ge}");
    }

    #[test]
    fn reproduces_paper_fi68_row_within_10pct() {
        let p = power_w(15_452.0, 500, 500 * 45, 201.13e6);
        assert!((p - 4.90).abs() / 4.90 < 0.10, "p = {p}");
        let ge = gops_per_joule(500, 201.13e6, p);
        assert!((ge - 20.52).abs() / 20.52 < 0.12, "gops/j = {ge}");
    }

    #[test]
    fn power_monotone_in_area_and_clock() {
        let base = power_w(50_000.0, 100, 10_000, 100e6);
        assert!(power_w(100_000.0, 100, 10_000, 100e6) > base);
        assert!(power_w(50_000.0, 200, 10_000, 100e6) > base);
        assert!(power_w(50_000.0, 100, 10_000, 200e6) > base);
        assert!(power_w(0.0, 0, 0, 0.0) == P_STATIC_W);
    }
}
