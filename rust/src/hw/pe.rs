//! Processing-element composition: one MAC datapath per arithmetic
//! provider — the ScaLop `PE` of paper §4.4 ("a multiplier and an adder in
//! which inputs and outputs are fixed-point numbers ...") extended to all
//! representations in Table 2.
//!
//! PEs are *pipelined*: Fmax is set by the slowest pipeline stage, not the
//! sum of all stages.  The floating-point PE splits into (multiplier |
//! FP-adder) stages and its critical stage is the un-pipelinable FP adder
//! chain (align → add → LZD → normalize → round) — which is exactly why
//! the paper's float32 datapath clocks at ~94 MHz while FI(6, 8) reaches
//! ~201 MHz with its single mult+add stage.

use super::components as c;
use super::components::Cost;
use crate::approx::arith::ArithKind;

/// Synthesized cost of one PE.
#[derive(Clone, Debug)]
pub struct PeCost {
    /// total ALMs across all stages
    pub alms: f64,
    pub dsps: u32,
    /// pipeline + operand registers clocked per cycle
    pub reg_bits: u32,
    /// slowest pipeline stage (sets Fmax), ns — includes register setup
    pub critical_ns: f64,
    /// stage delays for reporting/debug
    pub stages: Vec<f64>,
}

impl PeCost {
    fn from_stages(stages: Vec<Cost>, reg_bits: u32) -> PeCost {
        let alms: f64 = stages.iter().map(|s| s.alms).sum::<f64>() + 1.0; // ctrl
        let dsps = stages.iter().map(|s| s.dsps).sum();
        let delays: Vec<f64> =
            stages.iter().map(|s| s.delay_ns + c::T_SETUP).collect();
        let critical = delays.iter().cloned().fold(0.0, f64::max);
        PeCost {
            alms,
            dsps,
            reg_bits,
            critical_ns: critical,
            stages: delays,
        }
    }
}

/// Floating-point add chain: exponent compare, alignment shifter, mantissa
/// add, LZD, normalization shifter, rounding (one pipeline stage — the
/// serial dependency cannot be cut without wrecking latency·area).
/// `guard` is the number of guard/round/sticky bits carried (3 for a
/// rounding datapath; 0 for the CFPU approximate path whose products are
/// exact power-of-two rescalings and skip the rounding increment).
fn fp_adder(e_bits: u32, m_bits: u32, guard: u32, with_round: bool) -> Cost {
    let ws = m_bits + 1 + guard; // implied bit + guard/round/sticky
    let mut cost = c::adder(e_bits) // exponent compare/subtract
        .then(c::barrel_shifter(ws)) // align
        .then(c::adder(ws + 2)) // mantissa add
        .then(c::lod(ws)) // leading-zero detect
        .then(c::barrel_shifter(ws)); // normalize
    if with_round {
        cost = cost.then(c::adder(ws)); // round increment
    }
    cost.beside(c::adder(e_bits)) // exponent adjust (parallel tail)
}

/// Compose the MAC PE for a provider.
pub fn pe_cost(kind: &ArithKind) -> PeCost {
    match kind {
        // IEEE float32 baseline: 24-bit mantissa mult (one 27x27 DSP) +
        // full-width FP adder.
        ArithKind::Float32 => fp_pe(8, 23),
        ArithKind::FloatExact(r) => fp_pe(r.e_bits, r.m_bits),
        ArithKind::FloatCfpu(cf) => {
            // CFPU: the mantissa multiplier is REPLACED by skip logic —
            // the multiplier-free realization the paper highlights for
            // I(5, 10) (0 DSP blocks).  Skip logic: top-w all-0/all-1
            // detects on both operands + exponent adder + result mux; the
            // approximate path also drops the rounding increment (it only
            // rescales by powers of two).
            let (e, m) = (cf.rep.e_bits, cf.rep.m_bits);
            let skip = c::comparator(cf.w)
                .beside(c::comparator(cf.w))
                .then(c::adder(e + 1));
            // the skip-result mux folds into the adder's first stage; its
            // select delay lands on the adder's critical path
            let adder_stage = Cost {
                alms: 0.0,
                dsps: 0,
                delay_ns: c::T_LUT, // operand-select mux
                reg_bits: 0,
            }
            .then(fp_adder(e, m, 0, false));
            let stages = vec![skip, adder_stage];
            PeCost::from_stages(stages, 3 * (1 + e + m))
        }
        ArithKind::FixedExact(r) => fixed_pe(r.i_bits, r.f_bits, None),
        ArithKind::FixedDrum(d) => {
            fixed_pe(d.rep.i_bits, d.rep.f_bits, Some(d.t))
        }
        ArithKind::Binary => {
            // XNOR + popcount accumulate: single tiny stage
            let stage = Cost {
                alms: 4.0,
                dsps: 0,
                delay_ns: 2.0 * c::T_LUT,
                reg_bits: 0,
            }
            .then(c::adder(16));
            PeCost::from_stages(vec![stage], 16)
        }
    }
}

fn fp_pe(e_bits: u32, m_bits: u32) -> PeCost {
    let mult = c::dsp_mult(m_bits + 1, m_bits + 1);
    let stages = vec![mult, fp_adder(e_bits, m_bits, 3, true)];
    PeCost::from_stages(stages, 3 * (1 + e_bits + m_bits))
}

/// Fixed-point MAC: multiplier feeding a wide accumulator in ONE stage
/// (this is what doubles the clock in Table 5: no alignment/normalize
/// chain).  DRUM conditioning adds LODs + truncation shifters but shrinks
/// the multiplier to t x t.
fn fixed_pe(i_bits: u32, f_bits: u32, drum_t: Option<u32>) -> PeCost {
    let w = i_bits + f_bits;
    let acc_width = 2 * w; // widened partial sums (paper §4.2)
    let stage = match drum_t {
        None => c::dsp_mult(w, w).then(c::adder(acc_width)),
        Some(t) => c::lod(w)
            .beside(c::lod(w))
            .then(c::barrel_shifter(w).beside(c::barrel_shifter(w)))
            .then(c::dsp_mult(t, t))
            .then(c::barrel_shifter(2 * w)) // product re-expansion
            .then(c::adder(acc_width)),
    };
    PeCost::from_stages(vec![stage], 3 * (1 + w))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(s: &str) -> ArithKind {
        ArithKind::parse(s).unwrap()
    }

    #[test]
    fn fixed_pe_is_tiny_vs_float32() {
        let fixed = pe_cost(&k("FI(6,8)"));
        let f32pe = pe_cost(&ArithKind::Float32);
        // Table 5: 15,452 vs 209,805 ALMs over 500 PEs — >10x gap
        assert!(f32pe.alms > 8.0 * fixed.alms,
                "f32 {} vs fixed {}", f32pe.alms, fixed.alms);
        // and the fixed PE clocks about twice as fast
        assert!(fixed.critical_ns * 1.8 < f32pe.critical_ns);
    }

    #[test]
    fn cfpu_is_multiplier_free() {
        let i510 = pe_cost(&k("I(5,10)"));
        assert_eq!(i510.dsps, 0, "CFPU PE must use no DSPs");
        let fl510 = pe_cost(&k("FL(5,10)"));
        assert_eq!(fl510.dsps, 1);
        // CFPU trims the rounding stage: slightly smaller than FL(5,10)
        assert!(i510.alms < fl510.alms * 1.05);
    }

    #[test]
    fn float_area_grows_with_mantissa() {
        let a = pe_cost(&k("FL(4,6)")).alms;
        let b = pe_cost(&k("FL(4,12)")).alms;
        let cc = pe_cost(&k("FL(4,20)")).alms;
        assert!(a < b && b < cc);
    }

    #[test]
    fn fixed_area_grows_with_width() {
        assert!(pe_cost(&k("FI(4,4)")).alms < pe_cost(&k("FI(8,12)")).alms);
    }

    #[test]
    fn fp_critical_stage_is_the_adder_not_the_mult() {
        let pe = pe_cost(&ArithKind::Float32);
        assert_eq!(pe.stages.len(), 2);
        assert!(pe.stages[1] > pe.stages[0],
                "FP adder stage must dominate: {:?}", pe.stages);
    }

    #[test]
    fn drum_adds_lod_and_shifters_but_small_mult() {
        let exact = pe_cost(&k("FI(8,8)"));
        let drum = pe_cost(&k("H(8,8,6)"));
        assert!(drum.alms > exact.alms);
        assert_eq!(exact.dsps, 1);
        assert_eq!(drum.dsps, 1);
    }

    #[test]
    fn binary_pe_is_tiny_and_dsp_free() {
        let bin = pe_cost(&ArithKind::Binary);
        assert_eq!(bin.dsps, 0);
        assert!(bin.alms < 30.0, "XNOR PE should be a few ALMs");
        assert!(bin.alms < pe_cost(&k("FI(6,8)")).alms);
    }
}
