//! Primitive hardware components: parameterized ALM-count and delay
//! models.  These mirror the module library ScaLop exposes to Chisel
//! (FixedAdd, FixedMul, FloatAdd, ... — paper §4.4), reduced to their
//! synthesis cost.
//!
//! Units: ALMs (Arria-10 adaptive logic modules), delay in nanoseconds.

/// Cost of one primitive instance.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub alms: f64,
    pub dsps: u32,
    pub delay_ns: f64,
    /// register bits clocked every cycle (drives dynamic power)
    pub reg_bits: u32,
}

impl Cost {
    pub fn zero() -> Cost {
        Cost::default()
    }

    /// Series composition: areas add, delays add (same pipeline stage).
    pub fn then(self, other: Cost) -> Cost {
        Cost {
            alms: self.alms + other.alms,
            dsps: self.dsps + other.dsps,
            delay_ns: self.delay_ns + other.delay_ns,
            reg_bits: self.reg_bits + other.reg_bits,
        }
    }

    /// Parallel composition: areas add, delay is the max.
    pub fn beside(self, other: Cost) -> Cost {
        Cost {
            alms: self.alms + other.alms,
            dsps: self.dsps + other.dsps,
            delay_ns: self.delay_ns.max(other.delay_ns),
            reg_bits: self.reg_bits + other.reg_bits,
        }
    }
}

// --- calibration constants (fit against paper Table 5; see hw/mod.rs) ----

/// ns per carry-chain bit (Arria 10 carry chains are fast).
pub const T_CARRY: f64 = 0.030;
/// ns per LUT level (mux stage, comparator level, ...).
pub const T_LUT: f64 = 0.55;
/// Base DSP multiplier delay, plus per-mantissa-bit slope.
pub const T_DSP_BASE: f64 = 2.0;
pub const T_DSP_PER_BIT: f64 = 0.05;
/// Register setup + clock skew margin per stage.
pub const T_SETUP: f64 = 0.80;
/// ALM factor of a barrel-shifter stage (muxes per bit per stage).
pub const ALM_SHIFT_FACTOR: f64 = 1.25;

/// Ripple/carry-chain adder of width `w`.
pub fn adder(w: u32) -> Cost {
    Cost {
        alms: w as f64,
        dsps: 0,
        delay_ns: w as f64 * T_CARRY + T_LUT,
        reg_bits: 0,
    }
}

/// Comparator over `w` bits (all-zero / all-one detection is cheaper but
/// we lump it here).
pub fn comparator(w: u32) -> Cost {
    Cost {
        alms: (w as f64 / 2.0).max(1.0),
        dsps: 0,
        delay_ns: (log2_ceil4(w) as f64) * T_LUT * 0.5 + T_LUT * 0.5,
        reg_bits: 0,
    }
}

fn log2_ceil4(w: u32) -> u32 {
    // ceil(log4(w)): 6-input LUTs compare ~4 bits per level
    let mut l = 0;
    let mut c = 1u32;
    while c < w.max(1) {
        c *= 4;
        l += 1;
    }
    l
}

/// Barrel shifter: `w` data bits, `ceil(log2(w))` mux stages.
pub fn barrel_shifter(w: u32) -> Cost {
    let stages = ceil_log2(w);
    Cost {
        alms: w as f64 * stages as f64 * ALM_SHIFT_FACTOR,
        dsps: 0,
        delay_ns: stages as f64 * T_LUT,
        reg_bits: 0,
    }
}

/// Leading-one/zero detector over `w` bits (priority encoder).
pub fn lod(w: u32) -> Cost {
    Cost {
        alms: w as f64 * 0.5,
        dsps: 0,
        delay_ns: ceil_log2(w) as f64 * T_LUT * 0.55,
        reg_bits: 0,
    }
}

/// Hardened DSP multiplier: one Arria-10 DSP handles up to 27x27.
/// Wider products gang DSPs (ceil(w/27)^2).
pub fn dsp_mult(wa: u32, wb: u32) -> Cost {
    let ga = wa.div_ceil(27).max(1);
    let gb = wb.div_ceil(27).max(1);
    Cost {
        alms: if ga * gb > 1 { (wa + wb) as f64 } else { 0.0 },
        dsps: ga * gb,
        delay_ns: T_DSP_BASE + T_DSP_PER_BIT * wa.max(wb) as f64,
        reg_bits: 0,
    }
}

/// Soft (LUT) array multiplier — used when a design must avoid DSPs.
pub fn lut_mult(wa: u32, wb: u32) -> Cost {
    Cost {
        alms: wa as f64 * wb as f64 * 0.7,
        dsps: 0,
        delay_ns: (wa + wb) as f64 * T_CARRY * 2.0 + 2.0 * T_LUT,
        reg_bits: 0,
    }
}

/// Pipeline register bank of `bits` flip-flops.  ALM-free on Arria 10
/// (each ALM bundles FFs with its LUTs) but it clocks power.
pub fn register(bits: u32) -> Cost {
    Cost { alms: 0.0, dsps: 0, delay_ns: T_SETUP, reg_bits: bits }
}

/// Small control FSM / handshake overhead per PE.
pub fn control() -> Cost {
    Cost { alms: 1.0, dsps: 0, delay_ns: 0.0, reg_bits: 4 }
}

pub fn ceil_log2(w: u32) -> u32 {
    if w <= 1 {
        0
    } else {
        32 - (w - 1).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(13), 4);
        assert_eq!(ceil_log2(16), 4);
        assert_eq!(ceil_log2(17), 5);
    }

    #[test]
    fn adder_scales_linearly() {
        assert!(adder(32).alms > adder(8).alms);
        assert!(adder(32).delay_ns > adder(8).delay_ns);
    }

    #[test]
    fn dsp_mult_ganging() {
        assert_eq!(dsp_mult(16, 16).dsps, 1);
        assert_eq!(dsp_mult(24, 24).dsps, 1); // 27x27 mode
        assert_eq!(dsp_mult(32, 32).dsps, 4);
    }

    #[test]
    fn lut_mult_avoids_dsps() {
        let c = lut_mult(11, 11);
        assert_eq!(c.dsps, 0);
        assert!(c.alms > 50.0);
    }

    #[test]
    fn composition_rules() {
        let a = adder(8);
        let b = barrel_shifter(8);
        let s = a.then(b);
        assert!((s.alms - (a.alms + b.alms)).abs() < 1e-9);
        assert!((s.delay_ns - (a.delay_ns + b.delay_ns)).abs() < 1e-9);
        let p = a.beside(b);
        assert_eq!(p.delay_ns, a.delay_ns.max(b.delay_ns));
    }
}
