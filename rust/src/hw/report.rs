//! Table-5 report generator: "Hardware Cost of Various Implementations".

use super::datapath::{Datapath, FpgaDevice, ARRIA10, N_PE};
use crate::approx::arith::ArithKind;

#[derive(Clone, Debug)]
pub struct HwRow {
    pub representation: String,
    pub alms: u64,
    pub alm_util: f64,
    pub dsps: u32,
    pub dsp_util: f64,
    pub clock_mhz: f64,
    pub power_w: f64,
    pub gops_per_j: f64,
}

impl HwRow {
    pub fn from_datapath(name: &str, dp: &Datapath, dev: &FpgaDevice)
                         -> HwRow {
        let (a, d) = dp.utilization(dev);
        HwRow {
            representation: name.to_string(),
            alms: dp.alms.round() as u64,
            alm_util: a,
            dsps: dp.dsps,
            dsp_util: d,
            clock_mhz: dp.fmax_mhz,
            power_w: dp.power_w,
            gops_per_j: dp.gops_per_j,
        }
    }
}

/// Build the Table-5 rows for a set of representations (defaults to the
/// paper's five).
pub fn hw_report(kinds: &[(&str, ArithKind)]) -> Vec<HwRow> {
    kinds
        .iter()
        .map(|(name, k)| {
            let dp = Datapath::synthesize(k, N_PE);
            HwRow::from_datapath(name, &dp, &ARRIA10)
        })
        .collect()
}

/// The paper's Table-5 representation set.
pub fn table5_kinds() -> Vec<(&'static str, ArithKind)> {
    vec![
        ("float32", ArithKind::Float32),
        ("float16", ArithKind::parse("FL(5,10)").unwrap()),
        ("FL(4, 9)", ArithKind::parse("FL(4,9)").unwrap()),
        ("I(5, 10)", ArithKind::parse("I(5,10)").unwrap()),
        ("FI(6, 8)", ArithKind::parse("FI(6,8)").unwrap()),
    ]
}

/// Render rows in the paper's table layout.
pub fn format_table(rows: &[HwRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<12} {:>9} {:>7} {:>6} {:>7} {:>10} {:>9} {:>12}\n",
        "Repr", "ALMs", "(util)", "DSPs", "(util)", "Clock(MHz)",
        "Power(W)", "Gops/J"
    ));
    s.push_str(&"-".repeat(80));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:<12} {:>9} {:>6.0}% {:>6} {:>6.0}% {:>10.2} {:>9.2} {:>12.2}\n",
            r.representation,
            r.alms,
            r.alm_util * 100.0,
            r.dsps,
            r.dsp_util * 100.0,
            r.clock_mhz,
            r.power_w,
            r.gops_per_j
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_has_five_paper_rows() {
        let rows = hw_report(&table5_kinds());
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].representation, "float32");
        let txt = format_table(&rows);
        assert!(txt.contains("FI(6, 8)"));
        assert!(txt.contains("Gops/J"));
    }

    #[test]
    fn i510_row_is_dsp_free() {
        let rows = hw_report(&table5_kinds());
        let i510 = rows.iter().find(|r| r.representation == "I(5, 10)")
            .unwrap();
        assert_eq!(i510.dsps, 0);
        assert_eq!(i510.dsp_util, 0.0);
    }
}
