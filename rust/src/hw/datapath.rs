//! Datapath model: the paper maps the DCNN onto a datapath of 500 PEs
//! plus control/scheduling (after DnnWeaver [28], §5.2) on an Arria 10.

use super::pe::{pe_cost, PeCost};
use super::power::{gops_per_joule, power_w};
use crate::approx::arith::ArithKind;

/// Target device (paper §5.2: Arria 10 with 427,200 ALMs, 55,562,240
/// block-RAM bits, 1,518 DSP blocks).
#[derive(Clone, Copy, Debug)]
pub struct FpgaDevice {
    pub name: &'static str,
    pub alms: u64,
    pub bram_bits: u64,
    pub dsps: u32,
}

pub const ARRIA10: FpgaDevice = FpgaDevice {
    name: "Arria 10",
    alms: 427_200,
    bram_bits: 55_562_240,
    dsps: 1_518,
};

/// Number of PEs in the paper's datapath.
pub const N_PE: usize = 500;

/// Interconnect + scheduler overhead added on top of the PE array:
/// a fixed controller plus per-PE fan-out logic.
const CTRL_ALMS_FIXED: f64 = 500.0;
const CTRL_ALMS_PER_PE: f64 = 1.0;

/// Aggregated synthesis estimate for a full datapath.
#[derive(Clone, Copy, Debug)]
pub struct Datapath {
    pub kind_bits: u32,
    pub n_pe: usize,
    pub alms: f64,
    pub dsps: u32,
    pub reg_bits: u64,
    pub fmax_mhz: f64,
    pub power_w: f64,
    pub gops_per_j: f64,
}

impl Datapath {
    /// Synthesize (analytically) a uniform datapath of `n_pe` PEs.
    pub fn synthesize(kind: &ArithKind, n_pe: usize) -> Datapath {
        let pe: PeCost = pe_cost(kind);
        let alms = pe.alms * n_pe as f64
            + CTRL_ALMS_FIXED
            + CTRL_ALMS_PER_PE * n_pe as f64;
        let dsps = pe.dsps * n_pe as u32;
        let reg_bits = pe.reg_bits as u64 * n_pe as u64;
        let fmax_mhz = 1_000.0 / pe.critical_ns;
        let p = power_w(alms, dsps, reg_bits, fmax_mhz * 1e6);
        Datapath {
            kind_bits: kind.total_bits(),
            n_pe,
            alms,
            dsps,
            reg_bits,
            fmax_mhz,
            power_w: p,
            gops_per_j: gops_per_joule(n_pe, fmax_mhz * 1e6, p),
        }
    }

    /// Utilization fractions on a device.
    pub fn utilization(&self, dev: &FpgaDevice) -> (f64, f64) {
        (
            self.alms / dev.alms as f64,
            self.dsps as f64 / dev.dsps as f64,
        )
    }

    /// Does the datapath fit the device at all?
    pub fn fits(&self, dev: &FpgaDevice) -> bool {
        let (a, d) = self.utilization(dev);
        a <= 1.0 && d <= 1.0
    }

    /// Scalar cost used by the explorer's pass-1 objective: weighted blend
    /// of normalized area, DSP and power (lower is better).
    pub fn explore_cost(&self, dev: &FpgaDevice) -> f64 {
        let (a, d) = self.utilization(dev);
        // power normalized to the float32 reference (~12 W)
        let p = self.power_w / 12.0;
        0.4 * a + 0.2 * d + 0.4 * p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(s: &str) -> ArithKind {
        ArithKind::parse(s).unwrap()
    }

    #[test]
    fn table5_orderings_hold() {
        let f32dp = Datapath::synthesize(&ArithKind::Float32, N_PE);
        let f16dp = Datapath::synthesize(&k("FL(5,10)"), N_PE);
        let fl49 = Datapath::synthesize(&k("FL(4,9)"), N_PE);
        let i510 = Datapath::synthesize(&k("I(5,10)"), N_PE);
        let fi68 = Datapath::synthesize(&k("FI(6,8)"), N_PE);

        // ALM ordering: float32 >> float16 > FL(4,9) and FI is tiny
        assert!(f32dp.alms > 1.8 * f16dp.alms);
        assert!(f16dp.alms > fl49.alms);
        assert!(fl49.alms > 4.0 * fi68.alms);

        // DSP story: everyone 500 except the CFPU design
        assert_eq!(f32dp.dsps, 500);
        assert_eq!(i510.dsps, 0);
        assert_eq!(fi68.dsps, 500);

        // clock: fixed point runs ~2x float32
        assert!(fi68.fmax_mhz > 1.7 * f32dp.fmax_mhz);

        // power ordering (Table 5): f32 > f16 > FL > I > FI
        assert!(f32dp.power_w > f16dp.power_w);
        assert!(f16dp.power_w > fl49.power_w);
        assert!(fl49.power_w > i510.power_w);
        assert!(i510.power_w > fi68.power_w);

        // energy-efficiency ordering is the reverse
        assert!(fi68.gops_per_j > i510.gops_per_j);
        assert!(i510.gops_per_j > fl49.gops_per_j);
        assert!(fl49.gops_per_j > f16dp.gops_per_j);
        assert!(f16dp.gops_per_j > f32dp.gops_per_j);
    }

    #[test]
    fn float32_row_magnitudes_close_to_paper() {
        // paper: 209,805 ALMs (49%), 94.41 MHz, 12.38 W, 3.81 Gops/J
        let dp = Datapath::synthesize(&ArithKind::Float32, N_PE);
        let alms_err = (dp.alms - 209_805.0).abs() / 209_805.0;
        assert!(alms_err < 0.20, "ALMs {} (err {alms_err:.2})", dp.alms);
        assert!((dp.fmax_mhz - 94.41).abs() / 94.41 < 0.25,
                "fmax {}", dp.fmax_mhz);
        assert!((dp.power_w - 12.38).abs() / 12.38 < 0.25,
                "power {}", dp.power_w);
        assert!((dp.gops_per_j - 3.81).abs() / 3.81 < 0.35,
                "gops/J {}", dp.gops_per_j);
        let (autil, dutil) = dp.utilization(&ARRIA10);
        assert!((0.3..0.7).contains(&autil));
        assert!((dutil - 0.329).abs() < 0.01);
    }

    #[test]
    fn fits_device() {
        assert!(Datapath::synthesize(&ArithKind::Float32, N_PE)
            .fits(&ARRIA10));
        // 4000 float32 PEs would blow the ALM budget
        assert!(!Datapath::synthesize(&ArithKind::Float32, 4_000)
            .fits(&ARRIA10));
    }

    #[test]
    fn explore_cost_prefers_narrow() {
        let wide = Datapath::synthesize(&k("FI(8,14)"), N_PE);
        let narrow = Datapath::synthesize(&k("FI(4,6)"), N_PE);
        assert!(narrow.explore_cost(&ARRIA10) < wide.explore_cost(&ARRIA10));
    }
}
