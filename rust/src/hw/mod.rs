//! Hardware cost model — the ScaLop substitute (see DESIGN.md §3).
//!
//! The paper synthesizes Chisel-generated RTL with Quartus on an Arria 10
//! and reports ALMs, DSPs, Fmax, power and energy efficiency (Table 5).
//! No FPGA or Quartus exists in this environment, so this module replaces
//! synthesis with a *component-level analytical model*: every arithmetic
//! unit is composed from parameterized primitives (carry-chain adders,
//! barrel shifters, leading-zero detectors, DSP blocks, registers), each
//! with an ALM count and a propagation-delay estimate; a PE composes
//! primitives, a datapath replicates PEs, and the power model converts
//! (ALM, DSP, register-bit) activity × clock into watts.
//!
//! Calibration: the per-unit constants were fit once against the paper's
//! own Table 5 (the float32 row anchors the scale) and are documented at
//! their definitions.  The model lands within ~±15% of every Table-5 cell
//! and — the property that matters for design-space exploration —
//! preserves every *ordering* and *ratio class* in the table: FI ≫ FL >
//! float16 > float32 in energy efficiency, CFPU-based I(e, m) is the only
//! DSP-free design, fixed point doubles the clock.

pub mod components;
pub mod datapath;
pub mod pe;
pub mod power;
pub mod report;
pub mod rtl;

pub use datapath::{Datapath, FpgaDevice};
pub use pe::PeCost;
pub use report::{hw_report, HwRow};
