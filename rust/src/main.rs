//! `lop` — CLI for the Lop reproduction: quality simulation (LopPy half),
//! hardware cost analysis (ScaLop half), the §4.2 design-space explorer,
//! and the inference serving runtime.
//!
//! Run `lop help` for the command list.  Everything operates on the AOT
//! artifacts produced by `make artifacts`.

use anyhow::{bail, Context, Result};
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use lop::approx::arith::ArithKind;
use lop::cli::Args;
use lop::config::{ExploreFileConfig, ServeFileConfig, TomlDoc};
use lop::coordinator::eval::Evaluator;
use lop::coordinator::explorer::{Explorer, ExploreOpts, Family};
use lop::coordinator::pareto::{
    auto_config, distill_labels, Objective, ParetoFront,
};
use lop::coordinator::ranges::{format_table1, profile_ranges};
use lop::coordinator::router::OverloadPolicy;
use lop::coordinator::server::{Server, ServerOpts};
use lop::data::{synth, Dataset};
use lop::hw::datapath::{Datapath, ARRIA10, N_PE};
use lop::hw::report::{format_table, hw_report, table5_kinds};
use lop::hw::rtl::datapath_verilog;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::runtime::ArtifactDir;
use lop::util::prng::Rng;

const HELP: &str = "\
lop — customized data representations + approximate computing for ML
(reproduction of Nazemi & Pedram, 2018; see DESIGN.md)

USAGE: lop <command> [flags]

COMMANDS
  summary                     print the Fig. 2 DCNN architecture
  ranges    [--n 2000]        Table 1: per-layer WBA value ranges
  eval      --config C        accuracy of a configuration
            [--n N] [--engine] [--threads T]
  table3    [--n N]           Table 3: floating-point configurations
  table4    [--n N]           Table 4: fixed-point configurations
  hw-report [--repr \"a;b\"]    Table 5: hardware cost model
  netlist   --repr C          ScaLop structural netlist (Verilog-flavored)
  explore   [--subset 400] [--with-approx] [--model M]
            [--objectives \"accuracy,latency,hw\"] [--max-sims 8]
            [--front-out pareto_front.json] [--accuracy-budget B]
            [--calib 64] [--bench-json F] [--config-file F]
            surrogate-guided Pareto DSE (emits a front artifact)
  serve     [--requests 2000] [--rate 500] [--configs \"a;b\"]
            [--max-batch 16] [--max-wait-ms 2] [--engine-workers 2]
            [--overload reject|shed|degrade] [--deadline-ms D]
            [--auto [--front pareto_front.json] --accuracy-budget B]
            [--stats-every N] [--no-pjrt] [--config-file F]
            [--model M]       serving benchmark
  help                        this message

Observability: serve prints a Prometheus-style telemetry snapshot on
shutdown (and every N responses with --stats-every N) and writes it as
JSON to TELEMETRY_serving.json ($LOP_TELEMETRY_JSON overrides the
path).  LOP_TRACE=1 adds per-stage latency breakdowns to responses.

Config syntax: float32 | FI(i,f) | FL(e,m) | H(i,f,t) | I(e,m[,w]) |
binxnor — uniform, or 'a|b|...' with one segment per model layer.
Model syntax (--model / [serve] model): 'paper_dcnn' or a NetSpec
string like '28x28x1: conv(5x5,32,pad=2)+relu+pool | dense(10)'
(non-paper models serve deterministic synthetic weights).";

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "summary" => cmd_summary(),
        "ranges" => cmd_ranges(args),
        "eval" => cmd_eval(args),
        "table3" => cmd_table(args, true),
        "table4" => cmd_table(args, false),
        "hw-report" => cmd_hw_report(args),
        "netlist" => cmd_netlist(args),
        "explore" => cmd_explore(args),
        "serve" => cmd_serve(args),
        "help" | "" => {
            println!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `lop help`)"),
    }
}

fn load_all() -> Result<(ArtifactDir, Model, Dataset)> {
    let art = ArtifactDir::discover()?;
    let model = Model::load(NetSpec::paper_dcnn(),
                            &art.weights_path())?;
    let ds = Dataset::load(&art.dataset_path())?;
    Ok((art, model, ds))
}

/// Parse a config string against the paper spec (the topology every
/// artifact-backed command evaluates).
fn paper_cfg(s: &str) -> Result<ReprMap> {
    ReprMap::parse_for(&NetSpec::paper_dcnn(), s)
        .map_err(|e| anyhow::anyhow!(e))
}

fn evaluator(subset: usize, threads: usize, use_pjrt: bool)
             -> Result<Evaluator> {
    let (art, model, ds) = load_all()?;
    let runner = if use_pjrt {
        // falls back to the bit-accurate engine when PJRT cannot start
        // (e.g. a build without the `pjrt` feature)
        lop::runtime::runner_or_warn(art)
    } else {
        None
    };
    Ok(Evaluator::new(model, runner, ds, subset, threads))
}

// ---------------------------------------------------------------------------

fn cmd_summary() -> Result<()> {
    // rendered from the NetSpec preset, not hardcoded — `summary`
    // prints whatever the spec says, so it cannot drift from the code
    let spec = NetSpec::paper_dcnn();
    println!("DCNN architecture (paper Fig. 2):");
    println!("spec: {spec}");
    println!();
    println!("{:<8} {:>18} {:>14}", "layer", "weights", "output");
    println!("{}", "-".repeat(44));
    for (l, out) in spec.layers().iter().zip(spec.output_shapes()) {
        let (wshape, _) = l.param_shapes();
        let wtxt = wshape
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x");
        let otxt = out
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(",");
        println!("{:<8} {:>18} {:>14}", l.name, wtxt, format!("[B,{otxt}]"));
    }
    println!("total parameters: {}", spec.param_count());
    if let Ok(art) = ArtifactDir::discover() {
        println!("trained float32 baseline accuracy: {:.4}",
                 art.baseline_accuracy);
    }
    Ok(())
}

fn cmd_ranges(args: &Args) -> Result<()> {
    let (art, model, ds) = load_all()?;
    let n = args.usize("n", 2_000);
    let r = profile_ranges(&model, &ds, n, 0);
    println!("Table 1 — value ranges of weights/biases/activations");
    println!("(profiled over {n} training images)\n");
    print!("{}", format_table1(&r));
    match lop::coordinator::ranges::compare_with_python(
        &r, &art.ranges_path()) {
        Ok(dev) => println!(
            "\ncross-check vs python dump (ranges.json): max deviation \
             {dev:.4}"),
        Err(e) => println!("\n(python cross-check unavailable: {e})"),
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg =
        paper_cfg(args.opt_str("config").context("--config required")?)?;
    let n = args.usize("n", 2_000);
    let threads = args.usize("threads", 0);
    let use_pjrt = !args.switch("engine");
    let mut ev = evaluator(n, threads, use_pjrt)?;
    let t0 = Instant::now();
    let acc = ev.accuracy(&cfg)?;
    let base = ev.accuracy(&ReprMap::uniform_for(
        &NetSpec::paper_dcnn(),
        ArithKind::Float32,
    ))?;
    println!("config       : {}", cfg.name());
    println!("backend      : {:?}", ev.backend_for(&cfg));
    println!("images       : {}", ev.subset.len());
    println!("accuracy     : {acc:.4}");
    println!("baseline     : {base:.4}");
    println!("relative     : {:.2}%", acc / base * 100.0);
    println!("elapsed      : {:.2?}", t0.elapsed());
    Ok(())
}

/// The exact configuration mixes from the paper's Table 3.
pub fn table3_rows() -> Vec<&'static str> {
    vec![
        "FL(4,8)|FL(4,9)|FL(4,8)|FL(4,9)",
        "FL(4,9)",
        "I(4,8)|I(4,9)|I(4,8)|I(4,9)",
        "I(4,9)",
        "I(5,10)",
    ]
}

/// The exact configuration mixes from the paper's Table 4.
pub fn table4_rows() -> Vec<&'static str> {
    vec![
        "FI(5,8)|FI(5,8)|FI(6,8)|FI(6,8)",
        "FI(6,8)|FI(6,8)|H(8,8,14)|H(8,8,14)",
        "H(6,8,12)|H(6,8,12)|H(8,8,14)|H(8,8,14)",
        "FI(6,8)",
    ]
}

fn cmd_table(args: &Args, float_table: bool) -> Result<()> {
    let rows = if float_table { table3_rows() } else { table4_rows() };
    let (no, what) = if float_table {
        ("Table 3", "floating-point")
    } else {
        ("Table 4", "fixed-point")
    };
    let n = args.usize("n", 2_000);
    let threads = args.usize("threads", 0);
    let mut ev = evaluator(n, threads, true)?;
    let base = ev.accuracy(&ReprMap::uniform_for(
        &NetSpec::paper_dcnn(),
        ArithKind::Float32,
    ))?;
    println!("{no} — classification accuracy, {what} configurations");
    println!("(n = {} test images, float32 baseline = {base:.4})\n",
             ev.subset.len());
    println!("{:<48} {:>9} {:>10}", "CONV1 | CONV2 | FC1 | FC2",
             "accuracy", "relative");
    println!("{}", "-".repeat(70));
    for row in rows {
        let cfg = paper_cfg(row)?;
        let t0 = Instant::now();
        let acc = ev.accuracy(&cfg)?;
        println!("{:<48} {:>9.4} {:>9.2}%   ({:.1?})", row, acc,
                 acc / base * 100.0, t0.elapsed());
    }
    Ok(())
}

fn cmd_hw_report(args: &Args) -> Result<()> {
    let kinds: Vec<(String, ArithKind)> = match args.opt_str("repr") {
        Some(list) => list
            .split(';')
            .map(|s| {
                ArithKind::parse(s.trim())
                    .map(|k| (s.trim().to_string(), k))
                    .map_err(|e| anyhow::anyhow!(e))
            })
            .collect::<Result<Vec<_>>>()?,
        None => table5_kinds()
            .into_iter()
            .map(|(n, k)| (n.to_string(), k))
            .collect(),
    };
    let refs: Vec<(&str, ArithKind)> =
        kinds.iter().map(|(n, k)| (n.as_str(), *k)).collect();
    println!(
        "Table 5 — hardware cost of the {}-PE datapath on {} \
         (analytical model, see DESIGN.md §3)\n",
        N_PE, ARRIA10.name
    );
    print!("{}", format_table(&hw_report(&refs)));
    Ok(())
}

fn cmd_netlist(args: &Args) -> Result<()> {
    let kind = ArithKind::parse(
        args.opt_str("repr").context("--repr required")?,
    )
    .map_err(|e| anyhow::anyhow!(e))?;
    let n_pe = args.usize("n-pe", N_PE);
    print!("{}", datapath_verilog(&kind, n_pe));
    let dp = Datapath::synthesize(&kind, n_pe);
    eprintln!(
        "// model: {:.0} ALMs, {} DSPs, {:.1} MHz, {:.2} W",
        dp.alms, dp.dsps, dp.fmax_mhz, dp.power_w
    );
    Ok(())
}

/// A hermetic synthetic-digit dataset for non-paper explore/serve
/// flows (no `make artifacts` needed).
fn synth_dataset(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    let (ti, tl) = synth::generate(n_train, seed);
    let (ei, el) = synth::generate(n_test, seed + 1);
    Dataset {
        h: 28,
        w: 28,
        train: lop::data::loader::Split { images: ti, labels: tl },
        test: lop::data::loader::Split { images: ei, labels: el },
    }
}

fn cmd_explore(args: &Args) -> Result<()> {
    let mut opts = ExploreOpts::default();
    let mut subset = args.usize("subset", 400);
    let mut objectives = lop::coordinator::pareto::ALL_OBJECTIVES
        .to_vec();
    let mut max_sims = 8;
    let mut calib = 64;
    let mut front_out: Option<String> = None;
    if let Some(f) = args.opt_str("config-file") {
        let doc = TomlDoc::parse(&std::fs::read_to_string(f)?)
            .map_err(|e| anyhow::anyhow!(e))?;
        let fc = ExploreFileConfig::from_toml(&doc)
            .map_err(|e| anyhow::anyhow!(e))?;
        opts = fc.opts;
        subset = fc.subset;
        objectives = fc.objectives;
        max_sims = fc.max_sims;
        calib = fc.calib;
        front_out = fc.front_out;
    }
    opts.accuracy_bound = args.f64("bound", opts.accuracy_bound);
    if args.switch("with-approx") {
        opts.families = vec![
            Family::Fixed,
            Family::Float,
            Family::FixedDrum,
            Family::FloatCfpu,
        ];
    }
    subset = args.usize("subset", subset);
    if let Some(list) = args.opt_str("objectives") {
        objectives = Objective::parse_list(list)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    max_sims = args.usize("max-sims", max_sims);
    calib = args.usize("calib", calib);
    if let Some(p) = args.opt_str("front-out") {
        front_out = Some(p.to_string());
    }
    let budget = args.opt_str("accuracy-budget").map(|b| {
        b.parse::<f64>().map_err(|_| {
            anyhow::anyhow!("--accuracy-budget wants a number, \
                             got '{b}'")
        })
    }).transpose()?;
    let threads = args.usize("threads", 0);
    let spec = NetSpec::preset_or_parse(
        args.opt_str("model").unwrap_or("paper_dcnn"),
    )
    .map_err(|e| anyhow::anyhow!(e))?;

    // Artifacts drive the paper topology; anything else explores a
    // deterministic synthetic model on distilled synthetic digits
    // (hermetic — same fixture the tier-1 suite pins).
    let mut ev = if spec.is_paper_dcnn() {
        evaluator(subset, threads, !args.switch("engine"))?
    } else {
        anyhow::ensure!(
            spec.input_len() == 784,
            "the synthetic digit set is 28x28x1; model '{spec}' wants \
             {} inputs",
            spec.input_len()
        );
        println!("model: {spec}");
        println!("(non-paper topology: synthetic weights, distilled \
                  labels, engine backend)");
        let model = Model::synthetic(spec.clone(), 42);
        let mut ds = synth_dataset(512, 256, 4242);
        distill_labels(&model, &mut ds, threads);
        Evaluator::new(model, None, ds, subset, threads)
    };

    let mut explorer = Explorer::new(spec.clone())
        .opts(opts)
        .objectives(&objectives)
        .max_sims(max_sims)
        .calibration(calib);
    if let Some(b) = budget {
        explorer = explorer.budget(b);
    }
    let bench = args
        .opt_str("bench-json")
        .map(std::path::PathBuf::from)
        .or_else(|| {
            let p = std::path::PathBuf::from(
                "BENCH_gemm_kernels.json",
            );
            p.exists().then_some(p)
        });
    if let Some(p) = bench {
        println!("latency scale: calibrating from {}", p.display());
        explorer = explorer.bench_json(p);
    }

    println!("surrogate-guided DSE: subset {subset}, calib {calib}, \
              max sims {max_sims}, objectives {:?}",
             objectives.iter().map(|o| o.name()).collect::<Vec<_>>());
    let t0 = Instant::now();
    let front = explorer.run(&mut ev)?;

    println!("\nbaseline accuracy (subset): {:.4}",
             front.baseline_accuracy());
    println!(
        "{:<44} {:>9} {:>9} {:>11} {:>8}  {}",
        "config", "accuracy", "est_acc", "latency_us", "hw_cost",
        "origin"
    );
    println!("{}", "-".repeat(92));
    for p in front.points() {
        println!(
            "{:<44} {:>9.4} {:>9.4} {:>11.1} {:>8.4}  {}",
            p.repr_map.name(),
            p.accuracy,
            p.est_accuracy,
            p.est_latency / 1_000.0,
            p.hw_cost,
            if p.simulated { "simulated" } else { "surrogate" }
        );
    }
    println!(
        "\nspace {} configs -> {} front points, {} full-net sims \
         ({} saved) in {:.1?}; cost model: {}",
        front.space(),
        front.points().len(),
        front.sims(),
        front.space().saturating_sub(front.sims() as u64),
        t0.elapsed(),
        front.cost_source()
    );
    if let Some(b) = budget {
        match front.best_within(b) {
            Some(p) => println!(
                "cheapest config meeting accuracy {b}: {} \
                 (accuracy {:.4}, hw {:.4})",
                p.repr_map.name(), p.accuracy, p.hw_cost
            ),
            None => println!(
                "no front point meets accuracy {b}"
            ),
        }
    }
    if let Some(path) = front_out {
        std::fs::write(&path, front.to_json())
            .with_context(|| format!("writing {path}"))?;
        println!("front artifact written to {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut sopts = ServerOpts::default();
    let mut spec = NetSpec::paper_dcnn();
    let mut auto = false;
    let mut front_path = "pareto_front.json".to_string();
    let mut accuracy_budget: Option<f64> = None;
    let mut stats_every = 0usize;
    if let Some(f) = args.opt_str("config-file") {
        let doc = TomlDoc::parse(&std::fs::read_to_string(f)?)
            .map_err(|e| anyhow::anyhow!(e))?;
        let fc = ServeFileConfig::from_toml(&doc)
            .map_err(|e| anyhow::anyhow!(e))?;
        spec = fc.spec;
        auto = fc.auto;
        front_path = fc.front;
        accuracy_budget = fc.accuracy_budget;
        sopts.configs = fc.configs;
        sopts.max_batch = fc.max_batch;
        sopts.max_wait = fc.max_wait;
        sopts.queue_capacity = fc.queue_capacity;
        sopts.engine_workers = fc.engine_workers;
        sopts.plan_cache_bytes = fc.plan_cache_mb * 1024 * 1024;
        sopts.use_pjrt = fc.use_pjrt;
        sopts.overload = fc.overload;
        sopts.deadline = fc.deadline;
        stats_every = fc.stats_every;
    }
    if let Some(m) = args.opt_str("model") {
        spec = NetSpec::preset_or_parse(m)
            .map_err(|e| anyhow::anyhow!(e))?;
        // configs from a file keep working when their arity still
        // matches the overridden model; only a layer-count change
        // invalidates them (reset to uniform, and say so — the user
        // can pass --configs to choose explicitly)
        if sopts.configs.iter().any(|c| c.len() != spec.len()) {
            eprintln!(
                "note: --model changed the layer count to {}; \
                 dropping the configured configs and serving uniform \
                 float32 (pass --configs to override)",
                spec.len()
            );
            sopts.configs =
                vec![ReprMap::uniform_for(&spec, ArithKind::Float32)];
        }
    }
    if let Some(list) = args.opt_str("configs") {
        sopts.configs = list
            .split(';')
            .map(|s| ReprMap::parse_for(&spec, s.trim()))
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    // --auto: pick the served config from an explored Pareto front
    // (overrides any configured config list)
    if args.switch("auto") {
        auto = true;
    }
    if let Some(p) = args.opt_str("front") {
        front_path = p.to_string();
    }
    if let Some(b) = args.opt_str("accuracy-budget") {
        let b: f64 = b.parse().map_err(|_| {
            anyhow::anyhow!("--accuracy-budget wants a number, \
                             got '{b}'")
        })?;
        accuracy_budget = Some(b);
    }
    if auto {
        let budget = accuracy_budget.context(
            "--auto needs --accuracy-budget (or [serve] \
             accuracy_budget in the config file)",
        )?;
        let raw = std::fs::read_to_string(&front_path)
            .with_context(|| {
                format!("--auto: reading {front_path} (run `lop \
                         explore --front-out {front_path}` first)")
            })?;
        let front = ParetoFront::from_json(&raw)?;
        let chosen = auto_config(&front, &spec, budget)?;
        let detail = front
            .points()
            .iter()
            .find(|p| p.repr_map == chosen)
            .expect("auto_config returns a front point");
        println!(
            "auto: {} from {front_path} (accuracy {:.4} [{}], \
             hw cost {:.4}, budget {budget})",
            chosen.name(),
            detail.accuracy,
            if detail.simulated { "simulated" } else { "surrogate" },
            detail.hw_cost
        );
        sopts.configs = vec![chosen];
    }
    sopts.max_batch = args.usize("max-batch", sopts.max_batch);
    sopts.max_wait = Duration::from_micros(
        (args.f64("max-wait-ms", sopts.max_wait.as_secs_f64() * 1e3)
            * 1e3) as u64,
    );
    sopts.engine_workers =
        args.usize("engine-workers", sopts.engine_workers);
    sopts.plan_cache_bytes =
        args.usize("plan-cache-mb", sopts.plan_cache_bytes >> 20)
            * 1024
            * 1024;
    if args.switch("no-pjrt") {
        sopts.use_pjrt = false;
    }
    if let Some(p) = args.opt_str("overload") {
        sopts.overload = OverloadPolicy::parse(p)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(ms) = args.opt_str("deadline-ms") {
        let ms: f64 = ms.parse().map_err(|_| {
            anyhow::anyhow!("--deadline-ms wants a number, got '{ms}'")
        })?;
        anyhow::ensure!(ms > 0.0, "--deadline-ms must be positive");
        sopts.deadline =
            Some(Duration::from_micros((ms * 1e3) as u64));
    }
    let requests = args.usize("requests", 2_000);
    let rate = args.f64("rate", 500.0); // req/s, open loop
    let stats_every = args.usize("stats-every", stats_every);

    println!("serving benchmark: {requests} requests at {rate} req/s \
              over configs {:?}",
             sopts.configs.iter().map(|c| c.name()).collect::<Vec<_>>());
    println!("batching: max_batch {}, max_wait {:?}, pjrt {}, \
              overload {}, deadline {:?}",
             sopts.max_batch, sopts.max_wait, sopts.use_pjrt,
             sopts.overload.name(), sopts.deadline);

    anyhow::ensure!(
        spec.input_len() == 784,
        "the CLI load generator renders 28x28x1 digits; model '{spec}' \
         wants {} inputs",
        spec.input_len()
    );
    let n_cfg = sopts.configs.len();
    let server = if spec.is_paper_dcnn() {
        Server::start(sopts)?
    } else {
        // non-paper topologies have no trained artifacts: serve a
        // deterministic synthetic model (exercises the full stack —
        // stream accuracy is meaningless on untrained weights)
        println!("model: {spec}");
        println!("(non-paper topology: synthetic weights, engine \
                  backend)");
        Server::start_with_model(
            sopts,
            std::sync::Arc::new(Model::synthetic(spec.clone(), 42)),
            None,
        )?
    };
    let metrics = server.metrics.clone();
    let (tx, rx) = channel();
    let mut rng = Rng::new(99);
    let (images, labels) = synth::generate(256, 4242);

    let t0 = Instant::now();
    let gap = Duration::from_secs_f64(1.0 / rate.max(1.0));
    let mut next = Instant::now();
    let mut rejected = 0usize;
    for i in 0..requests {
        let now = Instant::now();
        if now < next {
            std::thread::sleep(next - now);
        }
        next += gap;
        let img_idx = i % 256;
        let img: Vec<f32> = images[img_idx * 784..(img_idx + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        let cfg = rng.below(n_cfg as u64) as usize;
        // per-request deadlines default to --deadline-ms via the router
        if server.router.submit(cfg, img, None, tx.clone()).is_err() {
            rejected += 1;
        }
    }
    drop(tx);

    // Collect one response per accepted request (ids are sequential ==
    // submission order).  Every admitted request answers, even under
    // shed/expire — only synchronous rejections reply with nothing.
    let mut correct = 0usize;
    let mut served = 0usize;
    let mut got = 0usize;
    // With LOP_TRACE=1 responses carry a per-stage latency breakdown;
    // print the first few so a traced run shows where time goes
    // without flooding 2000 lines.
    let mut breakdowns_shown = 0usize;
    while got + rejected < requests {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(resp) => {
                got += 1;
                if let Some(pred) = resp.pred() {
                    served += 1;
                    let lbl =
                        labels[(resp.id as usize) % 256] as usize;
                    if pred == lbl {
                        correct += 1;
                    }
                }
                if breakdowns_shown < 5 {
                    if let Some(b) = &resp.breakdown {
                        println!("trace req {}: total {:?} | {}",
                                 resp.id, resp.latency, b.render());
                        breakdowns_shown += 1;
                    }
                }
                if stats_every > 0 && got % stats_every == 0 {
                    println!("--- telemetry after {got} responses ---");
                    print!("{}", metrics.snapshot()
                        .merged_with(lop::telemetry::global()
                            .snapshot())
                        .render_prometheus());
                }
            }
            Err(_) => break,
        }
    }
    let wall = t0.elapsed();
    let cache = server.plan_cache.stats();
    server.shutdown()?;

    println!("\n{}", "-".repeat(60));
    println!("plan cache: {} prepares for {} configs ({} hits, \
              {} evictions, {:.2} MiB panels resident)",
             cache.prepares, n_cfg, cache.hits, cache.evictions,
             cache.resident_bytes as f64 / (1024.0 * 1024.0));
    println!("served {served} of {got} answered (rejected {rejected}) \
              in {:.2}s — offered {rate} req/s, served {:.1} req/s",
             wall.as_secs_f64(),
             served as f64 / wall.as_secs_f64().max(1e-9));
    println!("stream accuracy {:.3}",
             correct as f64 / served.max(1) as f64);
    println!("{}", metrics.summary(wall));

    // Shutdown telemetry: the serving registry merged with the
    // process-global one (stage histograms, pack/vecmath counters),
    // as Prometheus text on stdout and as the versioned JSON artifact
    // CI's telemetry-sanity step validates.
    let snap = metrics
        .snapshot()
        .merged_with(lop::telemetry::global().snapshot());
    println!("\n--- telemetry (Prometheus exposition) ---");
    print!("{}", snap.render_prometheus());
    snap.write_json("LOP_TELEMETRY_JSON", "TELEMETRY_serving.json");
    Ok(())
}
