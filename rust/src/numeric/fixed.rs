//! FI(i, f): sign-magnitude fixed point with `i` integral and `f`
//! fractional bits (+ 1 sign bit).  Paper §4.1.1 / Table 2.
//!
//! Semantics are bit-identical to `bitref.fi_quantize` / `fi_encode` /
//! `fi_decode`: round-half-away-from-zero on the magnitude, saturation at
//! `2^i - 2^-f`, -0 normalizes to +0.

use super::traits::Representation;

/// Sign-magnitude fixed point FI(i, f).
///
/// Encode/decode round-trips through the quantized value, and the
/// quantization error inside the representable range is at most half an
/// ulp:
///
/// ```
/// use lop::numeric::{FixedPoint, Representation};
///
/// let rep = FixedPoint::new(6, 8);
/// let q = rep.quantize(1.23456);
/// assert_eq!(rep.decode(rep.encode(1.23456)), q);
/// assert!((q - 1.23456).abs() <= rep.ulp() / 2.0);
/// assert_eq!(rep.total_bits(), 15); // 1 sign + 6 integral + 8 fraction
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FixedPoint {
    pub i_bits: u32,
    pub f_bits: u32,
}

impl FixedPoint {
    /// The coordinator restricts BCIs to i+f <= 22 so the PJRT fake-quant
    /// path (f32 arithmetic) stays bit-exact with this implementation.
    pub const MAX_TOTAL: u32 = 30;

    pub fn new(i_bits: u32, f_bits: u32) -> Self {
        assert!(
            i_bits + f_bits >= 1 && i_bits + f_bits <= Self::MAX_TOTAL,
            "FI({i_bits}, {f_bits}) out of supported range"
        );
        FixedPoint { i_bits, f_bits }
    }

    #[inline]
    pub fn scale(&self) -> f64 {
        (1u64 << self.f_bits) as f64
    }

    /// Largest magnitude code: 2^(i+f) - 1.
    #[inline]
    pub fn max_code(&self) -> u64 {
        (1u64 << (self.i_bits + self.f_bits)) - 1
    }

    /// Quantize to the magnitude code (no sign): round-half-away, saturate.
    #[inline]
    pub fn code_of(&self, x: f32) -> u64 {
        let mag = (x.abs() as f64) * self.scale();
        let k = (mag + 0.5).floor() as u64;
        k.min(self.max_code())
    }

    /// The quantization step (one fractional ulp).
    #[inline]
    pub fn ulp(&self) -> f32 {
        (1.0 / self.scale()) as f32
    }
}

impl Representation for FixedPoint {
    fn name(&self) -> String {
        format!("FI({}, {})", self.i_bits, self.f_bits)
    }

    fn total_bits(&self) -> u32 {
        1 + self.i_bits + self.f_bits
    }

    #[inline]
    fn quantize(&self, x: f32) -> f32 {
        let k = self.code_of(x);
        let v = (k as f64 / self.scale()) as f32;
        if x < 0.0 && v != 0.0 {
            -v
        } else {
            v
        }
    }

    fn encode(&self, x: f32) -> u64 {
        let k = self.code_of(x);
        let sign = if x < 0.0 && k != 0 { 1u64 } else { 0 };
        (sign << (self.i_bits + self.f_bits)) | k
    }

    fn decode(&self, bits: u64) -> f32 {
        let nb = self.i_bits + self.f_bits;
        let k = bits & ((1u64 << nb) - 1);
        let sign = (bits >> nb) & 1;
        let v = (k as f64 / self.scale()) as f32;
        if sign == 1 {
            -v
        } else {
            v
        }
    }

    fn max_value(&self) -> f32 {
        (self.max_code() as f64 / self.scale()) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    #[test]
    fn known_values() {
        let r = FixedPoint::new(4, 8);
        assert_eq!(r.quantize(0.0), 0.0);
        assert_eq!(r.quantize(-0.0), 0.0);
        assert_eq!(r.quantize(1.0), 1.0);
        assert_eq!(r.quantize(1.0 / 512.0), 1.0 / 256.0); // tie away from 0
        assert_eq!(r.quantize(-1.0 / 512.0), -1.0 / 256.0);
        assert_eq!(r.quantize(100.0), r.max_value());
        assert_eq!(r.quantize(-100.0), -r.max_value());
        assert_eq!(r.total_bits(), 13);
        assert_eq!(r.name(), "FI(4, 8)");
    }

    #[test]
    fn integer_special_case() {
        // paper §4.1.1: integer = fixed point with f = 0
        let r = FixedPoint::new(8, 0);
        assert_eq!(r.quantize(3.4), 3.0);
        assert_eq!(r.quantize(3.5), 4.0);
        assert_eq!(r.quantize(-3.5), -4.0);
        assert_eq!(r.max_value(), 255.0);
    }

    #[test]
    fn prop_on_grid_and_saturated() {
        prop::check(
            "fi quantized value is on the grid and within range",
            11,
            prop::DEFAULT_CASES,
            |rng| {
                let i = rng.below(9) as u32;
                let f = rng.below(12) as u32;
                let x = (rng.normal() * 20.0) as f32;
                (FixedPoint::new(i.max(1), f), x)
            },
            |(rep, x)| {
                let q = rep.quantize(*x);
                let k = q as f64 * rep.scale();
                k == k.round() && q.abs() <= rep.max_value()
            },
        );
    }

    #[test]
    fn prop_monotone() {
        prop::check(
            "fi quantize is monotone",
            12,
            prop::DEFAULT_CASES,
            |rng| {
                let rep = FixedPoint::new(1 + rng.below(8) as u32,
                                          rng.below(10) as u32);
                let a = (rng.normal() * 10.0) as f32;
                let b = (rng.normal() * 10.0) as f32;
                (rep, a.min(b), a.max(b))
            },
            |(rep, lo, hi)| rep.quantize(*lo) <= rep.quantize(*hi),
        );
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        prop::check_msg(
            "fi encode/decode roundtrip equals quantize",
            13,
            prop::DEFAULT_CASES,
            |rng| {
                let rep = FixedPoint::new(1 + rng.below(8) as u32,
                                          rng.below(10) as u32);
                (rep, (rng.normal() * 50.0) as f32)
            },
            |(rep, x)| {
                let want = rep.quantize(*x);
                let got = rep.decode(rep.encode(*x));
                if got == want {
                    Ok(())
                } else {
                    Err(format!("got {got}, want {want}"))
                }
            },
        );
    }

    #[test]
    fn prop_error_bound() {
        prop::check(
            "fi error <= half ulp inside range",
            14,
            prop::DEFAULT_CASES,
            |rng| {
                let rep = FixedPoint::new(5, 1 + rng.below(10) as u32);
                (rep, rng.range_f32(-30.0, 30.0))
            },
            |(rep, x)| {
                (rep.quantize(*x) - x).abs() <= rep.ulp() / 2.0 + 1e-9
            },
        );
    }

    #[test]
    fn quantize_idempotent() {
        let mut rng = Rng::new(5);
        let rep = FixedPoint::new(6, 8);
        for _ in 0..500 {
            let x = (rng.normal() * 30.0) as f32;
            let q = rep.quantize(x);
            assert_eq!(rep.quantize(q), q);
        }
    }
}
