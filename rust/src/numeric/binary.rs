//! BinXnor: the paper's §4.5 extensibility example — a binary (0/1)
//! representation whose multiply is XNOR, as in binarized neural networks
//! (Courbariaux et al.).  It is "a new data representation based on
//! fixed-point in which the number of integral bits is one and there are
//! no fractional bits", with `__mul__` overridden to XNOR.

use super::traits::Representation;

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct BinXnor;

impl BinXnor {
    /// The XNOR "multiply": 1 when both bits agree, else 0.
    #[inline]
    pub fn xnor_mul(a: u64, b: u64) -> u64 {
        !(a ^ b) & 1
    }

    /// Binarize a real value: x >= threshold -> 1 else 0.
    #[inline]
    pub fn binarize(x: f32) -> u64 {
        (x >= 0.0) as u64
    }

    /// The +1/-1 interpretation used when mapping XNOR counts back to
    /// real-valued dot products: popcount(xnor) * 2 - n.
    #[inline]
    pub fn to_pm1(bit: u64) -> f32 {
        if bit == 1 {
            1.0
        } else {
            -1.0
        }
    }
}

impl Representation for BinXnor {
    fn name(&self) -> String {
        "BinXNOR".to_string()
    }

    fn total_bits(&self) -> u32 {
        1
    }

    fn quantize(&self, x: f32) -> f32 {
        Self::to_pm1(Self::binarize(x))
    }

    fn encode(&self, x: f32) -> u64 {
        Self::binarize(x)
    }

    fn decode(&self, bits: u64) -> f32 {
        Self::to_pm1(bits & 1)
    }

    fn max_value(&self) -> f32 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xnor_truth_table() {
        assert_eq!(BinXnor::xnor_mul(0, 0), 1);
        assert_eq!(BinXnor::xnor_mul(0, 1), 0);
        assert_eq!(BinXnor::xnor_mul(1, 0), 0);
        assert_eq!(BinXnor::xnor_mul(1, 1), 1);
    }

    #[test]
    fn xnor_equals_pm1_product() {
        // XNOR in {0,1} corresponds to multiplication in {-1,+1}
        for a in 0..2u64 {
            for b in 0..2u64 {
                let pm = BinXnor::to_pm1(a) * BinXnor::to_pm1(b);
                assert_eq!(BinXnor::to_pm1(BinXnor::xnor_mul(a, b)), pm);
            }
        }
    }

    #[test]
    fn quantize_signs() {
        let r = BinXnor;
        assert_eq!(r.quantize(3.2), 1.0);
        assert_eq!(r.quantize(-0.1), -1.0);
        assert_eq!(r.quantize(0.0), 1.0);
        assert_eq!(r.decode(r.encode(-5.0)), -1.0);
    }
}
