//! FL(e, m): floating point with `e` exponent and `m` mantissa bits
//! (+ 1 sign bit).  Paper §4.1.2 / Table 2.
//!
//! Semantics (bit-identical to `bitref.fl_quantize`): implied leading one,
//! IEEE-like bias `2^(e-1)-1`, exponent field 0 reserved for zero
//! (subnormals flush), no inf/nan (top exponent field is an ordinary
//! value), round-to-nearest-even on the mantissa, saturation at the max
//! finite value, magnitudes below the smallest normal round to the nearer
//! of {0, min_normal} with ties to min_normal.

use super::traits::Representation;

/// Customized floating point FL(e, m).
///
/// Encode/decode round-trips through the quantized value, which is
/// idempotent and saturates at the largest finite value:
///
/// ```
/// use lop::numeric::{FloatRep, Representation};
///
/// let rep = FloatRep::new(4, 9);
/// let q = rep.quantize(3.14159);
/// assert_eq!(rep.decode(rep.encode(3.14159)), q);
/// assert_eq!(rep.quantize(q), q); // idempotent
/// assert_eq!(rep.quantize(1e30), rep.max_value()); // saturating
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FloatRep {
    pub e_bits: u32,
    pub m_bits: u32,
}

impl FloatRep {
    pub fn new(e_bits: u32, m_bits: u32) -> Self {
        assert!(
            (2..=8).contains(&e_bits),
            "FL exponent must have 2..=8 bits (got {e_bits})"
        );
        assert!(
            (1..=23).contains(&m_bits),
            "FL mantissa must have 1..=23 bits (got {m_bits}); \
             m = 0 degenerates into the logarithmic representation"
        );
        FloatRep { e_bits, m_bits }
    }

    #[inline]
    pub fn bias(&self) -> i32 {
        (1 << (self.e_bits - 1)) - 1
    }

    #[inline]
    pub fn emin(&self) -> i32 {
        1 - self.bias()
    }

    #[inline]
    pub fn emax(&self) -> i32 {
        ((1 << self.e_bits) - 1) - self.bias()
    }

    #[inline]
    pub fn min_normal(&self) -> f64 {
        exp2i(self.emin())
    }

    #[inline]
    pub fn max_finite(&self) -> f64 {
        (2.0 - exp2i(-(self.m_bits as i32))) * exp2i(self.emax())
    }

    /// Quantize in f64 (exact for f32-valued and product-of-lattice
    /// inputs, whose significands fit 52 bits).
    ///
    /// Implementation is the IEEE bit trick (RNE directly on the binary64
    /// pattern) — ~5x faster than the decompose/round/recompose form it
    /// replaced (§Perf iteration 3); `quantize_f64_ref` in the test module
    /// keeps the readable reference and a property test pins equality.
    #[inline]
    pub fn quantize_f64(&self, x: f64) -> f64 {
        if x == 0.0 || x.is_nan() {
            return 0.0;
        }
        if x.is_infinite() {
            return x.signum() * self.max_finite();
        }
        let bits = x.to_bits();
        let sign = bits & 0x8000_0000_0000_0000;
        let comb = bits & 0x7FFF_FFFF_FFFF_FFFF;
        let shift = 52 - self.m_bits;
        // round-to-nearest-even on the m-bit significand; mantissa carry
        // propagates into the exponent field automatically
        let half = (1u64 << (shift - 1)) - 1;
        let tie = (comb >> shift) & 1;
        let comb2 = (comb + half + tie) & !((1u64 << shift) - 1);
        let e2 = ((comb2 >> 52) as i32) - 1023;
        if e2 > self.emax() {
            let mx = self.max_finite();
            return if sign != 0 { -mx } else { mx };
        }
        if e2 < self.emin() {
            let mn = self.min_normal();
            let a = f64::from_bits(comb);
            let v = if a * 2.0 >= mn { mn } else { 0.0 };
            return if sign != 0 { -v } else { v };
        }
        f64::from_bits(comb2 | sign)
    }
}

/// Exact 2^n for |n| within f64 range.
#[inline]
pub fn exp2i(n: i32) -> f64 {
    f64::from_bits(((n + 1023) as u64) << 52)
}

/// Round-half-to-even of a non-negative f64 that is exactly representable
/// (arguments here have <= 53 significant bits by construction).  Used by
/// the reference implementation in the test module.
#[cfg(test)]
fn round_half_even(x: f64) -> i64 {
    let lo = x.floor();
    let frac = x - lo;
    let lo = lo as i64;
    if frac > 0.5 {
        lo + 1
    } else if frac < 0.5 {
        lo
    } else {
        lo + (lo & 1)
    }
}

impl Representation for FloatRep {
    fn name(&self) -> String {
        format!("FL({}, {})", self.e_bits, self.m_bits)
    }

    fn total_bits(&self) -> u32 {
        1 + self.e_bits + self.m_bits
    }

    #[inline]
    fn quantize(&self, x: f32) -> f32 {
        self.quantize_f64(x as f64) as f32
    }

    fn encode(&self, x: f32) -> u64 {
        let q = self.quantize_f64(x as f64);
        if q == 0.0 {
            return 0;
        }
        let sign = if q < 0.0 { 1u64 } else { 0 };
        let a = q.abs();
        let mut eu = ((a.to_bits() >> 52) & 0x7FF) as i32 - 1023;
        let mut sig = a / exp2i(eu);
        if sig >= 2.0 {
            eu += 1;
            sig /= 2.0;
        }
        let field = (eu + self.bias()) as u64;
        let man = ((sig - 1.0) * (1u64 << self.m_bits) as f64).round() as u64;
        debug_assert!(field >= 1 && field < (1 << self.e_bits));
        (sign << (self.e_bits + self.m_bits)) | (field << self.m_bits) | man
    }

    fn decode(&self, bits: u64) -> f32 {
        let man = bits & ((1u64 << self.m_bits) - 1);
        let field = (bits >> self.m_bits) & ((1u64 << self.e_bits) - 1);
        let sign = (bits >> (self.e_bits + self.m_bits)) & 1;
        if field == 0 {
            return 0.0;
        }
        let v = (1.0 + man as f64 / (1u64 << self.m_bits) as f64)
            * exp2i(field as i32 - self.bias());
        (if sign == 1 { -v } else { v }) as f32
    }

    fn max_value(&self) -> f32 {
        self.max_finite() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// The readable decompose/round/recompose reference that
    /// `quantize_f64` (bit-trick form) must match exactly.
    fn quantize_f64_ref(rep: &FloatRep, x: f64) -> f64 {
        if x == 0.0 || x.is_nan() {
            return 0.0;
        }
        if x.is_infinite() {
            return x.signum() * rep.max_finite();
        }
        let sign = if x < 0.0 { -1.0 } else { 1.0 };
        let a = x.abs();
        let mut eu = ((a.to_bits() >> 52) & 0x7FF) as i32 - 1023;
        let mut sig = a / exp2i(eu);
        let mut k = round_half_even(sig * (1u64 << rep.m_bits) as f64);
        if k == (1u64 << (rep.m_bits + 1)) as i64 {
            k = (1u64 << rep.m_bits) as i64;
            eu += 1;
        }
        sig = k as f64 / (1u64 << rep.m_bits) as f64;
        let y = sig * exp2i(eu);
        if y > rep.max_finite() {
            return sign * rep.max_finite();
        }
        let mn = rep.min_normal();
        if y < mn {
            return sign * if a * 2.0 >= mn { mn } else { 0.0 };
        }
        sign * y
    }

    #[test]
    fn prop_bit_trick_matches_reference() {
        prop::check_msg(
            "fast quantize_f64 == reference implementation",
            77,
            1024,
            |rng| {
                let rep = FloatRep::new(2 + rng.below(6) as u32,
                                        1 + rng.below(20) as u32);
                // cover normals, near-ties, tiny and huge magnitudes
                let x = match rng.below(4) {
                    0 => rng.normal() * 100.0,
                    1 => rng.normal() * 1e-8,
                    2 => rng.normal() * 1e12,
                    _ => {
                        // exact product of two lattice values (GEMM case)
                        let a = rep.quantize((rng.normal() * 10.0) as f32);
                        let b = rep.quantize((rng.normal() * 10.0) as f32);
                        return (rep, a as f64 * b as f64);
                    }
                };
                (rep, x)
            },
            |(rep, x)| {
                let fast = rep.quantize_f64(*x);
                let refv = quantize_f64_ref(rep, *x);
                if fast.to_bits() == refv.to_bits()
                    || (fast == 0.0 && refv == 0.0)
                {
                    Ok(())
                } else {
                    Err(format!("fast {fast} != ref {refv}"))
                }
            },
        );
    }

    #[test]
    fn known_values() {
        let r = FloatRep::new(4, 9);
        assert_eq!(r.bias(), 7);
        assert_eq!(r.emin(), -6);
        assert_eq!(r.emax(), 8);
        assert_eq!(r.quantize(1.0), 1.0);
        assert_eq!(r.quantize(-1.0), -1.0);
        assert_eq!(r.quantize(0.0), 0.0);
        assert_eq!(r.quantize(1e30), r.max_value());
        assert_eq!(r.quantize(-1e30), -r.max_value());
        assert_eq!(r.total_bits(), 14);
        assert_eq!(r.name(), "FL(4, 9)");
    }

    #[test]
    fn min_normal_rounding() {
        let r = FloatRep::new(4, 9);
        let mn = r.min_normal() as f32;
        assert_eq!(r.quantize(mn * 0.49), 0.0);
        assert_eq!(r.quantize(mn * 0.51), mn);
        assert_eq!(r.quantize(mn * 0.5), mn); // tie -> min normal
        assert_eq!(r.quantize(-mn * 0.5), -mn);
    }

    #[test]
    fn rne_tie_to_even() {
        let r = FloatRep::new(4, 2);
        // 1.125 is halfway between 1.0 (mantissa 00, even) and 1.25
        assert_eq!(r.quantize(1.125), 1.0);
        // 1.375 is halfway between 1.25 (01) and 1.5 (10, even)
        assert_eq!(r.quantize(1.375), 1.5);
    }

    #[test]
    fn prop_idempotent() {
        prop::check(
            "fl quantize idempotent",
            21,
            prop::DEFAULT_CASES,
            |rng| {
                let rep = FloatRep::new(2 + rng.below(6) as u32,
                                        1 + rng.below(15) as u32);
                (rep, (rng.normal() * 100.0) as f32)
            },
            |(rep, x)| {
                let q = rep.quantize(*x);
                rep.quantize(q) == q
            },
        );
    }

    #[test]
    fn prop_monotone() {
        prop::check(
            "fl quantize monotone",
            22,
            prop::DEFAULT_CASES,
            |rng| {
                let rep = FloatRep::new(2 + rng.below(6) as u32,
                                        1 + rng.below(12) as u32);
                let a = (rng.normal() * 100.0) as f32;
                let b = (rng.normal() * 100.0) as f32;
                (rep, a.min(b), a.max(b))
            },
            |(rep, lo, hi)| rep.quantize(*lo) <= rep.quantize(*hi),
        );
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        prop::check_msg(
            "fl encode/decode roundtrip equals quantize",
            23,
            prop::DEFAULT_CASES,
            |rng| {
                let rep = FloatRep::new(2 + rng.below(6) as u32,
                                        1 + rng.below(14) as u32);
                (rep, (rng.normal() * 1000.0) as f32)
            },
            |(rep, x)| {
                let want = rep.quantize(*x);
                let got = rep.decode(rep.encode(*x));
                if got == want {
                    Ok(())
                } else {
                    Err(format!("got {got}, want {want}"))
                }
            },
        );
    }

    #[test]
    fn prop_relative_error_bound() {
        prop::check(
            "fl relative error <= 2^-(m+1) inside normal range",
            24,
            prop::DEFAULT_CASES,
            |rng| {
                let rep = FloatRep::new(5, 1 + rng.below(12) as u32);
                (rep, rng.range_f32(0.001, 1000.0))
            },
            |(rep, x)| {
                let q = rep.quantize(*x) as f64;
                let x = *x as f64;
                if x < rep.min_normal() || x > rep.max_finite() {
                    true
                } else {
                    (q - x).abs() / x <= exp2i(-(rep.m_bits as i32 + 1)) + 1e-12
                }
            },
        );
    }

    #[test]
    fn exp2i_exact() {
        assert_eq!(exp2i(0), 1.0);
        assert_eq!(exp2i(-62), 2.0f64.powi(-62));
        assert_eq!(exp2i(64), 2.0f64.powi(64));
    }
}
