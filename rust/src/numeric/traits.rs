//! The `Representation` trait: what every customizable data representation
//! implements (paper §4.1 — "a Numeric class for each data representation").

/// A customizable data representation: a finite lattice of representable
/// values plus an encoding to hardware bit patterns.
pub trait Representation: Send + Sync + std::fmt::Debug {
    /// Short notation used in reports, e.g. `FI(6, 8)` / `FL(4, 9)`.
    fn name(&self) -> String;

    /// Total storage bits (sign included).
    fn total_bits(&self) -> u32;

    /// Snap `x` onto the representation lattice (round + saturate).
    fn quantize(&self, x: f32) -> f32;

    /// Encode the quantized value of `x` as a bit pattern.
    fn encode(&self, x: f32) -> u64;

    /// Decode a bit pattern back to its real value.
    fn decode(&self, bits: u64) -> f32;

    /// Largest representable magnitude.
    fn max_value(&self) -> f32;

    /// Quantize a whole slice in place (hot path for weight conversion).
    fn quantize_slice(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.quantize(*x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{FixedPoint, FloatRep};
    use crate::util::prop;

    fn slice_matches_scalar<R: Representation>(rep: &R, xs: &[f32])
                                               -> Result<(), String> {
        let mut ys = xs.to_vec();
        rep.quantize_slice(&mut ys);
        for (x, y) in xs.iter().zip(&ys) {
            let want = rep.quantize(*x);
            if want.to_bits() != y.to_bits() {
                return Err(format!(
                    "{}: quantize_slice({x}) = {y}, scalar = {want}",
                    rep.name()
                ));
            }
        }
        Ok(())
    }

    #[test]
    fn prop_quantize_slice_matches_scalar_fi() {
        prop::check_msg(
            "quantize_slice == scalar quantize (FI, random widths)",
            31,
            prop::DEFAULT_CASES,
            |rng| {
                let rep = FixedPoint::new(rng.below(9) as u32,
                                          1 + rng.below(12) as u32);
                let xs: Vec<f32> = (0..8)
                    .map(|_| (rng.normal() * 40.0) as f32)
                    .collect();
                (rep, xs)
            },
            |(rep, xs)| slice_matches_scalar(rep, xs),
        );
    }

    #[test]
    fn prop_quantize_slice_matches_scalar_fl() {
        prop::check_msg(
            "quantize_slice == scalar quantize (FL, random widths)",
            32,
            prop::DEFAULT_CASES,
            |rng| {
                let rep = FloatRep::new(2 + rng.below(7) as u32,
                                        1 + rng.below(23) as u32);
                let xs: Vec<f32> = (0..8)
                    .map(|_| (rng.normal() * 100.0) as f32)
                    .collect();
                (rep, xs)
            },
            |(rep, xs)| slice_matches_scalar(rep, xs),
        );
    }

    #[test]
    fn quantize_slice_edge_values() {
        // the original one-off fixture, kept for the saturation and
        // signed-zero edges random draws rarely hit
        let rep = FixedPoint::new(4, 6);
        let xs = [0.37f32, -2.11, 100.0, -100.0, 0.0, -0.0];
        slice_matches_scalar(&rep, &xs).unwrap();
    }
}
