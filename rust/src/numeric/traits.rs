//! The `Representation` trait: what every customizable data representation
//! implements (paper §4.1 — "a Numeric class for each data representation").

/// A customizable data representation: a finite lattice of representable
/// values plus an encoding to hardware bit patterns.
pub trait Representation: Send + Sync + std::fmt::Debug {
    /// Short notation used in reports, e.g. `FI(6, 8)` / `FL(4, 9)`.
    fn name(&self) -> String;

    /// Total storage bits (sign included).
    fn total_bits(&self) -> u32;

    /// Snap `x` onto the representation lattice (round + saturate).
    fn quantize(&self, x: f32) -> f32;

    /// Encode the quantized value of `x` as a bit pattern.
    fn encode(&self, x: f32) -> u64;

    /// Decode a bit pattern back to its real value.
    fn decode(&self, bits: u64) -> f32;

    /// Largest representable magnitude.
    fn max_value(&self) -> f32;

    /// Quantize a whole slice in place (hot path for weight conversion).
    fn quantize_slice(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.quantize(*x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::FixedPoint;

    #[test]
    fn quantize_slice_matches_scalar() {
        let rep = FixedPoint::new(4, 6);
        let xs = [0.37f32, -2.11, 100.0, -100.0, 0.0];
        let mut ys = xs;
        rep.quantize_slice(&mut ys);
        for (x, y) in xs.iter().zip(ys.iter()) {
            assert_eq!(rep.quantize(*x), *y);
        }
    }
}
