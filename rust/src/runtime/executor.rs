//! Model executor: compiles the HLO artifacts once per (variant, batch)
//! and runs batched forward passes with weights resident on the device.
//!
//! The real executor drives a PJRT CPU client through external XLA
//! bindings (the `xla` crate from xla-rs) and is gated behind the
//! `pjrt` cargo feature — the offline build image carries no XLA
//! bindings, so the default build compiles an API-compatible stub whose
//! constructor fails with a clear message (see DESIGN.md §5).  Variant
//! selection and quantization-scalar packing are pure functions and stay
//! available in every build.
//!
//! Performance notes (§Perf in EXPERIMENTS.md): weight tensors are
//! uploaded once per network configuration and cached as `PjRtBuffer`s
//! (12.8 MB — re-uploading them per batch dominated early profiles);
//! executables are compiled lazily and cached; inputs are padded to the
//! nearest lowered batch size.

use crate::approx::arith::ArithKind;
use crate::nn::spec::ReprMap;
use crate::runtime::artifact::ArtifactDir;
use anyhow::{bail, ensure, Result};

/// Try to start the PJRT runner, warning on stderr and returning `None`
/// when the backend is unavailable (a build without the `pjrt` feature,
/// or a genuine PJRT init failure) so callers fall back to the
/// bit-accurate engine backend.
pub fn runner_or_warn(art: ArtifactDir) -> Option<ModelRunner> {
    match ModelRunner::new(art) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("note: PJRT backend unavailable ({e}); \
                       using the bit-accurate engine");
            None
        }
    }
}

/// Which AOT artifact family a configuration runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    F32,
    Fi,
    Fl,
}

impl Variant {
    pub fn tag(&self) -> &'static str {
        match self {
            Variant::F32 => "f32",
            Variant::Fi => "fi",
            Variant::Fl => "fl",
        }
    }

    /// Decide the artifact for a network configuration, or None when
    /// the config needs the bit-accurate engine (approximate
    /// multipliers or mixed representation families).  Note the
    /// artifacts only implement the *paper* topology — callers gate
    /// on `NetSpec::is_paper_dcnn` before trusting a `Some`.
    pub fn for_config(cfg: &ReprMap) -> Option<Variant> {
        if cfg.kinds().iter().all(|l| matches!(l, ArithKind::Float32)) {
            return Some(Variant::F32);
        }
        if cfg
            .kinds()
            .iter()
            .all(|l| matches!(l, ArithKind::FixedExact(_)))
        {
            return Some(Variant::Fi);
        }
        if cfg
            .kinds()
            .iter()
            .all(|l| matches!(l, ArithKind::FloatExact(_)))
        {
            return Some(Variant::Fl);
        }
        None
    }
}

/// How a configuration will execute: a PJRT artifact variant (exact
/// arithmetic, XLA-compiled), or the bit-accurate engine with the
/// per-layer packed GEMM kernels `nn::gemm::select_kernel` resolves.
///
/// This is the kernel-selection seam between L2 and L3: the evaluator
/// picks its backend through it, and serving/reporting code can name
/// the exact kernels a config runs on without preparing a network.
/// Both backends keep the constant weight side resident: PJRT uploads
/// weight buffers once per config, the engine conditions each layer's
/// weights into prepacked kernel panels once in `Model::prepare`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecutionPlan {
    /// Runs on the PJRT fake-quant artifacts (when a runner exists).
    Pjrt(Variant),
    /// Runs on the engine; one packed-kernel name per layer (e.g.
    /// `packed-drum`), matching `PreparedNet::kernel_names`.  Each
    /// layer's plan carries its prepacked weight panels after
    /// `Model::prepare`.
    Engine(Vec<&'static str>),
}

impl ExecutionPlan {
    /// The per-layer engine kernel names, `None` for PJRT plans — for
    /// serving/reporting code that wants to print what a config's
    /// forwards will run on (e.g. `examples/serve_inference.rs`).
    pub fn engine_kernels(&self) -> Option<&[&'static str]> {
        match self {
            ExecutionPlan::Engine(names) => Some(names),
            ExecutionPlan::Pjrt(_) => None,
        }
    }

    /// True when this plan targets a PJRT artifact variant; its
    /// negation means the config runs on the engine (whose
    /// `PreparedNet` the serving stack shares through
    /// `coordinator::plan_cache`).  Callers still need a live runner —
    /// without one (stub build, init failure) even a PJRT plan falls
    /// back to the engine.  Used by the server's worker-mask split and
    /// the evaluator's backend choice.
    pub fn is_pjrt(&self) -> bool {
        matches!(self, ExecutionPlan::Pjrt(_))
    }
}

/// Decide the execution plan for `cfg`.  Configs with an expressible
/// artifact variant plan for PJRT (callers without a live runner — or
/// with a non-paper topology — fall back to the engine); everything
/// else names its engine kernels, one per layer, however many the
/// config has.
pub fn execution_plan(cfg: &ReprMap) -> ExecutionPlan {
    match Variant::for_config(cfg) {
        Some(v) => ExecutionPlan::Pjrt(v),
        None => ExecutionPlan::Engine(
            cfg.kinds()
                .iter()
                .map(crate::nn::gemm::kernel_name)
                .collect(),
        ),
    }
}

/// Quantization scalars (q0, q1) per layer for the fi/fl artifacts
/// (which implement the 4-layer paper topology only).
pub fn quant_scalars(cfg: &ReprMap) -> Result<Vec<f32>> {
    ensure!(cfg.len() == 4,
            "the AOT artifacts implement the 4-layer paper DCNN; \
             config has {} layers", cfg.len());
    let mut out = Vec::with_capacity(8);
    for l in cfg.kinds() {
        match l {
            ArithKind::Float32 => out.extend([0.0, 0.0]),
            ArithKind::FixedExact(r) => {
                out.push((1u64 << r.f_bits) as f32); // scale
                out.push(r.max_code() as f32); // maxk
            }
            ArithKind::FloatExact(r) => {
                out.push(r.e_bits as f32);
                out.push(r.m_bits as f32);
            }
            other => bail!(
                "config {} is not PJRT-expressible",
                other.name()
            ),
        }
    }
    Ok(out)
}

#[cfg(feature = "pjrt")]
mod pjrt_runner {
    use super::{quant_scalars, Variant};
    use crate::approx::arith::ArithKind;
    use crate::nn::loader::load_weights;
    use crate::nn::loader::PARAM_NAMES;
    use crate::nn::spec::ReprMap;
    use crate::nn::tensor::Tensor;
    use crate::runtime::artifact::ArtifactDir;
    use anyhow::{Context, Result};
    use std::collections::HashMap;

    pub struct ModelRunner {
        client: xla::PjRtClient,
        pub art: ArtifactDir,
        /// float32 parameters in artifact order: (dims, data)
        weights: Vec<(Vec<usize>, Vec<f32>)>,
        execs: HashMap<(Variant, usize), xla::PjRtLoadedExecutable>,
        /// uploaded (possibly quantized) weight buffers, keyed by config
        /// name
        wbufs: HashMap<String, Vec<xla::PjRtBuffer>>,
        pub compile_count: usize,
    }

    impl ModelRunner {
        pub fn new(art: ArtifactDir) -> Result<ModelRunner> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
            let params = load_weights(&art.weights_path())?;
            crate::nn::loader::validate_dcnn(&params)?;
            let weights = PARAM_NAMES
                .iter()
                .map(|n| {
                    let t = &params[*n];
                    (t.shape.clone(), t.data.clone())
                })
                .collect();
            Ok(ModelRunner {
                client,
                art,
                weights,
                execs: HashMap::new(),
                wbufs: HashMap::new(),
                compile_count: 0,
            })
        }

        fn executable(&mut self, variant: Variant, batch: usize)
                      -> Result<&xla::PjRtLoadedExecutable> {
            if !self.execs.contains_key(&(variant, batch)) {
                let path = self.art.hlo_path(variant.tag(), batch);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )
                .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| {
                        anyhow::anyhow!("compiling {path:?}: {e}")
                    })?;
                self.compile_count += 1;
                self.execs.insert((variant, batch), exe);
            }
            Ok(&self.execs[&(variant, batch)])
        }

        /// Upload (quantizing first when required) the weight set for
        /// `cfg`.
        fn weight_buffers(&mut self, cfg: &ReprMap)
                          -> Result<&Vec<xla::PjRtBuffer>> {
            let key = cfg.name();
            if !self.wbufs.contains_key(&key) {
                let mut bufs = Vec::with_capacity(8);
                for (pi, (dims, data)) in self.weights.iter().enumerate() {
                    let kind = cfg.kind(pi / 2); // w, b alternate
                    let qdata: Vec<f32> = match kind {
                        ArithKind::Float32 => data.clone(),
                        k => data.iter().map(|&v| k.quantize(v)).collect(),
                    };
                    let buf = self
                        .client
                        .buffer_from_host_buffer::<f32>(&qdata, dims, None)
                        .map_err(|e| {
                            anyhow::anyhow!("uploading weights: {e}")
                        })?;
                    bufs.push(buf);
                }
                self.wbufs.insert(key.clone(), bufs);
            }
            Ok(&self.wbufs[&key])
        }

        /// Run a forward pass for `cfg` over `x` ([n,28,28,1] tensor);
        /// returns logits [n,10].  Pads to the nearest lowered batch size
        /// internally.
        pub fn forward(&mut self, cfg: &ReprMap, x: &Tensor)
                       -> Result<Tensor> {
            let variant = Variant::for_config(cfg).with_context(|| {
                format!("config {} is not PJRT-expressible", cfg.name())
            })?;
            let n = x.shape[0];
            assert_eq!(&x.shape[1..], &[28, 28, 1]);
            let mut logits = Vec::with_capacity(n * 10);
            let mut done = 0;
            while done < n {
                let chunk =
                    (n - done).min(*self.art.batch_sizes.last().unwrap());
                let batch = self.art.batch_for(chunk);
                let mut padded = vec![0.0f32; batch * 784];
                padded[..chunk * 784].copy_from_slice(
                    &x.data[done * 784..(done + chunk) * 784],
                );
                let out =
                    self.forward_padded(cfg, variant, &padded, batch)?;
                logits.extend_from_slice(&out[..chunk * 10]);
                done += chunk;
            }
            Ok(Tensor::new(vec![n, 10], logits))
        }

        fn forward_padded(&mut self, cfg: &ReprMap, variant: Variant,
                          padded: &[f32], batch: usize)
                          -> Result<Vec<f32>> {
            let scalars = if variant == Variant::F32 {
                Vec::new()
            } else {
                quant_scalars(cfg)?
            };
            // upload input + scalars
            let xbuf = self
                .client
                .buffer_from_host_buffer::<f32>(padded,
                                                &[batch, 28, 28, 1],
                                                None)
                .map_err(|e| anyhow::anyhow!("uploading input: {e}"))?;
            let mut sbufs = Vec::with_capacity(scalars.len());
            for s in &scalars {
                sbufs.push(
                    self.client
                        .buffer_from_host_buffer::<f32>(&[*s], &[], None)
                        .map_err(|e| {
                            anyhow::anyhow!("uploading scalar: {e}")
                        })?,
                );
            }
            // ensure weights + executable exist (two-phase to appease
            // borrows)
            self.weight_buffers(cfg)?;
            self.executable(variant, batch)?;
            let wbufs = &self.wbufs[&cfg.name()];
            let exe = &self.execs[&(variant, batch)];

            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(17);
            args.push(&xbuf);
            args.extend(wbufs.iter());
            args.extend(sbufs.iter());
            let result = exe
                .execute_b::<&xla::PjRtBuffer>(&args)
                .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("download: {e}"))?;
            let out = lit
                .to_tuple1()
                .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
            let v = out
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
            anyhow::ensure!(v.len() == batch * 10,
                            "bad output size {}", v.len());
            Ok(v)
        }

        /// Number of executables compiled so far (for cache-behavior
        /// tests).
        pub fn cached_executables(&self) -> usize {
            self.execs.len()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_runner::ModelRunner;

#[cfg(not(feature = "pjrt"))]
mod stub_runner {
    use crate::nn::spec::ReprMap;
    use crate::nn::tensor::Tensor;
    use crate::runtime::artifact::ArtifactDir;
    use anyhow::{bail, Result};

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: this build has no XLA bindings \
         (rebuild with `--features pjrt` and the xla dependency, see \
         DESIGN.md §5); exact-arithmetic configs still run on the \
         bit-accurate engine backend";

    /// API-compatible stand-in for the PJRT [`ModelRunner`] used when the
    /// crate is built without the `pjrt` feature.  Construction fails, so
    /// callers holding `Option<ModelRunner>` (the evaluator, the server's
    /// worker pool) fall back to the bit-accurate engine backend.
    pub struct ModelRunner {
        /// kept for API parity: `examples/explore_dse.rs` reads it
        pub art: ArtifactDir,
    }

    impl ModelRunner {
        pub fn new(_art: ArtifactDir) -> Result<ModelRunner> {
            bail!(UNAVAILABLE)
        }

        pub fn forward(&mut self, _cfg: &ReprMap, _x: &Tensor)
                       -> Result<Tensor> {
            bail!(UNAVAILABLE)
        }

        /// Number of executables compiled so far (always zero: the stub
        /// cannot be constructed).
        pub fn cached_executables(&self) -> usize {
            0
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_runner::ModelRunner;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{FixedPoint, FloatRep};

    fn cfg4(s: &str) -> ReprMap {
        ReprMap::parse_n(s, 4).unwrap()
    }

    #[test]
    fn variant_selection() {
        let f32cfg = ReprMap::uniform(ArithKind::Float32, 4);
        assert_eq!(Variant::for_config(&f32cfg), Some(Variant::F32));
        let fi = ReprMap::uniform(
            ArithKind::FixedExact(FixedPoint::new(6, 8)),
            4,
        );
        assert_eq!(Variant::for_config(&fi), Some(Variant::Fi));
        let fl = ReprMap::uniform(
            ArithKind::FloatExact(FloatRep::new(4, 9)),
            4,
        );
        assert_eq!(Variant::for_config(&fl), Some(Variant::Fl));
        let h = cfg4("H(6,8,12)");
        assert_eq!(Variant::for_config(&h), None);
        let mixed = cfg4("FI(6,8)|FI(6,8)|FL(4,9)|FL(4,9)");
        assert_eq!(Variant::for_config(&mixed), None);
    }

    #[test]
    fn execution_plan_selection() {
        let fi = ReprMap::uniform(
            ArithKind::FixedExact(FixedPoint::new(6, 8)),
            4,
        );
        assert_eq!(execution_plan(&fi),
                   ExecutionPlan::Pjrt(Variant::Fi));
        assert_eq!(execution_plan(&fi).engine_kernels(), None);
        assert!(execution_plan(&fi).is_pjrt());
        let mixed = cfg4("FI(6,8)|FI(6,8)|H(8,8,14)|I(5,10)");
        // kernel names are ISA-suffixed under native dispatch; derive
        // the expectation from the dispatcher (cfpu never suffixes —
        // it has no SIMD variant)
        let want: Vec<&'static str> = ["FI(6,8)", "FI(6,8)",
                                       "H(8,8,14)", "I(5,10)"]
            .iter()
            .map(|s| {
                crate::nn::gemm::kernel_name(
                    &ArithKind::parse(s).unwrap())
            })
            .collect();
        assert_eq!(execution_plan(&mixed),
                   ExecutionPlan::Engine(want.clone()));
        assert_eq!(execution_plan(&mixed).engine_kernels(),
                   Some(&want[..]));
        assert_eq!(want[3], "packed-cfpu");
        assert!(!execution_plan(&mixed).is_pjrt());
        // engine plans follow the config's arity, not a fixed 4
        let five = ReprMap::parse_n("H(6,8,12)", 5).unwrap();
        assert_eq!(execution_plan(&five).engine_kernels()
                       .map(|k| k.len()),
                   Some(5));
    }

    #[test]
    fn scalar_packing() {
        let cfg = cfg4("FI(5,8)|FI(5,8)|FI(6,8)|FI(6,8)");
        let s = quant_scalars(&cfg).unwrap();
        assert_eq!(s.len(), 8);
        assert_eq!(s[0], 256.0); // 2^8
        assert_eq!(s[1], (1u64 << 13) as f32 - 1.0); // 2^(5+8)-1
        assert_eq!(s[4], 256.0);
        assert_eq!(s[5], (1u64 << 14) as f32 - 1.0);
        let flc = cfg4("FL(4,9)");
        let s = quant_scalars(&flc).unwrap();
        assert_eq!(&s[0..2], &[4.0, 9.0]);
        assert!(quant_scalars(&cfg4("I(5,10)")).is_err());
        // non-paper arity is rejected, not silently mis-packed
        let five = ReprMap::uniform(ArithKind::Float32, 5);
        assert!(quant_scalars(&five).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runner_fails_with_clear_message() {
        let art = ArtifactDir {
            root: std::path::PathBuf::from("/nonexistent"),
            batch_sizes: vec![1],
            baseline_accuracy: 0.0,
        };
        let err = match ModelRunner::new(art) {
            Ok(_) => panic!("stub ModelRunner must not construct"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("pjrt"), "unhelpful error: {err}");
    }
}
