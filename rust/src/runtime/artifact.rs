//! Artifact directory discovery and inventory (`artifacts/` produced by
//! `make artifacts`): HLO text modules, weights, dataset, ranges, meta.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ArtifactDir {
    pub root: PathBuf,
    /// batch sizes the fwd artifacts were lowered for (ascending)
    pub batch_sizes: Vec<usize>,
    pub baseline_accuracy: f64,
}

impl ArtifactDir {
    /// Resolve the artifact directory: `$LOP_ARTIFACTS`, or `./artifacts`,
    /// or `<manifest>/artifacts`.
    pub fn discover() -> Result<ArtifactDir> {
        let mut candidates = Vec::new();
        if let Ok(p) = std::env::var("LOP_ARTIFACTS") {
            candidates.push(PathBuf::from(p));
        }
        candidates.push(PathBuf::from("artifacts"));
        candidates.push(
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        );
        for c in candidates {
            if c.join("meta.json").is_file() {
                return Self::open(&c);
            }
        }
        bail!(
            "artifacts not found — run `make artifacts` first \
             (or set LOP_ARTIFACTS)"
        )
    }

    pub fn open(root: &Path) -> Result<ArtifactDir> {
        let meta_raw = std::fs::read_to_string(root.join("meta.json"))
            .with_context(|| format!("reading {:?}", root.join("meta.json")))?;
        let meta = Json::parse(&meta_raw)
            .map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
        let batch_sizes: Vec<usize> = meta
            .get("batch_sizes")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(Json::as_f64)
                    .map(|f| f as usize)
                    .collect()
            })
            .unwrap_or_default();
        if batch_sizes.is_empty() {
            bail!("meta.json has no batch_sizes");
        }
        let baseline_accuracy = meta
            .get("baseline_accuracy")
            .and_then(Json::as_f64)
            .context("meta.json missing baseline_accuracy")?;
        Ok(ArtifactDir {
            root: root.to_path_buf(),
            batch_sizes,
            baseline_accuracy,
        })
    }

    pub fn hlo_path(&self, variant: &str, batch: usize) -> PathBuf {
        self.root.join(format!("fwd_{variant}_b{batch}.hlo.txt"))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.root.join("weights.bin")
    }

    pub fn dataset_path(&self) -> PathBuf {
        self.root.join("dataset.bin")
    }

    pub fn ranges_path(&self) -> PathBuf {
        self.root.join("ranges.json")
    }

    /// Smallest lowered batch size >= n, or the largest available.
    pub fn batch_for(&self, n: usize) -> usize {
        for &b in &self.batch_sizes {
            if b >= n {
                return b;
            }
        }
        *self.batch_sizes.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_for_picks_smallest_fit() {
        let a = ArtifactDir {
            root: PathBuf::from("/x"),
            batch_sizes: vec![1, 16, 64],
            baseline_accuracy: 0.95,
        };
        assert_eq!(a.batch_for(1), 1);
        assert_eq!(a.batch_for(2), 16);
        assert_eq!(a.batch_for(16), 16);
        assert_eq!(a.batch_for(17), 64);
        assert_eq!(a.batch_for(1000), 64);
    }

    #[test]
    fn hlo_path_naming() {
        let a = ArtifactDir {
            root: PathBuf::from("/art"),
            batch_sizes: vec![1],
            baseline_accuracy: 0.9,
        };
        assert_eq!(
            a.hlo_path("fi", 16),
            PathBuf::from("/art/fwd_fi_b16.hlo.txt")
        );
    }
}
