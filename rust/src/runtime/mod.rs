//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` and executes them on the CPU PJRT client from the L3
//! request path — Python never runs at inference time.
//!
//! Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md
//! and python/compile/aot.py).

pub mod artifact;
pub mod executor;

pub use artifact::ArtifactDir;
pub use executor::{execution_plan, runner_or_warn, ExecutionPlan,
                   ModelRunner, Variant};
