//! Minimal JSON parser (serde is not in the offline crate set).
//!
//! Supports the full JSON grammar minus exotic escapes (\u is decoded for
//! the BMP only), which is all the build artifacts (`ranges.json`,
//! `meta.json`) need.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(c) => {
                    // copy one UTF-8 scalar
                    let len = utf8_len(c);
                    let end = (self.i + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[self.i..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn ranges_json_shape() {
        // mirrors what aot.py writes
        let j = Json::parse(
            r#"{"conv1": {"w": [-0.5, 0.5], "range": [-1.0, 1.0]}}"#,
        )
        .unwrap();
        let r = j.get("conv1").unwrap().get("range").unwrap();
        assert_eq!(r.idx(0).unwrap().as_f64(), Some(-1.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nulll").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(),
                   Json::Str("é".into()));
    }
}
