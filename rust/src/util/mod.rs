//! Infrastructure substrates built from scratch (no external crates are
//! available offline beyond the vendored set): PRNG, a mini property-test
//! harness, a bench timing harness, and a small JSON parser.

pub mod bench;
pub mod json;
pub mod prng;
pub mod prop;
