//! Bench timing harness (criterion is not in the offline crate set).
//!
//! `cargo bench` targets are declared with `harness = false` and drive this
//! module: warmup, timed iterations, and a summary with mean / p50 / p99.
//!
//! Iteration times land in a [`crate::telemetry::Histogram`] (log2
//! buckets), so bench percentiles come from the same read-out the
//! serving metrics use: a percentile is the covering bucket's upper
//! bound clamped to the observed max — within 2x of the true sample
//! value, exact at p100.  No bench keeps a private sorted-`Vec`
//! percentile path.

use crate::telemetry::Histogram;
use std::time::Instant;

/// Result of one benchmark: per-iteration wall times in nanoseconds,
/// accumulated in a shared-shape telemetry histogram.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub hist: Histogram,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.hist.mean()
    }

    /// Bucketed percentile in nanoseconds (see the module docs for
    /// the error bound).
    pub fn percentile_ns(&self, p: f64) -> u64 {
        self.hist.percentile(p)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.percentile_ns(50.0) as f64),
            fmt_ns(self.percentile_ns(99.0) as f64),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let hist = Histogram::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        hist.record(t0.elapsed().as_nanos() as u64);
    }
    BenchResult { name: name.to_string(), iters, hist }
}

/// Print the standard header row for a bench table.
pub fn header() {
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "p50", "p99"
    );
    println!("{}", "-".repeat(86));
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write a bench's rows as the repo's standard JSON artifact
/// (`{"bench": <name>, "rows": [{...}, ...]}`) to `default_path`, or
/// to `$<env_override>` when set.  Each element of `rows` is one
/// preformatted JSON object body *without* the enclosing braces
/// (e.g. `"shape": "FC1", "speedup": 1.25`); this helper owns the
/// header/footer, per-row bracing, trailing-comma discipline and
/// write-error reporting, so the per-bench emitters
/// (`gemm_kernels`, `serving_throughput`) cannot drift apart —
/// CI's sanity gates parse both artifacts.
pub fn write_bench_json(name: &str, env_override: &str,
                        default_path: &str, rows: &[String]) {
    let path = std::env::var(env_override)
        .unwrap_or_else(|_| default_path.to_string());
    let mut body =
        format!("{{\n  \"bench\": \"{name}\",\n  \"rows\": [\n");
    for (i, row) in rows.iter().enumerate() {
        body.push_str(&format!(
            "    {{{row}}}{}\n",
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    body.push_str("  ]\n}\n");
    match std::fs::write(&path, body) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_iterations() {
        let r = bench("noop", 2, 16, || {
            black_box(1 + 1);
        });
        assert_eq!(r.hist.count(), 16);
        assert!(r.mean_ns() >= 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let hist = Histogram::new();
        for v in [50, 10, 30, 20, 40] {
            hist.record(v);
        }
        let r = BenchResult { name: "x".into(), iters: 5, hist };
        // bucketed semantics: each read-out covers its true sample
        // (within 2x) and p100 is the exact max
        let (p50, p99, p100) = (r.percentile_ns(50.0),
                                r.percentile_ns(99.0),
                                r.percentile_ns(100.0));
        assert!((30..=50).contains(&p50), "p50 = {p50}");
        assert!(p50 <= p99 && p99 <= p100, "{p50} {p99} {p100}");
        assert_eq!(p100, 50);
    }

    #[test]
    fn bench_json_shape() {
        let dir = std::env::temp_dir().join("lop_bench_json_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("out.json");
        write_bench_json(
            "unit",
            "LOP_TEST_BENCH_JSON_UNSET",
            path.to_str().unwrap(),
            &[r#""a": 1, "b": "x""#.to_string(),
              r#""a": 2, "b": "y""#.to_string()],
        );
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"bench\": \"unit\""), "{s}");
        assert!(s.contains("{\"a\": 1, \"b\": \"x\"},"), "{s}");
        assert!(s.contains("{\"a\": 2, \"b\": \"y\"}\n"), "{s}");
        // minimal well-formedness: balanced braces, no trailing comma
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert!(!s.contains("},\n  ]"), "{s}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2_500.0), "2.50 µs");
        assert_eq!(fmt_ns(3_000_000.0), "3.00 ms");
        assert_eq!(fmt_ns(2_000_000_000.0), "2.000 s");
    }
}
