//! Bench timing harness (criterion is not in the offline crate set).
//!
//! `cargo bench` targets are declared with `harness = false` and drive this
//! module: warmup, timed iterations, and a summary with mean / p50 / p99.

use std::time::Instant;

/// Result of one benchmark: per-iteration wall times in nanoseconds.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub ns: Vec<u64>,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.ns.iter().sum::<u64>() as f64 / self.ns.len().max(1) as f64
    }

    pub fn percentile_ns(&self, p: f64) -> u64 {
        if self.ns.is_empty() {
            return 0;
        }
        let mut v = self.ns.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.percentile_ns(50.0) as f64),
            fmt_ns(self.percentile_ns(99.0) as f64),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut ns = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        ns.push(t0.elapsed().as_nanos() as u64);
    }
    BenchResult { name: name.to_string(), iters, ns }
}

/// Print the standard header row for a bench table.
pub fn header() {
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "p50", "p99"
    );
    println!("{}", "-".repeat(86));
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write a bench's rows as the repo's standard JSON artifact
/// (`{"bench": <name>, "rows": [{...}, ...]}`) to `default_path`, or
/// to `$<env_override>` when set.  Each element of `rows` is one
/// preformatted JSON object body *without* the enclosing braces
/// (e.g. `"shape": "FC1", "speedup": 1.25`); this helper owns the
/// header/footer, per-row bracing, trailing-comma discipline and
/// write-error reporting, so the per-bench emitters
/// (`gemm_kernels`, `serving_throughput`) cannot drift apart —
/// CI's sanity gates parse both artifacts.
pub fn write_bench_json(name: &str, env_override: &str,
                        default_path: &str, rows: &[String]) {
    let path = std::env::var(env_override)
        .unwrap_or_else(|_| default_path.to_string());
    let mut body =
        format!("{{\n  \"bench\": \"{name}\",\n  \"rows\": [\n");
    for (i, row) in rows.iter().enumerate() {
        body.push_str(&format!(
            "    {{{row}}}{}\n",
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    body.push_str("  ]\n}\n");
    match std::fs::write(&path, body) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_iterations() {
        let r = bench("noop", 2, 16, || {
            black_box(1 + 1);
        });
        assert_eq!(r.ns.len(), 16);
        assert!(r.mean_ns() >= 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            iters: 5,
            ns: vec![50, 10, 30, 20, 40],
        };
        assert_eq!(r.percentile_ns(0.0), 10);
        assert_eq!(r.percentile_ns(50.0), 30);
        assert_eq!(r.percentile_ns(100.0), 50);
    }

    #[test]
    fn bench_json_shape() {
        let dir = std::env::temp_dir().join("lop_bench_json_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("out.json");
        write_bench_json(
            "unit",
            "LOP_TEST_BENCH_JSON_UNSET",
            path.to_str().unwrap(),
            &[r#""a": 1, "b": "x""#.to_string(),
              r#""a": 2, "b": "y""#.to_string()],
        );
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"bench\": \"unit\""), "{s}");
        assert!(s.contains("{\"a\": 1, \"b\": \"x\"},"), "{s}");
        assert!(s.contains("{\"a\": 2, \"b\": \"y\"}\n"), "{s}");
        // minimal well-formedness: balanced braces, no trailing comma
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert!(!s.contains("},\n  ]"), "{s}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2_500.0), "2.50 µs");
        assert_eq!(fmt_ns(3_000_000.0), "3.00 ms");
        assert_eq!(fmt_ns(2_000_000_000.0), "2.000 s");
    }
}
