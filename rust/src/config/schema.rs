//! Typed configuration schemas loaded from TOML files (see
//! `examples/configs/*.toml`).

use super::toml::TomlDoc;
use crate::coordinator::explorer::{ExploreOpts, Family};
use crate::nn::network::NetConfig;
use std::time::Duration;

/// `[serve]` section.
#[derive(Clone, Debug)]
pub struct ServeFileConfig {
    pub configs: Vec<NetConfig>,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
    pub engine_workers: usize,
    /// Byte cap (in MiB) on the server's shared plan cache — the
    /// resident prepacked weight panels all engine workers share.
    pub plan_cache_mb: usize,
    pub use_pjrt: bool,
}

impl ServeFileConfig {
    pub fn from_toml(doc: &TomlDoc) -> Result<ServeFileConfig, String> {
        let configs = match doc.get("serve", "configs") {
            Some(v) => {
                let arr = v.as_array().ok_or("serve.configs must be array")?;
                arr.iter()
                    .map(|x| {
                        NetConfig::parse(
                            x.as_str().ok_or("config must be string")?,
                        )
                    })
                    .collect::<Result<Vec<_>, _>>()?
            }
            None => vec![NetConfig::parse("float32").unwrap()],
        };
        Ok(ServeFileConfig {
            configs,
            max_batch: doc.get_int("serve", "max_batch").unwrap_or(16)
                as usize,
            max_wait: Duration::from_micros(
                (doc.get_float("serve", "max_wait_ms").unwrap_or(2.0)
                    * 1_000.0) as u64,
            ),
            queue_capacity: doc
                .get_int("serve", "queue_capacity")
                .unwrap_or(4_096) as usize,
            engine_workers: doc
                .get_int("serve", "engine_workers")
                .unwrap_or(2) as usize,
            plan_cache_mb: doc
                .get_int("serve", "plan_cache_mb")
                .unwrap_or(256) as usize,
            use_pjrt: doc.get_bool("serve", "use_pjrt").unwrap_or(true),
        })
    }
}

/// `[explore]` section.
#[derive(Clone, Debug)]
pub struct ExploreFileConfig {
    pub opts: ExploreOpts,
    pub subset: usize,
}

impl ExploreFileConfig {
    pub fn from_toml(doc: &TomlDoc) -> Result<ExploreFileConfig, String> {
        let mut opts = ExploreOpts::default();
        if let Some(b) = doc.get_float("explore", "accuracy_bound") {
            opts.accuracy_bound = b;
        }
        if let Some(lo) = doc.get_int("explore", "frac_lo") {
            opts.frac_bci.0 = lo as u32;
        }
        if let Some(hi) = doc.get_int("explore", "frac_hi") {
            opts.frac_bci.1 = hi as u32;
        }
        if let Some(h) = doc.get_int("explore", "int_headroom") {
            opts.int_headroom = h as u32;
        }
        if let Some(sp) = doc.get_bool("explore", "second_pass") {
            opts.second_pass = sp;
        }
        if let Some(fams) = doc.get("explore", "families") {
            let arr = fams.as_array().ok_or("families must be array")?;
            opts.families = arr
                .iter()
                .map(|f| match f.as_str() {
                    Some("fixed") => Ok(Family::Fixed),
                    Some("float") => Ok(Family::Float),
                    Some("drum") => Ok(Family::FixedDrum),
                    Some("cfpu") => Ok(Family::FloatCfpu),
                    other => Err(format!("unknown family {other:?}")),
                })
                .collect::<Result<Vec<_>, _>>()?;
        }
        Ok(ExploreFileConfig {
            opts,
            subset: doc.get_int("explore", "subset").unwrap_or(500)
                as usize,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_parses() {
        let doc = TomlDoc::parse(
            r#"
[serve]
configs = ["float32", "FI(6,8)", "H(6,8,12)"]
max_batch = 32
max_wait_ms = 1.5
plan_cache_mb = 64
use_pjrt = false
"#,
        )
        .unwrap();
        let c = ServeFileConfig::from_toml(&doc).unwrap();
        assert_eq!(c.configs.len(), 3);
        assert_eq!(c.max_batch, 32);
        assert_eq!(c.max_wait, Duration::from_micros(1_500));
        assert_eq!(c.plan_cache_mb, 64);
        assert!(!c.use_pjrt);
    }

    #[test]
    fn explore_config_parses() {
        let doc = TomlDoc::parse(
            r#"
[explore]
accuracy_bound = 0.02
frac_lo = 6
frac_hi = 10
families = ["fixed", "drum"]
subset = 250
second_pass = false
"#,
        )
        .unwrap();
        let c = ExploreFileConfig::from_toml(&doc).unwrap();
        assert_eq!(c.opts.accuracy_bound, 0.02);
        assert_eq!(c.opts.frac_bci, (6, 10));
        assert_eq!(c.opts.families,
                   vec![Family::Fixed, Family::FixedDrum]);
        assert!(!c.opts.second_pass);
        assert_eq!(c.subset, 250);
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        let c = ServeFileConfig::from_toml(&doc).unwrap();
        assert_eq!(c.max_batch, 16);
        assert_eq!(c.plan_cache_mb, 256);
        assert!(c.use_pjrt);
        let e = ExploreFileConfig::from_toml(&doc).unwrap();
        assert_eq!(e.subset, 500);
    }
}
