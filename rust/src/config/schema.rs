//! Typed configuration schemas loaded from TOML files (see
//! `examples/configs/*.toml`).

use super::toml::TomlDoc;
use crate::coordinator::explorer::{ExploreOpts, Family};
use crate::coordinator::pareto::Objective;
use crate::coordinator::router::OverloadPolicy;
use crate::nn::spec::{NetSpec, ReprMap};
use std::time::Duration;

/// `[serve]` section.
#[derive(Clone, Debug)]
pub struct ServeFileConfig {
    /// The served topology: `model = "paper_dcnn"` (default) or a
    /// spec-grammar string like
    /// `"28x28x1: dense(64)+relu | dense(10)"`.
    pub spec: NetSpec,
    /// Per-config assignments, parsed against `spec`'s arity.
    pub configs: Vec<ReprMap>,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
    pub engine_workers: usize,
    /// Byte cap (in MiB) on the server's shared plan cache — the
    /// resident prepacked weight panels all engine workers share.
    pub plan_cache_mb: usize,
    pub use_pjrt: bool,
    /// `overload = "reject" | "shed" | "degrade"` — what admission
    /// does when a config's queue is at `queue_capacity`.
    pub overload: OverloadPolicy,
    /// `deadline_ms` — server-wide default queueing deadline; absent
    /// means requests never expire in queue.
    pub deadline: Option<Duration>,
    /// `auto = true` — pick the served config from a Pareto-front
    /// artifact at startup instead of `configs`.
    pub auto: bool,
    /// `front` — path of the `pareto_front.json` artifact `auto`
    /// loads (default `pareto_front.json`).
    pub front: String,
    /// `accuracy_budget` — the minimum accuracy `auto` selection must
    /// meet (required when `auto = true` unless the CLI supplies it).
    pub accuracy_budget: Option<f64>,
    /// `stats_every = N` — print a Prometheus-style telemetry
    /// snapshot after every N answered requests (0, the default,
    /// disables periodic printing; the shutdown snapshot always
    /// prints).
    pub stats_every: usize,
}

impl ServeFileConfig {
    pub fn from_toml(doc: &TomlDoc) -> Result<ServeFileConfig, String> {
        let spec = match doc.get_str("serve", "model") {
            Some(m) => NetSpec::preset_or_parse(m)
                .map_err(|e| format!("serve.model: {e}"))?,
            None => NetSpec::paper_dcnn(),
        };
        let configs = match doc.get("serve", "configs") {
            Some(v) => {
                let arr = v.as_array().ok_or("serve.configs must be array")?;
                arr.iter()
                    .map(|x| {
                        ReprMap::parse_for(
                            &spec,
                            x.as_str().ok_or("config must be string")?,
                        )
                    })
                    .collect::<Result<Vec<_>, _>>()?
            }
            None => vec![ReprMap::uniform_for(
                &spec,
                crate::approx::arith::ArithKind::Float32,
            )],
        };
        // Default the PJRT toggle from what the build can actually
        // do: a crate compiled without the `pjrt` feature ships an
        // API-compatible stub whose runner never starts, so defaulting
        // to `true` there would plan a worker split around a backend
        // that silently cannot exist.  An explicit `use_pjrt = true`
        // on a stub build is honored (the server still falls back to
        // the engine pool) but warned about loudly.
        let use_pjrt = doc
            .get_bool("serve", "use_pjrt")
            .unwrap_or(cfg!(feature = "pjrt"));
        if use_pjrt && !cfg!(feature = "pjrt") {
            eprintln!(
                "warning: [serve] use_pjrt = true, but this build has \
                 no `pjrt` feature (stub runtime); every config will \
                 be served by the engine workers"
            );
        }
        let overload = match doc.get_str("serve", "overload") {
            Some(s) => OverloadPolicy::parse(s)
                .map_err(|e| format!("serve.overload: {e}"))?,
            None => OverloadPolicy::Reject,
        };
        let deadline = doc.get_float("serve", "deadline_ms").map(|ms| {
            Duration::from_micros((ms * 1_000.0) as u64)
        });
        if let Some(d) = deadline {
            if d.is_zero() {
                return Err("serve.deadline_ms must be positive \
                            (every request would expire unserved)"
                    .to_string());
            }
        }
        let accuracy_budget =
            doc.get_float("serve", "accuracy_budget");
        if let Some(b) = accuracy_budget {
            if !(0.0..=1.0).contains(&b) {
                return Err(format!(
                    "serve.accuracy_budget {b} outside [0, 1]"
                ));
            }
        }
        Ok(ServeFileConfig {
            spec,
            configs,
            max_batch: doc.get_int("serve", "max_batch").unwrap_or(16)
                as usize,
            max_wait: Duration::from_micros(
                (doc.get_float("serve", "max_wait_ms").unwrap_or(2.0)
                    * 1_000.0) as u64,
            ),
            queue_capacity: doc
                .get_int("serve", "queue_capacity")
                .unwrap_or(4_096) as usize,
            engine_workers: doc
                .get_int("serve", "engine_workers")
                .unwrap_or(2) as usize,
            plan_cache_mb: doc
                .get_int("serve", "plan_cache_mb")
                .unwrap_or(256) as usize,
            use_pjrt,
            overload,
            deadline,
            auto: doc.get_bool("serve", "auto").unwrap_or(false),
            front: doc
                .get_str("serve", "front")
                .unwrap_or("pareto_front.json")
                .to_string(),
            accuracy_budget,
            stats_every: doc
                .get_int("serve", "stats_every")
                .unwrap_or(0) as usize,
        })
    }
}

/// `[explore]` section.
#[derive(Clone, Debug)]
pub struct ExploreFileConfig {
    pub opts: ExploreOpts,
    pub subset: usize,
    /// `objectives = ["accuracy", "latency", "hw"]` — the active
    /// search dimensions (default: all three).
    pub objectives: Vec<Objective>,
    /// Cap on full-net simulations spent on the predicted front.
    pub max_sims: usize,
    /// Calibration batch size for the sensitivity sweep.
    pub calib: usize,
    /// Where to write the `pareto_front.json` artifact (`front_out`;
    /// absent means don't write unless the CLI says so).
    pub front_out: Option<String>,
}

impl ExploreFileConfig {
    pub fn from_toml(doc: &TomlDoc) -> Result<ExploreFileConfig, String> {
        let mut opts = ExploreOpts::default();
        if let Some(b) = doc.get_float("explore", "accuracy_bound") {
            opts.accuracy_bound = b;
        }
        if let Some(lo) = doc.get_int("explore", "frac_lo") {
            opts.frac_bci.0 = lo as u32;
        }
        if let Some(hi) = doc.get_int("explore", "frac_hi") {
            opts.frac_bci.1 = hi as u32;
        }
        if let Some(h) = doc.get_int("explore", "int_headroom") {
            opts.int_headroom = h as u32;
        }
        if let Some(sp) = doc.get_bool("explore", "second_pass") {
            opts.second_pass = sp;
        }
        if let Some(fams) = doc.get("explore", "families") {
            let arr = fams.as_array().ok_or("families must be array")?;
            opts.families = arr
                .iter()
                .map(|f| match f.as_str() {
                    Some("fixed") => Ok(Family::Fixed),
                    Some("float") => Ok(Family::Float),
                    Some("drum") => Ok(Family::FixedDrum),
                    Some("cfpu") => Ok(Family::FloatCfpu),
                    other => Err(format!("unknown family {other:?}")),
                })
                .collect::<Result<Vec<_>, _>>()?;
        }
        let objectives = match doc.get("explore", "objectives") {
            Some(v) => {
                let arr = v
                    .as_array()
                    .ok_or("explore.objectives must be array")?;
                let names = arr
                    .iter()
                    .map(|o| {
                        o.as_str()
                            .ok_or("objective must be string")
                            .map(str::to_string)
                    })
                    .collect::<Result<Vec<_>, _>>()?;
                Objective::parse_list(&names.join(","))
                    .map_err(|e| format!("explore.objectives: {e}"))?
            }
            None => {
                crate::coordinator::pareto::ALL_OBJECTIVES.to_vec()
            }
        };
        Ok(ExploreFileConfig {
            opts,
            subset: doc.get_int("explore", "subset").unwrap_or(500)
                as usize,
            objectives,
            max_sims: doc
                .get_int("explore", "max_sims")
                .unwrap_or(8) as usize,
            calib: doc.get_int("explore", "calib").unwrap_or(64)
                as usize,
            front_out: doc
                .get_str("explore", "front_out")
                .map(str::to_string),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_parses() {
        let doc = TomlDoc::parse(
            r#"
[serve]
configs = ["float32", "FI(6,8)", "H(6,8,12)"]
max_batch = 32
max_wait_ms = 1.5
plan_cache_mb = 64
use_pjrt = false
stats_every = 50
"#,
        )
        .unwrap();
        let c = ServeFileConfig::from_toml(&doc).unwrap();
        assert!(c.spec.is_paper_dcnn(), "model defaults to the paper");
        assert_eq!(c.configs.len(), 3);
        assert_eq!(c.configs[0].len(), 4, "uniform broadcasts to 4");
        assert_eq!(c.max_batch, 32);
        assert_eq!(c.max_wait, Duration::from_micros(1_500));
        assert_eq!(c.plan_cache_mb, 64);
        assert!(!c.use_pjrt);
        assert_eq!(c.overload, OverloadPolicy::Reject);
        assert_eq!(c.deadline, None);
        assert_eq!(c.stats_every, 50);
    }

    #[test]
    fn serve_config_overload_and_deadline() {
        let doc = TomlDoc::parse(
            r#"
[serve]
overload = "degrade"
deadline_ms = 50
"#,
        )
        .unwrap();
        let c = ServeFileConfig::from_toml(&doc).unwrap();
        assert_eq!(c.overload, OverloadPolicy::Degrade);
        // integer TOML values coerce to float for *_ms keys
        assert_eq!(c.deadline, Some(Duration::from_millis(50)));

        let frac = TomlDoc::parse("[serve]\ndeadline_ms = 2.5\n")
            .unwrap();
        let c = ServeFileConfig::from_toml(&frac).unwrap();
        assert_eq!(c.deadline, Some(Duration::from_micros(2_500)));

        let bad = TomlDoc::parse("[serve]\noverload = \"drop\"\n")
            .unwrap();
        let e = ServeFileConfig::from_toml(&bad).unwrap_err();
        assert!(e.contains("serve.overload"), "{e}");

        let zero = TomlDoc::parse("[serve]\ndeadline_ms = 0\n")
            .unwrap();
        let e = ServeFileConfig::from_toml(&zero).unwrap_err();
        assert!(e.contains("positive"), "{e}");
    }

    #[test]
    fn serve_config_takes_a_model_spec() {
        let doc = TomlDoc::parse(
            r#"
[serve]
model = "28x28x1: dense(64)+relu | dense(32)+relu | dense(10)"
configs = ["FI(6,8)", "FI(6,8)|FL(4,9)|H(6,8,12)"]
"#,
        )
        .unwrap();
        let c = ServeFileConfig::from_toml(&doc).unwrap();
        assert!(!c.spec.is_paper_dcnn());
        assert_eq!(c.spec.len(), 3);
        assert_eq!(c.configs[0].len(), 3, "uniform broadcasts to 3");
        assert_eq!(c.configs[1].kind(2).name(), "H(6, 8, 12)");
        // arity mismatches are rejected with the layer counts
        let bad = TomlDoc::parse(
            r#"
[serve]
model = "28x28x1: dense(64)+relu | dense(10)"
configs = ["FI(6,8)|FL(4,9)|H(6,8,12)"]
"#,
        )
        .unwrap();
        let e = ServeFileConfig::from_toml(&bad).unwrap_err();
        assert!(e.contains("expected 1 or 2"), "{e}");
    }

    #[test]
    fn explore_config_parses() {
        let doc = TomlDoc::parse(
            r#"
[explore]
accuracy_bound = 0.02
frac_lo = 6
frac_hi = 10
families = ["fixed", "drum"]
subset = 250
second_pass = false
"#,
        )
        .unwrap();
        let c = ExploreFileConfig::from_toml(&doc).unwrap();
        assert_eq!(c.opts.accuracy_bound, 0.02);
        assert_eq!(c.opts.frac_bci, (6, 10));
        assert_eq!(c.opts.families,
                   vec![Family::Fixed, Family::FixedDrum]);
        assert!(!c.opts.second_pass);
        assert_eq!(c.subset, 250);
    }

    #[test]
    fn explore_config_parses_surrogate_keys() {
        let doc = TomlDoc::parse(
            r#"
[explore]
objectives = ["accuracy", "hw"]
max_sims = 4
calib = 32
front_out = "front.json"
"#,
        )
        .unwrap();
        let c = ExploreFileConfig::from_toml(&doc).unwrap();
        assert_eq!(c.objectives,
                   vec![Objective::Accuracy, Objective::HwCost]);
        assert_eq!(c.max_sims, 4);
        assert_eq!(c.calib, 32);
        assert_eq!(c.front_out.as_deref(), Some("front.json"));

        let bad = TomlDoc::parse(
            "[explore]\nobjectives = [\"speed\"]\n",
        )
        .unwrap();
        let e = ExploreFileConfig::from_toml(&bad).unwrap_err();
        assert!(e.contains("explore.objectives"), "{e}");
    }

    #[test]
    fn serve_config_parses_auto_keys() {
        let doc = TomlDoc::parse(
            r#"
[serve]
auto = true
front = "out/pareto_front.json"
accuracy_budget = 0.9
"#,
        )
        .unwrap();
        let c = ServeFileConfig::from_toml(&doc).unwrap();
        assert!(c.auto);
        assert_eq!(c.front, "out/pareto_front.json");
        assert_eq!(c.accuracy_budget, Some(0.9));

        let bad = TomlDoc::parse(
            "[serve]\naccuracy_budget = 1.5\n",
        )
        .unwrap();
        let e = ServeFileConfig::from_toml(&bad).unwrap_err();
        assert!(e.contains("accuracy_budget"), "{e}");
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        let c = ServeFileConfig::from_toml(&doc).unwrap();
        assert_eq!(c.max_batch, 16);
        assert_eq!(c.plan_cache_mb, 256);
        assert!(c.spec.is_paper_dcnn());
        // the pjrt default tracks the build: stub builds must not
        // plan for a worker that can never start
        assert_eq!(c.use_pjrt, cfg!(feature = "pjrt"));
        assert!(!c.auto);
        assert_eq!(c.front, "pareto_front.json");
        assert_eq!(c.accuracy_budget, None);
        assert_eq!(c.stats_every, 0);
        let e = ExploreFileConfig::from_toml(&doc).unwrap();
        assert_eq!(e.subset, 500);
        assert_eq!(e.objectives.len(), 3);
        assert_eq!(e.max_sims, 8);
        assert_eq!(e.calib, 64);
        assert_eq!(e.front_out, None);
    }
}
