//! Run-configuration system: a TOML-subset parser (no external crates in
//! the offline set) plus typed configs for the server and explorer.

pub mod schema;
pub mod toml;

pub use schema::{ExploreFileConfig, ServeFileConfig};
pub use toml::TomlDoc;
