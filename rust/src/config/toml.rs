//! Minimal TOML-subset parser: `[section]` headers, `key = value` pairs
//! with string / integer / float / boolean / array-of-scalar values, `#`
//! comments.  This covers Lop's config files; it is not a full TOML
//! implementation (no nested tables, no multi-line strings, no dates).

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: section -> key -> value.  Top-level keys live in the
/// "" section.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section"))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| err(lineno, "expected key = value"))?;
            let value = parse_value(v.trim())
                .map_err(|m| err(lineno, &m))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key)?.as_str()
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key)?.as_int()
    }

    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.as_float()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key)?.as_bool()
    }
}

fn err(lineno: usize, msg: &str) -> String {
    format!("toml line {}: {msg}", lineno + 1)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside a quoted string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Split on commas that are not inside quotes (arrays of strings may
/// contain commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_document() {
        let doc = TomlDoc::parse(
            r#"
# top comment
name = "lop"
count = 42
[serve]
max_batch = 16        # trailing comment
max_wait_ms = 2.5
use_pjrt = true
configs = ["FI(6,8)", "float32"]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("", "name"), Some("lop"));
        assert_eq!(doc.get_int("", "count"), Some(42));
        assert_eq!(doc.get_int("serve", "max_batch"), Some(16));
        assert_eq!(doc.get_float("serve", "max_wait_ms"), Some(2.5));
        assert_eq!(doc.get_bool("serve", "use_pjrt"), Some(true));
        let arr = doc.get("serve", "configs").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_str(), Some("FI(6,8)"));
    }

    #[test]
    fn string_with_hash_and_comma() {
        let doc = TomlDoc::parse(r#"x = "a # not comment, really""#)
            .unwrap();
        assert_eq!(doc.get_str("", "x"), Some("a # not comment, really"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("x = ").is_err());
        assert!(TomlDoc::parse("x = [1, ").is_err());
    }

    #[test]
    fn int_vs_float() {
        let doc = TomlDoc::parse("a = 3\nb = 3.5\nc = -2").unwrap();
        assert_eq!(doc.get_int("", "a"), Some(3));
        assert_eq!(doc.get_float("", "b"), Some(3.5));
        assert_eq!(doc.get_int("", "c"), Some(-2));
        // ints coerce to float on demand
        assert_eq!(doc.get_float("", "a"), Some(3.0));
    }
}
