//! Hand-rolled CLI argument parser (clap is not in the offline crate set).
//!
//! Grammar: `lop <command> [--flag value | --flag=value | --switch]
//! [positional ...]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub cmd: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter();
        let mut out = Args { cmd: it.next().unwrap_or_default(),
                             ..Default::default() };
        let mut pending: Option<String> = None;
        for a in it {
            if let Some(flag) = a.strip_prefix("--") {
                // a new flag: any pending key was a boolean switch
                if let Some(key) = pending.take() {
                    out.switches.push(key);
                }
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    pending = Some(flag.to_string());
                }
            } else if let Some(key) = pending.take() {
                out.flags.insert(key, a);
            } else {
                out.positional.push(a);
            }
        }
        // a trailing `--flag` with no value is a switch
        if let Some(k) = pending {
            out.switches.push(k);
        }
        out
    }

    pub fn from_env() -> Args {
        let mut argv: Vec<String> = std::env::args().skip(1).collect();
        if argv.is_empty() {
            argv.push("help".to_string());
        }
        Args::parse(argv)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
            || self
                .flags
                .get(key)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_and_positional() {
        let a = parse("eval --config FI(6,8) --n 500 extra");
        assert_eq!(a.cmd, "eval");
        assert_eq!(a.str("config", ""), "FI(6,8)");
        assert_eq!(a.usize("n", 0), 500);
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("serve --rate=250.5 --max-batch=32");
        assert_eq!(a.f64("rate", 0.0), 250.5);
        assert_eq!(a.usize("max-batch", 0), 32);
    }

    #[test]
    fn trailing_switch() {
        let a = parse("explore --with-approx");
        assert!(a.switch("with-approx"));
        assert!(!a.switch("other"));
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("serve --no-pjrt --requests 10");
        assert!(a.switch("no-pjrt"));
        assert_eq!(a.usize("requests", 0), 10);
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.str("missing", "dflt"), "dflt");
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.f64("missing", 1.5), 1.5);
    }
}
