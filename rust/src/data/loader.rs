//! LOPD dataset loader — reads `artifacts/dataset.bin` written by
//! `python/compile/data.py::write_dataset_bin`.
//!
//! Format: magic "LOPD", u32 version, u32 n_train, u32 n_test, u32 h,
//! u32 w, then train pixels u8[n*h*w], train labels u8[n], test pixels,
//! test labels.  Pixels are u8; `to_float` divides by 255 exactly as the
//! Python side does, so both languages feed bit-identical inputs.

use crate::nn::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Split {
    pub images: Vec<u8>, // n * h * w
    pub labels: Vec<u8>, // n
}

#[derive(Clone, Debug)]
pub struct Dataset {
    pub h: usize,
    pub w: usize,
    pub train: Split,
    pub test: Split,
}

impl Split {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

impl Dataset {
    pub fn load(path: &Path) -> Result<Dataset> {
        let raw = std::fs::read(path)
            .with_context(|| format!("reading dataset from {path:?}"))?;
        Self::parse(&raw)
    }

    pub fn parse(raw: &[u8]) -> Result<Dataset> {
        if raw.len() < 24 || &raw[0..4] != b"LOPD" {
            bail!("bad LOPD header");
        }
        let u = |i: usize| {
            u32::from_le_bytes(raw[i..i + 4].try_into().unwrap()) as usize
        };
        let (ver, ntr, nte, h, w) = (u(4), u(8), u(12), u(16), u(20));
        if ver != 1 {
            bail!("unsupported LOPD version {ver}");
        }
        let px = h * w;
        let need = 24 + ntr * px + ntr + nte * px + nte;
        if raw.len() != need {
            bail!("LOPD size mismatch: have {}, need {need}", raw.len());
        }
        let mut off = 24;
        let mut take = |n: usize| {
            let s = raw[off..off + n].to_vec();
            off += n;
            s
        };
        let train = Split { images: take(ntr * px), labels: take(ntr) };
        let test = Split { images: take(nte * px), labels: take(nte) };
        Ok(Dataset { h, w, train, test })
    }

    /// A batch of images as an f32 tensor [n, h, w, 1] in [0, 1].
    pub fn batch(&self, split: &Split, idx: &[usize]) -> Tensor {
        let px = self.h * self.w;
        let mut data = Vec::with_capacity(idx.len() * px);
        for &i in idx {
            assert!(i < split.len(), "index {i} out of range");
            data.extend(
                split.images[i * px..(i + 1) * px]
                    .iter()
                    .map(|&p| p as f32 / 255.0),
            );
        }
        Tensor::new(vec![idx.len(), self.h, self.w, 1], data)
    }

    /// The full split as one tensor (careful: test split is ~6 MB as f32).
    pub fn all(&self, split: &Split) -> Tensor {
        let idx: Vec<usize> = (0..split.len()).collect();
        self.batch(split, &idx)
    }

    /// Labels of a split as usize.
    pub fn labels(split: &Split) -> Vec<usize> {
        split.labels.iter().map(|&l| l as usize).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_lopd() -> Vec<u8> {
        let (ntr, nte, h, w) = (2u32, 1u32, 2u32, 2u32);
        let mut raw = b"LOPD".to_vec();
        for v in [1u32, ntr, nte, h, w] {
            raw.extend(v.to_le_bytes());
        }
        raw.extend([0u8, 64, 128, 255, 10, 20, 30, 40]); // train px
        raw.extend([3u8, 7]); // train labels
        raw.extend([255u8, 0, 0, 255]); // test px
        raw.extend([9u8]); // test labels
        raw
    }

    #[test]
    fn parse_and_batch() {
        let ds = Dataset::parse(&tiny_lopd()).unwrap();
        assert_eq!(ds.train.len(), 2);
        assert_eq!(ds.test.len(), 1);
        let b = ds.batch(&ds.train, &[0]);
        assert_eq!(b.shape, vec![1, 2, 2, 1]);
        assert_eq!(b.data, vec![0.0, 64.0 / 255.0, 128.0 / 255.0, 1.0]);
        assert_eq!(Dataset::labels(&ds.test), vec![9]);
    }

    #[test]
    fn rejects_size_mismatch() {
        let mut raw = tiny_lopd();
        raw.pop();
        assert!(Dataset::parse(&raw).is_err());
        raw.push(0);
        raw.push(0);
        assert!(Dataset::parse(&raw).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(Dataset::parse(b"XXXX").is_err());
    }
}
