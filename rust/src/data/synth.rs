//! Dependency-free synthetic digit generator (Rust port of the *shape* of
//! `python/compile/data.py`, not bit-identical to it) — used by unit tests
//! and by the serving load generator so they never need artifacts on disk.
//! Canonical experiment data always comes from `dataset.bin`.

use crate::nn::tensor::Tensor;
use crate::util::prng::Rng;

pub const H: usize = 28;
pub const W: usize = 28;

/// 5x7 dot-matrix font (same glyphs as the Python generator).
const GLYPHS: [[u8; 7]; 10] = [
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110], // 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110], // 1
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111], // 2
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110], // 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010], // 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110], // 5
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110], // 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000], // 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110], // 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100], // 9
];

fn glyph_at(d: usize, gy: i64, gx: i64) -> f32 {
    if !(0..7).contains(&gy) || !(0..5).contains(&gx) {
        return 0.0;
    }
    ((GLYPHS[d][gy as usize] >> (4 - gx as usize)) & 1) as f32
}

/// Render one digit with random affine jitter + noise; u8 pixels.
pub fn render(digit: usize, rng: &mut Rng) -> [u8; H * W] {
    let ang = rng.range_f32(-0.25, 0.25) as f64;
    let scale = rng.range_f32(0.75, 1.10) as f64;
    let shear = rng.range_f32(-0.25, 0.25) as f64;
    let tx = rng.range_f32(-2.5, 2.5) as f64;
    let ty = rng.range_f32(-2.5, 2.5) as f64;
    let cell_h = 20.0 / 7.0 * scale;
    let cell_w = 14.0 / 5.0 * scale;
    let (ca, sa) = (ang.cos(), ang.sin());
    let (cy, cx) = (H as f64 / 2.0 + ty, W as f64 / 2.0 + tx);

    let mut img = [0f32; H * W];
    for y in 0..H {
        for x in 0..W {
            let u = x as f64 - cx;
            let v = y as f64 - cy;
            let ur = ca * u + sa * v - shear * (-sa * u + ca * v);
            let vr = -sa * u + ca * v;
            let gx = ur / cell_w + 2.5;
            let gy = vr / cell_h + 3.5;
            let (x0, y0) = (gx.floor(), gy.floor());
            let (fx, fy) = ((gx - x0) as f32, (gy - y0) as f32);
            let (x0, y0) = (x0 as i64, y0 as i64);
            let s = (1.0 - fy) * (1.0 - fx) * glyph_at(digit, y0, x0)
                + (1.0 - fy) * fx * glyph_at(digit, y0, x0 + 1)
                + fy * (1.0 - fx) * glyph_at(digit, y0 + 1, x0)
                + fy * fx * glyph_at(digit, y0 + 1, x0 + 1);
            img[y * W + x] = s;
        }
    }
    // light blur + noise
    let mut out = [0u8; H * W];
    for y in 0..H {
        for x in 0..W {
            let mut acc = 0f32;
            let mut wsum = 0f32;
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    let yy = y as i64 + dy;
                    let xx = x as i64 + dx;
                    if (0..H as i64).contains(&yy)
                        && (0..W as i64).contains(&xx)
                    {
                        let wgt = if dy == 0 && dx == 0 { 2.0 } else { 1.0 };
                        acc += wgt * img[yy as usize * W + xx as usize];
                        wsum += wgt;
                    }
                }
            }
            let mut v = acc / wsum + (rng.normal() as f32) * 0.03;
            v = v.clamp(0.0, 1.0);
            out[y * W + x] = (v * 255.0).round() as u8;
        }
    }
    out
}

/// Generate `n` labeled images.
pub fn generate(n: usize, seed: u64) -> (Vec<u8>, Vec<u8>) {
    let mut rng = Rng::new(seed);
    let mut images = Vec::with_capacity(n * H * W);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let d = rng.below(10) as usize;
        labels.push(d as u8);
        images.extend_from_slice(&render(d, &mut rng));
    }
    (images, labels)
}

/// Generate directly as an input tensor [n, 28, 28, 1] plus labels.
pub fn generate_tensor(n: usize, seed: u64) -> (Tensor, Vec<usize>) {
    let (images, labels) = generate(n, seed);
    let data: Vec<f32> = images.iter().map(|&p| p as f32 / 255.0).collect();
    (
        Tensor::new(vec![n, H, W, 1], data),
        labels.iter().map(|&l| l as usize).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let (a, la) = generate(20, 9);
        let (b, lb) = generate(20, 9);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn images_have_ink_but_not_too_much() {
        let (imgs, _) = generate(50, 1);
        for img in imgs.chunks(H * W) {
            let on = img.iter().filter(|&&p| p > 64).count() as f64
                / (H * W) as f64;
            assert!(on > 0.01, "blank image");
            assert!(on < 0.7, "image mostly ink");
        }
    }

    #[test]
    fn all_classes_appear() {
        let (_, labels) = generate(500, 2);
        for c in 0..10u8 {
            assert!(labels.contains(&c), "class {c} missing");
        }
    }

    #[test]
    fn classes_distinct() {
        // mean images of class pairs must differ
        let (imgs, labels) = generate(400, 3);
        let mut means = vec![[0f64; H * W]; 10];
        let mut counts = [0usize; 10];
        for (img, &l) in imgs.chunks(H * W).zip(&labels) {
            counts[l as usize] += 1;
            for (m, &p) in means[l as usize].iter_mut().zip(img) {
                *m += p as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= (c.max(1) * 255) as f64;
            }
        }
        for a in 0..10 {
            for b in (a + 1)..10 {
                let d: f64 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y).abs())
                    .sum::<f64>()
                    / (H * W) as f64;
                assert!(d > 0.01, "classes {a}/{b} indistinct ({d})");
            }
        }
    }
}
