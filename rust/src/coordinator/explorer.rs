//! The design-space explorer.
//!
//! [`Explorer`] is the search API — surrogate-guided, multi-objective:
//! profile per-layer quality sensitivity and an
//! analytic/bench-calibrated cost model ([`super::pareto`]), enumerate
//! the predicted Pareto front by a dominance-pruned layer DP, and
//! spend the full-net `Evaluator` budget only on predicted-front
//! configs.  Returns a [`ParetoFront`] artifact with per-point
//! provenance.  (The paper's §4.2 two-pass greedy shipped as a
//! deprecated `explore` shim through PR 9 and is gone; the surrogate
//! search subsumes it — an accuracy [`Explorer::budget`] reproduces
//! pass 1's bound, and the front's cheap end covers pass 2's
//! widening.)
//!
//! Candidate generation follows §4.2: the range-determined
//! field (integral/exponent bits) is lower-bounded by profiled WBA
//! ranges, the accuracy-determined field (fraction/mantissa bits)
//! enumerates a bit-count interval.  [`candidate_sets`] additionally
//! consults each layer's parameter shapes — wider fan-in earns more
//! partial-sum headroom — so non-paper topologies get per-layer, not
//! broadcast, candidate sets.
//!
//! The explorer publishes `explorer.evals` (full-net evaluator
//! forwards, counted in [`super::eval`]) and `explorer.sims`
//! (simulation slots spent on predicted-front configs) on the global
//! telemetry registry.

use super::eval::Evaluator;
use super::pareto::{
    prune_nondominated, surrogate_front, CostModel, Objective,
    ParetoFront, ParetoPoint, SensitivityProfile, ALL_OBJECTIVES,
};
use super::ranges::{exp_bits_for, int_bits_for, profile_ranges};
use crate::approx::arith::ArithKind;
use crate::approx::cfpu::CfpuMul;
use crate::approx::drum::DrumMul;
use crate::nn::network::LayerRanges;
use crate::nn::spec::{NetSpec, ReprMap};
use crate::numeric::{FixedPoint, FloatRep};
use anyhow::{bail, Result};
use std::collections::BTreeSet;
use std::path::PathBuf;

/// Which representation families the search enumerates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Fixed,
    Float,
    FixedDrum,
    FloatCfpu,
}

#[derive(Clone, Debug)]
pub struct ExploreOpts {
    /// relative accuracy loss bound vs float32 baseline (e.g. 0.01 = 1%)
    pub accuracy_bound: f64,
    /// BCI for the accuracy-determined field (fraction / mantissa bits)
    pub frac_bci: (u32, u32),
    /// extra integral-bit headroom enumerated beyond the range bound
    /// (partial-sum widening, §4.2); [`candidate_sets`] adds a
    /// per-layer fan-in term on top
    pub int_headroom: u32,
    pub families: Vec<Family>,
    /// retained for config-file compatibility (the removed two-pass
    /// greedy's quality-recovery switch); the surrogate explorer
    /// ignores it
    pub second_pass: bool,
    /// DRUM widths / CFPU tuning widths enumerated for approx families
    pub drum_ts: Vec<u32>,
    pub cfpu_ws: Vec<u32>,
}

impl Default for ExploreOpts {
    fn default() -> Self {
        ExploreOpts {
            accuracy_bound: 0.01,
            frac_bci: (4, 12),
            int_headroom: 2,
            families: vec![Family::Fixed, Family::Float],
            second_pass: true,
            drum_ts: vec![10, 12, 14],
            cfpu_ws: vec![3],
        }
    }
}

// ---------------------------------------------------------------------
// candidate generation
// ---------------------------------------------------------------------

/// Enumerate candidate providers for one value-range magnitude with an
/// explicit integral-bit headroom (the shared §4.2 core).
fn candidates_for_mag(range_mag: f64, int_headroom: u32,
                      opts: &ExploreOpts) -> Vec<ArithKind> {
    let mut out = Vec::new();
    let ilb = int_bits_for(range_mag);
    let elb = exp_bits_for(range_mag);
    for fam in &opts.families {
        match fam {
            Family::Fixed => {
                for i in ilb..=ilb + int_headroom {
                    for f in opts.frac_bci.0..=opts.frac_bci.1 {
                        if i + f <= 22 {
                            out.push(ArithKind::FixedExact(
                                FixedPoint::new(i, f),
                            ));
                        }
                    }
                }
            }
            Family::Float => {
                // exponent is range-determined ("only a few bits needed")
                for m in opts.frac_bci.0..=opts.frac_bci.1 {
                    out.push(ArithKind::FloatExact(FloatRep::new(
                        elb.clamp(2, 7),
                        m.max(1),
                    )));
                }
            }
            Family::FixedDrum => {
                for i in ilb..=ilb + int_headroom {
                    for f in opts.frac_bci.0..=opts.frac_bci.1 {
                        for &t in &opts.drum_ts {
                            if i + f <= 22 && t >= 2 && t <= i + f {
                                out.push(ArithKind::FixedDrum(
                                    DrumMul::new(FixedPoint::new(i, f), t),
                                ));
                            }
                        }
                    }
                }
            }
            Family::FloatCfpu => {
                for m in opts.frac_bci.0..=opts.frac_bci.1 {
                    for &w in &opts.cfpu_ws {
                        out.push(ArithKind::FloatCfpu(CfpuMul::new(
                            FloatRep::new(elb.clamp(2, 7), m.max(1)),
                            w,
                        )));
                    }
                }
            }
        }
    }
    out
}

/// Extra integral-bit headroom a layer earns from its fan-in: a dot
/// product of `k` terms can grow partial sums by up to `log2(k)` bits,
/// of which roughly half materialize for centered data (§4.2's
/// widening argument), capped so huge layers don't blow the 22-bit
/// fixed budget.
fn fanin_headroom(spec: &NetSpec, layer: usize) -> u32 {
    let (wshape, _) = spec.layers()[layer].param_shapes();
    let fan_in: usize =
        wshape[..wshape.len() - 1].iter().product::<usize>().max(1);
    (((fan_in as f64).log2().ceil() as u32) / 2).min(4)
}

/// Candidate providers for one layer: range-driven per §4.2, plus
/// shape-aware integral headroom from the layer's parameter fan-in.
pub fn layer_candidates(spec: &NetSpec, layer: usize,
                        ranges: &[LayerRanges], opts: &ExploreOpts)
                        -> Result<Vec<ArithKind>, String> {
    let n = spec.len();
    if layer >= n {
        return Err(format!(
            "layer {layer} out of range for the {n}-layer spec \
             '{spec}'"
        ));
    }
    if ranges.len() != n {
        return Err(format!(
            "layer {}/{n}: {} WBA range entries for the {n}-layer \
             spec '{spec}' (profile one range per layer)",
            layer + 1,
            ranges.len()
        ));
    }
    let mag = {
        let c = ranges[layer].combined();
        (c.0.abs()).max(c.1.abs()) as f64
    };
    let headroom = opts.int_headroom + fanin_headroom(spec, layer);
    let cands = candidates_for_mag(mag, headroom, opts);
    if cands.is_empty() {
        return Err(format!(
            "layer {}/{n} ('{}'): no candidates for range magnitude \
             {mag} under the configured families/BCI",
            layer + 1,
            ranges[layer].layer
        ));
    }
    Ok(cands)
}

/// Per-layer candidate sets for a whole spec (the bug-fixed
/// replacement for broadcasting one range's candidates): arity is
/// checked against the spec and every layer's set reflects its own
/// range *and* parameter shape.
pub fn candidate_sets(spec: &NetSpec, ranges: &[LayerRanges],
                      opts: &ExploreOpts)
                      -> Result<Vec<Vec<ArithKind>>, String> {
    if ranges.len() != spec.len() {
        return Err(format!(
            "{} WBA range entries for the {}-layer spec '{spec}' \
             (profile one range per layer)",
            ranges.len(),
            spec.len()
        ));
    }
    (0..spec.len())
        .map(|l| layer_candidates(spec, l, ranges, opts))
        .collect()
}

// ---------------------------------------------------------------------
// the fluent Explorer
// ---------------------------------------------------------------------

/// Fluent, surrogate-guided multi-objective explorer.
///
/// ```no_run
/// # use lop::coordinator::explorer::Explorer;
/// # use lop::coordinator::pareto::Objective;
/// # fn demo(ev: &mut lop::coordinator::eval::Evaluator) {
/// let front = Explorer::new(ev.spec().clone())
///     .objectives(&[Objective::Accuracy, Objective::HwCost])
///     .budget(0.9)
///     .max_sims(8)
///     .run(ev)
///     .unwrap();
/// println!("{} points, {} sims", front.points().len(), front.sims());
/// # }
/// ```
///
/// `run` profiles ranges (unless provided), builds per-layer candidate
/// sets ([`candidate_sets`]), fits the quality/cost surrogates, prunes
/// the space to the predicted front, and simulates at most
/// [`Explorer::max_sims`] of those configs through the real evaluator.
#[derive(Clone, Debug)]
pub struct Explorer {
    spec: NetSpec,
    opts: ExploreOpts,
    objectives: Vec<Objective>,
    budget: Option<f64>,
    max_sims: usize,
    calib: usize,
    beam: usize,
    ranges: Option<Vec<LayerRanges>>,
    candidates: Option<Vec<Vec<ArithKind>>>,
    bench_json: Option<PathBuf>,
}

impl Explorer {
    pub fn new(spec: NetSpec) -> Explorer {
        Explorer {
            spec,
            opts: ExploreOpts::default(),
            objectives: ALL_OBJECTIVES.to_vec(),
            budget: None,
            max_sims: 8,
            calib: 64,
            beam: 512,
            ranges: None,
            candidates: None,
            bench_json: None,
        }
    }

    /// Candidate-generation options (families, BCI, headroom).
    pub fn opts(mut self, opts: ExploreOpts) -> Explorer {
        self.opts = opts;
        self
    }

    /// Active objectives (default: all three).  Duplicates collapse.
    pub fn objectives(mut self, objectives: &[Objective]) -> Explorer {
        let mut o = Vec::new();
        for &x in objectives {
            if !o.contains(&x) {
                o.push(x);
            }
        }
        if !o.is_empty() {
            self.objectives = o;
        }
        self
    }

    /// Accuracy budget: the first simulation slot goes to the cheapest
    /// predicted point meeting it, and [`ParetoFront::best_within`]
    /// answers serving-time selection against the same number.
    pub fn budget(mut self, accuracy_budget: f64) -> Explorer {
        self.budget = Some(accuracy_budget);
        self
    }

    /// Cap on full-net evaluator simulations spent on the predicted
    /// front (the baseline float32 evaluation is not counted).
    pub fn max_sims(mut self, max_sims: usize) -> Explorer {
        self.max_sims = max_sims;
        self
    }

    /// Calibration batch size for the perturbation sweep (drawn from
    /// the head of the evaluator's subset, so calibration inputs are
    /// a subset of what simulation measures).
    pub fn calibration(mut self, n: usize) -> Explorer {
        self.calib = n.max(1);
        self
    }

    /// DP beam cap (kept points per layer step).
    pub fn beam(mut self, beam: usize) -> Explorer {
        self.beam = beam.max(1);
        self
    }

    /// Use pre-profiled WBA ranges instead of profiling in `run`.
    pub fn ranges(mut self, ranges: Vec<LayerRanges>) -> Explorer {
        self.ranges = Some(ranges);
        self
    }

    /// Override candidate generation entirely (AxOSyn-style extension
    /// point: any per-layer `ArithKind` sets, e.g. for operators the
    /// built-in families don't enumerate).
    pub fn candidates(mut self, candidates: Vec<Vec<ArithKind>>)
                      -> Explorer {
        self.candidates = Some(candidates);
        self
    }

    /// Calibrate the latency scale from a `BENCH_gemm_kernels.json`
    /// (used only when every candidate kind has a measured row).
    pub fn bench_json(mut self, path: PathBuf) -> Explorer {
        self.bench_json = Some(path);
        self
    }

    /// Run the search.  See the type-level docs for the pipeline.
    pub fn run(self, ev: &mut Evaluator) -> Result<ParetoFront> {
        if &self.spec != ev.spec() {
            bail!("Explorer spec '{}' does not match the evaluator's \
                   '{}'",
                  self.spec, ev.spec());
        }
        let spec = self.spec;
        let cands = match self.candidates {
            Some(c) => {
                if c.len() != spec.len() {
                    bail!("{} candidate sets for the {}-layer spec \
                           '{spec}'",
                          c.len(), spec.len());
                }
                for (l, set) in c.iter().enumerate() {
                    if set.is_empty() {
                        bail!("layer {}/{}: empty candidate set",
                              l + 1, spec.len());
                    }
                }
                c
            }
            None => {
                let ranges = match self.ranges {
                    Some(r) => r,
                    None => profile_ranges(ev.model(), ev.dataset(),
                                           256, ev.threads),
                };
                match candidate_sets(&spec, &ranges, &self.opts) {
                    Ok(c) => c,
                    Err(e) => bail!("{e}"),
                }
            }
        };
        let cost = CostModel::calibrated(&spec, &cands,
                                         self.bench_json.as_deref());

        // baseline + calibration batch off the evaluator's own subset
        let f32_cfg = ReprMap::uniform_for(&spec, ArithKind::Float32);
        let baseline = ev.accuracy(&f32_cfg)?;
        let calib_n = self.calib.min(ev.subset.len()).max(1);
        let calib_idx: Vec<usize> =
            ev.subset[..calib_n].to_vec();
        let calib_x =
            ev.dataset().batch(&ev.dataset().test, &calib_idx);
        let profile = SensitivityProfile::profile(
            ev.model(), &calib_x, &cands, ev.threads,
        );

        // surrogate-predicted front over the full space
        let space = cands
            .iter()
            .fold(1u64, |a, c| a.saturating_mul(c.len() as u64));
        let predicted = surrogate_front(&spec, &profile, &cost, &cands,
                                        &self.objectives, self.beam);
        let mut points: Vec<ParetoPoint> = predicted
            .into_iter()
            .map(|(repr_map, v)| {
                let est = (baseline - v[0]).clamp(0.0, 1.0);
                ParetoPoint {
                    repr_map,
                    accuracy: est,
                    est_accuracy: est,
                    est_latency: v[1],
                    hw_cost: v[2],
                    simulated: false,
                }
            })
            .collect();
        points.sort_by(|a, b| {
            a.hw_cost
                .total_cmp(&b.hw_cost)
                .then(a.est_latency.total_cmp(&b.est_latency))
        });

        // spend the simulation budget: the budget-meeting pick first,
        // then an even spread across the hw-sorted front
        let mut picks: BTreeSet<usize> = BTreeSet::new();
        if !points.is_empty() && self.max_sims > 0 {
            if let Some(b) = self.budget {
                if let Some(i) =
                    points.iter().position(|p| p.est_accuracy >= b)
                {
                    picks.insert(i);
                }
            }
            let last = points.len() - 1;
            let slots = self.max_sims.min(points.len());
            for s in 0..slots {
                if picks.len() >= self.max_sims {
                    break;
                }
                picks.insert(s * last / (slots - 1).max(1));
            }
            while picks.len() > self.max_sims {
                let max = *picks.iter().next_back().unwrap();
                picks.remove(&max);
            }
        }
        let sim_counter =
            crate::telemetry::global().counter("explorer.sims");
        let mut sims = 0;
        for &i in &picks {
            let acc = ev.accuracy(&points[i].repr_map)?;
            points[i].accuracy = acc;
            points[i].simulated = true;
            sim_counter.inc();
            sims += 1;
        }

        // measured accuracy can reorder the front — re-prune on the
        // final (loss, latency, hw) vectors before emitting
        let scored: Vec<(ParetoPoint, [f64; 3])> = points
            .into_iter()
            .map(|p| {
                let v = [1.0 - p.accuracy, p.est_latency, p.hw_cost];
                (p, v)
            })
            .collect();
        let final_points: Vec<ParetoPoint> =
            prune_nondominated(scored, &self.objectives)
                .into_iter()
                .map(|(p, _)| p)
                .collect();

        Ok(ParetoFront::from_points(&spec, final_points, baseline,
                                    sims, space, cost.source()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_generation_respects_bci() {
        let opts = ExploreOpts {
            families: vec![Family::Fixed],
            frac_bci: (4, 6),
            int_headroom: 1,
            ..Default::default()
        };
        let cands = candidates_for_mag(9.85, opts.int_headroom, &opts);
        // i in {4, 5}, f in {4, 5, 6} -> 6 candidates (paper FC1 range)
        assert_eq!(cands.len(), 6);
        for c in &cands {
            match c {
                ArithKind::FixedExact(r) => {
                    assert!(r.i_bits >= 4 && r.i_bits <= 5);
                    assert!(r.f_bits >= 4 && r.f_bits <= 6);
                }
                _ => panic!("unexpected family"),
            }
        }
    }

    #[test]
    fn float_candidates_have_range_determined_exponent() {
        let opts = ExploreOpts {
            families: vec![Family::Float],
            frac_bci: (8, 9),
            ..Default::default()
        };
        // paper FC2 range |35.76| -> e = 4 suffices (2^8 = 256)
        for c in candidates_for_mag(35.76, opts.int_headroom, &opts) {
            match c {
                ArithKind::FloatExact(r) => assert_eq!(r.e_bits, 4),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn approx_families_enumerate() {
        let opts = ExploreOpts {
            families: vec![Family::FixedDrum, Family::FloatCfpu],
            frac_bci: (8, 8),
            int_headroom: 0,
            drum_ts: vec![12],
            cfpu_ws: vec![3],
            ..Default::default()
        };
        let cands = candidates_for_mag(9.85, opts.int_headroom, &opts);
        assert!(cands.iter().any(|c| c.name().starts_with("H(")));
        assert!(cands.iter().any(|c| c.name().starts_with("I(")));
    }

    fn ranges_for(spec: &NetSpec, mag: f32) -> Vec<LayerRanges> {
        spec.layers()
            .iter()
            .map(|l| LayerRanges {
                layer: l.name.clone(),
                w: (-mag, mag),
                b: (-mag, mag),
                a: (-mag, mag),
            })
            .collect()
    }

    #[test]
    fn candidate_sets_are_per_layer_and_shape_aware() {
        // conv fan-in 3*3*1 = 9 -> headroom 2; fc fan-in 1568 -> 4
        let spec = NetSpec::parse(
            "28x28x1: conv(3x3,8,pad=1)+relu+pool | dense(10)",
        )
        .unwrap();
        let opts = ExploreOpts {
            families: vec![Family::Fixed],
            frac_bci: (4, 4),
            int_headroom: 0,
            ..Default::default()
        };
        let sets =
            candidate_sets(&spec, &ranges_for(&spec, 9.85), &opts)
                .unwrap();
        assert_eq!(sets.len(), 2);
        let max_i = |set: &[ArithKind]| {
            set.iter()
                .map(|k| match k {
                    ArithKind::FixedExact(r) => r.i_bits,
                    _ => panic!(),
                })
                .max()
                .unwrap()
        };
        // same range, different shapes -> different candidate sets
        assert_eq!(max_i(&sets[0]), 4 + 2);
        assert_eq!(max_i(&sets[1]), 4 + 4);
        assert!(sets[1].len() > sets[0].len());
    }

    #[test]
    fn candidate_sets_reject_arity_mismatch() {
        let spec = NetSpec::parse(
            "28x28x1: dense(16)+relu | dense(10)",
        )
        .unwrap();
        let opts = ExploreOpts::default();
        let one = ranges_for(&spec, 1.0)[..1].to_vec();
        let err = candidate_sets(&spec, &one, &opts).unwrap_err();
        assert!(err.contains("1 WBA range entries"), "{err}");
        assert!(err.contains("2-layer"), "{err}");
        let err =
            layer_candidates(&spec, 5, &ranges_for(&spec, 1.0), &opts)
                .unwrap_err();
        assert!(err.contains("layer 5"), "{err}");
    }
}
