//! The paper's exploration strategy (§4.2), implemented verbatim:
//!
//! 1. Partition the network layer-wise; profile WBA value ranges
//!    (Table 1) to lower-bound the range-determined field (integral bits /
//!    exponent bits), widened for partial-sum growth.
//! 2. Enumerate the accuracy-determined field (fractional / mantissa bits)
//!    over a bit-count interval (BCI).
//! 3. **Pass 1** (topological, input → output): per part, pick the
//!    cheapest (hardware cost model) candidate whose accuracy loss is
//!    within the bound — earlier parts frozen at their chosen configs,
//!    later parts at full precision.
//! 4. **Pass 2** (optional quality recovery): same order, later parts now
//!    at their pass-1 configs; maximize accuracy subject to a bounded
//!    hardware-cost increase (here: at most one extra accuracy bit, the
//!    paper's own example of the constraint).

use super::eval::Evaluator;
use super::ranges::{exp_bits_for, int_bits_for};
use crate::approx::arith::ArithKind;
use crate::approx::cfpu::CfpuMul;
use crate::approx::drum::DrumMul;
use crate::hw::datapath::{Datapath, ARRIA10, N_PE};
use crate::nn::network::LayerRanges;
use crate::nn::spec::ReprMap;
use crate::numeric::{FixedPoint, FloatRep};
use anyhow::Result;

/// Which representation families the search enumerates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Fixed,
    Float,
    FixedDrum,
    FloatCfpu,
}

#[derive(Clone, Debug)]
pub struct ExploreOpts {
    /// relative accuracy loss bound vs float32 baseline (e.g. 0.01 = 1%)
    pub accuracy_bound: f64,
    /// BCI for the accuracy-determined field (fraction / mantissa bits)
    pub frac_bci: (u32, u32),
    /// extra integral-bit headroom enumerated beyond the range bound
    /// (partial-sum widening, §4.2)
    pub int_headroom: u32,
    pub families: Vec<Family>,
    /// run the quality-recovery second pass
    pub second_pass: bool,
    /// DRUM widths / CFPU tuning widths enumerated for approx families
    pub drum_ts: Vec<u32>,
    pub cfpu_ws: Vec<u32>,
}

impl Default for ExploreOpts {
    fn default() -> Self {
        ExploreOpts {
            accuracy_bound: 0.01,
            frac_bci: (4, 12),
            int_headroom: 2,
            families: vec![Family::Fixed, Family::Float],
            second_pass: true,
            drum_ts: vec![10, 12, 14],
            cfpu_ws: vec![3],
        }
    }
}

/// One explored candidate at one part.
#[derive(Clone, Debug)]
pub struct TraceEntry {
    pub part: usize,
    pub candidate: String,
    pub accuracy: f64,
    pub cost: f64,
    pub feasible: bool,
    pub chosen: bool,
    pub pass: u8,
}

#[derive(Clone, Debug)]
pub struct ExploreResult {
    pub baseline: f64,
    pub pass1: ReprMap,
    pub pass1_accuracy: f64,
    pub chosen: ReprMap,
    pub accuracy: f64,
    pub evals: usize,
    pub trace: Vec<TraceEntry>,
}

/// Candidate providers for one part given its value range.
pub fn candidates_for(range_mag: f64, opts: &ExploreOpts)
                      -> Vec<ArithKind> {
    let mut out = Vec::new();
    let ilb = int_bits_for(range_mag);
    let elb = exp_bits_for(range_mag);
    for fam in &opts.families {
        match fam {
            Family::Fixed => {
                for i in ilb..=ilb + opts.int_headroom {
                    for f in opts.frac_bci.0..=opts.frac_bci.1 {
                        if i + f <= 22 {
                            out.push(ArithKind::FixedExact(
                                FixedPoint::new(i, f),
                            ));
                        }
                    }
                }
            }
            Family::Float => {
                // exponent is range-determined ("only a few bits needed")
                for m in opts.frac_bci.0..=opts.frac_bci.1 {
                    out.push(ArithKind::FloatExact(FloatRep::new(
                        elb.clamp(2, 7),
                        m.max(1),
                    )));
                }
            }
            Family::FixedDrum => {
                for i in ilb..=ilb + opts.int_headroom {
                    for f in opts.frac_bci.0..=opts.frac_bci.1 {
                        for &t in &opts.drum_ts {
                            if i + f <= 22 && t >= 2 && t <= i + f {
                                out.push(ArithKind::FixedDrum(
                                    DrumMul::new(FixedPoint::new(i, f), t),
                                ));
                            }
                        }
                    }
                }
            }
            Family::FloatCfpu => {
                for m in opts.frac_bci.0..=opts.frac_bci.1 {
                    for &w in &opts.cfpu_ws {
                        out.push(ArithKind::FloatCfpu(CfpuMul::new(
                            FloatRep::new(elb.clamp(2, 7), m.max(1)),
                            w,
                        )));
                    }
                }
            }
        }
    }
    out
}

/// Hardware cost of a *uniform* datapath built from one part's provider —
/// the per-part objective the greedy pass minimizes.
fn part_cost(kind: &ArithKind) -> f64 {
    Datapath::synthesize(kind, N_PE).explore_cost(&ARRIA10)
}

/// Run the full §4.2 exploration over however many parts the
/// evaluator's topology has (one part per layer — `spec.len()`, the
/// arity `ranges` must match).
pub fn explore(ev: &mut Evaluator, ranges: &[LayerRanges],
               opts: &ExploreOpts) -> Result<ExploreResult> {
    let n_parts = ranges.len();
    assert_eq!(n_parts, ev.spec().len(),
               "one WBA range per layer-wise partition part");
    let f32_uniform = ReprMap::uniform(ArithKind::Float32, n_parts);
    let baseline = ev.accuracy(&f32_uniform)?;
    let floor = baseline * (1.0 - opts.accuracy_bound);
    let mut trace = Vec::new();

    // ---------- pass 1: cost-min subject to accuracy ----------
    let mut cfg = f32_uniform;
    for part in 0..n_parts {
        let mag = {
            let c = ranges[part].combined();
            (c.0.abs()).max(c.1.abs()) as f64
        };
        let cands = candidates_for(mag, opts);
        let mut best: Option<(f64, ArithKind, f64)> = None; // (cost, k, acc)
        let mut fallback: Option<(f64, ArithKind, f64)> = None; // max acc
        for cand in cands {
            let mut trial = cfg.clone();
            trial.set(part, cand);
            let acc = ev.accuracy(&trial)?;
            let cost = part_cost(&cand);
            let feasible = acc >= floor;
            trace.push(TraceEntry {
                part,
                candidate: cand.name(),
                accuracy: acc,
                cost,
                feasible,
                chosen: false,
                pass: 1,
            });
            if feasible
                && best.as_ref().map(|(c, _, _)| cost < *c).unwrap_or(true)
            {
                best = Some((cost, cand, acc));
            }
            if fallback
                .as_ref()
                .map(|(_, _, a)| acc > *a)
                .unwrap_or(true)
            {
                fallback = Some((cost, cand, acc));
            }
        }
        let (_, chosen_kind, _) = best.or(fallback).expect("no candidates");
        cfg.set(part, chosen_kind);
        let name = chosen_kind.name();
        if let Some(t) = trace
            .iter_mut()
            .rev()
            .find(|t| t.part == part && t.pass == 1 && t.candidate == name)
        {
            t.chosen = true;
        }
    }
    let pass1 = cfg;
    let pass1_accuracy = ev.accuracy(&pass1)?;

    // ---------- pass 2: quality recovery under bounded cost ----------
    let mut chosen = pass1.clone();
    if opts.second_pass {
        for part in 0..n_parts {
            let mut best_acc = ev.accuracy(&chosen)?;
            let mut best_kind = *chosen.kind(part);
            for cand in widen_by_one(chosen.kind(part)) {
                let mut trial = chosen.clone();
                trial.set(part, cand);
                let acc = ev.accuracy(&trial)?;
                trace.push(TraceEntry {
                    part,
                    candidate: cand.name(),
                    accuracy: acc,
                    cost: part_cost(&cand),
                    feasible: true,
                    chosen: false,
                    pass: 2,
                });
                if acc > best_acc {
                    best_acc = acc;
                    best_kind = cand;
                }
            }
            chosen.set(part, best_kind);
        }
    }
    let accuracy = ev.accuracy(&chosen)?;

    Ok(ExploreResult {
        baseline,
        pass1,
        pass1_accuracy,
        chosen,
        accuracy,
        evals: ev.eval_count,
        trace,
    })
}

/// Pass-2 neighborhood: one extra bit on the accuracy-determined field
/// (the paper's example of "bounded increase in hardware cost").
fn widen_by_one(kind: &ArithKind) -> Vec<ArithKind> {
    match kind {
        ArithKind::FixedExact(r) if r.i_bits + r.f_bits < 22 => {
            vec![ArithKind::FixedExact(FixedPoint::new(r.i_bits,
                                                       r.f_bits + 1))]
        }
        ArithKind::FloatExact(r) if r.m_bits < 23 => {
            vec![ArithKind::FloatExact(FloatRep::new(r.e_bits,
                                                     r.m_bits + 1))]
        }
        ArithKind::FixedDrum(d) if d.rep.i_bits + d.rep.f_bits < 22 => {
            vec![ArithKind::FixedDrum(DrumMul::new(
                FixedPoint::new(d.rep.i_bits, d.rep.f_bits + 1),
                d.t,
            ))]
        }
        ArithKind::FloatCfpu(c) if c.rep.m_bits < 23 => {
            vec![ArithKind::FloatCfpu(CfpuMul::new(
                FloatRep::new(c.rep.e_bits, c.rep.m_bits + 1),
                c.w,
            ))]
        }
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_generation_respects_bci() {
        let opts = ExploreOpts {
            families: vec![Family::Fixed],
            frac_bci: (4, 6),
            int_headroom: 1,
            ..Default::default()
        };
        let cands = candidates_for(9.85, &opts); // paper FC1 range
        // i in {4, 5}, f in {4, 5, 6} -> 6 candidates
        assert_eq!(cands.len(), 6);
        for c in &cands {
            match c {
                ArithKind::FixedExact(r) => {
                    assert!(r.i_bits >= 4 && r.i_bits <= 5);
                    assert!(r.f_bits >= 4 && r.f_bits <= 6);
                }
                _ => panic!("unexpected family"),
            }
        }
    }

    #[test]
    fn float_candidates_have_range_determined_exponent() {
        let opts = ExploreOpts {
            families: vec![Family::Float],
            frac_bci: (8, 9),
            ..Default::default()
        };
        // paper FC2 range |35.76| -> e = 4 suffices (2^8 = 256)
        for c in candidates_for(35.76, &opts) {
            match c {
                ArithKind::FloatExact(r) => assert_eq!(r.e_bits, 4),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn widen_adds_one_accuracy_bit() {
        let k = ArithKind::parse("FI(6,8)").unwrap();
        assert_eq!(widen_by_one(&k)[0].name(), "FI(6, 9)");
        let k = ArithKind::parse("FL(4,9)").unwrap();
        assert_eq!(widen_by_one(&k)[0].name(), "FL(4, 10)");
        assert!(widen_by_one(&ArithKind::Float32).is_empty());
    }

    #[test]
    fn approx_families_enumerate() {
        let opts = ExploreOpts {
            families: vec![Family::FixedDrum, Family::FloatCfpu],
            frac_bci: (8, 8),
            int_headroom: 0,
            drum_ts: vec![12],
            cfpu_ws: vec![3],
            ..Default::default()
        };
        let cands = candidates_for(9.85, &opts);
        assert!(cands.iter().any(|c| c.name().starts_with("H(")));
        assert!(cands.iter().any(|c| c.name().starts_with("I(")));
    }
}
