//! Dynamic batcher: per-configuration request queues with a
//! max-batch / max-wait batching policy (the vLLM-style continuous-batching
//! core, sized for this workload).
//!
//! Workers block on `next_batch` with a mask of configurations they can
//! serve (the PJRT worker serves exact-arithmetic configs, engine workers
//! serve everything); a batch is released when a queue reaches
//! `max_batch` or its oldest request has waited `max_wait`.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// flattened 28x28 image in [0, 1]
    pub image: Vec<f32>,
    pub config_id: usize,
    pub submitted: Instant,
    pub reply: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    pub latency: Duration,
}

struct Inner {
    queues: Vec<VecDeque<Request>>,
    closed: bool,
}

/// One-lock observability snapshot of a [`BatchQueue`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QueueSnapshot {
    /// Pending requests per config queue (order = config order).
    pub depths: Vec<usize>,
    /// Whether the queue has been closed (drain in progress).
    pub closed: bool,
}

pub struct BatchQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// per-queue capacity: submit() rejects beyond this (backpressure)
    pub capacity: usize,
}

impl BatchQueue {
    pub fn new(n_configs: usize, max_batch: usize, max_wait: Duration,
               capacity: usize) -> BatchQueue {
        BatchQueue {
            inner: Mutex::new(Inner {
                queues: (0..n_configs).map(|_| VecDeque::new()).collect(),
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch,
            max_wait,
            capacity,
        }
    }

    /// Enqueue; `Err(req)` when the target queue is full (backpressure).
    pub fn push(&self, req: Request) -> Result<(), Request> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(req);
        }
        let q = &mut g.queues[req.config_id];
        if q.len() >= self.capacity {
            return Err(req);
        }
        q.push_back(req);
        drop(g);
        self.cv.notify_all();
        Ok(())
    }

    pub fn depth(&self, config_id: usize) -> usize {
        self.inner.lock().unwrap().queues[config_id].len()
    }

    /// Depth of every queue in one lock acquisition (observability
    /// snapshot for the server/metrics reporting).
    pub fn depths(&self) -> Vec<usize> {
        self.snapshot().depths
    }

    /// Consistent observability snapshot — per-queue depths and the
    /// closed flag under one lock acquisition, so a reporter never
    /// sees depths from before a `close` paired with a closed flag
    /// from after it.  `Server::queue_depths` reads its depths through
    /// this; the closed flag is for drain-state reporting.
    pub fn snapshot(&self) -> QueueSnapshot {
        let g = self.inner.lock().unwrap();
        QueueSnapshot {
            depths: g.queues.iter().map(|q| q.len()).collect(),
            closed: g.closed,
        }
    }

    /// Blocking: next batch from any queue accepted by `mask`.  Returns
    /// `None` once closed and drained (for this worker's mask).
    pub fn next_batch(&self, mask: &[bool])
                      -> Option<(usize, Vec<Request>)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            let now = Instant::now();
            // pick the ready queue with the oldest head (FIFO fairness)
            let mut pick: Option<(usize, Instant)> = None;
            let mut soonest_deadline: Option<Duration> = None;
            for (ci, q) in g.queues.iter().enumerate() {
                if !mask[ci] || q.is_empty() {
                    continue;
                }
                let head = q.front().unwrap().submitted;
                let age = now.duration_since(head);
                let ready = q.len() >= self.max_batch
                    || age >= self.max_wait
                    || g.closed;
                if ready {
                    if pick.map(|(_, h)| head < h).unwrap_or(true) {
                        pick = Some((ci, head));
                    }
                } else {
                    let remain = self.max_wait - age;
                    if soonest_deadline.map(|d| remain < d).unwrap_or(true)
                    {
                        soonest_deadline = Some(remain);
                    }
                }
            }
            if let Some((ci, _)) = pick {
                let q = &mut g.queues[ci];
                let take = q.len().min(self.max_batch);
                let batch: Vec<Request> = q.drain(..take).collect();
                return Some((ci, batch));
            }
            if g.closed {
                // nothing ready and closed: drained for this mask?
                let empty = g
                    .queues
                    .iter()
                    .enumerate()
                    .all(|(ci, q)| !mask[ci] || q.is_empty());
                if empty {
                    return None;
                }
                continue; // closed flushes partial batches via `ready`
            }
            g = match soonest_deadline {
                Some(d) => self.cv.wait_timeout(g, d).unwrap().0,
                None => self.cv.wait(g).unwrap(),
            };
        }
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(id: u64, config_id: usize, tx: &Sender<Response>) -> Request {
        Request {
            id,
            image: vec![0.0; 4],
            config_id,
            submitted: Instant::now(),
            reply: tx.clone(),
        }
    }

    #[test]
    fn full_batch_released_immediately() {
        let q = BatchQueue::new(1, 4, Duration::from_secs(60), 100);
        let (tx, _rx) = channel();
        for i in 0..4 {
            q.push(req(i, 0, &tx)).unwrap();
        }
        let (ci, batch) = q.next_batch(&[true]).unwrap();
        assert_eq!(ci, 0);
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0); // FIFO
    }

    #[test]
    fn partial_batch_released_after_max_wait() {
        let q = BatchQueue::new(1, 64, Duration::from_millis(30), 100);
        let (tx, _rx) = channel();
        q.push(req(7, 0, &tx)).unwrap();
        let t0 = Instant::now();
        let (_, batch) = q.next_batch(&[true]).unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(25), "waited {waited:?}");
        assert!(waited < Duration::from_millis(500));
    }

    #[test]
    fn mask_filters_queues() {
        let q = BatchQueue::new(2, 1, Duration::from_millis(5), 100);
        let (tx, _rx) = channel();
        q.push(req(1, 0, &tx)).unwrap();
        q.push(req(2, 1, &tx)).unwrap();
        assert_eq!(q.depths(), vec![1, 1]);
        let (ci, _) = q.next_batch(&[false, true]).unwrap();
        assert_eq!(ci, 1);
        assert_eq!(q.depth(0), 1);
        assert_eq!(q.depths(), vec![1, 0]);
        let snap = q.snapshot();
        assert_eq!(snap.depths, vec![1, 0]);
        assert!(!snap.closed);
        q.close();
        assert!(q.snapshot().closed);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let q = BatchQueue::new(1, 4, Duration::from_secs(1), 2);
        let (tx, _rx) = channel();
        q.push(req(1, 0, &tx)).unwrap();
        q.push(req(2, 0, &tx)).unwrap();
        assert!(q.push(req(3, 0, &tx)).is_err());
    }

    #[test]
    fn close_flushes_then_returns_none() {
        let q = Arc::new(BatchQueue::new(1, 64, Duration::from_secs(60),
                                         100));
        let (tx, _rx) = channel();
        q.push(req(1, 0, &tx)).unwrap();
        q.close();
        let (_, batch) = q.next_batch(&[true]).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(q.next_batch(&[true]).is_none());
        assert!(q.push(req(2, 0, &tx)).is_err());
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q = Arc::new(BatchQueue::new(1, 8, Duration::from_millis(5),
                                         10_000));
        let (tx, _rx) = channel();
        let n = 200u64;
        let qp = q.clone();
        let prod = std::thread::spawn(move || {
            for i in 0..n {
                qp.push(req(i, 0, &tx)).unwrap();
            }
            qp.close();
        });
        let mut got = 0;
        while let Some((_, b)) = q.next_batch(&[true]) {
            got += b.len();
        }
        prod.join().unwrap();
        assert_eq!(got as u64, n);
    }
}
