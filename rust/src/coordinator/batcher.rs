//! Dynamic batcher: per-configuration request queues with a
//! max-batch / max-wait batching policy (the vLLM-style continuous-batching
//! core, sized for this workload), extended with per-request queueing
//! deadlines and a degrade-aware admission path.
//!
//! Workers block on `next_batch` with a mask of configurations they can
//! serve (the PJRT worker serves exact-arithmetic configs, engine workers
//! serve everything); a batch is released when a queue reaches
//! `max_batch`, its oldest request has waited `max_wait`, or waiting any
//! longer would miss the oldest request's deadline.  Requests whose
//! deadline has already passed are **expired**: removed from their queue
//! and answered with `Response::Error(Expired)` instead of being served
//! stale — a released batch never contains an expired request.

use super::metrics::Metrics;
use crate::telemetry::StageBreakdown;
use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How far before a head-of-queue deadline the batcher releases a
/// partial batch: `next_batch` hands the largest batch that can still
/// be given to a worker *before* the deadline passes, rather than
/// waiting out `max_wait` and expiring the head.  The slack covers the
/// wake-up + drain hand-over so the release lands on the meeting side
/// of the deadline.  (The deadline itself is a *queueing* deadline —
/// admission to dequeue — not an end-to-end one; a request released
/// just in time may still finish serving after it.)
const DEADLINE_RELEASE_SLACK: Duration = Duration::from_micros(500);

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// flattened 28x28 image in [0, 1]
    pub image: Vec<f32>,
    pub config_id: usize,
    pub submitted: Instant,
    /// Queueing deadline: if the request is still queued at this
    /// instant it is expired (answered `Error(Expired)`), never served.
    pub deadline: Option<Instant>,
    pub reply: Sender<Response>,
}

/// Every way a request can fail after the router accepted it — the
/// error half of [`Outcome`].  Each kind is distinguishable at the
/// client and counted in its own [`Metrics`] counter; none of them
/// enter the latency histogram.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// The queueing deadline passed before a worker picked the request
    /// up; it was removed from its queue unserved.
    Expired,
    /// The backend's forward pass failed (e.g. a PJRT execution
    /// error); the request reached a worker but produced no
    /// prediction.
    Backend,
    /// Dropped at admission by the `Shed` overload policy: the queue
    /// was past its high-water mark and the newest request yields.
    Shed,
}

/// What a [`Response`] carries: a real prediction, or a typed failure.
/// The pre-PR-7 contract smuggled backend failures through the success
/// path as the sentinel `pred = usize::MAX`, indistinguishable from a
/// class index; every failure mode is now explicit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Predicted class index.
    Ok(usize),
    Error(FailureKind),
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub outcome: Outcome,
    /// Submit-to-reply time.  Only `Ok` responses are recorded in the
    /// server's latency histogram; failures carry their latency here
    /// but are counted in their own [`Metrics`] counters instead.
    pub latency: Duration,
    /// Per-stage attribution of where this request's time went —
    /// populated for served requests when `LOP_TRACE` tracing is on
    /// (`None` otherwise, and always `None` for shed/expired/backend
    /// failures, which never run the full stage pipeline).  Shared
    /// `Arc` because every request in a batch shares the batch-level
    /// stage costs.
    pub breakdown: Option<Arc<StageBreakdown>>,
}

impl Response {
    /// The predicted class, if the request was actually served.
    pub fn pred(&self) -> Option<usize> {
        match self.outcome {
            Outcome::Ok(p) => Some(p),
            Outcome::Error(_) => None,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self.outcome, Outcome::Ok(_))
    }
}

/// Why [`BatchQueue::admit`] refused a request.  Shutdown and overload
/// are different conditions and must surface differently at the
/// router (`SubmitError::ShuttingDown` vs the overload policy); the
/// pre-PR-7 `Err(req)` collapsed them, reporting `Overloaded` during
/// drain.
#[derive(Debug)]
pub enum PushError {
    /// The queue is closed (server draining for shutdown).
    Closed(Request),
    /// The target queue — and every degrade rung offered — is at the
    /// high-water mark.
    Full(Request),
}

impl PushError {
    /// Recover the request (e.g. to reply to it directly).
    pub fn into_request(self) -> Request {
        match self {
            PushError::Closed(r) | PushError::Full(r) => r,
        }
    }
}

/// Where [`BatchQueue::admit`] placed an accepted request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admitted {
    /// On the queue it asked for.
    Queued,
    /// Re-routed to this cheaper config's queue (degrade ladder).
    Degraded(usize),
}

struct Inner {
    queues: Vec<VecDeque<Request>>,
    closed: bool,
}

/// One-lock observability snapshot of a [`BatchQueue`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QueueSnapshot {
    /// Pending requests per config queue (order = config order).
    pub depths: Vec<usize>,
    /// Whether the queue has been closed (drain in progress).
    pub closed: bool,
}

pub struct BatchQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Per-queue high-water mark: `admit` refuses beyond this.  What
    /// the refusal *means* — reject, shed, or degrade — is the
    /// router's overload policy, not the queue's concern.
    pub capacity: usize,
    /// Expiry accounting (`expired` ticks as the sweep removes
    /// requests); shared with the router and server.
    metrics: Arc<Metrics>,
}

impl BatchQueue {
    pub fn new(n_configs: usize, max_batch: usize, max_wait: Duration,
               capacity: usize, metrics: Arc<Metrics>) -> BatchQueue {
        BatchQueue {
            inner: Mutex::new(Inner {
                queues: (0..n_configs).map(|_| VecDeque::new()).collect(),
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch,
            max_wait,
            capacity,
            metrics,
        }
    }

    /// Enqueue under one lock acquisition, with overload fallback: if
    /// the target queue is at capacity, try each config id in `ladder`
    /// (the router's degrade ladder, nearest-cheaper first) before
    /// giving up.  The room check and the enqueue are atomic, so a
    /// degrade decision cannot race another submitter into an
    /// over-full queue.
    pub fn admit(&self, mut req: Request, ladder: &[usize])
                 -> Result<Admitted, PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(req));
        }
        if g.queues[req.config_id].len() < self.capacity {
            let ci = req.config_id;
            g.queues[ci].push_back(req);
            drop(g);
            self.cv.notify_all();
            return Ok(Admitted::Queued);
        }
        for &ci in ladder {
            if g.queues[ci].len() < self.capacity {
                req.config_id = ci;
                g.queues[ci].push_back(req);
                drop(g);
                self.cv.notify_all();
                return Ok(Admitted::Degraded(ci));
            }
        }
        Err(PushError::Full(req))
    }

    /// Enqueue on the request's own queue only; the error carries the
    /// request back so the caller can reply to or report it.
    pub fn push(&self, req: Request) -> Result<(), PushError> {
        self.admit(req, &[]).map(|_| ())
    }

    pub fn depth(&self, config_id: usize) -> usize {
        self.inner.lock().unwrap().queues[config_id].len()
    }

    /// Depth of every queue in one lock acquisition (observability
    /// snapshot for the server/metrics reporting).
    pub fn depths(&self) -> Vec<usize> {
        self.snapshot().depths
    }

    /// Consistent observability snapshot — per-queue depths and the
    /// closed flag under one lock acquisition, so a reporter never
    /// sees depths from before a `close` paired with a closed flag
    /// from after it.  `Server::queue_depths` reads its depths through
    /// this; the closed flag is for drain-state reporting.
    pub fn snapshot(&self) -> QueueSnapshot {
        let g = self.inner.lock().unwrap();
        QueueSnapshot {
            depths: g.queues.iter().map(|q| q.len()).collect(),
            closed: g.closed,
        }
    }

    /// Blocking: next batch from any queue accepted by `mask`.  Returns
    /// `None` once closed and drained (for this worker's mask).
    ///
    /// Deadline semantics: every wake-up first sweeps the masked
    /// queues, removing requests whose deadline has passed and
    /// answering them `Error(Expired)` — so a released batch never
    /// contains an expired request.  A queue's release point is the
    /// earlier of the batching timer (`head.submitted + max_wait`) and
    /// the head's deadline minus [`DEADLINE_RELEASE_SLACK`], i.e. the
    /// largest batch that still meets the oldest request's deadline.
    pub fn next_batch(&self, mask: &[bool])
                      -> Option<(usize, Vec<Request>)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            let now = Instant::now();
            // Expiry sweep.  Replying under the lock is safe: std mpsc
            // senders are unbounded and never block.  Also track the
            // earliest live deadline so the wait below wakes in time
            // to expire a mid-queue request promptly.
            let mut earliest_deadline: Option<Instant> = None;
            for (ci, q) in g.queues.iter_mut().enumerate() {
                if !mask[ci] {
                    continue;
                }
                let mut i = 0;
                while i < q.len() {
                    match q[i].deadline {
                        Some(d) if d <= now => {
                            let req = q.remove(i).unwrap();
                            self.metrics.expired.inc();
                            let _ = req.reply.send(Response {
                                id: req.id,
                                outcome: Outcome::Error(
                                    FailureKind::Expired,
                                ),
                                latency:
                                    now.duration_since(req.submitted),
                                breakdown: None,
                            });
                        }
                        Some(d) => {
                            let sooner = earliest_deadline
                                .map(|e| d < e)
                                .unwrap_or(true);
                            if sooner {
                                earliest_deadline = Some(d);
                            }
                            i += 1;
                        }
                        None => i += 1,
                    }
                }
            }
            // pick the ready queue with the oldest head (FIFO fairness)
            let mut pick: Option<(usize, Instant)> = None;
            let mut next_wake: Option<Instant> = None;
            for (ci, q) in g.queues.iter().enumerate() {
                if !mask[ci] || q.is_empty() {
                    continue;
                }
                let head = q.front().unwrap();
                let mut release_at = head.submitted + self.max_wait;
                if let Some(d) = head.deadline {
                    let dl = d
                        .checked_sub(DEADLINE_RELEASE_SLACK)
                        .unwrap_or(d);
                    release_at = release_at.min(dl);
                }
                let ready = q.len() >= self.max_batch
                    || now >= release_at
                    || g.closed;
                if ready {
                    let h = head.submitted;
                    if pick.map(|(_, ph)| h < ph).unwrap_or(true) {
                        pick = Some((ci, h));
                    }
                } else if next_wake
                    .map(|w| release_at < w)
                    .unwrap_or(true)
                {
                    next_wake = Some(release_at);
                }
            }
            if let Some((ci, _)) = pick {
                let q = &mut g.queues[ci];
                let take = q.len().min(self.max_batch);
                let batch: Vec<Request> = q.drain(..take).collect();
                return Some((ci, batch));
            }
            if g.closed {
                // Once closed, any non-empty masked queue is `ready`
                // (the `|| g.closed` arm above), so reaching here with
                // no pick means this worker's queues are drained.
                // (The pre-deadline code kept a `continue` for the
                // non-empty case in this spot; it was unreachable —
                // and would have busy-spun under the lock had it ever
                // run.)
                return None;
            }
            // Sleep until the soonest release point or live deadline
            // (whichever comes first); both are in the future here —
            // a past release point made its queue `ready` and a past
            // deadline was swept above.
            if let Some(d) = earliest_deadline {
                next_wake = Some(next_wake.map_or(d, |w| w.min(d)));
            }
            g = match next_wake {
                Some(at) => {
                    let dur = at.saturating_duration_since(now);
                    self.cv.wait_timeout(g, dur).unwrap().0
                }
                None => self.cv.wait(g).unwrap(),
            };
        }
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn bq(n_configs: usize, max_batch: usize, max_wait: Duration,
          capacity: usize) -> BatchQueue {
        BatchQueue::new(n_configs, max_batch, max_wait, capacity,
                        Arc::new(Metrics::new()))
    }

    fn req(id: u64, config_id: usize, tx: &Sender<Response>) -> Request {
        Request {
            id,
            image: vec![0.0; 4],
            config_id,
            submitted: Instant::now(),
            deadline: None,
            reply: tx.clone(),
        }
    }

    fn req_deadline(id: u64, config_id: usize, deadline: Instant,
                    tx: &Sender<Response>) -> Request {
        Request { deadline: Some(deadline), ..req(id, config_id, tx) }
    }

    #[test]
    fn full_batch_released_immediately() {
        let q = bq(1, 4, Duration::from_secs(60), 100);
        let (tx, _rx) = channel();
        for i in 0..4 {
            q.push(req(i, 0, &tx)).unwrap();
        }
        let (ci, batch) = q.next_batch(&[true]).unwrap();
        assert_eq!(ci, 0);
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0); // FIFO
    }

    #[test]
    fn partial_batch_released_after_max_wait() {
        let q = bq(1, 64, Duration::from_millis(30), 100);
        let (tx, _rx) = channel();
        q.push(req(7, 0, &tx)).unwrap();
        let t0 = Instant::now();
        let (_, batch) = q.next_batch(&[true]).unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(25), "waited {waited:?}");
        assert!(waited < Duration::from_millis(500));
    }

    #[test]
    fn mask_filters_queues() {
        let q = bq(2, 1, Duration::from_millis(5), 100);
        let (tx, _rx) = channel();
        q.push(req(1, 0, &tx)).unwrap();
        q.push(req(2, 1, &tx)).unwrap();
        assert_eq!(q.depths(), vec![1, 1]);
        let (ci, _) = q.next_batch(&[false, true]).unwrap();
        assert_eq!(ci, 1);
        assert_eq!(q.depth(0), 1);
        assert_eq!(q.depths(), vec![1, 0]);
        let snap = q.snapshot();
        assert_eq!(snap.depths, vec![1, 0]);
        assert!(!snap.closed);
        q.close();
        assert!(q.snapshot().closed);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let q = bq(1, 4, Duration::from_secs(1), 2);
        let (tx, _rx) = channel();
        q.push(req(1, 0, &tx)).unwrap();
        q.push(req(2, 0, &tx)).unwrap();
        assert!(matches!(q.push(req(3, 0, &tx)),
                         Err(PushError::Full(_))));
    }

    #[test]
    fn closed_and_full_are_distinct_errors() {
        let q = bq(1, 4, Duration::from_secs(1), 1);
        let (tx, _rx) = channel();
        q.push(req(1, 0, &tx)).unwrap();
        // full queue → Full, carrying the request back
        let r = match q.push(req(2, 0, &tx)) {
            Err(PushError::Full(r)) => r,
            other => panic!("expected Full, got {other:?}"),
        };
        assert_eq!(r.id, 2);
        // closed queue → Closed, even though it is also at capacity
        q.close();
        let r = match q.push(req(3, 0, &tx)) {
            Err(PushError::Closed(r)) => r,
            other => panic!("expected Closed, got {other:?}"),
        };
        assert_eq!(r.id, 3);
        // and into_request round-trips both variants
        assert_eq!(PushError::Full(req(4, 0, &tx))
                       .into_request().id, 4);
    }

    #[test]
    fn admit_degrades_to_ladder_when_full() {
        let q = bq(3, 4, Duration::from_secs(1), 1);
        let (tx, _rx) = channel();
        assert_eq!(q.admit(req(0, 0, &tx), &[1, 2]).unwrap(),
                   Admitted::Queued);
        // queue 0 full → first rung with room wins, and the request's
        // config_id is rewritten to the rung it landed on
        assert_eq!(q.admit(req(1, 0, &tx), &[1, 2]).unwrap(),
                   Admitted::Degraded(1));
        assert_eq!(q.admit(req(2, 0, &tx), &[1, 2]).unwrap(),
                   Admitted::Degraded(2));
        assert!(matches!(q.admit(req(3, 0, &tx), &[1, 2]),
                         Err(PushError::Full(_))));
        assert_eq!(q.depths(), vec![1, 1, 1]);
        let (ci, batch) = q.next_batch(&[false, true, false]).unwrap();
        assert_eq!(ci, 1);
        assert_eq!(batch[0].id, 1);
        assert_eq!(batch[0].config_id, 1, "degraded request must be \
                   relabelled to the queue it landed on");
    }

    #[test]
    fn close_flushes_then_returns_none() {
        let q = bq(1, 64, Duration::from_secs(60), 100);
        let (tx, _rx) = channel();
        q.push(req(1, 0, &tx)).unwrap();
        q.close();
        let (_, batch) = q.next_batch(&[true]).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(q.next_batch(&[true]).is_none());
        assert!(matches!(q.push(req(2, 0, &tx)),
                         Err(PushError::Closed(_))));
    }

    #[test]
    fn expired_requests_are_answered_not_served() {
        let metrics = Arc::new(Metrics::new());
        let q = BatchQueue::new(1, 4, Duration::from_secs(60), 100,
                                metrics.clone());
        let (tx, rx) = channel();
        let past = Instant::now();
        q.push(req_deadline(1, 0, past, &tx)).unwrap();
        q.push(req_deadline(2, 0, past, &tx)).unwrap();
        // close so the drain terminates; the sweep must still answer
        // both expired requests rather than flushing them as a batch
        q.close();
        assert!(q.next_batch(&[true]).is_none());
        let mut ids = Vec::new();
        while let Ok(r) = rx.try_recv() {
            assert_eq!(r.outcome, Outcome::Error(FailureKind::Expired));
            assert_eq!(r.pred(), None);
            assert!(!r.is_ok());
            ids.push(r.id);
        }
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(metrics.expired.get(), 2);
    }

    #[test]
    fn mixed_queue_releases_live_requests_only() {
        let q = bq(1, 8, Duration::from_secs(60), 100);
        let (tx, rx) = channel();
        let now = Instant::now();
        q.push(req_deadline(1, 0, now, &tx)).unwrap(); // expired
        q.push(req(2, 0, &tx)).unwrap(); // live, no deadline
        q.push(req_deadline(3, 0, now, &tx)).unwrap(); // expired
        q.push(req(4, 0, &tx)).unwrap(); // live
        q.close();
        let (_, batch) = q.next_batch(&[true]).unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 4], "batch must hold live requests \
                   only, in FIFO order");
        assert!(q.next_batch(&[true]).is_none());
        let expired: Vec<u64> = rx.try_iter().map(|r| r.id).collect();
        assert_eq!(expired, vec![1, 3]);
    }

    #[test]
    fn deadline_releases_partial_batch_early() {
        // max_wait is effectively infinite; the head's 40ms deadline
        // must force an early release (before the deadline, so the
        // request is served, not expired).
        let q = bq(1, 64, Duration::from_secs(3600), 100);
        let (tx, rx) = channel();
        let d = Instant::now() + Duration::from_millis(40);
        q.push(req_deadline(9, 0, d, &tx)).unwrap();
        let t0 = Instant::now();
        let (_, batch) = q.next_batch(&[true]).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 9);
        let waited = t0.elapsed();
        assert!(waited < Duration::from_millis(500),
                "released by deadline, not max_wait: {waited:?}");
        assert!(rx.try_recv().is_err(), "served, not expired");
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q = Arc::new(bq(1, 8, Duration::from_millis(5), 10_000));
        let (tx, _rx) = channel();
        let n = 200u64;
        let qp = q.clone();
        let prod = std::thread::spawn(move || {
            for i in 0..n {
                qp.push(req(i, 0, &tx)).unwrap();
            }
            qp.close();
        });
        let mut got = 0;
        while let Some((_, b)) = q.next_batch(&[true]) {
            got += b.len();
        }
        prod.join().unwrap();
        assert_eq!(got as u64, n);
    }

    // ------------------------------------------------ property sweep

    /// One generated scenario: requests with fabricated ages (so the
    /// test never sleeps) and a deadline class each —
    /// 0 = none, 1 = live (now + 1h), 2 = already expired.
    #[derive(Debug)]
    struct Scenario {
        n_queues: usize,
        max_batch: usize,
        max_wait: Duration,
        /// (queue, age, deadline class)
        reqs: Vec<(usize, Duration, u8)>,
    }

    fn gen_scenario(rng: &mut crate::util::prng::Rng) -> Scenario {
        let n_queues = 1 + rng.below(3) as usize;
        let max_batch = [1usize, 2, 4, 8][rng.below(4) as usize];
        // small enough that an "old" head is instantly ready, or huge
        // enough that nothing is ready before close()
        let max_wait = if rng.below(2) == 0 {
            Duration::from_millis(5)
        } else {
            Duration::from_secs(1800)
        };
        let n = rng.below(24) as usize;
        let reqs = (0..n)
            .map(|_| {
                let q = rng.below(n_queues as u64) as usize;
                let age = if rng.below(2) == 0 {
                    Duration::ZERO
                } else {
                    Duration::from_secs(1)
                };
                (q, age, rng.below(3) as u8)
            })
            .collect();
        Scenario { n_queues, max_batch, max_wait, reqs }
    }

    /// Satellite-5 property: across random max_batch / max_wait /
    /// deadline combinations — no released batch contains an expired
    /// request, released batches are FIFO prefixes, the post-close
    /// pick is always the globally oldest ready head (cross-queue
    /// fairness), close() flushes every live partial, and every
    /// expired request is answered `Error(Expired)` exactly once.
    #[test]
    fn prop_deadline_fifo_and_close_flush() {
        crate::util::prop::check_msg(
            "batcher deadline/FIFO/close-flush",
            0x10ad_5eed,
            64,
            gen_scenario,
            |s| {
                let metrics = Arc::new(Metrics::new());
                let q = BatchQueue::new(s.n_queues, s.max_batch,
                                        s.max_wait, 10_000,
                                        metrics.clone());
                let (tx, rx) = channel();
                let now0 = Instant::now();
                // mirror: per-queue FIFO of live (id, submitted)
                let mut live: Vec<Vec<(u64, Instant)>> =
                    vec![Vec::new(); s.n_queues];
                let mut expired_ids: Vec<u64> = Vec::new();
                for (id, &(qi, age, dc)) in s.reqs.iter().enumerate() {
                    let id = id as u64;
                    let submitted =
                        now0.checked_sub(age).unwrap_or(now0);
                    let deadline = match dc {
                        0 => None,
                        1 => Some(now0 + Duration::from_secs(3600)),
                        // already past by the time any sweep runs
                        _ => Some(submitted + Duration::from_nanos(1)),
                    };
                    if dc == 2 {
                        expired_ids.push(id);
                    } else {
                        live[qi].push((id, submitted));
                    }
                    q.push(Request {
                        id,
                        image: vec![0.0; 4],
                        config_id: qi,
                        submitted,
                        deadline,
                        reply: tx.clone(),
                    })
                    .map_err(|e| format!("push failed: {e:?}"))?;
                }
                let mask = vec![true; s.n_queues];
                let check_batch =
                    |ci: usize, batch: &[Request],
                     live: &mut [Vec<(u64, Instant)>]|
                     -> Result<(), String> {
                        let want = live[ci].len().min(s.max_batch);
                        if batch.len() != want {
                            return Err(format!(
                                "queue {ci}: batch len {} != {want}",
                                batch.len()));
                        }
                        for (r, &(id, _)) in
                            batch.iter().zip(live[ci].iter())
                        {
                            if r.deadline
                                .is_some_and(|d| d <= Instant::now())
                            {
                                return Err(format!(
                                    "expired id {} released", r.id));
                            }
                            if r.id != id {
                                return Err(format!(
                                    "queue {ci}: got id {} want {id} \
                                     (FIFO prefix violated)", r.id));
                            }
                        }
                        live[ci].drain(..batch.len());
                        Ok(())
                    };
                // Pre-close probe, only when the mirror says a batch
                // is certainly releasable (mirror-ready ⊆ real-ready,
                // so this cannot block): a full batch of live
                // requests, or an old head with the small max_wait.
                let probe = (0..s.n_queues).any(|ci| {
                    live[ci].len() >= s.max_batch
                        || (!live[ci].is_empty()
                            && live[ci][0].1 < now0
                            && s.max_wait < Duration::from_secs(1))
                });
                if probe {
                    let (ci, batch) = q.next_batch(&mask)
                        .ok_or("probe: queue drained early")?;
                    check_batch(ci, &batch, &mut live)?;
                }
                // close() flushes every remaining live partial
                q.close();
                while let Some((ci, batch)) = q.next_batch(&mask) {
                    // cross-queue FIFO fairness: once closed every
                    // non-empty queue is ready, so the pick must be
                    // the globally oldest head (ties allowed — equal
                    // fabricated ages share one submitted instant)
                    let head = live[ci]
                        .first()
                        .ok_or_else(|| {
                            format!("queue {ci}: unexpected batch")
                        })?
                        .1;
                    for (oi, l) in live.iter().enumerate() {
                        if let Some(&(_, h)) = l.first() {
                            if head > h {
                                return Err(format!(
                                    "unfair pick: queue {ci} head is \
                                     newer than queue {oi}'s"));
                            }
                        }
                    }
                    check_batch(ci, &batch, &mut live)?;
                }
                if live.iter().any(|l| !l.is_empty()) {
                    return Err(format!(
                        "close() left live requests queued: {live:?}"));
                }
                // every expired request answered exactly once
                let mut got: Vec<u64> =
                    rx.try_iter()
                        .map(|r| {
                            (r.outcome
                                == Outcome::Error(FailureKind::Expired))
                                .then_some(r.id)
                                .ok_or_else(|| format!(
                                    "non-expired reply {:?}", r.outcome))
                        })
                        .collect::<Result<_, _>>()?;
                got.sort_unstable();
                expired_ids.sort_unstable();
                if got != expired_ids {
                    return Err(format!(
                        "expired replies {got:?} != {expired_ids:?}"));
                }
                let n = metrics.expired.get();
                if n as usize != expired_ids.len() {
                    return Err(format!(
                        "metrics.expired {n} != {}", expired_ids.len()));
                }
                Ok(())
            },
        );
    }
}
