//! Value-range profiling (paper §4.2 / Table 1): dump the [min, max] of
//! weights, biases and activations per partition part by running the
//! trained float32 network over (a slice of) the training set, and derive
//! the range-determined BCI lower bounds from them.

use crate::data::Dataset;
use crate::nn::network::{LayerRanges, Model};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// Profile WBA ranges over the first `n` training images — one entry
/// per layer of the model's spec, whatever its depth.
pub fn profile_ranges(model: &Model, ds: &Dataset, n: usize,
                      threads: usize) -> Vec<LayerRanges> {
    let n = n.min(ds.train.len()).max(1);
    let idx: Vec<usize> = (0..n).collect();
    let x = ds.batch(&ds.train, &idx);
    model.ranges(&x, threads)
}

/// Integral bits needed to represent |v| <= `mag` in sign-magnitude
/// fixed point: ceil(log2(mag)) clamped at >= 0 (the sign bit is separate).
pub fn int_bits_for(mag: f64) -> u32 {
    if mag <= 1.0 {
        0
    } else {
        (mag.log2().ceil() as i64).max(0) as u32
    }
}

/// Exponent bits needed for a float representation to cover `mag`:
/// the max exponent `emax = 2^(e-1)` must satisfy `2^emax >= mag`.
pub fn exp_bits_for(mag: f64) -> u32 {
    let need = if mag <= 2.0 { 1 } else { mag.log2().ceil() as i64 };
    let mut e = 2u32;
    while (1i64 << (e - 1)) < need {
        e += 1;
    }
    e
}

/// Table-1 row rendering.
pub fn format_table1(ranges: &[LayerRanges]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<7} {:>18} {:>18} {:>18} {:>18}\n",
        "Layer", "weights", "biases", "activations", "combined range"
    ));
    s.push_str(&"-".repeat(84));
    s.push('\n');
    for r in ranges {
        let c = r.combined();
        s.push_str(&format!(
            "{:<7} [{:>7.2}, {:>6.2}] [{:>7.2}, {:>6.2}] \
             [{:>7.2}, {:>6.2}] [{:>7.2}, {:>6.2}]\n",
            r.layer, r.w.0, r.w.1, r.b.0, r.b.1, r.a.0, r.a.1, c.0, c.1
        ));
    }
    s
}

/// Cross-check against the python-side dump (`artifacts/ranges.json`):
/// returns the maximum absolute deviation of the combined range bounds.
pub fn compare_with_python(ranges: &[LayerRanges], json_path: &Path)
                           -> Result<f64> {
    let raw = std::fs::read_to_string(json_path)
        .with_context(|| format!("reading {json_path:?}"))?;
    let j = Json::parse(&raw).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut worst = 0f64;
    for r in ranges {
        let lr = j
            .get(&r.layer)
            .and_then(|l| l.get("range"))
            .and_then(Json::as_arr)
            .with_context(|| format!("ranges.json missing {}", r.layer))?;
        let (plo, phi) = (
            lr[0].as_f64().context("bad lo")?,
            lr[1].as_f64().context("bad hi")?,
        );
        let c = r.combined();
        worst = worst.max((c.0 as f64 - plo).abs());
        worst = worst.max((c.1 as f64 - phi).abs());
    }
    Ok(worst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_bits_examples() {
        // paper: FC1 range [-9.85, 6.80] -> 4 integral bits
        assert_eq!(int_bits_for(9.85), 4);
        assert_eq!(int_bits_for(35.76), 6);
        assert_eq!(int_bits_for(1.45), 1);
        assert_eq!(int_bits_for(0.5), 0);
        assert_eq!(int_bits_for(16.0), 4);
        assert_eq!(int_bits_for(16.01), 5);
    }

    #[test]
    fn exp_bits_examples() {
        // 4 exponent bits (emax = 8) cover |v| < 2^8
        assert_eq!(exp_bits_for(35.76), 4);
        assert_eq!(exp_bits_for(200.0), 4);
        assert_eq!(exp_bits_for(300.0), 5);
        assert_eq!(exp_bits_for(1.0), 2);
    }
}
