//! L3 coordinator — the paper's system contribution, Rust-side:
//!
//! * `ranges`   — WBA value-range profiling (paper Table 1, §4.2)
//! * `eval`     — accuracy evaluation with backend selection + memoization
//! * `explorer` — the fluent `Explorer` driver: the paper's two-pass
//!   topological strategy (§4.2) plus the surrogate-guided
//!   multi-objective search, per-layer candidate generation
//! * `pareto`   — surrogate machinery for the explorer: quality
//!   sensitivity profiles, the analytic/bench-calibrated cost model,
//!   dominance pruning, and the `pareto_front.json` artifact that
//!   `serve --auto` consumes
//! * `batcher`/`server`/`router` — the inference serving runtime: request
//!   routing with deadline-aware admission and an overload policy
//!   (reject / shed / degrade-to-cheaper-config), per-config dynamic
//!   batching with expiry, worker pools, typed `Ok`/`Error` responses,
//!   metrics (the vLLM-router-shaped part of the stack)
//! * `plan_cache` — one shared `Arc<PreparedNet>` per configuration
//!   (single-flight prepare, LRU-by-bytes eviction) serving every
//!   engine worker and the evaluator
//! * `metrics`  — latency/throughput accounting

pub mod batcher;
pub mod eval;
pub mod explorer;
pub mod metrics;
pub mod pareto;
pub mod plan_cache;
pub mod ranges;
pub mod router;
pub mod server;
