//! Request router: the serving front door.  Maps a requested network
//! configuration (the paper's "domain choice") to its queue, assigns
//! request ids, applies admission control, and tracks submission metrics.

use super::batcher::{BatchQueue, Request, Response};
use super::metrics::Metrics;
use crate::nn::network::NetConfig;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

pub struct Router {
    pub configs: Vec<NetConfig>,
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    UnknownConfig,
    Overloaded,
}

impl Router {
    pub fn new(configs: Vec<NetConfig>, queue: Arc<BatchQueue>,
               metrics: Arc<Metrics>) -> Router {
        Router { configs, queue, metrics, next_id: AtomicU64::new(0) }
    }

    pub fn config_id(&self, name: &str) -> Option<usize> {
        self.configs.iter().position(|c| c.name() == name)
    }

    /// Submit one image for classification under configuration
    /// `config_id`; the response arrives on `reply`.
    pub fn submit(&self, config_id: usize, image: Vec<f32>,
                  reply: Sender<Response>) -> Result<u64, SubmitError> {
        if config_id >= self.configs.len() {
            return Err(SubmitError::UnknownConfig);
        }
        debug_assert_eq!(image.len(), 784);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            image,
            config_id,
            submitted: Instant::now(),
            reply,
        };
        match self.queue.push(req) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(id)
            }
            Err(_) => Err(SubmitError::Overloaded),
        }
    }

    pub fn queue_depth(&self, config_id: usize) -> usize {
        self.queue.depth(config_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::arith::ArithKind;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn mk_router(cap: usize) -> (Router, Arc<BatchQueue>) {
        let configs = vec![
            NetConfig::uniform(ArithKind::Float32),
            NetConfig::parse("FI(6,8)").unwrap(),
        ];
        let q = Arc::new(BatchQueue::new(configs.len(), 8,
                                         Duration::from_millis(10), cap));
        let r = Router::new(configs, q.clone(), Arc::new(Metrics::new()));
        (r, q)
    }

    #[test]
    fn routes_by_config() {
        let (r, q) = mk_router(100);
        let (tx, _rx) = channel();
        r.submit(1, vec![0.0; 784], tx.clone()).unwrap();
        r.submit(1, vec![0.0; 784], tx.clone()).unwrap();
        r.submit(0, vec![0.0; 784], tx).unwrap();
        assert_eq!(q.depth(0), 1);
        assert_eq!(q.depth(1), 2);
    }

    #[test]
    fn unknown_config_rejected() {
        let (r, _) = mk_router(100);
        let (tx, _rx) = channel();
        assert_eq!(r.submit(9, vec![0.0; 784], tx),
                   Err(SubmitError::UnknownConfig));
    }

    #[test]
    fn overload_rejected() {
        let (r, _) = mk_router(1);
        let (tx, _rx) = channel();
        r.submit(0, vec![0.0; 784], tx.clone()).unwrap();
        assert_eq!(r.submit(0, vec![0.0; 784], tx),
                   Err(SubmitError::Overloaded));
    }

    #[test]
    fn config_lookup_by_name() {
        let (r, _) = mk_router(10);
        assert_eq!(r.config_id("float32"), Some(0));
        assert_eq!(r.config_id("FI(6, 8)"), Some(1));
        assert_eq!(r.config_id("nope"), None);
    }

    #[test]
    fn ids_are_unique() {
        let (r, _) = mk_router(100);
        let (tx, _rx) = channel();
        let a = r.submit(0, vec![0.0; 784], tx.clone()).unwrap();
        let b = r.submit(0, vec![0.0; 784], tx).unwrap();
        assert_ne!(a, b);
    }
}
