//! Request router: the serving front door.  Maps a requested network
//! configuration (the paper's "domain choice") to its queue, assigns
//! request ids, stamps queueing deadlines, applies the overload policy
//! under the batcher's one queue lock, and counts every admission
//! outcome.
//!
//! The overload policies are the runtime half of the paper's
//! quality/cost dial: `Reject` refuses, `Shed` answers `Error(Shed)`
//! immediately, and `Degrade` re-routes the request to the nearest
//! *cheaper* served configuration — ordered by a static ladder built
//! from the `hw/` cost model's ranks — trading answer quality for
//! admission capacity instead of queueing past the deadline.

use super::batcher::{Admitted, BatchQueue, FailureKind, Outcome,
                     PushError, Request, Response};
use super::metrics::Metrics;
use crate::hw::datapath::{Datapath, ARRIA10, N_PE};
use crate::nn::spec::ReprMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What `Router::submit` does when the target queue is at its
/// high-water mark.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Refuse the submission with `SubmitError::Overloaded` (the
    /// pre-PR-7 behavior, now counted in `Metrics::rejected`).
    #[default]
    Reject,
    /// Accept, then immediately drop the newest request with an
    /// `Error(Shed)` reply: the client hears an answer for every
    /// accepted request and load is shed at the door, bounding queue
    /// delay for everything already admitted.
    Shed,
    /// Re-route to the nearest cheaper served config with queue room
    /// (static hardware-cost ladder); refuse only when every rung is
    /// full too.
    Degrade,
}

impl OverloadPolicy {
    pub fn parse(s: &str) -> Result<OverloadPolicy, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "reject" => Ok(OverloadPolicy::Reject),
            "shed" => Ok(OverloadPolicy::Shed),
            "degrade" => Ok(OverloadPolicy::Degrade),
            other => Err(format!(
                "unknown overload policy '{other}' \
                 (expected reject | shed | degrade)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OverloadPolicy::Reject => "reject",
            OverloadPolicy::Shed => "shed",
            OverloadPolicy::Degrade => "degrade",
        }
    }
}

/// Mean per-layer hardware cost of a configuration — the explorer's
/// scalar FPGA objective (ALM/DSP utilization + power, see
/// `hw::datapath::Datapath::explore_cost`), reused as the degrade
/// ladder's rank so "cheaper" means the same thing at admission time
/// as it does in design-space exploration.
fn config_cost(map: &ReprMap) -> f64 {
    let n = map.len().max(1) as f64;
    map.kinds()
        .iter()
        .map(|k| Datapath::synthesize(k, N_PE).explore_cost(&ARRIA10))
        .sum::<f64>()
        / n
}

/// One degrade ladder per served config: the indices of strictly
/// cheaper configs, nearest-cheaper first, so a degraded request loses
/// as little quality as the overload requires.
fn build_ladders(configs: &[ReprMap]) -> Vec<Vec<usize>> {
    let costs: Vec<f64> = configs.iter().map(config_cost).collect();
    costs
        .iter()
        .map(|&own| {
            let mut cheaper: Vec<usize> = (0..configs.len())
                .filter(|&j| costs[j] < own)
                .collect();
            // descending cost = closest quality first
            cheaper.sort_by(|&a, &b| {
                costs[b].partial_cmp(&costs[a]).unwrap()
            });
            cheaper
        })
        .collect()
}

pub struct Router {
    pub configs: Vec<ReprMap>,
    /// Flattened image length every request must match
    /// (`NetSpec::input_len` of the served model).
    input_len: usize,
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    policy: OverloadPolicy,
    /// Applied to submissions that carry no deadline of their own
    /// (`ServerOpts::deadline` / `[serve] deadline_ms`).
    default_deadline: Option<Duration>,
    /// `ladders[i]` = cheaper-config fallbacks for config `i`
    /// (empty unless the policy is `Degrade`).
    ladders: Vec<Vec<usize>>,
    next_id: AtomicU64,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    UnknownConfig,
    /// The image length does not match the served model's input
    /// shape (`h * w * c`).
    BadInput,
    /// Admission refused under load: the target queue is at capacity
    /// and the policy found no other placement.  Counted in
    /// `Metrics::rejected`.
    Overloaded,
    /// The server is draining for shutdown — not an overload signal
    /// (the pre-PR-7 router reported `Overloaded` here).
    ShuttingDown,
}

impl Router {
    pub fn new(configs: Vec<ReprMap>, input_len: usize,
               queue: Arc<BatchQueue>, metrics: Arc<Metrics>,
               policy: OverloadPolicy,
               default_deadline: Option<Duration>)
               -> Router {
        let ladders = match policy {
            OverloadPolicy::Degrade => build_ladders(&configs),
            _ => vec![Vec::new(); configs.len()],
        };
        Router {
            configs,
            input_len,
            queue,
            metrics,
            policy,
            default_deadline,
            ladders,
            next_id: AtomicU64::new(0),
        }
    }

    pub fn config_id(&self, name: &str) -> Option<usize> {
        self.configs.iter().position(|c| c.name() == name)
    }

    pub fn policy(&self) -> OverloadPolicy {
        self.policy
    }

    /// The degrade fallbacks for `config_id` (empty unless the policy
    /// is `Degrade`): strictly cheaper configs, nearest-cheaper first.
    pub fn ladder(&self, config_id: usize) -> &[usize] {
        &self.ladders[config_id]
    }

    /// Submit one image for classification under configuration
    /// `config_id`; the response arrives on `reply`.  `deadline` is a
    /// relative *queueing* deadline (falls back to the server-wide
    /// default): if the request is still queued when it elapses, the
    /// batcher answers `Error(Expired)` instead of serving it stale.
    ///
    /// Every admission outcome is accounted: accepted submissions tick
    /// `submitted` (plus `degraded`/`shed` for those placements — a
    /// shed request is answered right here and still returns `Ok`),
    /// and `Overloaded` refusals tick `rejected`.  Client errors
    /// (`UnknownConfig`/`BadInput`) and `ShuttingDown` touch nothing.
    pub fn submit(&self, config_id: usize, image: Vec<f32>,
                  deadline: Option<Duration>, reply: Sender<Response>)
                  -> Result<u64, SubmitError> {
        if config_id >= self.configs.len() {
            return Err(SubmitError::UnknownConfig);
        }
        if image.len() != self.input_len {
            return Err(SubmitError::BadInput);
        }
        // Admission time (policy + enqueue) under the `submit` stage.
        // This overlaps the start of `queue_wait` (queueing is clocked
        // from `submitted`), which is why per-request breakdowns and
        // the CI stage-sum check use the interior stages only.
        let _span = crate::telemetry::Span::enter(
            crate::telemetry::Stage::Submit,
        );
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let submitted = Instant::now();
        let deadline = deadline
            .or(self.default_deadline)
            .map(|d| submitted + d);
        let req = Request {
            id,
            image,
            config_id,
            submitted,
            deadline,
            reply,
        };
        match self.queue.admit(req, &self.ladders[config_id]) {
            Ok(Admitted::Queued) => {
                self.metrics.submitted.inc();
                Ok(id)
            }
            Ok(Admitted::Degraded(_)) => {
                self.metrics.submitted.inc();
                self.metrics.degraded.inc();
                Ok(id)
            }
            Err(PushError::Closed(_)) => Err(SubmitError::ShuttingDown),
            Err(PushError::Full(req)) => match self.policy {
                OverloadPolicy::Shed => {
                    // accepted-then-dropped: the client gets a typed
                    // answer now instead of an error or a stale result
                    self.metrics.submitted.inc();
                    self.metrics.shed.inc();
                    let _ = req.reply.send(Response {
                        id: req.id,
                        outcome: Outcome::Error(FailureKind::Shed),
                        latency: req.submitted.elapsed(),
                        breakdown: None,
                    });
                    Ok(id)
                }
                _ => {
                    self.metrics.rejected.inc();
                    Err(SubmitError::Overloaded)
                }
            },
        }
    }

    pub fn queue_depth(&self, config_id: usize) -> usize {
        self.queue.depth(config_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::arith::ArithKind;
    use std::sync::mpsc::channel;

    fn mk_router_with(cap: usize, policy: OverloadPolicy,
                      deadline: Option<Duration>)
                      -> (Router, Arc<BatchQueue>, Arc<Metrics>) {
        let configs = vec![
            ReprMap::uniform(ArithKind::Float32, 4),
            ReprMap::parse_n("FI(6,8)", 4).unwrap(),
        ];
        let metrics = Arc::new(Metrics::new());
        let q = Arc::new(BatchQueue::new(configs.len(), 8,
                                         Duration::from_millis(10),
                                         cap, metrics.clone()));
        let r = Router::new(configs, 784, q.clone(), metrics.clone(),
                            policy, deadline);
        (r, q, metrics)
    }

    fn mk_router(cap: usize) -> (Router, Arc<BatchQueue>) {
        let (r, q, _) =
            mk_router_with(cap, OverloadPolicy::Reject, None);
        (r, q)
    }

    #[test]
    fn routes_by_config() {
        let (r, q) = mk_router(100);
        let (tx, _rx) = channel();
        r.submit(1, vec![0.0; 784], None, tx.clone()).unwrap();
        r.submit(1, vec![0.0; 784], None, tx.clone()).unwrap();
        r.submit(0, vec![0.0; 784], None, tx).unwrap();
        assert_eq!(q.depth(0), 1);
        assert_eq!(q.depth(1), 2);
    }

    #[test]
    fn unknown_config_rejected() {
        let (r, _) = mk_router(100);
        let (tx, _rx) = channel();
        assert_eq!(r.submit(9, vec![0.0; 784], None, tx),
                   Err(SubmitError::UnknownConfig));
    }

    #[test]
    fn wrong_image_length_rejected() {
        let (r, q) = mk_router(100);
        let (tx, _rx) = channel();
        assert_eq!(r.submit(0, vec![0.0; 100], None, tx),
                   Err(SubmitError::BadInput));
        assert_eq!(q.depth(0), 0, "rejected request must not enqueue");
    }

    #[test]
    fn overload_rejected_and_counted() {
        let (r, _, m) = mk_router_with(1, OverloadPolicy::Reject, None);
        let (tx, _rx) = channel();
        r.submit(0, vec![0.0; 784], None, tx.clone()).unwrap();
        assert_eq!(r.submit(0, vec![0.0; 784], None, tx.clone()),
                   Err(SubmitError::Overloaded));
        assert_eq!(r.submit(0, vec![0.0; 784], None, tx),
                   Err(SubmitError::Overloaded));
        // rejected submissions are visible: one accepted, two refused
        assert_eq!(m.submitted.get(), 1);
        assert_eq!(m.rejected.get(), 2);
        // client errors are not admission refusals
        let (tx2, _rx2) = channel();
        let (r2, _, m2) =
            mk_router_with(1, OverloadPolicy::Reject, None);
        assert_eq!(r2.submit(9, vec![0.0; 784], None, tx2),
                   Err(SubmitError::UnknownConfig));
        assert_eq!(m2.rejected.get(), 0);
    }

    #[test]
    fn shutdown_is_not_overload() {
        let (r, q, m) = mk_router_with(1, OverloadPolicy::Reject, None);
        let (tx, _rx) = channel();
        q.close();
        assert_eq!(r.submit(0, vec![0.0; 784], None, tx),
                   Err(SubmitError::ShuttingDown));
        assert_eq!(m.rejected.get(), 0,
                   "drain refusals are not overload rejections");
    }

    #[test]
    fn shed_policy_answers_at_the_door() {
        let (r, _, m) = mk_router_with(1, OverloadPolicy::Shed, None);
        let (tx, rx) = channel();
        r.submit(0, vec![0.0; 784], None, tx.clone()).unwrap();
        // queue full → shed: submit still succeeds, the reply channel
        // carries the typed drop
        r.submit(0, vec![0.0; 784], None, tx).unwrap();
        let resp = rx.try_recv().expect("shed reply is immediate");
        assert_eq!(resp.outcome, Outcome::Error(FailureKind::Shed));
        assert_eq!(m.submitted.get(), 2);
        assert_eq!(m.shed.get(), 1);
        assert_eq!(m.rejected.get(), 0);
    }

    #[test]
    fn degrade_policy_reroutes_down_the_ladder() {
        let configs = vec![
            ReprMap::uniform(ArithKind::Float32, 4), // expensive
            ReprMap::parse_n("FI(6,8)", 4).unwrap(), // cheap
        ];
        let metrics = Arc::new(Metrics::new());
        let q = Arc::new(BatchQueue::new(2, 8,
                                         Duration::from_millis(10), 1,
                                         metrics.clone()));
        let r = Router::new(configs, 784, q.clone(), metrics.clone(),
                            OverloadPolicy::Degrade, None);
        let (tx, _rx) = channel();
        r.submit(0, vec![0.0; 784], None, tx.clone()).unwrap();
        // queue 0 full → lands on the cheaper config's queue
        r.submit(0, vec![0.0; 784], None, tx.clone()).unwrap();
        assert_eq!(q.depth(0), 1);
        assert_eq!(q.depth(1), 1);
        assert_eq!(metrics.degraded.get(), 1);
        // both rungs full → refuse, and count it
        assert_eq!(r.submit(0, vec![0.0; 784], None, tx),
                   Err(SubmitError::Overloaded));
        assert_eq!(metrics.rejected.get(), 1);
    }

    #[test]
    fn ladders_rank_by_hw_cost() {
        let configs = vec![
            ReprMap::uniform(ArithKind::Float32, 4),
            ReprMap::parse_n("FI(6,8)", 4).unwrap(),
            ReprMap::parse_n("binxnor", 4).unwrap(),
        ];
        let metrics = Arc::new(Metrics::new());
        let q = Arc::new(BatchQueue::new(3, 8,
                                         Duration::from_millis(10),
                                         100, metrics.clone()));
        let r = Router::new(configs, 784, q, metrics,
                            OverloadPolicy::Degrade, None);
        // float32 (DSP multipliers + FP adders) > FI(6,8) (narrow
        // fixed) > binary XNOR (LUT popcount) in the hw cost model —
        // the ladder walks nearest-cheaper first
        assert_eq!(r.ladder(0), &[1, 2]);
        assert_eq!(r.ladder(1), &[2]);
        assert_eq!(r.ladder(2), &[] as &[usize]);
    }

    #[test]
    fn reject_and_shed_have_empty_ladders() {
        let (r, _, _) = mk_router_with(4, OverloadPolicy::Reject, None);
        assert!(r.ladder(0).is_empty() && r.ladder(1).is_empty());
        assert_eq!(r.policy(), OverloadPolicy::Reject);
    }

    #[test]
    fn deadlines_default_and_override() {
        let (r, q, _) = mk_router_with(
            100,
            OverloadPolicy::Reject,
            Some(Duration::from_secs(3600)),
        );
        let (tx, _rx) = channel();
        r.submit(0, vec![0.0; 784], None, tx.clone()).unwrap();
        r.submit(0, vec![0.0; 784],
                 Some(Duration::from_secs(7200)), tx).unwrap();
        let (_, batch) = q.next_batch(&[true, true]).unwrap();
        // close enough: both deadlines are set, and the per-request
        // override lands later than the server-wide default
        let d0 = batch[0].deadline.expect("default applied");
        let d1 = batch[1].deadline.expect("override applied");
        assert!(d1 > d0);
    }

    #[test]
    fn no_deadline_by_default() {
        let (r, q) = mk_router(100);
        let (tx, _rx) = channel();
        r.submit(0, vec![0.0; 784], None, tx).unwrap();
        let (_, batch) = q.next_batch(&[true, true]).unwrap();
        assert_eq!(batch[0].deadline, None);
    }

    #[test]
    fn config_lookup_by_name() {
        let (r, _) = mk_router(10);
        assert_eq!(r.config_id("float32"), Some(0));
        assert_eq!(r.config_id("FI(6, 8)"), Some(1));
        assert_eq!(r.config_id("nope"), None);
    }

    #[test]
    fn overload_policy_parse_roundtrip() {
        for p in [OverloadPolicy::Reject, OverloadPolicy::Shed,
                  OverloadPolicy::Degrade] {
            assert_eq!(OverloadPolicy::parse(p.name()), Ok(p));
        }
        assert_eq!(OverloadPolicy::parse(" Shed "),
                   Ok(OverloadPolicy::Shed));
        assert!(OverloadPolicy::parse("drop").is_err());
        assert_eq!(OverloadPolicy::default(), OverloadPolicy::Reject);
    }

    #[test]
    fn ids_are_unique() {
        let (r, _) = mk_router(100);
        let (tx, _rx) = channel();
        let a = r.submit(0, vec![0.0; 784], None, tx.clone()).unwrap();
        let b = r.submit(0, vec![0.0; 784], None, tx).unwrap();
        assert_ne!(a, b);
    }
}
