//! Request router: the serving front door.  Maps a requested network
//! configuration (the paper's "domain choice") to its queue, assigns
//! request ids, applies admission control, and tracks submission metrics.

use super::batcher::{BatchQueue, Request, Response};
use super::metrics::Metrics;
use crate::nn::spec::ReprMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

pub struct Router {
    pub configs: Vec<ReprMap>,
    /// Flattened image length every request must match
    /// (`NetSpec::input_len` of the served model).
    input_len: usize,
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    UnknownConfig,
    /// The image length does not match the served model's input
    /// shape (`h * w * c`).
    BadInput,
    Overloaded,
}

impl Router {
    pub fn new(configs: Vec<ReprMap>, input_len: usize,
               queue: Arc<BatchQueue>, metrics: Arc<Metrics>)
               -> Router {
        Router {
            configs,
            input_len,
            queue,
            metrics,
            next_id: AtomicU64::new(0),
        }
    }

    pub fn config_id(&self, name: &str) -> Option<usize> {
        self.configs.iter().position(|c| c.name() == name)
    }

    /// Submit one image for classification under configuration
    /// `config_id`; the response arrives on `reply`.
    pub fn submit(&self, config_id: usize, image: Vec<f32>,
                  reply: Sender<Response>) -> Result<u64, SubmitError> {
        if config_id >= self.configs.len() {
            return Err(SubmitError::UnknownConfig);
        }
        if image.len() != self.input_len {
            return Err(SubmitError::BadInput);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            image,
            config_id,
            submitted: Instant::now(),
            reply,
        };
        match self.queue.push(req) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(id)
            }
            Err(_) => Err(SubmitError::Overloaded),
        }
    }

    pub fn queue_depth(&self, config_id: usize) -> usize {
        self.queue.depth(config_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::arith::ArithKind;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn mk_router(cap: usize) -> (Router, Arc<BatchQueue>) {
        let configs = vec![
            ReprMap::uniform(ArithKind::Float32, 4),
            ReprMap::parse_n("FI(6,8)", 4).unwrap(),
        ];
        let q = Arc::new(BatchQueue::new(configs.len(), 8,
                                         Duration::from_millis(10), cap));
        let r = Router::new(configs, 784, q.clone(),
                            Arc::new(Metrics::new()));
        (r, q)
    }

    #[test]
    fn routes_by_config() {
        let (r, q) = mk_router(100);
        let (tx, _rx) = channel();
        r.submit(1, vec![0.0; 784], tx.clone()).unwrap();
        r.submit(1, vec![0.0; 784], tx.clone()).unwrap();
        r.submit(0, vec![0.0; 784], tx).unwrap();
        assert_eq!(q.depth(0), 1);
        assert_eq!(q.depth(1), 2);
    }

    #[test]
    fn unknown_config_rejected() {
        let (r, _) = mk_router(100);
        let (tx, _rx) = channel();
        assert_eq!(r.submit(9, vec![0.0; 784], tx),
                   Err(SubmitError::UnknownConfig));
    }

    #[test]
    fn wrong_image_length_rejected() {
        let (r, q) = mk_router(100);
        let (tx, _rx) = channel();
        assert_eq!(r.submit(0, vec![0.0; 100], tx),
                   Err(SubmitError::BadInput));
        assert_eq!(q.depth(0), 0, "rejected request must not enqueue");
    }

    #[test]
    fn overload_rejected() {
        let (r, _) = mk_router(1);
        let (tx, _rx) = channel();
        r.submit(0, vec![0.0; 784], tx.clone()).unwrap();
        assert_eq!(r.submit(0, vec![0.0; 784], tx),
                   Err(SubmitError::Overloaded));
    }

    #[test]
    fn config_lookup_by_name() {
        let (r, _) = mk_router(10);
        assert_eq!(r.config_id("float32"), Some(0));
        assert_eq!(r.config_id("FI(6, 8)"), Some(1));
        assert_eq!(r.config_id("nope"), None);
    }

    #[test]
    fn ids_are_unique() {
        let (r, _) = mk_router(100);
        let (tx, _rx) = channel();
        let a = r.submit(0, vec![0.0; 784], tx.clone()).unwrap();
        let b = r.submit(0, vec![0.0; 784], tx).unwrap();
        assert_ne!(a, b);
    }
}
