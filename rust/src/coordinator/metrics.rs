//! Serving metrics: lock-free counters + a log-bucketed latency histogram
//! (p50/p99 without storing every sample).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-bucketed histogram: bucket i covers [2^i, 2^(i+1)) microseconds.
const BUCKETS: usize = 40;

#[derive(Debug)]
pub struct Metrics {
    /// Requests *accepted* by the router (queued, degraded, or shed —
    /// everything that will eventually get a [`Response`]).  At drain,
    /// `submitted == completed + shed + expired + backend_failures`.
    pub submitted: AtomicU64,
    /// Requests served to an `Ok` prediction (== latency-histogram
    /// entries); failures are counted in their own counters below and
    /// never here.
    pub completed: AtomicU64,
    /// Admissions refused outright (`SubmitError::Overloaded`): the
    /// `Reject` policy's refusals, or `Degrade` with every rung full.
    /// The only admission outcome that does *not* produce a Response.
    pub rejected: AtomicU64,
    /// Accepted, then dropped at the door by the `Shed` policy
    /// (answered `Error(Shed)`).
    pub shed: AtomicU64,
    /// Accepted onto a cheaper config's queue by the `Degrade`
    /// policy's cost ladder.
    pub degraded: AtomicU64,
    /// Removed from a queue unserved because the queueing deadline
    /// passed (answered `Error(Expired)`).
    pub expired: AtomicU64,
    /// Reached a worker whose backend forward failed (answered
    /// `Error(Backend)`; excluded from the latency histogram — the
    /// pre-PR-7 path recorded these as completions under a sentinel
    /// prediction).
    pub backend_failures: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    /// Weight panels resident in the shared plan cache (layers x
    /// resident configs) — a *gauge*, synced from
    /// `plan_cache::PlanCacheStats` by the engine workers; since PR 4
    /// the pool shares one cache, so this no longer accumulates per
    /// worker.
    pub panels_cached: AtomicU64,
    /// Bytes resident in those prepacked weight panels (gauge).
    pub panel_bytes: AtomicU64,
    /// Plan-cache gets served from a resident prepared net (gauge,
    /// mirrored from the cache's own counters).
    pub plan_hits: AtomicU64,
    /// Plan-cache gets that prepared a network (== `Model::prepare`
    /// runs across the whole worker pool; gauge).
    pub plan_misses: AtomicU64,
    /// Prepared nets dropped by the plan cache's byte cap (gauge).
    pub plan_evictions: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            backend_failures: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_items: AtomicU64::new(0),
            panels_cached: AtomicU64::new(0),
            panel_bytes: AtomicU64::new(0),
            plan_hits: AtomicU64::new(0),
            plan_misses: AtomicU64::new(0),
            plan_evictions: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Publish the plan cache's current residency (`count` panel
    /// layers totalling `bytes`).  Store semantics — every engine
    /// worker syncs the same shared-cache snapshot, so the gauges are
    /// idempotent across the pool (worker-count invariant), unlike the
    /// pre-PR-4 per-worker accumulation.
    pub fn set_panels(&self, count: u64, bytes: u64) {
        self.panels_cached.store(count, Ordering::Relaxed);
        self.panel_bytes.store(bytes, Ordering::Relaxed);
    }

    /// Publish the plan cache's hit/miss/eviction counters (same
    /// store-a-snapshot discipline as [`Metrics::set_panels`]).
    pub fn set_plan_cache(&self, hits: u64, misses: u64,
                          evictions: u64) {
        self.plan_hits.store(hits, Ordering::Relaxed);
        self.plan_misses.store(misses, Ordering::Relaxed);
        self.plan_evictions.store(evictions, Ordering::Relaxed);
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate percentile (upper bound of the bucket containing it).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.completed.load(Ordering::Relaxed);
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self, wall: Duration) -> String {
        let n = self.completed.load(Ordering::Relaxed);
        format!(
            "completed {} reqs in {:.2}s  ({:.1} req/s)\n\
             latency: mean {:.2} ms  p50 <= {:.2} ms  \
             p99 <= {:.2} ms  p999 <= {:.2} ms\n\
             admission: {} rejected, {} shed, {} degraded, \
             {} expired, {} backend failures\n\
             batching: {} batches, mean size {:.1}\n\
             panel cache: {} weight panels, {:.2} MiB resident \
             (shared; {} hits / {} prepares / {} evictions)",
            n,
            wall.as_secs_f64(),
            n as f64 / wall.as_secs_f64().max(1e-9),
            self.mean_latency_us() / 1e3,
            self.percentile_us(50.0) as f64 / 1e3,
            self.percentile_us(99.0) as f64 / 1e3,
            self.percentile_us(99.9) as f64 / 1e3,
            self.rejected.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.degraded.load(Ordering::Relaxed),
            self.expired.load(Ordering::Relaxed),
            self.backend_failures.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.panels_cached.load(Ordering::Relaxed),
            self.panel_bytes.load(Ordering::Relaxed) as f64
                / (1024.0 * 1024.0),
            self.plan_hits.load(Ordering::Relaxed),
            self.plan_misses.load(Ordering::Relaxed),
            self.plan_evictions.load(Ordering::Relaxed)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_bracket_samples() {
        let m = Metrics::new();
        for us in [100u64, 200, 400, 800, 100_000] {
            m.record_latency(Duration::from_micros(us));
        }
        let p50 = m.percentile_us(50.0);
        assert!((128..=512).contains(&p50), "p50 {p50}");
        let p99 = m.percentile_us(99.0);
        assert!(p99 >= 100_000, "p99 {p99}");
        assert!(m.mean_latency_us() > 0.0);
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.percentile_us(99.0), 0);
        assert_eq!(m.percentile_us(99.9), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.panels_cached.load(Ordering::Relaxed), 0);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 0);
        assert_eq!(m.backend_failures.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn p999_reads_the_tail_bucket() {
        let m = Metrics::new();
        // 500 fast requests and one 2-second straggler: p99 stays in
        // the fast bucket (rank 496 of 501), p999 must surface the
        // straggler's bucket (rank ceil(0.999 * 501) = 501)
        for _ in 0..500 {
            m.record_latency(Duration::from_micros(100));
        }
        m.record_latency(Duration::from_secs(2));
        assert!(m.percentile_us(99.0) <= 256,
                "p99 {}", m.percentile_us(99.0));
        assert!(m.percentile_us(99.9) >= 2_000_000,
                "p999 {}", m.percentile_us(99.9));
    }

    #[test]
    fn admission_counters_and_summary() {
        let m = Metrics::new();
        m.rejected.fetch_add(3, Ordering::Relaxed);
        m.shed.fetch_add(2, Ordering::Relaxed);
        m.degraded.fetch_add(5, Ordering::Relaxed);
        m.expired.fetch_add(1, Ordering::Relaxed);
        m.backend_failures.fetch_add(4, Ordering::Relaxed);
        let s = m.summary(Duration::from_secs(1));
        assert!(s.contains("3 rejected, 2 shed, 5 degraded, \
                            1 expired, 4 backend failures"), "{s}");
        assert!(s.contains("p999 <="), "{s}");
    }

    #[test]
    fn panel_gauges_take_the_latest_snapshot() {
        let m = Metrics::new();
        // two workers syncing the same shared cache: gauges converge
        // to the snapshot, they do not double-count the pool
        m.set_panels(8, 14_000_000);
        m.set_panels(8, 14_000_000);
        assert_eq!(m.panels_cached.load(Ordering::Relaxed), 8);
        assert_eq!(m.panel_bytes.load(Ordering::Relaxed), 14_000_000);
        m.set_plan_cache(10, 2, 1);
        m.set_plan_cache(11, 2, 1);
        assert_eq!(m.plan_hits.load(Ordering::Relaxed), 11);
        assert_eq!(m.plan_misses.load(Ordering::Relaxed), 2);
        assert_eq!(m.plan_evictions.load(Ordering::Relaxed), 1);
        let s = m.summary(Duration::from_secs(1));
        assert!(s.contains("8 weight panels"), "{s}");
        assert!(s.contains("11 hits / 2 prepares / 1 evictions"), "{s}");
    }
}
