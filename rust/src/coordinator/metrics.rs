//! Serving metrics: a private telemetry registry per server.
//!
//! Since PR 10 this is a thin, named view over [`crate::telemetry`]:
//! every counter/gauge/histogram here is a registry handle, so the
//! `serve` loop, the benches, and CI's sanity gates all read the same
//! series through [`Metrics::snapshot`] — no private percentile math.
//!
//! Each `Metrics` owns its *own* [`Registry`] (not [`telemetry::
//! global()`](crate::telemetry::global)): tests routinely run several
//! `Server`s in one process, and their admission counts must not
//! cross-pollute.  Genuinely process-wide series (GEMM pack counts,
//! `stage.*` span histograms) live in the global registry instead.
//!
//! Plan-cache mirrors: the shared cache's hit/miss/evict counters are
//! mirrored with [`Counter::store_max`] (monotone, so a stale store
//! is a no-op), and its residency gauges with sequence-tagged
//! [`Gauge::set_at`] fed by `PlanCache::gauge_snapshot()` — the
//! PR-4-era racing plain stores could publish a stale snapshot over a
//! fresher one until the next batch; now the registry rejects stale
//! sequences outright.

use crate::telemetry::{
    Counter, Gauge, Histogram, Registry, TelemetrySnapshot,
};
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug)]
pub struct Metrics {
    registry: Registry,
    /// Requests *accepted* by the router (queued, degraded, or shed —
    /// everything that will eventually get a [`Response`]).  At drain,
    /// `submitted == completed + shed + expired + backend_failures`.
    ///
    /// [`Response`]: super::batcher::Response
    pub submitted: Arc<Counter>,
    /// Requests served to an `Ok` prediction (== latency-histogram
    /// entries); failures are counted in their own counters below and
    /// never here.
    pub completed: Arc<Counter>,
    /// Admissions refused outright (`SubmitError::Overloaded`): the
    /// `Reject` policy's refusals, or `Degrade` with every rung full.
    /// The only admission outcome that does *not* produce a Response.
    pub rejected: Arc<Counter>,
    /// Accepted, then dropped at the door by the `Shed` policy
    /// (answered `Error(Shed)`).
    pub shed: Arc<Counter>,
    /// Accepted onto a cheaper config's queue by the `Degrade`
    /// policy's cost ladder.
    pub degraded: Arc<Counter>,
    /// Removed from a queue unserved because the queueing deadline
    /// passed (answered `Error(Expired)`).
    pub expired: Arc<Counter>,
    /// Reached a worker whose backend forward failed (answered
    /// `Error(Backend)`; excluded from the latency histogram).
    pub backend_failures: Arc<Counter>,
    pub batches: Arc<Counter>,
    pub batched_items: Arc<Counter>,
    /// Weight panels resident in the shared plan cache (layers x
    /// resident configs) — a sequence-tagged gauge synced from
    /// `PlanCache::gauge_snapshot()` by the engine workers.
    pub panels_cached: Arc<Gauge>,
    /// Bytes resident in those prepacked weight panels (gauge).
    pub panel_bytes: Arc<Gauge>,
    /// Plan-cache gets served from a resident prepared net (monotone
    /// mirror of the cache's own counter).
    pub plan_hits: Arc<Counter>,
    /// Plan-cache gets that prepared a network (== `Model::prepare`
    /// runs across the whole worker pool; monotone mirror).
    pub plan_misses: Arc<Counter>,
    /// Prepared nets dropped by the plan cache's byte cap (monotone
    /// mirror).
    pub plan_evictions: Arc<Counter>,
    /// End-to-end `Ok` latency in microseconds (submit -> response).
    pub latency_us: Arc<Histogram>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        let registry = Registry::new();
        // handle registrations run before `registry` moves into the
        // struct (field-init order is source order; it is last)
        Metrics {
            submitted: registry.counter("serving.submitted"),
            completed: registry.counter("serving.completed"),
            rejected: registry.counter("serving.rejected"),
            shed: registry.counter("serving.shed"),
            degraded: registry.counter("serving.degraded"),
            expired: registry.counter("serving.expired"),
            backend_failures: registry.counter("serving.backend_failures"),
            batches: registry.counter("serving.batches"),
            batched_items: registry.counter("serving.batched_items"),
            panels_cached: registry.gauge("plan_cache.resident_panels"),
            panel_bytes: registry.gauge("plan_cache.resident_bytes"),
            plan_hits: registry.counter("plan_cache.hits"),
            plan_misses: registry.counter("plan_cache.misses"),
            plan_evictions: registry.counter("plan_cache.evictions"),
            latency_us: registry.histogram("serving.latency_us"),
            registry,
        }
    }

    /// The registry behind the named handles (for snapshot-side
    /// lookups; updates should go through the typed fields).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Export every serving series (deterministically name-ordered).
    pub fn snapshot(&self) -> TelemetrySnapshot {
        self.registry.snapshot()
    }

    /// Publish a plan-cache residency snapshot taken at sequence
    /// `seq` (`count` panel layers totalling `bytes`).  Applies only
    /// if `seq` is newer than the currently published snapshot, so
    /// racing engine workers can never regress the gauges — the fix
    /// for PR 4's "stale until the next batch" store race.
    pub fn set_panels_at(&self, seq: u64, count: u64, bytes: u64) {
        self.panels_cached.set_at(seq, count);
        self.panel_bytes.set_at(seq, bytes);
    }

    /// Publish the plan cache's hit/miss/eviction counters.  These
    /// are monotone at the source, so the mirror uses `store_max`:
    /// stale stores are no-ops instead of regressions.
    pub fn set_plan_cache(&self, hits: u64, misses: u64,
                          evictions: u64) {
        self.plan_hits.store_max(hits);
        self.plan_misses.store_max(misses);
        self.plan_evictions.store_max(evictions);
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        self.latency_us.record(us);
        self.completed.inc();
    }

    pub fn record_batch(&self, n: usize) {
        self.batches.inc();
        self.batched_items.add(n as u64);
    }

    pub fn mean_latency_us(&self) -> f64 {
        self.latency_us.mean()
    }

    /// Latency percentile in microseconds — the shared histogram's
    /// read-out: in `[true, 2*true)`, clamped by the exact max.
    pub fn percentile_us(&self, p: f64) -> u64 {
        self.latency_us.percentile(p)
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            return 0.0;
        }
        self.batched_items.get() as f64 / b as f64
    }

    pub fn summary(&self, wall: Duration) -> String {
        let n = self.completed.get();
        format!(
            "completed {} reqs in {:.2}s  ({:.1} req/s)\n\
             latency: mean {:.2} ms  p50 <= {:.2} ms  \
             p99 <= {:.2} ms  p999 <= {:.2} ms\n\
             admission: {} rejected, {} shed, {} degraded, \
             {} expired, {} backend failures\n\
             batching: {} batches, mean size {:.1}\n\
             panel cache: {} weight panels, {:.2} MiB resident \
             (shared; {} hits / {} prepares / {} evictions)",
            n,
            wall.as_secs_f64(),
            n as f64 / wall.as_secs_f64().max(1e-9),
            self.mean_latency_us() / 1e3,
            self.percentile_us(50.0) as f64 / 1e3,
            self.percentile_us(99.0) as f64 / 1e3,
            self.percentile_us(99.9) as f64 / 1e3,
            self.rejected.get(),
            self.shed.get(),
            self.degraded.get(),
            self.expired.get(),
            self.backend_failures.get(),
            self.batches.get(),
            self.mean_batch_size(),
            self.panels_cached.get(),
            self.panel_bytes.get() as f64 / (1024.0 * 1024.0),
            self.plan_hits.get(),
            self.plan_misses.get(),
            self.plan_evictions.get()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_bracket_samples() {
        let m = Metrics::new();
        for us in [100u64, 200, 400, 800, 100_000] {
            m.record_latency(Duration::from_micros(us));
        }
        let p50 = m.percentile_us(50.0);
        assert!((128..=512).contains(&p50), "p50 {p50}");
        let p99 = m.percentile_us(99.0);
        assert!(p99 >= 100_000, "p99 {p99}");
        assert!(m.mean_latency_us() > 0.0);
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.percentile_us(99.0), 0);
        assert_eq!(m.percentile_us(99.9), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.panels_cached.get(), 0);
        assert_eq!(m.rejected.get(), 0);
        assert_eq!(m.backend_failures.get(), 0);
    }

    #[test]
    fn p999_reads_the_tail_bucket() {
        let m = Metrics::new();
        // 500 fast requests and one 2-second straggler: p99 stays in
        // the fast bucket (rank 496 of 501), p999 must surface the
        // straggler's bucket (rank ceil(0.999 * 501) = 501)
        for _ in 0..500 {
            m.record_latency(Duration::from_micros(100));
        }
        m.record_latency(Duration::from_secs(2));
        assert!(m.percentile_us(99.0) <= 256,
                "p99 {}", m.percentile_us(99.0));
        // the max clamp makes the tail read-out exact
        assert_eq!(m.percentile_us(99.9), 2_000_000);
    }

    #[test]
    fn admission_counters_and_summary() {
        let m = Metrics::new();
        m.rejected.add(3);
        m.shed.add(2);
        m.degraded.add(5);
        m.expired.inc();
        m.backend_failures.add(4);
        let s = m.summary(Duration::from_secs(1));
        assert!(s.contains("3 rejected, 2 shed, 5 degraded, \
                            1 expired, 4 backend failures"), "{s}");
        assert!(s.contains("p999 <="), "{s}");
    }

    #[test]
    fn panel_gauges_reject_stale_snapshots() {
        let m = Metrics::new();
        // two workers publish shared-cache snapshots out of order:
        // the later sequence wins regardless of arrival order
        m.set_panels_at(5, 8, 14_000_000);
        m.set_panels_at(3, 4, 7_000_000); // stale — must not apply
        assert_eq!(m.panels_cached.get(), 8);
        assert_eq!(m.panel_bytes.get(), 14_000_000);
        m.set_panels_at(6, 10, 20_000_000);
        assert_eq!(m.panels_cached.get(), 10);
        // monotone mirrors: a lagging worker's store is a no-op
        m.set_plan_cache(11, 2, 1);
        m.set_plan_cache(10, 2, 1);
        assert_eq!(m.plan_hits.get(), 11);
        assert_eq!(m.plan_misses.get(), 2);
        assert_eq!(m.plan_evictions.get(), 1);
        let s = m.summary(Duration::from_secs(1));
        assert!(!s.contains("8 weight panels"), "{s}");
        assert!(s.contains("10 weight panels"), "{s}");
        assert!(s.contains("11 hits / 2 prepares / 1 evictions"), "{s}");
    }

    #[test]
    fn snapshot_exports_the_named_series() {
        let m = Metrics::new();
        m.submitted.add(7);
        m.record_latency(Duration::from_micros(300));
        let snap = m.snapshot();
        use crate::telemetry::MetricValue;
        assert_eq!(snap.get("serving.submitted"),
                   Some(&MetricValue::Counter(7)));
        match snap.get("serving.latency_us") {
            Some(MetricValue::Histogram(h)) => assert_eq!(h.count, 1),
            other => panic!("unexpected {other:?}"),
        }
        // two servers in one process do not share registries
        let other = Metrics::new();
        assert_eq!(other.submitted.get(), 0);
    }
}
