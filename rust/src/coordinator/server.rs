//! The inference server: router → dynamic batcher → worker pool.
//!
//! Two worker kinds, matching the two evaluation backends:
//!  * one PJRT worker (the XLA client is not Send, so it is constructed
//!    inside its thread) serving every exact-arithmetic configuration;
//!  * N engine workers running the bit-accurate Rust engine, serving the
//!    approximate-multiplier configurations (and acting as overflow for
//!    everything when PJRT is unavailable).
//!
//! Engine workers do **not** own prepared networks: they all serve from
//! one shared [`PlanCache`], so each configuration is conditioned and
//! prepacked exactly once per server no matter how many workers run —
//! panel residency and prepare time scale with configs, not
//! `workers x configs` (`rust/tests/plan_cache.rs` pins the
//! invariance, `benches/serving_throughput.rs` measures it).

use super::batcher::{BatchQueue, FailureKind, Outcome, Request,
                     Response};
use super::metrics::Metrics;
use super::plan_cache::PlanCache;
use super::router::{OverloadPolicy, Router};
use crate::nn::network::Model;
use crate::nn::spec::{NetSpec, ReprMap};
use crate::nn::tensor::Tensor;
use crate::runtime::{execution_plan, ArtifactDir, ModelRunner};
use crate::telemetry::{self, Stage, StageBreakdown};
use anyhow::{bail, ensure, Context, Result};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct ServerOpts {
    /// One entry per served configuration; every entry's arity must
    /// match the model's spec (checked at startup).
    pub configs: Vec<ReprMap>,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
    pub engine_workers: usize,
    /// threads each engine worker hands to its GEMM calls
    pub engine_gemm_threads: usize,
    /// byte cap on the shared plan cache's resident prepacked panels
    pub plan_cache_bytes: usize,
    pub use_pjrt: bool,
    /// Admission behavior when a config's queue is past
    /// `queue_capacity` (see [`OverloadPolicy`]).
    pub overload: OverloadPolicy,
    /// Server-wide default *queueing* deadline for submissions that do
    /// not carry their own (`[serve] deadline_ms`); `None` = requests
    /// wait as long as service takes.
    pub deadline: Option<Duration>,
    /// Test hook (hermetic backend-failure coverage): every engine
    /// forward takes the backend-failure reply path instead of running
    /// the model — exactly what a failed PJRT forward does, but
    /// reachable without a PJRT runtime.  Never set outside tests.
    pub inject_backend_failures: bool,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts {
            // the paper preset's arity; servers over other specs set
            // their own configs (parsed via `ReprMap::parse_for`)
            configs: vec![ReprMap::uniform(
                crate::approx::arith::ArithKind::Float32,
                NetSpec::paper_dcnn().len(),
            )],
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_capacity: 4_096,
            engine_workers: 2,
            engine_gemm_threads: 1,
            plan_cache_bytes:
                super::plan_cache::DEFAULT_CAPACITY_BYTES,
            // a stub build can never start the PJRT worker, so do not
            // plan for one unless the feature is compiled in
            use_pjrt: cfg!(feature = "pjrt"),
            overload: OverloadPolicy::Reject,
            deadline: None,
            inject_backend_failures: false,
        }
    }
}

pub struct Server {
    pub router: Arc<Router>,
    pub metrics: Arc<Metrics>,
    /// The shared prepared-net cache every engine worker serves from
    /// (public so tests/benches can read its stats).
    pub plan_cache: Arc<PlanCache>,
    queue: Arc<BatchQueue>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start over the artifact directory's trained weights — the
    /// production entry point for the paper topology (needs `make
    /// artifacts`; the artifacts implement `NetSpec::paper_dcnn`).
    pub fn start(opts: ServerOpts) -> Result<Server> {
        let art = ArtifactDir::discover()?;
        let model = Arc::new(
            Model::load(NetSpec::paper_dcnn(), &art.weights_path())
                .context("loading weights")?,
        );
        Server::start_with_model(opts, model, Some(art))
    }

    /// Start over an in-memory model of *any* topology — the hermetic
    /// entry point for benches and tests that have no artifact
    /// directory (`rust/tests/netspec_topology.rs` serves a 5-layer
    /// MLP and a 2-conv net through here).  With `art: None` the PJRT
    /// worker cannot start (it reads AOT artifacts), so every
    /// configuration routes to the engine pool.
    pub fn start_with_model(opts: ServerOpts, model: Arc<Model>,
                            art: Option<ArtifactDir>)
                            -> Result<Server> {
        ensure!(
            !opts.configs.is_empty(),
            "ServerOpts::configs is empty: a server with no served \
             configurations would reject every submit while its \
             workers block forever on an all-empty mask; configure \
             at least one ReprMap"
        );
        for c in &opts.configs {
            ensure!(
                c.len() == model.spec().len(),
                "config '{}' has {} layers for the {}-layer spec '{}'",
                c.name(),
                c.len(),
                model.spec().len(),
                model.spec()
            );
        }
        let in_shape = model.spec().input_shape();
        let metrics = Arc::new(Metrics::new());
        let queue = Arc::new(BatchQueue::new(
            opts.configs.len(),
            opts.max_batch,
            opts.max_wait,
            opts.queue_capacity,
            metrics.clone(),
        ));
        let router = Arc::new(Router::new(
            opts.configs.clone(),
            model.spec().input_len(),
            queue.clone(),
            metrics.clone(),
            opts.overload,
            opts.deadline,
        ));
        let plan_cache = Arc::new(PlanCache::with_capacity(
            model.clone(),
            opts.plan_cache_bytes,
        ));

        // Without the `pjrt` feature (or without artifacts) the
        // ModelRunner can never start, and the AOT artifacts only
        // implement the paper DCNN topology — so in all three cases
        // route everything to the engine workers instead of assigning
        // configs to a worker that dies at startup.
        let pjrt_available = cfg!(feature = "pjrt")
            && opts.use_pjrt
            && art.is_some()
            && model.spec().is_paper_dcnn();
        let pjrt_mask: Vec<bool> = opts
            .configs
            .iter()
            .map(|c| pjrt_available && execution_plan(c).is_pjrt())
            .collect();
        // engine workers cover what PJRT does not
        let engine_mask: Vec<bool> =
            pjrt_mask.iter().map(|p| !p).collect();

        let mut workers = Vec::new();
        if pjrt_mask.iter().any(|&b| b) {
            let art = art.expect("pjrt mask implies artifacts");
            let q = queue.clone();
            let m = metrics.clone();
            let cfgs = opts.configs.clone();
            let cache = plan_cache.clone();
            let threads = opts.engine_gemm_threads;
            workers.push(std::thread::spawn(move || {
                pjrt_worker(art, cache, cfgs, q, m, pjrt_mask, threads,
                            in_shape);
            }));
        }
        if engine_mask.iter().any(|&b| b) || !opts.use_pjrt {
            for _ in 0..opts.engine_workers.max(1) {
                let q = queue.clone();
                let m = metrics.clone();
                let cache = plan_cache.clone();
                let cfgs = opts.configs.clone();
                let mask = engine_mask.clone();
                let threads = opts.engine_gemm_threads;
                let inject = opts.inject_backend_failures;
                workers.push(std::thread::spawn(move || {
                    engine_worker(cache, cfgs, q, m, mask, threads,
                                  in_shape, inject);
                }));
            }
        }
        Ok(Server { router, metrics, plan_cache, queue, workers })
    }

    /// Per-config queue depths right now (admission/observability
    /// snapshot, config order = `ServerOpts::configs`).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.queue.snapshot().depths
    }

    /// Close the queue, drain in-flight work, join workers.  A worker
    /// that panicked surfaces here as an error (the first panic wins)
    /// instead of being swallowed — CI's serving tests fail on a
    /// crashed worker rather than on a silently shorter reply stream.
    pub fn shutdown(self) -> Result<()> {
        self.queue.close();
        let mut first_panic: Option<String> = None;
        for w in self.workers {
            if let Err(payload) = w.join() {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| {
                        payload.downcast_ref::<String>().cloned()
                    })
                    .unwrap_or_else(|| {
                        "non-string panic payload".to_string()
                    });
                if first_panic.is_none() {
                    first_panic = Some(msg);
                }
            }
        }
        match first_panic {
            Some(msg) => bail!("serving worker panicked: {msg}"),
            None => Ok(()),
        }
    }
}

/// Reply `Ok(pred)` to a whole batch, stamping each request's
/// end-to-end latency.  `trace` is `Some` only when `LOP_TRACE` is on:
/// per-request queue-wait microseconds plus the batch-level stage
/// costs measured by the worker.  Each response gets its own
/// breakdown (queue wait differs per request); the batch-level tail
/// is copied from the shared slice.
fn respond(batch: Vec<Request>, preds: &[usize], metrics: &Metrics,
           trace: Option<(Vec<u64>, Vec<(&'static str, u64)>)>) {
    let _span = telemetry::Span::enter(Stage::Reply);
    let now = Instant::now();
    for (i, (req, &pred)) in batch.into_iter().zip(preds).enumerate() {
        let latency = now.duration_since(req.submitted);
        metrics.record_latency(latency);
        let breakdown = trace.as_ref().map(|(qw, shared)| {
            let mut stages = Vec::with_capacity(shared.len() + 1);
            stages.push((Stage::QueueWait.name(), qw[i]));
            stages.extend_from_slice(shared);
            Arc::new(StageBreakdown { stages })
        });
        let _ = req.reply.send(Response {
            id: req.id,
            outcome: Outcome::Ok(pred),
            latency,
            breakdown,
        });
    }
}

/// Reply `Error(Backend)` to a whole batch: counted in
/// `backend_failures` and kept out of the latency histogram — a failed
/// forward is not a completion.  (The pre-PR-7 path replied with the
/// sentinel `pred = usize::MAX` through [`respond`], recording the
/// failure as a served request and leaving the client unable to tell
/// a crashed backend from a class index.)
fn respond_failure(batch: Vec<Request>, metrics: &Metrics) {
    let now = Instant::now();
    for req in batch {
        metrics.backend_failures.inc();
        let _ = req.reply.send(Response {
            id: req.id,
            outcome: Outcome::Error(FailureKind::Backend),
            latency: now.duration_since(req.submitted),
            breakdown: None,
        });
    }
}

/// Stack a batch's flattened images into `[b, h, w, c]` per the
/// model spec's input shape (the router already validated each
/// image's length).
fn batch_tensor(batch: &[Request], in_shape: [usize; 3]) -> Tensor {
    let [h, w, c] = in_shape;
    let mut data = Vec::with_capacity(batch.len() * h * w * c);
    for r in batch {
        data.extend_from_slice(&r.image);
    }
    Tensor::new(vec![batch.len(), h, w, c], data)
}

fn pjrt_worker(art: ArtifactDir, cache: Arc<PlanCache>,
               configs: Vec<ReprMap>, queue: Arc<BatchQueue>,
               metrics: Arc<Metrics>, mask: Vec<bool>,
               engine_threads: usize, in_shape: [usize; 3]) {
    let mut runner = match ModelRunner::new(art) {
        Ok(r) => r,
        Err(e) => {
            // no `log` crate in the offline set: report on stderr.
            // Become an engine worker over the same mask so the configs
            // assigned to this worker are still served (the stub build
            // never reaches here — its configs route to engine workers
            // up front — but a runtime PJRT init failure does); it
            // shares the same plan cache as the regular engine pool.
            eprintln!("pjrt worker failed to start: {e:#}; \
                       serving its configs on the engine backend");
            engine_worker(cache, configs, queue, metrics, mask,
                          engine_threads, in_shape, false);
            return;
        }
    };
    while let Some((ci, batch)) = queue.next_batch(&mask) {
        let x = batch_tensor(&batch, in_shape);
        match runner.forward(&configs[ci], &x) {
            Ok(logits) => {
                metrics.record_batch(batch.len());
                // No per-stage breakdown on the PJRT path: the XLA
                // executable is opaque, so there is nothing finer
                // than the end-to-end latency to report.
                respond(batch, &logits.argmax_rows(), &metrics, None);
            }
            Err(e) => {
                eprintln!("pjrt forward failed: {e:#}");
                respond_failure(batch, &metrics);
            }
        }
    }
}

fn engine_worker(cache: Arc<PlanCache>, configs: Vec<ReprMap>,
                 queue: Arc<BatchQueue>, metrics: Arc<Metrics>,
                 mask: Vec<bool>, threads: usize,
                 in_shape: [usize; 3], inject_failures: bool) {
    while let Some((ci, batch)) = queue.next_batch(&mask) {
        if inject_failures {
            // ServerOpts::inject_backend_failures — drive the exact
            // failure path a crashed PJRT forward takes, end to end
            // (batcher → worker → respond_failure → metrics → client)
            respond_failure(batch, &metrics);
            continue;
        }
        let traced = telemetry::trace_enabled();
        // Per-request queue wait is recorded before the `base`
        // snapshot below, so the batch-level delta attributes only
        // the shared stages (the queue-wait slot of the delta is
        // zero by construction).
        let queue_waits: Option<Vec<u64>> = if traced {
            let now = Instant::now();
            Some(batch.iter().map(|r| {
                let us =
                    now.duration_since(r.submitted).as_micros() as u64;
                telemetry::record_stage(Stage::QueueWait, us);
                us
            }).collect())
        } else {
            None
        };
        let base = telemetry::local_stage_sums();
        // One shared Arc<PreparedNet> per config across the whole
        // pool: the first batch anywhere prepares it (single-flight),
        // every other worker's batches ride the same panels.  The Arc
        // is held only for the batch, so an eviction between batches
        // frees the memory as soon as in-flight work drains.
        let net = {
            let _span = telemetry::Span::enter(Stage::PlanLookup);
            cache.get(&configs[ci])
        };
        // Mirror the cache state every batch.  The monotone counters
        // go through `store_max`, so a stale racing store is a no-op
        // rather than a backwards jump; the residency gauges ride a
        // sequence-tagged snapshot taken under the cache lock, so a
        // slow worker's stale (panels, bytes) pair can never
        // overwrite a fresher one (the PR-4 scheme let the last
        // writer win and stayed wrong until the next batch).
        let (h, m, e) = cache.counters();
        metrics.set_plan_cache(h, m, e);
        let (seq, panels, bytes) = cache.gauge_snapshot();
        metrics.set_panels_at(seq, panels, bytes);
        let x = {
            let _span = telemetry::Span::enter(Stage::BatchAssemble);
            batch_tensor(&batch, in_shape)
        };
        let preds = net.predict(&x, threads);
        metrics.record_batch(batch.len());
        let trace = queue_waits.map(|qw| {
            // Batch-level stage costs: this thread's span-recorded
            // microseconds since `base`.  Exact when the GEMM driver
            // runs on this thread (engine_gemm_threads = 1, the
            // default); a parallel driver's worker-thread time lands
            // in the global stage histograms but not in this
            // per-batch breakdown.
            let after = telemetry::local_stage_sums();
            let shared: Vec<(&'static str, u64)> = [
                Stage::BatchAssemble,
                Stage::PlanLookup,
                Stage::GemmPack,
                Stage::GemmKernel,
                Stage::GemmEpilogue,
            ]
            .iter()
            .map(|&s| {
                (s.name(), after[s as usize] - base[s as usize])
            })
            .collect();
            (qw, shared)
        });
        respond(batch, &preds, &metrics, trace);
    }
}

#[cfg(test)]
mod tests {
    // Server integration tests live in rust/tests/serving.rs (they
    // need artifacts), rust/tests/plan_cache.rs (hermetic, over a
    // synthetic paper-spec Model via `Server::start_with_model`) and
    // rust/tests/netspec_topology.rs (hermetic, non-paper specs);
    // unit coverage for the queue/router/metrics/plan-cache pieces is
    // in their own modules.
}
