//! The inference server: router → dynamic batcher → worker pool.
//!
//! Two worker kinds, matching the two evaluation backends:
//!  * one PJRT worker (the XLA client is not Send, so it is constructed
//!    inside its thread) serving every exact-arithmetic configuration;
//!  * N engine workers running the bit-accurate Rust engine, serving the
//!    approximate-multiplier configurations (and acting as overflow for
//!    everything when PJRT is unavailable).

use super::batcher::{BatchQueue, Request, Response};
use super::metrics::Metrics;
use super::router::Router;
use crate::nn::network::{Dcnn, NetConfig};
use crate::nn::tensor::Tensor;
use crate::runtime::{ArtifactDir, ModelRunner, Variant};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct ServerOpts {
    pub configs: Vec<NetConfig>,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
    pub engine_workers: usize,
    /// threads each engine worker hands to its GEMM calls
    pub engine_gemm_threads: usize,
    pub use_pjrt: bool,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts {
            configs: vec![NetConfig::uniform(
                crate::approx::arith::ArithKind::Float32,
            )],
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_capacity: 4_096,
            engine_workers: 2,
            engine_gemm_threads: 1,
            use_pjrt: true,
        }
    }
}

pub struct Server {
    pub router: Arc<Router>,
    pub metrics: Arc<Metrics>,
    queue: Arc<BatchQueue>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    pub fn start(opts: ServerOpts) -> Result<Server> {
        let art = ArtifactDir::discover()?;
        let dcnn = Arc::new(
            Dcnn::load(&art.weights_path()).context("loading weights")?,
        );
        let metrics = Arc::new(Metrics::new());
        let queue = Arc::new(BatchQueue::new(
            opts.configs.len(),
            opts.max_batch,
            opts.max_wait,
            opts.queue_capacity,
        ));
        let router = Arc::new(Router::new(
            opts.configs.clone(),
            queue.clone(),
            metrics.clone(),
        ));

        // Without the `pjrt` feature the ModelRunner stub can never
        // start, so route everything to the engine workers instead of
        // assigning configs to a worker that dies at startup.
        let pjrt_available = cfg!(feature = "pjrt") && opts.use_pjrt;
        let pjrt_mask: Vec<bool> = opts
            .configs
            .iter()
            .map(|c| pjrt_available && Variant::for_config(c).is_some())
            .collect();
        // engine workers cover what PJRT does not
        let engine_mask: Vec<bool> =
            pjrt_mask.iter().map(|p| !p).collect();

        let mut workers = Vec::new();
        if pjrt_mask.iter().any(|&b| b) {
            let q = queue.clone();
            let m = metrics.clone();
            let cfgs = opts.configs.clone();
            let art2 = art.clone();
            let d = dcnn.clone();
            let threads = opts.engine_gemm_threads;
            workers.push(std::thread::spawn(move || {
                pjrt_worker(art2, d, cfgs, q, m, pjrt_mask, threads);
            }));
        }
        if engine_mask.iter().any(|&b| b) || !opts.use_pjrt {
            for _ in 0..opts.engine_workers.max(1) {
                let q = queue.clone();
                let m = metrics.clone();
                let d = dcnn.clone();
                let cfgs = opts.configs.clone();
                let mask = engine_mask.clone();
                let threads = opts.engine_gemm_threads;
                workers.push(std::thread::spawn(move || {
                    engine_worker(d, cfgs, q, m, mask, threads);
                }));
            }
        }
        Ok(Server { router, metrics, queue, workers })
    }

    /// Per-config queue depths right now (admission/observability
    /// snapshot, config order = `ServerOpts::configs`).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.queue.depths()
    }

    /// Close the queue, drain in-flight work, join workers.
    pub fn shutdown(self) {
        self.queue.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn respond(batch: Vec<Request>, preds: &[usize], metrics: &Metrics) {
    let now = Instant::now();
    for (req, &pred) in batch.into_iter().zip(preds) {
        let latency = now.duration_since(req.submitted);
        metrics.record_latency(latency);
        let _ = req.reply.send(Response { id: req.id, pred, latency });
    }
}

fn batch_tensor(batch: &[Request]) -> Tensor {
    let mut data = Vec::with_capacity(batch.len() * 784);
    for r in batch {
        data.extend_from_slice(&r.image);
    }
    Tensor::new(vec![batch.len(), 28, 28, 1], data)
}

fn pjrt_worker(art: ArtifactDir, dcnn: Arc<Dcnn>, configs: Vec<NetConfig>,
               queue: Arc<BatchQueue>, metrics: Arc<Metrics>,
               mask: Vec<bool>, engine_threads: usize) {
    let mut runner = match ModelRunner::new(art) {
        Ok(r) => r,
        Err(e) => {
            // no `log` crate in the offline set: report on stderr.
            // Become an engine worker over the same mask so the configs
            // assigned to this worker are still served (the stub build
            // never reaches here — its configs route to engine workers
            // up front — but a runtime PJRT init failure does).
            eprintln!("pjrt worker failed to start: {e:#}; \
                       serving its configs on the engine backend");
            engine_worker(dcnn, configs, queue, metrics, mask,
                          engine_threads);
            return;
        }
    };
    while let Some((ci, batch)) = queue.next_batch(&mask) {
        let x = batch_tensor(&batch);
        match runner.forward(&configs[ci], &x) {
            Ok(logits) => {
                metrics.record_batch(batch.len());
                respond(batch, &logits.argmax_rows(), &metrics);
            }
            Err(e) => {
                eprintln!("pjrt forward failed: {e:#}");
                let sentinels = vec![usize::MAX; batch.len()];
                respond(batch, &sentinels, &metrics);
            }
        }
    }
}

fn engine_worker(dcnn: Arc<Dcnn>, configs: Vec<NetConfig>,
                 queue: Arc<BatchQueue>, metrics: Arc<Metrics>,
                 mask: Vec<bool>, threads: usize) {
    let mut prepared: HashMap<usize, crate::nn::network::PreparedNet> =
        HashMap::new();
    while let Some((ci, batch)) = queue.next_batch(&mask) {
        // First batch for a config prepares it once — quantization AND
        // weight-panel prepacking — and accounts the resident panels;
        // every later batch (batch-1 requests included) runs on fully
        // conditioned panels.
        if !prepared.contains_key(&ci) {
            let net = dcnn.prepare(configs[ci]);
            let (count, bytes) = net.packed_panel_stats();
            metrics.record_panels(count as u64, bytes as u64);
            prepared.insert(ci, net);
        }
        let net = &prepared[&ci];
        let x = batch_tensor(&batch);
        let preds = net.predict(&x, threads);
        metrics.record_batch(batch.len());
        respond(batch, &preds, &metrics);
    }
}

#[cfg(test)]
mod tests {
    // Server integration tests live in rust/tests/serving.rs (they need
    // artifacts); unit coverage for the queue/router/metrics pieces is in
    // their own modules.
}
