//! Accuracy evaluation with backend selection + memoization.
//!
//! Exact-arithmetic configs run on the PJRT fake-quant artifacts (fast,
//! XLA-compiled); approximate-multiplier and mixed-family configs run on
//! the bit-accurate Rust engine (the ground truth for approximate
//! datapaths).  Results are memoized by configuration name — the §4.2
//! explorer re-visits configurations constantly — and so are the
//! engine's `PreparedNet`s: each holds its layers' prepacked weight
//! panels, so re-scoring a config (full-test-set re-runs, frontier
//! re-ranking) never re-quantizes or re-packs its weights.

use crate::data::Dataset;
use crate::nn::network::{Dcnn, NetConfig, PreparedNet};
use crate::runtime::{execution_plan, ExecutionPlan, ModelRunner};
use anyhow::Result;
use std::collections::HashMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    Engine,
}

/// Evaluator over a fixed test subset.
pub struct Evaluator {
    dcnn: Dcnn,
    runner: Option<ModelRunner>,
    ds: Dataset,
    /// evaluation subset indices (explorer uses a reduced subset; final
    /// frontier re-scores on the full test set)
    pub subset: Vec<usize>,
    pub threads: usize,
    cache: HashMap<String, f64>,
    /// engine networks by config name, each holding its layers'
    /// prepacked weight panels (conditioned once, on first use)
    prepared: HashMap<String, PreparedNet>,
    pub eval_count: usize,
}

/// Prepared-net cache bound: a `PreparedNet` holds quantized weights +
/// prepacked panels (~tens of MB for this DCNN), and the explorer
/// visits ~100 distinct configs — but each *trial* config is scored
/// once (the accuracy memo absorbs revisits), so only the handful of
/// configs that get re-scored (baseline, frontier, full-test re-runs)
/// profit from staying resident.  Cap the cache and evict arbitrarily
/// beyond it: bounded memory, and the hot few stay cached in practice.
const PREPARED_CAP: usize = 8;

impl Evaluator {
    pub fn new(dcnn: Dcnn, runner: Option<ModelRunner>, ds: Dataset,
               subset_n: usize, threads: usize) -> Evaluator {
        let n = subset_n.min(ds.test.len());
        Evaluator {
            dcnn,
            runner,
            ds,
            subset: (0..n).collect(),
            threads,
            cache: HashMap::new(),
            prepared: HashMap::new(),
            eval_count: 0,
        }
    }

    pub fn backend_for(&self, cfg: &NetConfig) -> Backend {
        match execution_plan(cfg) {
            ExecutionPlan::Pjrt(_) if self.runner.is_some() => {
                Backend::Pjrt
            }
            _ => Backend::Engine,
        }
    }

    /// Accuracy of `cfg` on the evaluation subset (memoized).
    pub fn accuracy(&mut self, cfg: &NetConfig) -> Result<f64> {
        let key = cfg.name();
        if let Some(&a) = self.cache.get(&key) {
            return Ok(a);
        }
        let acc = self.accuracy_on(cfg, &self.subset.clone())?;
        self.cache.insert(key, acc);
        self.eval_count += 1;
        Ok(acc)
    }

    /// Accuracy on an explicit index set (not memoized).
    pub fn accuracy_on(&mut self, cfg: &NetConfig, idx: &[usize])
                       -> Result<f64> {
        let labels: Vec<usize> =
            idx.iter().map(|&i| self.ds.test.labels[i] as usize).collect();
        let preds = match self.backend_for(cfg) {
            Backend::Pjrt => {
                let x = self.ds.batch(&self.ds.test, idx);
                let runner = self.runner.as_mut().unwrap();
                runner.forward(cfg, &x)?.argmax_rows()
            }
            Backend::Engine => {
                // prepare once per config: quantization + panel
                // prepacking are hoisted out of every later re-score
                let key = cfg.name();
                if !self.prepared.contains_key(&key) {
                    if self.prepared.len() >= PREPARED_CAP {
                        if let Some(evict) =
                            self.prepared.keys().next().cloned()
                        {
                            self.prepared.remove(&evict);
                        }
                    }
                    let net = self.dcnn.prepare(*cfg);
                    self.prepared.insert(key.clone(), net);
                }
                let net = &self.prepared[&key];
                // chunk to bound memory (im2col of large batches is big)
                let mut preds = Vec::with_capacity(idx.len());
                for chunk in idx.chunks(64) {
                    let x = self.ds.batch(&self.ds.test, chunk);
                    preds.extend(net.predict(&x, self.threads));
                }
                preds
            }
        };
        let correct =
            preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
        Ok(correct as f64 / idx.len().max(1) as f64)
    }

    /// Full-test-set accuracy (used for final reporting).
    pub fn accuracy_full(&mut self, cfg: &NetConfig) -> Result<f64> {
        let idx: Vec<usize> = (0..self.ds.test.len()).collect();
        self.accuracy_on(cfg, &idx)
    }

    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Engine networks resident in the prepare cache.
    pub fn prepared_nets(&self) -> usize {
        self.prepared.len()
    }

    /// Prepacked weight-panel bytes resident across cached engine
    /// networks (the explorer reports this next to eval counts).
    pub fn panel_bytes(&self) -> usize {
        self.prepared
            .values()
            .map(|n| n.packed_panel_stats().1)
            .sum()
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    pub fn dcnn(&self) -> &Dcnn {
        &self.dcnn
    }
}
