//! Accuracy evaluation with backend selection + memoization.
//!
//! Exact-arithmetic configs on the paper topology run on the PJRT
//! fake-quant artifacts (fast, XLA-compiled); approximate-multiplier
//! and mixed-family configs — and every non-paper `NetSpec`, which
//! the AOT artifacts do not implement — run on the bit-accurate Rust
//! engine (the ground truth for approximate datapaths).  Results are
//! memoized by structural fingerprint — the §4.2
//! explorer re-visits configurations constantly — and prepared engine
//! networks come from a shared [`PlanCache`] (one `Arc<PreparedNet>`
//! per config, single-flight prepare, LRU eviction by panel bytes), so
//! re-scoring a config never re-quantizes or re-packs its weights and
//! an evaluator can share residency with a serving worker pool instead
//! of duplicating it.

use super::plan_cache::PlanCache;
use crate::data::Dataset;
use crate::nn::network::Model;
use crate::nn::spec::{NetSpec, ReprMap};
use crate::runtime::{execution_plan, ModelRunner};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    Engine,
}

/// Evaluator over a fixed test subset.
pub struct Evaluator {
    /// shared prepared-net cache (replaces the pre-PR-4 private
    /// capped-at-8 map; the LRU byte cap bounds residency instead)
    plans: Arc<PlanCache>,
    runner: Option<ModelRunner>,
    ds: Dataset,
    /// evaluation subset indices (explorer uses a reduced subset; final
    /// frontier re-scores on the full test set)
    pub subset: Vec<usize>,
    pub threads: usize,
    cache: HashMap<String, f64>,
    pub eval_count: usize,
}

impl Evaluator {
    /// Stand-alone evaluator: wraps `model` in its own
    /// default-capacity [`PlanCache`].
    pub fn new(model: Model, runner: Option<ModelRunner>, ds: Dataset,
               subset_n: usize, threads: usize) -> Evaluator {
        Evaluator::with_plan_cache(
            Arc::new(PlanCache::new(Arc::new(model))),
            runner,
            ds,
            subset_n,
            threads,
        )
    }

    /// Evaluator over an existing shared cache — score configs against
    /// the same resident `PreparedNet`s a serving pool (or a second
    /// evaluator) is using, instead of preparing private copies.
    pub fn with_plan_cache(plans: Arc<PlanCache>,
                           runner: Option<ModelRunner>, ds: Dataset,
                           subset_n: usize, threads: usize)
                           -> Evaluator {
        let n = subset_n.min(ds.test.len());
        Evaluator {
            plans,
            runner,
            ds,
            subset: (0..n).collect(),
            threads,
            cache: HashMap::new(),
            eval_count: 0,
        }
    }

    /// The topology this evaluator scores configurations against.
    pub fn spec(&self) -> &NetSpec {
        self.plans.model().spec()
    }

    pub fn backend_for(&self, cfg: &ReprMap) -> Backend {
        // the AOT artifacts implement only the paper DCNN topology,
        // so any other spec is engine-only regardless of the config
        if execution_plan(cfg).is_pjrt()
            && self.runner.is_some()
            && self.spec().is_paper_dcnn()
        {
            Backend::Pjrt
        } else {
            Backend::Engine
        }
    }

    /// Accuracy of `cfg` on the evaluation subset (memoized by
    /// structural fingerprint).
    pub fn accuracy(&mut self, cfg: &ReprMap) -> Result<f64> {
        let key = self.plans.key_of(cfg);
        if let Some(&a) = self.cache.get(&key) {
            return Ok(a);
        }
        let acc = self.accuracy_on(cfg, &self.subset.clone())?;
        self.cache.insert(key, acc);
        self.eval_count += 1;
        // process-wide companion to the per-evaluator `eval_count`,
        // exported with telemetry snapshots
        crate::telemetry::global().counter("explorer.evals").inc();
        Ok(acc)
    }

    /// Accuracy on an explicit index set (not memoized).
    pub fn accuracy_on(&mut self, cfg: &ReprMap, idx: &[usize])
                       -> Result<f64> {
        let labels: Vec<usize> =
            idx.iter().map(|&i| self.ds.test.labels[i] as usize).collect();
        let preds = match self.backend_for(cfg) {
            Backend::Pjrt => {
                let x = self.ds.batch(&self.ds.test, idx);
                let runner = self.runner.as_mut().unwrap();
                runner.forward(cfg, &x)?.argmax_rows()
            }
            Backend::Engine => {
                // the shared cache prepares once per config
                // (quantization + panel prepacking hoisted out of every
                // later re-score, even across evaluators/workers)
                let net = self.plans.get(cfg);
                // chunk to bound memory (im2col of large batches is big)
                let mut preds = Vec::with_capacity(idx.len());
                for chunk in idx.chunks(64) {
                    let x = self.ds.batch(&self.ds.test, chunk);
                    preds.extend(net.predict(&x, self.threads));
                }
                preds
            }
        };
        let correct =
            preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
        Ok(correct as f64 / idx.len().max(1) as f64)
    }

    /// Full-test-set accuracy (used for final reporting).
    pub fn accuracy_full(&mut self, cfg: &ReprMap) -> Result<f64> {
        let idx: Vec<usize> = (0..self.ds.test.len()).collect();
        self.accuracy_on(cfg, &idx)
    }

    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// The shared prepared-net cache (hit/miss/eviction stats live on
    /// it).
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plans
    }

    /// Engine networks resident in the shared plan cache.
    pub fn prepared_nets(&self) -> usize {
        self.plans.stats().resident_configs
    }

    /// Prepacked weight-panel bytes resident across cached engine
    /// networks (the explorer reports this next to eval counts).
    pub fn panel_bytes(&self) -> usize {
        self.plans.stats().resident_bytes
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    pub fn model(&self) -> &Model {
        self.plans.model()
    }
}
