//! Shared prepared-network cache: **one `Arc<PreparedNet>` per
//! configuration across the whole serving/eval stack**.
//!
//! After PR 3 every engine worker and every eval slot conditioned and
//! prepacked its *own* `PreparedNet`, so panel memory and prepare time
//! scaled with `workers x configs`.  `PlanCache` collapses that to
//! `configs`:
//!
//! * **Single-flight preparation** — the first requester of a config
//!   quantizes + prepacks it (`Model::prepare`); concurrent requesters
//!   for the *same* config block on that in-flight entry instead of
//!   duplicating the work, then share the finished `Arc`.
//! * **LRU eviction by panel bytes** — residency is bounded by the
//!   total `packed_panel_stats` bytes of cached networks, not an entry
//!   count; the least-recently-used config is dropped first.  The most
//!   recently prepared config is never evicted by its own insertion,
//!   so the bound is soft by at most one network.  Eviction drops the
//!   cache's `Arc` only — workers mid-batch keep theirs until the
//!   batch finishes.
//! * **Observability** — hit / miss / eviction / in-flight-wait
//!   counters plus resident panel stats, surfaced through
//!   [`PlanCache::stats`] and mirrored into `coordinator::metrics`
//!   gauges by the engine workers.
//!
//! Sharing is sound because `PreparedNet` is immutable after
//! `Model::prepare` (`Send + Sync`, pinned in `nn::network` tests) and
//! the `PackedWeights` identity guards from PR 3 make cross-kind panel
//! confusion a panic, not a wrong answer.  The cache key is the
//! **structural fingerprint** `NetSpec::fingerprint(&ReprMap)` — the
//! canonical spec-grammar string plus every layer's full provider
//! name — which is injective over (topology, assignment), so two
//! different topologies served from one process can never collide on
//! a config name the way the old name-string key could.
//!
//! `rust/tests/plan_cache.rs` pins single-flight under contention (one
//! `weight_pack_count_global` increment per layer), the byte cap, the
//! bit-identity of evicted-then-refetched configs, and the
//! worker-count invariance of the prepare count.

use crate::nn::network::{Model, PreparedNet};
use crate::nn::spec::ReprMap;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Default residency bound: comfortably holds the explorer's
/// re-scored frontier (a prepared DCNN's panels are ~13–26 MiB
/// depending on the provider's element width) without letting a wide
/// DSE sweep pin hundreds of networks.
pub const DEFAULT_CAPACITY_BYTES: usize = 256 * 1024 * 1024;

/// One cached network plus its accounting.
struct Resident {
    net: Arc<PreparedNet>,
    /// panel layers / panel bytes, from `packed_panel_stats` at insert
    panels: usize,
    bytes: usize,
    /// logical clock of the last `get` that returned this entry
    last_used: u64,
}

enum Slot {
    /// A thread is inside `Model::prepare` for this config; waiters
    /// block on the condvar until the slot becomes `Ready` (or is
    /// cleared because the preparer panicked, in which case one waiter
    /// takes over).
    InFlight,
    Ready(Resident),
}

struct Inner {
    slots: HashMap<String, Slot>,
    /// sum of `Resident::bytes` over `Ready` slots
    resident_bytes: usize,
    /// sum of `Resident::panels` over `Ready` slots
    resident_panels: usize,
    /// logical LRU clock (bumped per `get`)
    tick: u64,
}

/// Counter snapshot from [`PlanCache::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// `get` calls served from a resident entry (including after a
    /// wait on an in-flight preparation).
    pub hits: u64,
    /// `get` calls that had to prepare the network themselves.
    pub misses: u64,
    /// Networks dropped to respect the byte capacity.
    pub evictions: u64,
    /// `get` calls that blocked at least once on another thread's
    /// in-flight preparation (each counted once).
    pub inflight_waits: u64,
    /// Total `Model::prepare` runs — equals `misses`; kept separate so
    /// the acceptance invariant ("prepare count is independent of
    /// worker count") reads off one field.
    pub prepares: u64,
    /// Configurations currently resident.
    pub resident_configs: usize,
    /// Layers with cached weight panels across resident configs.
    pub resident_panels: usize,
    /// Prepacked panel bytes across resident configs.
    pub resident_bytes: usize,
}

/// Concurrent, capacity-bounded map from configuration fingerprint to
/// `Arc<PreparedNet>`.  See the module docs for the full contract.
pub struct PlanCache {
    model: Arc<Model>,
    capacity_bytes: usize,
    inner: Mutex<Inner>,
    ready: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    inflight_waits: AtomicU64,
    /// Lock-free mirrors of `Inner::{resident_panels, resident_bytes}`
    /// — written only while the map lock is held (one store after each
    /// insert-and-evict in `prepare_slot`), read without it, so the
    /// engine workers can refresh metric gauges on every batch.
    resident_panels_gauge: AtomicU64,
    resident_bytes_gauge: AtomicU64,
}

/// Clears the in-flight marker if `Model::prepare` panics, so waiters
/// retry (one of them becomes the new preparer) instead of blocking
/// forever.  Disarmed on the success path.
struct ClearOnPanic<'a> {
    cache: &'a PlanCache,
    key: &'a str,
    armed: bool,
}

impl Drop for ClearOnPanic<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        // Tolerate a poisoned mutex during unwind: a double panic
        // would abort the process and hide the original failure.
        let mut g = match self.cache.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        g.slots.remove(self.key);
        drop(g);
        self.cache.ready.notify_all();
    }
}

impl PlanCache {
    /// Cache over `model` with the default byte capacity.
    pub fn new(model: Arc<Model>) -> PlanCache {
        PlanCache::with_capacity(model, DEFAULT_CAPACITY_BYTES)
    }

    /// Cache over `model` bounded to `capacity_bytes` of resident
    /// prepacked panels (soft by at most the most recent network).
    pub fn with_capacity(model: Arc<Model>, capacity_bytes: usize)
                         -> PlanCache {
        PlanCache {
            model,
            capacity_bytes,
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                resident_bytes: 0,
                resident_panels: 0,
                tick: 0,
            }),
            ready: Condvar::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            inflight_waits: AtomicU64::new(0),
            resident_panels_gauge: AtomicU64::new(0),
            resident_bytes_gauge: AtomicU64::new(0),
        }
    }

    /// The cache key for `cfg`: the structural fingerprint of this
    /// cache's model topology plus the per-layer assignment.
    /// Panics when `cfg`'s arity does not match the model's spec.
    pub fn key_of(&self, cfg: &ReprMap) -> String {
        self.model.spec().fingerprint(cfg)
    }

    /// The prepared network for `cfg` — cached, or prepared exactly
    /// once no matter how many workers ask concurrently.
    pub fn get(&self, cfg: &ReprMap) -> Arc<PreparedNet> {
        self.get_noting_miss(cfg).0
    }

    /// [`PlanCache::get`], additionally reporting whether *this call*
    /// ran the preparation (a miss).  Residency only changes inside a
    /// miss (the insert plus any evictions it triggers), so hot
    /// callers — the engine worker batch loop — can skip re-locking
    /// the cache for a metrics snapshot on pure hits.
    pub fn get_noting_miss(&self, cfg: &ReprMap)
                           -> (Arc<PreparedNet>, bool) {
        let key = self.key_of(cfg);
        let mut waited = false;
        let mut g = self.inner.lock().unwrap();
        loop {
            g.tick += 1;
            let now = g.tick;
            match g.slots.get_mut(&key) {
                Some(Slot::Ready(r)) => {
                    r.last_used = now;
                    let net = r.net.clone();
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return (net, false);
                }
                Some(Slot::InFlight) => {
                    if !waited {
                        waited = true;
                        self.inflight_waits
                            .fetch_add(1, Ordering::Relaxed);
                    }
                    g = self.ready.wait(g).unwrap();
                    // re-inspect: the slot is now Ready, or gone (the
                    // preparer panicked — loop makes us the preparer)
                }
                None => {
                    g.slots.insert(key.clone(), Slot::InFlight);
                    drop(g);
                    return (self.prepare_slot(&key, cfg), true);
                }
            }
        }
    }

    /// Prepare `cfg` outside the lock, publish it, evict LRU entries
    /// beyond the byte capacity, wake waiters.
    fn prepare_slot(&self, key: &str, cfg: &ReprMap)
                    -> Arc<PreparedNet> {
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut guard = ClearOnPanic { cache: self, key, armed: true };
        let net = Arc::new(self.model.prepare(cfg));
        guard.armed = false;
        let (panels, bytes) = net.packed_panel_stats();
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let now = g.tick;
        g.resident_bytes += bytes;
        g.resident_panels += panels;
        g.slots.insert(
            key.to_string(),
            Slot::Ready(Resident {
                net: net.clone(),
                panels,
                bytes,
                last_used: now,
            }),
        );
        self.evict_beyond_cap(&mut g, key);
        // refresh the lock-free residency mirrors while still holding
        // the lock, so they always reflect a consistent post-insert,
        // post-eviction state (readers may briefly see the previous
        // consistent state, never a torn one)
        self.resident_panels_gauge
            .store(g.resident_panels as u64, Ordering::Relaxed);
        self.resident_bytes_gauge
            .store(g.resident_bytes as u64, Ordering::Relaxed);
        drop(g);
        self.ready.notify_all();
        net
    }

    /// Drop least-recently-used `Ready` entries (never `keep`, never
    /// in-flight slots) until resident bytes fit the capacity.
    fn evict_beyond_cap(&self, g: &mut MutexGuard<'_, Inner>,
                        keep: &str) {
        while g.resident_bytes > self.capacity_bytes {
            let victim = g
                .slots
                .iter()
                .filter_map(|(k, s)| match s {
                    Slot::Ready(r) if k != keep => {
                        Some((k.clone(), r.last_used))
                    }
                    _ => None,
                })
                .min_by_key(|&(_, used)| used)
                .map(|(k, _)| k);
            let Some(k) = victim else {
                return; // only `keep` / in-flight entries remain
            };
            if let Some(Slot::Ready(r)) = g.slots.remove(&k) {
                g.resident_bytes -= r.bytes;
                g.resident_panels -= r.panels;
                self.evictions.fetch_add(1, Ordering::Relaxed);
                // the cache's Arc drops here; workers mid-batch keep
                // the network alive through their own Arc
            }
        }
    }

    /// Counter + residency snapshot (counters are `Relaxed`; the
    /// residency fields are mutually consistent — read under the map
    /// lock).
    pub fn stats(&self) -> PlanCacheStats {
        let g = self.inner.lock().unwrap();
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            inflight_waits: self.inflight_waits.load(Ordering::Relaxed),
            prepares: self.misses.load(Ordering::Relaxed),
            resident_configs: g
                .slots
                .values()
                .filter(|s| matches!(s, Slot::Ready(_)))
                .count(),
            resident_panels: g.resident_panels,
            resident_bytes: g.resident_bytes,
        }
    }

    /// `(prepare count, resident panel bytes)` — the cache-level
    /// mirror of `PreparedNet::packed_panel_stats`, and the pair the
    /// acceptance invariant compares across engine worker counts.
    pub fn packed_panel_stats(&self) -> (u64, usize) {
        let s = self.stats();
        (s.prepares, s.resident_bytes)
    }

    /// Lock-free `(hits, misses, evictions)` snapshot — unlike
    /// [`PlanCache::stats`] this never takes the map mutex, so the
    /// engine workers can mirror live counters into
    /// `coordinator::metrics` on every batch without contending with
    /// concurrent `get`s.
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.evictions.load(Ordering::Relaxed),
        )
    }

    /// Lock-free `(resident panel layers, resident panel bytes)` —
    /// mirrors maintained under the map lock at every residency
    /// change, read here without it.  The pair carries no ordering
    /// information, so racing publishers of these values can briefly
    /// publish stale state; metric mirroring should use
    /// [`PlanCache::gauge_snapshot`] instead.
    pub fn resident_gauges(&self) -> (u64, u64) {
        (
            self.resident_panels_gauge.load(Ordering::Relaxed),
            self.resident_bytes_gauge.load(Ordering::Relaxed),
        )
    }

    /// Sequence-tagged residency snapshot for telemetry gauges:
    /// `(seq, resident panel layers, resident panel bytes)`, read
    /// under the map lock with a freshly bumped logical clock.  Every
    /// snapshot carries a unique, monotonically increasing sequence
    /// and the triple is internally consistent, so publishing it via
    /// `telemetry::Gauge::set_at` closes the PR-4 staleness race: a
    /// racing worker's older snapshot (smaller seq) can never
    /// overwrite a fresher one.  Bumping the clock does not perturb
    /// LRU order — entries keep their own `last_used` stamps.
    pub fn gauge_snapshot(&self) -> (u64, u64, u64) {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        (g.tick, g.resident_panels as u64, g.resident_bytes as u64)
    }

    /// Whether `cfg` is resident right now (does not touch LRU order).
    pub fn contains(&self, cfg: &ReprMap) -> bool {
        matches!(
            self.inner.lock().unwrap().slots.get(&self.key_of(cfg)),
            Some(Slot::Ready(_))
        )
    }

    /// The trained network this cache prepares from.
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// The configured residency bound in bytes.
    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::spec::NetSpec;

    fn cfg(s: &str) -> ReprMap {
        ReprMap::parse_for(&NetSpec::paper_dcnn(), s).unwrap()
    }

    fn paper(seed: u64) -> Arc<Model> {
        Arc::new(Model::synthetic(NetSpec::paper_dcnn(), seed))
    }

    #[test]
    fn hit_after_miss_shares_one_arc() {
        let cache = PlanCache::new(paper(1));
        let c = cfg("FI(6,8)");
        let (a, missed) = cache.get_noting_miss(&c);
        assert!(missed, "first get prepares");
        let (b, missed2) = cache.get_noting_miss(&c);
        assert!(!missed2, "second get rides the cache");
        assert!(Arc::ptr_eq(&a, &b), "second get must share the Arc");
        let s = cache.stats();
        assert_eq!((s.misses, s.hits, s.prepares), (1, 1, 1));
        assert_eq!(s.resident_configs, 1);
        assert_eq!(s.resident_panels, 4);
        assert!(s.resident_bytes > 0);
        assert!(cache.contains(&c));
    }

    #[test]
    fn distinct_configs_prepare_separately() {
        let cache = PlanCache::new(paper(2));
        let a = cache.get(&cfg("FI(6,8)"));
        let b = cache.get(&cfg("FI(5,8)"));
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(a.cfg, cfg("FI(6,8)"));
        assert_eq!(b.cfg, cfg("FI(5,8)"));
        assert_eq!(cache.stats().prepares, 2);
    }

    #[test]
    fn zero_capacity_keeps_only_the_latest() {
        // cap 0: every insertion evicts everything else, but the
        // just-prepared network itself always stays (soft bound).
        let cache = PlanCache::with_capacity(paper(3), 0);
        cache.get(&cfg("FI(6,8)"));
        assert_eq!(cache.stats().resident_configs, 1);
        cache.get(&cfg("FI(5,8)"));
        let s = cache.stats();
        assert_eq!(s.resident_configs, 1);
        assert_eq!(s.evictions, 1);
        assert!(cache.contains(&cfg("FI(5,8)")));
        assert!(!cache.contains(&cfg("FI(6,8)")));
        // panel accounting drained along with the eviction
        let one = cache.get(&cfg("FI(5,8)")).packed_panel_stats();
        assert_eq!(cache.stats().resident_bytes, one.1);
        assert_eq!(cache.stats().resident_panels, one.0);
    }

    #[test]
    fn gauge_snapshots_carry_unique_increasing_sequences() {
        let cache = PlanCache::new(paper(5));
        let (s1, p1, b1) = cache.gauge_snapshot();
        let (s2, p2, b2) = cache.gauge_snapshot();
        assert!(s2 > s1, "sequences must strictly increase");
        assert_eq!((p1, b1), (0, 0));
        assert_eq!((p2, b2), (0, 0));
        cache.get(&cfg("FI(6,8)"));
        let (s3, p3, b3) = cache.gauge_snapshot();
        assert!(s3 > s2);
        assert_eq!(p3, 4);
        assert!(b3 > 0);
        // snapshot clock bumps do not disturb LRU eviction order:
        // entries keep their own last_used stamps
        let s = cache.stats();
        assert_eq!(s.resident_configs, 1);
    }

    #[test]
    fn refetch_after_eviction_reprepares() {
        let cache = PlanCache::with_capacity(paper(4), 0);
        let a = cache.get(&cfg("FI(6,8)"));
        cache.get(&cfg("binxnor")); // evicts FI(6,8)
        let b = cache.get(&cfg("FI(6,8)")); // must re-prepare
        assert!(!Arc::ptr_eq(&a, &b), "evicted entry cannot be reused");
        assert_eq!(cache.stats().prepares, 3);
        // deterministic prepare: the re-prepared net is equivalent
        assert_eq!(a.packed_panel_stats(), b.packed_panel_stats());
    }
}
