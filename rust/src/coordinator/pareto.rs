//! Surrogate-guided, Pareto-front design-space exploration — the autoAx
//! shape (PAPERS.md, arXiv 1902.10807) grafted onto the paper's §4.2
//! layer-wise search:
//!
//! * **Quality surrogate** ([`SensitivityProfile`]): quantize one layer
//!   at a time (every other layer at float32), run a small calibration
//!   batch, and record the fraction of predictions that flip.  Under
//!   the additive-independence assumption the predicted accuracy of a
//!   mixed config is `baseline - sum(per-layer drops)` — one forward
//!   pass per (layer, candidate) instead of per *combination*.
//! * **Cost surrogate** ([`CostModel`]): analytic ns/MAC per
//!   [`ArithKind`] from [`Datapath::synthesize`] fmax at [`N_PE`] PEs,
//!   optionally re-calibrated from measured `BENCH_gemm_kernels.json`
//!   throughput rows; latency is `sum(layer_macs[i] * ns_per_mac)`,
//!   hardware cost the mean per-layer [`Datapath::explore_cost`].
//! * **Dominance-pruned search** ([`surrogate_front`]): a layer-by-layer
//!   dynamic program over (accuracy-drop, latency, hw-cost) triples.
//!   Per-layer contributions are additive in all three objectives, so a
//!   config whose prefix is dominated cannot re-enter the front — each
//!   DP step prunes to the non-dominated set (plus a deterministic beam
//!   cap) before the next cross-product.
//! * **Provenance-carrying artifact** ([`ParetoFront`]): only
//!   surrogate-predicted-front configs are simulated through the real
//!   `Evaluator`/PlanCache path (the `Explorer` drives that), and every
//!   emitted point says whether its accuracy is measured or predicted.
//!   `serve --auto` re-loads the artifact via [`ParetoFront::from_json`]
//!   and [`auto_config`] picks the cheapest config meeting an accuracy
//!   budget at startup.
//!
//! The fluent driver that ties these to an `Evaluator` lives in
//! [`super::explorer::Explorer`]; this module is the pure machinery so
//! every piece is unit-testable without a dataset.

use crate::approx::arith::ArithKind;
use crate::data::loader::{Dataset, Split};
use crate::hw::datapath::{Datapath, ARRIA10, N_PE};
use crate::nn::network::Model;
use crate::nn::spec::{NetSpec, ReprMap};
use crate::nn::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::Path;

/// Tie tolerance for dominance comparisons on measured quantities.
pub const EPS: f64 = 1e-9;

// ---------------------------------------------------------------------
// objectives and dominance
// ---------------------------------------------------------------------

/// One search objective.  Internally every objective is *minimized*
/// over a fixed `[f64; 3]` vector: index 0 is accuracy loss (predicted
/// drop during the search, `1 - measured` afterwards), index 1 latency
/// in ns, index 2 hardware cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    Accuracy,
    Latency,
    HwCost,
}

/// Every objective, in vector-index order.
pub const ALL_OBJECTIVES: [Objective; 3] =
    [Objective::Accuracy, Objective::Latency, Objective::HwCost];

impl Objective {
    /// Index into the minimized `[acc_loss, latency_ns, hw_cost]`
    /// objective vector.
    pub fn index(&self) -> usize {
        match self {
            Objective::Accuracy => 0,
            Objective::Latency => 1,
            Objective::HwCost => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Accuracy => "accuracy",
            Objective::Latency => "latency",
            Objective::HwCost => "hw",
        }
    }

    pub fn parse(s: &str) -> Result<Objective, String> {
        match s.trim() {
            "accuracy" | "acc" => Ok(Objective::Accuracy),
            "latency" | "lat" => Ok(Objective::Latency),
            "hw" | "hw_cost" | "cost" => Ok(Objective::HwCost),
            other => Err(format!(
                "unknown objective '{other}' \
                 (expected accuracy, latency, or hw)"
            )),
        }
    }

    /// Parse a comma-separated objective list, e.g. `accuracy,hw`.
    pub fn parse_list(s: &str) -> Result<Vec<Objective>, String> {
        let mut out = Vec::new();
        for part in s.split(',') {
            if part.trim().is_empty() {
                continue;
            }
            let o = Objective::parse(part)?;
            if !out.contains(&o) {
                out.push(o);
            }
        }
        if out.is_empty() {
            return Err(format!("no objectives in '{s}'"));
        }
        Ok(out)
    }
}

/// Strict Pareto dominance on full minimized vectors: `a` is no worse
/// everywhere and strictly better somewhere.
pub fn dominates(a: &[f64; 3], b: &[f64; 3]) -> bool {
    dominates_on(a, b, &ALL_OBJECTIVES)
}

/// [`dominates`] restricted to the active objectives.
pub fn dominates_on(a: &[f64; 3], b: &[f64; 3],
                    objectives: &[Objective]) -> bool {
    let mut strict = false;
    for o in objectives {
        let j = o.index();
        if a[j] > b[j] {
            return false;
        }
        if a[j] < b[j] {
            strict = true;
        }
    }
    strict
}

fn proj_eq(a: &[f64; 3], b: &[f64; 3], objectives: &[Objective]) -> bool {
    objectives.iter().all(|o| a[o.index()] == b[o.index()])
}

/// Indices of the non-dominated points (ties kept, order preserved).
/// This is the *reference* O(n^2) definition the tests and the CI gate
/// check the pruned search against.
pub fn pareto_front_indices(points: &[[f64; 3]]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, p)| j != i && dominates(p, &points[i]))
        })
        .collect()
}

/// Prune `items` to the non-dominated set under `objectives`,
/// deduplicating points whose *projected* vectors are equal (the first
/// in lexicographic full-vector order wins, which makes the result
/// deterministic regardless of input order).
pub fn prune_nondominated<T>(mut items: Vec<(T, [f64; 3])>,
                             objectives: &[Objective])
                             -> Vec<(T, [f64; 3])> {
    items.sort_by(|a, b| {
        a.1[0]
            .total_cmp(&b.1[0])
            .then(a.1[1].total_cmp(&b.1[1]))
            .then(a.1[2].total_cmp(&b.1[2]))
    });
    let mut kept: Vec<(T, [f64; 3])> = Vec::new();
    'next: for (t, v) in items {
        for (_, kv) in &kept {
            if dominates_on(kv, &v, objectives)
                || proj_eq(kv, &v, objectives)
            {
                continue 'next;
            }
        }
        // Sort order is lexicographic on the *full* vector, so under a
        // projected objective set a later item can still dominate an
        // earlier keep — the backward retain is load-bearing.
        kept.retain(|(_, kv)| !dominates_on(&v, kv, objectives));
        kept.push((t, v));
    }
    kept
}

// ---------------------------------------------------------------------
// quality surrogate
// ---------------------------------------------------------------------

/// Per-layer quality sensitivity: for each layer, the prediction-flip
/// fraction of each candidate kind measured with *only that layer*
/// quantized (one-pass perturbation sweep on a calibration batch).
#[derive(Clone, Debug)]
pub struct SensitivityProfile {
    drops: Vec<Vec<(ArithKind, f64)>>,
}

impl SensitivityProfile {
    /// Run the perturbation sweep: one forward per (layer, candidate)
    /// on `calib_x`, against the float32 baseline predictions.
    pub fn profile(model: &Model, calib_x: &Tensor,
                   candidates: &[Vec<ArithKind>], threads: usize)
                   -> SensitivityProfile {
        let spec = model.spec();
        assert_eq!(candidates.len(), spec.len(),
                   "one candidate set per layer");
        let f32_cfg = ReprMap::uniform_for(spec, ArithKind::Float32);
        let base = model.prepare(&f32_cfg).predict(calib_x, threads);
        let n = base.len().max(1) as f64;
        let mut drops = Vec::with_capacity(candidates.len());
        for (layer, cands) in candidates.iter().enumerate() {
            let mut row = Vec::with_capacity(cands.len());
            for &kind in cands {
                let drop = if kind == ArithKind::Float32 {
                    0.0
                } else {
                    let mut cfg = f32_cfg.clone();
                    cfg.set(layer, kind);
                    let pred =
                        model.prepare(&cfg).predict(calib_x, threads);
                    let flips = pred
                        .iter()
                        .zip(&base)
                        .filter(|(p, b)| p != b)
                        .count();
                    flips as f64 / n
                };
                row.push((kind, drop));
            }
            drops.push(row);
        }
        SensitivityProfile { drops }
    }

    /// Build a profile from precomputed drops (tests, replay).
    pub fn from_drops(drops: Vec<Vec<(ArithKind, f64)>>)
                      -> SensitivityProfile {
        SensitivityProfile { drops }
    }

    /// Measured flip fraction for `kind` at `layer` (0.0 when the kind
    /// was not profiled — float32 in particular).
    pub fn drop_of(&self, layer: usize, kind: &ArithKind) -> f64 {
        self.drops
            .get(layer)
            .and_then(|row| {
                row.iter().find(|(k, _)| k == kind).map(|(_, d)| *d)
            })
            .unwrap_or(0.0)
    }

    /// Additive-independence accuracy prediction for a full config.
    pub fn predict(&self, baseline: f64, cfg: &ReprMap) -> f64 {
        let total: f64 = cfg
            .kinds()
            .iter()
            .enumerate()
            .map(|(i, k)| self.drop_of(i, k))
            .sum();
        (baseline - total).clamp(0.0, 1.0)
    }
}

// ---------------------------------------------------------------------
// cost surrogate
// ---------------------------------------------------------------------

/// Analytic + optionally bench-calibrated latency/hw-cost model.
#[derive(Clone, Debug)]
pub struct CostModel {
    macs: Vec<u64>,
    ns_per_mac: HashMap<String, f64>,
    source: &'static str,
}

/// ns per MAC from the synthesized datapath alone: [`N_PE`] parallel
/// PEs, one MAC per PE per cycle at the kind's fmax.
fn analytic_ns_per_mac(kind: &ArithKind) -> f64 {
    let dp = Datapath::synthesize(kind, N_PE);
    1000.0 / (dp.fmax_mhz * N_PE as f64)
}

/// Best measured prepacked throughput per kind from a
/// `BENCH_gemm_kernels.json`, as ns/MAC.  Row kind strings are the
/// bench's *parse* spellings (`FI(6,8)`); they are re-canonicalized
/// through [`ArithKind::parse`] so lookups by [`ArithKind::name`]
/// (`FI(6, 8)`) hit.  Unparseable or non-positive rows are skipped.
fn bench_ns_per_mac(path: &Path) -> Result<HashMap<String, f64>> {
    let raw = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    let json = Json::parse(&raw)
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
    let rows = json
        .get("rows")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| anyhow!("{}: no rows array", path.display()))?;
    let mut best: HashMap<String, f64> = HashMap::new();
    for row in rows {
        let kind = match row
            .get("kind")
            .and_then(|k| k.as_str())
            .map(ArithKind::parse)
        {
            Some(Ok(k)) => k.name(),
            _ => continue,
        };
        let mmacs = row
            .get("prepacked_mmacs")
            .and_then(|m| m.as_f64())
            .unwrap_or(0.0);
        if mmacs <= 0.0 {
            continue;
        }
        let e = best.entry(kind).or_insert(0.0);
        if mmacs > *e {
            *e = mmacs;
        }
    }
    Ok(best
        .into_iter()
        .map(|(k, mmacs)| (k, 1000.0 / mmacs))
        .collect())
}

impl CostModel {
    /// Purely analytic model (no bench file).
    pub fn analytic(spec: &NetSpec, candidates: &[Vec<ArithKind>])
                    -> CostModel {
        let mut ns = HashMap::new();
        for row in candidates {
            for kind in row {
                ns.entry(kind.name())
                    .or_insert_with(|| analytic_ns_per_mac(kind));
            }
        }
        CostModel {
            macs: spec.layer_macs(),
            ns_per_mac: ns,
            source: "analytic",
        }
    }

    /// Analytic model, re-calibrated from a bench JSON when *every*
    /// candidate kind has a measured row.  Partial coverage falls back
    /// to fully analytic — mixing measured and analytic scales inside
    /// one front would make cross-kind latency comparisons meaningless.
    pub fn calibrated(spec: &NetSpec, candidates: &[Vec<ArithKind>],
                      bench_json: Option<&Path>) -> CostModel {
        let mut model = CostModel::analytic(spec, candidates);
        let Some(path) = bench_json else { return model };
        let Ok(bench) = bench_ns_per_mac(path) else { return model };
        let covered = candidates.iter().flatten().all(|k| {
            *k == ArithKind::Float32 || bench.contains_key(&k.name())
        });
        if !covered {
            return model;
        }
        for (kind, ns) in bench {
            model.ns_per_mac.insert(kind, ns);
        }
        model.source = "bench-calibrated";
        model
    }

    /// Where the latency scale came from (`analytic` or
    /// `bench-calibrated`) — recorded in the artifact.
    pub fn source(&self) -> &'static str {
        self.source
    }

    /// ns/MAC for `kind` (analytic fallback for kinds that were not in
    /// any candidate set).
    pub fn ns_per_mac(&self, kind: &ArithKind) -> f64 {
        self.ns_per_mac
            .get(&kind.name())
            .copied()
            .unwrap_or_else(|| analytic_ns_per_mac(kind))
    }

    /// Predicted latency contribution of one layer under `kind`.
    pub fn layer_latency_ns(&self, layer: usize, kind: &ArithKind)
                            -> f64 {
        self.macs[layer] as f64 * self.ns_per_mac(kind)
    }

    /// Predicted single-sample latency of a full config.
    pub fn latency_ns(&self, cfg: &ReprMap) -> f64 {
        cfg.kinds()
            .iter()
            .enumerate()
            .map(|(i, k)| self.layer_latency_ns(i, k))
            .sum()
    }

    /// Per-kind datapath cost (the §4.2 greedy objective, reused as
    /// the third search dimension).
    pub fn unit_cost(kind: &ArithKind) -> f64 {
        Datapath::synthesize(kind, N_PE).explore_cost(&ARRIA10)
    }

    /// Mean per-layer datapath cost of a config.
    pub fn hw_cost(&self, cfg: &ReprMap) -> f64 {
        let n = cfg.len().max(1) as f64;
        cfg.kinds().iter().map(CostModel::unit_cost).sum::<f64>() / n
    }
}

// ---------------------------------------------------------------------
// dominance-pruned search
// ---------------------------------------------------------------------

/// Enumerate the surrogate-predicted Pareto front by a layer-wise
/// dynamic program.  All three objectives are additive over layers
/// (drop by the independence assumption, latency and mean-hw-cost by
/// construction), so dominated prefixes cannot produce non-dominated
/// completions and each step may safely prune.  `beam` caps the kept
/// set per step (evenly-spaced downsample along the hw-cost sort) so
/// the DP stays polynomial on adversarial fronts.
///
/// Returns `(config, [predicted_drop, latency_ns, hw_cost])` pairs.
pub fn surrogate_front(spec: &NetSpec, profile: &SensitivityProfile,
                       cost: &CostModel,
                       candidates: &[Vec<ArithKind>],
                       objectives: &[Objective], beam: usize)
                       -> Vec<(ReprMap, [f64; 3])> {
    assert_eq!(candidates.len(), spec.len(),
               "one candidate set per layer");
    let n = spec.len().max(1) as f64;
    let beam = beam.max(1);
    let mut partial: Vec<(Vec<ArithKind>, [f64; 3])> =
        vec![(Vec::new(), [0.0; 3])];
    for (layer, cands) in candidates.iter().enumerate() {
        // Per-layer contribution vectors, pre-pruned: a per-layer
        // dominated choice yields a dominated total against the same
        // prefix, so it can never help.
        let contribs: Vec<(ArithKind, [f64; 3])> = cands
            .iter()
            .map(|&k| {
                (k, [
                    profile.drop_of(layer, &k),
                    cost.layer_latency_ns(layer, &k),
                    CostModel::unit_cost(&k) / n,
                ])
            })
            .collect();
        let contribs = prune_nondominated(contribs, objectives);
        let mut next = Vec::with_capacity(partial.len() * contribs.len());
        for (prefix, acc) in &partial {
            for (kind, c) in &contribs {
                let mut kinds = prefix.clone();
                kinds.push(*kind);
                next.push((kinds, [
                    acc[0] + c[0],
                    acc[1] + c[1],
                    acc[2] + c[2],
                ]));
            }
        }
        partial = prune_nondominated(next, objectives);
        if partial.len() > beam {
            // prune_nondominated returns hw-vector-lex-sorted keeps in
            // insertion order of the lex sweep; re-sort on hw cost and
            // keep `beam` evenly spaced points for a deterministic,
            // spread-preserving cap.
            partial.sort_by(|a, b| {
                a.1[2]
                    .total_cmp(&b.1[2])
                    .then(a.1[1].total_cmp(&b.1[1]))
                    .then(a.1[0].total_cmp(&b.1[0]))
            });
            let last = partial.len() - 1;
            let picked: Vec<usize> = (0..beam)
                .map(|s| s * last / (beam - 1).max(1))
                .collect();
            let mut keep = Vec::with_capacity(beam);
            let mut prev = usize::MAX;
            for i in picked {
                if i != prev {
                    keep.push(partial[i].clone());
                    prev = i;
                }
            }
            partial = keep;
        }
    }
    partial
        .into_iter()
        .map(|(kinds, v)| (ReprMap::from_kinds(kinds), v))
        .collect()
}

// ---------------------------------------------------------------------
// the artifact
// ---------------------------------------------------------------------

/// One point of the explored front.  `accuracy == est_accuracy` until
/// the point is simulated through the real evaluator, after which
/// `accuracy` is measured and `simulated` is true.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoPoint {
    pub repr_map: ReprMap,
    pub accuracy: f64,
    pub est_accuracy: f64,
    pub est_latency: f64,
    pub hw_cost: f64,
    pub simulated: bool,
}

/// The `pareto_front.json` artifact: the explored front plus enough
/// provenance (baseline, simulation count, space size, cost-model
/// source) to audit it.
#[derive(Clone, Debug)]
pub struct ParetoFront {
    spec: String,
    points: Vec<ParetoPoint>,
    baseline_accuracy: f64,
    sims: usize,
    space: u64,
    cost_source: String,
}

impl ParetoFront {
    /// Assemble a front (points are re-sorted cheapest-hardware-first,
    /// latency as tiebreak; an empty set is representable so failed
    /// searches still round-trip).
    pub fn from_points(spec: &NetSpec, mut points: Vec<ParetoPoint>,
                       baseline_accuracy: f64, sims: usize, space: u64,
                       cost_source: &str) -> ParetoFront {
        points.sort_by(|a, b| {
            a.hw_cost
                .total_cmp(&b.hw_cost)
                .then(a.est_latency.total_cmp(&b.est_latency))
        });
        // size of the most recently assembled front, for snapshots
        crate::telemetry::global()
            .gauge("explorer.front_points")
            .set(points.len() as u64);
        ParetoFront {
            spec: spec.to_string(),
            points,
            baseline_accuracy,
            sims,
            space,
            cost_source: cost_source.to_string(),
        }
    }

    pub fn points(&self) -> &[ParetoPoint] {
        &self.points
    }

    /// Canonical grammar string of the topology the front was explored
    /// on ([`auto_config`] refuses a mismatched spec).
    pub fn spec(&self) -> &str {
        &self.spec
    }

    pub fn baseline_accuracy(&self) -> f64 {
        self.baseline_accuracy
    }

    /// Full-net evaluator simulations the search spent.
    pub fn sims(&self) -> usize {
        self.sims
    }

    /// Size of the exhaustive configuration space the surrogates
    /// searched (product of per-layer candidate counts, saturating).
    pub fn space(&self) -> u64 {
        self.space
    }

    pub fn cost_source(&self) -> &str {
        &self.cost_source
    }

    /// Cheapest point whose accuracy meets `accuracy_budget`:
    /// minimal hardware cost, then latency; a simulated point beats a
    /// predicted-only point on an exact tie (trust measurements).
    pub fn best_within(&self, accuracy_budget: f64)
                       -> Option<&ParetoPoint> {
        self.points
            .iter()
            .filter(|p| p.accuracy + EPS >= accuracy_budget)
            .min_by(|a, b| {
                a.hw_cost
                    .total_cmp(&b.hw_cost)
                    .then(a.est_latency.total_cmp(&b.est_latency))
                    .then(b.simulated.cmp(&a.simulated))
            })
    }

    /// True when some front point is at least as good as
    /// `(accuracy, latency_ns, hw_cost)` on all three objectives
    /// (within [`EPS`]) — the acceptance check against exhaustive
    /// enumeration.
    pub fn dominates_or_ties(&self, accuracy: f64, latency_ns: f64,
                             hw_cost: f64) -> bool {
        self.points.iter().any(|p| {
            p.accuracy + EPS >= accuracy
                && p.est_latency <= latency_ns + EPS
                && p.hw_cost <= hw_cost + EPS
        })
    }

    /// Serialize to the versioned artifact schema.  `f64` values are
    /// written via Rust's shortest-round-trip `Display`, so
    /// [`ParetoFront::from_json`] reconstructs bit-identical numbers.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"artifact\": \"pareto_front\",\n");
        s.push_str("  \"version\": 1,\n");
        s.push_str(&format!("  \"spec\": {},\n", quote(&self.spec)));
        s.push_str(&format!("  \"baseline_accuracy\": {},\n",
                            self.baseline_accuracy));
        s.push_str(&format!("  \"sims\": {},\n", self.sims));
        s.push_str(&format!("  \"space\": {},\n", self.space));
        s.push_str(&format!("  \"cost_source\": {},\n",
                            quote(&self.cost_source)));
        s.push_str("  \"points\": [");
        for (i, p) in self.points.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"config\": {}, \"accuracy\": {}, \
                 \"est_accuracy\": {}, \"est_latency_ns\": {}, \
                 \"hw_cost\": {}, \"simulated\": {}}}",
                quote(&p.repr_map.name()),
                p.accuracy,
                p.est_accuracy,
                p.est_latency,
                p.hw_cost,
                p.simulated
            ));
        }
        if !self.points.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("]\n}\n");
        s
    }

    /// Parse the artifact (schema-checked; point errors are indexed).
    pub fn from_json(raw: &str) -> Result<ParetoFront> {
        let json = Json::parse(raw)
            .map_err(|e| anyhow!("pareto_front JSON: {e}"))?;
        let artifact =
            json.get("artifact").and_then(|a| a.as_str()).unwrap_or("");
        if artifact != "pareto_front" {
            bail!("not a pareto_front artifact (artifact = \
                   '{artifact}')");
        }
        let version =
            json.get("version").and_then(|v| v.as_f64()).unwrap_or(0.0);
        if version != 1.0 {
            bail!("unsupported pareto_front version {version}");
        }
        let spec_str = json
            .get("spec")
            .and_then(|s| s.as_str())
            .ok_or_else(|| anyhow!("pareto_front: missing spec"))?
            .to_string();
        let spec = NetSpec::parse(&spec_str)
            .map_err(|e| anyhow!("pareto_front spec: {e}"))?;
        let num = |key: &str| -> Result<f64> {
            json.get(key).and_then(|v| v.as_f64()).ok_or_else(|| {
                anyhow!("pareto_front: missing number '{key}'")
            })
        };
        let baseline_accuracy = num("baseline_accuracy")?;
        let sims = num("sims")? as usize;
        let space = num("space")? as u64;
        let cost_source = json
            .get("cost_source")
            .and_then(|s| s.as_str())
            .unwrap_or("analytic")
            .to_string();
        let rows = json
            .get("points")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow!("pareto_front: missing points"))?;
        let mut points = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            let perr =
                |what: &str| anyhow!("pareto_front point {i}: {what}");
            let config = row
                .get("config")
                .and_then(|c| c.as_str())
                .ok_or_else(|| perr("missing config"))?;
            let repr_map = ReprMap::parse_for(&spec, config)
                .map_err(|e| perr(&e))?;
            let pnum = |key: &str| -> Result<f64> {
                row.get(key).and_then(|v| v.as_f64()).ok_or_else(|| {
                    perr(&format!("missing number '{key}'"))
                })
            };
            points.push(ParetoPoint {
                repr_map,
                accuracy: pnum("accuracy")?,
                est_accuracy: pnum("est_accuracy")?,
                est_latency: pnum("est_latency_ns")?,
                hw_cost: pnum("hw_cost")?,
                simulated: row
                    .get("simulated")
                    .and_then(|b| b.as_bool())
                    .ok_or_else(|| perr("missing simulated flag"))?,
            });
        }
        Ok(ParetoFront {
            spec: spec_str,
            points,
            baseline_accuracy,
            sims,
            space,
            cost_source,
        })
    }
}

/// JSON string literal (the artifact only ever holds grammar strings,
/// but escape defensively).
fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// The `serve --auto` contract: cheapest front config meeting the
/// accuracy budget, with spec-mismatch and infeasible-budget errors
/// that say what *was* available.
pub fn auto_config(front: &ParetoFront, spec: &NetSpec, budget: f64)
                   -> Result<ReprMap> {
    let spec_str = spec.to_string();
    if front.spec() != spec_str {
        bail!("pareto front was explored on '{}' but the server is \
               configured for '{spec_str}'",
              front.spec());
    }
    if !(0.0..=1.0).contains(&budget) {
        bail!("accuracy budget {budget} outside [0, 1]");
    }
    match front.best_within(budget) {
        Some(p) => Ok(p.repr_map.clone()),
        None => {
            let best = front
                .points()
                .iter()
                .map(|p| p.accuracy)
                .fold(f64::NEG_INFINITY, f64::max);
            if best.is_finite() {
                bail!("no front point meets accuracy budget {budget} \
                       (best available: {best:.4})");
            }
            bail!("pareto front is empty; re-run explore");
        }
    }
}

// ---------------------------------------------------------------------
// label distillation (exact-surrogate test harness)
// ---------------------------------------------------------------------

/// Overwrite both splits' labels with the float32 model's own
/// predictions.  The float32 baseline accuracy then equals 1.0 exactly
/// and every quantized config's accuracy equals `1 - flip_fraction` —
/// which is precisely what [`SensitivityProfile`] measures, so on a
/// distilled dataset with calibration batch == eval subset the
/// surrogate is *exact*, not approximate.  Used by the tier-1 DSE
/// suite and the hermetic CI smoke flow.
pub fn distill_labels(model: &Model, ds: &mut Dataset, threads: usize) {
    let f32_cfg =
        ReprMap::uniform_for(model.spec(), ArithKind::Float32);
    let net = model.prepare(&f32_cfg);
    let relabel = |split: &Split| -> Vec<u8> {
        let mut labels = Vec::with_capacity(split.len());
        let mut at = 0;
        while at < split.len() {
            let hi = (at + 64).min(split.len());
            let idx: Vec<usize> = (at..hi).collect();
            let x = ds.batch(split, &idx);
            labels.extend(
                net.predict(&x, threads).into_iter().map(|p| p as u8),
            );
            at = hi;
        }
        labels
    };
    let train = relabel(&ds.train);
    let test = relabel(&ds.test);
    ds.train.labels = train;
    ds.test.labels = test;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::FixedPoint;

    fn fi(i: u32, f: u32) -> ArithKind {
        ArithKind::FixedExact(FixedPoint::new(i, f))
    }

    #[test]
    fn dominance_is_strict_and_projectable() {
        let a = [0.1, 10.0, 1.0];
        let b = [0.2, 10.0, 1.0];
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &a)); // ties never dominate
        // restricted to latency+hw the two are equal -> no dominance
        let lh = [Objective::Latency, Objective::HwCost];
        assert!(!dominates_on(&a, &b, &lh));
        assert!(proj_eq(&a, &b, &lh));
    }

    #[test]
    fn prune_keeps_exactly_the_front_and_dedupes() {
        let pts = vec![
            ("a", [0.0, 3.0, 1.0]),
            ("b", [0.1, 2.0, 1.0]),
            ("dup", [0.0, 3.0, 1.0]), // projected-equal to a
            ("dom", [0.2, 3.0, 2.0]), // dominated by b
        ];
        let kept = prune_nondominated(pts, &ALL_OBJECTIVES);
        let names: Vec<&str> = kept.iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["a", "b"]);
        // reference definition agrees
        let all = [[0.0, 3.0, 1.0], [0.1, 2.0, 1.0], [0.0, 3.0, 1.0],
                   [0.2, 3.0, 2.0]];
        assert_eq!(pareto_front_indices(&all), vec![0, 1, 2]);
    }

    #[test]
    fn cost_model_orders_kinds_by_width() {
        let spec = NetSpec::paper_dcnn();
        let cands = vec![vec![fi(4, 4), fi(4, 12)]; spec.len()];
        let cm = CostModel::analytic(&spec, &cands);
        assert_eq!(cm.source(), "analytic");
        // narrower fixed point -> faster clock -> lower ns/MAC
        assert!(cm.ns_per_mac(&fi(4, 4)) < cm.ns_per_mac(&fi(4, 12)));
        let narrow = ReprMap::uniform_for(&spec, fi(4, 4));
        let wide = ReprMap::uniform_for(&spec, fi(4, 12));
        assert!(cm.latency_ns(&narrow) < cm.latency_ns(&wide));
        assert!(cm.hw_cost(&narrow) < cm.hw_cost(&wide));
        // latency is additive over the per-layer terms
        let total: f64 = (0..spec.len())
            .map(|l| cm.layer_latency_ns(l, &fi(4, 4)))
            .sum();
        assert!((cm.latency_ns(&narrow) - total).abs() < 1e-9);
    }

    #[test]
    fn sensitivity_prediction_is_additive_and_clamped() {
        let spec = NetSpec::parse(
            "28x28x1: dense(16)+relu | dense(10)",
        )
        .unwrap();
        let p = SensitivityProfile::from_drops(vec![
            vec![(fi(4, 4), 0.3), (ArithKind::Float32, 0.0)],
            vec![(fi(4, 6), 0.2)],
        ]);
        let mut cfg =
            ReprMap::uniform_for(&spec, ArithKind::Float32);
        assert_eq!(p.predict(0.9, &cfg), 0.9);
        cfg.set(0, fi(4, 4));
        assert!((p.predict(0.9, &cfg) - 0.6).abs() < 1e-12);
        cfg.set(1, fi(4, 6));
        assert!((p.predict(0.9, &cfg) - 0.4).abs() < 1e-12);
        // drops larger than the baseline clamp at zero
        assert_eq!(p.predict(0.3, &cfg), 0.0);
    }

    #[test]
    fn surrogate_front_matches_reference_on_a_small_space() {
        let spec = NetSpec::parse(
            "28x28x1: dense(16)+relu | dense(10)",
        )
        .unwrap();
        let cands = vec![
            vec![ArithKind::Float32, fi(4, 4), fi(4, 8)],
            vec![ArithKind::Float32, fi(4, 6)],
        ];
        let profile = SensitivityProfile::from_drops(vec![
            vec![(fi(4, 4), 0.25), (fi(4, 8), 0.05)],
            vec![(fi(4, 6), 0.1)],
        ]);
        let cm = CostModel::analytic(&spec, &cands);
        let front = surrogate_front(&spec, &profile, &cm, &cands,
                                    &ALL_OBJECTIVES, 512);
        assert!(!front.is_empty());
        // reference: exhaustively score all 6 configs and prune
        let mut all = Vec::new();
        for &k0 in &cands[0] {
            for &k1 in &cands[1] {
                let cfg = ReprMap::from_kinds(vec![k0, k1]);
                all.push([
                    profile.drop_of(0, &k0) + profile.drop_of(1, &k1),
                    cm.latency_ns(&cfg),
                    cm.hw_cost(&cfg),
                ]);
            }
        }
        let reference = pareto_front_indices(&all);
        // every DP-front vector appears in the reference front and
        // vice versa (projection-dedupe may drop exact duplicates,
        // none exist here)
        assert_eq!(front.len(), reference.len());
        for (_, v) in &front {
            assert!(reference.iter().any(|&i| {
                (all[i][0] - v[0]).abs() < 1e-12
                    && (all[i][1] - v[1]).abs() < 1e-9
                    && (all[i][2] - v[2]).abs() < 1e-12
            }));
        }
    }

    #[test]
    fn front_json_round_trips_and_best_within_picks_cheapest() {
        let spec = NetSpec::parse(
            "28x28x1: dense(16)+relu | dense(10)",
        )
        .unwrap();
        let point = |kind, acc: f64, lat: f64, hw: f64, sim| {
            ParetoPoint {
                repr_map: ReprMap::uniform_for(&spec, kind),
                accuracy: acc,
                est_accuracy: acc,
                est_latency: lat,
                hw_cost: hw,
                simulated: sim,
            }
        };
        let front = ParetoFront::from_points(
            &spec,
            vec![
                point(fi(4, 8), 0.95, 200.0, 0.4, true),
                point(fi(4, 4), 0.80, 100.0, 0.2, false),
                point(ArithKind::Float32, 0.99, 900.0, 1.0, true),
            ],
            0.99,
            2,
            12,
            "analytic",
        );
        // sorted cheapest-hw first
        assert!(front.points()[0].hw_cost <= front.points()[1].hw_cost);
        let back = ParetoFront::from_json(&front.to_json()).unwrap();
        assert_eq!(back.points(), front.points());
        assert_eq!(back.spec(), front.spec());
        assert_eq!(back.sims(), 2);
        assert_eq!(back.space(), 12);
        assert_eq!(back.cost_source(), "analytic");
        assert_eq!(back.baseline_accuracy(), 0.99);
        // budget 0.9 -> FI(4, 8) (cheapest meeting it), not float32
        let best = front.best_within(0.9).unwrap();
        assert_eq!(best.repr_map.name(),
                   ReprMap::uniform_for(&spec, fi(4, 8)).name());
        // auto_config agrees and validates the spec
        let cfg = auto_config(&front, &spec, 0.9).unwrap();
        assert_eq!(cfg, best.repr_map);
        let other =
            NetSpec::parse("28x28x1: dense(10)").unwrap();
        assert!(auto_config(&front, &other, 0.9).is_err());
        assert!(auto_config(&front, &spec, 1.5).is_err());
        // budget nobody meets names the best available accuracy
        let e = auto_config(&front, &spec, 0.999).unwrap_err();
        assert!(format!("{e}").contains("best available"),
                "{e}");
    }

    #[test]
    fn from_json_rejects_malformed_artifacts() {
        assert!(ParetoFront::from_json("{}").is_err());
        assert!(ParetoFront::from_json("not json").is_err());
        let wrong_version = r#"{"artifact": "pareto_front",
            "version": 2, "spec": "28x28x1: dense(10)",
            "baseline_accuracy": 1, "sims": 0, "space": 1,
            "points": []}"#;
        assert!(ParetoFront::from_json(wrong_version).is_err());
        // a point with a bad config string errs with its index
        let bad_point = r#"{"artifact": "pareto_front",
            "version": 1, "spec": "28x28x1: dense(10)",
            "baseline_accuracy": 1, "sims": 0, "space": 1,
            "points": [{"config": "bogus", "accuracy": 1,
                        "est_accuracy": 1, "est_latency_ns": 1,
                        "hw_cost": 1, "simulated": false}]}"#;
        let e = ParetoFront::from_json(bad_point).unwrap_err();
        assert!(format!("{e}").contains("point 0"), "{e}");
    }
}
