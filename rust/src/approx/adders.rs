//! Approximate adders: lower-part-OR adder (LOA, Mahdiani et al.) — exact
//! ripple add on the high part, bitwise OR on the low `l` bits with a
//! carry-in generated from the AND of the low parts' MSBs.
//! Matches `bitref.loa_add`.

/// LOA: approximate `a + b` with an `l`-bit OR-ed lower part.
#[inline]
pub fn loa_add(a: u64, b: u64, l: u32) -> u64 {
    if l == 0 {
        return a + b;
    }
    let mask = (1u64 << l) - 1;
    let lo = (a & mask) | (b & mask);
    let cin = ((a >> (l - 1)) & 1) & ((b >> (l - 1)) & 1);
    let hi = (a >> l) + (b >> l) + cin;
    (hi << l) | lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn l_zero_is_exact() {
        prop::check(
            "loa(l=0) exact",
            81,
            prop::DEFAULT_CASES,
            |rng| (rng.below(1 << 30), rng.below(1 << 30)),
            |&(a, b)| loa_add(a, b, 0) == a + b,
        );
    }

    #[test]
    fn prop_error_bound() {
        prop::check(
            "loa error < 2^l",
            82,
            prop::DEFAULT_CASES,
            |rng| {
                let l = rng.below(13) as u32;
                (rng.below(1 << 20), rng.below(1 << 20), l)
            },
            |&(a, b, l)| loa_add(a, b, l).abs_diff(a + b) < (1u64 << l.max(1)),
        );
    }

    #[test]
    fn prop_add_zero_identity() {
        prop::check(
            "loa(a, 0) == a",
            83,
            prop::DEFAULT_CASES,
            |rng| (rng.below(1 << 24), rng.below(13) as u32),
            |&(a, l)| loa_add(a, 0, l) == a,
        );
    }

    #[test]
    fn known_values() {
        // low 3 bits OR: 0b101 | 0b011 = 0b111; high: 0 + 0 + (1&0)=0
        assert_eq!(loa_add(0b101, 0b011, 3), 0b111);
        // carry-in from MSB AND of low parts
        assert_eq!(loa_add(0b100, 0b100, 3), 0b100 | (1 << 3));
    }
}
