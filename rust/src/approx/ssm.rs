//! SSM: static segment multiplier (Narayanamoorthy, Moghaddam, Liu, Park
//! & Kim, TVLSI'15) — multiply only an n-bit segment of each w-bit
//! operand, the segment being one of two *static* positions (high when it
//! has any set bit, else low).  Cheaper selection logic than DRUM's
//! arbitrary-position LOD + barrel shifter, at a higher worst-case error.
//! Matches `bitref.ssm_mul`.

/// Segment select: (segment value, shift to restore weight).
/// Requires 2n >= w so the two static positions cover every operand
/// (the TVLSI'15 design point); narrower segments need the
/// multi-position variant.
#[inline]
pub fn ssm_segment(a: u64, w: u32, n: u32) -> (u64, u32) {
    debug_assert!(n > 0 && n <= w && 2 * n >= w
                  && (w == 64 || a < (1u64 << w)));
    let hi = a >> (w - n);
    if hi != 0 {
        (hi, w - n)
    } else {
        (a & ((1u64 << n) - 1), 0)
    }
}

/// SSM product of two w-bit unsigned integers with n-bit segments.
#[inline]
pub fn ssm_mul(a: u64, b: u64, w: u32, n: u32) -> u64 {
    let (sa, sha) = ssm_segment(a, w, n);
    let (sb, shb) = ssm_segment(b, w, n);
    (sa * sb) << (sha + shb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn small_operands_exact() {
        // both operands fit their low segment: product is exact
        for (a, b) in [(3u64, 5u64), (15, 15), (0, 9)] {
            assert_eq!(ssm_mul(a, b, 16, 8), a * b);
        }
    }

    #[test]
    fn known_segmentation() {
        // w=8, n=4: a=0b1011_0000 -> high segment 0b1011, shift 4
        assert_eq!(ssm_segment(0b1011_0000, 8, 4), (0b1011, 4));
        // a=0b0000_1011 -> low segment
        assert_eq!(ssm_segment(0b0000_1011, 8, 4), (0b1011, 0));
    }

    #[test]
    fn prop_error_bounded_by_segment_truncation() {
        // error comes only from dropped low bits below a high segment
        prop::check_msg(
            "ssm relative error < 2^-(n-2)",
            91,
            prop::DEFAULT_CASES,
            |rng| {
                let n = 8 + rng.below(9) as u32;
                let a = rng.below(1 << 16);
                let b = rng.below(1 << 16);
                (a, b, n)
            },
            |&(a, b, n)| {
                let exact = a * b;
                let approx = ssm_mul(a, b, 16, n);
                // each operand drops < 2^(w-n); error <= da*b + db*a
                let drop = 1u64 << (16 - n);
                if exact - approx <= drop * (a + b) {
                    Ok(())
                } else {
                    Err(format!("err {} > bound", exact - approx))
                }
            },
        );
    }

    #[test]
    fn prop_never_overestimates() {
        // segments drop bits, never add them
        prop::check(
            "ssm <= exact",
            92,
            prop::DEFAULT_CASES,
            |rng| (rng.below(1 << 20), rng.below(1 << 20)),
            |&(a, b)| ssm_mul(a, b, 20, 10) <= a * b,
        );
    }
}
