//! Mitchell's logarithmic multiplier (1962) — the classic log-domain
//! approximate multiplier the logarithmic-representation line of work
//! builds on.  log2(v) ≈ t + (v - 2^t)/2^t; the antilog uses the same
//! linear approximation.  Matches `bitref.mitchell_mul`.

use super::lod::bit_length;

/// Fixed-point log2 with `nfrac` fractional bits: (t << nfrac) | frac.
#[inline]
pub fn log2_fix(v: u64, nfrac: u32) -> u64 {
    debug_assert!(v > 0);
    let t = bit_length(v) - 1;
    let frac = ((v - (1u64 << t)) << nfrac) >> t;
    ((t as u64) << nfrac) | frac
}

/// Mitchell product of two unsigned integers.
///
/// Powers of two multiply exactly; otherwise the linear log/antilog
/// approximation underestimates by at most ~11.1%:
///
/// ```
/// use lop::approx::mitchell::mitchell_mul;
///
/// assert_eq!(mitchell_mul(64, 128, 16), 64 * 128); // powers of two
///
/// let (a, b) = (1000u64, 3000u64);
/// let approx = mitchell_mul(a, b, 16) as f64;
/// let exact = (a * b) as f64;
/// assert!(approx >= exact * 0.888 && approx <= exact * 1.001);
/// ```
#[inline]
pub fn mitchell_mul(a: u64, b: u64, nfrac: u32) -> u64 {
    if a == 0 || b == 0 {
        return 0;
    }
    let s = log2_fix(a, nfrac) + log2_fix(b, nfrac);
    let t = (s >> nfrac) as u32;
    let frac = s & ((1u64 << nfrac) - 1);
    if t >= nfrac {
        ((1u64 << nfrac) + frac) << (t - nfrac)
    } else {
        ((1u64 << nfrac) + frac) >> (nfrac - t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn powers_of_two_exact() {
        for ta in 0..12 {
            for tb in 0..12 {
                let (a, b) = (1u64 << ta, 1u64 << tb);
                assert_eq!(mitchell_mul(a, b, 16), a * b);
            }
        }
    }

    #[test]
    fn zero_product() {
        assert_eq!(mitchell_mul(0, 123, 16), 0);
        assert_eq!(mitchell_mul(123, 0, 16), 0);
    }

    #[test]
    fn prop_error_bound() {
        // Mitchell's well-known worst case: underestimates by at most
        // ~11.1%, never overestimates (beyond truncation noise).
        prop::check_msg(
            "mitchell within (-11.2%, +0.1%)",
            61,
            prop::DEFAULT_CASES,
            |rng| (1 + rng.below((1 << 16) - 1), 1 + rng.below((1 << 16) - 1)),
            |&(a, b)| {
                let exact = a * b;
                let approx = mitchell_mul(a, b, 16);
                let rel = (approx as f64 - exact as f64) / exact as f64;
                if (-0.112..=0.001).contains(&rel) {
                    Ok(())
                } else {
                    Err(format!("a={a} b={b} rel={rel}"))
                }
            },
        );
    }

    #[test]
    fn prop_monotone_in_magnitude() {
        prop::check(
            "mitchell roughly monotone (scaling one operand up)",
            62,
            prop::DEFAULT_CASES,
            |rng| (1 + rng.below(1 << 12), 1 + rng.below(1 << 12)),
            |&(a, b)| mitchell_mul(a * 2, b, 16) >= mitchell_mul(a, b, 16),
        );
    }
}
