//! DRUM(k): Dynamic Range Unbiased Multiplier (Hashemi, Bahar & Reda,
//! ICCAD'15) — the approximate multiplier behind the paper's H(i, f, t)
//! configurations (Table 2), generalized to arbitrary widths.
//!
//! Each operand keeps only the `k` bits at/below its leading one; the LSB
//! of the kept window is forced to 1 (the unbiasing trick that centers the
//! truncation error), everything below is zeroed.  The k x k product is
//! then exact.  Matches `bitref.drum_approx_operand` / `drum_mul`.

use super::lod::bit_length;
use crate::numeric::{FixedPoint, Representation};

/// DRUM operand conditioning.
#[inline]
pub fn drum_approx_operand(a: u64, k: u32) -> u64 {
    if a < (1u64 << k) {
        return a;
    }
    let t = bit_length(a) - 1; // leading-one position
    let sh = t - k + 1; // dropped low bits
    ((a >> sh) | 1) << sh
}

/// DRUM(k) product of two unsigned integers.
///
/// Each conditioned operand is within a factor (1 ± 2^-(k-1)) of its
/// true value, so the relative product error is bounded by roughly
/// 2^-(k-2):
///
/// ```
/// use lop::approx::drum::drum_mul;
///
/// let (a, b, k) = (1000u64, 3000u64, 6);
/// let exact = (a * b) as f64;
/// let rel = (drum_mul(a, b, k) as f64 - exact).abs() / exact;
/// assert!(rel <= 0.0625, "relative error {rel} above 2^-(k-2)");
/// // operands that fit k bits multiply exactly
/// assert_eq!(drum_mul(31, 63, 6), 31 * 63);
/// ```
#[inline]
pub fn drum_mul(a: u64, b: u64, k: u32) -> u64 {
    drum_approx_operand(a, k) * drum_approx_operand(b, k)
}

/// The H(i, f, t) multiplier: sign-magnitude FI operands, DRUM(t) on the
/// magnitude codes, product re-quantized into FI(i, f).
/// Matches `bitref.h_mul`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DrumMul {
    pub rep: FixedPoint,
    pub t: u32,
}

impl DrumMul {
    pub fn new(rep: FixedPoint, t: u32) -> Self {
        assert!(t >= 2, "DRUM needs k >= 2 (got {t})");
        DrumMul { rep, t }
    }

    pub fn name(&self) -> String {
        format!("H({}, {}, {})", self.rep.i_bits, self.rep.f_bits, self.t)
    }

    /// Multiply two reals through the H datapath.
    pub fn mul(&self, x: f32, y: f32) -> f32 {
        let ka = self.rep.code_of(x);
        let kb = self.rep.code_of(y);
        let prod = drum_mul(ka, kb, self.t); // 2f fractional bits
        let v = prod as f64 / exp2u(2 * self.rep.f_bits);
        let q = self.rep.quantize(v as f32);
        let neg = ((x < 0.0 && ka != 0) ^ (y < 0.0 && kb != 0)) && q != 0.0;
        if neg {
            -q
        } else {
            q
        }
    }

    /// The raw magnitude-code product with 2f fractional bits (used by the
    /// wide-accumulation GEMM path, which defers re-quantization).
    #[inline]
    pub fn mul_codes(&self, ka: u64, kb: u64) -> u64 {
        drum_mul(ka, kb, self.t)
    }
}

#[inline]
fn exp2u(n: u32) -> f64 {
    (1u64 << n) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn exact_below_threshold() {
        for k in [4u32, 8, 12] {
            for a in [0u64, 1, (1 << k) - 1] {
                assert_eq!(drum_approx_operand(a, k), a);
            }
        }
    }

    #[test]
    fn known_conditioning() {
        // a = 0b110110, k = 3: keep bits 5..3 -> 0b110, force bit 3 LSB=1
        // window is bits [5,4,3] = 110 -> set bit3 -> 111, shifted back
        assert_eq!(drum_approx_operand(0b110110, 3), 0b111000);
        assert_eq!(drum_approx_operand(0b100000, 3), 0b101000);
    }

    #[test]
    fn prop_error_bound() {
        prop::check(
            "drum relative error <= 2^-(k-2)",
            41,
            prop::DEFAULT_CASES,
            |rng| {
                let k = 2 + rng.below(18) as u32;
                let a = rng.next_u64() >> (34 + rng.below(20));
                let b = rng.next_u64() >> (34 + rng.below(20));
                (a, b, k)
            },
            |&(a, b, k)| {
                let exact = (a as u128) * (b as u128);
                let approx = drum_mul(a, b, k) as u128;
                if exact == 0 {
                    approx == 0
                } else {
                    // per-operand factor <= (1 + 2^-(k-1))
                    let f = 1.0 + (2.0f64).powi(-(k as i32 - 1));
                    let diff = exact.abs_diff(approx) as f64;
                    diff / exact as f64 <= f * f - 1.0 + 1e-12
                }
            },
        );
    }

    #[test]
    fn prop_commutative() {
        prop::check(
            "drum commutative",
            42,
            prop::DEFAULT_CASES,
            |rng| (rng.below(1 << 20), rng.below(1 << 20),
                   3 + rng.below(12) as u32),
            |&(a, b, k)| drum_mul(a, b, k) == drum_mul(b, a, k),
        );
    }

    #[test]
    fn h_mul_sign_and_zero() {
        let h = DrumMul::new(FixedPoint::new(6, 8), 12);
        assert_eq!(h.mul(0.0, 3.0), 0.0);
        assert_eq!(h.mul(3.0, 0.0), 0.0);
        let p = h.mul(1.5, 2.0);
        assert!(p > 0.0);
        assert_eq!(h.mul(-1.5, 2.0), -p);
        assert_eq!(h.mul(1.5, -2.0), -p);
        assert_eq!(h.mul(-1.5, -2.0), p);
    }

    #[test]
    fn h_mul_small_operands_exact() {
        // both magnitudes below 2^t: DRUM passes through, product exact
        let h = DrumMul::new(FixedPoint::new(6, 8), 14);
        let (x, y) = (0.25f32, 0.5f32);
        assert_eq!(h.mul(x, y), 0.125);
    }

    #[test]
    fn name_matches_paper_notation() {
        let h = DrumMul::new(FixedPoint::new(8, 8), 14);
        assert_eq!(h.name(), "H(8, 8, 14)");
    }
}
