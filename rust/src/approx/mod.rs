//! Approximate arithmetic operations (paper §4.1.3), generalized to
//! arbitrary bit-widths as the paper requires.
//!
//! Every unit is bit-identical to its reference in
//! `python/compile/bitref.py`; `rust/tests/golden_vectors.rs` enforces
//! this against Python-generated vectors.

pub mod adders;
pub mod arith;
pub mod cfpu;
pub mod drum;
pub mod lod;
pub mod mitchell;
pub mod ssm;
pub mod truncated;

pub use arith::{Arith, ArithKind};
pub use cfpu::CfpuMul;
pub use drum::DrumMul;
