//! Truncation-based multiplier (after Chang & Satzoda, TVLSI'10),
//! generalized to arbitrary width: an n x n array multiplier that drops
//! partial-product columns below column `n - keep` and adds a constant
//! compensation of half the expected dropped weight.
//! Matches `bitref.truncated_mul`.

/// n x n unsigned multiply keeping the top `keep` partial-product columns.
pub fn truncated_mul(a: u64, b: u64, n: u32, keep: u32) -> u64 {
    debug_assert!(n <= 32 && a < (1u64 << n) && b < (1u64 << n));
    if keep >= n {
        return a * b;
    }
    let cut = n - keep;
    let mut acc = 0u64;
    for j in 0..n {
        if (b >> j) & 1 == 1 {
            let pp = a << j;
            acc += (pp >> cut) << cut;
        }
    }
    let comp = if cut >= 1 { 1u64 << (cut - 1) } else { 0 };
    acc + comp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn keep_all_is_exact() {
        prop::check(
            "truncated(n, n) == exact",
            71,
            prop::DEFAULT_CASES,
            |rng| (rng.below(1 << 16), rng.below(1 << 16)),
            |&(a, b)| truncated_mul(a, b, 16, 16) == a * b,
        );
    }

    #[test]
    fn prop_bounded_error() {
        prop::check_msg(
            "truncated error <= n * 2^cut",
            72,
            prop::DEFAULT_CASES,
            |rng| {
                let keep = 1 + rng.below(15) as u32;
                (rng.below(1 << 16), rng.below(1 << 16), keep)
            },
            |&(a, b, keep)| {
                let exact = a * b;
                let approx = truncated_mul(a, b, 16, keep);
                let bound = 16u64 << (16 - keep);
                if exact.abs_diff(approx) <= bound {
                    Ok(())
                } else {
                    Err(format!("diff {} > {bound}", exact.abs_diff(approx)))
                }
            },
        );
    }

    #[test]
    fn zero_operand() {
        // only the compensation constant remains
        assert_eq!(truncated_mul(0, 0, 16, 8), 1 << 7);
        assert_eq!(truncated_mul(0, 0, 16, 16), 0);
    }
}
