//! Leading-one detector (LOD) / priority encoder — the building block of
//! dynamic-range approximate multipliers like DRUM (and the hardware cost
//! driver the paper calls out for [21]: "leading-one detector and barrel
//! shifter").

/// Position of the leading one (0-based from the LSB); `None` for 0.
#[inline]
pub fn leading_one(a: u64) -> Option<u32> {
    if a == 0 {
        None
    } else {
        Some(63 - a.leading_zeros())
    }
}

/// Bit length: number of bits needed to represent `a` (0 -> 0).
#[inline]
pub fn bit_length(a: u64) -> u32 {
    64 - a.leading_zeros()
}

/// One-hot mask of the leading one (hardware LOD output); 0 for 0.
#[inline]
pub fn lod_mask(a: u64) -> u64 {
    match leading_one(a) {
        Some(t) => 1u64 << t,
        None => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn known_values() {
        assert_eq!(leading_one(0), None);
        assert_eq!(leading_one(1), Some(0));
        assert_eq!(leading_one(0b1000_0000), Some(7));
        assert_eq!(leading_one(u64::MAX), Some(63));
        assert_eq!(bit_length(0), 0);
        assert_eq!(bit_length(255), 8);
        assert_eq!(lod_mask(0b0110), 0b0100);
    }

    #[test]
    fn prop_mask_dominates() {
        prop::check(
            "lod mask <= a < 2*mask",
            31,
            prop::DEFAULT_CASES,
            |rng| rng.next_u64() >> rng.below(64),
            |&a| {
                if a == 0 {
                    lod_mask(a) == 0
                } else {
                    let m = lod_mask(a);
                    m <= a && a < m.saturating_mul(2).max(m)
                }
            },
        );
    }
}
