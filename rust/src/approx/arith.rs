//! Arithmetic providers: a (data representation, multiplier) pairing — the
//! paper's notion of a *domain* choice ("within each domain the choice of
//! data representation and exact vs. approximate arithmetic operation is
//! fixed", §3).  One provider is attached per partition part (per layer in
//! layer-wise optimization).
//!
//! The scalar semantics live here; the packed, tiled GEMM kernels that
//! the NN engine actually runs are under `nn/gemm/` (one monomorphized
//! microkernel per provider kind — no dispatch inside MAC loops).

use super::cfpu::CfpuMul;
use super::drum::DrumMul;
use crate::numeric::{BinXnor, FixedPoint, FloatRep, Representation};

/// All supported (representation × arithmetic) pairings (paper Table 2
/// plus the baseline and the §4.5 extension).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArithKind {
    /// float32 baseline (exact IEEE mul/add).
    Float32,
    /// FI(i, f) with exact multiply, wide accumulation.
    FixedExact(FixedPoint),
    /// H(i, f, t): FI(i, f) with the DRUM(t) approximate multiplier.
    FixedDrum(DrumMul),
    /// FL(e, m) with exact multiply, wide accumulation.
    FloatExact(FloatRep),
    /// I(e, m): FL(e, m) with the CFPU(w) approximate multiplier.
    FloatCfpu(CfpuMul),
    /// Binary 0/1 representation with XNOR multiply (paper §4.5).
    Binary,
}

impl ArithKind {
    /// Paper notation, e.g. `FI(6, 8)`, `H(6, 8, 12)`, `FL(4, 9)`,
    /// `I(5, 10)`, `float32`, `BinXNOR`.
    pub fn name(&self) -> String {
        match self {
            ArithKind::Float32 => "float32".to_string(),
            ArithKind::FixedExact(r) => r.name(),
            ArithKind::FixedDrum(d) => d.name(),
            ArithKind::FloatExact(r) => r.name(),
            ArithKind::FloatCfpu(c) => c.name(),
            ArithKind::Binary => "BinXNOR".to_string(),
        }
    }

    /// Storage bits per value (used by the hardware cost model).
    pub fn total_bits(&self) -> u32 {
        match self {
            ArithKind::Float32 => 32,
            ArithKind::FixedExact(r) => r.total_bits(),
            ArithKind::FixedDrum(d) => d.rep.total_bits(),
            ArithKind::FloatExact(r) => r.total_bits(),
            ArithKind::FloatCfpu(c) => c.rep.total_bits(),
            ArithKind::Binary => 1,
        }
    }

    /// True when the PJRT fake-quant path computes this config exactly
    /// (exact multipliers only; approximate multipliers need the
    /// bit-accurate engine).
    pub fn pjrt_expressible(&self) -> bool {
        matches!(
            self,
            ArithKind::Float32
                | ArithKind::FixedExact(_)
                | ArithKind::FloatExact(_)
        )
    }

    /// Snap a value onto the provider's representation lattice.
    pub fn quantize(&self, x: f32) -> f32 {
        match self {
            ArithKind::Float32 => x,
            ArithKind::FixedExact(r) => r.quantize(x),
            ArithKind::FixedDrum(d) => d.rep.quantize(x),
            ArithKind::FloatExact(r) => r.quantize(x),
            ArithKind::FloatCfpu(c) => c.rep.quantize(x),
            ArithKind::Binary => BinXnor.quantize(x),
        }
    }

    /// Scalar multiply through the provider's datapath (operands are
    /// quantized internally where the unit requires it).
    pub fn mul(&self, a: f32, b: f32) -> f32 {
        match self {
            ArithKind::Float32 => a * b,
            ArithKind::FixedExact(r) => {
                // exact product of two FI values carried at 2f fractional
                // bits (no intermediate re-quantization)
                let pa = r.quantize(a) as f64;
                let pb = r.quantize(b) as f64;
                (pa * pb) as f32
            }
            ArithKind::FixedDrum(d) => d.mul(a, b),
            ArithKind::FloatExact(r) => {
                let pa = r.quantize(a) as f64;
                let pb = r.quantize(b) as f64;
                (pa * pb) as f32
            }
            ArithKind::FloatCfpu(c) => c.mul(a, b),
            ArithKind::Binary => {
                BinXnor.quantize(a) * BinXnor.quantize(b)
            }
        }
    }

    /// The MAC-array product fed to the *wide* accumulator: the full-width
    /// product before any re-quantization (the paper widens the
    /// integral-bit BCI so partial sums never need narrowing, §4.2).
    /// This is the semantics the GEMM kernels under `nn/gemm/` implement;
    /// [`ArithKind::mul`] by contrast models the standalone scalar unit,
    /// whose output register is in the representation (it re-quantizes).
    pub fn mul_wide(&self, a: f32, b: f32) -> f64 {
        match self {
            ArithKind::Float32 => (a * b) as f64,
            ArithKind::FixedExact(r) => {
                r.quantize(a) as f64 * r.quantize(b) as f64
            }
            ArithKind::FixedDrum(d) => {
                let ka = d.rep.code_of(a);
                let kb = d.rep.code_of(b);
                let p = d.mul_codes(ka, kb) as f64
                    / (1u64 << (2 * d.rep.f_bits)) as f64;
                let neg = (a < 0.0 && ka != 0) ^ (b < 0.0 && kb != 0);
                if neg {
                    -p
                } else {
                    p
                }
            }
            ArithKind::FloatExact(r) => {
                r.quantize(a) as f64 * r.quantize(b) as f64
            }
            ArithKind::FloatCfpu(c) => c.mul(a, b) as f64,
            ArithKind::Binary => {
                (BinXnor.quantize(a) * BinXnor.quantize(b)) as f64
            }
        }
    }

    /// Parse paper notation: `f32` | `float32` | `FI(i,f)` | `H(i,f,t)` |
    /// `FL(e,m)` | `I(e,m)` | `I(e,m,w)` | `binxnor`.
    pub fn parse(s: &str) -> Result<ArithKind, String> {
        let t = s.trim();
        let lower = t.to_ascii_lowercase();
        if lower == "f32" || lower == "float32" {
            return Ok(ArithKind::Float32);
        }
        if lower == "binxnor" || lower == "binary" {
            return Ok(ArithKind::Binary);
        }
        let (head, args) = t
            .split_once('(')
            .ok_or_else(|| format!("cannot parse arith '{s}'"))?;
        let args = args
            .strip_suffix(')')
            .ok_or_else(|| format!("missing ')' in '{s}'"))?;
        let nums: Result<Vec<u32>, _> = args
            .split(',')
            .map(|a| a.trim().parse::<u32>())
            .collect();
        let nums = nums.map_err(|e| format!("bad number in '{s}': {e}"))?;
        match (head.trim().to_ascii_uppercase().as_str(), nums.as_slice()) {
            ("FI", [i, f]) => Ok(ArithKind::FixedExact(FixedPoint::new(*i, *f))),
            ("H", [i, f, t]) => Ok(ArithKind::FixedDrum(DrumMul::new(
                FixedPoint::new(*i, *f),
                *t,
            ))),
            ("FL", [e, m]) => Ok(ArithKind::FloatExact(FloatRep::new(*e, *m))),
            // paper writes I(e, m); the CFPU tuning width defaults to 3
            ("I", [e, m]) => Ok(ArithKind::FloatCfpu(CfpuMul::new(
                FloatRep::new(*e, *m),
                3,
            ))),
            ("I", [e, m, w]) => Ok(ArithKind::FloatCfpu(CfpuMul::new(
                FloatRep::new(*e, *m),
                *w,
            ))),
            _ => Err(format!("unknown arith notation '{s}'")),
        }
    }
}

/// Object-safe alias used by code that holds heterogeneous providers.
pub trait Arith: Send + Sync {
    fn kind(&self) -> ArithKind;
}

impl Arith for ArithKind {
    fn kind(&self) -> ArithKind {
        *self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        // incl. a non-default CFPU tuning width: name() must spell it
        // out so the round-trip reconstructs the exact unit
        for s in ["float32", "FI(6, 8)", "H(6, 8, 12)", "FL(4, 9)",
                  "I(5, 10)", "I(4, 9, 2)", "BinXNOR"] {
            let k = ArithKind::parse(s).unwrap();
            assert_eq!(ArithKind::parse(&k.name()).unwrap(), k);
            assert_eq!(k.name(), *s, "name() is canonical");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ArithKind::parse("FI(6)").is_err());
        assert!(ArithKind::parse("XX(1,2)").is_err());
        assert!(ArithKind::parse("FI(6,8").is_err());
        assert!(ArithKind::parse("").is_err());
    }

    #[test]
    fn bits_accounting() {
        assert_eq!(ArithKind::parse("FI(6,8)").unwrap().total_bits(), 15);
        assert_eq!(ArithKind::parse("FL(4,9)").unwrap().total_bits(), 14);
        assert_eq!(ArithKind::Float32.total_bits(), 32);
        assert_eq!(ArithKind::Binary.total_bits(), 1);
    }

    #[test]
    fn pjrt_expressibility() {
        assert!(ArithKind::parse("FI(6,8)").unwrap().pjrt_expressible());
        assert!(ArithKind::parse("FL(4,9)").unwrap().pjrt_expressible());
        assert!(!ArithKind::parse("H(6,8,12)").unwrap().pjrt_expressible());
        assert!(!ArithKind::parse("I(5,10)").unwrap().pjrt_expressible());
    }

    #[test]
    fn scalar_mul_kinds() {
        let fi = ArithKind::parse("FI(6,8)").unwrap();
        assert_eq!(fi.mul(0.5, 0.25), 0.125);
        let f32k = ArithKind::Float32;
        assert_eq!(f32k.mul(0.3, 0.3), 0.3f32 * 0.3f32);
        let bin = ArithKind::Binary;
        assert_eq!(bin.mul(2.0, -3.0), -1.0);
        assert_eq!(bin.mul(-2.0, -3.0), 1.0);
    }
}
