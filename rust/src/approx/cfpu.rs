//! CFPU: Configurable Floating-Point Unit multiplier (Imani, Peroni &
//! Rosing, DAC'17) — the approximate multiplier behind the paper's
//! I(e, m) configurations (Table 2).
//!
//! The mantissa multiplier is *skipped* when one operand's mantissa is
//! close to a power of two: if the top `w` mantissa bits are all 0 the
//! product is approximated by the other operand with exponents added; if
//! all 1, the same with an exponent increment.  Otherwise it falls back to
//! the exact (rounded) multiply.  `w` is the configurability knob trading
//! error for how often the expensive exact path runs.  The realization is
//! multiplier-free when the fallback is disabled in hardware; the cost
//! model (`hw/`) accounts for both.  Matches `bitref.cfpu_mul`.

use crate::numeric::{FloatRep, Representation};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CfpuMul {
    pub rep: FloatRep,
    pub w: u32,
}

impl CfpuMul {
    pub fn new(rep: FloatRep, w: u32) -> Self {
        assert!(w >= 1, "CFPU tuning width must be >= 1");
        CfpuMul { rep, w }
    }

    /// Paper notation.  The tuning width is part of the name whenever
    /// it differs from the paper's default of 3, so
    /// `ArithKind::parse(name())` always reconstructs this exact unit
    /// (the round-trip `rust/tests/config_roundtrip.rs` pins).
    pub fn name(&self) -> String {
        if self.w == 3 {
            format!("I({}, {})", self.rep.e_bits, self.rep.m_bits)
        } else {
            format!("I({}, {}, {})", self.rep.e_bits, self.rep.m_bits,
                    self.w)
        }
    }

    /// Saturate/flush a positive product magnitude into the representation
    /// (approximate path only scales by powers of two, so no re-rounding).
    fn clamp(&self, y: f64) -> f64 {
        let mx = self.rep.max_finite();
        if y > mx {
            return mx;
        }
        let mn = self.rep.min_normal();
        if y < mn {
            return if y * 2.0 >= mn { mn } else { 0.0 };
        }
        y
    }

    pub fn mul(&self, x: f32, y: f32) -> f32 {
        self.mul_bits(self.rep.encode(x), self.rep.encode(y))
    }

    /// Multiply two already-encoded FL(e, m) bit patterns (the GEMM hot
    /// path pre-encodes operands once instead of per MAC).
    pub fn mul_bits(&self, bx: u64, by: u64) -> f32 {
        let (e, m) = (self.rep.e_bits, self.rep.m_bits);
        let man_mask = (1u64 << m) - 1;
        let fx = (bx >> m) & ((1u64 << e) - 1);
        let fy = (by >> m) & ((1u64 << e) - 1);
        if fx == 0 || fy == 0 {
            return 0.0;
        }
        let (mx, my) = (bx & man_mask, by & man_mask);
        let sx = (bx >> (e + m)) & 1;
        let sy = (by >> (e + m)) & 1;
        let sign = if (sx ^ sy) == 1 { -1.0 } else { 1.0 };
        let bias = self.rep.bias() as i64;
        let top = (1u64 << self.w) - 1;

        let approx = |keep_field: u64, keep_man: u64, drop_field: u64,
                      round_up: bool| -> f32 {
            let eu = (keep_field as i64 - bias) + (drop_field as i64 - bias)
                + i64::from(round_up);
            let sig = 1.0 + keep_man as f64 / (1u64 << m) as f64;
            let val = sig * pow2(eu as i32);
            (sign * self.clamp(val)) as f32
        };

        if self.w <= m {
            let ytop = (my >> (m - self.w)) & top;
            if ytop == 0 {
                return approx(fx, mx, fy, false);
            }
            if ytop == top {
                return approx(fx, mx, fy, true);
            }
            let xtop = (mx >> (m - self.w)) & top;
            if xtop == 0 {
                return approx(fy, my, fx, false);
            }
            if xtop == top {
                return approx(fy, my, fx, true);
            }
        }
        // exact fallback: multiply the decoded values, round to FL(e, m)
        let xv = self.rep.decode(bx) as f64;
        let yv = self.rep.decode(by) as f64;
        self.rep.quantize_f64(xv * yv) as f32
    }
}

#[inline]
fn pow2(n: i32) -> f64 {
    // n stays within [-2*bias-1, 2*emax+1] ⊆ [-255, 257] for e <= 8
    f64::from_bits(((n + 1023) as u64) << 52)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn exact(rep: FloatRep, x: f32, y: f32) -> f32 {
        let xq = rep.quantize(x) as f64;
        let yq = rep.quantize(y) as f64;
        rep.quantize_f64(xq * yq) as f32
    }

    #[test]
    fn power_of_two_operand_exact() {
        let c = CfpuMul::new(FloatRep::new(4, 9), 3);
        for p in [0.25f32, 0.5, 1.0, 2.0, 4.0, 64.0] {
            for x in [1.3f32, -2.7, 0.11, 9.9] {
                let xq = c.rep.quantize(x);
                assert_eq!(c.mul(xq, p), exact(c.rep, xq, p),
                           "x={xq} p={p}");
            }
        }
    }

    #[test]
    fn zero_operands() {
        let c = CfpuMul::new(FloatRep::new(4, 9), 3);
        assert_eq!(c.mul(0.0, 5.0), 0.0);
        assert_eq!(c.mul(5.0, 0.0), 0.0);
    }

    #[test]
    fn prop_sign_correct() {
        prop::check(
            "cfpu sign follows operand signs",
            51,
            prop::DEFAULT_CASES,
            |rng| ((rng.normal() * 10.0) as f32, (rng.normal() * 10.0) as f32),
            |&(x, y)| {
                let c = CfpuMul::new(FloatRep::new(4, 9), 3);
                let p = c.mul(x, y);
                p == 0.0 || (p > 0.0) == ((x > 0.0) == (y > 0.0))
            },
        );
    }

    #[test]
    fn prop_error_bound() {
        prop::check_msg(
            "cfpu relative error <= 2^-w + 2^-(m-1)",
            52,
            prop::DEFAULT_CASES,
            |rng| {
                let w = 1 + rng.below(4) as u32;
                let x = rng.range_f32(0.1, 10.0);
                let y = rng.range_f32(0.1, 10.0);
                (w, x, y)
            },
            |&(w, x, y)| {
                let rep = FloatRep::new(5, 10);
                let c = CfpuMul::new(rep, w);
                let got = c.mul(x, y) as f64;
                let want = exact(rep, x, y) as f64;
                if want == 0.0 {
                    return Ok(());
                }
                let rel = (got - want).abs() / want.abs();
                let bound = (2.0f64).powi(-(w as i32))
                    + (2.0f64).powi(-(rep.m_bits as i32 - 1));
                if rel <= bound {
                    Ok(())
                } else {
                    Err(format!("rel={rel} > bound={bound}"))
                }
            },
        );
    }

    #[test]
    fn large_w_falls_back_to_exact() {
        let rep = FloatRep::new(4, 9);
        let c = CfpuMul::new(rep, 10); // w > m: check can never pass
        let mut rng = crate::util::prng::Rng::new(7);
        for _ in 0..300 {
            let x = (rng.normal() * 5.0) as f32;
            let y = (rng.normal() * 5.0) as f32;
            assert_eq!(c.mul(x, y), exact(rep, x, y));
        }
    }

    #[test]
    fn name_matches_paper_notation() {
        assert_eq!(CfpuMul::new(FloatRep::new(5, 10), 3).name(), "I(5, 10)");
    }
}
