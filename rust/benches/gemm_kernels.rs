//! GEMM kernel microbenchmarks — the L3 hot path the §Perf pass
//! iterates on.  For every arithmetic provider this runs, on the
//! network's real layer shapes, **once per benched ISA tier**:
//!
//! * the packed, tiled kernel with weights re-packed per call
//!   (`GemmPlan::run` — the pre-prepack serving cost),
//! * the same kernel on prepacked weight panels
//!   (`GemmPlan::run_prepacked` — what `PreparedNet::forward` runs
//!   after `prepare`), and
//! * the pre-tiling `reference` kernel (the oracle), and
//! * the prepacked kernel with a **fused bias+ReLU epilogue**
//!   (`run_prepacked_with`) vs the same GEMM followed by the two
//!   standalone `vecmath` passes — the `dense+relu` layer both ways
//!   (the §Perf iteration-11 win: the epilogue touches each output
//!   tile while it is still cache-resident),
//!
//! reporting M MAC/s, the packed : reference speedup, the
//! prepacked : per-call-repack speedup (the §Perf iteration-7 win; it
//! is largest at batch 1, where weight packing dominates), and the
//! fused : unfused epilogue speedup (`fused_speedup` in the JSON —
//! CI's bench gate requires it present and positive).
//!
//! The ISA axis (§Perf iteration 9): with `LOP_FORCE_ISA` set, only
//! that tier is benched (kernels are pinned process-wide anyway);
//! unforced, every tier in `isa::detected()` runs, so one invocation
//! on an AVX2 machine produces a scalar series *and* an avx2 series
//! per case.  Each JSON row carries `"isa"` and the resolved kernel
//! name, so CI can diff tiers and sanity-check that every benched ISA
//! produced a series.  The whole table is written as JSON
//! (`BENCH_gemm_kernels.json`, or `$LOP_BENCH_JSON`).

use lop::approx::arith::ArithKind;
use lop::nn::gemm::reference::gemm_reference;
use lop::nn::gemm::{isa, Epilogue, GemmPlan, Isa};
use lop::nn::vecmath;
use lop::util::bench::{bench, header, write_bench_json};
use lop::util::prng::Rng;

struct Row {
    shape: String,
    kind: String,
    isa: Isa,
    kernel: &'static str,
    threads: usize,
    packed_ns: f64,
    prepacked_ns: f64,
    // bucketed percentiles of the prepacked (serving-path) series,
    // from the shared telemetry histogram inside BenchResult
    prepacked_p50_ns: u64,
    prepacked_p99_ns: u64,
    reference_ns: f64,
    fused_ns: f64,
    unfused_ns: f64,
    mmacs_packed: f64,
    mmacs_prepacked: f64,
    mmacs_reference: f64,
}

/// The ISA tiers this bench run covers: the forced tier only when
/// `LOP_FORCE_ISA` pins the process, else every detected tier.
fn benched_isas() -> Vec<Isa> {
    match std::env::var(isa::FORCE_ENV) {
        Ok(s) if !s.trim().is_empty() => vec![isa::active()],
        _ => isa::detected(),
    }
}

fn mats(m: usize, k: usize, n: usize, kind: &ArithKind)
        -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..m * k).map(|_| (rng.normal() * 2.0) as f32)
        .collect();
    let w: Vec<f32> = (0..k * n)
        .map(|_| kind.quantize(rng.normal() as f32))
        .collect();
    (x, w, vec![0.0; m * n])
}

fn run_shape(label: &str, tier: Isa, m: usize, k: usize, n: usize,
             iters: usize, kinds: &[(&str, usize)],
             rows: &mut Vec<Row>) {
    println!("\n--- {label} @ {tier}: [{m} x {k}] @ [{k} x {n}] ---");
    header();
    let macs = (m * k * n) as f64;
    for (ks, threads) in kinds {
        let kind = ArithKind::parse(ks).unwrap();
        let mut plan = GemmPlan::with_isa(&kind, tier);
        let (x, w, mut out) = mats(m, k, n, &kind);
        let rp = bench(
            &format!("{ks}@{tier} repack/call (threads={threads})"),
            1,
            iters,
            || {
                plan.run(&x, &w, m, k, n, &mut out, *threads);
                std::hint::black_box(&out);
            },
        );
        // condition the weight panels once, then serve from the cache —
        // the PreparedNet::forward path after `prepare`
        plan.prepack(&w, k, n);
        let rq = bench(
            &format!("{ks}@{tier} prepacked (threads={threads})"),
            1,
            iters,
            || {
                plan.run_prepacked(&x, m, &mut out, *threads);
                std::hint::black_box(&out);
            },
        );
        let rr = bench(
            &format!("{ks}@{tier} reference (threads={threads})"),
            1,
            iters,
            || {
                gemm_reference(&kind, &x, &w, m, k, n, &mut out,
                               *threads);
                std::hint::black_box(&out);
            },
        );
        // the fused-epilogue series: bias + ReLU applied per
        // cache-resident output tile vs as two standalone vecmath
        // passes over the finished (cold again) output — the
        // `dense+relu` layer both ways
        let bias: Vec<f32> =
            (0..n).map(|j| ((j % 7) as f32 - 3.0) * 0.05).collect();
        let ep = Epilogue::BiasRelu { bias: &bias };
        let rf = bench(
            &format!("{ks}@{tier} fused bias+relu (threads={threads})"),
            1,
            iters,
            || {
                plan.run_prepacked_with(&x, m, &mut out, *threads,
                                        &ep);
                std::hint::black_box(&out);
            },
        );
        let ru = bench(
            &format!("{ks}@{tier} unfused bias+relu \
                      (threads={threads})"),
            1,
            iters,
            || {
                plan.run_prepacked(&x, m, &mut out, *threads);
                vecmath::add_bias_in_place(&mut out, &bias);
                vecmath::relu_in_place(&mut out);
                std::hint::black_box(&out);
            },
        );
        let mm_p = macs / (rp.mean_ns() / 1e9) / 1e6;
        let mm_q = macs / (rq.mean_ns() / 1e9) / 1e6;
        let mm_r = macs / (rr.mean_ns() / 1e9) / 1e6;
        println!("{}  -> {:.0} M MAC/s", rp.summary(), mm_p);
        println!("{}  -> {:.0} M MAC/s  (vs repack/call {:.2}x)",
                 rq.summary(), mm_q,
                 rp.mean_ns() / rq.mean_ns().max(1.0));
        println!("{}  -> {:.0} M MAC/s  (packed {:.2}x)",
                 rr.summary(), mm_r,
                 rr.mean_ns() / rp.mean_ns().max(1.0));
        println!("{}  (fused vs unfused {:.2}x)",
                 rf.summary(),
                 ru.mean_ns() / rf.mean_ns().max(1.0));
        rows.push(Row {
            shape: label.to_string(),
            kind: ks.to_string(),
            isa: tier,
            kernel: plan.kernel_name(),
            threads: *threads,
            packed_ns: rp.mean_ns(),
            prepacked_ns: rq.mean_ns(),
            prepacked_p50_ns: rq.percentile_ns(50.0),
            prepacked_p99_ns: rq.percentile_ns(99.0),
            reference_ns: rr.mean_ns(),
            fused_ns: rf.mean_ns(),
            unfused_ns: ru.mean_ns(),
            mmacs_packed: mm_p,
            mmacs_prepacked: mm_q,
            mmacs_reference: mm_r,
        });
    }
}

fn write_json(rows: &[Row]) {
    let bodies: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "\"shape\": \"{}\", \"kind\": \"{}\", \"isa\": \
                 \"{}\", \"kernel\": \"{}\", \"threads\": {}, \
                 \"packed_mean_ns\": {:.0}, \"prepacked_mean_ns\": \
                 {:.0}, \"prepacked_p50_ns\": {}, \
                 \"prepacked_p99_ns\": {}, \
                 \"reference_mean_ns\": {:.0}, \
                 \"packed_mmacs\": {:.1}, \"prepacked_mmacs\": {:.1}, \
                 \"reference_mmacs\": {:.1}, \"fused_mean_ns\": {:.0}, \
                 \"unfused_mean_ns\": {:.0}, \"speedup\": {:.3}, \
                 \"prepack_speedup\": {:.3}, \"fused_speedup\": {:.3}",
                r.shape,
                r.kind,
                r.isa,
                r.kernel,
                r.threads,
                r.packed_ns,
                r.prepacked_ns,
                r.prepacked_p50_ns,
                r.prepacked_p99_ns,
                r.reference_ns,
                r.mmacs_packed,
                r.mmacs_prepacked,
                r.mmacs_reference,
                r.fused_ns,
                r.unfused_ns,
                r.reference_ns / r.packed_ns.max(1.0),
                r.packed_ns / r.prepacked_ns.max(1.0),
                r.unfused_ns / r.fused_ns.max(1.0)
            )
        })
        .collect();
    write_bench_json("gemm_kernels", "LOP_BENCH_JSON",
                     "BENCH_gemm_kernels.json", &bodies);
}

fn main() {
    let tiers = benched_isas();
    let names: Vec<&str> = tiers.iter().map(|t| t.name()).collect();
    println!("=== GEMM kernels: prepacked vs repack/call vs reference, \
              M MAC/s ===");
    println!("ISAs benched: {}", names.join(", "));
    let mut rows = Vec::new();

    for &tier in &tiers {
        // FC1 shape (the network's dominant GEMM): batch 64 — all six
        // provider variants, single- and all-core
        run_shape(
            "FC1, batch 64",
            tier,
            64,
            3136,
            1024,
            5,
            &[
                ("float32", 1),
                ("float32", 0),
                ("FI(6,8)", 1),
                ("FI(6,8)", 0),
                ("H(6,8,12)", 0),
                ("FL(4,9)", 0),
                ("binxnor", 0),
            ],
            &mut rows,
        );

        // FC1 at batch 1: the serving case where per-call weight
        // packing (O(kn)) dominates the O(mkn) MACs — the prepack win
        // shows here
        run_shape(
            "FC1, batch 1",
            tier,
            1,
            3136,
            1024,
            20,
            &[
                ("float32", 1),
                ("FI(6,8)", 1),
                ("H(6,8,12)", 1),
                ("FL(4,9)", 1),
                ("I(5,10)", 1),
                ("binxnor", 1),
            ],
            &mut rows,
        );

        // CFPU is the expensive provider: smaller shape, same layout
        run_shape(
            "FC-small (CFPU-viable)",
            tier,
            64,
            784,
            256,
            5,
            &[("I(5,10)", 1), ("I(5,10)", 0), ("FL(5,10)", 0)],
            &mut rows,
        );

        // CONV2 as im2col: [batch*14*14, 800] @ [800, 64]
        run_shape(
            "CONV2 im2col, batch 16",
            tier,
            16 * 196,
            800,
            64,
            5,
            &[("float32", 0), ("FI(6,8)", 0), ("H(6,8,12)", 0)],
            &mut rows,
        );
    }

    write_json(&rows);
}
