//! GEMM kernel microbenchmarks — the L3 hot path the §Perf pass iterates
//! on.  Reports per-provider throughput in M MAC/s on the network's real
//! layer shapes.

use lop::approx::arith::ArithKind;
use lop::nn::gemm::gemm;
use lop::util::bench::{bench, header};
use lop::util::prng::Rng;

fn mats(m: usize, k: usize, n: usize, kind: &ArithKind)
        -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..m * k).map(|_| (rng.normal() * 2.0) as f32)
        .collect();
    let w: Vec<f32> = (0..k * n)
        .map(|_| kind.quantize(rng.normal() as f32))
        .collect();
    (x, w, vec![0.0; m * n])
}

fn run_shape(label: &str, m: usize, k: usize, n: usize, iters: usize,
             kinds: &[(&str, usize)]) {
    println!("\n--- {label}: [{m} x {k}] @ [{k} x {n}] ---");
    header();
    let macs = (m * k * n) as f64;
    for (ks, threads) in kinds {
        let kind = ArithKind::parse(ks).unwrap();
        let (x, w, mut out) = mats(m, k, n, &kind);
        let r = bench(
            &format!("{ks} (threads={threads})"),
            1,
            iters,
            || {
                gemm(&kind, &x, &w, m, k, n, &mut out, *threads);
                std::hint::black_box(&out);
            },
        );
        let mmacs = macs / (r.mean_ns() / 1e9) / 1e6;
        println!("{}  -> {:.0} M MAC/s", r.summary(), mmacs);
    }
}

fn main() {
    println!("=== GEMM kernels: M MAC/s per arithmetic provider ===");

    // FC1 shape (the network's dominant GEMM): batch 64
    run_shape(
        "FC1, batch 64",
        64,
        3136,
        1024,
        5,
        &[
            ("float32", 1),
            ("float32", 0),
            ("FI(6,8)", 1),
            ("FI(6,8)", 0),
            ("H(6,8,12)", 0),
            ("FL(4,9)", 0),
            ("binxnor", 0),
        ],
    );

    // CFPU is the expensive provider: smaller shape, same layout
    run_shape(
        "FC-small (CFPU-viable)",
        64,
        784,
        256,
        5,
        &[("I(5,10)", 1), ("I(5,10)", 0), ("FL(5,10)", 0)],
    );

    // CONV2 as im2col: [batch*14*14, 800] @ [800, 64]
    run_shape(
        "CONV2 im2col, batch 16",
        16 * 196,
        800,
        64,
        5,
        &[("float32", 0), ("FI(6,8)", 0), ("H(6,8,12)", 0)],
    );
}
