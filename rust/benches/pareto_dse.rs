//! Surrogate-guided DSE end-to-end: how long one `Explorer::run` pass
//! takes on a hermetic 3-layer MLP, and how many full-network
//! simulations the surrogate front saves versus exhaustive
//! enumeration of the same candidate space.
//!
//!     cargo bench --bench pareto_dse
//!
//! Emits `BENCH_pareto_dse.json` (override with
//! `$LOP_PARETO_BENCH_JSON`) for CI trend tracking.

use lop::coordinator::eval::Evaluator;
use lop::coordinator::explorer::{Explorer, ExploreOpts, Family};
use lop::coordinator::pareto::distill_labels;
use lop::data::loader::{Dataset, Split};
use lop::data::synth;
use lop::nn::network::Model;
use lop::nn::spec::NetSpec;
use lop::util::bench::{fmt_ns, write_bench_json};
use std::time::Instant;

fn synth_dataset(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    let (tr_imgs, tr_labels) = synth::generate(n_train, seed);
    let (te_imgs, te_labels) = synth::generate(n_test, seed + 1);
    Dataset {
        h: 28,
        w: 28,
        train: Split { images: tr_imgs, labels: tr_labels },
        test: Split { images: te_imgs, labels: te_labels },
    }
}

fn main() {
    let spec = NetSpec::parse(
        "28x28x1: dense(32)+relu | dense(16)+relu | dense(10)",
    )
    .unwrap();
    let model = Model::synthetic(spec.clone(), 42);
    let mut ds = synth_dataset(256, 128, 4242);
    // distilled labels: the float net's own predictions are ground
    // truth, so accuracies measure representation error alone
    distill_labels(&model, &mut ds, 0);
    let mut ev = Evaluator::new(model, None, ds, 64, 0);

    let opts = ExploreOpts {
        accuracy_bound: 0.05,
        frac_bci: (4, 8),
        int_headroom: 1,
        families: vec![Family::Fixed],
        second_pass: true,
        ..Default::default()
    };

    println!("pareto_dse: surrogate-guided DSE over '{spec}'\n");
    let t0 = Instant::now();
    let front = Explorer::new(spec.clone())
        .opts(opts)
        .max_sims(8)
        .calibration(64)
        .run(&mut ev)
        .expect("explorer pass failed");
    let elapsed = t0.elapsed();

    let sims = front.sims() as u64;
    let space = front.space();
    assert!(sims < space,
            "surrogate must save simulations ({sims} of {space})");
    let saved = space - sims;
    println!("candidate space    : {space} configs");
    println!("full simulations   : {sims} ({saved} saved)");
    println!("front points       : {}", front.points().len());
    println!("baseline accuracy  : {:.4}", front.baseline_accuracy());
    println!("cost model         : {}", front.cost_source());
    println!("explorer wall time : {}",
             fmt_ns(elapsed.as_nanos() as f64));
    for p in front.points() {
        println!("  {:<44} acc {:.4} lat {:>9.1} us hw {:.3} [{}]",
                 p.repr_map.name(), p.accuracy,
                 p.est_latency / 1_000.0, p.hw_cost,
                 if p.simulated { "simulated" } else { "surrogate" });
    }

    let rows = vec![format!(
        "\"series\": \"explorer_pass\", \"spec\": \"{spec}\", \
         \"space\": {space}, \"front_points\": {}, \"sims\": {sims}, \
         \"sims_saved\": {saved}, \"baseline\": {}, \
         \"elapsed_ms\": {}, \"cost_source\": \"{}\"",
        front.points().len(),
        front.baseline_accuracy(),
        elapsed.as_millis(),
        front.cost_source()
    )];
    write_bench_json("pareto_dse", "LOP_PARETO_BENCH_JSON",
                     "BENCH_pareto_dse.json", &rows);
}
