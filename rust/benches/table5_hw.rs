//! Bench + regeneration of paper Table 5: hardware cost of the 500-PE
//! datapath per representation, plus the width-sweep ablations and the
//! analytical-synthesis timing.

use lop::approx::arith::ArithKind;
use lop::hw::datapath::{Datapath, N_PE};
use lop::hw::report::{format_table, hw_report, table5_kinds};
use lop::util::bench::{bench, black_box, header};

fn main() {
    println!("=== Table 5: hardware cost of various implementations ===\n");
    print!("{}", format_table(&hw_report(&table5_kinds())));

    println!("\npaper reference rows (Arria 10, Quartus):");
    println!("  float32  209,805 ALMs  500 DSPs   94.41 MHz  12.38 W   \
              3.81 Gops/J");
    println!("  float16  101,644 ALMs  500 DSPs  113.86 MHz   7.30 W   \
              7.80 Gops/J");
    println!("  FL(4,9)   93,500 ALMs  500 DSPs  115.89 MHz   6.68 W   \
              8.67 Gops/J");
    println!("  I(5,10)   92,111 ALMs    0 DSPs  116.80 MHz   6.28 W   \
              9.30 Gops/J");
    println!("  FI(6,8)   15,452 ALMs  500 DSPs  201.13 MHz   4.90 W  \
              20.52 Gops/J");

    println!("\n=== FI(6, f) fractional-width sweep ===");
    println!("{:<10} {:>9} {:>11} {:>9} {:>10}", "repr", "ALMs",
             "clock MHz", "power W", "Gops/J");
    for f in [4u32, 6, 8, 10, 12, 14, 16] {
        let k = ArithKind::parse(&format!("FI(6,{f})")).unwrap();
        let dp = Datapath::synthesize(&k, N_PE);
        println!("{:<10} {:>9.0} {:>11.2} {:>9.2} {:>10.2}", k.name(),
                 dp.alms, dp.fmax_mhz, dp.power_w, dp.gops_per_j);
    }

    println!("\n=== FL(4, m) mantissa-width sweep ===");
    println!("{:<10} {:>9} {:>11} {:>9} {:>10}", "repr", "ALMs",
             "clock MHz", "power W", "Gops/J");
    for m in [4u32, 6, 8, 9, 10, 12, 16, 23] {
        let k = ArithKind::parse(&format!("FL(4,{m})")).unwrap();
        let dp = Datapath::synthesize(&k, N_PE);
        println!("{:<10} {:>9.0} {:>11.2} {:>9.2} {:>10.2}", k.name(),
                 dp.alms, dp.fmax_mhz, dp.power_w, dp.gops_per_j);
    }

    println!("\n=== timing (analytical synthesis is the explorer's inner \
              objective) ===");
    header();
    let kinds: Vec<ArithKind> = ["float32", "FI(6,8)", "H(6,8,12)",
                                 "FL(4,9)", "I(5,10)"]
        .iter()
        .map(|s| ArithKind::parse(s).unwrap())
        .collect();
    let r = bench("Datapath::synthesize x5 kinds", 10, 200, || {
        for k in &kinds {
            black_box(Datapath::synthesize(k, N_PE));
        }
    });
    println!("{}", r.summary());
}
