//! Bench + regeneration of paper Table 4: classification accuracy of the
//! fixed-point-based customized computations (FI rows on the PJRT
//! fake-quant path, H rows — DRUM approximate multiplier — on the
//! bit-accurate engine).

use lop::approx::arith::ArithKind;
use lop::coordinator::eval::Evaluator;
use lop::data::Dataset;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::runtime::ArtifactDir;
use std::time::Instant;

const ROWS: [&str; 4] = [
    "FI(5,8)|FI(5,8)|FI(6,8)|FI(6,8)",
    "FI(6,8)|FI(6,8)|H(8,8,14)|H(8,8,14)",
    "H(6,8,12)|H(6,8,12)|H(8,8,14)|H(8,8,14)",
    "FI(6,8)",
];

const PAPER: [f64; 4] = [0.9898, 1.0, 1.0, 1.0];

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(400);
    let art = ArtifactDir::discover().expect("run `make artifacts`");
    let spec = NetSpec::paper_dcnn();
    let model = Model::load(spec.clone(), &art.weights_path()).unwrap();
    let ds = Dataset::load(&art.dataset_path()).unwrap();
    // engine fallback when PJRT is unavailable (non-pjrt build)
    let runner = lop::runtime::runner_or_warn(art);
    let mut ev = Evaluator::new(model, runner, ds, n, 0);

    let base = ev
        .accuracy(&ReprMap::uniform_for(&spec, ArithKind::Float32))
        .unwrap();
    println!("=== Table 4: accuracy of fixed-point customized \
              computations (n = {n}, baseline {base:.4}) ===\n");
    println!("{:<46} {:>9} {:>9} {:>11} {:>9}",
             "CONV1|CONV2|FC1|FC2", "accuracy", "relative", "paper rel.",
             "time");
    println!("{}", "-".repeat(88));
    for (row, paper) in ROWS.iter().zip(PAPER) {
        let cfg = ReprMap::parse_for(&spec, row).unwrap();
        let t0 = Instant::now();
        let acc = ev.accuracy(&cfg).unwrap();
        println!("{:<46} {:>9.4} {:>8.2}% {:>10.2}% {:>8.1?}", row, acc,
                 acc / base * 100.0, paper * 100.0, t0.elapsed());
    }
    println!("\n(shape check: FI(6,8) reaches baseline; FI(5,8) on the \
              convs costs ~1%; DRUM-augmented rows hold baseline — the \
              paper's qualitative ordering)");
}
