//! Bench + regeneration of paper Table 3: classification accuracy of the
//! floating-point-based customized computations (FL rows on the PJRT
//! fake-quant path, I rows — CFPU approximate multiplier — on the
//! bit-accurate engine).
//!
//! The bench uses a reduced subset to stay fast; EXPERIMENTS.md records
//! the full-test-set run (`lop table3`).

use lop::approx::arith::ArithKind;
use lop::coordinator::eval::Evaluator;
use lop::data::Dataset;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::runtime::ArtifactDir;
use std::time::Instant;

const ROWS: [&str; 5] = [
    "FL(4,8)|FL(4,9)|FL(4,8)|FL(4,9)",
    "FL(4,9)",
    "I(4,8)|I(4,9)|I(4,8)|I(4,9)",
    "I(4,9)",
    "I(5,10)",
];

// paper-reported relative accuracies for the same rows
const PAPER: [f64; 5] = [0.9898, 1.0, 0.9490, 0.9490, 1.0];

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(200);
    let art = ArtifactDir::discover().expect("run `make artifacts`");
    let spec = NetSpec::paper_dcnn();
    let model = Model::load(spec.clone(), &art.weights_path()).unwrap();
    let ds = Dataset::load(&art.dataset_path()).unwrap();
    // engine fallback when PJRT is unavailable (non-pjrt build)
    let runner = lop::runtime::runner_or_warn(art);
    let mut ev = Evaluator::new(model, runner, ds, n, 0);

    let base = ev
        .accuracy(&ReprMap::uniform_for(&spec, ArithKind::Float32))
        .unwrap();
    println!("=== Table 3: accuracy of floating-point customized \
              computations (n = {n}, baseline {base:.4}) ===\n");
    println!("{:<46} {:>9} {:>9} {:>11} {:>9}",
             "CONV1|CONV2|FC1|FC2", "accuracy", "relative", "paper rel.",
             "time");
    println!("{}", "-".repeat(88));
    for (row, paper) in ROWS.iter().zip(PAPER) {
        let cfg = ReprMap::parse_for(&spec, row).unwrap();
        let t0 = Instant::now();
        let acc = ev.accuracy(&cfg).unwrap();
        println!("{:<46} {:>9.4} {:>8.2}% {:>10.2}% {:>8.1?}", row, acc,
                 acc / base * 100.0, paper * 100.0, t0.elapsed());
    }
    println!("\n(shape check: FL(4,9) uniform should reach ~100% \
              relative; narrow-mantissa CFPU rows degrade; I(5,10) \
              recovers — the paper's qualitative ordering)");
}
