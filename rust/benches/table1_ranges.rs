//! Bench + regeneration of paper Table 1: per-layer WBA value ranges.
//! Prints the table rows (the experiment artifact) and times the range
//! profiling pass.

use lop::coordinator::ranges::{format_table1, int_bits_for,
                               profile_ranges};
use lop::data::Dataset;
use lop::nn::network::Model;
use lop::nn::spec::NetSpec;
use lop::runtime::ArtifactDir;
use lop::util::bench::{bench, header};

fn main() {
    let art = ArtifactDir::discover().expect("run `make artifacts`");
    let model =
        Model::load(NetSpec::paper_dcnn(), &art.weights_path()).unwrap();
    let ds = Dataset::load(&art.dataset_path()).unwrap();

    println!("=== Table 1: value range of weights, biases and \
              activations per layer ===\n");
    let ranges = profile_ranges(&model, &ds, 2_000, 0);
    print!("{}", format_table1(&ranges));
    println!("\nderived range-determined BCI lower bounds (integral \
              bits, sign-magnitude):");
    for r in &ranges {
        let c = r.combined();
        let mag = c.0.abs().max(c.1.abs()) as f64;
        println!("  {:<6} |range| {:>6.2} -> {} integral bits (paper \
                  widens by +[0,3] for partial sums)",
                 r.layer, mag, int_bits_for(mag));
    }

    println!("\n=== timing ===");
    header();
    for n in [100usize, 500, 2_000] {
        let r = bench(&format!("profile_ranges(n={n})"), 1, 5, || {
            let rr = profile_ranges(&model, &ds, n, 0);
            std::hint::black_box(rr);
        });
        println!("{}", r.summary());
    }
}
