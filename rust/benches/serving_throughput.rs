//! Serving throughput/latency bench: the router → dynamic batcher →
//! engine worker stack, **hermetic** (synthetic weights + synthetic
//! digits — no `make artifacts`), so CI can run it and gate on it.
//!
//! Two series, both written to `BENCH_serving_throughput.json` (path
//! override: `LOP_SERVING_BENCH_JSON`):
//!
//! * `workers` — the PR-4 headline: K engine-backed configs served at
//!   1/2/4 workers over one shared `PlanCache`.  The bench *asserts*
//!   (so a regression fails `cargo bench`, and with it CI) that the
//!   prepare count and resident panel bytes are identical at every
//!   worker count — residency scales with configs, not
//!   `workers x configs`.
//! * `policy` — the historical max-batch/max-wait ablation, kept on
//!   the engine backend (the PJRT open-loop run lives in
//!   `examples/serve_inference.rs`).

use lop::coordinator::server::{Server, ServerOpts};
use lop::data::synth;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::util::bench::write_bench_json;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Engine-backed configuration mix: one per panel family (fixed
/// element panels, DRUM-conditioned, float lattice, binary word
/// panels).
const CONFIGS: [&str; 4] = ["FI(6,8)", "H(6,8,12)", "FL(4,9)", "binxnor"];

struct Row {
    series: &'static str,
    workers: usize,
    configs: usize,
    max_batch: usize,
    max_wait_ms: f64,
    served: usize,
    req_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
    prepares: u64,
    panel_bytes: usize,
    hits: u64,
    inflight_waits: u64,
    evictions: u64,
}

fn opts(configs: Vec<ReprMap>, workers: usize, max_batch: usize,
        max_wait: Duration) -> ServerOpts {
    ServerOpts {
        configs,
        max_batch,
        max_wait,
        queue_capacity: 8_192,
        engine_workers: workers,
        engine_gemm_threads: 1,
        plan_cache_bytes: 512 * 1024 * 1024, // no eviction in-series
        use_pjrt: false, // hermetic: engine backend only
    }
}

/// Closed burst of `n` requests spread round-robin over the server's
/// configs; returns the served count, the burst wall time, and the
/// (p50, p99) latency in ms **over this burst's responses only** —
/// the server's cumulative histogram also holds the warm-up requests,
/// whose latency includes the one-time `Model::prepare` and would
/// otherwise dominate p99 of a ~200-request series.
fn burst(server: &Server, images: &[u8], n: usize, n_cfg: usize)
         -> (usize, Duration, f64, f64) {
    let (tx, rx) = channel();
    let t0 = Instant::now();
    for i in 0..n {
        let idx = i % 256;
        let img: Vec<f32> = images[idx * 784..(idx + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        server
            .router
            .submit(i % n_cfg, img, tx.clone())
            .expect("submit");
    }
    drop(tx);
    let mut lat_us: Vec<u64> = Vec::with_capacity(n);
    while lat_us.len() < n {
        match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(resp) => lat_us.push(resp.latency.as_micros() as u64),
            Err(_) => break,
        }
    }
    let wall = t0.elapsed();
    lat_us.sort_unstable();
    let pct = |p: f64| -> f64 {
        if lat_us.is_empty() {
            return 0.0;
        }
        let rank = ((p / 100.0) * lat_us.len() as f64).ceil() as usize;
        lat_us[rank.saturating_sub(1).min(lat_us.len() - 1)] as f64
            / 1e3
    };
    (lat_us.len(), wall, pct(50.0), pct(99.0))
}

fn run_series(series: &'static str, model: &Arc<Model>,
              configs: &[ReprMap], workers: usize, max_batch: usize,
              max_wait: Duration, n: usize, images: &[u8],
              rows: &mut Vec<Row>) {
    let server = Server::start_with_model(
        opts(configs.to_vec(), workers, max_batch, max_wait),
        model.clone(),
        None,
    )
    .expect("server");
    // warm up: one request per config prepares it outside the timed
    // burst (the cold path is what tests/plan_cache.rs pins)
    let (wtx, wrx) = channel();
    for ci in 0..configs.len() {
        server.router.submit(ci, vec![0.0; 784], wtx.clone()).unwrap();
    }
    drop(wtx);
    for _ in 0..configs.len() {
        wrx.recv_timeout(Duration::from_secs(120)).expect("warmup");
    }

    let (got, wall, p50_ms, p99_ms) =
        burst(&server, images, n, configs.len());
    let cache = server.plan_cache.stats();
    let snap_depth: usize = server.queue_depths().iter().sum();
    let row = Row {
        series,
        workers,
        configs: configs.len(),
        max_batch,
        max_wait_ms: max_wait.as_secs_f64() * 1e3,
        served: got,
        req_per_s: got as f64 / wall.as_secs_f64().max(1e-9),
        p50_ms,
        p99_ms,
        mean_batch: server.metrics.mean_batch_size(),
        prepares: cache.prepares,
        panel_bytes: cache.resident_bytes,
        hits: cache.hits,
        inflight_waits: cache.inflight_waits,
        evictions: cache.evictions,
    };
    server.shutdown().expect("worker panicked");
    assert_eq!(snap_depth, 0, "queues not drained after closed burst");
    assert_eq!(got, n, "request stream was not fully served");
    println!("{:>7} {:>8} {:>8} {:>10} {:>10.1} {:>9.2} {:>9.2} \
              {:>9} {:>11.2} {:>6} {:>6}",
             row.workers, row.configs, row.max_batch, row.served,
             row.req_per_s, row.p50_ms, row.p99_ms, row.prepares,
             row.panel_bytes as f64 / (1024.0 * 1024.0), row.hits,
             row.evictions);
    rows.push(row);
}

fn write_json(rows: &[Row]) {
    let bodies: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "\"series\": \"{}\", \"workers\": {}, \"configs\": \
                 {}, \"max_batch\": {}, \"max_wait_ms\": {:.1}, \
                 \"served\": {}, \"req_per_s\": {:.1}, \"p50_ms\": \
                 {:.2}, \"p99_ms\": {:.2}, \"mean_batch\": {:.2}, \
                 \"prepares\": {}, \"panel_bytes\": {}, \"hits\": {}, \
                 \"inflight_waits\": {}, \"evictions\": {}",
                r.series,
                r.workers,
                r.configs,
                r.max_batch,
                r.max_wait_ms,
                r.served,
                r.req_per_s,
                r.p50_ms,
                r.p99_ms,
                r.mean_batch,
                r.prepares,
                r.panel_bytes,
                r.hits,
                r.inflight_waits,
                r.evictions
            )
        })
        .collect();
    write_bench_json("serving_throughput", "LOP_SERVING_BENCH_JSON",
                     "BENCH_serving_throughput.json", &bodies);
}

fn main() {
    let spec = NetSpec::paper_dcnn();
    let model = Arc::new(Model::synthetic(spec.clone(), 7));
    let (images, _) = synth::generate(256, 31);
    let configs: Vec<ReprMap> = CONFIGS
        .iter()
        .map(|s| ReprMap::parse_for(&spec, s).unwrap())
        .collect();
    let mut rows = Vec::new();

    println!("=== serving throughput: shared plan cache, closed \
              bursts, engine backend (hermetic) ===\n");
    println!("{:>7} {:>8} {:>8} {:>10} {:>10} {:>9} {:>9} {:>9} \
              {:>11} {:>6} {:>6}",
             "workers", "configs", "maxbatch", "served", "req/s",
             "p50 (ms)", "p99 (ms)", "prepares", "panels(MiB)",
             "hits", "evict");

    // --- series 1: worker scaling over one shared PlanCache --------
    for workers in [1usize, 2, 4] {
        run_series("workers", &model, &configs, workers, 16,
                   Duration::from_millis(2), 192, &images, &mut rows);
    }
    // The acceptance invariant: prepares and resident panel bytes are
    // a function of the config set alone.  A violation aborts the
    // bench (non-zero exit), which fails the CI bench-serving job.
    let worker_rows: Vec<&Row> =
        rows.iter().filter(|r| r.series == "workers").collect();
    let (p0, b0) = (worker_rows[0].prepares, worker_rows[0].panel_bytes);
    assert_eq!(p0, CONFIGS.len() as u64,
               "each config must be prepared exactly once");
    for r in &worker_rows {
        assert_eq!(
            (r.prepares, r.panel_bytes),
            (p0, b0),
            "prepare count / resident panel bytes changed with the \
             worker count ({} workers)",
            r.workers
        );
    }
    println!("\nplan-cache invariance: {} prepares, {:.2} MiB resident \
              at every worker count OK",
             p0, b0 as f64 / (1024.0 * 1024.0));

    // --- series 2: batching-policy ablation (single config) --------
    println!();
    let one = vec![configs[0].clone()];
    for (max_batch, wait_ms) in
        [(1usize, 0.5f64), (8, 2.0), (16, 2.0), (64, 4.0)]
    {
        run_series("policy", &model, &one, 2, max_batch,
                   Duration::from_micros((wait_ms * 1e3) as u64), 256,
                   &images, &mut rows);
    }
    println!("\n(policy ablation: throughput should rise with \
              max_batch, trading p99)");

    write_json(&rows);
}
