//! Serving throughput/latency bench: the router → dynamic batcher →
//! engine worker stack, **hermetic** (synthetic weights + synthetic
//! digits — no `make artifacts`), so CI can run it and gate on it.
//!
//! Three series, all written to `BENCH_serving_throughput.json` (path
//! override: `LOP_SERVING_BENCH_JSON`):
//!
//! * `workers` — the PR-4 headline: K engine-backed configs served at
//!   1/2/4 workers over one shared `PlanCache`.  The bench *asserts*
//!   (so a regression fails `cargo bench`, and with it CI) that the
//!   prepare count and resident panel bytes are identical at every
//!   worker count — residency scales with configs, not
//!   `workers x configs`.
//! * `policy` — the historical max-batch/max-wait ablation, kept on
//!   the engine backend (the PJRT open-loop run lives in
//!   `examples/serve_inference.rs`).
//! * `stress` — open-loop arrival at 1x/10x/100x of measured capacity
//!   against every overload policy (reject/shed/degrade), over a small
//!   high-water mark so queueing delay stays bounded.  Emits
//!   p50/p99/p999 + shed-rate + degrade-rate per run and *asserts* the
//!   policy matrix: `Reject` keeps p99 of accepted requests flat under
//!   100x, `Shed` sheds (non-zero rate, zero expired), `Degrade`
//!   serves at least as much as `Reject` by re-routing down the
//!   hw-cost ladder.

use lop::coordinator::batcher::{FailureKind, Outcome};
use lop::coordinator::router::{OverloadPolicy, SubmitError};
use lop::coordinator::server::{Server, ServerOpts};
use lop::data::synth;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::telemetry::Histogram;
use lop::util::bench::write_bench_json;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Engine-backed configuration mix: one per panel family (fixed
/// element panels, DRUM-conditioned, float lattice, binary word
/// panels).
const CONFIGS: [&str; 4] = ["FI(6,8)", "H(6,8,12)", "FL(4,9)", "binxnor"];

struct Row {
    series: &'static str,
    workers: usize,
    configs: usize,
    max_batch: usize,
    max_wait_ms: f64,
    served: usize,
    req_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
    prepares: u64,
    panel_bytes: usize,
    hits: u64,
    inflight_waits: u64,
    evictions: u64,
}

fn opts(configs: Vec<ReprMap>, workers: usize, max_batch: usize,
        max_wait: Duration) -> ServerOpts {
    ServerOpts {
        configs,
        max_batch,
        max_wait,
        queue_capacity: 8_192,
        engine_workers: workers,
        engine_gemm_threads: 1,
        plan_cache_bytes: 512 * 1024 * 1024, // no eviction in-series
        use_pjrt: false, // hermetic: engine backend only
        overload: OverloadPolicy::Reject,
        deadline: None,
        inject_backend_failures: false,
    }
}

/// Closed burst of `n` requests spread round-robin over the server's
/// configs; returns the served count, the burst wall time, and the
/// (p50, p99) latency in ms **over this burst's responses only** —
/// the server's cumulative histogram also holds the warm-up requests,
/// whose latency includes the one-time `Model::prepare` and would
/// otherwise dominate p99 of a ~200-request series.  Percentiles use
/// the shared `lop::telemetry::Histogram` bucketed read-out (within
/// 2x of the true sample; exact at the max), same as the server's
/// own latency series.
fn burst(server: &Server, images: &[u8], n: usize, n_cfg: usize)
         -> (usize, Duration, f64, f64) {
    let (tx, rx) = channel();
    let t0 = Instant::now();
    for i in 0..n {
        let idx = i % 256;
        let img: Vec<f32> = images[idx * 784..(idx + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        server
            .router
            .submit(i % n_cfg, img, None, tx.clone())
            .expect("submit");
    }
    drop(tx);
    let lat = Histogram::new();
    while (lat.count() as usize) < n {
        match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(resp) => {
                assert!(resp.is_ok(), "closed burst cannot fail: {:?}",
                        resp.outcome);
                lat.record(resp.latency.as_micros() as u64);
            }
            Err(_) => break,
        }
    }
    let wall = t0.elapsed();
    (lat.count() as usize, wall, pct_ms(&lat, 50.0), pct_ms(&lat, 99.0))
}

/// Histogram percentile (recorded in µs), returned in ms.
fn pct_ms(h: &Histogram, p: f64) -> f64 {
    h.percentile(p) as f64 / 1e3
}

fn run_series(series: &'static str, model: &Arc<Model>,
              configs: &[ReprMap], workers: usize, max_batch: usize,
              max_wait: Duration, n: usize, images: &[u8],
              rows: &mut Vec<Row>) {
    let server = Server::start_with_model(
        opts(configs.to_vec(), workers, max_batch, max_wait),
        model.clone(),
        None,
    )
    .expect("server");
    // warm up: one request per config prepares it outside the timed
    // burst (the cold path is what tests/plan_cache.rs pins)
    warm_up(&server, configs.len());

    let (got, wall, p50_ms, p99_ms) =
        burst(&server, images, n, configs.len());
    let cache = server.plan_cache.stats();
    let snap_depth: usize = server.queue_depths().iter().sum();
    let row = Row {
        series,
        workers,
        configs: configs.len(),
        max_batch,
        max_wait_ms: max_wait.as_secs_f64() * 1e3,
        served: got,
        req_per_s: got as f64 / wall.as_secs_f64().max(1e-9),
        p50_ms,
        p99_ms,
        mean_batch: server.metrics.mean_batch_size(),
        prepares: cache.prepares,
        panel_bytes: cache.resident_bytes,
        hits: cache.hits,
        inflight_waits: cache.inflight_waits,
        evictions: cache.evictions,
    };
    server.shutdown().expect("worker panicked");
    assert_eq!(snap_depth, 0, "queues not drained after closed burst");
    assert_eq!(got, n, "request stream was not fully served");
    println!("{:>7} {:>8} {:>8} {:>10} {:>10.1} {:>9.2} {:>9.2} \
              {:>9} {:>11.2} {:>6} {:>6}",
             row.workers, row.configs, row.max_batch, row.served,
             row.req_per_s, row.p50_ms, row.p99_ms, row.prepares,
             row.panel_bytes as f64 / (1024.0 * 1024.0), row.hits,
             row.evictions);
    rows.push(row);
}

/// Drain one warm-up request per config so `Model::prepare` runs
/// outside any timed window.
fn warm_up(server: &Server, n_cfg: usize) {
    let (wtx, wrx) = channel();
    for ci in 0..n_cfg {
        server
            .router
            .submit(ci, vec![0.0; 784], None, wtx.clone())
            .expect("warmup submit");
    }
    drop(wtx);
    for _ in 0..n_cfg {
        wrx.recv_timeout(Duration::from_secs(120)).expect("warmup");
    }
}

// ---------------------------------------------------------------------
// series 3: open-loop overload stress (1x/10x/100x x policy matrix)
// ---------------------------------------------------------------------

/// Queue high-water mark for the stress servers.  Equal to the batch
/// size, so an accepted request waits at most ~2 batch drains — that
/// bounded queueing delay is what keeps `Reject`'s p99 flat at 100x.
const STRESS_HWM: usize = 16;
const STRESS_MAX_WAIT: Duration = Duration::from_millis(1);

struct StressRow {
    policy: &'static str,
    mult: usize,
    offered: usize,
    offered_rps: f64,
    accepted: usize,
    served: usize,
    rejected: usize,
    shed: u64,
    degraded: u64,
    expired: u64,
    backend_failures: u64,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
    shed_rate: f64,
    degrade_rate: f64,
    ladder: usize,
}

/// Measure the sustainable service rate of the stress configuration
/// (all traffic on config 0) with a deep queue: a closed burst batches
/// maximally, so this is an *upper* bound on what a paced open loop
/// can push through — offering exactly this rate saturates the server.
fn measure_capacity(model: &Arc<Model>, configs: &[ReprMap],
                    images: &[u8]) -> f64 {
    let server = Server::start_with_model(
        opts(configs.to_vec(), 2, STRESS_HWM, STRESS_MAX_WAIT),
        model.clone(),
        None,
    )
    .expect("server");
    warm_up(&server, configs.len());
    let (got, wall, _, _) = burst(&server, images, 192, 1);
    server.shutdown().expect("worker panicked");
    assert_eq!(got, 192, "capacity burst was not fully served");
    (got as f64 / wall.as_secs_f64().max(1e-9)).max(50.0)
}

/// Open-loop arrival on config 0 at `rate` req/s (absolute-schedule
/// pacing: oversleeps self-correct, so the offered rate holds).
/// Returns (sync-rejected, ok-latency histogram in µs, shed
/// responses).
fn open_loop(server: &Server, images: &[u8], offered: usize, rate: f64)
             -> (usize, Histogram, u64) {
    let (tx, rx) = channel();
    let gap = Duration::from_secs_f64(1.0 / rate);
    let mut next = Instant::now();
    let mut rejected = 0usize;
    for i in 0..offered {
        let now = Instant::now();
        if now < next {
            std::thread::sleep(next - now);
        }
        next += gap;
        let idx = i % 256;
        let img: Vec<f32> = images[idx * 784..(idx + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        match server.router.submit(0, img, None, tx.clone()) {
            Ok(_) => {}
            Err(SubmitError::Overloaded) => rejected += 1,
            Err(e) => panic!("unexpected submit error: {e:?}"),
        }
    }
    drop(tx);
    // every accepted request gets exactly one typed response
    let accepted = offered - rejected;
    let ok_lat = Histogram::new();
    let mut shed = 0u64;
    for _ in 0..accepted {
        let resp = rx
            .recv_timeout(Duration::from_secs(300))
            .expect("accepted request never answered");
        match resp.outcome {
            Outcome::Ok(_) => {
                ok_lat.record(resp.latency.as_micros() as u64)
            }
            Outcome::Error(FailureKind::Shed) => shed += 1,
            Outcome::Error(k) => {
                panic!("unexpected failure in stress run: {k:?}")
            }
        }
    }
    (rejected, ok_lat, shed)
}

fn run_stress(policy: OverloadPolicy, mult: usize, capacity_rps: f64,
              model: &Arc<Model>, configs: &[ReprMap], images: &[u8],
              stress_rows: &mut Vec<StressRow>) {
    let server = Server::start_with_model(
        ServerOpts {
            overload: policy,
            // the stress queue holds at most one batch — a tight
            // high-water mark is the knob the policy matrix turns on
            queue_capacity: STRESS_HWM,
            ..opts(configs.to_vec(), 2, STRESS_HWM, STRESS_MAX_WAIT)
        },
        model.clone(),
        None,
    )
    .expect("server");
    warm_up(&server, configs.len());

    let rate = capacity_rps * mult as f64;
    // shorter windows at higher multiples keep total offered bounded
    let window = match mult {
        1 => 1.0,
        10 => 0.3,
        _ => 0.1,
    };
    let offered = ((rate * window) as usize).clamp(64, 20_000);
    let (rejected, ok_lat, shed_resp) =
        open_loop(&server, images, offered, rate);

    let m = &server.metrics;
    let shed = m.shed.get();
    let degraded = m.degraded.get();
    let expired = m.expired.get();
    let backend_failures = m.backend_failures.get();
    let ladder = server.router.ladder(0).len();
    server.shutdown().expect("worker panicked");

    let accepted = offered - rejected;
    let served = ok_lat.count() as usize;
    assert_eq!(shed, shed_resp,
               "shed counter and shed responses disagree");
    assert_eq!(accepted, served + shed as usize,
               "accepted = served + shed under no-deadline stress");
    let row = StressRow {
        policy: match policy {
            OverloadPolicy::Reject => "reject",
            OverloadPolicy::Shed => "shed",
            OverloadPolicy::Degrade => "degrade",
        },
        mult,
        offered,
        offered_rps: rate,
        accepted,
        served,
        rejected,
        shed,
        degraded,
        expired,
        backend_failures,
        p50_ms: pct_ms(&ok_lat, 50.0),
        p99_ms: pct_ms(&ok_lat, 99.0),
        p999_ms: pct_ms(&ok_lat, 99.9),
        shed_rate: shed as f64 / offered.max(1) as f64,
        degrade_rate: degraded as f64 / accepted.max(1) as f64,
        ladder,
    };
    println!("{:>8} {:>5}x {:>8} {:>8} {:>8} {:>8} {:>6} {:>7} \
              {:>9.2} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
             row.policy, row.mult, row.offered, row.accepted,
             row.served, row.rejected, row.shed, row.degraded,
             row.p50_ms, row.p99_ms, row.p999_ms, row.shed_rate,
             row.degrade_rate);
    stress_rows.push(row);
}

/// The acceptance matrix over the stress rows.  Mirrored (from the
/// emitted JSON) by the CI bench-serving sanity step, so a regression
/// fails both the bench binary and the gate that parses its output.
fn assert_stress_matrix(stress_rows: &[StressRow]) {
    let find = |policy: &str, mult: usize| -> &StressRow {
        stress_rows
            .iter()
            .find(|r| r.policy == policy && r.mult == mult)
            .expect("stress row missing")
    };
    // Reject: the bounded queue means accepted requests never wait
    // more than ~2 batch drains, so the true p99 at 100x stays within
    // 2x of the 1x p99 (slop: two max_wait timer quanta + 1ms
    // scheduler noise).  Both sides now come from the log2-bucketed
    // histogram, whose read-out is in [true, 2*true) — the 100x side
    // can read up to 2x high and the 1x side can be exact, so the
    // bucketed gate doubles the factor and the slop: true <= 2t + s
    // implies read <= 2*(2t + s) <= 4*read1 + 2s.
    let slop_ms = 2.0 * STRESS_MAX_WAIT.as_secs_f64() * 1e3 + 1.0;
    let (r1, r100) = (find("reject", 1), find("reject", 100));
    assert!(
        r100.p99_ms <= 4.0 * r1.p99_ms + 2.0 * slop_ms,
        "reject p99 blew up under 100x load: {:.2}ms vs {:.2}ms at 1x",
        r100.p99_ms, r1.p99_ms
    );
    // Shed: answers overload at the door — non-zero shed rate, and
    // nothing ever expires (no deadlines in this series).
    let s100 = find("shed", 100);
    assert!(s100.shed_rate > 0.0, "shed policy shed nothing at 100x");
    for r in stress_rows {
        assert_eq!(r.expired, 0, "no deadlines => nothing may expire");
        assert_eq!(r.backend_failures, 0, "engine backend cannot fail");
    }
    // Degrade: re-routes down the hw-cost ladder instead of refusing,
    // so it must serve at least as much as Reject at the same load.
    let d100 = find("degrade", 100);
    assert!(d100.ladder >= 1, "degrade server has no cheaper configs");
    assert!(d100.degraded > 0, "degrade policy re-routed nothing");
    assert!(
        d100.served >= r100.served,
        "degrade served less than reject at 100x: {} < {}",
        d100.served, r100.served
    );
}

fn write_json(rows: &[Row], stress_rows: &[StressRow]) {
    let mut bodies: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "\"series\": \"{}\", \"workers\": {}, \"configs\": \
                 {}, \"max_batch\": {}, \"max_wait_ms\": {:.1}, \
                 \"served\": {}, \"req_per_s\": {:.1}, \"p50_ms\": \
                 {:.2}, \"p99_ms\": {:.2}, \"mean_batch\": {:.2}, \
                 \"prepares\": {}, \"panel_bytes\": {}, \"hits\": {}, \
                 \"inflight_waits\": {}, \"evictions\": {}",
                r.series,
                r.workers,
                r.configs,
                r.max_batch,
                r.max_wait_ms,
                r.served,
                r.req_per_s,
                r.p50_ms,
                r.p99_ms,
                r.mean_batch,
                r.prepares,
                r.panel_bytes,
                r.hits,
                r.inflight_waits,
                r.evictions
            )
        })
        .collect();
    bodies.extend(stress_rows.iter().map(|r| {
        format!(
            "\"series\": \"stress\", \"policy\": \"{}\", \"mult\": {}, \
             \"offered\": {}, \"offered_rps\": {:.1}, \"accepted\": \
             {}, \"served\": {}, \"rejected\": {}, \"shed\": {}, \
             \"degraded\": {}, \"expired\": {}, \"backend_failures\": \
             {}, \"p50_ms\": {:.2}, \"p99_ms\": {:.2}, \"p999_ms\": \
             {:.2}, \"shed_rate\": {:.4}, \"degrade_rate\": {:.4}, \
             \"ladder\": {}",
            r.policy,
            r.mult,
            r.offered,
            r.offered_rps,
            r.accepted,
            r.served,
            r.rejected,
            r.shed,
            r.degraded,
            r.expired,
            r.backend_failures,
            r.p50_ms,
            r.p99_ms,
            r.p999_ms,
            r.shed_rate,
            r.degrade_rate,
            r.ladder
        )
    }));
    write_bench_json("serving_throughput", "LOP_SERVING_BENCH_JSON",
                     "BENCH_serving_throughput.json", &bodies);
}

fn main() {
    let spec = NetSpec::paper_dcnn();
    let model = Arc::new(Model::synthetic(spec.clone(), 7));
    let (images, _) = synth::generate(256, 31);
    let configs: Vec<ReprMap> = CONFIGS
        .iter()
        .map(|s| ReprMap::parse_for(&spec, s).unwrap())
        .collect();
    let mut rows = Vec::new();

    println!("=== serving throughput: shared plan cache, closed \
              bursts, engine backend (hermetic) ===\n");
    println!("{:>7} {:>8} {:>8} {:>10} {:>10} {:>9} {:>9} {:>9} \
              {:>11} {:>6} {:>6}",
             "workers", "configs", "maxbatch", "served", "req/s",
             "p50 (ms)", "p99 (ms)", "prepares", "panels(MiB)",
             "hits", "evict");

    // --- series 1: worker scaling over one shared PlanCache --------
    for workers in [1usize, 2, 4] {
        run_series("workers", &model, &configs, workers, 16,
                   Duration::from_millis(2), 192, &images, &mut rows);
    }
    // The acceptance invariant: prepares and resident panel bytes are
    // a function of the config set alone.  A violation aborts the
    // bench (non-zero exit), which fails the CI bench-serving job.
    let worker_rows: Vec<&Row> =
        rows.iter().filter(|r| r.series == "workers").collect();
    let (p0, b0) = (worker_rows[0].prepares, worker_rows[0].panel_bytes);
    assert_eq!(p0, CONFIGS.len() as u64,
               "each config must be prepared exactly once");
    for r in &worker_rows {
        assert_eq!(
            (r.prepares, r.panel_bytes),
            (p0, b0),
            "prepare count / resident panel bytes changed with the \
             worker count ({} workers)",
            r.workers
        );
    }
    println!("\nplan-cache invariance: {} prepares, {:.2} MiB resident \
              at every worker count OK",
             p0, b0 as f64 / (1024.0 * 1024.0));

    // --- series 2: batching-policy ablation (single config) --------
    println!();
    let one = vec![configs[0].clone()];
    for (max_batch, wait_ms) in
        [(1usize, 0.5f64), (8, 2.0), (16, 2.0), (64, 4.0)]
    {
        run_series("policy", &model, &one, 2, max_batch,
                   Duration::from_micros((wait_ms * 1e3) as u64), 256,
                   &images, &mut rows);
    }
    println!("\n(policy ablation: throughput should rise with \
              max_batch, trading p99)");

    // --- series 3: open-loop overload stress -----------------------
    // All traffic targets config 0 (the float-lattice config — the
    // top of the hw-cost ladder); the two cheaper configs below it
    // are the degrade policy's spillover capacity.
    let stress_configs: Vec<ReprMap> = ["FL(4,9)", "FI(6,8)", "binxnor"]
        .iter()
        .map(|s| ReprMap::parse_for(&spec, s).unwrap())
        .collect();
    let capacity_rps =
        measure_capacity(&model, &stress_configs, &images);
    println!("\n=== overload stress: open loop on config 0, measured \
              capacity {capacity_rps:.0} req/s, high-water mark \
              {STRESS_HWM} ===\n");
    println!("{:>8} {:>6} {:>8} {:>8} {:>8} {:>8} {:>6} {:>7} \
              {:>9} {:>9} {:>9} {:>7} {:>7}",
             "policy", "mult", "offered", "accepted", "served",
             "rejected", "shed", "degrade", "p50 (ms)", "p99 (ms)",
             "p999(ms)", "shedrt", "degrrt");
    let mut stress_rows = Vec::new();
    for policy in [OverloadPolicy::Reject, OverloadPolicy::Shed,
                   OverloadPolicy::Degrade]
    {
        for mult in [1usize, 10, 100] {
            run_stress(policy, mult, capacity_rps, &model,
                       &stress_configs, &images, &mut stress_rows);
        }
    }
    assert_stress_matrix(&stress_rows);
    println!("\noverload policy matrix: reject p99 flat at 100x, shed \
              sheds without expiry, degrade out-serves reject OK");

    write_json(&rows, &stress_rows);
}
