//! Serving throughput/latency bench: the router → dynamic batcher →
//! worker stack under closed bursts at several batching policies.
//! (The open-loop end-to-end run is `examples/serve_inference.rs`.)

use lop::coordinator::server::{Server, ServerOpts};
use lop::data::synth;
use lop::nn::network::NetConfig;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

fn burst(server: &Server, images: &[u8], n: usize)
         -> (usize, Duration, f64, f64) {
    let (tx, rx) = channel();
    let t0 = Instant::now();
    for i in 0..n {
        let idx = i % 256;
        let img: Vec<f32> = images[idx * 784..(idx + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        server
            .router
            .submit(0, img, tx.clone())
            .expect("submit");
    }
    drop(tx);
    let mut got = 0;
    while got < n {
        if rx.recv_timeout(Duration::from_secs(60)).is_err() {
            break;
        }
        got += 1;
    }
    let wall = t0.elapsed();
    let p50 = server.metrics.percentile_us(50.0) as f64 / 1e3;
    let p99 = server.metrics.percentile_us(99.0) as f64 / 1e3;
    (got, wall, p50, p99)
}

fn main() {
    let (images, _) = synth::generate(256, 31);
    println!("=== serving throughput: closed 512-request bursts, \
              float32 on PJRT ===\n");
    println!("{:>10} {:>12} {:>12} {:>12} {:>12} {:>12}", "max_batch",
             "max_wait", "served", "req/s", "p50 (ms)", "p99 (ms)");
    for (max_batch, wait_ms) in
        [(1usize, 0.5f64), (8, 2.0), (16, 2.0), (16, 8.0), (64, 4.0)]
    {
        let opts = ServerOpts {
            configs: vec![NetConfig::parse("float32").unwrap()],
            max_batch,
            max_wait: Duration::from_micros((wait_ms * 1e3) as u64),
            queue_capacity: 8_192,
            engine_workers: 1,
            engine_gemm_threads: 1,
            use_pjrt: true,
        };
        let server = Server::start(opts).expect("server");
        // warm up the executable cache outside the timed burst
        let (wtx, wrx) = channel();
        server.router.submit(0, vec![0.0; 784], wtx).unwrap();
        let _ = wrx.recv_timeout(Duration::from_secs(120));

        let n = 512;
        let (got, wall, p50, p99) = burst(&server, &images, n);
        println!("{:>10} {:>10.1}ms {:>12} {:>12.1} {:>12.2} {:>12.2}",
                 max_batch, wait_ms, got,
                 got as f64 / wall.as_secs_f64(), p50, p99);
        server.shutdown();
    }
    println!("\n(batching ablation: throughput should rise with \
              max_batch until the PJRT artifact batch cap, trading p99)");
}
