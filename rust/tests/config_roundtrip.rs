//! Property suite for the topology-generic config API: random
//! `NetSpec`s × random `ReprMap`s round-trip through the string
//! grammars and the TOML `[serve]` schema, structural fingerprints
//! are equal iff (spec, assignment) are equal, and arity mismatches /
//! malformed segments are rejected with the offending layer named.
//! Scale with `LOP_PROP_CASES=N` like the other property suites.

use lop::approx::arith::ArithKind;
use lop::config::{ServeFileConfig, TomlDoc};
use lop::nn::spec::{NetSpec, NetSpecBuilder, ReprMap};
use lop::util::prop;
use lop::util::prng::Rng;

/// A random valid spec: 0–2 conv layers (kernel 1/3/5, optional
/// relu/pool) then 1–3 dense layers — every shape decision mirrors
/// the builder's own rules so `build` cannot fail.
fn rand_spec(rng: &mut Rng) -> NetSpec {
    let h = [8usize, 12, 16, 28][rng.below(4) as usize];
    let w = [8usize, 12, 16, 28][rng.below(4) as usize];
    let c = 1 + rng.below(3) as usize;
    let mut b: NetSpecBuilder = NetSpec::builder([h, w, c]);
    let (mut hh, mut ww) = (h, w);
    for _ in 0..rng.below(3) {
        // the builder only accepts centered windows: kh == kw ==
        // 2*pad + 1 (what the engine's fixed-grid im2col computes)
        let k = [1usize, 3, 5][rng.below(3) as usize];
        let pad = (k - 1) / 2;
        let cout = 1 + rng.below(8) as usize;
        b = b.conv2d(k, k, cout, pad);
        if rng.below(2) == 1 {
            b = b.relu();
        }
        if hh % 2 == 0 && ww % 2 == 0 && rng.below(2) == 1 {
            b = b.pool();
            hh /= 2;
            ww /= 2;
        }
    }
    for _ in 0..1 + rng.below(3) {
        b = b.dense(1 + rng.below(32) as usize);
        if rng.below(2) == 1 {
            b = b.relu();
        }
    }
    b.build().expect("generator only emits valid specs")
}

/// A random provider covering every `ArithKind` variant, parameters
/// inside each unit's supported window.
fn rand_kind(rng: &mut Rng) -> ArithKind {
    let i = rng.below(9) as u32;
    let f = 1 + rng.below(12) as u32;
    let e = 2 + rng.below(7) as u32;
    let m = 1 + rng.below(20) as u32;
    match rng.below(6) {
        0 => ArithKind::parse("float32").unwrap(),
        1 => ArithKind::parse(&format!("FI({i},{f})")).unwrap(),
        2 => {
            let t = 2 + rng.below(14) as u32;
            ArithKind::parse(&format!("H({i},{f},{t})")).unwrap()
        }
        3 => ArithKind::parse(&format!("FL({e},{m})")).unwrap(),
        4 => {
            let w = 1 + rng.below(6) as u32;
            ArithKind::parse(&format!("I({e},{m},{w})")).unwrap()
        }
        _ => ArithKind::parse("binxnor").unwrap(),
    }
}

fn rand_map(rng: &mut Rng, n: usize) -> ReprMap {
    if rng.below(4) == 0 {
        // every 4th map is uniform, exercising the broadcast form
        ReprMap::uniform(rand_kind(rng), n)
    } else {
        ReprMap::from_kinds((0..n).map(|_| rand_kind(rng)).collect())
    }
}

#[test]
fn spec_grammar_roundtrips() {
    prop::check_msg(
        "NetSpec::parse(display(spec)) == spec",
        201,
        prop::DEFAULT_CASES,
        |rng| rand_spec(rng).to_string(),
        |text| {
            let spec = NetSpec::parse(text)
                .map_err(|e| format!("re-parse failed: {e}"))?;
            if spec.to_string() == *text {
                Ok(())
            } else {
                Err(format!("display drifted: '{spec}'"))
            }
        },
    );
}

#[test]
fn reprmap_grammar_roundtrips_against_its_spec() {
    prop::check_msg(
        "ReprMap::parse_for(spec, name(map)) == map",
        202,
        prop::DEFAULT_CASES,
        |rng| {
            let spec = rand_spec(rng);
            let map = rand_map(rng, spec.len());
            (spec, map)
        },
        |(spec, map)| {
            let back = ReprMap::parse_for(spec, &map.name())
                .map_err(|e| format!("re-parse failed: {e}"))?;
            if back == *map {
                Ok(())
            } else {
                Err(format!("got {}, want {}", back.name(), map.name()))
            }
        },
    );
}

#[test]
fn toml_serve_schema_roundtrips_spec_and_configs() {
    prop::check_msg(
        "[serve] model + configs round-trip through TOML",
        203,
        64, // each case parses a document; keep the suite fast
        |rng| {
            let spec = rand_spec(rng);
            let map = rand_map(rng, spec.len());
            (spec, map)
        },
        |(spec, map)| {
            let text = format!(
                "[serve]\nmodel = \"{spec}\"\nconfigs = [\"{}\"]\n",
                map.name()
            );
            let doc = TomlDoc::parse(&text)
                .map_err(|e| format!("toml: {e}"))?;
            let fc = ServeFileConfig::from_toml(&doc)
                .map_err(|e| format!("schema: {e}"))?;
            if fc.spec != *spec {
                return Err(format!("spec drifted: '{}'", fc.spec));
            }
            if fc.configs != vec![map.clone()] {
                return Err(format!("configs drifted: {:?}",
                                   fc.configs));
            }
            Ok(())
        },
    );
}

#[test]
fn fingerprints_equal_iff_spec_and_assignment_equal() {
    prop::check_msg(
        "fingerprint(a) == fingerprint(b) iff a == b",
        204,
        prop::DEFAULT_CASES,
        |rng| {
            let s1 = rand_spec(rng);
            let m1 = rand_map(rng, s1.len());
            // half the cases compare a pair against itself, half
            // against an independently drawn pair
            let same = rng.below(2) == 0;
            let (s2, m2) = if same {
                (s1.clone(), m1.clone())
            } else {
                let s2 = rand_spec(rng);
                let m2 = rand_map(rng, s2.len());
                (s2, m2)
            };
            (s1, m1, s2, m2)
        },
        |(s1, m1, s2, m2)| {
            let eq_pair = s1 == s2 && m1 == m2;
            let eq_fp = s1.fingerprint(m1) == s2.fingerprint(m2);
            if eq_pair == eq_fp {
                Ok(())
            } else {
                Err(format!(
                    "pair-equal = {eq_pair} but fingerprint-equal = \
                     {eq_fp}\n  fp1 = {}\n  fp2 = {}",
                    s1.fingerprint(m1),
                    s2.fingerprint(m2)
                ))
            }
        },
    );
}

#[test]
fn fingerprint_is_sensitive_to_single_layer_changes() {
    prop::check_msg(
        "flipping one assignment changes the fingerprint",
        205,
        prop::DEFAULT_CASES,
        |rng| {
            let spec = rand_spec(rng);
            let map = rand_map(rng, spec.len());
            let layer = rng.below(spec.len() as u64) as usize;
            let mut other = rand_kind(rng);
            // redraw until the kind actually differs
            while other == *map.kind(layer) {
                other = rand_kind(rng);
            }
            (spec, map, layer, other)
        },
        |(spec, map, layer, other)| {
            let mut flipped = map.clone();
            flipped.set(*layer, *other);
            if spec.fingerprint(map) == spec.fingerprint(&flipped) {
                Err(format!(
                    "layer {layer} flip invisible: {}",
                    spec.fingerprint(map)
                ))
            } else {
                Ok(())
            }
        },
    );
}

#[test]
fn arity_mismatches_are_rejected() {
    prop::check_msg(
        "ReprMap::parse_for rejects wrong-arity configs",
        206,
        prop::DEFAULT_CASES,
        |rng| {
            let spec = rand_spec(rng);
            // an explicit per-layer string of the WRONG arity
            // (n + 1, or n - 1 when that is still >= 2 so it cannot
            // be read as a broadcast)
            let n = spec.len();
            let wrong = if n >= 3 && rng.below(2) == 0 {
                n - 1
            } else {
                n + 1
            };
            let map = rand_map(rng, wrong);
            let text = map
                .kinds()
                .iter()
                .map(|k| k.name())
                .collect::<Vec<_>>()
                .join("|");
            (spec, wrong, text)
        },
        |(spec, wrong, text)| {
            if *wrong == 1 || *wrong == spec.len() {
                return Ok(()); // a 1-segment string is a broadcast
            }
            match ReprMap::parse_for(spec, text) {
                Err(e) if e.contains(&format!("{}", spec.len())) => {
                    Ok(())
                }
                Err(e) => Err(format!(
                    "error does not name the expected arity: {e}"
                )),
                Ok(_) => Err("wrong arity accepted".to_string()),
            }
        },
    );
}

#[test]
fn every_arith_kind_roundtrips_through_its_name() {
    // the satellite contract: parse(display(c)) == c for every
    // ArithKind, including non-default CFPU tuning widths
    prop::check_msg(
        "ArithKind::parse(name(k)) == k",
        207,
        prop::DEFAULT_CASES,
        |rng| rand_kind(rng),
        |k| {
            let back = ArithKind::parse(&k.name())
                .map_err(|e| format!("re-parse failed: {e}"))?;
            if back == *k {
                Ok(())
            } else {
                Err(format!("got {}, want {}", back.name(), k.name()))
            }
        },
    );
}

#[test]
fn malformed_configs_name_the_offending_layer() {
    let spec = NetSpec::parse(
        "28x28x1: dense(32)+relu | dense(16)+relu | dense(10)",
    )
    .unwrap();
    let e = ReprMap::parse_for(&spec, "FI(6,8)||float32").unwrap_err();
    assert!(e.contains("layer 2/3") && e.contains("empty segment"),
            "{e}");
    let e = ReprMap::parse_for(&spec, "FI(6,8)|WAT(9)|float32")
        .unwrap_err();
    assert!(e.contains("layer 2/3") && e.contains("WAT(9)"), "{e}");
    let e = ReprMap::parse_for(&spec, "FI(6,8)|float32").unwrap_err();
    assert!(e.contains("expected 1 or 3"), "{e}");
}
