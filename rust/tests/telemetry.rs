//! Tier-1 acceptance for `lop::telemetry`: bucket math against a
//! scalar oracle, concurrent record/merge conservation, span RAII
//! (nesting and panic unwind), snapshot JSON round-trip, and the
//! serving accounting identity read through registry counters alone.
//!
//! The trace flag is process-global, so exactly one test here owns
//! it ([`spans_nest_and_record_on_unwind`]); everything it asserts
//! about global state is monotone (`>=`), and its exact claims read
//! this thread's local stage sums, which no other test can touch.

use lop::coordinator::batcher::{FailureKind, Outcome};
use lop::coordinator::router::OverloadPolicy;
use lop::coordinator::server::{Server, ServerOpts};
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::telemetry::{
    bucket_index, bucket_upper_bound, local_stage_sums, set_trace,
    Histogram, LocalHistogram, MetricValue, Registry, Span, Stage,
    TelemetrySnapshot, BUCKETS, STAGES,
};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

// ---------------------------------------------------------------------
// histogram: bucket boundaries and the [true, 2*true) bound
// ---------------------------------------------------------------------

#[test]
fn bucket_boundaries_match_the_scalar_oracle() {
    // oracle: floor(log2(v)) as the highest-set-bit position, in
    // integer math (a float log2 rounds wrong near 2^53 and above)
    let oracle = |v: u64| (64 - v.max(1).leading_zeros() - 1) as usize;
    for i in 1..64u32 {
        let b = 1u64 << i;
        for v in [b - 1, b, b + 1, b + (b >> 1)] {
            assert_eq!(bucket_index(v), oracle(v), "v={v}");
        }
    }
    assert_eq!(bucket_index(0), 0);
    assert_eq!(bucket_index(1), 0);
    assert_eq!(bucket_index(u64::MAX), 63);
    assert_eq!(bucket_upper_bound(0), 2);
    assert_eq!(bucket_upper_bound(62), 1u64 << 63);
    assert_eq!(bucket_upper_bound(63), u64::MAX);
    // exactly one bucket per value: upper bound of bucket i is the
    // first value that lands in bucket i+1
    for i in 0..62usize {
        let ub = bucket_upper_bound(i);
        assert_eq!(bucket_index(ub - 1), i);
        assert_eq!(bucket_index(ub), i + 1);
    }
    // a single-observation histogram reads exact at every percentile
    // (the max clamp collapses the bucket bound onto the value)
    for v in [1u64, 2, 3, 1023, 1024, 1025, 1 << 40, u64::MAX] {
        let h = Histogram::new();
        h.record(v);
        assert_eq!(h.percentile(50.0), v, "v={v}");
        assert_eq!(h.percentile(100.0), v, "v={v}");
    }
}

#[test]
fn concurrent_recording_and_shard_merges_conserve_counts() {
    // 8 threads, 5000 observations each: even threads batch through a
    // LocalHistogram shard, odd threads hit the shared atomics
    // directly.  The result must equal a single-threaded oracle.
    let shared = Arc::new(Histogram::new());
    let val = |t: u64, i: u64| (i * (t + 1)) % 250_000 + 1;
    std::thread::scope(|scope| {
        for t in 0..8u64 {
            let shared = Arc::clone(&shared);
            scope.spawn(move || {
                if t % 2 == 0 {
                    let mut local = LocalHistogram::new();
                    for i in 1..=5000u64 {
                        local.record(val(t, i));
                    }
                    local.merge_into(&shared);
                    assert_eq!(local.count(), 0, "shard reset on flush");
                } else {
                    for i in 1..=5000u64 {
                        shared.record(val(t, i));
                    }
                }
            });
        }
    });
    let oracle = Histogram::new();
    let mut all: Vec<u64> = Vec::with_capacity(40_000);
    for t in 0..8u64 {
        for i in 1..=5000u64 {
            oracle.record(val(t, i));
            all.push(val(t, i));
        }
    }
    assert_eq!(shared.count(), 40_000);
    assert_eq!(shared.count(), oracle.count());
    assert_eq!(shared.sum(), oracle.sum());
    assert_eq!(shared.max_value(), oracle.max_value());
    assert_eq!(shared.bucket_counts(), oracle.bucket_counts());
    // percentile read-outs respect [true, 2*true) vs the sorted oracle
    all.sort_unstable();
    for p in [50.0, 99.0, 99.9] {
        let rank = ((p / 100.0) * all.len() as f64).ceil() as usize;
        let truth = all[rank - 1];
        let read = shared.percentile(p);
        assert!(read >= truth && read < 2 * truth,
                "p{p}: read {read} vs true {truth}");
    }
    assert_eq!(shared.percentile(100.0), *all.last().unwrap());
}

// ---------------------------------------------------------------------
// spans: nesting and RAII on panic (sole owner of the trace flag)
// ---------------------------------------------------------------------

fn idx(s: Stage) -> usize {
    STAGES.iter().position(|&x| x == s).unwrap()
}

#[test]
fn spans_nest_and_record_on_unwind() {
    // traced off: entering a span records nothing on this thread
    set_trace(false);
    let base = local_stage_sums();
    {
        let _s = Span::enter(Stage::BatchAssemble);
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(local_stage_sums(), base, "untraced span recorded");

    set_trace(true);
    // nesting: the outer span's scope encloses the inner one, so its
    // recorded time must be at least the inner stage's
    let base = local_stage_sums();
    {
        let _outer = Span::enter(Stage::BatchAssemble);
        std::thread::sleep(Duration::from_millis(4));
        {
            let _inner = Span::enter(Stage::PlanLookup);
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let after = local_stage_sums();
    let outer = after[idx(Stage::BatchAssemble)]
        - base[idx(Stage::BatchAssemble)];
    let inner =
        after[idx(Stage::PlanLookup)] - base[idx(Stage::PlanLookup)];
    assert!(inner >= 1_000, "inner span lost time: {inner}us");
    assert!(outer >= inner,
            "outer {outer}us must enclose inner {inner}us");

    // RAII on panic: a span dropped during unwind still records
    let base = local_stage_sums();
    let r = catch_unwind(AssertUnwindSafe(|| {
        let _s = Span::enter(Stage::GemmEpilogue);
        std::thread::sleep(Duration::from_millis(2));
        panic!("batch blew up mid-stage");
    }));
    assert!(r.is_err());
    let after = local_stage_sums();
    let us = after[idx(Stage::GemmEpilogue)]
        - base[idx(Stage::GemmEpilogue)];
    assert!(us >= 1_000, "unwound span lost time: {us}us");
    set_trace(false);
}

// ---------------------------------------------------------------------
// snapshots: JSON round-trip and structural invariants
// ---------------------------------------------------------------------

#[test]
fn snapshot_round_trips_and_orders_percentiles() {
    let r = Registry::new();
    r.counter("serving.submitted").add(512);
    r.gauge("plan_cache.resident_panels").set_at(9, 6);
    let h = r.histogram("serving.latency_us");
    for i in 1..=500u64 {
        h.record(i * 37 % 90_000 + 1);
    }
    h.record(2_000_000); // one straggler to spread the tail
    let snap = r.snapshot();

    let text = snap.to_json();
    let back = TelemetrySnapshot::from_json(&text).unwrap();
    assert_eq!(snap, back, "JSON round-trip must be lossless");

    match back.get("serving.latency_us") {
        Some(MetricValue::Histogram(hs)) => {
            assert_eq!(hs.count, 501);
            assert_eq!(hs.cumulative.len(), BUCKETS);
            assert_eq!(*hs.cumulative.last().unwrap(), hs.count);
            assert!(hs.cumulative.windows(2).all(|w| w[0] <= w[1]),
                    "cumulative buckets must be monotone");
            assert!(hs.p50 <= hs.p99 && hs.p99 <= hs.p999
                        && hs.p999 <= hs.max,
                    "p50 {} p99 {} p999 {} max {}",
                    hs.p50, hs.p99, hs.p999, hs.max);
            assert_eq!(hs.p999, 2_000_000, "max clamp: exact tail");
        }
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(back.get("serving.submitted"),
               Some(&MetricValue::Counter(512)));
}

// ---------------------------------------------------------------------
// the serving accounting identity, via registry counters alone
// ---------------------------------------------------------------------

fn small_spec() -> NetSpec {
    NetSpec::parse("28x28x1: dense(8)+relu | dense(10)").unwrap()
}

fn start(opts: ServerOpts, seed: u64) -> Server {
    let model = Arc::new(Model::synthetic(small_spec(), seed));
    Server::start_with_model(opts, model, None).unwrap()
}

fn counter_of(snap: &TelemetrySnapshot, name: &str) -> u64 {
    match snap.get(name) {
        Some(MetricValue::Counter(v)) => *v,
        other => panic!("{name}: expected a counter, got {other:?}"),
    }
}

#[test]
fn accounting_identity_holds_through_the_registry() {
    // Shed leg: capacity 1 with a held queue (max_batch 2, max_wait
    // 5s) deterministically sheds 3 of 4 accepted requests; shutdown
    // flushes the held one to completion.
    let spec = small_spec();
    let opts = ServerOpts {
        configs: vec![ReprMap::parse_for(&spec, "FI(6,8)").unwrap()],
        max_batch: 2,
        max_wait: Duration::from_secs(5),
        queue_capacity: 1,
        engine_workers: 1,
        engine_gemm_threads: 1,
        use_pjrt: false,
        overload: OverloadPolicy::Shed,
        ..ServerOpts::default()
    };
    let server = start(opts, 23);
    let (tx, rx) = channel();
    for _ in 0..4 {
        server.router.submit(0, vec![0.1; 784], None, tx.clone())
            .unwrap();
    }
    drop(tx);
    for _ in 0..3 {
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r.outcome, Outcome::Error(FailureKind::Shed));
    }
    let metrics = server.metrics.clone();
    server.shutdown().unwrap();
    assert!(rx.recv_timeout(Duration::from_secs(60)).unwrap().is_ok());

    // read every term from the exported snapshot, not typed fields —
    // the registry is the system of record
    let snap = metrics.snapshot();
    let c = |name: &str| counter_of(&snap, name);
    assert_eq!(c("serving.submitted"), 4);
    assert_eq!(c("serving.shed"), 3);
    assert_eq!(c("serving.completed"), 1);
    assert_eq!(
        c("serving.submitted"),
        c("serving.completed") + c("serving.shed")
            + c("serving.expired") + c("serving.backend_failures"),
        "every accepted request must resolve exactly once"
    );

    // Backend leg: injected forward failures resolve as
    // backend_failures and keep the identity intact.
    let spec = small_spec();
    let opts = ServerOpts {
        configs: vec![ReprMap::parse_for(&spec, "FI(6,8)").unwrap()],
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_capacity: 64,
        engine_workers: 1,
        engine_gemm_threads: 1,
        use_pjrt: false,
        overload: OverloadPolicy::Reject,
        inject_backend_failures: true,
        ..ServerOpts::default()
    };
    let server = start(opts, 29);
    let (tx, rx) = channel();
    for _ in 0..5 {
        server.router.submit(0, vec![0.1; 784], None, tx.clone())
            .unwrap();
    }
    drop(tx);
    for _ in 0..5 {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.outcome, Outcome::Error(FailureKind::Backend));
    }
    let metrics = server.metrics.clone();
    server.shutdown().unwrap();
    let snap = metrics.snapshot();
    let c = |name: &str| counter_of(&snap, name);
    assert_eq!(c("serving.backend_failures"), 5);
    assert_eq!(c("serving.completed"), 0);
    assert_eq!(
        c("serving.submitted"),
        c("serving.completed") + c("serving.shed")
            + c("serving.expired") + c("serving.backend_failures")
    );
    // failures stay out of the latency histogram
    match snap.get("serving.latency_us") {
        Some(MetricValue::Histogram(h)) => assert_eq!(h.count, 0),
        other => panic!("unexpected {other:?}"),
    }
}
