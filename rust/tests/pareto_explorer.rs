//! Acceptance suite for the surrogate-guided Pareto explorer:
//!
//! * dominance pruning against a hand-computed 3-objective front;
//! * surrogate-vs-simulated agreement, pinned *exactly* via label
//!   distillation (labels := the float32 model's own predictions, so
//!   baseline accuracy is 1.0 and measured accuracy is literally
//!   `1 - flip_fraction` — the quantity the sensitivity profile
//!   measures on the same images);
//! * the headline acceptance claim: on the paper DCNN the explorer
//!   spends strictly fewer full-net simulations than exhaustive
//!   enumeration while its front dominates-or-ties every exhaustively
//!   found point;
//! * `ParetoFront` JSON round-trip of an explorer-produced artifact;
//! * `best_within` edge cases (empty front, unmeetable budget, ties);
//! * the full `serve --auto` startup path over a hermetic synthetic
//!   dataset on a non-paper topology.
//!
//! Everything is hermetic: synthetic weights + synthetic digits,
//! engine backend, no `make artifacts`.

use lop::approx::arith::ArithKind;
use lop::coordinator::eval::Evaluator;
use lop::coordinator::explorer::Explorer;
use lop::coordinator::pareto::{
    auto_config, distill_labels, pareto_front_indices, CostModel,
    ParetoFront, ParetoPoint, SensitivityProfile,
};
use lop::coordinator::server::{Server, ServerOpts};
use lop::data::loader::{Dataset, Split};
use lop::data::synth;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::numeric::FixedPoint;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

fn fi(i: u32, f: u32) -> ArithKind {
    ArithKind::FixedExact(FixedPoint::new(i, f))
}

fn synth_dataset(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    let (tr_imgs, tr_labels) = synth::generate(n_train, seed);
    let (te_imgs, te_labels) = synth::generate(n_test, seed + 1);
    Dataset {
        h: 28,
        w: 28,
        train: Split { images: tr_imgs, labels: tr_labels },
        test: Split { images: te_imgs, labels: te_labels },
    }
}

/// Model + evaluator over *distilled* labels: the float32 net's own
/// predictions are ground truth, so its subset accuracy is exactly 1
/// and every quantized config's accuracy is exactly
/// `1 - prediction_flip_fraction` — making the additive surrogate
/// exact for single-layer perturbations.
fn distilled_evaluator(spec: &NetSpec, seed: u64, subset: usize)
                       -> Evaluator {
    let model = Model::synthetic(spec.clone(), seed);
    let mut ds = synth_dataset(48, 16, seed + 100);
    distill_labels(&model, &mut ds, 1);
    Evaluator::new(model, None, ds, subset, 1)
}

#[test]
fn dominance_pruning_matches_a_hand_computed_front() {
    // minimized [acc_loss, latency, hw]; front computed by hand:
    //   a: best loss          d: dominated by b (worse everywhere)
    //   b: balanced           e: dominated by c (loss and hw worse,
    //   c: best hw               latency equal)
    //   f: best latency
    let pts = [
        [0.00, 40.0, 0.9], // a — front
        [0.05, 30.0, 0.5], // b — front
        [0.20, 50.0, 0.2], // c — front
        [0.10, 45.0, 0.7], // d — dominated by b
        [0.30, 50.0, 0.4], // e — dominated by c
        [0.25, 10.0, 0.8], // f — front (fastest)
    ];
    assert_eq!(pareto_front_indices(&pts), vec![0, 1, 2, 5]);
}

#[test]
fn surrogate_predictions_are_monotone_in_measured_drops() {
    // a profile with strictly decreasing drops as precision grows
    // must predict strictly non-decreasing accuracy — the ordering
    // the simulated points later confirm
    let profile = SensitivityProfile::from_drops(vec![vec![
        (fi(4, 4), 0.40),
        (fi(4, 6), 0.20),
        (fi(4, 8), 0.05),
        (fi(4, 10), 0.00),
    ]]);
    let spec = NetSpec::parse("28x28x1: dense(10)").unwrap();
    let mut last = -1.0;
    for f in [4, 6, 8, 10] {
        let cfg = ReprMap::uniform_for(&spec, fi(4, f));
        let pred = profile.predict(1.0, &cfg);
        assert!(pred >= last,
                "prediction must not degrade as f grows: {pred} after \
                 {last}");
        last = pred;
    }
    assert_eq!(last, 1.0, "a zero-drop config predicts the baseline");
}

/// The acceptance criterion, made deterministic: paper DCNN topology,
/// three layers pinned to float32 and one varied over 4 fixed-point
/// candidates (space = 4).  With distilled labels and calibration
/// batch == evaluation subset the surrogate is exact, so the explorer
/// must (a) simulate strictly fewer than 4 configs, and (b) produce a
/// front that dominates-or-ties every exhaustively evaluated point.
#[test]
fn paper_dcnn_front_beats_exhaustive_with_fewer_sims() {
    let spec = NetSpec::paper_dcnn();
    let mut ev = distilled_evaluator(&spec, 3, 16);
    let fc2 = vec![fi(4, 4), fi(4, 6), fi(4, 8), fi(4, 10)];
    let candidates = vec![
        vec![ArithKind::Float32],
        vec![ArithKind::Float32],
        vec![ArithKind::Float32],
        fc2.clone(),
    ];

    let front = Explorer::new(spec.clone())
        .candidates(candidates.clone())
        .calibration(16)
        .max_sims(2)
        .run(&mut ev)
        .unwrap();

    assert_eq!(front.space(), 4);
    assert!(front.sims() < 4,
            "must simulate strictly fewer configs than exhaustive \
             enumeration (sims = {})", front.sims());
    assert!(front.sims() > 0);
    assert_eq!(front.baseline_accuracy(), 1.0,
               "distilled labels make the float32 baseline exact");

    // every simulated point's measured accuracy equals its surrogate
    // prediction exactly (the distillation construction)
    let mut simulated = 0usize;
    for p in front.points() {
        if p.simulated {
            simulated += 1;
            assert!((p.accuracy - p.est_accuracy).abs() < 1e-9,
                    "{}: measured {} vs predicted {}",
                    p.repr_map.name(), p.accuracy, p.est_accuracy);
        }
    }
    assert_eq!(simulated, front.sims());

    // exhaustive ground truth: evaluate all 4 configs for real and
    // score them with the same cost model
    let cost = CostModel::analytic(&spec, &candidates);
    for k in fc2 {
        let mut cfg =
            ReprMap::uniform_for(&spec, ArithKind::Float32);
        cfg.set(3, k);
        let acc = ev.accuracy(&cfg).unwrap();
        let lat = cost.latency_ns(&cfg);
        let hw = cost.hw_cost(&cfg);
        assert!(front.dominates_or_ties(acc, lat, hw),
                "front must dominate-or-tie exhaustive point {} \
                 (acc {acc}, lat {lat}, hw {hw})",
                cfg.name());
    }
}

#[test]
fn explorer_front_round_trips_through_json() {
    let spec = NetSpec::parse(
        "28x28x1: dense(16)+relu | dense(10)",
    )
    .unwrap();
    let mut ev = distilled_evaluator(&spec, 7, 16);
    let front = Explorer::new(spec.clone())
        .candidates(vec![
            vec![ArithKind::Float32, fi(4, 6)],
            vec![ArithKind::Float32, fi(4, 8)],
        ])
        .calibration(16)
        .max_sims(2)
        .run(&mut ev)
        .unwrap();
    assert!(!front.points().is_empty());

    let json = front.to_json();
    let back = ParetoFront::from_json(&json).unwrap();
    assert_eq!(back.points(), front.points(),
               "f64 Display round-trips bit-exactly");
    assert_eq!(back.spec(), front.spec());
    assert_eq!(back.sims(), front.sims());
    assert_eq!(back.space(), front.space());
    assert_eq!(back.baseline_accuracy(), front.baseline_accuracy());
    assert_eq!(back.cost_source(), front.cost_source());
    // and the artifact is loadable JSON for the CI gate's parser
    assert!(json.contains("\"artifact\": \"pareto_front\""));
}

#[test]
fn best_within_edge_cases() {
    let spec = NetSpec::parse("28x28x1: dense(10)").unwrap();
    let point = |f: u32, acc: f64, lat: f64, hw: f64| ParetoPoint {
        repr_map: ReprMap::uniform_for(&spec, fi(4, f)),
        accuracy: acc,
        est_accuracy: acc,
        est_latency: lat,
        hw_cost: hw,
        simulated: true,
    };

    // empty front: nothing qualifies, auto_config reports emptiness
    let empty = ParetoFront::from_points(&spec, vec![], 1.0, 0, 0,
                                         "analytic");
    assert!(empty.best_within(0.0).is_none());
    let e = auto_config(&empty, &spec, 0.5).unwrap_err();
    assert!(format!("{e}").contains("empty"), "{e}");

    let front = ParetoFront::from_points(
        &spec,
        vec![
            point(4, 0.70, 100.0, 0.2),
            point(6, 0.90, 150.0, 0.2), // hw tie with f=8, lower lat
            point(8, 0.90, 200.0, 0.2),
            point(10, 0.99, 400.0, 0.8),
        ],
        1.0,
        4,
        16,
        "analytic",
    );
    // budget tighter than every point -> None
    assert!(front.best_within(0.995).is_none());
    // loose budget -> the cheapest point outright
    assert_eq!(front.best_within(0.0).unwrap().repr_map,
               ReprMap::uniform_for(&spec, fi(4, 4)));
    // hw-cost tie at 0.9 -> the lower-latency point wins
    let b = front.best_within(0.9).unwrap();
    assert_eq!(b.repr_map, ReprMap::uniform_for(&spec, fi(4, 6)));
    assert_eq!(b.est_latency, 150.0);
    // a budget exactly on a point's accuracy is met (EPS tolerance)
    assert!(front.best_within(0.99).is_some());
}

#[test]
fn explorer_rejects_malformed_candidate_sets() {
    let spec = NetSpec::parse(
        "28x28x1: dense(16)+relu | dense(10)",
    )
    .unwrap();
    let mut ev = distilled_evaluator(&spec, 11, 8);
    // wrong arity: one set for a two-layer spec
    let e = Explorer::new(spec.clone())
        .candidates(vec![vec![fi(4, 6)]])
        .run(&mut ev)
        .unwrap_err();
    assert!(format!("{e}").contains("1 candidate sets"), "{e}");
    // empty per-layer set names the layer
    let e = Explorer::new(spec.clone())
        .candidates(vec![vec![fi(4, 6)], vec![]])
        .run(&mut ev)
        .unwrap_err();
    assert!(format!("{e}").contains("layer 2/2"), "{e}");
    // spec mismatch against the evaluator is caught up front
    let other = NetSpec::parse("28x28x1: dense(10)").unwrap();
    let e = Explorer::new(other).run(&mut ev).unwrap_err();
    assert!(format!("{e}").contains("does not match"), "{e}");
}

/// The full `serve --auto` startup path, hermetically: explore a
/// non-paper topology, write the artifact, re-load it the way the CLI
/// does, pick the cheapest config meeting the budget, and serve real
/// requests with it.
#[test]
fn serve_auto_boots_from_an_emitted_front() {
    let spec = NetSpec::parse(
        "28x28x1: dense(16)+relu | dense(10)",
    )
    .unwrap();
    let seed = 21;
    let mut ev = distilled_evaluator(&spec, seed, 16);
    let front = Explorer::new(spec.clone())
        .candidates(vec![
            vec![ArithKind::Float32, fi(4, 6), fi(4, 10)],
            vec![ArithKind::Float32, fi(4, 8)],
        ])
        .calibration(16)
        .max_sims(3)
        .budget(0.5)
        .run(&mut ev)
        .unwrap();
    assert!(!front.points().is_empty());

    // write + re-load the artifact exactly as `lop serve --auto` does
    let path = std::env::temp_dir().join(format!(
        "lop_pareto_front_test_{}.json",
        std::process::id()
    ));
    std::fs::write(&path, front.to_json()).unwrap();
    let loaded =
        ParetoFront::from_json(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
    std::fs::remove_file(&path).ok();

    // budget = the weakest point's accuracy, so selection always has
    // at least one candidate and picks the cheapest meeting it
    let budget = loaded
        .points()
        .iter()
        .map(|p| p.accuracy)
        .fold(f64::INFINITY, f64::min);
    let chosen = auto_config(&loaded, &spec, budget).unwrap();
    let cheapest_ok = loaded.best_within(budget).unwrap();
    assert_eq!(chosen, cheapest_ok.repr_map);

    // an unmeetable budget refuses with the best available accuracy
    assert!(auto_config(&loaded, &spec, 1.0 + 1e-6).is_err());
    // a different topology refuses even with a met budget
    let other = NetSpec::parse("28x28x1: dense(10)").unwrap();
    let e = auto_config(&loaded, &other, budget).unwrap_err();
    assert!(format!("{e}").contains("explored on"), "{e}");

    // boot a real server on the chosen config (same synthetic seed =
    // same weights the explorer measured) and serve requests
    let sopts = ServerOpts {
        configs: vec![chosen],
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_capacity: 1_024,
        engine_workers: 1,
        engine_gemm_threads: 1,
        use_pjrt: false,
        ..ServerOpts::default()
    };
    let server = Server::start_with_model(
        sopts,
        Arc::new(Model::synthetic(spec.clone(), seed)),
        None,
    )
    .unwrap();
    let (images, _) = synth::generate(8, 99);
    let (tx, rx) = channel();
    for i in 0..8 {
        let img: Vec<f32> = images[i * 784..(i + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        server.router.submit(0, img, None, tx.clone()).unwrap();
    }
    drop(tx);
    for _ in 0..8 {
        let r = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("response stream ended early");
        assert!(r.pred().expect("serving failed") < 10);
    }
    server.shutdown().unwrap();
}
