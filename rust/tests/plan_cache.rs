//! Hermetic suite for the shared prepared-net cache
//! (`coordinator::plan_cache`) — the PR-4 contracts:
//!
//! * **single-flight**: N workers requesting one config concurrently
//!   produce exactly one `Model::prepare` — one weight-pack operation
//!   per layer on the *global* counter — and share one `Arc`;
//! * **byte-capped LRU**: residency never exceeds the cap by more
//!   than the most recent network, the least-recently-*used* config
//!   is evicted first;
//! * **determinism across eviction**: an evicted-then-refetched
//!   config re-prepares to bit-identical outputs;
//! * **worker-count invariance**: `packed_panel_stats` (prepare count,
//!   resident panel bytes) for K configs is identical at 1 and 4
//!   engine workers — the acceptance criterion, exercised through
//!   real `Server` worker pools over `Server::start_with_model`.
//!
//! Tests serialize on a file-local mutex: the harness runs a binary's
//! tests concurrently in one process, and the exact global
//! `weight_pack_count_global` deltas asserted here must not see
//! sibling tests packing.

use lop::coordinator::plan_cache::PlanCache;
use lop::coordinator::server::{Server, ServerOpts};
use lop::data::synth;
use lop::nn::gemm::pack::weight_pack_count_global;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use std::sync::mpsc::channel;
use std::sync::{Arc, Barrier, Mutex, MutexGuard};
use std::time::Duration;

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // a sibling test panicking while holding the lock only poisons
    // it; the serialization itself is still valid
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn cfg(s: &str) -> ReprMap {
    ReprMap::parse_for(&NetSpec::paper_dcnn(), s).unwrap()
}

fn paper(seed: u64) -> Arc<Model> {
    Arc::new(Model::synthetic(NetSpec::paper_dcnn(), seed))
}

/// Resident panel bytes of one prepared net for `c` (probe cache).
fn bytes_of(model: &Arc<Model>, c: &ReprMap) -> usize {
    let probe = PlanCache::new(model.clone());
    probe.get(c);
    probe.stats().resident_bytes
}

#[test]
fn single_flight_prepares_once_under_contention() {
    let _g = lock();
    let cache = Arc::new(PlanCache::new(paper(11)));
    // mixed config: element panels, DRUM conditioning, float lattice
    // AND the binary bitmap path all behind one single-flight entry
    let c = cfg("FI(6,8)|H(6,8,6)|FL(4,9)|binxnor");
    let packs_before = weight_pack_count_global();

    const N: usize = 8;
    let barrier = Arc::new(Barrier::new(N));
    let mut handles = Vec::new();
    for _ in 0..N {
        let cache = cache.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            barrier.wait(); // maximize get() contention
            cache.get(&c)
        }));
    }
    let nets: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    // one weight-pack operation per layer, process-wide — the N - 1
    // losers of the single-flight race packed nothing
    assert_eq!(
        weight_pack_count_global() - packs_before,
        4,
        "contended prepare must condition each layer exactly once"
    );
    for net in &nets[1..] {
        assert!(Arc::ptr_eq(&nets[0], net),
                "all workers must share one Arc<PreparedNet>");
    }
    let s = cache.stats();
    assert_eq!(s.prepares, 1);
    assert_eq!(s.misses, 1);
    assert_eq!(s.hits, N as u64 - 1);
    assert!(s.inflight_waits <= N as u64 - 1);
    assert_eq!(s.resident_configs, 1);
    assert_eq!(s.resident_panels, 4);
}

#[test]
fn lru_eviction_respects_byte_cap() {
    let _g = lock();
    let model = paper(12);
    // same provider family -> every net has identical panel bytes
    let (a, b, c) = (cfg("FI(6,8)"), cfg("FI(5,8)"), cfg("FI(4,8)"));
    let one = bytes_of(&model, &a);
    assert!(one > 0);

    // room for two networks, not three
    let cache = PlanCache::with_capacity(model, one * 2 + one / 2);
    cache.get(&a);
    cache.get(&b);
    assert_eq!(cache.stats().evictions, 0, "two nets fit the cap");
    cache.get(&a); // refresh A: B becomes least-recently-used
    cache.get(&c); // exceeds the cap -> evict exactly B
    let s = cache.stats();
    assert_eq!(s.evictions, 1);
    assert_eq!(s.resident_configs, 2);
    assert!(s.resident_bytes <= one * 2 + one / 2,
            "resident {} bytes exceeds the cap", s.resident_bytes);
    assert!(cache.contains(&a), "recently-used A must survive");
    assert!(cache.contains(&c), "just-inserted C must survive");
    assert!(!cache.contains(&b), "LRU B must be the victim");
}

#[test]
fn evicted_then_refetched_is_bit_identical() {
    let _g = lock();
    let model = paper(13);
    let (a, b) = (cfg("H(6,8,6)"), cfg("FI(6,8)"));
    // cap below two networks: inserting B always evicts A
    let cache =
        PlanCache::with_capacity(model.clone(), bytes_of(&model, &a));
    let x = NetSpec::paper_dcnn().synthetic_input(2, 14);

    let first = cache.get(&a);
    let out1 = first.forward(&x, 1);
    cache.get(&b);
    assert!(!cache.contains(&a), "cap must have evicted A");

    let second = cache.get(&a); // re-prepares from the same Dcnn
    assert!(!Arc::ptr_eq(&first, &second));
    let out2 = second.forward(&x, 1);
    assert_eq!(cache.stats().prepares, 3);
    for (i, (p, q)) in out1.data.iter().zip(&out2.data).enumerate() {
        assert_eq!(p.to_bits(), q.to_bits(),
                   "logit[{i}] diverged across eviction: {p} vs {q}");
    }
}

/// Run a K-config burst through a real engine worker pool and return
/// the shared cache's `(prepare count, resident panel bytes)`.
fn serve_burst(model: &Arc<Model>, workers: usize) -> (u64, usize) {
    let configs =
        vec![cfg("FI(6,8)"), cfg("H(6,8,12)"), cfg("binxnor")];
    let n_cfg = configs.len();
    let server = Server::start_with_model(
        ServerOpts {
            configs,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 1_024,
            engine_workers: workers,
            engine_gemm_threads: 1,
            plan_cache_bytes: 512 * 1024 * 1024,
            use_pjrt: false, // hermetic: no artifacts in tier-1
            ..ServerOpts::default()
        },
        model.clone(),
        None,
    )
    .unwrap();

    let (images, _) = synth::generate(32, 77);
    let (tx, rx) = channel();
    let n = 24;
    for i in 0..n {
        let img: Vec<f32> = images[(i % 32) * 784..(i % 32 + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        server.router.submit(i % n_cfg, img, None, tx.clone()).unwrap();
    }
    drop(tx);
    for _ in 0..n {
        rx.recv_timeout(Duration::from_secs(120))
            .expect("response stream ended early");
    }
    let stats = server.plan_cache.packed_panel_stats();
    server.shutdown().expect("a serving worker panicked");
    stats
}

#[test]
fn packed_panel_stats_invariant_across_worker_counts() {
    let _g = lock();
    let model = paper(15);
    let at1 = serve_burst(&model, 1);
    let at4 = serve_burst(&model, 4);
    assert_eq!(at1.0, 3, "K = 3 configs -> exactly 3 prepares");
    assert!(at1.1 > 0);
    assert_eq!(
        at1, at4,
        "prepare count / resident panel bytes must be a function of \
         the config set, not the worker count"
    );
}
