//! The acceptance suite for the topology-generic API: non-4-layer
//! networks (a 5-layer MLP and a 2-conv net) build via `NetSpec`,
//! prepare with prepacked panels, serve through a real `Server`
//! worker pool via the shared `PlanCache` (structural-fingerprint
//! keys), and complete an explorer DSE pass — all hermetic (synthetic
//! weights + synthetic digits, engine backend, no artifacts).

use lop::approx::arith::ArithKind;
use lop::coordinator::eval::Evaluator;
use lop::coordinator::explorer::{Explorer, ExploreOpts, Family};
use lop::coordinator::plan_cache::PlanCache;
use lop::coordinator::server::{Server, ServerOpts};
use lop::data::loader::{Dataset, Split};
use lop::data::synth;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

fn deep_mlp() -> NetSpec {
    NetSpec::parse(
        "28x28x1: dense(64)+relu | dense(48)+relu | dense(32)+relu | \
         dense(24)+relu | dense(10)",
    )
    .unwrap()
}

fn two_conv() -> NetSpec {
    NetSpec::parse(
        "28x28x1: conv(3x3,8,pad=1)+relu+pool | \
         conv(3x3,16,pad=1)+relu+pool | dense(10)",
    )
    .unwrap()
}

/// A hermetic Dataset over the synthetic digit generator (the LOPD
/// loader's fields are public precisely so suites can do this).
fn synth_dataset(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    let (tr_imgs, tr_labels) = synth::generate(n_train, seed);
    let (te_imgs, te_labels) = synth::generate(n_test, seed + 1);
    Dataset {
        h: 28,
        w: 28,
        train: Split { images: tr_imgs, labels: tr_labels },
        test: Split { images: te_imgs, labels: te_labels },
    }
}

/// Round-robin `n` requests over the server's configs and wait for
/// every response.
fn drive(server: &Server, n: usize, n_cfg: usize, input_len: usize) {
    let (images, _) = synth::generate(32, 99);
    assert_eq!(input_len, 784, "generator renders 28x28x1 digits");
    let (tx, rx) = channel();
    for i in 0..n {
        let img: Vec<f32> = images[(i % 32) * 784..(i % 32 + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        server.router.submit(i % n_cfg, img, None, tx.clone()).unwrap();
    }
    drop(tx);
    for _ in 0..n {
        let r = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("response stream ended early");
        let pred = r.pred().expect("serving failed");
        assert!(pred < 10, "prediction {pred} out of range");
    }
}

fn opts(configs: Vec<ReprMap>, workers: usize) -> ServerOpts {
    ServerOpts {
        configs,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_capacity: 1_024,
        engine_workers: workers,
        engine_gemm_threads: 1,
        plan_cache_bytes: 512 * 1024 * 1024,
        use_pjrt: false, // hermetic: engine backend only
        ..ServerOpts::default()
    }
}

#[test]
fn deep_mlp_serves_through_the_shared_plan_cache() {
    let spec = deep_mlp();
    assert_eq!(spec.len(), 5, "a non-4-layer topology");
    let model = Arc::new(Model::synthetic(spec.clone(), 41));
    let configs = vec![
        ReprMap::parse_for(&spec, "FI(6,8)").unwrap(),
        ReprMap::parse_for(&spec,
                           "FI(6,8)|FL(4,9)|H(6,8,12)|I(5,10)|float32")
            .unwrap(),
    ];
    let n_cfg = configs.len();
    let server =
        Server::start_with_model(opts(configs, 3), model.clone(), None)
            .unwrap();
    drive(&server, 30, n_cfg, spec.input_len());
    let stats = server.plan_cache.stats();
    assert_eq!(stats.prepares, 2, "one prepare per config");
    assert_eq!(stats.resident_configs, 2);
    assert_eq!(stats.resident_panels, 2 * spec.len(),
               "every layer of every config holds prepacked panels");
    assert!(stats.resident_bytes > 0);
    server.shutdown().expect("a serving worker panicked");
}

#[test]
fn two_conv_net_serves_and_matches_direct_inference() {
    let spec = two_conv();
    assert_eq!(spec.len(), 3);
    let model = Arc::new(Model::synthetic(spec.clone(), 43));
    let cfg = ReprMap::parse_for(&spec, "FI(6,8)").unwrap();
    let server = Server::start_with_model(opts(vec![cfg.clone()], 2),
                                          model.clone(), None)
        .unwrap();

    // served predictions must equal direct engine inference
    let (images, _) = synth::generate(8, 7);
    let (tx, rx) = channel();
    for i in 0..8 {
        let img: Vec<f32> = images[i * 784..(i + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        server.router.submit(0, img, None, tx.clone()).unwrap();
    }
    drop(tx);
    let mut preds = vec![usize::MAX; 8];
    for _ in 0..8 {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        preds[r.id as usize] = r.pred().expect("serving failed");
    }
    server.shutdown().unwrap();

    let net = model.prepare(&cfg);
    for (i, want) in preds.iter().enumerate() {
        let img: Vec<f32> = images[i * 784..(i + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        let t = lop::nn::Tensor::new(vec![1, 28, 28, 1], img);
        assert_eq!(*want, net.predict(&t, 1)[0], "image {i}");
    }
}

#[test]
fn plan_cache_keys_are_structural_fingerprints() {
    // one cache per model; the keys carry the topology, so the same
    // uniform config on different specs maps to different keys
    let mlp = deep_mlp();
    let conv = two_conv();
    let mlp_cache =
        PlanCache::new(Arc::new(Model::synthetic(mlp.clone(), 1)));
    let conv_cache =
        PlanCache::new(Arc::new(Model::synthetic(conv.clone(), 1)));
    let mlp_cfg = ReprMap::uniform_for(&mlp, ArithKind::Float32);
    let conv_cfg = ReprMap::uniform_for(&conv, ArithKind::Float32);
    assert_ne!(mlp_cache.key_of(&mlp_cfg),
               conv_cache.key_of(&conv_cfg));
    // and prepared residency follows each spec's own depth
    mlp_cache.get(&mlp_cfg);
    conv_cache.get(&conv_cfg);
    assert_eq!(mlp_cache.stats().resident_panels, mlp.len());
    assert_eq!(conv_cache.stats().resident_panels, conv.len());
}

#[test]
fn router_rejects_wrong_sized_images_for_the_spec() {
    let spec = two_conv();
    let model = Arc::new(Model::synthetic(spec.clone(), 5));
    let cfg = ReprMap::uniform_for(&spec, ArithKind::Float32);
    let server =
        Server::start_with_model(opts(vec![cfg], 1), model, None)
            .unwrap();
    let (tx, _rx) = channel();
    assert!(server.router.submit(0, vec![0.0; 100], None, tx).is_err(),
            "a 100-float image cannot feed a 784-input spec");
    server.shutdown().unwrap();
}

#[test]
fn server_rejects_arity_mismatched_configs_at_startup() {
    let spec = deep_mlp(); // 5 layers
    let model = Arc::new(Model::synthetic(spec, 9));
    let four = ReprMap::uniform(ArithKind::Float32, 4);
    let err = Server::start_with_model(opts(vec![four], 1), model, None)
        .err()
        .expect("4-kind config over a 5-layer spec must not start");
    let msg = format!("{err:#}");
    assert!(msg.contains("4 layers") && msg.contains("5-layer"),
            "{msg}");
}

#[test]
fn explorer_completes_a_dse_pass_on_a_non_paper_topology() {
    let spec = two_conv();
    let model = Model::synthetic(spec.clone(), 47);
    let ds = synth_dataset(64, 48, 1234);
    // WBA ranges straight off the model (one entry per spec layer)
    let x = ds.batch(&ds.train, &(0..16).collect::<Vec<_>>());
    let ranges = model.ranges(&x, 1);
    assert_eq!(ranges.len(), spec.len());

    let mut ev = Evaluator::new(model, None, ds, 32, 1);
    assert_eq!(ev.spec().len(), 3);
    let opts = ExploreOpts {
        accuracy_bound: 0.5, // untrained weights: loose bound
        frac_bci: (4, 5),
        int_headroom: 0,
        families: vec![Family::Fixed],
        second_pass: true,
        ..Default::default()
    };
    let front = Explorer::new(spec.clone())
        .opts(opts)
        .ranges(ranges)
        .max_sims(3)
        .calibration(16)
        .run(&mut ev)
        .unwrap();

    // the search ran over THIS spec's layers, not a hardcoded 4
    assert!(!front.points().is_empty());
    for p in front.points() {
        assert_eq!(p.repr_map.len(), spec.len());
        // candidate generation stayed in the configured family
        for k in p.repr_map.kinds() {
            assert!(
                matches!(k,
                         ArithKind::FixedExact(_) | ArithKind::Float32),
                "layer {k:?}"
            );
        }
    }
    // surrogate pruning held the simulation budget
    assert!(front.sims() <= 3);
    assert!(front.points().iter().any(|p| p.simulated));
    assert!(front.space() >= front.points().len() as u64);
    // the evaluator's shared plan cache held engine nets for the
    // 3-layer spec (3 panels per resident config)
    let stats = ev.plan_cache().stats();
    assert!(stats.resident_configs > 0);
    assert_eq!(stats.resident_panels % spec.len(), 0);
}
