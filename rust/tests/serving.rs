//! Serving-stack integration tests: router → batcher → workers over real
//! artifacts, on both backends.
//!
//! Tests serialize on a file-local mutex: the warm-start test reads
//! the process-wide `weight_pack_count_global` counter, which a
//! concurrently running sibling server would perturb (the harness runs
//! one binary's tests in parallel threads of one process).

use lop::coordinator::server::{Server, ServerOpts};
use lop::data::Dataset;
use lop::nn::gemm::pack::weight_pack_count_global;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::runtime::ArtifactDir;
use std::sync::mpsc::channel;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn cfg(s: &str) -> ReprMap {
    ReprMap::parse_for(&NetSpec::paper_dcnn(), s).unwrap()
}

fn opts(configs: Vec<ReprMap>, use_pjrt: bool) -> ServerOpts {
    ServerOpts {
        configs,
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_capacity: 1_024,
        engine_workers: 2,
        engine_gemm_threads: 1,
        plan_cache_bytes: 512 * 1024 * 1024,
        use_pjrt,
        ..ServerOpts::default()
    }
}

fn test_images(n: usize) -> (Vec<Vec<f32>>, Vec<usize>, Model) {
    let art = ArtifactDir::discover().expect("run `make artifacts`");
    let model =
        Model::load(NetSpec::paper_dcnn(), &art.weights_path()).unwrap();
    let ds = Dataset::load(&art.dataset_path()).unwrap();
    let mut imgs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let t = ds.batch(&ds.test, &[i]);
        imgs.push(t.data);
        labels.push(ds.test.labels[i] as usize);
    }
    (imgs, labels, model)
}

#[test]
fn pjrt_backend_serves_correct_predictions() {
    let _g = lock();
    let (imgs, _, model) = test_images(24);
    let c = cfg("FI(6,8)");
    let server = Server::start(opts(vec![c.clone()], true)).unwrap();
    let (tx, rx) = channel();
    for img in &imgs {
        server.router.submit(0, img.clone(), None, tx.clone()).unwrap();
    }
    drop(tx);
    let mut preds = vec![usize::MAX; imgs.len()];
    for _ in 0..imgs.len() {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        preds[r.id as usize] = r.pred().expect("serving failed");
    }
    server.shutdown().unwrap();

    // must match direct engine inference exactly (argmax level)
    let net = model.prepare(&c);
    for (i, img) in imgs.iter().enumerate() {
        let t = lop::nn::tensor::Tensor::new(vec![1, 28, 28, 1],
                                             img.clone());
        let direct = net.predict(&t, 1)[0];
        assert_eq!(preds[i], direct, "image {i}");
    }
}

#[test]
fn engine_backend_serves_approx_configs() {
    let _g = lock();
    let (imgs, labels, _) = test_images(16);
    let server =
        Server::start(opts(vec![cfg("H(6,8,12)")], true)).unwrap();
    let (tx, rx) = channel();
    for img in &imgs {
        server.router.submit(0, img.clone(), None, tx.clone()).unwrap();
    }
    drop(tx);
    let mut correct = 0;
    for _ in 0..imgs.len() {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        if r.pred() == Some(labels[r.id as usize]) {
            correct += 1;
        }
    }
    server.shutdown().unwrap();
    assert!(correct >= 12, "H(6,8,12) got only {correct}/16 right");
}

#[test]
fn mixed_backends_share_one_server() {
    let _g = lock();
    let (imgs, _, _) = test_images(12);
    let configs = vec![
        cfg("float32"),   // PJRT
        cfg("H(6,8,12)"), // engine
    ];
    let server = Server::start(opts(configs, true)).unwrap();
    let (tx, rx) = channel();
    for (i, img) in imgs.iter().enumerate() {
        server.router.submit(i % 2, img.clone(), None, tx.clone()).unwrap();
    }
    drop(tx);
    let mut got = 0;
    for _ in 0..imgs.len() {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.pred().expect("serving failed") < 10);
        got += 1;
    }
    assert_eq!(got, imgs.len());
    assert!(server.metrics.mean_batch_size() >= 1.0);
    server.shutdown().unwrap();
}

#[test]
fn no_pjrt_falls_back_to_engine_everywhere() {
    let _g = lock();
    let (imgs, _, model) = test_images(8);
    let c = cfg("FI(6,8)");
    let server = Server::start(opts(vec![c.clone()], false)).unwrap();
    let (tx, rx) = channel();
    for img in &imgs {
        server.router.submit(0, img.clone(), None, tx.clone()).unwrap();
    }
    drop(tx);
    let net = model.prepare(&c);
    for _ in 0..imgs.len() {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        let t = lop::nn::tensor::Tensor::new(
            vec![1, 28, 28, 1],
            imgs[r.id as usize].clone(),
        );
        assert_eq!(r.pred().expect("serving failed"),
                   net.predict(&t, 1)[0]);
    }
    server.shutdown().unwrap();
}

#[test]
fn warm_start_skips_reprepare() {
    let _g = lock();
    let (imgs, _, _) = test_images(8);
    // engine-backed config, 2 workers sharing one PlanCache
    let server =
        Server::start(opts(vec![cfg("H(6,8,12)")], false)).unwrap();

    // cold burst: the first batch pays quantization + prepacking once
    let (tx, rx) = channel();
    for img in &imgs[..4] {
        server.router.submit(0, img.clone(), None, tx.clone()).unwrap();
    }
    for _ in 0..4 {
        rx.recv_timeout(Duration::from_secs(120)).unwrap();
    }
    let cold = server.plan_cache.stats();
    assert_eq!(cold.prepares, 1, "cold start prepares exactly once");

    // warm burst: same config, any worker — zero re-preparation and
    // zero weight-side packing anywhere in the process
    let packs_before = weight_pack_count_global();
    for img in &imgs[4..] {
        server.router.submit(0, img.clone(), None, tx.clone()).unwrap();
    }
    drop(tx);
    for _ in 0..4 {
        rx.recv_timeout(Duration::from_secs(120)).unwrap();
    }
    let warm = server.plan_cache.stats();
    assert_eq!(warm.prepares, 1,
               "warm requests must ride the cached PreparedNet");
    assert!(warm.hits > cold.hits);
    assert_eq!(
        weight_pack_count_global(),
        packs_before,
        "a warm-start batch repacked weights somewhere in the pool"
    );
    server.shutdown().unwrap();
}
