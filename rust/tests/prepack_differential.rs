//! Differential suite for the prepacked-weight path (§Perf iteration
//! 7): for every `ArithKind` variant, `GemmPlan::run_prepacked` over
//! cached panels must be *bit-identical* both to the per-call-packing
//! `GemmPlan::run` and to the pre-tiling `reference` oracle, across
//! randomized shapes (including m = 0, k = 0, n = 1 and
//! non-tile-divisible sizes) and thread counts.  On top of the value
//! contract it pins the two structural contracts of the refactor:
//!
//! * **prepack-once**: after `Model::prepare`, `PreparedNet::forward`
//!   performs zero weight-side packing work (observed through
//!   `gemm::pack::weight_pack_count`, a thread-local counter);
//! * **no panel sharing**: panels conditioned under one `ArithKind`
//!   are refused — not silently consumed — by every other kernel or
//!   parameterization.
//!
//! Scale the randomized sweeps with `LOP_PROP_CASES=N`; failures print
//! a replay snippet (seed + case) via `util::prop`.

use lop::approx::arith::ArithKind;
use lop::nn::gemm::pack::weight_pack_count;
use lop::nn::gemm::reference::gemm_reference;
use lop::nn::gemm::{default_threads, select_kernel, GemmPlan};
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::util::prng::Rng;
use lop::util::prop;

/// One representative per `ArithKind` variant plus width variations
/// (same coverage as tests/gemm_differential.rs).
const KINDS: [&str; 11] = [
    "float32",
    "FI(6,8)",
    "FI(3,4)",
    "FI(8,11)",
    "H(6,8,6)",
    "H(8,8,14)",
    "FL(4,9)",
    "FL(5,10)",
    "I(5,10)",
    "I(4,9,2)",
    "binxnor",
];

fn rand_operands(rng: &mut Rng, kind: &ArithKind, m: usize, k: usize,
                 n: usize) -> (Vec<f32>, Vec<f32>) {
    let x: Vec<f32> = (0..m * k)
        .map(|_| {
            if rng.below(4) == 0 {
                0.0
            } else {
                (rng.normal() * 2.0) as f32
            }
        })
        .collect();
    // weights pre-quantized, as the layer contract requires
    let w: Vec<f32> = (0..k * n)
        .map(|_| kind.quantize(rng.normal() as f32))
        .collect();
    (x, w)
}

/// Prepack `w` into a fresh plan and compare `run_prepacked` at each
/// thread count against both `run` and the reference oracle, bitwise.
/// The prepacked output of a *second* call over the same panels must
/// also match the first (cached panels are not consumed or mutated).
fn diff(kind: &ArithKind, x: &[f32], w: &[f32], m: usize, k: usize,
        n: usize, thread_counts: &[usize]) -> Result<(), String> {
    let mut oracle = vec![f32::NAN; m * n];
    gemm_reference(kind, x, w, m, k, n, &mut oracle, 1);
    let mut plan = GemmPlan::new(kind);
    plan.prepack(w, k, n);
    let mut percall = vec![f32::NAN; m * n];
    plan.run(x, w, m, k, n, &mut percall, 1);
    for &threads in thread_counts {
        let mut got = vec![f32::NAN; m * n];
        plan.run_prepacked(x, m, &mut got, threads);
        let mut again = vec![f32::NAN; m * n];
        plan.run_prepacked(x, m, &mut again, threads);
        for (i, &g) in got.iter().enumerate() {
            if g.to_bits() != oracle[i].to_bits() {
                return Err(format!(
                    "{} ({m}x{k}x{n}, threads={threads}): \
                     prepacked[{i}] = {g} ({:#010x}), reference {} \
                     ({:#010x})",
                    kind.name(),
                    g.to_bits(),
                    oracle[i],
                    oracle[i].to_bits()
                ));
            }
            if g.to_bits() != percall[i].to_bits() {
                return Err(format!(
                    "{} ({m}x{k}x{n}, threads={threads}): \
                     prepacked[{i}] = {g}, per-call run gave {}",
                    kind.name(),
                    percall[i]
                ));
            }
            if g.to_bits() != again[i].to_bits() {
                return Err(format!(
                    "{} ({m}x{k}x{n}, threads={threads}): second \
                     prepacked call diverged at [{i}]",
                    kind.name()
                ));
            }
        }
    }
    Ok(())
}

/// Dimension generator biased toward tile/block boundaries.
fn dim(rng: &mut Rng, max: u64, edges: &[usize]) -> usize {
    if rng.below(3) == 0 {
        edges[rng.below(edges.len() as u64) as usize]
    } else {
        rng.below(max + 1) as usize
    }
}

#[test]
fn randomized_shapes_bit_identical() {
    for (ki, ks) in KINDS.iter().enumerate() {
        let kind = ArithKind::parse(ks).unwrap();
        prop::check_msg(
            &format!("prepacked == run == reference ({ks})"),
            0xBEEF + ki as u64,
            24,
            |rng| {
                // m/n edges straddle the MR/NR tiles (4, 8), k edges
                // straddle the 64-bit binary words; ~1 case in 5 is
                // big enough (m*n >= 16384) that the default-threads
                // leg genuinely spawns threads
                let (m, n) = if rng.below(5) == 0 {
                    (64 + rng.below(17) as usize,
                     256 + rng.below(9) as usize)
                } else {
                    (dim(rng, 33, &[0, 1, 3, 4, 5, 8, 9, 16, 32]),
                     dim(rng, 32, &[0, 1, 3, 4, 5, 8, 9, 31]))
                };
                let k = dim(rng, 96, &[0, 1, 2, 63, 64, 65]);
                (m, k, n, rng.next_u64())
            },
            |&(m, k, n, seed)| {
                let mut rng = Rng::new(seed);
                let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
                diff(&kind, &x, &w, m, k, n, &[1, default_threads()])
            },
        );
    }
}

#[test]
fn explicit_edge_shapes_bit_identical() {
    // (m, k, n): empty output, empty reduction, single column, single
    // cell, exact word boundary, word boundary + 1, and shapes that
    // cross the KC = 256 depth blocking — each at >= 2 thread counts
    let shapes = [
        (0, 5, 3),
        (3, 0, 4),
        (5, 7, 1),
        (1, 1, 1),
        (4, 64, 4),
        (8, 129, 9),
        (13, 300, 11),
        (33, 257, 18),
    ];
    let mut rng = Rng::new(17);
    for ks in KINDS {
        let kind = ArithKind::parse(ks).unwrap();
        for &(m, k, n) in &shapes {
            let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
            diff(&kind, &x, &w, m, k, n, &[1, 2, default_threads()])
                .unwrap();
        }
    }
}

#[test]
fn threaded_blocks_bit_identical() {
    // Large enough (m*n >= 16384) that the prepacked path really
    // spawns threads and splits rows across MC blocks; m and n
    // deliberately not divisible by MC/NC/MR/NR, k crosses KC.
    let (m, k, n) = (65, 257, 258);
    let mut rng = Rng::new(18);
    for ks in KINDS {
        let kind = ArithKind::parse(ks).unwrap();
        let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
        diff(&kind, &x, &w, m, k, n, &[1, 2, 3, default_threads()])
            .unwrap();
    }
}

// ---------------------------------------------------------------------------
// panel-identity contracts: panels never cross kernels or configurations
// ---------------------------------------------------------------------------

#[test]
#[should_panic(expected = "packed by kernel")]
fn panels_from_another_kind_are_refused() {
    // FI and H share the i32 panel element type — without the identity
    // check the FI kernel would happily (and wrongly) consume
    // DRUM-conditioned panels.
    let fi = select_kernel(&ArithKind::parse("FI(6,8)").unwrap());
    let h = select_kernel(&ArithKind::parse("H(6,8,6)").unwrap());
    let w = [0.5f32; 12];
    let pw = h.prepack_weights(&w, 4, 3);
    let mut out = [0.0f32; 3];
    fi.run_prepacked(&[1.0; 4], &pw, 1, &mut out, 1);
}

#[test]
#[should_panic(expected = "different `packed-fi` configuration")]
fn panels_from_another_width_are_refused() {
    // same kernel name, different representation widths
    let wide = select_kernel(&ArithKind::parse("FI(6,8)").unwrap());
    let narrow = select_kernel(&ArithKind::parse("FI(3,4)").unwrap());
    let w = [0.5f32; 12];
    let pw = narrow.prepack_weights(&w, 4, 3);
    let mut out = [0.0f32; 3];
    wide.run_prepacked(&[1.0; 4], &pw, 1, &mut out, 1);
}

#[test]
fn two_prepares_with_different_kinds_never_share_panels() {
    // Same weight matrix prepacked under FI(6, 8) and H(6, 8, 6) (same
    // panel element type): each plan must reproduce ITS OWN reference
    // semantics bit-for-bit — any panel sharing between the two
    // `prepare`-style calls would leak one conditioning into the other.
    let (m, k, n) = (9, 37, 11);
    let fi = ArithKind::parse("FI(6,8)").unwrap();
    let h = ArithKind::parse("H(6,8,6)").unwrap();
    let mut rng = Rng::new(19);
    // quantize under the shared FI(6, 8) lattice (H's rep is the same)
    let (x, w) = rand_operands(&mut rng, &fi, m, k, n);
    let mut plan_fi = GemmPlan::new(&fi);
    let mut plan_h = GemmPlan::new(&h);
    plan_fi.prepack(&w, k, n);
    plan_h.prepack(&w, k, n);
    for (kind, plan) in [(&fi, &plan_fi), (&h, &plan_h)] {
        let mut got = vec![f32::NAN; m * n];
        plan.run_prepacked(&x, m, &mut got, 1);
        let mut want = vec![f32::NAN; m * n];
        gemm_reference(kind, &x, &w, m, k, n, &mut want, 1);
        for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), ww.to_bits(),
                       "{}: out[{i}] = {g} vs reference {ww}",
                       kind.name());
        }
    }
}

// ---------------------------------------------------------------------------
// network-level contract: prepare conditions weights exactly once
// ---------------------------------------------------------------------------

#[test]
fn forward_does_zero_weight_packing_after_prepare() {
    let spec = NetSpec::paper_dcnn();
    let model = Model::synthetic(spec.clone(), 23);
    // mixed config covering element panels AND the binary bitmap path
    let cfg =
        ReprMap::parse_for(&spec, "FI(6,8)|H(6,8,6)|FL(4,9)|binxnor")
            .unwrap();
    let x = spec.synthetic_input(1, 24);

    let before_prepare = weight_pack_count();
    let net = model.prepare(&cfg);
    assert_eq!(
        weight_pack_count(),
        before_prepare + 4,
        "prepare conditions each of the 4 layers' weights exactly once"
    );
    let (count, bytes) = net.packed_panel_stats();
    assert_eq!(count, 4);
    assert!(bytes > 0);

    // the acceptance criterion: forwards after prepare do ZERO
    // weight-side pack_b_block / bitmap-encode work (the activation
    // side still packs per call, which the counter ignores)
    let before_forwards = weight_pack_count();
    let a = net.forward(&x, 1);
    let b = net.forward(&x, 1);
    assert_eq!(
        weight_pack_count(),
        before_forwards,
        "forward repacked weights after prepare"
    );
    assert_eq!(a.data, b.data, "forwards over cached panels diverged");

    // and the cached-path output equals a freshly prepared net's
    let c = model.prepare(&cfg).forward(&x, 1);
    assert_eq!(a.data, c.data);
}
