//! Differential suite for the prepacked-weight path (§Perf iteration
//! 7): for every `ArithKind` variant, at every ISA this machine can
//! dispatch to (`isa::detected`), `GemmPlan::run_prepacked` over
//! cached panels must be *bit-identical* to the per-call-packing
//! `GemmPlan::run` (they share one kernel and one packing, FMA or
//! not), and must match the pre-tiling `reference` oracle — bitwise
//! for every kernel except the AVX2+FMA f32 tier, which is held to
//! the documented `fma_f32_bound` — across randomized shapes
//! (including m = 0, k = 0, n = 1 and non-tile-divisible sizes) and
//! thread counts.  On top of the value contract it pins the two
//! structural contracts of the refactor:
//!
//! * **prepack-once**: after `Model::prepare`, `PreparedNet::forward`
//!   performs zero weight-side packing work (observed through
//!   `gemm::pack::weight_pack_count`, a thread-local counter);
//! * **no panel sharing**: panels conditioned under one `ArithKind`
//!   are refused — not silently consumed — by every other kernel,
//!   parameterization, or panel geometry (`tests/isa_dispatch.rs`
//!   additionally pins the cross-forced-ISA refusal).
//!
//! Run under `LOP_FORCE_ISA=scalar` to pin the portable kernels on any
//! machine.  Scale the randomized sweeps with `LOP_PROP_CASES=N`;
//! failures print a replay snippet (seed + case) via `util::prop`.

use lop::approx::arith::ArithKind;
use lop::nn::gemm::pack::weight_pack_count;
use lop::nn::gemm::reference::gemm_reference;
use lop::nn::gemm::{default_threads, fma_f32_bound, isa, select_kernel,
                    GemmPlan, Isa, Kernel};
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::util::prng::Rng;
use lop::util::prop;

/// One representative per `ArithKind` variant plus width variations
/// (same coverage as tests/gemm_differential.rs).
const KINDS: [&str; 11] = [
    "float32",
    "FI(6,8)",
    "FI(3,4)",
    "FI(8,11)",
    "H(6,8,6)",
    "H(8,8,14)",
    "FL(4,9)",
    "FL(5,10)",
    "I(5,10)",
    "I(4,9,2)",
    "binxnor",
];

fn rand_operands(rng: &mut Rng, kind: &ArithKind, m: usize, k: usize,
                 n: usize) -> (Vec<f32>, Vec<f32>) {
    let x: Vec<f32> = (0..m * k)
        .map(|_| {
            if rng.below(4) == 0 {
                0.0
            } else {
                (rng.normal() * 2.0) as f32
            }
        })
        .collect();
    // weights pre-quantized, as the layer contract requires
    let w: Vec<f32> = (0..k * n)
        .map(|_| kind.quantize(rng.normal() as f32))
        .collect();
    (x, w)
}

/// Prepack `w` into a fresh plan at `tier` and compare `run_prepacked`
/// at each thread count against both `run` and the reference oracle.
/// The prepacked output of a *second* call over the same panels must
/// also match the first (cached panels are not consumed or mutated).
fn diff(kind: &ArithKind, tier: Isa, x: &[f32], w: &[f32], m: usize,
        k: usize, n: usize, thread_counts: &[usize])
        -> Result<(), String> {
    let mut oracle = vec![f32::NAN; m * n];
    gemm_reference(kind, x, w, m, k, n, &mut oracle, 1);
    let mut plan = GemmPlan::with_isa(kind, tier);
    plan.prepack(w, k, n);
    let mut percall = vec![f32::NAN; m * n];
    plan.run(x, w, m, k, n, &mut percall, 1);
    let fma = *kind == ArithKind::Float32 && plan.isa() != Isa::Scalar;
    let bound =
        if fma { fma_f32_bound(x, w, m, k, n) } else { Vec::new() };
    for &threads in thread_counts {
        let mut got = vec![f32::NAN; m * n];
        plan.run_prepacked(x, m, &mut got, threads);
        let mut again = vec![f32::NAN; m * n];
        plan.run_prepacked(x, m, &mut again, threads);
        for (i, &g) in got.iter().enumerate() {
            let vs_oracle = if fma {
                (g as f64 - oracle[i] as f64).abs() <= bound[i]
            } else {
                g.to_bits() == oracle[i].to_bits()
            };
            if !vs_oracle {
                return Err(format!(
                    "{} [{}] ({m}x{k}x{n}, threads={threads}): \
                     prepacked[{i}] = {g} ({:#010x}), reference {} \
                     ({:#010x})",
                    kind.name(),
                    plan.kernel_name(),
                    g.to_bits(),
                    oracle[i],
                    oracle[i].to_bits()
                ));
            }
            // prepacked vs per-call (and vs a second prepacked run) is
            // bitwise at every tier: same kernel, same packing
            if g.to_bits() != percall[i].to_bits() {
                return Err(format!(
                    "{} [{}] ({m}x{k}x{n}, threads={threads}): \
                     prepacked[{i}] = {g}, per-call run gave {}",
                    kind.name(),
                    plan.kernel_name(),
                    percall[i]
                ));
            }
            if g.to_bits() != again[i].to_bits() {
                return Err(format!(
                    "{} [{}] ({m}x{k}x{n}, threads={threads}): second \
                     prepacked call diverged at [{i}]",
                    kind.name(),
                    plan.kernel_name()
                ));
            }
        }
    }
    Ok(())
}

/// Dimension generator biased toward tile/block boundaries.
fn dim(rng: &mut Rng, max: u64, edges: &[usize]) -> usize {
    if rng.below(3) == 0 {
        edges[rng.below(edges.len() as u64) as usize]
    } else {
        rng.below(max + 1) as usize
    }
}

#[test]
fn randomized_shapes_match_per_isa() {
    for tier in isa::detected() {
        for (ki, ks) in KINDS.iter().enumerate() {
            let kind = ArithKind::parse(ks).unwrap();
            prop::check_msg(
                &format!(
                    "prepacked == run == reference ({ks} @ {tier})"),
                0xBEEF + ki as u64,
                24,
                |rng| {
                    // m/n edges straddle every MR/NR tile in play (4,
                    // 6, 8, 16), k edges straddle the 64-bit binary
                    // words; ~1 case in 5 is big enough (m*n >= 16384)
                    // that the default-threads leg genuinely spawns
                    // threads
                    let (m, n) = if rng.below(5) == 0 {
                        (64 + rng.below(17) as usize,
                         256 + rng.below(9) as usize)
                    } else {
                        (dim(rng, 33, &[0, 1, 3, 4, 5, 6, 8, 9, 16, 32]),
                         dim(rng, 32, &[0, 1, 3, 4, 5, 8, 9, 16, 17, 31]))
                    };
                    let k = dim(rng, 96, &[0, 1, 2, 63, 64, 65]);
                    (m, k, n, rng.next_u64())
                },
                |&(m, k, n, seed)| {
                    let mut rng = Rng::new(seed);
                    let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
                    diff(&kind, tier, &x, &w, m, k, n,
                         &[1, default_threads()])
                },
            );
        }
    }
}

#[test]
fn explicit_edge_shapes_match_per_isa() {
    // (m, k, n): empty output, empty reduction, single column, single
    // cell, exact word boundary, word boundary + 1, and shapes that
    // cross the KC = 256 depth blocking — each at >= 2 thread counts
    let shapes = [
        (0, 5, 3),
        (3, 0, 4),
        (5, 7, 1),
        (1, 1, 1),
        (4, 64, 4),
        (8, 129, 9),
        (13, 300, 11),
        (33, 257, 18),
    ];
    let mut rng = Rng::new(17);
    for tier in isa::detected() {
        for ks in KINDS {
            let kind = ArithKind::parse(ks).unwrap();
            for &(m, k, n) in &shapes {
                let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
                diff(&kind, tier, &x, &w, m, k, n,
                     &[1, 2, default_threads()])
                    .unwrap();
            }
        }
    }
}

#[test]
fn threaded_blocks_match_per_isa() {
    // Large enough (m*n >= 16384) that the prepacked path really
    // spawns threads and splits rows across blocks; m and n
    // deliberately not divisible by any MC/NC/MR/NR in play, k
    // crosses KC.
    let (m, k, n) = (65, 257, 258);
    let mut rng = Rng::new(18);
    for tier in isa::detected() {
        for ks in KINDS {
            let kind = ArithKind::parse(ks).unwrap();
            let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
            diff(&kind, tier, &x, &w, m, k, n,
                 &[1, 2, 3, default_threads()])
                .unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// panel-identity contracts: panels never cross kernels or configurations
// ---------------------------------------------------------------------------

#[test]
#[should_panic(expected = "packed by kernel")]
fn panels_from_another_kind_are_refused() {
    // FI and H share the i32 panel element type — without the identity
    // check the FI kernel would happily (and wrongly) consume
    // DRUM-conditioned panels.  (select_kernel resolves at the active
    // ISA; the name check fires at every tier.)
    let fi = select_kernel(&ArithKind::parse("FI(6,8)").unwrap());
    let h = select_kernel(&ArithKind::parse("H(6,8,6)").unwrap());
    let w = [0.5f32; 12];
    let pw = h.prepack_weights(&w, 4, 3);
    let mut out = [0.0f32; 3];
    fi.run_prepacked(&[1.0; 4], &pw, 1, &mut out, 1);
}

#[test]
#[should_panic(expected = "configuration")]
fn panels_from_another_width_are_refused() {
    // same kernel name (whatever the active ISA suffixes it to),
    // different representation widths -> cfg_tag mismatch
    let wide = select_kernel(&ArithKind::parse("FI(6,8)").unwrap());
    let narrow = select_kernel(&ArithKind::parse("FI(3,4)").unwrap());
    let w = [0.5f32; 12];
    let pw = narrow.prepack_weights(&w, 4, 3);
    let mut out = [0.0f32; 3];
    wide.run_prepacked(&[1.0; 4], &pw, 1, &mut out, 1);
}

#[test]
fn two_prepares_with_different_kinds_never_share_panels() {
    // Same weight matrix prepacked under FI(6, 8) and H(6, 8, 6) (same
    // panel element type): each plan must reproduce ITS OWN reference
    // semantics bit-for-bit — any panel sharing between the two
    // `prepare`-style calls would leak one conditioning into the other.
    let (m, k, n) = (9, 37, 11);
    let fi = ArithKind::parse("FI(6,8)").unwrap();
    let h = ArithKind::parse("H(6,8,6)").unwrap();
    let mut rng = Rng::new(19);
    // quantize under the shared FI(6, 8) lattice (H's rep is the same)
    let (x, w) = rand_operands(&mut rng, &fi, m, k, n);
    let mut plan_fi = GemmPlan::new(&fi);
    let mut plan_h = GemmPlan::new(&h);
    plan_fi.prepack(&w, k, n);
    plan_h.prepack(&w, k, n);
    for (kind, plan) in [(&fi, &plan_fi), (&h, &plan_h)] {
        let mut got = vec![f32::NAN; m * n];
        plan.run_prepacked(&x, m, &mut got, 1);
        let mut want = vec![f32::NAN; m * n];
        gemm_reference(kind, &x, &w, m, k, n, &mut want, 1);
        for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), ww.to_bits(),
                       "{}: out[{i}] = {g} vs reference {ww}",
                       kind.name());
        }
    }
}

// ---------------------------------------------------------------------------
// network-level contract: prepare conditions weights exactly once
// ---------------------------------------------------------------------------

#[test]
fn forward_does_zero_weight_packing_after_prepare() {
    let spec = NetSpec::paper_dcnn();
    let model = Model::synthetic(spec.clone(), 23);
    // mixed config covering element panels AND the binary bitmap path
    let cfg =
        ReprMap::parse_for(&spec, "FI(6,8)|H(6,8,6)|FL(4,9)|binxnor")
            .unwrap();
    let x = spec.synthetic_input(1, 24);

    let before_prepare = weight_pack_count();
    let net = model.prepare(&cfg);
    assert_eq!(
        weight_pack_count(),
        before_prepare + 4,
        "prepare conditions each of the 4 layers' weights exactly once"
    );
    let (count, bytes) = net.packed_panel_stats();
    assert_eq!(count, 4);
    assert!(bytes > 0);

    // the acceptance criterion: forwards after prepare do ZERO
    // weight-side pack_b_block / bitmap-encode work (the activation
    // side still packs per call, which the counter ignores)
    let before_forwards = weight_pack_count();
    let a = net.forward(&x, 1);
    let b = net.forward(&x, 1);
    assert_eq!(
        weight_pack_count(),
        before_forwards,
        "forward repacked weights after prepare"
    );
    assert_eq!(a.data, b.data, "forwards over cached panels diverged");

    // and the cached-path output equals a freshly prepared net's
    let c = model.prepare(&cfg).forward(&x, 1);
    assert_eq!(a.data, c.data);
}
