//! Numeric-layer property suite on the shared `util::prop` harness:
//! encode/decode round-trips through `quantize` across random
//! bit-widths, the DRUM(t) relative-error bound against the exact
//! multiply, and `quantize_slice` == scalar `quantize` for every
//! representation.  Scale with `LOP_PROP_CASES=N`.

use lop::approx::drum::{drum_mul, DrumMul};
use lop::numeric::{BinXnor, FixedPoint, FloatRep, Representation};
use lop::util::prop;

#[test]
fn fi_roundtrip_through_quantize_random_widths() {
    prop::check_msg(
        "FI decode(encode(x)) == quantize(x), random widths",
        101,
        prop::DEFAULT_CASES,
        |rng| {
            let rep = FixedPoint::new(rng.below(9) as u32,
                                      1 + rng.below(14) as u32);
            // mix in-range, saturating and tiny magnitudes
            let scale = [0.01f64, 1.0, 50.0, 1e4][rng.below(4) as usize];
            (rep, (rng.normal() * scale) as f32)
        },
        |(rep, x)| {
            let want = rep.quantize(*x);
            let got = rep.decode(rep.encode(*x));
            if got.to_bits() == want.to_bits()
                || (got == 0.0 && want == 0.0)
            {
                Ok(())
            } else {
                Err(format!("got {got}, want {want}"))
            }
        },
    );
}

#[test]
fn fl_roundtrip_through_quantize_random_widths() {
    prop::check_msg(
        "FL decode(encode(x)) == quantize(x), random widths",
        102,
        prop::DEFAULT_CASES,
        |rng| {
            let rep = FloatRep::new(2 + rng.below(7) as u32,
                                    1 + rng.below(23) as u32);
            let scale = [1e-6f64, 1.0, 100.0, 1e8][rng.below(4) as usize];
            (rep, (rng.normal() * scale) as f32)
        },
        |(rep, x)| {
            let want = rep.quantize(*x);
            let got = rep.decode(rep.encode(*x));
            if got.to_bits() == want.to_bits()
                || (got == 0.0 && want == 0.0)
            {
                Ok(())
            } else {
                Err(format!("got {got}, want {want}"))
            }
        },
    );
}

#[test]
fn drum_relative_error_bound_vs_exact_multiply() {
    // Each conditioned operand is within (1 ± 2^-(t-1)) of its true
    // value, so the product error is bounded by (1 + 2^-(t-1))^2 - 1.
    prop::check_msg(
        "DRUM(t) product within (1 + 2^-(t-1))^2 - 1 of exact",
        103,
        prop::DEFAULT_CASES,
        |rng| {
            let t = 2 + rng.below(16) as u32;
            let a = rng.below(1 << 24);
            let b = rng.below(1 << 24);
            (a, b, t)
        },
        |&(a, b, t)| {
            let exact = (a as u128) * (b as u128);
            let approx = drum_mul(a, b, t) as u128;
            if exact == 0 {
                return if approx == 0 {
                    Ok(())
                } else {
                    Err(format!("0 * b gave {approx}"))
                };
            }
            let f = 1.0 + (2.0f64).powi(-(t as i32 - 1));
            let bound = f * f - 1.0 + 1e-12;
            let rel = exact.abs_diff(approx) as f64 / exact as f64;
            if rel <= bound {
                Ok(())
            } else {
                Err(format!("rel error {rel} > bound {bound}"))
            }
        },
    );
}

#[test]
fn h_unit_tracks_quantized_product() {
    // End-to-end through the H(i, f, t) datapath: the approximate
    // product stays within the DRUM relative bound of the quantized
    // operands' product, plus the final FI re-quantization half-ulp.
    // Operands stay small enough that saturation cannot engage
    // (|q(x) q(y)| * (1 + bound) < max_value).
    prop::check_msg(
        "H(i, f, t) mul within DRUM bound + half ulp",
        104,
        prop::DEFAULT_CASES,
        |rng| {
            let t = 4 + rng.below(12) as u32;
            let h = DrumMul::new(FixedPoint::new(6, 8), t);
            let x = rng.range_f32(-6.0, 6.0);
            let y = rng.range_f32(-6.0, 6.0);
            (h, x, y)
        },
        |(h, x, y)| {
            let qx = h.rep.quantize(*x) as f64;
            let qy = h.rep.quantize(*y) as f64;
            let got = h.mul(*x, *y) as f64;
            let f = 1.0 + (2.0f64).powi(-(h.t as i32 - 1));
            // slack: the unit rounds the wide product to f32 before
            // re-quantizing (<= 2^-24 relative, ~1e-6 at these
            // magnitudes); the DRUM + half-ulp terms are attainable
            // exactly, so the cushion must cover that double rounding
            let tol = (f * f - 1.0) * (qx * qy).abs()
                + h.rep.ulp() as f64 / 2.0
                + 1e-5;
            if (got - qx * qy).abs() <= tol {
                Ok(())
            } else {
                Err(format!("got {got}, want ~{} (tol {tol})", qx * qy))
            }
        },
    );
}

#[test]
fn quantize_slice_matches_scalar_all_reps() {
    prop::check_msg(
        "quantize_slice == scalar quantize (FI / FL / BinXNOR)",
        105,
        prop::DEFAULT_CASES,
        |rng| {
            let which = rng.below(3);
            let xs: Vec<f32> = (0..16)
                .map(|_| (rng.normal() * 30.0) as f32)
                .collect();
            (which, rng.below(9) as u32, 1 + rng.below(12) as u32, xs)
        },
        |(which, a, b, xs)| {
            let rep: Box<dyn Representation> = match which {
                0 => Box::new(FixedPoint::new(*a, *b)),
                1 => Box::new(FloatRep::new(2 + a % 7, *b)),
                _ => Box::new(BinXnor),
            };
            let mut ys = xs.clone();
            rep.quantize_slice(&mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let want = rep.quantize(*x);
                if want.to_bits() != y.to_bits() {
                    return Err(format!(
                        "{}: slice({x}) = {y}, scalar = {want}",
                        rep.name()
                    ));
                }
            }
            Ok(())
        },
    );
}
