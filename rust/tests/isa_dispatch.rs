//! The forced-dispatch test layer (§Perf iteration 9): pins the ISA
//! dispatch *policy* — which kernel a `GemmPlan` gets, how
//! `LOP_FORCE_ISA` overrides it, how unknown/unsupported tokens fail,
//! and that prepacked panels can never cross a forced-ISA boundary
//! silently.  Value-level per-ISA correctness lives in
//! tests/gemm_differential.rs and tests/prepack_differential.rs; this
//! suite is about *selection*.
//!
//! CI runs this binary twice: once with no override (native dispatch)
//! and once under `LOP_FORCE_ISA=scalar`.  Every test here must pass
//! under both; the env-sensitive assertions read the variable and
//! assert consistency rather than assuming one leg.

use std::panic::{catch_unwind, AssertUnwindSafe};

use lop::approx::arith::ArithKind;
use lop::nn::gemm::isa::{self, Isa, FORCE_ENV};
use lop::nn::gemm::reference::gemm_reference;
use lop::nn::gemm::{kernel_name, kernel_name_isa, select_kernel,
                    select_kernel_isa, GemmPlan, Kernel};
use lop::util::prng::Rng;

/// Every ArithKind family, one representative each.
const KINDS: [&str; 6] =
    ["float32", "FI(6,8)", "H(6,8,6)", "FL(4,9)", "I(5,10)", "binxnor"];

/// The kinds that actually have a SIMD kernel at the Avx2 tier (FL and
/// CFPU keep their scalar kernel at every tier).
fn has_simd_variant(kind: &ArithKind) -> bool {
    !matches!(kind,
              ArithKind::FloatExact(_) | ArithKind::FloatCfpu(_))
}

// ---------------------------------------------------------------------------
// token parsing and resolution
// ---------------------------------------------------------------------------

#[test]
fn unknown_isa_tokens_error_with_the_offending_token() {
    for bogus in ["neon", "avx512", "sse9", "fastest", "scalar2"] {
        let e = Isa::parse(bogus).unwrap_err();
        assert!(e.contains(bogus),
                "parse error must carry the offending token `{bogus}`: \
                 {e}");
        assert!(e.contains("scalar") && e.contains("avx2"),
                "parse error must list valid tokens: {e}");
        // resolve() (what active() runs over LOP_FORCE_ISA) surfaces
        // the same token — a forced run never silently falls back
        let e = isa::resolve(Some(bogus)).unwrap_err();
        assert!(e.contains(bogus), "{e}");
    }
}

#[test]
fn empty_force_token_means_auto_detect() {
    assert_eq!(isa::resolve(None), Ok(isa::detect()));
    assert_eq!(isa::resolve(Some("")), Ok(isa::detect()));
    assert_eq!(isa::resolve(Some("   \t ")), Ok(isa::detect()));
}

#[test]
fn forcing_scalar_always_resolves() {
    // the scalar round-trip works on every machine, which is what lets
    // CI pin the portable kernels on any runner
    assert_eq!(isa::resolve(Some("scalar")), Ok(Isa::Scalar));
    assert_eq!(isa::resolve(Some(" SCALAR ")), Ok(Isa::Scalar));
    assert!(isa::supported(Isa::Scalar));
}

#[test]
fn forcing_an_unsupported_isa_is_an_error_not_a_fallback() {
    if isa::supported(Isa::Avx2) {
        assert_eq!(isa::resolve(Some("avx2")), Ok(Isa::Avx2));
    } else {
        let e = isa::resolve(Some("avx2")).unwrap_err();
        assert!(e.contains("avx2") && e.contains("not supported"),
                "{e}");
    }
}

// ---------------------------------------------------------------------------
// dispatch policy: widest wins, force wins over widest
// ---------------------------------------------------------------------------

#[test]
fn active_isa_honors_the_environment() {
    // CI runs this test once per LOP_FORCE_ISA leg; in-process we
    // assert active() is consistent with however this process was
    // launched (active() memoizes the env read, so setting the var
    // here would be a lie — the launcher decides).
    let active = isa::active();
    match std::env::var(FORCE_ENV) {
        Ok(s) if !s.trim().is_empty() => {
            assert_eq!(active, Isa::parse(&s).unwrap(),
                       "{FORCE_ENV}={s} must pin dispatch");
        }
        _ => {
            assert_eq!(active, isa::detect(),
                       "unforced dispatch must pick the widest \
                        detected ISA");
            assert_eq!(active, *isa::detected().last().unwrap());
        }
    }
}

#[test]
fn default_plans_dispatch_at_the_active_isa() {
    let active = isa::active();
    for ks in KINDS {
        let kind = ArithKind::parse(ks).unwrap();
        let plan = GemmPlan::new(&kind);
        // the kernel's own tier: the active ISA for kinds with a SIMD
        // variant, Scalar for FL/CFPU whose scalar kernel is their
        // widest at every tier
        let want_isa = if has_simd_variant(&kind) {
            active
        } else {
            Isa::Scalar
        };
        assert_eq!(plan.isa(), want_isa, "{ks}");
        assert_eq!(plan.kernel_name(), kernel_name_isa(&kind, active),
                   "{ks}");
        assert_eq!(plan.kernel_name(), kernel_name(&kind), "{ks}");
        // select_kernel (the layer/bench entry point) agrees
        assert_eq!(select_kernel(&kind).name(), plan.kernel_name(),
                   "{ks}");
    }
}

#[test]
fn every_detected_isa_is_constructible_and_correct() {
    // reachability smoke: each tier the dispatcher could pick on this
    // machine builds real kernels whose output matches the reference
    // oracle (bitwise for all these kinds except FMA f32, which the
    // differential suites bound — here we use int/bit kinds only)
    let (m, k, n) = (9, 70, 7);
    let mut rng = Rng::new(41);
    for tier in isa::detected() {
        for ks in ["FI(6,8)", "H(6,8,6)", "binxnor"] {
            let kind = ArithKind::parse(ks).unwrap();
            let x: Vec<f32> =
                (0..m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n)
                .map(|_| kind.quantize(rng.normal() as f32))
                .collect();
            let plan = GemmPlan::with_isa(&kind, tier);
            let mut got = vec![f32::NAN; m * n];
            plan.run(&x, &w, m, k, n, &mut got, 1);
            let mut want = vec![f32::NAN; m * n];
            gemm_reference(&kind, &x, &w, m, k, n, &mut want, 1);
            for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), ww.to_bits(),
                           "{ks}@{tier}: out[{i}] = {g} vs {ww}");
            }
        }
    }
}

#[test]
fn scalar_tier_reports_scalar_names_everywhere() {
    // the LOP_FORCE_ISA=scalar round-trip at the plan layer: a plan
    // pinned to Scalar must report unsuffixed names and Scalar tier
    // for every kind, on every machine
    for ks in KINDS {
        let kind = ArithKind::parse(ks).unwrap();
        let plan = GemmPlan::with_isa(&kind, Isa::Scalar);
        assert_eq!(plan.isa(), Isa::Scalar, "{ks}");
        assert!(!plan.kernel_name().contains('+'),
                "{ks}: scalar kernel name `{}` must carry no ISA \
                 suffix",
                plan.kernel_name());
    }
}

#[test]
fn unsupported_tier_construction_panics() {
    if isa::supported(Isa::Avx2) {
        return; // nothing unsupported to test on this machine
    }
    let kind = ArithKind::parse("FI(6,8)").unwrap();
    let err = catch_unwind(AssertUnwindSafe(|| {
        select_kernel_isa(&kind, Isa::Avx2)
    }))
    .expect_err("building kernels for an unsupported ISA must panic");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("not supported"), "{msg}");
}

// ---------------------------------------------------------------------------
// panel identity across forced ISAs
// ---------------------------------------------------------------------------

#[test]
fn panels_never_cross_a_forced_isa_boundary() {
    // A process forced to one ISA writes panels (e.g. the plan cache);
    // consuming them under a different forced ISA must panic — the
    // panel layout (MR/NR geometry, word tiles) differs per kernel, so
    // a silent mis-multiply would be the failure mode without the
    // identity check.  Names are ISA-suffixed, so the kernel-name
    // check is what fires.
    if !isa::supported(Isa::Avx2) {
        return; // only one tier exists here; cross-ISA is untestable
    }
    let (k, n) = (37, 11);
    let mut rng = Rng::new(42);
    for ks in ["float32", "FI(6,8)", "H(6,8,6)", "binxnor"] {
        let kind = ArithKind::parse(ks).unwrap();
        let w: Vec<f32> = (0..k * n)
            .map(|_| kind.quantize(rng.normal() as f32))
            .collect();
        for (packer, consumer) in
            [(Isa::Scalar, Isa::Avx2), (Isa::Avx2, Isa::Scalar)]
        {
            let pack_kern = select_kernel_isa(&kind, packer);
            let run_kern = select_kernel_isa(&kind, consumer);
            let pw = pack_kern.prepack_weights(&w, k, n);
            let mut out = vec![f32::NAN; n];
            let err = catch_unwind(AssertUnwindSafe(|| {
                run_kern.run_prepacked(&[1.0; 37], &pw, 1, &mut out, 1);
            }))
            .unwrap_err();
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    err.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_default();
            assert!(
                msg.contains("packed by kernel"),
                "{ks}: {packer}->{consumer} panel crossing must \
                 panic with the kernel identity, got: {msg}"
            );
            assert!(
                msg.contains(pack_kern.name())
                    && msg.contains(run_kern.name()),
                "{ks}: panic must name both kernels, got: {msg}"
            );
        }
    }
}

#[test]
fn prepacked_plans_are_isa_consistent() {
    // a plan prepacks with the same kernel it runs — so prepack +
    // run_prepacked at an explicitly pinned tier never trips the
    // identity check, whatever the process's active ISA is
    let (m, k, n) = (3, 20, 5);
    let mut rng = Rng::new(43);
    for tier in isa::detected() {
        for ks in KINDS {
            let kind = ArithKind::parse(ks).unwrap();
            let x: Vec<f32> =
                (0..m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n)
                .map(|_| kind.quantize(rng.normal() as f32))
                .collect();
            let mut plan = GemmPlan::with_isa(&kind, tier);
            plan.prepack(&w, k, n);
            let mut a = vec![f32::NAN; m * n];
            plan.run_prepacked(&x, m, &mut a, 1);
            let mut b = vec![f32::NAN; m * n];
            plan.run(&x, &w, m, k, n, &mut b, 1);
            // same kernel both sides: bitwise, FMA or not
            for (i, (g, ww)) in a.iter().zip(&b).enumerate() {
                assert_eq!(g.to_bits(), ww.to_bits(),
                           "{ks}@{tier}: prepacked[{i}] = {g} vs \
                            per-call {ww}");
            }
        }
    }
}
