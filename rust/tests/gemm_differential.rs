//! Differential suite: the packed, tiled GEMM path must be
//! *bit-identical* to the pre-tiling `reference` kernels for every
//! `ArithKind` variant, across randomized shapes (including m = 0,
//! k = 0, n = 1, non-square, and non-divisible-by-tile sizes) and
//! across thread counts.
//!
//! Scale the randomized sweeps with `LOP_PROP_CASES=N`; failures print
//! a replay snippet (seed + case) via `util::prop`.

use lop::approx::arith::ArithKind;
use lop::nn::gemm::reference::gemm_reference;
use lop::nn::gemm::{default_threads, GemmPlan};
use lop::util::prng::Rng;
use lop::util::prop;

/// One representative per `ArithKind` variant plus width variations
/// (narrow + wide fixed/float, small + large DRUM windows, CFPU tuning
/// widths).
const KINDS: [&str; 11] = [
    "float32",
    "FI(6,8)",
    "FI(3,4)",
    "FI(8,11)",
    "H(6,8,6)",
    "H(8,8,14)",
    "FL(4,9)",
    "FL(5,10)",
    "I(5,10)",
    "I(4,9,2)",
    "binxnor",
];

fn rand_operands(rng: &mut Rng, kind: &ArithKind, m: usize, k: usize,
                 n: usize) -> (Vec<f32>, Vec<f32>) {
    // activations include exact zeros: the reference kernels zero-skip
    // and the packed path does not, so this exercises the proof that
    // skipping is bit-neutral
    let x: Vec<f32> = (0..m * k)
        .map(|_| {
            if rng.below(4) == 0 {
                0.0
            } else {
                (rng.normal() * 2.0) as f32
            }
        })
        .collect();
    // weights pre-quantized, as the layer contract requires
    let w: Vec<f32> = (0..k * n)
        .map(|_| kind.quantize(rng.normal() as f32))
        .collect();
    (x, w)
}

/// Run the packed plan at each thread count and compare every output
/// word against the reference kernels (computed once, single thread).
fn diff(kind: &ArithKind, plan: &GemmPlan, x: &[f32], w: &[f32],
        m: usize, k: usize, n: usize, thread_counts: &[usize])
        -> Result<(), String> {
    let mut want = vec![f32::NAN; m * n];
    gemm_reference(kind, x, w, m, k, n, &mut want, 1);
    for &threads in thread_counts {
        let mut got = vec![f32::NAN; m * n];
        plan.run(x, w, m, k, n, &mut got, threads);
        for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
            if g.to_bits() != ww.to_bits() {
                return Err(format!(
                    "{} ({m}x{k}x{n}, threads={threads}): out[{i}] = \
                     {g} ({:#010x}), reference {ww} ({:#010x})",
                    kind.name(),
                    g.to_bits(),
                    ww.to_bits()
                ));
            }
        }
    }
    Ok(())
}

/// Dimension generator biased toward tile/block boundaries.
fn dim(rng: &mut Rng, max: u64, edges: &[usize]) -> usize {
    if rng.below(3) == 0 {
        edges[rng.below(edges.len() as u64) as usize]
    } else {
        rng.below(max + 1) as usize
    }
}

#[test]
fn randomized_shapes_bit_identical() {
    for (ki, ks) in KINDS.iter().enumerate() {
        let kind = ArithKind::parse(ks).unwrap();
        let plan = GemmPlan::new(&kind);
        prop::check_msg(
            &format!("packed == reference ({ks})"),
            0xD1FF + ki as u64,
            24,
            |rng| {
                // m/n edges straddle the MR/NR tiles (4, 8), k edges
                // straddle the 64-bit binary words; ~1 case in 5 is
                // big enough (m*n >= 16384) that the default-threads
                // leg genuinely spawns threads at a random shape
                let (m, n) = if rng.below(5) == 0 {
                    (64 + rng.below(17) as usize,
                     256 + rng.below(9) as usize)
                } else {
                    (dim(rng, 33, &[0, 1, 3, 4, 5, 8, 9, 16, 32]),
                     dim(rng, 32, &[0, 1, 3, 4, 5, 8, 9, 31]))
                };
                let k = dim(rng, 96, &[0, 1, 2, 63, 64, 65]);
                (m, k, n, rng.next_u64())
            },
            |&(m, k, n, seed)| {
                let mut rng = Rng::new(seed);
                let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
                diff(&kind, &plan, &x, &w, m, k, n,
                     &[1, default_threads()])
            },
        );
    }
}

#[test]
fn explicit_edge_shapes_bit_identical() {
    // (m, k, n): empty output, empty reduction, single column, single
    // cell, exact word boundary, word boundary + 1, and shapes that
    // cross the KC = 256 depth blocking
    let shapes = [
        (0, 5, 3),
        (3, 0, 4),
        (5, 7, 1),
        (1, 1, 1),
        (4, 64, 4),
        (8, 129, 9),
        (13, 300, 11),
        (33, 257, 18),
    ];
    let mut rng = Rng::new(7);
    for ks in KINDS {
        let kind = ArithKind::parse(ks).unwrap();
        let plan = GemmPlan::new(&kind);
        for &(m, k, n) in &shapes {
            let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
            diff(&kind, &plan, &x, &w, m, k, n, &[1]).unwrap();
        }
    }
}

#[test]
fn threaded_blocks_bit_identical() {
    // Large enough (m*n >= 16384) that the packed path really spawns
    // threads and splits rows across MC blocks; m and n deliberately
    // not divisible by MC/NC/MR/NR, k crosses KC.
    let (m, k, n) = (65, 257, 258);
    let mut rng = Rng::new(8);
    for ks in KINDS {
        let kind = ArithKind::parse(ks).unwrap();
        let plan = GemmPlan::new(&kind);
        let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
        diff(&kind, &plan, &x, &w, m, k, n,
             &[1, 2, 3, default_threads()])
            .unwrap();
    }
}
