//! Differential suite: the packed, tiled GEMM path must match the
//! pre-tiling `reference` kernels for every `ArithKind` variant, at
//! **every ISA this machine can dispatch to** (`isa::detected`),
//! across randomized shapes (including m = 0, k = 0, n = 1,
//! non-square, and non-divisible-by-tile sizes) and thread counts.
//!
//! Exactness per kernel (the DESIGN.md §gemm tolerance table):
//! every integer/bit-parallel kernel (fi, drum, binxnor) and every
//! kernel without a SIMD variant (f32 scalar, fl, cfpu) is
//! *bit-identical* to the oracle; the AVX2+FMA f32 kernel — where
//! fused rounding is the point — is pinned by the per-element
//! `fma_f32_bound` instead.
//!
//! Run under `LOP_FORCE_ISA=scalar` to pin the portable kernels on any
//! machine (CI runs both legs).  Scale the randomized sweeps with
//! `LOP_PROP_CASES=N`; failures print a replay snippet (seed + case)
//! via `util::prop`.

use lop::approx::arith::ArithKind;
use lop::nn::gemm::reference::gemm_reference;
use lop::nn::gemm::{default_threads, fma_f32_bound, isa, GemmPlan, Isa};
use lop::util::prng::Rng;
use lop::util::prop;

/// One representative per `ArithKind` variant plus width variations
/// (narrow + wide fixed/float, small + large DRUM windows, CFPU tuning
/// widths).
const KINDS: [&str; 11] = [
    "float32",
    "FI(6,8)",
    "FI(3,4)",
    "FI(8,11)",
    "H(6,8,6)",
    "H(8,8,14)",
    "FL(4,9)",
    "FL(5,10)",
    "I(5,10)",
    "I(4,9,2)",
    "binxnor",
];

fn rand_operands(rng: &mut Rng, kind: &ArithKind, m: usize, k: usize,
                 n: usize) -> (Vec<f32>, Vec<f32>) {
    // activations include exact zeros: the reference kernels zero-skip
    // and the packed path does not, so this exercises the proof that
    // skipping is bit-neutral
    let x: Vec<f32> = (0..m * k)
        .map(|_| {
            if rng.below(4) == 0 {
                0.0
            } else {
                (rng.normal() * 2.0) as f32
            }
        })
        .collect();
    // weights pre-quantized, as the layer contract requires
    let w: Vec<f32> = (0..k * n)
        .map(|_| kind.quantize(rng.normal() as f32))
        .collect();
    (x, w)
}

/// Run the packed plan at each thread count and compare every output
/// word against the reference kernels (computed once, single thread).
/// Bitwise for every kernel except the FMA f32 tier, which is held to
/// `fma_f32_bound` (see module docs).
fn diff(kind: &ArithKind, plan: &GemmPlan, x: &[f32], w: &[f32],
        m: usize, k: usize, n: usize, thread_counts: &[usize])
        -> Result<(), String> {
    let mut want = vec![f32::NAN; m * n];
    gemm_reference(kind, x, w, m, k, n, &mut want, 1);
    let fma = *kind == ArithKind::Float32 && plan.isa() != Isa::Scalar;
    let bound =
        if fma { fma_f32_bound(x, w, m, k, n) } else { Vec::new() };
    for &threads in thread_counts {
        let mut got = vec![f32::NAN; m * n];
        plan.run(x, w, m, k, n, &mut got, threads);
        for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
            let ok = if fma {
                (*g as f64 - *ww as f64).abs() <= bound[i]
            } else {
                g.to_bits() == ww.to_bits()
            };
            if !ok {
                return Err(format!(
                    "{} [{}] ({m}x{k}x{n}, threads={threads}): \
                     out[{i}] = {g} ({:#010x}), reference {ww} \
                     ({:#010x})",
                    kind.name(),
                    plan.kernel_name(),
                    g.to_bits(),
                    ww.to_bits()
                ));
            }
        }
    }
    Ok(())
}

/// Dimension generator biased toward tile/block boundaries.
fn dim(rng: &mut Rng, max: u64, edges: &[usize]) -> usize {
    if rng.below(3) == 0 {
        edges[rng.below(edges.len() as u64) as usize]
    } else {
        rng.below(max + 1) as usize
    }
}

#[test]
fn randomized_shapes_match_reference_per_isa() {
    for tier in isa::detected() {
        for (ki, ks) in KINDS.iter().enumerate() {
            let kind = ArithKind::parse(ks).unwrap();
            let plan = GemmPlan::with_isa(&kind, tier);
            prop::check_msg(
                &format!("packed == reference ({ks} @ {tier})"),
                0xD1FF + ki as u64,
                24,
                |rng| {
                    // m/n edges straddle every MR/NR tile in play (4,
                    // 6, 8, 16), k edges straddle the 64-bit binary
                    // words; ~1 case in 5 is big enough (m*n >= 16384)
                    // that the default-threads leg genuinely spawns
                    // threads at a random shape
                    let (m, n) = if rng.below(5) == 0 {
                        (64 + rng.below(17) as usize,
                         256 + rng.below(9) as usize)
                    } else {
                        (dim(rng, 33, &[0, 1, 3, 4, 5, 6, 8, 9, 16, 32]),
                         dim(rng, 32, &[0, 1, 3, 4, 5, 8, 9, 16, 17, 31]))
                    };
                    let k = dim(rng, 96, &[0, 1, 2, 63, 64, 65]);
                    (m, k, n, rng.next_u64())
                },
                |&(m, k, n, seed)| {
                    let mut rng = Rng::new(seed);
                    let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
                    diff(&kind, &plan, &x, &w, m, k, n,
                         &[1, default_threads()])
                },
            );
        }
    }
}

#[test]
fn explicit_edge_shapes_match_reference_per_isa() {
    // (m, k, n): empty output, empty reduction, single column, single
    // cell, exact word boundary, word boundary + 1, and shapes that
    // cross the KC = 256 depth blocking
    let shapes = [
        (0, 5, 3),
        (3, 0, 4),
        (5, 7, 1),
        (1, 1, 1),
        (4, 64, 4),
        (8, 129, 9),
        (13, 300, 11),
        (33, 257, 18),
    ];
    let mut rng = Rng::new(7);
    for tier in isa::detected() {
        for ks in KINDS {
            let kind = ArithKind::parse(ks).unwrap();
            let plan = GemmPlan::with_isa(&kind, tier);
            for &(m, k, n) in &shapes {
                let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
                diff(&kind, &plan, &x, &w, m, k, n, &[1]).unwrap();
            }
        }
    }
}

#[test]
fn threaded_blocks_match_reference_per_isa() {
    // Large enough (m*n >= 16384) that the packed path really spawns
    // threads and splits rows across blocks; m and n deliberately not
    // divisible by any MC/NC/MR/NR in play, k crosses KC.
    let (m, k, n) = (65, 257, 258);
    let mut rng = Rng::new(8);
    for tier in isa::detected() {
        for ks in KINDS {
            let kind = ArithKind::parse(ks).unwrap();
            let plan = GemmPlan::with_isa(&kind, tier);
            let (x, w) = rand_operands(&mut rng, &kind, m, k, n);
            diff(&kind, &plan, &x, &w, m, k, n,
                 &[1, 2, 3, default_threads()])
                .unwrap();
        }
    }
}
