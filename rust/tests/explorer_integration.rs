//! §4.2 exploration over real artifacts through the [`Explorer`]
//! builder: on the trained paper DCNN and the real MNIST slice, the
//! surrogate-guided search must produce a mutually nondominated front
//! whose budget pick is within the accuracy bound and cheaper than
//! the float32 baseline.  (The surrogate machinery itself has a
//! hermetic suite in `pareto_explorer.rs`; this file pins behavior on
//! real weights, where ranges and sensitivities are not synthetic.)

use lop::approx::arith::ArithKind;
use lop::coordinator::eval::Evaluator;
use lop::coordinator::explorer::{ExploreOpts, Explorer, Family};
use lop::coordinator::pareto::dominates;
use lop::coordinator::ranges::profile_ranges;
use lop::data::Dataset;
use lop::hw::datapath::{Datapath, ARRIA10, N_PE};
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::runtime::{ArtifactDir, ModelRunner};

fn setup(subset: usize) -> (Evaluator, Vec<lop::nn::network::LayerRanges>) {
    let art = ArtifactDir::discover().expect("run `make artifacts`");
    let model =
        Model::load(NetSpec::paper_dcnn(), &art.weights_path()).unwrap();
    let ds = Dataset::load(&art.dataset_path()).unwrap();
    let ranges = profile_ranges(&model, &ds, 500, 0);
    let runner = ModelRunner::new(art).unwrap();
    let model2 = Model::load(NetSpec::paper_dcnn(),
                             &runner.art.weights_path())
        .unwrap();
    (Evaluator::new(model2, Some(runner), ds, subset, 0), ranges)
}

#[test]
fn explorer_front_meets_bound_and_beats_f32_cost() {
    let (mut ev, ranges) = setup(200);
    // the §4.2 bound, expressed as the builder's absolute budget
    let baseline = ev
        .accuracy(&ReprMap::uniform_for(&NetSpec::paper_dcnn(),
                                        ArithKind::Float32))
        .unwrap();
    let budget = baseline * (1.0 - 0.02);
    let sims0 =
        lop::telemetry::global().counter("explorer.sims").get();
    let opts = ExploreOpts {
        accuracy_bound: 0.02,
        frac_bci: (6, 9),
        int_headroom: 1,
        families: vec![Family::Fixed],
        ..Default::default()
    };
    let front = Explorer::new(NetSpec::paper_dcnn())
        .opts(opts)
        .ranges(ranges)
        .budget(budget)
        .max_sims(6)
        .run(&mut ev)
        .unwrap();
    assert!(!front.points().is_empty());
    assert!((front.baseline_accuracy() - baseline).abs() < 1e-9,
            "front baseline {} vs evaluator {baseline}",
            front.baseline_accuracy());

    // accuracy within bound on the evaluation subset
    let pick = front
        .best_within(budget)
        .expect("a config within the 2% bound must be on the front");
    assert!(pick.accuracy >= budget - 1e-9,
            "pick {} acc {} vs budget {budget}",
            pick.repr_map.name(), pick.accuracy);
    // every chosen layer is fixed point and cheaper than float32
    let f32cost = Datapath::synthesize(&ArithKind::Float32, N_PE)
        .explore_cost(&ARRIA10);
    for l in pick.repr_map.kinds() {
        assert!(matches!(l, ArithKind::FixedExact(_)), "layer {l:?}");
        let c = Datapath::synthesize(l, N_PE).explore_cost(&ARRIA10);
        assert!(c < f32cost, "{} not cheaper than float32", l.name());
    }
    // the simulation budget held, and the global `explorer.sims`
    // telemetry series advanced with it (monotone, so >= is race-free
    // against the other tests in this binary)
    assert!(front.sims() >= 1 && front.sims() <= 6,
            "sims {}", front.sims());
    let sims1 =
        lop::telemetry::global().counter("explorer.sims").get();
    assert!(sims1 >= sims0 + front.sims() as u64,
            "explorer.sims {sims0} -> {sims1}, front {}", front.sims());
}

#[test]
fn front_points_are_mutually_nondominated() {
    let (mut ev, ranges) = setup(150);
    let opts = ExploreOpts {
        accuracy_bound: 0.03,
        frac_bci: (5, 8),
        int_headroom: 1,
        families: vec![Family::Fixed],
        ..Default::default()
    };
    let front = Explorer::new(ev.spec().clone())
        .opts(opts)
        .ranges(ranges)
        .max_sims(4)
        .run(&mut ev)
        .unwrap();
    let pts = front.points();
    assert!(!pts.is_empty());
    // the emitted front is re-pruned on final (measured where
    // simulated) vectors: no point may dominate another
    for (i, a) in pts.iter().enumerate() {
        for (j, b) in pts.iter().enumerate() {
            if i == j {
                continue;
            }
            let av = [1.0 - a.accuracy, a.est_latency, a.hw_cost];
            let bv = [1.0 - b.accuracy, b.est_latency, b.hw_cost];
            assert!(!dominates(&av, &bv),
                    "point {i} dominates point {j}: {av:?} vs {bv:?}");
        }
    }
    // provenance: simulated survivors never exceed the spend
    assert!(pts.iter().filter(|p| p.simulated).count() <= front.sims());
    assert!(front.space() >= pts.len() as u64);
}

#[test]
fn integral_bits_respect_ranges() {
    let (mut ev, ranges) = setup(100);
    let opts = ExploreOpts {
        accuracy_bound: 0.05,
        frac_bci: (6, 7),
        int_headroom: 1,
        families: vec![Family::Fixed],
        ..Default::default()
    };
    let front = Explorer::new(ev.spec().clone())
        .opts(opts)
        .ranges(ranges)
        .max_sims(3)
        .run(&mut ev)
        .unwrap();
    assert!(!front.points().is_empty());
    // FC2's profiled range is ~±36, so every candidate (hence every
    // front point) carries >= 6 integral bits; CONV1's ~±1 range
    // lower-bounds near 0, capped by opted headroom (1) plus the
    // fan-in term (5x5x1 -> 2): no point may exceed 5 bits there
    for p in front.points() {
        match (p.repr_map.kind(3), p.repr_map.kind(0)) {
            (ArithKind::FixedExact(fc2), ArithKind::FixedExact(c1)) => {
                assert!(fc2.i_bits >= 6, "fc2 i_bits {}", fc2.i_bits);
                assert!(c1.i_bits <= 5, "conv1 i_bits {}", c1.i_bits);
            }
            _ => panic!("expected fixed-point layers"),
        }
    }
}

#[test]
fn unmeetable_budget_still_yields_a_concrete_front() {
    // an impossible budget (accuracy 1.5) can never be met; the
    // search must still emit a usable front instead of failing, and
    // `best_within` must answer honestly
    let (mut ev, ranges) = setup(60);
    let opts = ExploreOpts {
        accuracy_bound: 0.05,
        frac_bci: (4, 5),
        int_headroom: 0,
        families: vec![Family::Fixed],
        ..Default::default()
    };
    let front = Explorer::new(ev.spec().clone())
        .opts(opts)
        .ranges(ranges)
        .budget(1.5)
        .max_sims(2)
        .run(&mut ev)
        .unwrap();
    assert!(front.best_within(1.5).is_none(),
            "nothing can meet an accuracy budget above 1.0");
    assert!(!front.points().is_empty(),
            "the front must survive an unmeetable budget");
    for p in front.points() {
        for l in p.repr_map.kinds() {
            assert!(matches!(l, ArithKind::FixedExact(_)));
        }
    }
    assert!(front.sims() <= 2, "sims {}", front.sims());
}

#[test]
fn rust_and_python_table1_ranges_agree() {
    let art = ArtifactDir::discover().unwrap();
    let model =
        Model::load(NetSpec::paper_dcnn(), &art.weights_path()).unwrap();
    let ds = Dataset::load(&art.dataset_path()).unwrap();
    // same 2000-image slice the python dump used
    let ranges = profile_ranges(&model, &ds, 2_000, 0);
    let dev = lop::coordinator::ranges::compare_with_python(
        &ranges,
        &art.ranges_path(),
    )
    .unwrap();
    assert!(dev < 1e-2, "rust/python range deviation {dev}");
}
