//! §4.2 exploration strategy over real artifacts: the two-pass greedy
//! search must find a configuration within the accuracy bound and cheaper
//! than the float32 baseline.
//!
//! Exercises the deprecated `explore` shim on purpose — it pins the
//! verbatim paper procedure until the shim is removed; the surrogate
//! explorer has its own suite (`pareto_explorer.rs`).
#![allow(deprecated)]

use lop::approx::arith::ArithKind;
use lop::coordinator::eval::Evaluator;
use lop::coordinator::explorer::{explore, ExploreOpts, Family};
use lop::coordinator::ranges::profile_ranges;
use lop::data::Dataset;
use lop::hw::datapath::{Datapath, ARRIA10, N_PE};
use lop::nn::network::Model;
use lop::nn::spec::NetSpec;
use lop::runtime::{ArtifactDir, ModelRunner};

fn setup(subset: usize) -> (Evaluator, Vec<lop::nn::network::LayerRanges>) {
    let art = ArtifactDir::discover().expect("run `make artifacts`");
    let model =
        Model::load(NetSpec::paper_dcnn(), &art.weights_path()).unwrap();
    let ds = Dataset::load(&art.dataset_path()).unwrap();
    let ranges = profile_ranges(&model, &ds, 500, 0);
    let runner = ModelRunner::new(art).unwrap();
    let model2 = Model::load(NetSpec::paper_dcnn(),
                             &runner.art.weights_path())
        .unwrap();
    (Evaluator::new(model2, Some(runner), ds, subset, 0), ranges)
}

#[test]
fn explore_finds_config_within_bound_and_cheaper_than_f32() {
    let (mut ev, ranges) = setup(200);
    let opts = ExploreOpts {
        accuracy_bound: 0.02,
        frac_bci: (6, 9),
        int_headroom: 1,
        families: vec![Family::Fixed],
        second_pass: true,
        ..Default::default()
    };
    let res = explore(&mut ev, &ranges, &opts).unwrap();

    // accuracy within bound on the evaluation subset
    assert!(
        res.accuracy >= res.baseline * (1.0 - opts.accuracy_bound) - 1e-9,
        "chosen {} acc {} vs baseline {}",
        res.chosen.name(), res.accuracy, res.baseline
    );
    // every chosen layer is fixed point and cheaper than float32
    let f32cost = Datapath::synthesize(&ArithKind::Float32, N_PE)
        .explore_cost(&ARRIA10);
    for l in res.chosen.kinds() {
        assert!(matches!(l, ArithKind::FixedExact(_)), "layer {l:?}");
        let c = Datapath::synthesize(l, N_PE).explore_cost(&ARRIA10);
        assert!(c < f32cost, "{} not cheaper than float32", l.name());
    }
    // the trace marks exactly one chosen candidate per part in pass 1
    for part in 0..4 {
        let chosen: Vec<_> = res
            .trace
            .iter()
            .filter(|t| t.part == part && t.pass == 1 && t.chosen)
            .collect();
        assert_eq!(chosen.len(), 1, "part {part}");
    }
    // memoization kept the eval count sane: <= candidates * parts + extras
    assert!(res.evals <= 120, "evals {}", res.evals);
}

#[test]
fn pass2_never_hurts_accuracy() {
    let (mut ev, ranges) = setup(150);
    let opts = ExploreOpts {
        accuracy_bound: 0.03,
        frac_bci: (5, 8),
        int_headroom: 1,
        families: vec![Family::Fixed],
        second_pass: true,
        ..Default::default()
    };
    let res = explore(&mut ev, &ranges, &opts).unwrap();
    assert!(
        res.accuracy >= res.pass1_accuracy - 1e-9,
        "pass 2 degraded accuracy: {} -> {}",
        res.pass1_accuracy, res.accuracy
    );
}

#[test]
fn integral_bits_respect_ranges() {
    let (mut ev, ranges) = setup(100);
    let opts = ExploreOpts {
        accuracy_bound: 0.05,
        frac_bci: (6, 7),
        int_headroom: 1,
        families: vec![Family::Fixed],
        second_pass: false,
        ..Default::default()
    };
    let res = explore(&mut ev, &ranges, &opts).unwrap();
    // FC2 range is ~±36 -> needs >= 6 integral bits; CONV1 ~±1 -> small
    match (res.chosen.kind(3), res.chosen.kind(0)) {
        (ArithKind::FixedExact(fc2), ArithKind::FixedExact(c1)) => {
            assert!(fc2.i_bits >= 6, "fc2 i_bits {}", fc2.i_bits);
            assert!(c1.i_bits <= 3, "conv1 i_bits {}", c1.i_bits);
        }
        _ => panic!("expected fixed-point layers"),
    }
}

#[test]
fn infeasible_bound_falls_back_to_max_accuracy() {
    // an impossible bound (better than baseline + 50%) makes every
    // candidate infeasible; pass 1 must fall back to the most accurate
    // candidate instead of panicking
    let (mut ev, ranges) = setup(60);
    let opts = ExploreOpts {
        accuracy_bound: -0.5, // floor = 1.5 * baseline: unreachable
        frac_bci: (4, 5),
        int_headroom: 0,
        families: vec![Family::Fixed],
        second_pass: false,
        ..Default::default()
    };
    let res = explore(&mut ev, &ranges, &opts).unwrap();
    assert!(res.trace.iter().all(|t| !t.feasible || t.pass == 2));
    // it still returns a concrete fixed-point configuration
    for l in res.chosen.kinds() {
        assert!(matches!(l, ArithKind::FixedExact(_)));
    }
}

#[test]
fn rust_and_python_table1_ranges_agree() {
    let art = ArtifactDir::discover().unwrap();
    let model =
        Model::load(NetSpec::paper_dcnn(), &art.weights_path()).unwrap();
    let ds = Dataset::load(&art.dataset_path()).unwrap();
    // same 2000-image slice the python dump used
    let ranges = profile_ranges(&model, &ds, 2_000, 0);
    let dev = lop::coordinator::ranges::compare_with_python(
        &ranges,
        &art.ranges_path(),
    )
    .unwrap();
    assert!(dev < 1e-2, "rust/python range deviation {dev}");
}
