//! Load-shaping integration tests over a real `Server` worker pool —
//! hermetic (synthetic weights, engine backend, no artifacts): typed
//! failure outcomes, admission accounting, overload policies and
//! queueing deadlines, end to end.
//!
//! The overload tests hold the queue open deterministically instead of
//! racing the worker: with `max_batch = 2`, `max_wait = 5s` and one
//! queued request, the batcher is not ready (length 1 < 2, release is
//! seconds away), so the queue stays at its high-water mark until
//! `shutdown()` flushes the partial batch.

use lop::coordinator::batcher::{FailureKind, Outcome};
use lop::coordinator::router::{OverloadPolicy, SubmitError};
use lop::coordinator::server::{Server, ServerOpts};
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

fn small_spec() -> NetSpec {
    NetSpec::parse("28x28x1: dense(8)+relu | dense(10)").unwrap()
}

fn cfg(spec: &NetSpec, s: &str) -> ReprMap {
    ReprMap::parse_for(spec, s).unwrap()
}

/// `hold = true` parks one request in the queue for seconds (see the
/// module docs) so capacity-1 overflow behavior is race-free.
fn serve_opts(configs: Vec<ReprMap>, policy: OverloadPolicy,
              capacity: usize, hold: bool,
              deadline: Option<Duration>) -> ServerOpts {
    ServerOpts {
        configs,
        max_batch: if hold { 2 } else { 4 },
        max_wait: if hold {
            Duration::from_secs(5)
        } else {
            Duration::from_millis(1)
        },
        queue_capacity: capacity,
        engine_workers: 1,
        engine_gemm_threads: 1,
        use_pjrt: false, // hermetic: engine backend only
        overload: policy,
        deadline,
        ..ServerOpts::default()
    }
}

fn start(opts: ServerOpts, seed: u64) -> Server {
    let spec = small_spec();
    let model = Arc::new(Model::synthetic(spec, seed));
    Server::start_with_model(opts, model, None).unwrap()
}

fn img() -> Vec<f32> {
    vec![0.1; 784]
}

#[test]
fn empty_configs_is_a_startup_error() {
    let model = Arc::new(Model::synthetic(small_spec(), 3));
    let err = Server::start_with_model(
        ServerOpts { configs: vec![], use_pjrt: false,
                     ..ServerOpts::default() },
        model,
        None,
    )
    .err()
    .expect("a server with nothing to serve must not start");
    assert!(format!("{err:#}").contains("configs is empty"),
            "{err:#}");
}

#[test]
fn submit_after_shutdown_is_shutting_down_not_overload() {
    let spec = small_spec();
    let opts = serve_opts(vec![cfg(&spec, "FI(6,8)")],
                          OverloadPolicy::Reject, 64, false, None);
    let server = start(opts, 5);
    let router = server.router.clone();
    let metrics = server.metrics.clone();
    server.shutdown().unwrap();
    let (tx, _rx) = channel();
    assert_eq!(router.submit(0, img(), None, tx),
               Err(SubmitError::ShuttingDown));
    assert_eq!(metrics.rejected.get(), 0,
               "drain refusals must not count as overload");
}

#[test]
fn backend_failures_are_typed_counted_and_excluded_from_latency() {
    let spec = small_spec();
    let mut opts = serve_opts(vec![cfg(&spec, "FI(6,8)")],
                              OverloadPolicy::Reject, 64, false, None);
    opts.inject_backend_failures = true;
    let server = start(opts, 7);
    let (tx, rx) = channel();
    for _ in 0..5 {
        server.router.submit(0, img(), None, tx.clone()).unwrap();
    }
    drop(tx);
    for _ in 0..5 {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.outcome, Outcome::Error(FailureKind::Backend));
        assert_eq!(r.pred(), None);
        assert!(!r.is_ok());
    }
    let m = &server.metrics;
    assert_eq!(m.backend_failures.get(), 5);
    assert_eq!(m.completed.get(), 0,
               "failures must not count as completions");
    assert_eq!(m.percentile_us(99.0), 0,
               "failures must stay out of the latency buckets");
    assert_eq!(m.mean_latency_us(), 0.0);
    server.shutdown().unwrap();
}

#[test]
fn reject_policy_counts_every_refusal() {
    let spec = small_spec();
    let server = start(serve_opts(vec![cfg(&spec, "FI(6,8)")],
                                  OverloadPolicy::Reject, 1, true,
                                  None),
                       11);
    let (tx, rx) = channel();
    server.router.submit(0, img(), None, tx.clone()).unwrap();
    assert_eq!(server.router.submit(0, img(), None, tx.clone()),
               Err(SubmitError::Overloaded));
    assert_eq!(server.router.submit(0, img(), None, tx.clone()),
               Err(SubmitError::Overloaded));
    drop(tx);
    let metrics = server.metrics.clone();
    server.shutdown().unwrap(); // flushes the held partial batch
    let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(r.is_ok());
    assert_eq!(metrics.submitted.get(), 1,
               "submitted counts accepted admissions only");
    assert_eq!(metrics.rejected.get(), 2);
    assert_eq!(metrics.completed.get(), 1);
}

#[test]
fn shed_policy_drops_newest_with_a_typed_answer() {
    let spec = small_spec();
    let server = start(serve_opts(vec![cfg(&spec, "FI(6,8)")],
                                  OverloadPolicy::Shed, 1, true, None),
                       13);
    let (tx, rx) = channel();
    for _ in 0..4 {
        // all four are accepted: one queued, three shed at the door
        server.router.submit(0, img(), None, tx.clone()).unwrap();
    }
    drop(tx);
    for _ in 0..3 {
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r.outcome, Outcome::Error(FailureKind::Shed));
    }
    let metrics = server.metrics.clone();
    server.shutdown().unwrap();
    let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(r.is_ok(), "the queued request is served on drain");
    let m = &metrics;
    assert_eq!(m.shed.get(), 3);
    assert_eq!(m.expired.get(), 0);
    // the accounting identity: every accepted request resolves once
    assert_eq!(
        m.submitted.get(),
        m.completed.get()
            + m.shed.get()
            + m.expired.get()
            + m.backend_failures.get()
    );
}

#[test]
fn degrade_policy_reroutes_to_the_cheaper_config() {
    let spec = small_spec();
    // FL(4,9) (float-lattice PEs) sits above binxnor (LUT popcount)
    // on the hw-cost ladder
    let configs =
        vec![cfg(&spec, "FL(4,9)"), cfg(&spec, "binxnor")];
    let server = start(serve_opts(configs, OverloadPolicy::Degrade, 1,
                                  true, None),
                       17);
    assert_eq!(server.router.ladder(0), &[1]);
    let (tx, rx) = channel();
    server.router.submit(0, img(), None, tx.clone()).unwrap();
    // queue 0 full → re-routed to binxnor's queue, still accepted
    server.router.submit(0, img(), None, tx.clone()).unwrap();
    // every rung full → refused
    assert_eq!(server.router.submit(0, img(), None, tx.clone()),
               Err(SubmitError::Overloaded));
    drop(tx);
    let metrics = server.metrics.clone();
    server.shutdown().unwrap();
    for _ in 0..2 {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(r.is_ok(), "degraded requests are served, not dropped");
    }
    assert_eq!(metrics.submitted.get(), 2);
    assert_eq!(metrics.degraded.get(), 1);
    assert_eq!(metrics.rejected.get(), 1);
    assert_eq!(metrics.completed.get(), 2);
}

#[test]
fn queueing_deadlines_expire_and_per_request_overrides_win() {
    let spec = small_spec();
    // a 1ns server-wide default: every defaulted request has expired
    // by the time the batcher first sees it
    let mut opts = serve_opts(vec![cfg(&spec, "FI(6,8)")],
                              OverloadPolicy::Reject, 64, false,
                              Some(Duration::from_nanos(1)));
    opts.max_batch = 1; // release immediately once admitted
    let server = start(opts, 19);
    let (tx, rx) = channel();
    server.router.submit(0, img(), None, tx.clone()).unwrap();
    let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    assert_eq!(r.outcome, Outcome::Error(FailureKind::Expired));
    assert_eq!(r.pred(), None);
    // a generous per-request deadline overrides the server default
    server
        .router
        .submit(0, img(), Some(Duration::from_secs(3600)), tx.clone())
        .unwrap();
    drop(tx);
    let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(r.is_ok(), "a live deadline must not expire: {:?}",
            r.outcome);
    let m = &server.metrics;
    assert_eq!(m.expired.get(), 1);
    assert_eq!(m.completed.get(), 1);
    server.shutdown().unwrap();
}
