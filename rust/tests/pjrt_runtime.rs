//! End-to-end integration of the AOT bridge: HLO-text artifacts produced
//! by python/jax (`make artifacts`) load, compile and execute on the rust
//! PJRT CPU client, and their numerics agree with the bit-accurate Rust
//! engine.

use lop::approx::arith::ArithKind;
use lop::data::Dataset;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::runtime::{ArtifactDir, ModelRunner};

fn cfg(s: &str) -> ReprMap {
    ReprMap::parse_for(&NetSpec::paper_dcnn(), s).unwrap()
}

fn setup() -> (ModelRunner, Model, Dataset) {
    let art = ArtifactDir::discover().expect("run `make artifacts` first");
    let model =
        Model::load(NetSpec::paper_dcnn(), &art.weights_path()).unwrap();
    let ds = Dataset::load(&art.dataset_path()).unwrap();
    let runner = ModelRunner::new(art).unwrap();
    (runner, model, ds)
}

#[test]
fn pjrt_f32_matches_bit_accurate_engine() {
    let (mut runner, model, ds) = setup();
    let idx: Vec<usize> = (0..32).collect();
    let x = ds.batch(&ds.test, &idx);

    let c = ReprMap::uniform(ArithKind::Float32, 4);
    let pjrt = runner.forward(&c, &x).unwrap();
    let eng = model.prepare(&c).forward(&x, 0);

    assert_eq!(pjrt.shape, vec![32, 10]);
    let mut max_diff = 0f32;
    for (a, b) in pjrt.data.iter().zip(&eng.data) {
        max_diff = max_diff.max((a - b).abs());
    }
    // same math, different accumulation order: logits are O(10), so 1e-3
    // slack is ~1e-4 relative
    assert!(max_diff < 1e-3, "max logit diff {max_diff}");
    assert_eq!(pjrt.argmax_rows(), eng.argmax_rows());
}

#[test]
fn pjrt_fi_matches_bit_accurate_engine() {
    let (mut runner, model, ds) = setup();
    let idx: Vec<usize> = (32..64).collect();
    let x = ds.batch(&ds.test, &idx);

    let c = cfg("FI(5,8)|FI(5,8)|FI(6,8)|FI(6,8)");
    let pjrt = runner.forward(&c, &x).unwrap();
    let eng = model.prepare(&c).forward(&x, 0);

    let mut max_diff = 0f32;
    for (a, b) in pjrt.data.iter().zip(&eng.data) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-3, "max logit diff {max_diff}");
}

#[test]
fn pjrt_fl_matches_bit_accurate_engine() {
    let (mut runner, model, ds) = setup();
    let idx: Vec<usize> = (64..96).collect();
    let x = ds.batch(&ds.test, &idx);

    let c = cfg("FL(4,9)");
    let pjrt = runner.forward(&c, &x).unwrap();
    let eng = model.prepare(&c).forward(&x, 0);

    let mut max_diff = 0f32;
    for (a, b) in pjrt.data.iter().zip(&eng.data) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-3, "max logit diff {max_diff}");
}

#[test]
fn pjrt_batch_padding_consistent() {
    // a 5-image batch (padded to 16) must equal 5 single-image calls
    let (mut runner, _, ds) = setup();
    let c = ReprMap::uniform(ArithKind::Float32, 4);
    let idx: Vec<usize> = (0..5).collect();
    let x = ds.batch(&ds.test, &idx);
    let batched = runner.forward(&c, &x).unwrap();
    for (i, &ii) in idx.iter().enumerate() {
        let xi = ds.batch(&ds.test, &[ii]);
        let single = runner.forward(&c, &xi).unwrap();
        for j in 0..10 {
            let d = (batched.data[i * 10 + j] - single.data[j]).abs();
            assert!(d < 1e-4, "img {i} logit {j} diff {d}");
        }
    }
}

#[test]
fn executable_cache_reuse() {
    let (mut runner, _, ds) = setup();
    let c = ReprMap::uniform(ArithKind::Float32, 4);
    let x = ds.batch(&ds.test, &[0]);
    runner.forward(&c, &x).unwrap();
    let after_first = runner.cached_executables();
    runner.forward(&c, &x).unwrap();
    runner.forward(&c, &x).unwrap();
    assert_eq!(runner.cached_executables(), after_first,
               "repeat calls must not recompile");
}

#[test]
fn pjrt_f32_accuracy_matches_training_baseline() {
    let (mut runner, _, ds) = setup();
    let baseline = runner.art.baseline_accuracy;
    let n = 512.min(ds.test.len());
    let idx: Vec<usize> = (0..n).collect();
    let x = ds.batch(&ds.test, &idx);
    let c = ReprMap::uniform(ArithKind::Float32, 4);
    let pred = runner.forward(&c, &x).unwrap().argmax_rows();
    let labels = Dataset::labels(&ds.test);
    let correct = pred.iter().zip(&labels).filter(|(p, l)| p == l).count();
    let acc = correct as f64 / n as f64;
    assert!(
        (acc - baseline).abs() < 0.05,
        "pjrt accuracy {acc} far from training baseline {baseline}"
    );
}
